"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks every kernel against.
They intentionally use the most obvious formulation (gathers, argsort)
rather than the tiled/branch-free forms the kernels use.
"""

import jax.numpy as jnp

# Character codes, base-5 per the paper (§IV-B): $=0, A=1, C=2, G=3, T=4.
ALPHABET = "$ACGT"
BASE = 5


def prefix_encode_ref(reads_pad, prefix_len):
    """keys[r, o] = base-5 value of reads_pad[r, o : o + prefix_len].

    reads_pad: [R, Lp + prefix_len] int32 codes in 0..4, zero ($) padded.
    Returns [R, Lp] int64.
    """
    r, total = reads_pad.shape
    lp = total - prefix_len
    x = reads_pad.astype(jnp.int64)
    keys = jnp.zeros((r, lp), dtype=jnp.int64)
    for j in range(prefix_len):
        keys = keys * BASE + x[:, j : j + lp]
    return keys


def bucket_ref(keys, boundaries):
    """partition[i] = #{b : keys[i] >= boundaries[b]} (searchsorted right).

    keys: any int64 shape; boundaries: [NB] sorted int64. Returns int32.
    """
    return jnp.searchsorted(boundaries, keys, side="right").astype(jnp.int32)


def pair_sort_ref(keys, indexes):
    """Sort (key, index) pairs lexicographically. 1-D int64 arrays."""
    order = jnp.lexsort((indexes, keys))
    return keys[order], indexes[order]


def sort_ref(keys):
    """Plain ascending sort of 1-D int64 keys."""
    return jnp.sort(keys)


def encode_string(s, prefix_len):
    """Host-side helper: base-5 key of the first prefix_len chars of s,
    zero-padded — mirrors the paper's fixed-width numeric prefix."""
    v = 0
    for j in range(prefix_len):
        c = ALPHABET.index(s[j]) if j < len(s) else 0
        v = v * BASE + c
    return v

"""L1 Pallas kernel: in-VMEM bitonic sort of (key, index) pairs.

The reducer's sorting-group hot loop (paper §IV-C): a group of suffix keys
plus their packed indexes must be sorted entirely in memory. The VMEM block
plays the role the reducer heap plays in the paper — the group must fit, or
the caller splits it (longer prefix ⇒ smaller groups, Fig. 7).

Bitonic network: for N a power of two, log2(N) stages of compare-exchange
steps, each fully data-parallel — element i exchanges with partner i^j via
a take_along_axis shuffle and a branch-free select. Pairs are ordered
lexicographically by (key, index); because packed suffix indexes are unique
per entry, the order is total (callers padding to N must pad with unique
indexes, e.g. i64::MAX - i — the Rust runtime does).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stages(n):
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


def _pair_sort_body(keys, idxs, n):
    """One [1, N] bitonic pair sort, fully unrolled (static N)."""
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    for k, j in _stages(n):
        partner = pos ^ j
        pk = jnp.take_along_axis(keys, partner.astype(jnp.int32), axis=1)
        pi = jnp.take_along_axis(idxs, partner.astype(jnp.int32), axis=1)
        # ascending iff bit k of position is clear (uniform final stage).
        take_lesser = ((pos & k) == 0) == ((pos & j) == 0)
        self_lt = (keys < pk) | ((keys == pk) & (idxs < pi))
        choose_self = self_lt == take_lesser
        keys = jnp.where(choose_self, keys, pk)
        idxs = jnp.where(choose_self, idxs, pi)
    return keys, idxs


def _pair_kernel(k_ref, i_ref, ok_ref, oi_ref):
    n = k_ref.shape[1]
    keys, idxs = _pair_sort_body(k_ref[...], i_ref[...], n)
    ok_ref[...] = keys
    oi_ref[...] = idxs


def pair_sort(keys, indexes):
    """Sort 1-D int64 (key, index) pairs lexicographically. len power of 2."""
    (n,) = keys.shape
    if n & (n - 1):
        raise ValueError(f"bitonic sort needs power-of-two length, got {n}")
    ks, ix = pl.pallas_call(
        _pair_kernel,
        in_specs=[
            pl.BlockSpec((1, n), lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int64),
            jax.ShapeDtypeStruct((1, n), jnp.int64),
        ],
        interpret=True,
    )(keys[None, :], indexes[None, :])
    return ks[0], ix[0]


def sort(keys):
    """Plain ascending bitonic sort of 1-D int64 keys (len power of two).

    Ties are broken internally by position, so the result equals jnp.sort.
    """
    (n,) = keys.shape
    idx = jnp.arange(n, dtype=jnp.int64)
    ks, _ = pair_sort(keys, idx)
    return ks

"""L1 Pallas kernel: batched base-5 suffix-prefix encoding.

The scheme's map phase turns every (read, offset) suffix into a fixed-width
numeric sort key (paper §IV-B): the first `prefix_len` characters, base-5
($=0 A=1 C=2 G=3 T=4), packed into one int64. A suffix shorter than the
prefix is zero-padded, which *is* the paper's "the prefix is the suffix
itself" rule because $ = 0.

Kernel shape strategy (see DESIGN.md §Hardware-Adaptation): instead of one
gather per (read, offset) pair, a read tile of shape [RT, Lp + P] sits in
VMEM and the P-step Horner chain runs as P static slices — key[r, o] =
key*5 + tile[r, o + j]. No gathers, pure VPU integer multiply-add; the
offset dimension is fully vectorized along lanes.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both the pytest
oracle run and the Rust PJRT runtime execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BASE = 5


def _encode_kernel(x_ref, o_ref, *, prefix_len, lp):
    """One [RT, Lp+P] read tile -> one [RT, Lp] key tile (Horner chain)."""
    x = x_ref[...].astype(jnp.int64)
    acc = jnp.zeros((x.shape[0], lp), dtype=jnp.int64)
    for j in range(prefix_len):
        acc = acc * BASE + x[:, j : j + lp]
    o_ref[...] = acc


def prefix_encode(reads_pad, prefix_len, row_tile=None):
    """keys[r, o] = base-5 value of reads_pad[r, o : o + prefix_len].

    reads_pad: [R, Lp + prefix_len] int32 codes in 0..4 ($ padded).
    Returns [R, Lp] int64. `row_tile` picks the VMEM block height.
    """
    r, total = reads_pad.shape
    lp = total - prefix_len
    if lp <= 0:
        raise ValueError(f"padded width {total} <= prefix_len {prefix_len}")
    rt = row_tile or min(r, 128)
    if r % rt != 0:
        raise ValueError(f"rows {r} not divisible by row tile {rt}")
    kern = functools.partial(_encode_kernel, prefix_len=prefix_len, lp=lp)
    return pl.pallas_call(
        kern,
        grid=(r // rt,),
        in_specs=[pl.BlockSpec((rt, total), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, lp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, lp), jnp.int64),
        interpret=True,
    )(reads_pad)

"""L1 Pallas kernel: branch-free range partitioner (searchsorted).

The scheme partitions suffix keys to reducers by sampled range boundaries
(paper §IV-A, the TotalOrderPartitioner analog). With NB boundaries the
partition id of key k is |{b : k >= boundary_b}| — computed branch-free as
a broadcast compare + sum so the whole [RT, Lp] key tile is processed in
one VPU pass; no binary-search control flow.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bucket_kernel(k_ref, b_ref, o_ref):
    keys = k_ref[...]
    bounds = b_ref[...]
    # [RT, Lp, NB] compare; NB is small (reducer count), so this stays in VMEM.
    ge = keys[:, :, None] >= bounds[None, None, :]
    o_ref[...] = jnp.sum(ge.astype(jnp.int32), axis=-1)


def bucket(keys, boundaries, row_tile=None):
    """partition[r, o] = searchsorted-right(boundaries, keys[r, o]).

    keys: [R, Lp] int64; boundaries: [NB] sorted int64. Returns int32.
    """
    r, lp = keys.shape
    (nb,) = boundaries.shape
    rt = row_tile or min(r, 128)
    if r % rt != 0:
        raise ValueError(f"rows {r} not divisible by row tile {rt}")
    return pl.pallas_call(
        _bucket_kernel,
        grid=(r // rt,),
        in_specs=[
            pl.BlockSpec((rt, lp), lambda i: (i, 0)),
            pl.BlockSpec((nb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rt, lp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, lp), jnp.int32),
        interpret=True,
    )(keys, boundaries)

"""L2 — the JAX compute graph the Rust coordinator executes via PJRT.

Three exported entry points (all shapes static at lowering time):

  map_encode(reads_pad, seqnos, lengths, boundaries)
      -> (keys, indexes, partitions, valid)
    The map-task inner loop of the paper's scheme (§IV-A/B): every suffix of
    every read in the tile gets its base-5 prefix key, its packed index
    seq*1000 + offset, its shuffle partition, and a validity flag
    (offset <= read length; offset == length is the lone-"$" suffix).

  sample_sort(keys) -> sorted_keys
    Bitonic sort used by the boundary sampler (10000*n samples, §IV-A).

  group_sort(keys, indexes) -> (sorted_keys, sorted_indexes)
    The reducer sorting-group kernel: sort (key, index) pairs.

Python only runs at build time; `aot.py` lowers these to HLO text under
artifacts/ and the Rust runtime loads them from there.
"""

import jax.numpy as jnp

from compile.kernels import bitonic, bucket, prefix_encode

# The paper packs the suffix index as sequence_number * 1000 + offset
# because offsets range 0..200 (§IV-B). We keep the same constant, so the
# padded read width must stay under it.
OFFSET_RADIX = 1000


def map_encode(reads_pad, seqnos, lengths, boundaries, *, prefix_len):
    """Encode every suffix of a read tile.

    reads_pad:  [R, Lp + prefix_len] int32 codes 0..4 (0 = $/padding; a
                read of length l has codes at [0, l) and zeros after).
    seqnos:     [R] int64 global sequence numbers.
    lengths:    [R] int32 read lengths (characters, excluding $).
    boundaries: [NB] int64 sorted partition boundaries.

    Returns (keys [R, Lp] i64, indexes [R, Lp] i64,
             partitions [R, Lp] i32, valid [R, Lp] i32).
    """
    r, total = reads_pad.shape
    lp = total - prefix_len
    if lp >= OFFSET_RADIX:
        raise ValueError(f"padded width {lp} must be < {OFFSET_RADIX}")
    keys = prefix_encode.prefix_encode(reads_pad, prefix_len)
    parts = bucket.bucket(keys, boundaries)
    offs = jnp.arange(lp, dtype=jnp.int64)[None, :]
    indexes = seqnos[:, None] * OFFSET_RADIX + offs
    valid = (offs <= lengths.astype(jnp.int64)[:, None]).astype(jnp.int32)
    return keys, indexes, parts, valid


def sample_sort(keys):
    """Ascending sort of 1-D int64 keys (power-of-two length)."""
    return bitonic.sort(keys)


def group_sort(keys, indexes):
    """Lexicographic sort of (key, index) pairs (power-of-two length)."""
    return bitonic.pair_sort(keys, indexes)

"""AOT bridge: lower the L2 entry points to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Writes one .hlo.txt per (entry point, static shape) plus a manifest.txt the
Rust runtime reads to discover what is available:

    map_encode r=128 lp=208 p=23 nb=64 file=map_encode_r128_l208_p23_nb64.hlo.txt
    group_sort n=8192 file=group_sort_n8192.hlo.txt
    ...
"""

import argparse
import functools
import os

import jax

# Suffix keys are base-5^23 packed int64 (paper §IV-B uses `long` for
# prefix length 23); x64 must be on before any tracing happens.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# Static shape variants to export. Tuned for the Rust runtime's batching:
#   map_encode: (reads-per-tile, padded width, prefix length, boundaries)
#   group_sort / sample_sort: power-of-two block lengths.
MAP_ENCODE_VARIANTS = [
    # (R, Lp, P, NB) — Lp must be >= max read length + 1 and < 1000.
    # NB=16 variants: the bucket kernel's compare volume is R×Lp×NB, so
    # small-reducer-count jobs (the common case) use 4x less VPU work
    # (§Perf iteration 1); NB=64 kept for wide jobs.
    (512, 208, 23, 16),  # paper setting: ~200 bp reads, prefix 23
    (512, 104, 23, 16),  # ~100 bp reads (example-scale corpora)
    (128, 208, 23, 64),
    (128, 104, 23, 64),
    (64, 104, 13, 64),   # paper's `int` threshold example: prefix 13
    (512, 104, 13, 16),
]
GROUP_SORT_VARIANTS = [1024, 2048, 4096, 8192]
SAMPLE_SORT_VARIANTS = [4096]


def to_hlo_text(lowered):
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_map_encode(r, lp, p, nb):
    fn = functools.partial(model.map_encode, prefix_len=p)
    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((r, lp + p), jnp.int32),   # reads_pad
        jax.ShapeDtypeStruct((r,), jnp.int64),          # seqnos
        jax.ShapeDtypeStruct((r,), jnp.int32),          # lengths
        jax.ShapeDtypeStruct((nb,), jnp.int64),         # boundaries
    )


def lower_group_sort(n):
    return jax.jit(model.group_sort).lower(
        jax.ShapeDtypeStruct((n,), jnp.int64),
        jax.ShapeDtypeStruct((n,), jnp.int64),
    )


def lower_sample_sort(n):
    return jax.jit(model.sample_sort).lower(
        jax.ShapeDtypeStruct((n,), jnp.int64)
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []

    def emit(name, lowered, entry, **meta):
        fname = name + ".hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        kv = " ".join(f"{k}={v}" for k, v in meta.items())
        manifest.append(f"{entry} {kv} file={fname}")
        print(f"wrote {fname} ({len(text)} chars)")

    for r, lp, p, nb in MAP_ENCODE_VARIANTS:
        emit(
            f"map_encode_r{r}_l{lp}_p{p}_nb{nb}",
            lower_map_encode(r, lp, p, nb),
            entry="map_encode", r=r, lp=lp, p=p, nb=nb,
        )
    for n in GROUP_SORT_VARIANTS:
        emit(f"group_sort_n{n}", lower_group_sort(n), entry="group_sort", n=n)
    for n in SAMPLE_SORT_VARIANTS:
        emit(f"sample_sort_n{n}", lower_sample_sort(n), entry="sample_sort", n=n)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest.txt ({len(manifest)} entries)")


if __name__ == "__main__":
    main()

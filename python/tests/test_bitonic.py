"""L1 bitonic pair-sort kernel vs lexsort oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bitonic, ref


@settings(max_examples=20, deadline=None)
@given(
    logn=st.integers(0, 9),
    seed=st.integers(0, 2**32 - 1),
)
def test_pair_sort_matches_lexsort(logn, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 50, size=n, dtype=np.int64)  # force duplicates
    idxs = rng.permutation(n).astype(np.int64)
    gk, gi = bitonic.pair_sort(jnp.asarray(keys), jnp.asarray(idxs))
    wk, wi = ref.pair_sort_ref(jnp.asarray(keys), jnp.asarray(idxs))
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


@settings(max_examples=20, deadline=None)
@given(logn=st.integers(0, 10), seed=st.integers(0, 2**32 - 1))
def test_sort_matches_jnp_sort(logn, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    keys = rng.integers(-(5**13), 5**13, size=n, dtype=np.int64)
    got = bitonic.sort(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got), np.sort(keys))


def test_sentinel_padding():
    # Rust pads short groups with (i64::MAX, unique index); sentinels must
    # sink to the tail and leave the real prefix sorted.
    real_k = np.asarray([7, 3, 3, 1], dtype=np.int64)
    real_i = np.asarray([70, 31, 30, 10], dtype=np.int64)
    pad = 4
    keys = np.concatenate([real_k, np.full(pad, np.iinfo(np.int64).max)])
    idxs = np.concatenate([real_i, np.iinfo(np.int64).max - np.arange(pad)])
    gk, gi = bitonic.pair_sort(jnp.asarray(keys), jnp.asarray(idxs))
    np.testing.assert_array_equal(np.asarray(gk[:4]), [1, 3, 3, 7])
    np.testing.assert_array_equal(np.asarray(gi[:4]), [10, 30, 31, 70])


def test_rejects_non_power_of_two():
    import pytest

    with pytest.raises(ValueError):
        bitonic.sort(jnp.zeros((12,), dtype=jnp.int64))

"""L2 model entry points: shapes, packing, end-to-end suffix ordering."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def make_batch(rng, r, lp, p):
    reads = np.zeros((r, lp + p), dtype=np.int32)
    lens = rng.integers(1, lp, size=r).astype(np.int32)
    for i, l in enumerate(lens):
        reads[i, :l] = rng.integers(1, 5, size=l)
    seqs = np.arange(r, dtype=np.int64) + 1000 * rng.integers(0, 50)
    return reads, seqs, lens


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_map_encode_shapes_and_packing(seed):
    r, lp, p, nb = 8, 24, 5, 16
    rng = np.random.default_rng(seed)
    reads, seqs, lens = make_batch(rng, r, lp, p)
    bounds = np.sort(rng.integers(0, 5**p, size=nb, dtype=np.int64))
    keys, idxs, parts, valid = model.map_encode(
        jnp.asarray(reads), jnp.asarray(seqs), jnp.asarray(lens),
        jnp.asarray(bounds), prefix_len=p,
    )
    assert keys.shape == (r, lp) and keys.dtype == jnp.int64
    assert idxs.shape == (r, lp) and idxs.dtype == jnp.int64
    assert parts.shape == (r, lp) and parts.dtype == jnp.int32
    assert valid.shape == (r, lp) and valid.dtype == jnp.int32

    idxs, keys, parts, valid = map(np.asarray, (idxs, keys, parts, valid))
    # index packing: seq * 1000 + offset, recoverable by divmod (§IV-B)
    for i in range(r):
        for o in range(lp):
            assert idxs[i, o] // model.OFFSET_RADIX == seqs[i]
            assert idxs[i, o] % model.OFFSET_RADIX == o
    # validity: offsets 0..len inclusive (len = the "$" suffix)
    np.testing.assert_array_equal(
        valid, (np.arange(lp)[None, :] <= lens[:, None]).astype(np.int32)
    )
    # keys and partitions match the oracles
    np.testing.assert_array_equal(
        keys, np.asarray(ref.prefix_encode_ref(jnp.asarray(reads), p))
    )
    np.testing.assert_array_equal(
        parts, np.asarray(ref.bucket_ref(jnp.asarray(keys), jnp.asarray(bounds)))
    )


def test_suffix_order_equals_lexicographic():
    # End-to-end semantic check on a tiny corpus: sorting valid suffixes by
    # (prefix key, full-suffix text) must equal plain lexicographic order of
    # the suffix strings — the invariant the whole pipeline rests on.
    rng = np.random.default_rng(7)
    r, lp, p = 4, 12, 23  # p > lp: keys alone decide the total order
    reads, seqs, lens = make_batch(rng, r, lp, p)
    bounds = np.sort(rng.integers(0, 5**13, size=8, dtype=np.int64))
    keys, idxs, parts, valid = model.map_encode(
        jnp.asarray(reads), jnp.asarray(seqs), jnp.asarray(lens),
        jnp.asarray(bounds), prefix_len=p,
    )
    keys, idxs, valid = map(np.asarray, (keys, idxs, valid))

    entries = []
    for i in range(r):
        s = "".join(ref.ALPHABET[c] for c in reads[i, : lens[i]]) + "$"
        for o in range(lens[i] + 1):
            entries.append((keys[i, o], s[o:], idxs[i, o]))
    by_key = sorted(entries, key=lambda e: (e[0], e[1]))
    by_text = sorted(entries, key=lambda e: e[1])
    assert [e[2] for e in by_key] == [e[2] for e in by_text]
    # and with p=23 > every suffix length, the key alone is already total:
    assert [e[0] for e in by_key] == sorted(e[0] for e in entries)


def test_sample_and_group_sort_roundtrip():
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 100, size=256, dtype=np.int64)
    np.testing.assert_array_equal(
        np.asarray(model.sample_sort(jnp.asarray(keys))), np.sort(keys)
    )
    idxs = rng.permutation(256).astype(np.int64)
    gk, gi = model.group_sort(jnp.asarray(keys), jnp.asarray(idxs))
    wk, wi = ref.pair_sort_ref(jnp.asarray(keys), jnp.asarray(idxs))
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))

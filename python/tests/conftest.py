import os
import sys

import jax

# int64 suffix keys everywhere (see compile/aot.py).
jax.config.update("jax_enable_x64", True)

# Make `import compile...` work when pytest is invoked from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

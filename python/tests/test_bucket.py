"""L1 bucket (range partitioner) kernel vs searchsorted oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bucket, ref


@settings(max_examples=30, deadline=None)
@given(
    r=st.sampled_from([1, 4, 16]),
    lp=st.sampled_from([2, 8, 32]),
    nb=st.sampled_from([1, 3, 31, 64]),
    seed=st.integers(0, 2**32 - 1),
)
def test_matches_searchsorted(r, lp, nb, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 5**13, size=(r, lp), dtype=np.int64)
    bounds = np.sort(rng.integers(0, 5**13, size=nb, dtype=np.int64))
    got = bucket.bucket(jnp.asarray(keys), jnp.asarray(bounds), row_tile=r)
    want = ref.bucket_ref(jnp.asarray(keys), jnp.asarray(bounds))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


def test_boundary_semantics():
    # key == boundary goes to the right bucket (searchsorted side="right"):
    # partition id counts boundaries <= key.
    keys = jnp.asarray([[0, 5, 9, 10, 11, 99]], dtype=jnp.int64)
    bounds = jnp.asarray([10, 50], dtype=jnp.int64)
    got = np.asarray(bucket.bucket(keys, bounds, row_tile=1))
    np.testing.assert_array_equal(got, [[0, 0, 0, 1, 1, 2]])


def test_padded_boundaries_are_inert():
    # The Rust runtime pads unused boundary slots with i64::MAX; partition
    # ids must be unaffected.
    keys = jnp.asarray([[3, 17, 200]], dtype=jnp.int64)
    b1 = jnp.asarray([10, 100], dtype=jnp.int64)
    b2 = jnp.concatenate([b1, jnp.full((6,), 2**62, dtype=jnp.int64)])
    g1 = np.asarray(bucket.bucket(keys, b1, row_tile=1))
    g2 = np.asarray(bucket.bucket(keys, b2, row_tile=1))
    np.testing.assert_array_equal(g1, g2)

"""L1 prefix_encode kernel vs pure-jnp oracle, swept by hypothesis."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import prefix_encode, ref


def random_reads(rng, r, lp, p):
    """[R, Lp + P] code matrix: random lengths, $-terminated, 0-padded."""
    out = np.zeros((r, lp + p), dtype=np.int32)
    lens = rng.integers(0, lp, size=r)  # length < Lp so offset==len is valid
    for i, l in enumerate(lens):
        out[i, :l] = rng.integers(1, 5, size=l)
    return out, lens.astype(np.int32)


@settings(max_examples=25, deadline=None)
@given(
    r=st.sampled_from([1, 2, 8]),
    lp=st.sampled_from([4, 16, 40]),
    p=st.sampled_from([1, 3, 13, 23]),
    seed=st.integers(0, 2**32 - 1),
)
def test_matches_ref(r, lp, p, seed):
    rng = np.random.default_rng(seed)
    reads, _ = random_reads(rng, r, lp, p)
    got = prefix_encode.prefix_encode(jnp.asarray(reads), p, row_tile=r)
    want = ref.prefix_encode_ref(jnp.asarray(reads), p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int64


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_tiled_equals_untiled(seed):
    rng = np.random.default_rng(seed)
    reads, _ = random_reads(rng, 16, 24, 5)
    a = prefix_encode.prefix_encode(jnp.asarray(reads), 5, row_tile=4)
    b = prefix_encode.prefix_encode(jnp.asarray(reads), 5, row_tile=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_known_string():
    # SINICA$-style check with the DNA alphabet: read "ACGT", P=4.
    # suffix at offset 0 = "ACGT" -> 1*125 + 2*25 + 3*5 + 4 = 194
    # suffix at offset 2 = "GT$"  -> 3*125 + 4*25 + 0 + 0    = 475
    codes = np.zeros((1, 6 + 4), dtype=np.int32)
    codes[0, :4] = [1, 2, 3, 4]
    keys = np.asarray(prefix_encode.prefix_encode(jnp.asarray(codes), 4, row_tile=1))
    assert keys[0, 0] == 194
    assert keys[0, 2] == 475
    assert keys[0, 4] == 0  # "$" suffix encodes to all-$ = 0
    assert keys[0, 0] == ref.encode_string("ACGT", 4)
    assert keys[0, 2] == ref.encode_string("GT$", 4)


def test_prefix_is_suffix_when_short():
    # Paper §IV-B: a suffix shorter than the prefix encodes as itself
    # ($ padded), so equal suffixes encode equal and need no re-sort.
    p = 10
    codes = np.zeros((2, 12 + p), dtype=np.int32)
    codes[0, :3] = [1, 3, 4]  # AGT
    codes[1, :3] = [1, 3, 4]
    keys = np.asarray(prefix_encode.prefix_encode(jnp.asarray(codes), p, row_tile=2))
    assert keys[0, 1] == keys[1, 1]  # "GT$" == "GT$"
    assert keys[0, 0] == ref.encode_string("AGT", p)


def test_max_key_fits_int64():
    # TTTT...T (23 chars) is the largest 23-prefix; must fit in i64.
    v = ref.encode_string("T" * 23, 23)
    assert v == 5**23 - 1 < 2**63

//! `cargo bench --bench serve` — the serving tier under multi-client
//! load, plus the two v2-artifact serving levers: seal a synthetic
//! pair-end corpus, start one `QueryServer` over the artifact, and
//! drive it with {1, 2, 4, 8} concurrent clients issuing a
//! deterministic SEARCH/PAIRS mix. Then, on a long-read corpus, compare
//! the plain O(|P| log n) SEARCH bounds against the LCP-accelerated
//! O(|P| + log n) bounds at pattern lengths {8, 64, 512}, and time the
//! cold artifact open on the heap backend vs the zero-copy mmap backend
//! (the latter only when built with `--features mmap`). Reports
//! per-query latency (mean and p99), aggregate throughput, bound
//! latencies with byte-comparison counts, and open times; snapshots
//! everything to `BENCH_serve.json` at the repo root (override the path
//! with SAMR_BENCH_JSON, or set it empty to skip).

use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

use samr::bench_support::section;
use samr::kvstore::query::{QueryClient, QueryServer};
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::sealed::{self, SealedIndex};
use samr::suffix::search::IndexView;
use samr::suffix::validate::reference_order;

const PATTERNS: &[&[u8]] = &[b"ACG", b"T", b"GGC", b"ACGT", b"CATT", b"AA"];

/// One client-count's aggregate numbers.
struct Load {
    clients: usize,
    queries: usize,
    mean_us: f64,
    p99_us: f64,
    qps: f64,
}

fn drive(addr: std::net::SocketAddr, clients: usize, per_client: usize) -> Load {
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|w| {
            std::thread::spawn(move || {
                let mut c = QueryClient::connect(addr).expect("connect");
                let mut lat = Vec::with_capacity(per_client);
                for q in 0..per_client {
                    let i = (w + q) % PATTERNS.len();
                    let t = Instant::now();
                    // 1-in-8 queries is the heavier pair-end join
                    if q % 8 == 0 {
                        c.pairs(PATTERNS[i], PATTERNS[(i + 1) % PATTERNS.len()], 500)
                            .expect("PAIRS");
                    } else {
                        c.search(PATTERNS[i]).expect("SEARCH");
                    }
                    lat.push(t.elapsed().as_secs_f64() * 1e6);
                }
                lat
            })
        })
        .collect();
    let mut lat: Vec<f64> = Vec::with_capacity(clients * per_client);
    for w in workers {
        lat.extend(w.join().expect("worker"));
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let mean_us = lat.iter().sum::<f64>() / lat.len() as f64;
    let p99_us = lat[(lat.len() * 99 / 100).min(lat.len() - 1)];
    Load { clients, queries: lat.len(), mean_us, p99_us, qps: lat.len() as f64 / wall }
}

fn main() {
    let per_client: usize = std::env::var("SAMR_SERVE_QUERIES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);

    // seal a corpus with enough repetition that SEARCH hits are non-empty
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: 400,
        read_len: 60,
        len_jitter: 0,
        genome_len: 1 << 13,
        seed: 0xBE7C,
        ..Default::default()
    });
    let mut all = fwd.clone();
    all.extend(rev.iter().cloned());
    let order = reference_order(&all);
    let path = std::env::temp_dir().join(format!("samr-bench-serve-{}.samr", std::process::id()));
    sealed::seal(&path, &[&fwd, &rev], &order).expect("seal");
    let idx = Arc::new(SealedIndex::open(&path).expect("open"));
    let st = idx.stats();

    let mut server = QueryServer::start(0, idx).expect("query server");
    section(&format!(
        "query serving: {} suffixes, {} reads, {per_client} queries/client",
        st.n_suffixes, st.n_reads
    ));

    let mut series = Vec::new();
    for &clients in &[1usize, 2, 4, 8] {
        let l = drive(server.addr(), clients, per_client);
        let label = format!("clients={clients}");
        println!(
            "{label:<28} {:>10.1} µs mean {:>10.1} µs p99 {:>12.0} q/s  ({} queries)",
            l.mean_us, l.p99_us, l.qps, l.queries
        );
        series.push(l);
    }
    server.shutdown();
    let _ = std::fs::remove_file(&path);

    let (bounds, open) = bench_bounds_and_open();
    write_snapshot(st.n_suffixes, &series, &bounds, &open);
}

/// One pattern length's plain-vs-accelerated numbers.
struct BoundRow {
    plen: usize,
    accel_us: f64,
    plain_us: f64,
    accel_cmp: u64,
    plain_cmp: u64,
}

/// Cold-open timings; `mmap_ms` is `None` without the `mmap` feature.
struct OpenRow {
    reps: usize,
    heap_ms: f64,
    mmap_ms: Option<f64>,
}

/// Mean microseconds per call of `f` over `iters` calls.
fn time_us(iters: usize, mut f: impl FnMut() -> usize) -> f64 {
    let t = Instant::now();
    let mut sink = 0usize;
    for _ in 0..iters {
        sink += f();
    }
    black_box(sink);
    t.elapsed().as_secs_f64() * 1e6 / iters as f64
}

/// Seal a long-read corpus (600 bp reads, so 512 bp patterns are real
/// planted queries, not automatic misses) and measure (a) the plain vs
/// LCP-accelerated SEARCH bounds at pattern lengths {8, 64, 512} and
/// (b) the cold artifact open on each backend.
fn bench_bounds_and_open() -> (Vec<BoundRow>, OpenRow) {
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: 150,
        read_len: 600,
        len_jitter: 0,
        genome_len: 1 << 13,
        seed: 0x5EED,
        ..Default::default()
    });
    let mut all = fwd.clone();
    all.extend(rev.iter().cloned());
    let order = reference_order(&all);
    let path =
        std::env::temp_dir().join(format!("samr-bench-bounds-{}.samr", std::process::id()));
    sealed::seal(&path, &[&fwd, &rev], &order).expect("seal long-read corpus");
    let idx = SealedIndex::open(&path).expect("open");
    assert!(idx.stats().has_tree, "bounds bench needs the v2 tree section");

    section(&format!(
        "SEARCH bounds: plain O(|P| log n) vs accelerated O(|P| + log n), {} suffixes",
        idx.stats().n_suffixes
    ));
    let iters = 2000;
    let mut bounds = Vec::new();
    for &plen in &[8usize, 64, 512] {
        // planted: a prefix of a real read, so the range is non-empty
        let pattern = fwd[plen % fwd.len()].codes[..plen].to_vec();
        let (r_accel, accel_cmp) = idx.sa_range_counted(&pattern);
        let (r_plain, plain_cmp) = idx.sa_range_plain_counted(&pattern);
        assert_eq!(r_accel, r_plain, "bounds disagree at |P|={plen}");
        assert!(!r_accel.is_empty(), "planted pattern absent at |P|={plen}");
        let accel_us = time_us(iters, || idx.sa_range(&pattern).len());
        let plain_us = time_us(iters, || idx.sa_range_plain(&pattern).len());
        println!(
            "|P|={plen:<6} accel {accel_us:>8.2} µs ({accel_cmp:>6} cmp)   \
             plain {plain_us:>8.2} µs ({plain_cmp:>6} cmp)   speedup {:>5.1}x",
            plain_us / accel_us.max(1e-9)
        );
        bounds.push(BoundRow { plen, accel_us, plain_us, accel_cmp, plain_cmp });
    }

    section("cold open: heap backend vs zero-copy mmap backend");
    let reps = 20;
    let t = Instant::now();
    for _ in 0..reps {
        black_box(SealedIndex::open(&path).expect("heap open").stats().n_suffixes);
    }
    let heap_ms = t.elapsed().as_secs_f64() * 1e3 / reps as f64;
    println!("heap open (read + checksum)   {heap_ms:>8.3} ms");
    #[cfg(feature = "mmap")]
    let mmap_ms = {
        use samr::suffix::sealed::{Backend, OpenOptions};
        // deferred validation: the zero-copy point is NOT touching every
        // page at open; the structural preflight still runs
        let opts = OpenOptions { backend: Backend::Mmap, verify_checksum: false };
        let t = Instant::now();
        for _ in 0..reps {
            black_box(SealedIndex::open_with(&path, opts).expect("mmap open").stats().n_suffixes);
        }
        let ms = t.elapsed().as_secs_f64() * 1e3 / reps as f64;
        println!("mmap open (deferred verify)   {ms:>8.3} ms");
        Some(ms)
    };
    #[cfg(not(feature = "mmap"))]
    let mmap_ms = {
        println!("mmap open                     not compiled in (--features mmap)");
        None
    };
    let _ = std::fs::remove_file(&path);
    (bounds, OpenRow { reps, heap_ms, mmap_ms })
}

/// Spool the load series, the bound comparison, and the cold-open
/// timings to `BENCH_serve.json` (the trajectory file at the repo root;
/// override the path with SAMR_BENCH_JSON, or set it empty to skip).
/// Hand-rolled JSON — the offline vendor set has no serde — with fixed
/// ASCII keys, so no escaping is needed.
fn write_snapshot(n_suffixes: u64, series: &[Load], bounds: &[BoundRow], open: &OpenRow) {
    let path = match std::env::var("SAMR_BENCH_JSON") {
        Ok(p) if p.is_empty() => return,
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::PathBuf::from("../BENCH_serve.json"),
    };
    let mut rows = Vec::new();
    for l in series {
        rows.push(format!(
            "    {{\"clients\": {}, \"queries\": {}, \"mean_us\": {:.1}, \
             \"p99_us\": {:.1}, \"qps\": {:.0}}}",
            l.clients, l.queries, l.mean_us, l.p99_us, l.qps
        ));
    }
    let mut bound_rows = Vec::new();
    for b in bounds {
        bound_rows.push(format!(
            "    {{\"pattern_len\": {}, \"accel_us\": {:.2}, \"plain_us\": {:.2}, \
             \"accel_cmp\": {}, \"plain_cmp\": {}}}",
            b.plen, b.accel_us, b.plain_us, b.accel_cmp, b.plain_cmp
        ));
    }
    let mmap_json =
        open.mmap_ms.map(|ms| format!("{ms:.3}")).unwrap_or_else(|| "null".into());
    let doc = format!(
        "{{\n  \"schema\": \"samr-bench-serve-v2\",\n  \"suffixes\": {n_suffixes},\n  \
         \"series\": [\n{}\n  ],\n  \"bounds\": [\n{}\n  ],\n  \
         \"cold_open\": {{\"reps\": {}, \"heap_ms\": {:.3}, \"mmap_ms\": {}}}\n}}\n",
        rows.join(",\n"),
        bound_rows.join(",\n"),
        open.reps,
        open.heap_ms,
        mmap_json
    );
    match std::fs::write(&path, doc) {
        Ok(()) => println!("\nwrote serving-load snapshot to {}", path.display()),
        Err(e) => println!("\ncould not write {}: {e}", path.display()),
    }
}

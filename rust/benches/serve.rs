//! `cargo bench --bench serve` — the serving tier under multi-client
//! load: seal a synthetic pair-end corpus, start one `QueryServer` over
//! the artifact, and drive it with {1, 2, 4, 8} concurrent clients
//! issuing a deterministic SEARCH/PAIRS mix. Reports per-query latency
//! (mean and p99) and aggregate throughput per client count, and
//! snapshots the series to `BENCH_serve.json` at the repo root
//! (override the path with SAMR_BENCH_JSON, or set it empty to skip).

use std::sync::Arc;
use std::time::Instant;

use samr::bench_support::section;
use samr::kvstore::query::{QueryClient, QueryServer};
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::sealed::{self, SealedIndex};
use samr::suffix::validate::reference_order;

const PATTERNS: &[&[u8]] = &[b"ACG", b"T", b"GGC", b"ACGT", b"CATT", b"AA"];

/// One client-count's aggregate numbers.
struct Load {
    clients: usize,
    queries: usize,
    mean_us: f64,
    p99_us: f64,
    qps: f64,
}

fn drive(addr: std::net::SocketAddr, clients: usize, per_client: usize) -> Load {
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|w| {
            std::thread::spawn(move || {
                let mut c = QueryClient::connect(addr).expect("connect");
                let mut lat = Vec::with_capacity(per_client);
                for q in 0..per_client {
                    let i = (w + q) % PATTERNS.len();
                    let t = Instant::now();
                    // 1-in-8 queries is the heavier pair-end join
                    if q % 8 == 0 {
                        c.pairs(PATTERNS[i], PATTERNS[(i + 1) % PATTERNS.len()], 500)
                            .expect("PAIRS");
                    } else {
                        c.search(PATTERNS[i]).expect("SEARCH");
                    }
                    lat.push(t.elapsed().as_secs_f64() * 1e6);
                }
                lat
            })
        })
        .collect();
    let mut lat: Vec<f64> = Vec::with_capacity(clients * per_client);
    for w in workers {
        lat.extend(w.join().expect("worker"));
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let mean_us = lat.iter().sum::<f64>() / lat.len() as f64;
    let p99_us = lat[(lat.len() * 99 / 100).min(lat.len() - 1)];
    Load { clients, queries: lat.len(), mean_us, p99_us, qps: lat.len() as f64 / wall }
}

fn main() {
    let per_client: usize = std::env::var("SAMR_SERVE_QUERIES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);

    // seal a corpus with enough repetition that SEARCH hits are non-empty
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: 400,
        read_len: 60,
        len_jitter: 0,
        genome_len: 1 << 13,
        seed: 0xBE7C,
        ..Default::default()
    });
    let mut all = fwd.clone();
    all.extend(rev.iter().cloned());
    let order = reference_order(&all);
    let path = std::env::temp_dir().join(format!("samr-bench-serve-{}.samr", std::process::id()));
    sealed::seal(&path, &[&fwd, &rev], &order).expect("seal");
    let idx = Arc::new(SealedIndex::open(&path).expect("open"));
    let st = idx.stats();

    let mut server = QueryServer::start(0, idx).expect("query server");
    section(&format!(
        "query serving: {} suffixes, {} reads, {per_client} queries/client",
        st.n_suffixes, st.n_reads
    ));

    let mut series = Vec::new();
    for &clients in &[1usize, 2, 4, 8] {
        let l = drive(server.addr(), clients, per_client);
        let label = format!("clients={clients}");
        println!(
            "{label:<28} {:>10.1} µs mean {:>10.1} µs p99 {:>12.0} q/s  ({} queries)",
            l.mean_us, l.p99_us, l.qps, l.queries
        );
        series.push(l);
    }
    server.shutdown();
    let _ = std::fs::remove_file(&path);
    write_snapshot(st.n_suffixes, &series);
}

/// Spool the load series to `BENCH_serve.json` (the trajectory file at
/// the repo root; override the path with SAMR_BENCH_JSON, or set it
/// empty to skip). Hand-rolled JSON — the offline vendor set has no
/// serde — with fixed ASCII keys, so no escaping is needed.
fn write_snapshot(n_suffixes: u64, series: &[Load]) {
    let path = match std::env::var("SAMR_BENCH_JSON") {
        Ok(p) if p.is_empty() => return,
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::PathBuf::from("../BENCH_serve.json"),
    };
    let mut rows = Vec::new();
    for l in series {
        rows.push(format!(
            "    {{\"clients\": {}, \"queries\": {}, \"mean_us\": {:.1}, \
             \"p99_us\": {:.1}, \"qps\": {:.0}}}",
            l.clients, l.queries, l.mean_us, l.p99_us, l.qps
        ));
    }
    let doc = format!(
        "{{\n  \"schema\": \"samr-bench-serve-v1\",\n  \"suffixes\": {n_suffixes},\n  \"series\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::write(&path, doc) {
        Ok(()) => println!("\nwrote serving-load snapshot to {}", path.display()),
        Err(e) => println!("\ncould not write {}: {e}", path.display()),
    }
}

//! `cargo bench --bench figures` — regenerate Figures 3, 4, 5, 7, 8.

use samr::bench_support::{bench, section};
use samr::report::experiments::ScaledEnv;
use samr::report::Reporter;
use samr::runtime;

fn main() {
    runtime::init(Some(&runtime::default_artifacts_dir()));
    let thrift: f64 = std::env::var("SAMR_THRIFT").ok().and_then(|s| s.parse().ok()).unwrap_or(4.0);
    let reporter = Reporter {
        env: ScaledEnv { thrift, ..Default::default() },
        ..Default::default()
    };
    let mut out = String::new();

    section("Figure 3 — map-side spill mechanics");
    let m = bench("figure3", 0, 1, || out = reporter.figure3().expect("f3"));
    println!("{out}\n{m}");

    section("Figure 4 — reduce-side merge rounds");
    let m = bench("figure4", 0, 1, || out = reporter.figure4());
    println!("{out}\n{m}");

    section("Figure 5 — TeraSort scalability");
    let m = bench("figure5", 0, 1, || out = reporter.figure5().expect("f5"));
    println!("{out}\n{m}");

    section("Figure 7 — prefix length vs sorting groups");
    let m = bench("figure7", 0, 1, || out = reporter.figure7());
    println!("{out}\n{m}");

    section("Figure 8 — all variants + f(x) fits");
    let m = bench("figure8", 0, 1, || out = reporter.figure8().expect("f8"));
    println!("{out}\n{m}");
}

//! `cargo bench --bench micro` — microbenchmarks of the hot paths:
//! PJRT kernels vs native fallback, KV store command throughput,
//! MGETSUFFIX vs whole-read GET traffic, SA algorithms, spill/merge I/O.

use samr::bench_support::{bench_throughput, section};
use samr::kvstore::shard::{InProcStore, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::runtime::{self, native};
use samr::suffix::encode::pack_index;
use samr::suffix::reads::{synth_corpus, CorpusSpec};
use samr::suffix::sa;
use samr::util::rng::Rng;

fn main() {
    let pjrt = runtime::init(Some(&runtime::default_artifacts_dir()));
    let reads = synth_corpus(&CorpusSpec { n_reads: 2048, read_len: 100, ..Default::default() });
    let n_suffixes: u64 = reads.iter().map(|r| r.suffix_count() as u64).sum();
    let mut rng = Rng::new(5);
    let mut bounds: Vec<i64> =
        (0..31).map(|_| rng.below(5u64.pow(23) as u64) as i64).collect();
    bounds.sort_unstable();

    section("map_encode: suffix key generation");
    let m = bench_throughput("native encode_reads", 1, 5, n_suffixes as f64, "suffixes", || {
        std::hint::black_box(native::encode_reads(&reads, &bounds, 23));
    });
    println!("{m}");
    if pjrt {
        runtime::with_engine(|eng| {
            let eng = eng.expect("engine");
            let refs: Vec<&_> = reads.iter().collect();
            // wide job: 31 boundaries -> nb=64 variant
            let r64 = eng.map_encode_meta(104, 23, bounds.len()).map(|m| m.r).unwrap_or(128);
            let m = bench_throughput(
                &format!("pjrt map_encode nb64 ({r64}-read tiles)"),
                1,
                5,
                n_suffixes as f64,
                "suffixes",
                || {
                    for tile in refs.chunks(r64) {
                        std::hint::black_box(
                            eng.map_encode_tile(tile, &bounds, 23).expect("tile"),
                        );
                    }
                },
            );
            println!("{m}");
            // common job: 7 boundaries (8 reducers) -> nb=16, r=512 variant
            let b8 = &bounds[..7];
            let r16 = eng.map_encode_meta(104, 23, 7).map(|m| m.r).unwrap_or(128);
            let m = bench_throughput(
                &format!("pjrt map_encode nb16 ({r16}-read tiles)"),
                1,
                5,
                n_suffixes as f64,
                "suffixes",
                || {
                    for tile in refs.chunks(r16) {
                        std::hint::black_box(
                            eng.map_encode_tile(tile, b8, 23).expect("tile"),
                        );
                    }
                },
            );
            println!("{m}");
        });
    }

    section("group_sort: (key, index) pair sort");
    let keys: Vec<i64> = (0..8192).map(|_| rng.below(1 << 40) as i64).collect();
    let idxs: Vec<i64> = (0..8192).map(|i| i as i64).collect();
    let m = bench_throughput("native group_sort 8192", 1, 20, 8192.0, "pairs", || {
        let mut k = keys.clone();
        let mut ix = idxs.clone();
        native::group_sort(&mut k, &mut ix);
        std::hint::black_box((k, ix));
    });
    println!("{m}");
    if pjrt {
        runtime::with_engine(|eng| {
            let eng = eng.expect("engine");
            let m = bench_throughput("pjrt group_sort 8192", 1, 5, 8192.0, "pairs", || {
                let mut k = keys.clone();
                let mut ix = idxs.clone();
                eng.group_sort(&mut k, &mut ix).expect("group_sort");
                std::hint::black_box((k, ix));
            });
            println!("{m}");
            for n in [4096usize, 2048, 1024] {
                let m = bench_throughput(
                    &format!("pjrt group_sort {n}"),
                    1,
                    5,
                    n as f64,
                    "pairs",
                    || {
                        let mut k = keys[..n].to_vec();
                        let mut ix = idxs[..n].to_vec();
                        eng.group_sort(&mut k, &mut ix).expect("group_sort");
                        std::hint::black_box((k, ix));
                    },
                );
                println!("{m}");
            }
        });
    }

    section("KV store: MGETSUFFIX vs whole-read fetch (in-proc, modeled wire)");
    let mut st = InProcStore::new(4);
    st.put_reads(&reads).unwrap();
    let reqs: Vec<i64> = reads.iter().flat_map(|r| (0..=r.len()).map(|o| pack_index(r.seq, o))).collect();
    let m = bench_throughput("mgetsuffix all suffixes", 1, 5, reqs.len() as f64, "suffixes", || {
        std::hint::black_box(st.fetch_suffixes(&reqs).unwrap());
    });
    println!("{m}");

    section("KV store over TCP (RESP)");
    {
        let kv = LocalKvCluster::start(4).expect("kv");
        let mut client = kv.client().expect("client");
        client.put_reads(&reads).unwrap();
        let sample: Vec<i64> = reqs.iter().copied().step_by(16).collect();
        let m = bench_throughput("tcp mgetsuffix (1/16 sample)", 1, 3, sample.len() as f64, "suffixes", || {
            std::hint::black_box(client.fetch_suffixes(&sample).unwrap());
        });
        println!("{m}");
    }

    section("SA construction algorithms (single text)");
    let text: Vec<u8> = (0..200_000).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
    let m = bench_throughput("sais 200k", 1, 5, text.len() as f64, "chars", || {
        std::hint::black_box(sa::sais(&text));
    });
    println!("{m}");
    let m = bench_throughput("doubling 200k", 1, 2, text.len() as f64, "chars", || {
        std::hint::black_box(sa::doubling(&text));
    });
    println!("{m}");
}

//! `cargo bench --bench dataflow` — streamed (disk-backed) vs
//! materialized input at 1M+ records.
//!
//! Three legs: (1) scanning 1M records out of a spooled record file
//! through `RecordReader` vs iterating the same records resident in a
//! `Vec` — the price of the out-of-core input path; (2) spooling the
//! records to split files vs cloning them into a resident `Vec<Vec<_>>`
//! — the price at generation time; (3) one full identity-sort job over
//! the streamed splits, reporting wall time and the peak resident
//! record count the buffer budgets allowed (against the 1M-record
//! input that never sits in memory).

use std::hint::black_box;
use std::sync::Arc;

use samr::bench_support::{bench_throughput, section};
use samr::footprint::Ledger;
use samr::mapreduce::io::spool_records;
use samr::mapreduce::partitioner::RangePartitioner;
use samr::mapreduce::{resident, run_job, Job, JobConf, Record, ScratchDir};
use samr::util::rng::Rng;

fn synth(n: usize, seed: u64) -> Vec<Record> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            Record::new(
                rng.next_u64().to_be_bytes().to_vec(),
                rng.next_u64().to_be_bytes().to_vec(),
            )
        })
        .collect()
}

fn main() {
    let n: usize = std::env::var("SAMR_DATAFLOW_RECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 20);
    let recs = synth(n, 17);
    let dir = ScratchDir::new(None, "bench-dataflow").expect("scratch");
    let split_bytes = 1 << 20;

    section(&format!("input generation at {n} records"));
    let m = bench_throughput("materialized: clone into resident splits", 1, 5, n as f64, "recs", || {
        let mut splits: Vec<Vec<Record>> = Vec::new();
        let mut cur: Vec<Record> = Vec::new();
        let mut bytes = 0u64;
        for r in &recs {
            bytes += r.wire_bytes();
            cur.push(r.clone());
            if bytes >= split_bytes {
                splits.push(std::mem::take(&mut cur));
                bytes = 0;
            }
        }
        splits.push(cur);
        black_box(splits.len());
    });
    println!("{m}");
    let m = bench_throughput("streamed: spool to disk-backed splits", 1, 5, n as f64, "recs", || {
        // one path reused across iterations: File::create truncates, so
        // disk use stays bounded at one spool regardless of rep count
        let splits = spool_records(dir.path.join("in"), &recs, split_bytes).unwrap();
        black_box(splits.len());
    });
    println!("{m}");

    section(&format!("full scan at {n} records"));
    let m = bench_throughput("materialized Vec scan", 1, 5, n as f64, "recs", || {
        let mut total = 0u64;
        for r in &recs {
            total += r.wire_bytes();
        }
        black_box(total);
    });
    println!("{m}");
    let splits = spool_records(dir.path.join("scan"), &recs, split_bytes).unwrap();
    let m = bench_throughput("streamed RecordReader scan", 1, 5, n as f64, "recs", || {
        let mut total = 0u64;
        for s in &splits {
            let mut rd = s.open().unwrap();
            while let Some(r) = rd.next_record().unwrap() {
                total += r.wire_bytes();
            }
        }
        black_box(total);
    });
    println!("{m}");

    section("end-to-end identity sort over streamed splits");
    let n_reducers = 4;
    let samples: Vec<Vec<u8>> = recs.iter().take(4000).map(|r| r.key.clone()).collect();
    let part = Arc::new(RangePartitioner::from_samples(samples, n_reducers));
    let job = Job {
        name: "bench-dataflow".into(),
        conf: JobConf {
            n_reducers,
            split_bytes,
            io_sort_bytes: 4 << 20,
            reducer_heap_bytes: 16 << 20,
            fixed_width: true,
            ..JobConf::default()
        },
        map_factory: Arc::new(|_| {
            Box::new(|rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone()))
        }),
        reduce_factory: Arc::new(|_| {
            Box::new(
                |key: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                    for v in vals {
                        out(Record::new(key.to_vec(), v));
                    }
                },
            )
        }),
        partitioner: part.as_fn(),
    };
    let job_splits = spool_records(dir.path.join("job"), &recs, split_bytes).unwrap();
    resident::reset();
    let ledger = Ledger::new();
    let t0 = std::time::Instant::now();
    let res = run_job(&job, job_splits, &ledger).expect("job");
    println!(
        "    {n} records sorted in {:?}; peak resident records {} ({:.2}% of input)",
        t0.elapsed(),
        resident::peak(),
        100.0 * resident::peak() as f64 / n as f64
    );
    drop(res);
}

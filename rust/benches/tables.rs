//! `cargo bench --bench tables` — regenerate Tables III–VIII end to end
//! (scaled execution + cluster-model projection) and time each.

use samr::bench_support::{bench, section};
use samr::report::experiments::ScaledEnv;
use samr::report::Reporter;
use samr::runtime;

fn main() {
    runtime::init(Some(&runtime::default_artifacts_dir()));
    let thrift: f64 = std::env::var("SAMR_THRIFT").ok().and_then(|s| s.parse().ok()).unwrap_or(4.0);
    let reporter = Reporter {
        env: ScaledEnv { thrift, ..Default::default() },
        ..Default::default()
    };

    section("Table III — TeraSort footprint (5 cases)");
    let mut out = String::new();
    let m = bench("table3", 0, 1, || out = reporter.table3().expect("t3"));
    println!("{out}");
    println!("{m}");

    section("Table IV — TeraSort, 10 GB reducers");
    let m = bench("table4", 0, 1, || out = reporter.table4().expect("t4"));
    println!("{out}");
    println!("{m}");

    section("Table V — Scheme footprint (6 cases incl. pair-end)");
    let m = bench("table5", 0, 1, || out = reporter.table5().expect("t5"));
    println!("{out}");
    println!("{m}");

    section("Table VI — mem_heap");
    let m = bench("table6", 0, 1, || out = reporter.table6().expect("t6"));
    println!("{out}");
    println!("{m}");

    section("Table VII — mem_reducer");
    let m = bench("table7", 0, 1, || out = reporter.table7().expect("t7"));
    println!("{out}");
    println!("{m}");

    section("Table VIII — efficiency");
    let m = bench("table8", 0, 1, || out = reporter.table8().expect("t8"));
    println!("{out}");
    println!("{m}");
}

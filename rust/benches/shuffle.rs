//! `cargo bench --bench shuffle` — the fixed-width shuffle fast path vs
//! the generic `Record` path on ≥1M synthetic suffix-index records
//! (24 B each, like the scheme's shuffle): spill-buffer fill+sort
//! (two-allocations-per-record comparison sort vs packed LSD radix),
//! k-way merge (binary-heap `Record` merge vs loser tree over packed
//! pairs), and the reducer's numeric (key, index) group sort
//! (permutation comparison sort vs radix). Reports records/s and the
//! fixed/generic speedup — the acceptance target is >1x on every leg.
//! A thread-scaling series (1/2/4/8 threads on each parallel in-node
//! sorting path) follows, snapshotted to `BENCH_sort.json` at the repo
//! root for the baseline trajectory.

use samr::bench_support::{bench_throughput, section, Measurement};
use samr::mapreduce::merge::{
    kway_merge, kway_merge_fixed, merge_fixed_segments_threads, FixedRun, Run,
};
use samr::mapreduce::record::{FixedRec, Record};
use samr::runtime::native;
use samr::util::radix;
use samr::util::rng::Rng;

/// Synthetic suffix-index records: base-5 prefix keys below 5^13 (the
/// paper's int-key regime), packed `seq*1000+off` values, and a range
/// partition derived from the key — the distribution the mapper's
/// spill buffer actually sees.
fn synth(n: usize, n_partitions: u64, seed: u64) -> Vec<FixedRec> {
    let key_space = 5u64.pow(13);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let key = rng.below(key_space);
            FixedRec {
                partition: (key * n_partitions / key_space) as u32,
                key,
                value: (i as u64 / 100) * 1000 + (i as u64 % 100),
            }
        })
        .collect()
}

fn speedup(generic: &Measurement, fixed: &Measurement) -> String {
    let s = generic.mean.as_secs_f64() / fixed.mean.as_secs_f64();
    format!(
        "    fixed-width speedup: {s:.2}x{}",
        if s < 1.0 { "  (below 1x target!)" } else { "" }
    )
}

fn main() {
    let n: usize = std::env::var("SAMR_SHUFFLE_RECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 20);
    let recs = synth(n, 4, 11);

    section(&format!("spill-buffer fill + sort ({n} records, 4 partitions)"));
    // each iteration rebuilds the buffer exactly as the mapper absorb
    // loop would: the generic path allocates two Vecs per record, the
    // fixed path pushes packed structs; then both sort by (partition, key).
    let m_gen = bench_throughput("generic: Vec<(u32, Record)> + sort_by", 1, 3, n as f64, "recs", || {
        let mut buf: Vec<(u32, Record)> = recs
            .iter()
            .map(|r| {
                (
                    r.partition,
                    Record::new(r.key.to_be_bytes().to_vec(), r.value.to_be_bytes().to_vec()),
                )
            })
            .collect();
        buf.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.key.cmp(&b.1.key)));
        std::hint::black_box(buf.len());
    });
    println!("{m_gen}");
    let mut scratch: Vec<FixedRec> = Vec::new();
    let m_fix = bench_throughput("fixed:   Vec<FixedRec> + LSD radix", 1, 3, n as f64, "recs", || {
        let mut buf: Vec<FixedRec> = recs.clone();
        radix::sort_spill(&mut buf, &mut scratch);
        std::hint::black_box(buf.len());
    });
    println!("{m_fix}");
    println!("{}", speedup(&m_gen, &m_fix));

    section(&format!("k-way merge of 8 sorted runs ({n} records total)"));
    let runs: Vec<Vec<(u64, u64)>> = (0..8)
        .map(|r| {
            let mut v: Vec<(u64, u64)> = synth(n / 8, 1, 100 + r)
                .into_iter()
                .map(|rec| (rec.key, rec.value))
                .collect();
            v.sort_unstable();
            v
        })
        .collect();
    let m_gen = bench_throughput("generic: BinaryHeap over Records", 1, 3, n as f64, "recs", || {
        let gruns: Vec<Run> = runs
            .iter()
            .map(|v| {
                Run::from_vec(
                    v.iter()
                        .map(|&(k, val)| {
                            Record::new(k.to_be_bytes().to_vec(), val.to_be_bytes().to_vec())
                        })
                        .collect(),
                )
            })
            .collect();
        let mut count = 0u64;
        kway_merge(gruns, |r| {
            count += r.wire_bytes();
            Ok(())
        })
        .unwrap();
        std::hint::black_box(count);
    });
    println!("{m_gen}");
    let m_fix = bench_throughput("fixed:   loser tree over (u64, u64)", 1, 3, n as f64, "recs", || {
        let fruns: Vec<FixedRun> =
            runs.iter().map(|v| FixedRun::from_vec(v.clone())).collect();
        let mut count = 0u64;
        kway_merge_fixed(fruns, |_, v| {
            count += v & 1;
            Ok(())
        })
        .unwrap();
        std::hint::black_box(count);
    });
    println!("{m_fix}");
    println!("{}", speedup(&m_gen, &m_fix));

    section(&format!("reducer (key, index) group sort ({n} pairs)"));
    let keys: Vec<i64> = recs.iter().map(|r| r.key as i64).collect();
    let idxs: Vec<i64> = recs.iter().map(|r| r.value as i64).collect();
    let m_gen = bench_throughput("generic: permutation comparison sort", 1, 3, n as f64, "pairs", || {
        let mut k = keys.clone();
        let mut ix = idxs.clone();
        // the pre-radix implementation, kept here as the baseline
        let mut perm: Vec<usize> = (0..k.len()).collect();
        perm.sort_unstable_by_key(|&i| (k[i], ix[i]));
        let ks: Vec<i64> = perm.iter().map(|&i| k[i]).collect();
        let ixs: Vec<i64> = perm.iter().map(|&i| ix[i]).collect();
        k.copy_from_slice(&ks);
        ix.copy_from_slice(&ixs);
        std::hint::black_box((k, ix));
    });
    println!("{m_gen}");
    let m_fix = bench_throughput("fixed:   LSD radix pair sort", 1, 3, n as f64, "pairs", || {
        let mut k = keys.clone();
        let mut ix = idxs.clone();
        native::group_sort(&mut k, &mut ix);
        std::hint::black_box((k, ix));
    });
    println!("{m_fix}");
    println!("{}", speedup(&m_gen, &m_fix));

    // ---------------- parallel in-node sorting: thread scaling ----------------
    // Every series point is the SAME work at a different
    // parallel_sort_threads value; threads = 1 is the literal sequential
    // code, so the 1-thread row doubles as the regression baseline.
    let threads_series = [1usize, 2, 4, 8];
    let mut snapshot: Vec<(String, usize, Measurement)> = Vec::new();

    section(&format!("spill radix sort, thread scaling ({n} records)"));
    for &t in &threads_series {
        let mut scratch: Vec<FixedRec> = Vec::new();
        let m = bench_throughput(
            &format!("sort_spill_threads(threads={t})"),
            1,
            3,
            n as f64,
            "recs",
            || {
                let mut buf: Vec<FixedRec> = recs.clone();
                radix::sort_spill_threads(&mut buf, &mut scratch, t);
                std::hint::black_box(buf.len());
            },
        );
        println!("{m}");
        snapshot.push(("spill_radix".into(), t, m));
    }

    section(&format!("group (key, index) pair sort, thread scaling ({n} pairs)"));
    for &t in &threads_series {
        let m = bench_throughput(
            &format!("sort_pairs_threads(threads={t})"),
            1,
            3,
            n as f64,
            "pairs",
            || {
                let mut k = keys.clone();
                let mut ix = idxs.clone();
                radix::sort_pairs_threads(&mut k, &mut ix, t);
                std::hint::black_box((k, ix));
            },
        );
        println!("{m}");
        snapshot.push(("pair_sort".into(), t, m));
    }

    section(&format!("8-segment range-partitioned merge, thread scaling ({n} records)"));
    for &t in &threads_series {
        let m = bench_throughput(
            &format!("merge_fixed_segments_threads(threads={t})"),
            1,
            3,
            n as f64,
            "recs",
            || {
                let mut count = 0u64;
                merge_fixed_segments_threads(runs.clone(), t, |_, v| {
                    count += v & 1;
                    Ok(())
                })
                .unwrap();
                std::hint::black_box(count);
            },
        );
        println!("{m}");
        snapshot.push(("segment_merge".into(), t, m));
    }

    write_snapshot(n, &snapshot);
}

/// Spool the thread-scaling series to `BENCH_sort.json` (the trajectory
/// file at the repo root; override the path with SAMR_BENCH_JSON, or set
/// it empty to skip). Hand-rolled JSON — the offline vendor set has no
/// serde — with fixed ASCII keys, so no escaping is needed.
fn write_snapshot(n: usize, series: &[(String, usize, Measurement)]) {
    let path = match std::env::var("SAMR_BENCH_JSON") {
        Ok(p) if p.is_empty() => return,
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::PathBuf::from("../BENCH_sort.json"),
    };
    let mut rows = Vec::new();
    for (bench, threads, m) in series {
        rows.push(format!(
            "    {{\"bench\": \"{bench}\", \"threads\": {threads}, \"mean_s\": {:.6}, \
             \"sigma_s\": {:.6}, \"recs_per_s\": {:.0}}}",
            m.mean.as_secs_f64(),
            m.sigma.as_secs_f64(),
            n as f64 / m.mean.as_secs_f64(),
        ));
    }
    let doc = format!(
        "{{\n  \"schema\": \"samr-bench-sort-v1\",\n  \"records\": {n},\n  \"series\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::write(&path, doc) {
        Ok(()) => println!("\nwrote thread-scaling snapshot to {}", path.display()),
        Err(e) => println!("\ncould not write {}: {e}", path.display()),
    }
}

//! `cargo bench --bench fetch` — the zero-copy suffix-fetch ablation:
//! old `Vec<Vec<u8>>` fetch vs the flat `SuffixBatch` arena path, at
//! 100k and ~1M suffixes, sequential vs pipelined, over 1/4/8 shards.
//! The §IV-D claim under test: fetch cost should be bounded by moving
//! bytes, not by the allocator.
//!
//! `SAMR_FETCH_SUFFIXES` scales the big corpus (default 1_000_000).

use samr::bench_support::{bench_throughput, section};
use samr::kvstore::batch::SuffixBatch;
use samr::kvstore::shard::{InProcStore, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::suffix::encode::pack_index;
use samr::suffix::reads::Read;
use samr::util::bytes::parse_count;

/// A corpus of `n_reads` reads of `len` bases plus the request list for
/// every suffix of every read.
fn corpus(n_reads: u64, len: usize) -> (Vec<Read>, Vec<i64>) {
    let reads: Vec<Read> =
        (0..n_reads).map(|i| Read::new(i, vec![(i % 4 + 1) as u8; len])).collect();
    let reqs: Vec<i64> = reads
        .iter()
        .flat_map(|r| (0..=r.len()).map(|o| pack_index(r.seq, o)))
        .collect();
    (reads, reqs)
}

fn bench_inproc(label: &str, n_suffixes: usize) {
    section(&format!("{label}: Vec-of-Vecs vs SuffixBatch (in-process, 4 shards)"));
    let len = 49usize; // 50 suffixes per read
    let n_reads = (n_suffixes / (len + 1)) as u64;
    let (reads, reqs) = corpus(n_reads, len);
    let mut store = InProcStore::new(4);
    store.put_reads(&reads).expect("put");

    let m_vec =
        bench_throughput("vec fetch (alloc per suffix)", 1, 3, reqs.len() as f64, "suffixes", || {
            std::hint::black_box(store.fetch_suffixes(&reqs).unwrap());
        });
    println!("{m_vec}");
    let mut batch = SuffixBatch::new();
    let m_arena =
        bench_throughput("arena fetch (flat batch)", 1, 3, reqs.len() as f64, "suffixes", || {
            batch.clear();
            store.fetch_suffixes_into(&reqs, &mut batch).unwrap();
            std::hint::black_box(batch.len());
        });
    println!("{m_arena}");
    let speedup = m_vec.mean.as_secs_f64() / m_arena.mean.as_secs_f64();
    println!("    arena speedup at {}: {speedup:.2}x", reqs.len());
}

fn main() {
    let big: usize = std::env::var("SAMR_FETCH_SUFFIXES")
        .ok()
        .and_then(|s| parse_count(&s).map(|v| v as usize))
        .unwrap_or(1_000_000);

    // the acceptance target: a measurable win at 1M suffixes
    bench_inproc("100k suffixes", 100_000);
    bench_inproc(&format!("{big} suffixes"), big);

    // over real sockets: sequential vs pipelined, Vec vs arena
    let (reads, reqs) = corpus(2_000, 49); // 100k suffixes over TCP
    for shards in [1usize, 4, 8] {
        section(&format!("TCP fetch paths, {shards} shard(s), {} suffixes", reqs.len()));
        let kv = LocalKvCluster::start(shards).expect("kv cluster");
        let mut loader = kv.client().expect("loader");
        loader.put_reads(&reads).expect("put");

        let mut client = kv.client().expect("client");
        let m = bench_throughput("sequential vec fetch", 1, 3, reqs.len() as f64, "suffixes", || {
            std::hint::black_box(client.fetch_suffixes_sequential(&reqs).unwrap());
        });
        println!("{m}");
        let m_vec =
            bench_throughput("pipelined vec fetch", 1, 3, reqs.len() as f64, "suffixes", || {
                std::hint::black_box(client.fetch_suffixes(&reqs).unwrap());
            });
        println!("{m_vec}");
        let mut batch = SuffixBatch::new();
        let m_arena =
            bench_throughput("pipelined arena fetch", 1, 3, reqs.len() as f64, "suffixes", || {
                batch.clear();
                client.fetch_suffixes_into(&reqs, &mut batch).unwrap();
                std::hint::black_box(batch.len());
            });
        println!("{m_arena}");
        let speedup = m_vec.mean.as_secs_f64() / m_arena.mean.as_secs_f64();
        println!("    arena vs vec (pipelined) at {shards} shard(s): {speedup:.2}x");
    }
}

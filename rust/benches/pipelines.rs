//! `cargo bench --bench pipelines` — end-to-end pipeline throughput
//! (records/s) for the scheme vs TeraSort, plus the paper's ablations:
//! sorting-group threshold (§IV-C: 8e5 / 1.6e6 / 3.2e6), prefix length
//! (§IV-B: 13 = int vs 23 = long), index-only output mode (§IV-D's
//! "could be faster by not writing the suffixes"), the sequential vs
//! pipelined sharded `MGETSUFFIX` fetch path, and the reducer's
//! double-buffered prefetch.

use std::sync::Arc;

use samr::bench_support::{bench_throughput, section};
use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::mapreduce::JobConf;
use samr::report::experiments::example_corpus;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::encode::pack_index;
use samr::terasort::{self, TeraSortConfig};
use samr::util::bytes::human;

fn conf() -> JobConf {
    JobConf {
        n_reducers: 4,
        io_sort_bytes: 1 << 20,
        split_bytes: 1 << 20,
        reducer_heap_bytes: 16 << 20,
        ..JobConf::default()
    }
}

fn scheme_cfg() -> SchemeConfig {
    SchemeConfig {
        conf: conf(),
        group_threshold: 100_000,
        samples_per_reducer: 2_000,
        ..Default::default()
    }
}

fn run_scheme(cfg: &SchemeConfig, reads: &[samr::suffix::reads::Read]) -> (u64, u64) {
    let ledger = Ledger::new();
    let store = SharedStore::new(8);
    let s = store.clone();
    let res = scheme::run(
        reads,
        cfg,
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger,
    )
    .expect("scheme");
    (res.order.len() as u64, ledger.snapshot().local_disk_total())
}

fn main() {
    runtime::init(Some(&runtime::default_artifacts_dir()));
    let n_reads: usize =
        std::env::var("SAMR_READS").ok().and_then(|s| s.parse().ok()).unwrap_or(8_000);
    let reads = example_corpus(n_reads, 100, 11);
    let n_suffixes: u64 = reads.iter().map(|r| r.suffix_count() as u64).sum();

    section(&format!("end-to-end pipelines ({n_reads} reads, {n_suffixes} suffixes)"));
    let m = bench_throughput("terasort e2e", 1, 3, n_suffixes as f64, "suffixes", || {
        let ledger = Ledger::new();
        terasort::run(&reads, &TeraSortConfig { conf: conf(), ..Default::default() }, &ledger)
            .expect("terasort");
    });
    println!("{m}");
    let m = bench_throughput("scheme e2e", 1, 3, n_suffixes as f64, "suffixes", || {
        run_scheme(&scheme_cfg(), &reads);
    });
    println!("{m}");

    section("sequential vs pipelined sharded MGETSUFFIX (TCP)");
    // acceptance target: pipelined >= 1.5x sequential at 4+ shards
    for shards in [1usize, 4, 8] {
        let kv = LocalKvCluster::start(shards).expect("kv cluster");
        let mut loader = kv.client().expect("loader");
        loader.put_reads(&reads).expect("put");
        let all: Vec<i64> = reads
            .iter()
            .flat_map(|r| (0..=r.len()).map(|o| pack_index(r.seq, o)))
            .collect();
        let mut client = kv.client().expect("client");
        let m_seq = bench_throughput(
            &format!("sequential fetch, {shards} shard(s)"),
            1,
            3,
            all.len() as f64,
            "suffixes",
            || {
                std::hint::black_box(client.fetch_suffixes_sequential(&all).unwrap());
            },
        );
        println!("{m_seq}");
        let m_pipe = bench_throughput(
            &format!("pipelined fetch,  {shards} shard(s)"),
            1,
            3,
            all.len() as f64,
            "suffixes",
            || {
                std::hint::black_box(client.fetch_suffixes(&all).unwrap());
            },
        );
        println!("{m_pipe}");
        let speedup = m_seq.mean.as_secs_f64() / m_pipe.mean.as_secs_f64();
        println!(
            "    pipelined speedup at {shards} shard(s): {speedup:.2}x{}",
            if shards >= 4 && speedup < 1.5 { "  (below 1.5x target!)" } else { "" }
        );
    }

    section("reducer double-buffering (prefetch fetch behind sort)");
    for (name, prefetch) in [("blocking fetch", false), ("prefetched fetch", true)] {
        let cfg = SchemeConfig { prefetch, ..scheme_cfg() };
        let m = bench_throughput(name, 1, 3, n_suffixes as f64, "suffixes", || {
            run_scheme(&cfg, &reads);
        });
        println!("{m}");
    }

    section("ablation: sorting-group accumulation threshold (§IV-C)");
    for threshold in [25_000usize, 50_000, 100_000, 200_000] {
        let cfg = SchemeConfig { group_threshold: threshold, ..scheme_cfg() };
        let m = bench_throughput(
            &format!("threshold {threshold}"),
            0,
            3,
            n_suffixes as f64,
            "suffixes",
            || {
                run_scheme(&cfg, &reads);
            },
        );
        println!("{m}");
    }

    section("ablation: prefix length (13 = paper's int, 23 = long)");
    for p in [13usize, 23] {
        let cfg = SchemeConfig { prefix_len: p, ..scheme_cfg() };
        let m = bench_throughput(
            &format!("prefix {p}"),
            0,
            3,
            n_suffixes as f64,
            "suffixes",
            || {
                run_scheme(&cfg, &reads);
            },
        );
        println!("{m}");
    }

    section("ablation: output mode (write suffixes vs index-only)");
    for (name, write) in [("write-suffixes (paper fair mode)", true), ("index-only", false)] {
        let cfg = SchemeConfig { write_suffixes: write, ..scheme_cfg() };
        let ledger = Ledger::new();
        let store = SharedStore::new(8);
        let s = store.clone();
        let res = scheme::run(
            &reads,
            &cfg,
            Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
            &ledger,
        )
        .expect("scheme");
        let m = bench_throughput(name, 0, 3, n_suffixes as f64, "suffixes", || {
            run_scheme(&cfg, &reads);
        });
        println!(
            "{m}\n    KV fetch {} / HDFS write {}",
            human(ledger.get(Channel::KvFetch)),
            human(ledger.get(Channel::HdfsWrite))
        );
        drop(res);
    }
}

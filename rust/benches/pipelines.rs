//! `cargo bench --bench pipelines` — end-to-end pipeline throughput
//! (records/s) for the scheme vs TeraSort, plus the paper's ablations:
//! sorting-group threshold (§IV-C: 8e5 / 1.6e6 / 3.2e6), prefix length
//! (§IV-B: 13 = int vs 23 = long), and index-only output mode (§IV-D's
//! "could be faster by not writing the suffixes").

use std::sync::Arc;

use samr::bench_support::{bench_throughput, section};
use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::JobConf;
use samr::report::experiments::example_corpus;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::terasort::{self, TeraSortConfig};
use samr::util::bytes::human;

fn conf() -> JobConf {
    JobConf {
        n_reducers: 4,
        io_sort_bytes: 1 << 20,
        split_bytes: 1 << 20,
        reducer_heap_bytes: 16 << 20,
        ..JobConf::default()
    }
}

fn scheme_cfg() -> SchemeConfig {
    SchemeConfig {
        conf: conf(),
        group_threshold: 100_000,
        samples_per_reducer: 2_000,
        ..Default::default()
    }
}

fn run_scheme(cfg: &SchemeConfig, reads: &[samr::suffix::reads::Read]) -> (u64, u64) {
    let ledger = Ledger::new();
    let store = SharedStore::new(8);
    let s = store.clone();
    let res = scheme::run(
        reads,
        cfg,
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger,
    )
    .expect("scheme");
    (res.order.len() as u64, ledger.snapshot().local_disk_total())
}

fn main() {
    runtime::init(Some(&runtime::default_artifacts_dir()));
    let n_reads: usize =
        std::env::var("SAMR_READS").ok().and_then(|s| s.parse().ok()).unwrap_or(8_000);
    let reads = example_corpus(n_reads, 100, 11);
    let n_suffixes: u64 = reads.iter().map(|r| r.suffix_count() as u64).sum();

    section(&format!("end-to-end pipelines ({n_reads} reads, {n_suffixes} suffixes)"));
    let m = bench_throughput("terasort e2e", 1, 3, n_suffixes as f64, "suffixes", || {
        let ledger = Ledger::new();
        terasort::run(&reads, &TeraSortConfig { conf: conf(), ..Default::default() }, &ledger)
            .expect("terasort");
    });
    println!("{m}");
    let m = bench_throughput("scheme e2e", 1, 3, n_suffixes as f64, "suffixes", || {
        run_scheme(&scheme_cfg(), &reads);
    });
    println!("{m}");

    section("ablation: sorting-group accumulation threshold (§IV-C)");
    for threshold in [25_000usize, 50_000, 100_000, 200_000] {
        let cfg = SchemeConfig { group_threshold: threshold, ..scheme_cfg() };
        let m = bench_throughput(
            &format!("threshold {threshold}"),
            0,
            3,
            n_suffixes as f64,
            "suffixes",
            || {
                run_scheme(&cfg, &reads);
            },
        );
        println!("{m}");
    }

    section("ablation: prefix length (13 = paper's int, 23 = long)");
    for p in [13usize, 23] {
        let cfg = SchemeConfig { prefix_len: p, ..scheme_cfg() };
        let m = bench_throughput(
            &format!("prefix {p}"),
            0,
            3,
            n_suffixes as f64,
            "suffixes",
            || {
                run_scheme(&cfg, &reads);
            },
        );
        println!("{m}");
    }

    section("ablation: output mode (write suffixes vs index-only)");
    for (name, write) in [("write-suffixes (paper fair mode)", true), ("index-only", false)] {
        let cfg = SchemeConfig { write_suffixes: write, ..scheme_cfg() };
        let ledger = Ledger::new();
        let store = SharedStore::new(8);
        let s = store.clone();
        let res = scheme::run(
            &reads,
            &cfg,
            Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
            &ledger,
        )
        .expect("scheme");
        let m = bench_throughput(name, 0, 3, n_suffixes as f64, "suffixes", || {
            run_scheme(&cfg, &reads);
        });
        println!(
            "{m}\n    KV fetch {} / HDFS write {}",
            human(ledger.get(Channel::KvFetch)),
            human(ledger.get(Channel::HdfsWrite))
        );
        drop(res);
    }
}

//! The LCP/BWT emission oracle: proves the pipeline-emitted auxiliary
//! sections (computed incrementally at reduce-emit time and stitched at
//! seal time) are byte-identical to the classical sequential algorithms,
//! that turning the emission on changes *nothing* about the construction
//! itself, and that the LCP-accelerated search the sections enable is
//! both equivalent to the plain bounds and actually O(|P| + log n).
//!
//! Four claims, each with its own oracle:
//!  1. sealed LCP == Kasai's algorithm and sealed BWT == `bwt_from_sa`
//!     on a single-read corpus, across shards × fixed_shuffle × prefetch;
//!  2. sealed LCP/BWT == naive adjacent-suffix recompute on the paired
//!     multi-read corpus, across the same matrix;
//!  3. `emit_lcp` on/off leaves output order and all nine footprint
//!     ledger channels byte-identical (the emission is free);
//!  4. accelerated vs plain `sa_range` return identical ranges on fuzzed
//!     patterns (empty, planted, random, max-length absent), with a
//!     byte-comparison count proving the O(|P| + log n) bound.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use samr::footprint::{Ledger, CHANNELS};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::bwt::bwt_from_sa;
use samr::suffix::encode::unpack_index;
use samr::suffix::lcp::kasai;
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec, Read};
use samr::suffix::sa;
use samr::suffix::sealed::{SealedIndex, BWT_TERMINATOR};
use samr::suffix::search::IndexView;
use samr::util::rng::Rng;

fn init_runtime() {
    let dir = runtime::default_artifacts_dir();
    let dir = if dir.is_relative() {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    };
    runtime::init(Some(&dir));
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("samr-lcp-oracle-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(name)
}

/// Small-knob scheme config: several mappers, three reducers, tiny
/// sorting groups — so the matrix exercises batch boundaries, reducer
/// boundaries, and the tie-break path even on test-sized corpora.
fn small_cfg(fixed_shuffle: bool, prefetch: bool) -> SchemeConfig {
    SchemeConfig {
        conf: JobConf {
            n_reducers: 3,
            io_sort_bytes: 16 << 10,
            split_bytes: 8 << 10,
            reducer_heap_bytes: 256 << 10,
            ..JobConf::default()
        },
        group_threshold: 500,
        samples_per_reducer: 100,
        prefetch,
        fixed_shuffle,
        ..Default::default()
    }
}

/// Construct + seal `files` with the given matrix point; return the
/// opened artifact.
fn seal(
    files: &[&[Read]],
    shards: usize,
    fixed_shuffle: bool,
    prefetch: bool,
    name: &str,
) -> SealedIndex {
    let cfg = small_cfg(fixed_shuffle, prefetch);
    let store = SharedStore::new(shards);
    let factory: scheme::StoreFactory =
        Arc::new(move || Box::new(store.clone()) as Box<dyn SuffixStore>);
    let ledger = Ledger::new();
    let path = tmp(name);
    scheme::run_files_sealed(files, &cfg, factory, &ledger, &path).expect("sealed run");
    SealedIndex::open(&path).expect("open sealed")
}

fn paired_corpus() -> (Vec<Read>, Vec<Read>) {
    synth_paired_corpus(&CorpusSpec {
        n_reads: 30,
        read_len: 20,
        len_jitter: 0,
        genome_len: 2048,
        seed: 0x0AC1E,
        ..Default::default()
    })
}

/// Claim 1: on a single-read corpus the sealed aux sections ARE the
/// classical sequential structures. The sealed index holds one extra
/// suffix — the lone `$` (empty) suffix at rank 0, which `sais`/`kasai`
/// do not model — so sealed rank `i + 1` maps to oracle rank `i` for the
/// LCP, while `bwt_from_sa` already models the sentinel row and maps
/// rank for rank (its `None` slot is the sealed [`BWT_TERMINATOR`]).
#[test]
fn pipeline_lcp_and_bwt_match_the_sequential_oracles() {
    init_runtime();
    let mut rng = Rng::new(0x1CF);
    let text: Vec<u8> = (0..700).map(|_| 1 + rng.below(4) as u8).collect();
    let read = Read::new(0, text.clone());
    let n = text.len();
    let sa = sa::sais(&text);
    let lcp = kasai(&text, &sa);
    let oracle_bwt = bwt_from_sa(&text, &sa);
    for &shards in &[1usize, 3] {
        for &fixed_shuffle in &[false, true] {
            for &prefetch in &[false, true] {
                let tag = format!("shards={shards} fixed={fixed_shuffle} prefetch={prefetch}");
                let name = format!("kasai-s{shards}-f{fixed_shuffle}-p{prefetch}.samr");
                let reads: Vec<Read> = vec![read.clone()];
                let idx = seal(&[&reads], shards, fixed_shuffle, prefetch, &name);
                assert!(idx.has_lcp() && idx.has_tree() && idx.has_bwt(), "{tag}: aux sections");
                assert_eq!(idx.stats().n_suffixes as usize, n + 1, "{tag}: SA length");
                // rank 0 is the lone $ suffix; the text ranks follow in
                // sais order
                assert_eq!(unpack_index(idx.sa_at(0)), (0u64, n), "{tag}: rank 0 is $");
                assert_eq!(idx.lcp_at(0), 0, "{tag}: lcp[0]");
                for i in 0..n {
                    assert_eq!(
                        unpack_index(idx.sa_at(i + 1)),
                        (0u64, sa[i] as usize),
                        "{tag}: SA rank {}",
                        i + 1
                    );
                    assert_eq!(idx.lcp_at(i + 1), lcp[i], "{tag}: kasai rank {i}");
                }
                for r in 0..=n {
                    let want = match oracle_bwt[r] {
                        None => BWT_TERMINATOR,
                        Some(c) => c,
                    };
                    assert_eq!(idx.bwt_at(r), want, "{tag}: BWT rank {r}");
                }
            }
        }
    }
}

/// Claim 2: on the multi-read pair-end corpus, every sealed LCP entry
/// equals the naive common-prefix count of the adjacent sealed suffixes,
/// and every BWT entry equals the read byte preceding the suffix
/// ([`BWT_TERMINATOR`] at offset 0) — across the full construction
/// matrix, so batch stitches, reducer stitches, and tie-break groups are
/// all covered.
#[test]
fn pipeline_lcp_and_bwt_match_naive_recompute_across_the_matrix() {
    init_runtime();
    let (fwd, rev) = paired_corpus();
    let mut all = fwd.clone();
    all.extend(rev.iter().cloned());
    let by_seq: HashMap<u64, &[u8]> =
        all.iter().map(|r| (r.seq, r.codes.as_slice())).collect();
    for &shards in &[1usize, 3] {
        for &fixed_shuffle in &[false, true] {
            for &prefetch in &[false, true] {
                let tag = format!("shards={shards} fixed={fixed_shuffle} prefetch={prefetch}");
                let name = format!("naive-s{shards}-f{fixed_shuffle}-p{prefetch}.samr");
                let idx = seal(&[&fwd, &rev], shards, fixed_shuffle, prefetch, &name);
                let n = idx.stats().n_suffixes as usize;
                assert!(n > 0, "{tag}: empty index");
                for rank in 0..n {
                    let want_lcp = if rank == 0 {
                        0
                    } else {
                        let a = idx.suffix(idx.sa_at(rank - 1)).expect("suffix");
                        let b = idx.suffix(idx.sa_at(rank)).expect("suffix");
                        a.iter().zip(b).take_while(|(x, y)| x == y).count() as u32
                    };
                    assert_eq!(idx.lcp_at(rank), want_lcp, "{tag}: LCP rank {rank}");
                    let (seq, off) = unpack_index(idx.sa_at(rank));
                    let codes = by_seq[&seq];
                    let want_bwt = if off == 0 { BWT_TERMINATOR } else { codes[off - 1] };
                    assert_eq!(idx.bwt_at(rank), want_bwt, "{tag}: BWT rank {rank}");
                }
            }
        }
    }
}

/// Claim 3: the emission is free. Two otherwise-identical constructions
/// with `emit_lcp` on and off produce the same output order and the same
/// total on every one of the nine footprint-ledger channels — the
/// sidecar spool is deliberately uncharged local scratch, and the LCP
/// never rides in an output record.
#[test]
fn emit_lcp_leaves_output_order_and_every_ledger_channel_invariant() {
    init_runtime();
    let (fwd, rev) = paired_corpus();
    let run = |emit_lcp: bool| {
        let cfg = SchemeConfig { emit_lcp, ..small_cfg(true, true) };
        let store = SharedStore::new(3);
        let factory: scheme::StoreFactory =
            Arc::new(move || Box::new(store.clone()) as Box<dyn SuffixStore>);
        let ledger = Ledger::new();
        let result = scheme::run_files(&[&fwd, &rev], &cfg, factory, &ledger).expect("run");
        let channels: Vec<u64> = CHANNELS.iter().map(|&c| ledger.get(c)).collect();
        (result.order, channels)
    };
    let (order_on, ledger_on) = run(true);
    let (order_off, ledger_off) = run(false);
    assert_eq!(order_on, order_off, "output order must not depend on emit_lcp");
    for (slot, ch) in CHANNELS.iter().enumerate() {
        assert_eq!(
            ledger_on[slot],
            ledger_off[slot],
            "ledger channel {:?} must not depend on emit_lcp",
            ch.name()
        );
    }
}

/// Per-query byte-comparison ceiling for the accelerated bounds: two
/// bounds, each ≤ |P| plus one text byte per binary-search iteration.
fn accel_ceiling(pattern_len: usize, n_suffixes: usize) -> u64 {
    let lg = (usize::BITS - n_suffixes.leading_zeros()) as u64;
    2 * (pattern_len as u64 + lg + 2)
}

/// Claim 4a: on the sealed artifact, the accelerated and plain bounds
/// return identical ranges for every fuzzed pattern — empty, planted
/// (so non-trivial ranges occur), random, and max-length (1000 bp,
/// longer than any read, so necessarily absent) — and every accelerated
/// query stays under the O(|P| + log n) comparison ceiling.
#[test]
fn sealed_accelerated_search_matches_plain_on_fuzzed_patterns() {
    init_runtime();
    let (fwd, rev) = paired_corpus();
    let mut all = fwd.clone();
    all.extend(rev.iter().cloned());
    let idx = seal(&[&fwd, &rev], 3, true, true, "fuzz.samr");
    assert!(idx.stats().has_tree, "fuzz target must carry the tree");
    let mut rng = Rng::new(0xF22);
    let mut nonempty = 0usize;
    for trial in 0..300 {
        let pattern: Vec<u8> = if trial % 7 == 0 {
            Vec::new()
        } else if trial % 5 == 0 {
            // max-length pattern: longer than any read, necessarily absent
            (0..1000).map(|_| 1 + rng.below(4) as u8).collect()
        } else if trial % 3 == 0 {
            // planted slice of a real read
            let r = &all[rng.below(all.len() as u64) as usize].codes;
            let plen = (1 + rng.below(12) as usize).min(r.len());
            let at = rng.below((r.len() - plen + 1) as u64) as usize;
            r[at..at + plen].to_vec()
        } else {
            let plen = 1 + rng.below(24) as usize;
            (0..plen).map(|_| 1 + rng.below(4) as u8).collect()
        };
        let (accel, accel_n) = idx.sa_range_counted(&pattern);
        let (plain, _) = idx.sa_range_plain_counted(&pattern);
        assert_eq!(accel, plain, "trial {trial}: pattern {pattern:?}");
        if pattern.len() == 1000 {
            assert!(accel.is_empty(), "trial {trial}: over-length pattern matched");
        }
        for r in accel.clone() {
            assert!(idx.suffix_at(r).starts_with(&pattern), "trial {trial}: rank {r}");
        }
        if !accel.is_empty() {
            nonempty += 1;
        }
        assert!(
            accel_n <= accel_ceiling(pattern.len(), idx.n_suffixes()),
            "trial {trial}: {accel_n} compares for |P|={}",
            pattern.len()
        );
    }
    assert!(nonempty > 30, "fuzz must exercise non-trivial ranges ({nonempty})");
}

/// Claim 4b: the complexity separation, on a sealed artifact built by
/// the real pipeline. A corpus of reads sharing a 120 bp stem forces the
/// plain bounds to re-walk the stem at every midpoint (~|P| log n); the
/// accelerated bounds resume at the proven depth and stay under the
/// O(|P| + log n) ceiling, with the plain count strictly dominating.
#[test]
fn sealed_accelerated_search_beats_plain_on_the_repetitive_corpus() {
    init_runtime();
    let mut rng = Rng::new(0xBEEF);
    let stem: Vec<u8> = (0..120).map(|_| 1 + rng.below(4) as u8).collect();
    let reads: Vec<Read> = (0..48u64)
        .map(|seq| {
            let mut codes = stem.clone();
            codes.extend((0..40).map(|_| 1 + rng.below(4) as u8));
            Read::new(seq, codes)
        })
        .collect();
    let idx = seal(&[&reads], 1, true, false, "repetitive.samr");
    let pattern = &stem[..100];
    let (accel_range, accel_n) = idx.sa_range_counted(pattern);
    let (plain_range, plain_n) = idx.sa_range_plain_counted(pattern);
    assert_eq!(accel_range, plain_range);
    assert!(accel_range.len() >= reads.len(), "every read starts with the stem");
    assert!(
        accel_n <= accel_ceiling(pattern.len(), idx.n_suffixes()),
        "accelerated bound not O(|P| + log n): {accel_n} compares"
    );
    assert!(
        plain_n > 2 * accel_n,
        "plain path should re-compare the shared stem: plain={plain_n} accel={accel_n}"
    );
}

//! Integration: the PJRT-compiled kernels must agree bit-for-bit with the
//! native fallback. Requires `make artifacts` (skips politely otherwise).

use samr::runtime::{self, native};
use samr::suffix::encode::{encode_prefix, DEFAULT_PREFIX_LEN};
use samr::suffix::reads::{synth_corpus, CorpusSpec};
use samr::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = runtime::default_artifacts_dir();
    let dir = if dir.is_relative() {
        // tests run from the crate root
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    };
    dir.join("manifest.txt").exists().then_some(dir)
}

fn init() -> bool {
    match artifacts() {
        Some(dir) => {
            if runtime::init(Some(&dir)) {
                true
            } else {
                eprintln!("built without the `pjrt` feature; skipping PJRT integration test");
                false
            }
        }
        None => {
            eprintln!("artifacts/ missing; skipping PJRT integration test");
            false
        }
    }
}

#[test]
fn map_encode_matches_native() {
    if !init() {
        return;
    }
    let spec = CorpusSpec { n_reads: 100, read_len: 100, len_jitter: 3, ..Default::default() };
    let reads = synth_corpus(&spec);
    let mut rng = Rng::new(42);
    let mut bounds: Vec<i64> = (0..31).map(|_| rng.below(5u64.pow(23) as u64) as i64).collect();
    bounds.sort_unstable();

    runtime::with_engine(|eng| {
        let eng = eng.expect("engine should load");
        let refs: Vec<&_> = reads.iter().collect();
        for tile in refs.chunks(64) {
            let out = eng
                .map_encode_tile(tile, &bounds, DEFAULT_PREFIX_LEN)
                .expect("map_encode_tile");
            for (i, rd) in tile.iter().enumerate() {
                let mut native_out = Vec::new();
                native::encode_read(rd, &bounds, DEFAULT_PREFIX_LEN, &mut native_out);
                for (off, rec) in native_out.iter().enumerate() {
                    let j = i * out.lp + off;
                    assert_eq!(out.keys[j], rec.key, "key seq={} off={off}", rd.seq);
                    assert_eq!(out.indexes[j], rec.index, "index seq={} off={off}", rd.seq);
                    assert_eq!(
                        out.partitions[j] as u32, rec.partition,
                        "partition seq={} off={off}",
                        rd.seq
                    );
                    assert_eq!(out.valid[j], 1, "valid seq={} off={off}", rd.seq);
                }
                // offsets past len are invalid
                for off in rd.len() + 1..out.lp {
                    assert_eq!(out.valid[i * out.lp + off], 0);
                }
            }
        }
    });
}

#[test]
fn group_sort_matches_native() {
    if !init() {
        return;
    }
    runtime::with_engine(|eng| {
        let eng = eng.expect("engine");
        let mut rng = Rng::new(7);
        for n in [1usize, 2, 5, 100, 1000, 1024] {
            let mut keys: Vec<i64> = (0..n).map(|_| rng.below(50) as i64).collect();
            let mut idxs: Vec<i64> = (0..n).map(|i| i as i64 * 7 % n as i64).collect();
            let mut nk = keys.clone();
            let mut ni = idxs.clone();
            native::group_sort(&mut nk, &mut ni);
            eng.group_sort(&mut keys, &mut idxs).expect("group_sort");
            assert_eq!(keys, nk, "n={n}");
            assert_eq!(idxs, ni, "n={n}");
        }
    });
}

#[test]
fn sample_sort_matches_native() {
    if !init() {
        return;
    }
    runtime::with_engine(|eng| {
        let eng = eng.expect("engine");
        let mut rng = Rng::new(9);
        let mut keys: Vec<i64> = (0..3000).map(|_| rng.next_u64() as i64 & i64::MAX).collect();
        let mut want = keys.clone();
        native::sample_sort(&mut want);
        eng.sample_sort(&mut keys).expect("sample_sort");
        assert_eq!(keys, want);
    });
}

#[test]
fn known_prefix_key_through_pjrt() {
    if !init() {
        return;
    }
    runtime::with_engine(|eng| {
        let eng = eng.expect("engine");
        let read = samr::suffix::reads::Read::from_ascii(5, b"ACGT");
        let out = eng.map_encode_tile(&[&read], &[], DEFAULT_PREFIX_LEN).unwrap();
        assert_eq!(out.keys[0], encode_prefix(&read.codes, DEFAULT_PREFIX_LEN));
        assert_eq!(out.indexes[0], 5000);
    });
}

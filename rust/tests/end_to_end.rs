//! Integration: the full stack with real TCP KV servers, PJRT kernels
//! (when artifacts are built), real spill files — both pipelines, one
//! corpus, identical validated output. Plus failure-injection cases.

use std::sync::Arc;

use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{ShardedClient, SharedStore, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::validate::validate_order;
use samr::terasort::{self, TeraSortConfig};

fn init_runtime() {
    let dir = runtime::default_artifacts_dir();
    let dir = if dir.is_relative() {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    };
    runtime::init(Some(&dir));
}

fn conf(n_reducers: usize) -> JobConf {
    JobConf {
        n_reducers,
        io_sort_bytes: 64 << 10,
        split_bytes: 64 << 10,
        reducer_heap_bytes: 1 << 20,
        ..JobConf::default()
    }
}

#[test]
fn full_stack_over_tcp_matches_baseline() {
    init_runtime();
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: 600,
        read_len: 90,
        len_jitter: 4,
        genome_len: 1 << 16, // repetitive enough to create tie groups
        seed: 77,
        ..Default::default()
    });

    // pair-end scheme over real sockets: TWO input files, one shared
    // sharded store, one joint index stream (paper Case 6)
    let kv = LocalKvCluster::start(5).expect("kv cluster");
    let addrs = kv.addrs();
    let factory: scheme::StoreFactory = Arc::new(move || {
        Box::new(ShardedClient::connect(&addrs).expect("connect")) as Box<dyn SuffixStore>
    });
    let ledger = Ledger::new();
    let res = scheme::run_files(
        &[&fwd, &rev],
        &SchemeConfig {
            conf: conf(3),
            group_threshold: 20_000,
            samples_per_reducer: 1_000,
            ..Default::default()
        },
        factory,
        &ledger,
    )
    .expect("scheme");
    let mut reads = fwd;
    reads.extend(rev);
    validate_order(&reads, &res.order).expect("scheme order");

    // baseline on the same corpus
    let ledger_t = Ledger::new();
    let tera = terasort::run(
        &reads,
        &TeraSortConfig { conf: conf(3), ..Default::default() },
        &ledger_t,
    )
    .expect("terasort");
    assert_eq!(res.order, tera.order, "pipelines must agree");

    // headline: the scheme moved strictly fewer local-disk + shuffle bytes
    let s = ledger.snapshot();
    let t = ledger_t.snapshot();
    assert!(s.local_disk_total() < t.local_disk_total());
    assert!(s.get(Channel::Shuffle) < t.get(Channel::Shuffle));
    // and the KV servers saw real traffic
    let (inb, outb) = kv.traffic();
    assert!(inb > 0 && outb > 0);
    assert!(kv.used_memory() > 0);
}

#[test]
fn scheme_handles_degenerate_corpora() {
    init_runtime();
    // single 1-char read
    let reads = vec![samr::suffix::reads::Read::from_ascii(0, b"A")];
    let store = SharedStore::new(2);
    let s = store.clone();
    let ledger = Ledger::new();
    let res = scheme::run(
        &reads,
        &SchemeConfig {
            conf: conf(2),
            group_threshold: 10,
            samples_per_reducer: 10,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger,
    )
    .expect("scheme");
    validate_order(&reads, &res.order).expect("order");
    assert_eq!(res.order.len(), 2); // "A$" and "$"
}

#[test]
fn scheme_all_identical_reads_stress_tie_breaking() {
    init_runtime();
    // 100 identical reads: every suffix text has 100 duplicates
    let reads: Vec<_> = (0..100u64)
        .map(|i| samr::suffix::reads::Read::from_ascii(i, b"ACGTACGTACGTACGTACGTACGTACGT"))
        .collect();
    let store = SharedStore::new(3);
    let s = store.clone();
    let ledger = Ledger::new();
    let res = scheme::run(
        &reads,
        &SchemeConfig {
            conf: conf(2),
            group_threshold: 700, // forces many flushes mid-group
            samples_per_reducer: 100,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger,
    )
    .expect("scheme");
    validate_order(&reads, &res.order).expect("order with max duplicates");
}

#[test]
fn oversized_read_is_rejected_not_aliased() {
    // A 1000+ bp read has suffix offsets that alias into the NEXT
    // sequence number when packed (seq*1000 + offset) — release builds
    // used to let this through (the guard was a debug_assert) and emit a
    // silently wrong suffix array. This test runs in BOTH profiles (CI
    // runs the suite under --release too): ingestion must fail loudly.
    use samr::suffix::reads::{parse_fasta, ParsePolicy, Read};

    // parser-level ingestion: a real io::Error
    let mut fasta = b">huge\n".to_vec();
    fasta.extend(vec![b'A'; 1000]);
    let err = parse_fasta(&fasta, 0, ParsePolicy::Strict).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

    // fallible constructor: same rejection
    assert!(Read::try_new(0, vec![1u8; 1000]).is_err());
    assert!(Read::try_new(0, vec![1u8; 999]).is_ok());

    // and the packed index itself refuses to alias, even in release
    let packed = std::panic::catch_unwind(|| samr::suffix::encode::pack_index(5, 1000));
    assert!(packed.is_err(), "pack_index must panic on aliasing offsets");
    assert_eq!(
        samr::suffix::encode::pack_index(5, 999),
        samr::suffix::encode::pack_index(6, 0) - 1,
        "boundary offsets stay distinct"
    );
}

#[test]
fn missing_read_in_store_fails_loudly() {
    init_runtime();
    // a store that was never populated must fail the fetch — the reducer
    // propagates it as a clean io::Error through the job (see
    // scheme::tests::fetch_failure_is_a_clean_error_not_a_panic), never
    // silently emitting garbage.
    let mut empty = SharedStore::new(2);
    // sabotage: pre-fetch proves it's empty
    assert!(empty.fetch_suffixes(&[0]).is_err());
}

#[test]
fn terasort_conf_sweep_stays_correct() {
    init_runtime();
    let reads = samr::suffix::reads::synth_corpus(&CorpusSpec {
        n_reads: 150,
        read_len: 40,
        genome_len: 1 << 12,
        ..Default::default()
    });
    for (sort_kb, factor) in [(2u64, 2usize), (8, 3), (64, 10)] {
        let ledger = Ledger::new();
        let res = terasort::run(
            &reads,
            &TeraSortConfig {
                conf: JobConf {
                    n_reducers: 3,
                    io_sort_bytes: sort_kb << 10,
                    split_bytes: 16 << 10,
                    reducer_heap_bytes: 128 << 10,
                    io_sort_factor: factor,
                    ..JobConf::default()
                },
                ..Default::default()
            },
            &ledger,
        )
        .expect("terasort");
        validate_order(&reads, &res.order)
            .unwrap_or_else(|e| panic!("sort_kb={sort_kb} factor={factor}: {e}"));
    }
}

//! Sealed-artifact robustness: every corruption mode must yield a
//! descriptive `io::Error` from `SealedIndex::open` — never a panic and
//! never a silently wrong index. Each case patches real bytes in a real
//! sealed file; cases that target checks *behind* the checksum re-stamp
//! the trailing FNV so the patched field is actually reached.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;

use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::sealed::{
    self, SealedIndex, CHECKSUM_LEN, EXT_LEN, FOOTER_LEN, MIN_FILE_LEN,
};
use samr::suffix::validate::reference_order;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("samr-sealed-fmt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(name)
}

/// Seal a small two-file pair-end corpus and return the artifact bytes.
fn sealed_bytes(name: &str) -> (PathBuf, Vec<u8>) {
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: 12,
        read_len: 18,
        len_jitter: 0,
        genome_len: 1024,
        seed: 0xFEED,
        ..Default::default()
    });
    let mut all = fwd.clone();
    all.extend(rev.iter().cloned());
    let order = reference_order(&all);
    let path = tmp(name);
    sealed::seal(&path, &[&fwd, &rev], &order).expect("seal");
    let bytes = std::fs::read(&path).expect("read artifact");
    (path, bytes)
}

/// Write `bytes` to a fresh file and open it, converting any panic into
/// a test failure distinct from the expected clean `Err`.
fn open_patched(name: &str, bytes: &[u8]) -> std::io::Result<SealedIndex> {
    let path = tmp(name);
    std::fs::write(&path, bytes).expect("write patched artifact");
    catch_unwind(AssertUnwindSafe(|| SealedIndex::open(&path)))
        .unwrap_or_else(|_| panic!("SealedIndex::open panicked on {name}"))
}

/// Re-stamp the trailing checksum so patches to fields *behind* the
/// checksum gate are reached by open's later validation stages.
fn restamp(bytes: &mut [u8]) {
    let body = bytes.len() - CHECKSUM_LEN;
    let sum = sealed::checksum(&bytes[..body]);
    bytes[body..].copy_from_slice(&sum.to_le_bytes());
}

fn expect_err(name: &str, bytes: &[u8], needle: &str) {
    let err = match open_patched(name, bytes) {
        Err(e) => e,
        Ok(_) => panic!("{name}: corrupted artifact opened successfully"),
    };
    let msg = err.to_string();
    assert!(
        msg.contains(needle),
        "{name}: error {msg:?} does not mention {needle:?}"
    );
}

#[test]
fn pristine_artifact_opens() {
    let (path, bytes) = sealed_bytes("pristine.samr");
    let idx = SealedIndex::open(&path).expect("open pristine");
    assert!(idx.stats().n_suffixes > 0);
    assert!(bytes.len() >= MIN_FILE_LEN);
}

#[test]
fn truncation_below_the_minimal_container_is_rejected() {
    let (_, bytes) = sealed_bytes("tiny.samr");
    expect_err("tiny-cut.samr", &bytes[..MIN_FILE_LEN - 1], "shorter");
    expect_err("empty.samr", &[], "shorter");
}

#[test]
fn truncation_mid_file_is_rejected() {
    let (_, bytes) = sealed_bytes("midcut.samr");
    // cut inside the section payload: footer/checksum now read section
    // bytes, so either the checksum or the section table must trip
    let cut = &bytes[..bytes.len() - bytes.len() / 3];
    assert!(cut.len() >= MIN_FILE_LEN, "corpus too small for a mid-file cut");
    let err = match open_patched("midcut-cut.samr", cut) {
        Err(e) => e,
        Ok(_) => panic!("mid-file truncation opened successfully"),
    };
    assert!(!err.to_string().is_empty());
}

#[test]
fn flipped_checksum_byte_is_rejected() {
    let (_, mut bytes) = sealed_bytes("cksum.samr");
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    expect_err("cksum-flip.samr", &bytes, "checksum mismatch");
}

#[test]
fn flipped_payload_byte_is_rejected() {
    let (_, mut bytes) = sealed_bytes("payload.samr");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    expect_err("payload-flip.samr", &bytes, "checksum mismatch");
}

#[test]
fn wrong_version_is_rejected() {
    let (_, mut bytes) = sealed_bytes("version.samr");
    // version u32 LE at offset 8, just after the magic
    bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
    restamp(&mut bytes);
    expect_err("version-patch.samr", &bytes, "unsupported version 99");
}

#[test]
fn bad_magic_is_rejected() {
    let (_, mut bytes) = sealed_bytes("magic.samr");
    bytes[0..8].copy_from_slice(b"NOTANIDX");
    expect_err("magic-patch.samr", &bytes, "bad magic");
}

#[test]
fn zero_length_sa_section_is_rejected() {
    let (_, mut bytes) = sealed_bytes("zerosa.samr");
    // footer layout: counts (24) + 4 section (off, len) pairs; the SA
    // length is the second pair's len, at footer_start + 24 + 16 + 8
    let footer_start = bytes.len() - CHECKSUM_LEN - FOOTER_LEN;
    let sa_len_at = footer_start + 48;
    bytes[sa_len_at..sa_len_at + 8].copy_from_slice(&0u64.to_le_bytes());
    restamp(&mut bytes);
    expect_err("zerosa-patch.samr", &bytes, "SA");
}

// ---------------------------------------------------------------------
// v2 extension footer + auxiliary sections (LCP / midpoint tree / BWT)
// ---------------------------------------------------------------------

/// Byte offset of the v2 extension footer (three (off, len) pairs:
/// LCP at +0, TREE at +16, BWT at +32).
fn ext_start(bytes: &[u8]) -> usize {
    bytes.len() - CHECKSUM_LEN - FOOTER_LEN - EXT_LEN
}

#[test]
fn wrong_reserved_extension_length_is_rejected() {
    let (_, mut bytes) = sealed_bytes("reserved.samr");
    // the reserved footer word declares the extension-footer length; a
    // v2 artifact claiming 0 (or any non-EXT_LEN value) is inconsistent
    let reserved_at = bytes.len() - CHECKSUM_LEN - FOOTER_LEN + 88;
    bytes[reserved_at..reserved_at + 8].copy_from_slice(&0u64.to_le_bytes());
    restamp(&mut bytes);
    expect_err("reserved-patch.samr", &bytes, "extension footer");
}

#[test]
fn partial_lcp_section_is_rejected() {
    let (_, mut bytes) = sealed_bytes("lcpcut.samr");
    // shrink the declared LCP length by one entry: aux sections must be
    // present in full (n_sa entries) or absent — nothing in between
    let len_at = ext_start(&bytes) + 8;
    let declared = u64::from_le_bytes(bytes[len_at..len_at + 8].try_into().unwrap());
    assert!(declared > 4, "corpus too small to shrink the LCP section");
    bytes[len_at..len_at + 8].copy_from_slice(&(declared - 4).to_le_bytes());
    restamp(&mut bytes);
    expect_err("lcpcut-patch.samr", &bytes, "LCP");
}

#[test]
fn tree_section_outside_the_body_is_rejected() {
    let (_, mut bytes) = sealed_bytes("treeoff.samr");
    // point the midpoint-tree offset past the extension footer
    let off_at = ext_start(&bytes) + 16;
    bytes[off_at..off_at + 8].copy_from_slice(&(bytes.len() as u64).to_le_bytes());
    restamp(&mut bytes);
    expect_err("treeoff-patch.samr", &bytes, "midpoint-tree");
}

#[test]
fn zeroed_aux_lengths_degrade_to_plain_search() {
    // zero-length aux sections are the documented degrade, not an
    // error: the artifact opens and serves through the plain path with
    // identical answers
    let (path, mut bytes) = sealed_bytes("degrade.samr");
    let full = SealedIndex::open(&path).expect("open full");
    assert!(full.stats().has_lcp && full.stats().has_tree && full.stats().has_bwt);
    let et = ext_start(&bytes);
    for pair in 0..3 {
        let len_at = et + pair * 16 + 8;
        bytes[len_at..len_at + 8].copy_from_slice(&0u64.to_le_bytes());
    }
    restamp(&mut bytes);
    let degraded = open_patched("degrade-zeroed.samr", &bytes).expect("degrade must open");
    let st = degraded.stats();
    assert!(!st.has_lcp && !st.has_tree && !st.has_bwt);
    for pat in [&b"ACGT"[..], b"TT", b"A", b""] {
        let codes: Vec<u8> = pat.iter().map(|&c| samr::suffix::encode::code_of(c)).collect();
        assert_eq!(
            samr::suffix::search::IndexView::find(&degraded, &codes),
            samr::suffix::search::IndexView::find(&full, &codes),
            "degraded artifact must answer like the full one for {pat:?}"
        );
    }
}

#[test]
fn v1_artifact_opens_and_serves_like_plain_v2() {
    // back-compat: a version-1 file (no extension footer) must open and
    // answer identically to a v2 artifact without aux sections
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: 12,
        read_len: 18,
        len_jitter: 0,
        genome_len: 1024,
        seed: 0xFEED,
        ..Default::default()
    });
    let mut all = fwd.clone();
    all.extend(rev.iter().cloned());
    let order = reference_order(&all);
    let v1_path = tmp("compat-v1.samr");
    let v2_path = tmp("compat-v2plain.samr");
    sealed::seal_v1(&v1_path, &[&fwd, &rev], &order).expect("seal v1");
    sealed::seal_plain(&v2_path, &[&fwd, &rev], &order).expect("seal plain v2");
    let v1 = SealedIndex::open(&v1_path).expect("open v1");
    let v2 = SealedIndex::open(&v2_path).expect("open v2");
    assert_eq!(v1.version(), 1);
    assert_eq!(v2.version(), 2);
    assert!(!v1.stats().has_lcp && !v2.stats().has_lcp);
    for (rank, &want) in order.iter().enumerate() {
        assert_eq!(v1.sa_at(rank), want, "v1 SA rank {rank}");
        assert_eq!(v2.sa_at(rank), want, "v2 SA rank {rank}");
    }
    use samr::suffix::search::IndexView;
    for pat in [&b"ACGT"[..], b"GG", b"T", b"AAAA"] {
        let codes: Vec<u8> = pat.iter().map(|&c| samr::suffix::encode::code_of(c)).collect();
        assert_eq!(v1.find(&codes), v2.find(&codes), "v1 vs v2-plain SEARCH {pat:?}");
        assert_eq!(
            v1.find_pairs(&codes, &codes, 500),
            v2.find_pairs(&codes, &codes, 500),
            "v1 vs v2-plain PAIRS {pat:?}"
        );
    }
}

//! Chaos matrix for fault-tolerant execution: inject deterministic task
//! failures (panics and errors, per attempt, per point) and shard
//! outages (kill mid-pipeline, refuse reconnects, revive) into full
//! scheme runs, and assert the *strongest* recovery property the design
//! claims — not merely that the job finishes, but that its output bytes
//! and every one of the nine footprint-ledger channels are byte-identical
//! to a fault-free run. Retries charge their abandoned attempts to a
//! separate `wasted` tally; shard failover replays re-sends into
//! `wasted_sent`; neither may move a single accounted byte.
//!
//! Fault plans are seeded (`SAMR_FAULT_SEED`, CI pins it): sweep locally
//! with `for s in $(seq 0 31); do SAMR_FAULT_SEED=$s cargo test --test
//! fault_tolerance; done`.

use std::sync::Arc;
use std::time::Duration;

use samr::faults::{FaultPlan, FaultPoint, ShardFault};
use samr::footprint::{Footprint, Ledger, CHANNELS};
use samr::kvstore::client::FailoverConfig;
use samr::kvstore::shard::{ShardedClient, SharedStore, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::mapreduce::JobConf;
use samr::scheme::{self, SchemeConfig, StoreFactory};
use samr::suffix::reads::{synth_corpus, CorpusSpec, Read};
use samr::suffix::validate::validate_order;

fn corpus(seed: u64) -> Vec<Read> {
    synth_corpus(&CorpusSpec {
        n_reads: 60,
        read_len: 30,
        genome_len: 2048, // repetitive: forces incomplete-group ties
        seed,
        ..Default::default()
    })
}

fn scheme_cfg(
    fixed_shuffle: bool,
    prefetch: bool,
    max_attempts: usize,
    faults: Option<Arc<FaultPlan>>,
) -> SchemeConfig {
    let mut cfg = SchemeConfig {
        conf: JobConf {
            n_reducers: 3,
            split_bytes: 1 << 10, // several map tasks over this corpus
            io_sort_bytes: 8 << 10,
            reducer_heap_bytes: 64 << 10,
            ..JobConf::default()
        },
        group_threshold: 500,
        samples_per_reducer: 200,
        prefetch,
        fixed_shuffle,
        ..Default::default()
    };
    cfg.conf.max_task_attempts = max_attempts;
    cfg.conf.faults = faults;
    cfg
}

/// Everything one run produces that equivalence can be asserted over.
struct RunOut {
    order: Vec<i64>,
    fp: Footprint,
    out: Vec<(Vec<u8>, Vec<u8>)>,
    wasted: Footprint,
    n_maps: usize,
    n_reduces: usize,
}

fn run_once(reads: &[Read], factory: StoreFactory, cfg: &SchemeConfig) -> RunOut {
    let ledger = Ledger::new();
    let res = scheme::run(reads, cfg, factory, &ledger).expect("scheme run");
    let mut out = Vec::new();
    res.job
        .for_each_output(|r| {
            out.push((r.key, r.value));
            Ok(())
        })
        .expect("stream output");
    RunOut {
        order: res.order,
        fp: ledger.snapshot(),
        out,
        wasted: res.job.wasted,
        n_maps: res.job.map_stats.len(),
        n_reduces: res.job.reduce_stats.len(),
    }
}

fn inproc_factory(shards: usize) -> StoreFactory {
    let store = SharedStore::new(shards);
    Arc::new(move || Box::new(store.clone()) as Box<dyn SuffixStore>)
}

/// A client failover policy tight enough for tests: real deadlines,
/// fast deterministic backoff.
fn test_failover() -> FailoverConfig {
    FailoverConfig {
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(10),
        ..FailoverConfig::default()
    }
}

#[test]
fn chaos_task_faults_leave_output_and_footprint_byte_identical() {
    let reads = corpus(11);
    let seed = FaultPlan::env_seed(7);
    for shards in [1usize, 3] {
        for fixed_shuffle in [true, false] {
            for prefetch in [true, false] {
                let label =
                    format!("shards={shards} fixed={fixed_shuffle} prefetch={prefetch} seed={seed}");
                // fault-free baseline on the literal single-attempt path
                let base = run_once(
                    &reads,
                    inproc_factory(shards),
                    &scheme_cfg(fixed_shuffle, prefetch, 1, None),
                );
                assert_eq!(
                    base.wasted,
                    Footprint::default(),
                    "a clean run wastes nothing ({label})"
                );
                // seed a failure chain per phase against the REAL task
                // counts, so every spec is reachable and fires
                let plan = Arc::new(FaultPlan::seeded(seed, base.n_maps, base.n_reduces, 3));
                let n_specs = plan.task_faults.len();
                let faulted = run_once(
                    &reads,
                    inproc_factory(shards),
                    &scheme_cfg(fixed_shuffle, prefetch, 3, Some(plan.clone())),
                );
                validate_order(&reads, &faulted.order).expect("faulted order invalid");
                assert_eq!(faulted.order, base.order, "suffix order ({label})");
                assert_eq!(faulted.out, base.out, "output records ({label})");
                for ch in CHANNELS {
                    assert_eq!(
                        faulted.fp.get(ch),
                        base.fp.get(ch),
                        "{} bytes ({label})",
                        ch.name()
                    );
                }
                assert_eq!(
                    plan.task_faults_fired(),
                    n_specs,
                    "every injected fault must fire ({label})"
                );
                // a fault AFTER the task body ran abandons a fully-charged
                // attempt; one BEFORE it abandons an empty one
                if plan.task_faults.iter().any(|f| f.point == FaultPoint::Finish) {
                    assert_ne!(
                        faulted.wasted,
                        Footprint::default(),
                        "abandoned attempts must tally as waste ({label})"
                    );
                }
            }
        }
    }
}

#[test]
fn chaos_shard_kill_and_revival_over_tcp() {
    let reads = corpus(23);
    for shards in [1usize, 3] {
        let fo = test_failover();
        let base = {
            let kv = LocalKvCluster::start(shards).expect("kv cluster");
            let addrs = kv.addrs();
            let factory: StoreFactory = Arc::new(move || {
                Box::new(ShardedClient::connect_with(&addrs, fo).expect("connect"))
                    as Box<dyn SuffixStore>
            });
            run_once(&reads, factory, &scheme_cfg(true, true, 1, None))
        };
        // kill the last shard mid-run: its connections drop mid-pipeline,
        // two reconnects are accepted-then-dropped, the third revives it;
        // every reply is also slightly delayed
        let mut plan = FaultPlan::with_shard_fault(ShardFault {
            shard: shards - 1,
            kill_at_request: 5,
            refuse_connects: 2,
        });
        plan.reply_delay = Some(Duration::from_micros(200));
        let plan = Arc::new(plan);
        let faulted = {
            let kv =
                LocalKvCluster::start_with_faults(shards, Some(plan.clone())).expect("kv cluster");
            let addrs = kv.addrs();
            let factory: StoreFactory = Arc::new(move || {
                Box::new(ShardedClient::connect_with(&addrs, fo).expect("connect"))
                    as Box<dyn SuffixStore>
            });
            // max_task_attempts stays 1: the outage is absorbed a layer
            // below the engine, by client reconnect-and-replay alone
            run_once(&reads, factory, &scheme_cfg(true, true, 1, None))
        };
        assert_eq!(plan.shard_kills(), 1, "the kill must fire (shards={shards})");
        validate_order(&reads, &faulted.order).expect("faulted order invalid");
        assert_eq!(faulted.order, base.order, "suffix order (shards={shards})");
        assert_eq!(faulted.out, base.out, "output records (shards={shards})");
        for ch in CHANNELS {
            assert_eq!(
                faulted.fp.get(ch),
                base.fp.get(ch),
                "{} bytes (shards={shards}): replayed wire bytes must never \
                 reach the ledger",
                ch.name()
            );
        }
        assert_eq!(
            faulted.wasted,
            Footprint::default(),
            "client-level failover never abandons a task attempt (shards={shards})"
        );
    }
}

#[test]
fn chaos_combined_task_and_shard_faults_over_tcp() {
    // everything at once: task failure chains absorbed by engine retry,
    // a shard kill/revive absorbed by client failover, delayed replies —
    // one plan describes the whole storm, and the run still matches the
    // fault-free baseline byte for byte
    let reads = corpus(31);
    let shards = 3;
    let seed = FaultPlan::env_seed(13);
    let fo = test_failover();
    let base = {
        let kv = LocalKvCluster::start(shards).expect("kv cluster");
        let addrs = kv.addrs();
        let factory: StoreFactory = Arc::new(move || {
            Box::new(ShardedClient::connect_with(&addrs, fo).expect("connect"))
                as Box<dyn SuffixStore>
        });
        run_once(&reads, factory, &scheme_cfg(true, true, 1, None))
    };
    let mut plan = FaultPlan::seeded(seed, base.n_maps, base.n_reduces, 3);
    plan.shard = Some(ShardFault { shard: 0, kill_at_request: 4, refuse_connects: 2 });
    plan.reply_delay = Some(Duration::from_micros(200));
    let plan = Arc::new(plan);
    let n_specs = plan.task_faults.len();
    let faulted = {
        let kv = LocalKvCluster::start_with_faults(shards, Some(plan.clone())).expect("kv cluster");
        let addrs = kv.addrs();
        let factory: StoreFactory = Arc::new(move || {
            Box::new(ShardedClient::connect_with(&addrs, fo).expect("connect"))
                as Box<dyn SuffixStore>
        });
        run_once(&reads, factory, &scheme_cfg(true, true, 3, Some(plan.clone())))
    };
    assert_eq!(plan.task_faults_fired(), n_specs, "every task fault fired (seed={seed})");
    assert_eq!(plan.shard_kills(), 1, "the shard kill fired (seed={seed})");
    validate_order(&reads, &faulted.order).expect("faulted order invalid");
    assert_eq!(faulted.order, base.order, "suffix order (seed={seed})");
    assert_eq!(faulted.out, base.out, "output records (seed={seed})");
    for ch in CHANNELS {
        assert_eq!(
            faulted.fp.get(ch),
            base.fp.get(ch),
            "{} bytes (seed={seed})",
            ch.name()
        );
    }
}

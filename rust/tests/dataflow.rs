//! Out-of-core dataflow tests: the engine streams disk-backed input
//! splits in and spooled reduce output back out, so (1) the streaming
//! consumption path is byte-identical to the opt-in collected path with
//! identical totals on every footprint-ledger channel, on both shuffle
//! implementations, and (2) an input far larger than the configured
//! record-buffer budgets completes with peak resident records bounded
//! by those budgets — not by input volume.

use std::sync::{Arc, Mutex};

use samr::footprint::{Channel, Footprint, Ledger, CHANNELS};
use samr::mapreduce::io::spool_records;
use samr::mapreduce::partitioner::RangePartitioner;
use samr::mapreduce::record::batch_bytes;
use samr::mapreduce::{resident, run_job, Job, JobConf, Record, ScratchDir};
use samr::util::rng::Rng;

/// The resident gauge is process-global, so every job-running test in
/// this binary serializes through this gate.
static GATE: Mutex<()> = Mutex::new(());

/// Identity sort job over `n` random 8 B + 8 B records.
fn sort_job(n: usize, n_reducers: usize, conf: JobConf, seed: u64) -> (Job, Vec<Record>) {
    let mut rng = Rng::new(seed);
    let input: Vec<Record> = (0..n)
        .map(|_| {
            Record::new(
                rng.next_u64().to_be_bytes().to_vec(),
                rng.next_u64().to_be_bytes().to_vec(),
            )
        })
        .collect();
    let samples: Vec<Vec<u8>> = input.iter().take(2000).map(|r| r.key.clone()).collect();
    let part = Arc::new(RangePartitioner::from_samples(samples, n_reducers));
    let job = Job {
        name: "dataflow-sort".into(),
        conf: JobConf { n_reducers, ..conf },
        map_factory: Arc::new(|_| {
            Box::new(|rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone()))
        }),
        reduce_factory: Arc::new(|_| {
            Box::new(
                |key: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                    for v in vals {
                        out(Record::new(key.to_vec(), v));
                    }
                },
            )
        }),
        partitioner: part.as_fn(),
    };
    (job, input)
}

#[test]
fn streamed_and_collected_outputs_are_identical_on_both_shuffle_paths() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let conf = JobConf {
        split_bytes: 8 << 10,
        io_sort_bytes: 4 << 10,
        reducer_heap_bytes: 16 << 10,
        io_sort_factor: 3,
        ..JobConf::default()
    };
    let mut footprints: Vec<Footprint> = Vec::new();
    let mut outputs: Vec<Vec<Record>> = Vec::new();
    for (fixed, sort_threads) in
        [(false, 1), (false, 4), (true, 1), (true, 4)]
    {
        let (job, input) = sort_job(
            6000,
            3,
            JobConf { fixed_width: fixed, parallel_sort_threads: sort_threads, ..conf.clone() },
            99,
        );
        let spool = ScratchDir::new(None, "dataflow-eq-in").unwrap();
        let splits =
            spool_records(spool.path.join("input"), &input, job.conf.split_bytes).unwrap();
        let ledger = Ledger::new();
        let res = run_job(&job, splits, &ledger).unwrap();

        // collected path: opt-in full materialization
        let collected = res.collect_output().unwrap();

        // streaming path must visit exactly the same records...
        let mut streamed: Vec<Record> = Vec::new();
        res.for_each_output(|r| {
            streamed.push(r);
            Ok(())
        })
        .unwrap();
        let flat: Vec<Record> = collected.iter().flatten().cloned().collect();
        assert_eq!(streamed, flat, "streamed vs collected records (fixed={fixed})");

        // ...and the raw output-file bytes must equal the collected
        // records' serialized form, reducer by reducer
        for (file, recs) in res.output.iter().zip(&collected) {
            let raw = std::fs::read(&file.path).unwrap();
            let mut reencoded = Vec::new();
            for r in recs {
                r.write_to(&mut reencoded).unwrap();
            }
            assert_eq!(raw, reencoded, "output file bytes (fixed={fixed})");
            assert_eq!(file.records as usize, recs.len());
            assert_eq!(file.bytes, batch_bytes(recs));
        }

        // ledger invariants: the disk-backed ends charge exactly the
        // record wire bytes, as the resident-vector dataflow did
        let fp = ledger.snapshot();
        assert_eq!(fp.get(Channel::HdfsRead), batch_bytes(&input));
        assert_eq!(fp.get(Channel::HdfsWrite), batch_bytes(&flat));
        footprints.push(fp);
        outputs.push(flat);
    }
    // every (shuffle path, parallel_sort_threads) combination: identical
    // records and identical totals on every footprint channel
    for i in 1..outputs.len() {
        assert_eq!(outputs[0], outputs[i], "output diverged in combination {i}");
        for ch in CHANNELS {
            assert_eq!(
                footprints[0].get(ch),
                footprints[i].get(ch),
                "{} must match across shuffle paths and sort threads (combination {i})",
                ch.name()
            );
        }
    }
}

#[test]
fn input_beyond_buffer_budgets_stays_under_budget() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    // tiny budgets, big input: 20k records x 24 B = ~480 KB against a
    // ~6.5 KB map spill trigger and an ~8 KB reducer heap. The spill
    // trigger (~273 records) deliberately exceeds resident::GAUGE_BATCH
    // so the task-local gauge batches actually publish.
    let conf = JobConf {
        split_bytes: 16 << 10,
        io_sort_bytes: 8 << 10,
        reducer_heap_bytes: 8 << 10,
        io_sort_factor: 4,
        task_parallelism: 2,
        ..JobConf::default()
    };
    // parallel_sort_threads = 4 rides along: at these tiny buffer sizes
    // the parallel paths degrade to the sequential code by design, so
    // the budget bound must hold exactly as at threads = 1
    for (fixed, sort_threads) in [(false, 1), (true, 1), (true, 4)] {
        let (job, input) = sort_job(
            20_000,
            2,
            JobConf { fixed_width: fixed, parallel_sort_threads: sort_threads, ..conf.clone() },
            7,
        );
        let wire = input[0].wire_bytes(); // 24 B, uniform

        // record-count budgets implied by the byte knobs (+ slack for
        // the one emit batch that lands past a trigger)
        let per_map = job.conf.spill_trigger() / wire + 64;
        let per_reduce =
            (job.conf.merge_trigger() + job.conf.segment_memory_limit()) / wire + 64;
        let parallel = job.conf.task_parallelism as u64;
        let budget = parallel * per_map.max(per_reduce);
        assert!(
            (input.len() as u64) > 8 * budget,
            "input ({}) must dwarf the budget ({budget})",
            input.len()
        );

        let spool = ScratchDir::new(None, "dataflow-smoke-in").unwrap();
        let splits =
            spool_records(spool.path.join("input"), &input, job.conf.split_bytes).unwrap();
        assert!(splits.len() > 20, "tiny split_bytes must cut many splits");
        assert!(
            job.conf.spill_trigger() / wire > samr::mapreduce::resident::GAUGE_BATCH,
            "spill trigger must exceed the gauge publish batch or peak stays 0"
        );

        resident::reset();
        let ledger = Ledger::new();
        let res = run_job(&job, splits, &ledger).unwrap();
        let peak = resident::peak();

        // the job really ran out-of-core...
        assert!(res.map_stats.iter().any(|s| s.spills > 1), "want multi-spill maps");
        assert!(ledger.get(Channel::ReduceLocalWrite) > 0, "want reduce-side spills");
        // ...and the sort is correct
        let mut got: Vec<Vec<u8>> = Vec::new();
        res.for_each_output(|r| {
            got.push(r.key);
            Ok(())
        })
        .unwrap();
        let mut want: Vec<Vec<u8>> = input.iter().map(|r| r.key.clone()).collect();
        want.sort();
        assert_eq!(got, want);

        // headline: peak resident records bounded by the buffer
        // budgets, while the input is 8x+ larger
        assert!(peak > 0, "gauge must have seen the buffers fill");
        assert!(
            peak <= budget,
            "peak resident records {peak} exceeds budget {budget} (fixed={fixed})"
        );
    }
}

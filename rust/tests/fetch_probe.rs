//! Isolates KV fetch throughput under pipeline-like concurrency.
use std::time::Instant;

use samr::kvstore::shard::{ShardedClient, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::suffix::encode::pack_index;
use samr::suffix::reads::{synth_corpus, CorpusSpec};

// Manual probe, not a correctness test: it spins up an 8-shard TCP
// cluster and pushes ~300k suffixes through it, which is slow and
// port/timing sensitive on shared CI runners (the ROADMAP's "seed tests
// failing"). Run explicitly with `cargo test --test fetch_probe -- --ignored`.
#[test]
#[ignore = "throughput probe: needs local TCP cluster headroom; run with --ignored"]
fn fetch_throughput_probe() {
    let reads = synth_corpus(&CorpusSpec { n_reads: 3_000, read_len: 100, ..Default::default() });
    let kv = LocalKvCluster::start(8).unwrap();
    let addrs = kv.addrs();
    let mut loader = ShardedClient::connect(&addrs).unwrap();
    loader.put_reads(&reads).unwrap();
    let all: Vec<i64> = reads.iter().flat_map(|r| (0..=r.len()).map(|o| pack_index(r.seq, o))).collect();
    println!("{} suffixes", all.len());

    // single client, whole corpus
    let mut c = ShardedClient::connect(&addrs).unwrap();
    let t0 = Instant::now();
    let (out, _) = c.fetch_suffixes(&all).unwrap();
    println!("single client: {:?} ({:.0}/s)", t0.elapsed(), all.len() as f64 / t0.elapsed().as_secs_f64());
    assert_eq!(out.len(), all.len());

    // 8 concurrent clients fetching disjoint eighths (reducer pattern)
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for part in 0..8 {
            let addrs = addrs.clone();
            let chunk: Vec<i64> = all.iter().copied().skip(part).step_by(8).collect();
            s.spawn(move || {
                let mut c = ShardedClient::connect(&addrs).unwrap();
                c.fetch_suffixes(&chunk).unwrap();
            });
        }
    });
    println!("8 concurrent clients: {:?} ({:.0}/s aggregate)", t0.elapsed(), all.len() as f64 / t0.elapsed().as_secs_f64());
}

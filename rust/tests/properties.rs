//! Property-based tests over the system's core invariants, on random
//! corpora/configurations (in-tree testkit; failing seeds are printed).

use std::sync::Arc;

use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{InProcStore, SharedStore, SuffixStore};
use samr::mapreduce::engine::{run_job, Job, ScratchDir};
use samr::mapreduce::io::spool_records;
use samr::mapreduce::partitioner::RangePartitioner;
use samr::mapreduce::record::{encode_i64_key, Record};
use samr::mapreduce::JobConf;
use samr::runtime::native;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::encode::{encode_prefix, unpack_index};
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::validate::{reference_order, sais_reference_order, validate_order};
use samr::terasort::{self, TeraSortConfig};
use samr::testkit::{gen, property};

/// Suffix-key encoding is order-preserving w.r.t. $-terminated text order
/// for any pair of suffixes, up to key equality (shared prefix).
#[test]
fn prop_key_order_respects_text_order() {
    property("key order vs text order", 200, |rng| {
        let p = 1 + rng.below(23) as usize;
        let a = gen::dna(rng, 0, 40);
        let b = gen::dna(rng, 0, 40);
        let (ka, kb) = (encode_prefix(&a, p), encode_prefix(&b, p));
        // text order with implicit terminator = slice order (prefix-free via $)
        let text_cmp = a.cmp(&b);
        if ka < kb && text_cmp == std::cmp::Ordering::Greater {
            return Err(format!("key says {a:?} < {b:?}, text disagrees (p={p})"));
        }
        if ka > kb && text_cmp == std::cmp::Ordering::Less {
            return Err(format!("key says {a:?} > {b:?}, text disagrees (p={p})"));
        }
        Ok(())
    });
}

/// Packed indexes always round-trip.
#[test]
fn prop_index_roundtrip() {
    property("pack/unpack", 500, |rng| {
        let seq = rng.below(1 << 40);
        let off = rng.below(1000) as usize;
        let (s2, o2) = unpack_index(samr::suffix::encode::pack_index(seq, off));
        (s2 == seq && o2 == off)
            .then_some(())
            .ok_or_else(|| format!("{seq}/{off} -> {s2}/{o2}"))
    });
}

/// The native bucket function agrees with the RangePartitioner on
/// byte-encoded keys for ANY boundaries.
#[test]
fn prop_bucket_consistency() {
    property("bucket == partitioner", 200, |rng| {
        let bounds = gen::boundaries(rng, 16, 13);
        let bound_bytes: Vec<Vec<u8>> =
            bounds.iter().map(|&b| encode_i64_key(b).to_vec()).collect();
        let part = RangePartitioner::new(bound_bytes);
        for _ in 0..50 {
            let k = rng.below(5u64.pow(13)) as i64;
            let a = native::bucket(k, &bounds);
            let b = part.partition(&encode_i64_key(k));
            if a != b {
                return Err(format!("key {k}: bucket {a} != partitioner {b}"));
            }
        }
        Ok(())
    });
}

/// MapReduce with identity tasks is a permutation-preserving sorter for
/// any conf (buffers, factors, reducer counts).
#[test]
fn prop_mr_sorts_any_conf() {
    property("MR identity sort", 12, |rng| {
        let n_reducers = 1 + rng.below(5) as usize;
        let conf = JobConf {
            n_reducers,
            io_sort_bytes: 1 << (9 + rng.below(6)),
            split_bytes: 1 << (9 + rng.below(6)),
            reducer_heap_bytes: 1 << (12 + rng.below(6)),
            io_sort_factor: 2 + rng.below(9) as usize,
            ..JobConf::default()
        };
        let records: Vec<Record> = (0..500 + rng.below(1500))
            .map(|_| Record::new(rng.next_u64().to_be_bytes().to_vec(), vec![0u8; 8]))
            .collect();
        let samples: Vec<Vec<u8>> = records.iter().take(300).map(|r| r.key.clone()).collect();
        let part = Arc::new(RangePartitioner::from_samples(samples, n_reducers));
        let job = Job {
            name: "prop-sort".into(),
            conf: conf.clone(),
            map_factory: Arc::new(|_| {
                Box::new(|rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone()))
            }),
            reduce_factory: Arc::new(|_| {
                Box::new(|key: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                    for v in vals {
                        out(Record::new(key.to_vec(), v));
                    }
                })
            }),
            partitioner: part.as_fn(),
        };
        let ledger = Ledger::new();
        let spool = ScratchDir::new(None, "prop-sort-in").map_err(|e| e.to_string())?;
        let splits = spool_records(spool.path.join("input"), &records, conf.split_bytes)
            .map_err(|e| e.to_string())?;
        let res = run_job(&job, splits, &ledger).map_err(|e| e.to_string())?;
        let mut got: Vec<Vec<u8>> = Vec::new();
        res.for_each_output(|r| {
            got.push(r.key);
            Ok(())
        })
        .map_err(|e| e.to_string())?;
        let mut want: Vec<Vec<u8>> = records.iter().map(|r| r.key.clone()).collect();
        want.sort();
        (got == want).then_some(()).ok_or_else(|| {
            format!("sorted output mismatch ({} records, conf {conf:?})", want.len())
        })
    });
}

/// Both pipelines produce the reference order on arbitrary corpora —
/// including duplicates, single-char reads, and tiny thresholds that
/// force many flushes.
#[test]
fn prop_pipelines_match_reference() {
    property("pipelines == reference", 8, |rng| {
        let reads = gen::corpus(rng, 40, 24);
        let conf = JobConf {
            n_reducers: 1 + rng.below(4) as usize,
            io_sort_bytes: 4 << 10,
            split_bytes: 4 << 10,
            reducer_heap_bytes: 32 << 10,
            ..JobConf::default()
        };
        let want = reference_order(&reads);

        let ledger = Ledger::new();
        let tera = terasort::run(
            &reads,
            &TeraSortConfig { conf: conf.clone(), samples_per_reducer: 100, seed: rng.next_u64() },
            &ledger,
        )
        .map_err(|e| e.to_string())?;
        if tera.order != want {
            return Err(format!("terasort differs on {} reads", reads.len()));
        }

        let store = SharedStore::new(1 + rng.below(5) as usize);
        let s = store.clone();
        let cfg = SchemeConfig {
            conf,
            group_threshold: 1 + rng.below(2000) as usize,
            write_suffixes: rng.f64() < 0.5,
            samples_per_reducer: 100,
            prefix_len: if rng.f64() < 0.5 { 13 } else { 23 },
            seed: rng.next_u64(),
            prefetch: rng.f64() < 0.5,
            ..Default::default()
        };
        let ledger = Ledger::new();
        let res = scheme::run(
            &reads,
            &cfg,
            Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
            &ledger,
        )
        .map_err(|e| e.to_string())?;
        if res.order != want {
            return Err(format!(
                "scheme differs on {} reads (threshold {}, p {}, write {})",
                reads.len(),
                cfg.group_threshold,
                cfg.prefix_len,
                cfg.write_suffixes
            ));
        }
        validate_order(&reads, &res.order).map_err(|e| e)?;
        Ok(())
    });
}

/// Pair-end equivalence (paper Case 6): the distributed TWO-input-file
/// construction must produce exactly the order of a single-process SA-IS
/// reference over the concatenated corpus — across shard counts {1, 3}
/// and both shuffle implementations (`fixed_shuffle` on/off).
#[test]
fn pair_end_two_files_match_sais_reference() {
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: 60,
        read_len: 24,
        len_jitter: 2,
        genome_len: 2048, // repetitive: plenty of cross-file tie groups
        seed: 0xCA5E6,
        ..Default::default()
    });
    let mut all = fwd.clone();
    all.extend(rev.clone());
    // independent oracle: SA-IS over the concatenation, not the pipeline
    let want = sais_reference_order(&all);
    assert_eq!(want, reference_order(&all), "oracles disagree");

    for n_shards in [1usize, 3] {
        for fixed_shuffle in [true, false] {
            let store = SharedStore::new(n_shards);
            let s = store.clone();
            let cfg = SchemeConfig {
                conf: JobConf {
                    n_reducers: 3,
                    io_sort_bytes: 4 << 10,
                    split_bytes: 4 << 10,
                    reducer_heap_bytes: 48 << 10,
                    ..JobConf::default()
                },
                group_threshold: 700,
                samples_per_reducer: 200,
                fixed_shuffle,
                ..Default::default()
            };
            let ledger = Ledger::new();
            let res = scheme::run_files(
                &[&fwd, &rev],
                &cfg,
                Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
                &ledger,
            )
            .expect("two-file scheme run");
            assert_eq!(
                res.order, want,
                "two-file order != SA-IS reference (shards {n_shards}, fixed {fixed_shuffle})"
            );
            validate_order(&all, &res.order).expect("invalid two-file order");
        }
    }
}

/// The KV store returns exactly the suffix bytes for any (read, offset).
#[test]
fn prop_kvstore_suffix_exactness() {
    property("kv suffix exactness", 40, |rng| {
        let reads = gen::corpus(rng, 30, 50);
        let mut st = InProcStore::new(1 + rng.below(6) as usize);
        st.put_reads(&reads).map_err(|e| e.to_string())?;
        for _ in 0..20 {
            let r = &reads[rng.below(reads.len() as u64) as usize];
            let off = rng.below(r.suffix_count() as u64) as usize;
            let idx = samr::suffix::encode::pack_index(r.seq, off);
            let (got, _) = st.fetch_suffixes(&[idx]).map_err(|e| e.to_string())?;
            if got[0] != r.codes[off..] {
                return Err(format!("seq {} off {off}", r.seq));
            }
        }
        Ok(())
    });
}

/// Footprint invariants that must hold for every scheme run: shuffle is
/// exactly 24 B per suffix; KV fetch ≥ suffix payload; map local I/O is
/// write-heavier than read (spill + merge).
#[test]
fn prop_scheme_footprint_invariants() {
    property("scheme footprint invariants", 6, |rng| {
        let reads = gen::corpus(rng, 60, 40);
        let n_suffixes: u64 = reads.iter().map(|r| r.suffix_count() as u64).sum();
        let store = SharedStore::new(4);
        let s = store.clone();
        let ledger = Ledger::new();
        scheme::run(
            &reads,
            &SchemeConfig {
                conf: JobConf {
                    n_reducers: 2,
                    io_sort_bytes: 4 << 10,
                    split_bytes: 4 << 10,
                    reducer_heap_bytes: 64 << 10,
                    ..JobConf::default()
                },
                group_threshold: 500,
                samples_per_reducer: 100,
                ..Default::default()
            },
            Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
            &ledger,
        )
        .map_err(|e| e.to_string())?;
        let fp = ledger.snapshot();
        if fp.get(Channel::Shuffle) != n_suffixes * 24 {
            return Err(format!(
                "shuffle {} != 24 × {n_suffixes}",
                fp.get(Channel::Shuffle)
            ));
        }
        let payload: u64 = reads
            .iter()
            .map(|r| (0..=r.len()).map(|o| (r.len() - o) as u64).sum::<u64>())
            .sum();
        if fp.get(Channel::KvFetch) < payload {
            return Err("KV fetch below suffix payload".into());
        }
        if fp.get(Channel::MapLocalWrite) < fp.get(Channel::MapLocalRead) {
            return Err("map side should be write-heavier".into());
        }
        Ok(())
    });
}

//! Measures first-call (compile) vs steady-state cost of each PJRT entry
//! point — documents the per-worker-thread engine warmup cost.
use std::time::Instant;

// Manual probe, not a correctness test: it exists to print PJRT warmup
// timings and needs compiled kernel artifacts plus ~seconds of
// per-thread compile time (the ROADMAP's "seed tests failing"). Run
// explicitly with `cargo test --test compile_probe -- --ignored`.
#[test]
#[ignore = "PJRT warmup timing probe: needs kernel artifacts; run with --ignored"]
fn engine_warmup_cost() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        return;
    }
    if !samr::runtime::init(Some(&dir)) {
        return; // built without the `pjrt` feature
    }
    samr::runtime::with_engine(|eng| {
        let eng = eng.expect("engine");
        let t0 = Instant::now();
        let mut k = vec![5i64, 3, 1];
        let mut ix = vec![1i64, 2, 3];
        eng.group_sort(&mut k, &mut ix).unwrap();
        println!("group_sort first call (compile+run): {:?}", t0.elapsed());
        let t1 = Instant::now();
        for _ in 0..10 {
            let mut k = vec![5i64, 3, 1];
            let mut ix = vec![1i64, 2, 3];
            eng.group_sort(&mut k, &mut ix).unwrap();
        }
        println!("steady state x10: {:?}", t1.elapsed());
        let t2 = Instant::now();
        let r = samr::suffix::reads::Read::from_ascii(0, b"ACGT");
        eng.map_encode_tile(&[&r], &[1, 2], 23).unwrap();
        println!("map_encode first call (compile+run): {:?}", t2.elapsed());
    });
}

//! Serving-tier equivalence: one query path from construction output to
//! concurrent RESP clients. The matrix seals the two-file pair-end
//! construction under shards {1,3} × prefetch {on,off} and asserts that
//! SEARCH/PAIRS/STAT answers over TCP are byte-identical to the
//! in-memory `IndexView` answers over the same corpus — then hammers one
//! server with N concurrent clients to prove the lock-free read path
//! holds up.

use std::path::PathBuf;
use std::sync::Arc;

use samr::footprint::Ledger;
use samr::kvstore::query::{QueryClient, QueryServer};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::encode::codes_of;
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec, Read};
use samr::suffix::sealed::SealedIndex;
use samr::suffix::search::{CorpusIndex, IndexView};
use samr::suffix::validate::{read_map, reference_order};

fn init_runtime() {
    let dir = runtime::default_artifacts_dir();
    let dir = if dir.is_relative() {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    };
    runtime::init(Some(&dir));
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("samr-serving-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(name)
}

fn corpus() -> (Vec<Read>, Vec<Read>) {
    synth_paired_corpus(&CorpusSpec {
        n_reads: 30,
        read_len: 20,
        len_jitter: 0,
        genome_len: 2048,
        seed: 0xCAFE,
        ..Default::default()
    })
}

/// Construct + seal the two-file pair-end corpus with `shards` in-proc
/// store shards and the given prefetch mode; return the opened artifact.
fn seal_with(shards: usize, prefetch: bool, name: &str) -> (Vec<Read>, SealedIndex) {
    let (fwd, rev) = corpus();
    let cfg = SchemeConfig {
        conf: JobConf {
            n_reducers: 3,
            io_sort_bytes: 16 << 10,
            split_bytes: 8 << 10,
            reducer_heap_bytes: 256 << 10,
            ..JobConf::default()
        },
        group_threshold: 500,
        samples_per_reducer: 100,
        prefetch,
        ..Default::default()
    };
    let store = SharedStore::new(shards);
    let factory: scheme::StoreFactory =
        Arc::new(move || Box::new(store.clone()) as Box<dyn SuffixStore>);
    let ledger = Ledger::new();
    let path = tmp(name);
    scheme::run_files_sealed(&[&fwd, &rev], &cfg, factory, &ledger, &path).expect("sealed run");
    let idx = SealedIndex::open(&path).expect("open sealed");
    let mut all = fwd;
    all.extend(rev);
    (all, idx)
}

const PATTERNS: &[&[u8]] = &[b"A", b"T", b"ACGT", b"GG", b"CGTA", b"AAAAA", b"TTTT"];
const PAIR_SEEDS: &[(&[u8], &[u8], usize)] =
    &[(b"AC", b"GT", 500), (b"ACG", b"CGT", 200), (b"T", b"A", 1000)];

#[test]
fn sealed_answers_match_in_memory_across_the_matrix() {
    init_runtime();
    for &shards in &[1usize, 3] {
        for &prefetch in &[false, true] {
            let name = format!("matrix-s{shards}-p{prefetch}.samr");
            let (reads, idx) = seal_with(shards, prefetch, &name);
            let tag = format!("shards={shards} prefetch={prefetch}");

            // the sealed SA is the reference order, entry for entry
            let order = reference_order(&reads);
            assert_eq!(idx.stats().n_suffixes as usize, order.len(), "{tag}: SA length");
            for (rank, &want) in order.iter().enumerate() {
                assert_eq!(idx.index_at(rank), want, "{tag}: SA rank {rank}");
            }

            // every query answers identically on both views
            let map = read_map(&reads);
            let mem = CorpusIndex::new(&order, &map);
            for &p in PATTERNS {
                let codes = codes_of(p);
                assert_eq!(mem.find(&codes), idx.find(&codes), "{tag}: SEARCH {p:?}");
            }
            for &(f, r, max_insert) in PAIR_SEEDS {
                assert_eq!(
                    mem.find_pairs(&codes_of(f), &codes_of(r), max_insert),
                    idx.find_pairs(&codes_of(f), &codes_of(r), max_insert),
                    "{tag}: PAIRS {f:?}/{r:?}"
                );
            }

            // ... and over TCP, byte-identical to the in-memory answers
            let mut server = QueryServer::start(0, Arc::new(idx)).expect("query server");
            let mut c = QueryClient::connect(server.addr()).expect("connect");
            c.ping().expect("ping");
            for &p in PATTERNS {
                assert_eq!(c.search(p).expect("SEARCH"), mem.find(&codes_of(p)), "{tag}: TCP SEARCH {p:?}");
            }
            for &(f, r, max_insert) in PAIR_SEEDS {
                assert_eq!(
                    c.pairs(f, r, max_insert).expect("PAIRS"),
                    mem.find_pairs(&codes_of(f), &codes_of(r), max_insert),
                    "{tag}: TCP PAIRS {f:?}/{r:?}"
                );
            }
            let st = c.stat().expect("STAT");
            let local = server.index().stats();
            assert_eq!(st.n_suffixes, local.n_suffixes, "{tag}: STAT suffixes");
            assert_eq!(st.n_reads, local.n_reads, "{tag}: STAT reads");
            assert_eq!(st.n_files, 2, "{tag}: STAT files");
            assert_eq!(st.corpus_bytes, local.corpus_bytes, "{tag}: STAT corpus");
            assert_eq!(st.file_bytes, local.file_bytes, "{tag}: STAT artifact bytes");
            assert!(st.file_bytes > local.corpus_bytes, "{tag}: artifact wraps the corpus");
            assert!(
                st.has_lcp && st.has_tree && st.has_bwt,
                "{tag}: default construction serves the v2 acceleration sections"
            );
            let (sent, recvd) = c.traffic();
            assert!(sent > 0 && recvd > 0, "{tag}: wire accounting");
            server.shutdown();
        }
    }
}

#[test]
fn malformed_queries_get_resp_errors_not_disconnects() {
    init_runtime();
    let (_, idx) = seal_with(2, false, "errors.samr");
    let mut server = QueryServer::start(0, Arc::new(idx)).expect("query server");
    let mut c = QueryClient::connect(server.addr()).expect("connect");
    // a bad pattern byte is a server-side error, not a dropped connection
    assert!(c.search(b"ACGN").is_err(), "N must be rejected, not masked");
    assert!(c.search(b"acxt").is_err(), "x is not a base");
    // the connection survives the error reply
    c.ping().expect("ping after error");
    assert!(c.search(b"ACGT").is_ok());
    server.shutdown();
}

#[test]
fn concurrent_clients_share_one_server() {
    init_runtime();
    let (reads, idx) = seal_with(3, true, "concurrent.samr");
    let order = reference_order(&reads);
    let map = read_map(&reads);
    let mem = CorpusIndex::new(&order, &map);
    let expected: Vec<Vec<(u64, usize)>> =
        PATTERNS.iter().map(|p| mem.find(&codes_of(p))).collect();

    let server = QueryServer::start(0, Arc::new(idx)).expect("query server");
    let addr = server.addr();
    let expected = Arc::new(expected);
    let workers: Vec<_> = (0..8)
        .map(|w| {
            let expected = expected.clone();
            std::thread::spawn(move || {
                let mut c = QueryClient::connect(addr).expect("connect");
                for round in 0..20 {
                    let i = (w + round) % PATTERNS.len();
                    let hits = c.search(PATTERNS[i]).expect("SEARCH");
                    assert_eq!(hits, expected[i], "worker {w} round {round}");
                }
                let st = c.stat().expect("STAT");
                assert!(st.n_suffixes > 0);
            })
        })
        .collect();
    for w in workers {
        w.join().expect("worker panicked");
    }
}

//! Shuffle-equivalence property tests: the fixed-width fast path
//! (packed 24 B records, LSD-radix-sorted spills, loser-tree merges,
//! strided readers) may only change CPU time — never bytes. Output
//! order, emitted records, and every footprint-ledger channel total
//! must be identical to the generic `Record` path, across spill
//! thresholds {tiny, default} and reducer counts {1, 3}.

use std::sync::Arc;

use samr::footprint::{Channel, Footprint, Ledger, CHANNELS};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::io::spool_records;
use samr::mapreduce::partitioner::RangePartitioner;
use samr::mapreduce::{run_job, Job, JobConf, Record, ScratchDir};
use samr::scheme::{self, SchemeConfig, StoreFactory};
use samr::suffix::reads::{synth_corpus, CorpusSpec, Read};
use samr::suffix::validate::validate_order;
use samr::util::rng::Rng;

/// (io_sort_bytes, label): tiny forces many spills + merge rounds,
/// default stays single-spill on the map side.
const SPILL_THRESHOLDS: [(u64, &str); 2] = [(3 << 10, "tiny"), (100 << 10, "default")];
const REDUCER_COUNTS: [usize; 2] = [1, 3];

fn scheme_once(
    reads: &[Read],
    fixed: bool,
    io_sort: u64,
    n_reducers: usize,
    sort_threads: usize,
) -> (Vec<i64>, Vec<Record>, Footprint) {
    let store = SharedStore::new(3);
    let s = store.clone();
    let factory: StoreFactory = Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>);
    let cfg = SchemeConfig {
        conf: JobConf {
            n_reducers,
            split_bytes: 4 << 10,
            io_sort_bytes: io_sort,
            reducer_heap_bytes: 48 << 10, // tight: reduce-side spills too
            io_sort_factor: 3,
            parallel_sort_threads: sort_threads,
            ..JobConf::default()
        },
        group_threshold: 600,
        samples_per_reducer: 200,
        fixed_shuffle: fixed,
        parallel_sort_threads: sort_threads,
        ..Default::default()
    };
    let ledger = Ledger::new();
    let res = scheme::run(reads, &cfg, factory, &ledger).expect("scheme run");
    let output: Vec<Record> = res
        .job
        .collect_output()
        .expect("collect output")
        .into_iter()
        .flatten()
        .collect();
    (res.order, output, ledger.snapshot())
}

#[test]
fn fixed_shuffle_matches_generic_across_spills_and_reducers() {
    let reads = synth_corpus(&CorpusSpec {
        n_reads: 90,
        read_len: 40,
        len_jitter: 5,
        genome_len: 2048, // repetitive: forces tie-break fetches
        seed: 2024,
        ..Default::default()
    });
    for &n_reducers in &REDUCER_COUNTS {
        for &(io_sort, label) in &SPILL_THRESHOLDS {
            let (order_g, out_g, fp_g) = scheme_once(&reads, false, io_sort, n_reducers, 1);
            let (order_f, out_f, fp_f) = scheme_once(&reads, true, io_sort, n_reducers, 1);
            assert_eq!(
                order_f, order_g,
                "suffix order must match ({label} spills, {n_reducers} reducers)"
            );
            assert_eq!(
                out_f, out_g,
                "emitted records must match ({label} spills, {n_reducers} reducers)"
            );
            for ch in CHANNELS {
                assert_eq!(
                    fp_f.get(ch),
                    fp_g.get(ch),
                    "{} bytes must match ({label} spills, {n_reducers} reducers)",
                    ch.name()
                );
            }
            validate_order(&reads, &order_f).expect("order invalid");
            // sanity: the workload actually exercised the shuffle disks
            assert!(fp_f.get(Channel::Shuffle) > 0);
            if label == "tiny" {
                assert!(
                    fp_f.get(Channel::MapLocalRead) > 0,
                    "tiny spill threshold must force map-side merge rounds"
                );
            }
        }
    }
}

#[test]
fn parallel_sort_threads_leave_order_output_and_ledger_identical() {
    // parallel_sort_threads {1, 4} × shuffle paths × spill thresholds:
    // the threads=1 run IS the literal sequential code, so equality here
    // proves the parallel in-node sorting changes nothing but CPU time —
    // including on the out-of-core (tiny-spill, multi-merge-round) path.
    let reads = synth_corpus(&CorpusSpec {
        n_reads: 90,
        read_len: 40,
        len_jitter: 5,
        genome_len: 2048,
        seed: 2025,
        ..Default::default()
    });
    for fixed in [false, true] {
        for &(io_sort, label) in &SPILL_THRESHOLDS {
            let (order_1, out_1, fp_1) = scheme_once(&reads, fixed, io_sort, 3, 1);
            let (order_4, out_4, fp_4) = scheme_once(&reads, fixed, io_sort, 3, 4);
            assert_eq!(
                order_4, order_1,
                "suffix order must match (fixed={fixed}, {label} spills)"
            );
            assert_eq!(out_4, out_1, "records must match (fixed={fixed}, {label} spills)");
            for ch in CHANNELS {
                assert_eq!(
                    fp_4.get(ch),
                    fp_1.get(ch),
                    "{} bytes must match (fixed={fixed}, {label} spills)",
                    ch.name()
                );
            }
            validate_order(&reads, &order_4).expect("order invalid");
            if label == "tiny" {
                assert!(
                    fp_4.get(Channel::MapLocalRead) > 0,
                    "tiny spill threshold must force the out-of-core merge path"
                );
            }
        }
    }
}

#[test]
fn fixed_width_engine_runs_generic_tasks_via_adapters() {
    // a plain sort job written against the generic Record API (closures,
    // no overrides) must run unchanged — and byte-identically — on the
    // fixed-width path, through the default map_fixed/reduce_fixed
    // adapters, because its records happen to be 8 B + 8 B.
    let mut rng = Rng::new(77);
    let input: Vec<Record> = (0..4000)
        .map(|_| {
            Record::new(
                rng.next_u64().to_be_bytes().to_vec(),
                rng.next_u64().to_be_bytes().to_vec(),
            )
        })
        .collect();
    let samples: Vec<Vec<u8>> = input.iter().take(1500).map(|r| r.key.clone()).collect();
    let part = Arc::new(RangePartitioner::from_samples(samples, 3));
    let mut results = Vec::new();
    for fixed in [false, true] {
        let job = Job {
            name: format!("adapter-sort-{fixed}"),
            conf: JobConf {
                n_reducers: 3,
                split_bytes: 8 << 10,
                io_sort_bytes: 4 << 10,
                reducer_heap_bytes: 16 << 10,
                io_sort_factor: 3,
                fixed_width: fixed,
                ..JobConf::default()
            },
            map_factory: Arc::new(|_| {
                Box::new(|rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone()))
            }),
            reduce_factory: Arc::new(|_| {
                Box::new(
                    |key: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                        for v in vals {
                            out(Record::new(key.to_vec(), v));
                        }
                    },
                )
            }),
            partitioner: part.as_fn(),
        };
        let ledger = Ledger::new();
        let spool = ScratchDir::new(None, "adapter-in").expect("scratch");
        let splits =
            spool_records(spool.path.join("input"), &input, job.conf.split_bytes).expect("spool");
        let res = run_job(&job, splits, &ledger).expect("job");
        results.push((res.collect_output().expect("collect"), ledger.snapshot()));
    }
    assert_eq!(results[0], results[1], "adapter path must be byte-identical");
    // and the sort is actually a sort
    let keys: Vec<&Vec<u8>> = results[0].0.iter().flatten().map(|r| &r.key).collect();
    assert!(keys.windows(2).all(|w| w[0] <= w[1]));
}

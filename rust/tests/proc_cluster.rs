//! True multi-process cluster mode, end to end: the driver spawns real
//! `samr worker` and `samr shard` OS processes (the binary under test,
//! via `CARGO_BIN_EXE_samr`), runs the scheme across them, and the
//! result must be byte-identical to a fault-free single-process run —
//! suffix order, output records, and every one of the nine footprint
//! channels. The chaos test then SIGKILLs a worker mid-map, aborts
//! another worker mid-reduce (after it journaled its result), and
//! aborts a shard process mid-job — and asserts the *same* equivalence,
//! with the dead attempts' bytes in `wasted`.
//!
//! Fault plans are seeded (`SAMR_FAULT_SEED`, CI pins it): sweep locally
//! with `for s in $(seq 0 31); do SAMR_FAULT_SEED=$s cargo test --test
//! proc_cluster; done`.

use std::path::PathBuf;
use std::sync::Arc;

use samr::cluster::driver::{run_cluster_files, ClusterOpts, ClusterRun};
use samr::faults::FaultPlan;
use samr::footprint::{Footprint, Ledger, CHANNELS};
use samr::kvstore::shard::{ShardedClient, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::mapreduce::JobConf;
use samr::scheme::{self, SchemeConfig, StoreFactory};
use samr::suffix::reads::{synth_corpus, CorpusSpec, Read};
use samr::suffix::validate::validate_order;

const N_SHARDS: usize = 2;

fn samr_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_samr"))
}

fn corpus(seed: u64) -> Vec<Read> {
    synth_corpus(&CorpusSpec {
        n_reads: 60,
        read_len: 30,
        genome_len: 2048, // repetitive: forces incomplete-group ties
        seed,
        ..Default::default()
    })
}

fn cluster_cfg(max_attempts: usize) -> SchemeConfig {
    let mut cfg = SchemeConfig {
        conf: JobConf {
            n_reducers: 3,
            split_bytes: 1 << 10, // several map tasks over this corpus
            io_sort_bytes: 8 << 10,
            reducer_heap_bytes: 64 << 10,
            ..JobConf::default()
        },
        group_threshold: 500,
        samples_per_reducer: 200,
        ..Default::default()
    };
    cfg.conf.max_task_attempts = max_attempts;
    cfg
}

/// Everything one run produces that equivalence is asserted over.
struct RunOut {
    order: Vec<i64>,
    fp: Footprint,
    out: Vec<(Vec<u8>, Vec<u8>)>,
    wasted: Footprint,
    kv_memory: u64,
    n_maps: usize,
    n_reduces: usize,
}

/// Fault-free single-process baseline: same scheme, same config, same
/// shard count — the KV servers are threads of this process and the
/// whole job runs in the in-process engine.
fn single_process_baseline(reads: &[Read], cfg: &SchemeConfig) -> RunOut {
    let kv = LocalKvCluster::start(N_SHARDS).expect("kv cluster");
    let addrs = kv.addrs();
    let factory: StoreFactory = Arc::new(move || {
        Box::new(ShardedClient::connect(&addrs).expect("connect")) as Box<dyn SuffixStore>
    });
    let ledger = Ledger::new();
    let res = scheme::run(reads, cfg, factory, &ledger).expect("baseline scheme run");
    let mut out = Vec::new();
    res.job
        .for_each_output(|r| {
            out.push((r.key, r.value));
            Ok(())
        })
        .expect("stream output");
    RunOut {
        order: res.order,
        fp: ledger.snapshot(),
        out,
        wasted: res.job.wasted,
        kv_memory: res.kv_memory,
        n_maps: res.job.map_stats.len(),
        n_reduces: res.job.reduce_stats.len(),
    }
}

fn cluster_out(res: &ClusterRun, ledger: &Ledger) -> RunOut {
    let mut out = Vec::new();
    res.job
        .for_each_output(|r| {
            out.push((r.key, r.value));
            Ok(())
        })
        .expect("stream cluster output");
    RunOut {
        order: res.order.clone(),
        fp: ledger.snapshot(),
        out,
        wasted: res.job.wasted,
        kv_memory: res.kv_memory,
        n_maps: res.job.map_stats.len(),
        n_reduces: res.job.reduce_stats.len(),
    }
}

fn assert_equivalent(cluster: &RunOut, base: &RunOut, reads: &[Read], label: &str) {
    validate_order(reads, &cluster.order).expect("cluster order invalid");
    assert_eq!(cluster.order, base.order, "suffix order ({label})");
    assert_eq!(cluster.out, base.out, "output records ({label})");
    for ch in CHANNELS {
        assert_eq!(
            cluster.fp.get(ch),
            base.fp.get(ch),
            "{} bytes ({label}): cross-process accounting must be \
             byte-identical to the single-process engine",
            ch.name()
        );
    }
}

#[test]
fn cluster_mode_matches_single_process_run() {
    let reads = corpus(41);
    let cfg = cluster_cfg(1);
    let base = single_process_baseline(&reads, &cfg);

    let opts = ClusterOpts {
        n_workers: 2,
        n_shards: N_SHARDS,
        samr_bin: samr_bin(),
        plan: None,
    };
    let ledger = Ledger::new();
    let res = run_cluster_files(&[&reads], &cfg, &opts, &ledger).expect("cluster run");
    let cluster = cluster_out(&res, &ledger);

    assert_equivalent(&cluster, &base, &reads, "fault-free cluster");
    assert_eq!(cluster.n_maps, base.n_maps, "split plans must be identical");
    assert_eq!(cluster.n_reduces, base.n_reduces);
    assert_eq!(
        cluster.wasted,
        Footprint::default(),
        "a clean cluster run abandons no attempts"
    );
    assert_eq!(
        cluster.kv_memory, base.kv_memory,
        "shard processes hold exactly what in-process servers hold"
    );
}

#[test]
fn chaos_process_kills_leave_output_and_footprint_byte_identical() {
    let reads = corpus(53);
    let seed = FaultPlan::env_seed(7);
    // baseline runs clean with single attempts
    let base = single_process_baseline(&reads, &cluster_cfg(1));

    // one worker SIGKILLed before a map dispatch, one worker aborted
    // after journaling its reduce result, one shard process aborted
    // early in the job — all seed-chosen against the real task counts,
    // so every kill point is reachable and fires
    let max_attempts = 2;
    let plan = Arc::new(FaultPlan::seeded_process(
        seed,
        base.n_maps,
        base.n_reduces,
        max_attempts,
        N_SHARDS,
    ));
    // three workers: two die to the plan, the survivor finishes the job
    let opts = ClusterOpts {
        n_workers: 3,
        n_shards: N_SHARDS,
        samr_bin: samr_bin(),
        plan: Some(plan.clone()),
    };
    let ledger = Ledger::new();
    let res = run_cluster_files(&[&reads], &cluster_cfg(max_attempts), &opts, &ledger)
        .expect("cluster run survives process kills");
    let cluster = cluster_out(&res, &ledger);

    let label = format!("chaos seed={seed}");
    assert_equivalent(&cluster, &base, &reads, &label);
    assert!(
        plan.proc_kills() >= 3,
        "a map-phase worker kill, a reduce-phase worker kill, and a shard \
         kill must all fire ({label}; saw {})",
        plan.proc_kills()
    );
    assert_ne!(
        cluster.wasted,
        Footprint::default(),
        "dead attempts must tally their spent bytes as waste ({label})"
    );
    assert_eq!(
        cluster.kv_memory, base.kv_memory,
        "the respawned shard must replay to exactly the baseline store ({label})"
    );
}

//! Property tests for the overlapped, zero-copy fetch path: pipelining,
//! prefetching, and the flat `SuffixBatch` arenas may only change *when*
//! bytes move (and where they land), never *which* bytes — suffix order,
//! wire traffic, and ledger totals must be bit-identical to the blocking
//! `Vec`-of-`Vec`s path, across shard counts {1, 2, 5} and prefetch
//! {on, off}.

use std::sync::Arc;

use samr::footprint::{Channel, Footprint, Ledger};
use samr::kvstore::batch::SuffixBatch;
use samr::kvstore::shard::{SharedStore, ShardedClient, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::mapreduce::JobConf;
use samr::scheme::{self, SchemeConfig, StoreFactory};
use samr::suffix::encode::pack_index;
use samr::suffix::reads::{synth_corpus, CorpusSpec, Read};
use samr::suffix::validate::validate_order;
use samr::util::rng::Rng;

const SHARD_COUNTS: [usize; 3] = [1, 2, 5];

/// Mixed-length corpus plus a request list with shuffled positions,
/// repeats, and every-offset coverage for a few reads.
fn corpus_and_requests(seed: u64) -> (Vec<Read>, Vec<i64>) {
    let reads = synth_corpus(&CorpusSpec {
        n_reads: 120,
        read_len: 60,
        len_jitter: 9,
        genome_len: 1 << 12,
        seed,
        ..Default::default()
    });
    let mut reqs: Vec<i64> = Vec::new();
    for r in &reads {
        for off in 0..=r.len() {
            reqs.push(pack_index(r.seq, off));
        }
    }
    // shuffle (Fisher–Yates) and append some repeats
    let mut rng = Rng::new(seed ^ 0x5eed);
    for i in (1..reqs.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        reqs.swap(i, j);
    }
    let n = reqs.len();
    for _ in 0..n / 10 {
        let dup = reqs[rng.below(n as u64) as usize];
        reqs.push(dup);
    }
    (reads, reqs)
}

#[test]
fn pipelined_fetch_matches_sequential_over_tcp() {
    for &shards in &SHARD_COUNTS {
        let (reads, reqs) = corpus_and_requests(7 + shards as u64);
        let kv = LocalKvCluster::start(shards).expect("kv cluster");
        let mut loader = kv.client().expect("loader");
        loader.put_reads(&reads).expect("put");

        let mut seq_client = kv.client().expect("sequential client");
        let (seq_out, seq_traffic) =
            seq_client.fetch_suffixes_sequential(&reqs).expect("sequential fetch");

        let mut pipe_client = kv.client().expect("pipelined client");
        let (pipe_out, pipe_traffic) = pipe_client.fetch_suffixes(&reqs).expect("pipelined fetch");

        assert_eq!(pipe_out, seq_out, "texts must match at {shards} shards");
        // same per-shard grouping + same chunking = byte-identical wire
        // traffic; pipelining only moves flush timing
        assert_eq!(
            pipe_traffic, seq_traffic,
            "wire totals must match at {shards} shards"
        );
        assert!(pipe_traffic.sent > 0 && pipe_traffic.received > 0);
    }
}

#[test]
fn arena_fetch_matches_vec_fetch_over_tcp() {
    // the tentpole property: the zero-copy SuffixBatch path issues
    // byte-identical requests and receives byte-identical replies to the
    // old Vec-of-Vecs path — only the allocation pattern differs
    for &shards in &SHARD_COUNTS {
        let (reads, reqs) = corpus_and_requests(21 + shards as u64);
        let kv = LocalKvCluster::start(shards).expect("kv cluster");
        let mut loader = kv.client().expect("loader");
        loader.put_reads(&reads).expect("put");

        let mut vec_client = kv.client().expect("vec client");
        let (vec_out, vec_traffic) = vec_client.fetch_suffixes(&reqs).expect("vec fetch");

        let mut arena_client = kv.client().expect("arena client");
        let mut batch = SuffixBatch::new();
        // two rounds through one reused batch: reuse must not change
        // results (steady state is exactly this loop)
        for round in 0..2 {
            batch.clear();
            let arena_traffic = arena_client
                .fetch_suffixes_into(&reqs, &mut batch)
                .expect("arena fetch");
            assert_eq!(
                arena_traffic, vec_traffic,
                "wire totals must match at {shards} shards (round {round})"
            );
            assert_eq!(batch.len(), vec_out.len());
            for (i, v) in vec_out.iter().enumerate() {
                assert_eq!(
                    batch.get(i),
                    Some(&v[..]),
                    "text {i} must match at {shards} shards (round {round})"
                );
            }
        }
    }
}

#[test]
fn arena_fetch_matches_vec_fetch_inproc() {
    // same property through the modeled in-process backend
    for &shards in &SHARD_COUNTS {
        let (reads, reqs) = corpus_and_requests(33 + shards as u64);
        let mut store = SharedStore::new(shards);
        store.put_reads(&reads).expect("put");
        let (vec_out, vec_traffic) = store.fetch_suffixes(&reqs).expect("vec fetch");
        let mut batch = SuffixBatch::new();
        let arena_traffic = store.fetch_suffixes_into(&reqs, &mut batch).expect("arena fetch");
        assert_eq!(arena_traffic, vec_traffic, "modeled traffic at {shards} shards");
        assert_eq!(batch.len(), vec_out.len());
        for (i, v) in vec_out.iter().enumerate() {
            assert_eq!(batch.get(i), Some(&v[..]), "text {i} at {shards} shards");
        }
    }
}

#[test]
fn pipelined_put_matches_single_batch_puts() {
    for &shards in &SHARD_COUNTS {
        let (reads, reqs) = corpus_and_requests(40 + shards as u64);
        // pipelined path (put_reads uses windowed per-shard MSETs)
        let kv_a = LocalKvCluster::start(shards).expect("kv");
        let mut a = kv_a.client().expect("client");
        a.put_reads(&reads).expect("put");
        // tiny batches: different framing, same stored state
        let kv_b = LocalKvCluster::start(shards).expect("kv");
        let mut b = kv_b.client().expect("client");
        b.set_put_batch(17);
        b.put_reads(&reads).expect("put");

        let (out_a, _) = kv_a.client().unwrap().fetch_suffixes(&reqs).expect("fetch");
        let (out_b, _) = kv_b.client().unwrap().fetch_suffixes(&reqs).expect("fetch");
        assert_eq!(out_a, out_b, "stored state must not depend on put batching");
        assert_eq!(kv_a.used_memory(), kv_b.used_memory());
    }
}

fn run_scheme_once(
    reads: &[Read],
    shards: usize,
    prefetch: bool,
    write_suffixes: bool,
) -> (Vec<i64>, Footprint, Vec<Vec<u8>>) {
    let store = SharedStore::new(shards);
    let s = store.clone();
    let factory: StoreFactory = Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>);
    let cfg = SchemeConfig {
        conf: JobConf {
            n_reducers: 3,
            split_bytes: 4 << 10,
            io_sort_bytes: 8 << 10,
            reducer_heap_bytes: 64 << 10,
            ..JobConf::default()
        },
        group_threshold: 700, // several flushes per reducer -> real overlap
        samples_per_reducer: 200,
        write_suffixes,
        prefetch,
        ..Default::default()
    };
    let ledger = Ledger::new();
    let res = scheme::run(reads, &cfg, factory, &ledger).expect("scheme");
    let mut output: Vec<Vec<u8>> = Vec::new();
    res.job
        .for_each_output(|r| {
            output.push(r.key);
            Ok(())
        })
        .expect("stream output");
    (res.order, ledger.snapshot(), output)
}

#[test]
fn prefetching_reducer_is_equivalent_to_blocking() {
    for &shards in &SHARD_COUNTS {
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 80,
            read_len: 40,
            genome_len: 2048, // repetitive: forces tie-break fetches
            seed: 90 + shards as u64,
            ..Default::default()
        });
        for write_suffixes in [true, false] {
            let (order_b, fp_b, out_b) = run_scheme_once(&reads, shards, false, write_suffixes);
            let (order_p, fp_p, out_p) = run_scheme_once(&reads, shards, true, write_suffixes);
            assert_eq!(
                order_p, order_b,
                "suffix order must be byte-identical ({shards} shards, write={write_suffixes})"
            );
            assert_eq!(
                out_p, out_b,
                "emitted records must match ({shards} shards, write={write_suffixes})"
            );
            // ALL NINE ledger channels — the zero-copy arenas and the
            // prefetch overlap may not move a single accounted byte
            for ch in samr::footprint::CHANNELS {
                assert_eq!(
                    fp_p.get(ch),
                    fp_b.get(ch),
                    "{} bytes must match ({shards} shards, write={write_suffixes})",
                    ch.name()
                );
            }
            assert!(fp_p.get(Channel::KvFetch) > 0 && fp_p.get(Channel::KvPut) > 0);
            validate_order(&reads, &order_p).expect("order invalid");
        }
    }
}

#[test]
fn prefetching_reducer_equivalence_over_tcp() {
    // the same property through real sockets at 5 shards
    let reads = synth_corpus(&CorpusSpec {
        n_reads: 100,
        read_len: 50,
        genome_len: 2048,
        seed: 1234,
        ..Default::default()
    });
    let mut results: Vec<(Vec<i64>, u64)> = Vec::new();
    for prefetch in [false, true] {
        let kv = LocalKvCluster::start(5).expect("kv");
        let addrs = kv.addrs();
        let factory: StoreFactory = Arc::new(move || {
            Box::new(ShardedClient::connect(&addrs).expect("connect")) as Box<dyn SuffixStore>
        });
        let cfg = SchemeConfig {
            conf: JobConf {
                n_reducers: 2,
                split_bytes: 8 << 10,
                ..JobConf::scaled_down()
            },
            group_threshold: 900,
            samples_per_reducer: 200,
            prefetch,
            ..Default::default()
        };
        let ledger = Ledger::new();
        let res = scheme::run(&reads, &cfg, factory, &ledger).expect("scheme");
        validate_order(&reads, &res.order).expect("order invalid");
        results.push((res.order, ledger.get(Channel::KvFetch)));
    }
    assert_eq!(results[0].0, results[1].0, "TCP order must match");
    assert_eq!(results[0].1, results[1].1, "TCP KvFetch bytes must match");
}

//! Property tests for the overlapped fetch path: pipelining and
//! prefetching may only change *when* bytes move, never *which* bytes —
//! suffix order and ledger totals must be bit-identical to the blocking
//! sequential path, across shard counts {1, 2, 5}.

use std::sync::Arc;

use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{SharedStore, ShardedClient, SuffixStore};
use samr::kvstore::LocalKvCluster;
use samr::mapreduce::JobConf;
use samr::scheme::{self, SchemeConfig, StoreFactory};
use samr::suffix::encode::pack_index;
use samr::suffix::reads::{synth_corpus, CorpusSpec, Read};
use samr::suffix::validate::validate_order;
use samr::util::rng::Rng;

const SHARD_COUNTS: [usize; 3] = [1, 2, 5];

/// Mixed-length corpus plus a request list with shuffled positions,
/// repeats, and every-offset coverage for a few reads.
fn corpus_and_requests(seed: u64) -> (Vec<Read>, Vec<i64>) {
    let reads = synth_corpus(&CorpusSpec {
        n_reads: 120,
        read_len: 60,
        len_jitter: 9,
        genome_len: 1 << 12,
        seed,
        ..Default::default()
    });
    let mut reqs: Vec<i64> = Vec::new();
    for r in &reads {
        for off in 0..=r.len() {
            reqs.push(pack_index(r.seq, off));
        }
    }
    // shuffle (Fisher–Yates) and append some repeats
    let mut rng = Rng::new(seed ^ 0x5eed);
    for i in (1..reqs.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        reqs.swap(i, j);
    }
    let n = reqs.len();
    for _ in 0..n / 10 {
        let dup = reqs[rng.below(n as u64) as usize];
        reqs.push(dup);
    }
    (reads, reqs)
}

#[test]
fn pipelined_fetch_matches_sequential_over_tcp() {
    for &shards in &SHARD_COUNTS {
        let (reads, reqs) = corpus_and_requests(7 + shards as u64);
        let kv = LocalKvCluster::start(shards).expect("kv cluster");
        let mut loader = kv.client().expect("loader");
        loader.put_reads(&reads).expect("put");

        let mut seq_client = kv.client().expect("sequential client");
        let (seq_out, seq_traffic) =
            seq_client.fetch_suffixes_sequential(&reqs).expect("sequential fetch");

        let mut pipe_client = kv.client().expect("pipelined client");
        let (pipe_out, pipe_traffic) = pipe_client.fetch_suffixes(&reqs).expect("pipelined fetch");

        assert_eq!(pipe_out, seq_out, "texts must match at {shards} shards");
        // same per-shard grouping + same chunking = byte-identical wire
        // traffic; pipelining only moves flush timing
        assert_eq!(
            pipe_traffic, seq_traffic,
            "wire totals must match at {shards} shards"
        );
        assert!(pipe_traffic.sent > 0 && pipe_traffic.received > 0);
    }
}

#[test]
fn pipelined_put_matches_single_batch_puts() {
    for &shards in &SHARD_COUNTS {
        let (reads, reqs) = corpus_and_requests(40 + shards as u64);
        // pipelined path (put_reads uses windowed per-shard MSETs)
        let kv_a = LocalKvCluster::start(shards).expect("kv");
        let mut a = kv_a.client().expect("client");
        a.put_reads(&reads).expect("put");
        // tiny batches: different framing, same stored state
        let kv_b = LocalKvCluster::start(shards).expect("kv");
        let mut b = kv_b.client().expect("client");
        b.set_put_batch(17);
        b.put_reads(&reads).expect("put");

        let (out_a, _) = kv_a.client().unwrap().fetch_suffixes(&reqs).expect("fetch");
        let (out_b, _) = kv_b.client().unwrap().fetch_suffixes(&reqs).expect("fetch");
        assert_eq!(out_a, out_b, "stored state must not depend on put batching");
        assert_eq!(kv_a.used_memory(), kv_b.used_memory());
    }
}

fn run_scheme_once(
    reads: &[Read],
    shards: usize,
    prefetch: bool,
    write_suffixes: bool,
) -> (Vec<i64>, u64, u64, Vec<Vec<u8>>) {
    let store = SharedStore::new(shards);
    let s = store.clone();
    let factory: StoreFactory = Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>);
    let cfg = SchemeConfig {
        conf: JobConf {
            n_reducers: 3,
            split_bytes: 4 << 10,
            io_sort_bytes: 8 << 10,
            reducer_heap_bytes: 64 << 10,
            ..JobConf::default()
        },
        group_threshold: 700, // several flushes per reducer -> real overlap
        samples_per_reducer: 200,
        write_suffixes,
        prefetch,
        ..Default::default()
    };
    let ledger = Ledger::new();
    let res = scheme::run(reads, &cfg, factory, &ledger).expect("scheme");
    let mut output: Vec<Vec<u8>> = Vec::new();
    res.job
        .for_each_output(|r| {
            output.push(r.key);
            Ok(())
        })
        .expect("stream output");
    (
        res.order,
        ledger.get(Channel::KvFetch),
        ledger.get(Channel::KvPut),
        output,
    )
}

#[test]
fn prefetching_reducer_is_equivalent_to_blocking() {
    for &shards in &SHARD_COUNTS {
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 80,
            read_len: 40,
            genome_len: 2048, // repetitive: forces tie-break fetches
            seed: 90 + shards as u64,
            ..Default::default()
        });
        for write_suffixes in [true, false] {
            let (order_b, fetch_b, put_b, out_b) =
                run_scheme_once(&reads, shards, false, write_suffixes);
            let (order_p, fetch_p, put_p, out_p) =
                run_scheme_once(&reads, shards, true, write_suffixes);
            assert_eq!(
                order_p, order_b,
                "suffix order must be byte-identical ({shards} shards, write={write_suffixes})"
            );
            assert_eq!(
                out_p, out_b,
                "emitted records must match ({shards} shards, write={write_suffixes})"
            );
            assert_eq!(
                fetch_p, fetch_b,
                "KvFetch ledger bytes must match ({shards} shards, write={write_suffixes})"
            );
            assert_eq!(
                put_p, put_b,
                "KvPut ledger bytes must match ({shards} shards, write={write_suffixes})"
            );
            validate_order(&reads, &order_p).expect("order invalid");
        }
    }
}

#[test]
fn prefetching_reducer_equivalence_over_tcp() {
    // the same property through real sockets at 5 shards
    let reads = synth_corpus(&CorpusSpec {
        n_reads: 100,
        read_len: 50,
        genome_len: 2048,
        seed: 1234,
        ..Default::default()
    });
    let mut results: Vec<(Vec<i64>, u64)> = Vec::new();
    for prefetch in [false, true] {
        let kv = LocalKvCluster::start(5).expect("kv");
        let addrs = kv.addrs();
        let factory: StoreFactory = Arc::new(move || {
            Box::new(ShardedClient::connect(&addrs).expect("connect")) as Box<dyn SuffixStore>
        });
        let cfg = SchemeConfig {
            conf: JobConf {
                n_reducers: 2,
                split_bytes: 8 << 10,
                ..JobConf::scaled_down()
            },
            group_threshold: 900,
            samples_per_reducer: 200,
            prefetch,
            ..Default::default()
        };
        let ledger = Ledger::new();
        let res = scheme::run(&reads, &cfg, factory, &ledger).expect("scheme");
        validate_order(&reads, &res.order).expect("order invalid");
        results.push((res.order, ledger.get(Channel::KvFetch)));
    }
    assert_eq!(results[0].0, results[1].0, "TCP order must match");
    assert_eq!(results[0].1, results[1].1, "TCP KvFetch bytes must match");
}

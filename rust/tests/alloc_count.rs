//! Counting-allocator proof of the zero-copy fetch path's allocation
//! contract: a steady-state `fetch_suffixes_into` loop performs O(1)
//! heap allocations per batch — a bounded constant, NOT O(suffixes) —
//! while the old `Vec`-of-`Vec`s path allocates at least one `Vec` per
//! suffix. This lives in its own integration-test binary because a
//! `#[global_allocator]` is process-wide; the single `#[test]` keeps the
//! counting window free of concurrent test noise.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use samr::kvstore::batch::SuffixBatch;
use samr::kvstore::shard::{InProcStore, SuffixStore};
use samr::suffix::encode::pack_index;
use samr::suffix::reads::Read;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Count allocations during `f`, on this thread only by construction
/// (nothing else runs in this test binary while counting).
fn count_allocs(f: impl FnOnce()) -> u64 {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    f();
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_arena_fetch_allocates_o1_per_batch() {
    // a corpus big enough that O(suffixes) allocations are unmistakable
    let reads: Vec<Read> = (0..500u64)
        .map(|i| Read::new(i, vec![(i % 4 + 1) as u8; 40]))
        .collect();
    let n_suffixes: usize = reads.iter().map(|r| r.suffix_count()).sum();
    assert!(n_suffixes > 20_000);
    let reqs: Vec<i64> = reads
        .iter()
        .flat_map(|r| (0..=r.len()).map(|o| pack_index(r.seq, o)))
        .collect();

    let mut store = InProcStore::new(4);
    store.put_reads(&reads).expect("put");

    // warm up: first calls size the plan scratch, the arena, and the
    // spans table; steady state reuses all of them
    let mut batch = SuffixBatch::new();
    for _ in 0..3 {
        batch.clear();
        store.fetch_suffixes_into(&reqs, &mut batch).expect("warmup fetch");
    }

    const BATCHES: u64 = 5;
    let arena_allocs = count_allocs(|| {
        for _ in 0..BATCHES {
            batch.clear();
            store.fetch_suffixes_into(&reqs, &mut batch).expect("steady-state fetch");
        }
    });
    assert_eq!(batch.len(), reqs.len());

    // the old path: one Vec per suffix (plus the outer Vec), every batch
    let vec_allocs = count_allocs(|| {
        let (out, _) = store.fetch_suffixes(&reqs).expect("vec fetch");
        assert_eq!(out.len(), reqs.len());
    });

    // O(1) per batch: a handful of allocations TOTAL across 5 batches of
    // 20k+ suffixes (ideally zero; the bound absorbs platform noise),
    // vs >= one per suffix on the Vec path.
    assert!(
        arena_allocs <= 8 * BATCHES,
        "arena path must not allocate per suffix: {arena_allocs} allocations \
         across {BATCHES} batches of {n_suffixes} suffixes"
    );
    assert!(
        vec_allocs >= n_suffixes as u64,
        "sanity: the counting allocator must see the Vec path's per-suffix \
         allocations ({vec_allocs} < {n_suffixes})"
    );
}

//! Deterministic-equivalence oracle for the parallel in-node sorting
//! paths: the parallel stable LSD radix sort and the range-partitioned
//! parallel merges must produce output byte-identical to their
//! sequential counterparts on every input — including adversarial ones
//! (all-equal keys, already-sorted, reverse-sorted, below the engage
//! threshold, empty runs, duplicate-heavy) — at every thread count, and
//! a whole job run repeatedly with threads=8 must be bit-identical
//! across runs (catching scheduling-order nondeterminism that a single
//! comparison would miss).

use std::sync::Arc;

use samr::footprint::{Footprint, Ledger, CHANNELS};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::merge::{
    kway_merge_fixed, kway_merge_pairs, kway_merge_pairs_threads, merge_fixed_segments_threads,
    FixedRun,
};
use samr::mapreduce::record::FixedRec;
use samr::mapreduce::JobConf;
use samr::scheme::{self, SchemeConfig, StoreFactory};
use samr::suffix::reads::{synth_corpus, CorpusSpec};
use samr::util::radix::{sort_pairs, sort_pairs_threads, sort_spill, sort_spill_threads};
use samr::util::rng::Rng;

/// Matches `util::radix::PAR_MIN_PER_CHUNK` / the merges'
/// `PAR_MERGE_MIN_PER_PART`: inputs must exceed 2× this for the
/// parallel code to actually engage (below it the call intentionally
/// degrades to the sequential path — also covered here).
const ENGAGE: usize = 1 << 13;

const THREADS: [usize; 3] = [1, 2, 8];

// ---------------- radix: spill buffers ----------------

/// Adversarial spill buffers; every record's `value` tags its input
/// position, so stability (equal (partition, key) keep input order) is
/// byte-checkable through the plain equality assertion.
fn spill_cases() -> Vec<(&'static str, Vec<FixedRec>)> {
    let big = 3 * ENGAGE + 41; // engages the parallel scatter
    let mut rng = Rng::new(2026);
    let mk = |n: usize, mut f: Box<dyn FnMut(usize) -> (u32, u64)>| -> Vec<FixedRec> {
        (0..n)
            .map(|i| {
                let (partition, key) = f(i);
                FixedRec { partition, key, value: i as u64 }
            })
            .collect()
    };
    let mut random_key = {
        let mut r = Rng::new(7);
        move |_: usize| (0u32, r.next_u64())
    };
    vec![
        ("all-equal", mk(big, Box::new(|_| (3, 42)))),
        ("already-sorted", mk(big, Box::new(|i| (0, i as u64)))),
        ("reverse-sorted", mk(big, Box::new(move |i| (0, (big - i) as u64)))),
        ("single-chunk", mk(ENGAGE / 2, Box::new(move |_| (rng.below(4) as u32, rng.below(100))))),
        ("duplicate-heavy", {
            let mut r = Rng::new(5);
            mk(big, Box::new(move |_| (r.below(3) as u32, r.below(17))))
        }),
        ("random-wide", mk(big, Box::new(move |i| random_key(i)))),
    ]
}

#[test]
fn parallel_spill_sort_is_byte_identical_and_stable() {
    for (name, base) in spill_cases() {
        let mut scratch = Vec::new();
        let mut want = base.clone();
        sort_spill(&mut want, &mut scratch);
        // stability oracle on the sequential output itself
        for w in want.windows(2) {
            if (w[0].partition, w[0].key) == (w[1].partition, w[1].key) {
                assert!(w[0].value < w[1].value, "{name}: sequential sort unstable");
            }
        }
        for threads in THREADS {
            let mut got = base.clone();
            sort_spill_threads(&mut got, &mut scratch, threads);
            assert_eq!(got, want, "{name}: threads={threads} diverged from sequential");
        }
    }
}

// ---------------- radix: (key, index) pair sort ----------------

#[test]
fn parallel_pair_sort_is_byte_identical() {
    let n = 2 * ENGAGE + 9;
    let cases: Vec<(&str, Vec<i64>)> = vec![
        ("all-equal", vec![5i64; n]),
        ("already-sorted", (0..n as i64).collect()),
        ("reverse-sorted", (0..n as i64).rev().collect()),
        ("duplicate-heavy", {
            let mut r = Rng::new(31);
            (0..n).map(|_| r.below(23) as i64 - 11).collect()
        }),
        ("negative-heavy", {
            let mut r = Rng::new(32);
            (0..n).map(|_| r.next_u64() as i64).collect()
        }),
    ];
    let mut rng = Rng::new(33);
    let idxs0: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
    for (name, keys0) in cases {
        let (mut k_seq, mut i_seq) = (keys0.clone(), idxs0.clone());
        sort_pairs(&mut k_seq, &mut i_seq);
        for threads in THREADS {
            let (mut k, mut i) = (keys0.clone(), idxs0.clone());
            sort_pairs_threads(&mut k, &mut i, threads);
            assert_eq!(k, k_seq, "{name}: keys diverged at threads={threads}");
            assert_eq!(i, i_seq, "{name}: indexes diverged at threads={threads}");
        }
    }
}

// ---------------- merges ----------------

/// Sorted (keys, indexes) runs with globally unique indexes (the
/// scheme's regime) plus adversarial shapes: empty runs interleaved,
/// all-equal keys, one giant run among dwarfs.
fn pair_run_cases() -> Vec<(&'static str, Vec<(Vec<i64>, Vec<i64>)>)> {
    let mut next_index = 0i64;
    let mut run = |n: usize, key_space: u64, seed: u64| -> (Vec<i64>, Vec<i64>) {
        let mut r = Rng::new(seed);
        let mut pairs: Vec<(i64, i64)> = (0..n)
            .map(|_| {
                next_index += 1;
                (r.below(key_space.max(1)) as i64, next_index)
            })
            .collect();
        pairs.sort_unstable();
        (pairs.iter().map(|p| p.0).collect(), pairs.iter().map(|p| p.1).collect())
    };
    vec![
        ("empty-runs-mixed", vec![
            (Vec::new(), Vec::new()),
            run(3 * ENGAGE, 50, 1),
            (Vec::new(), Vec::new()),
            run(2 * ENGAGE, 50, 2),
        ]),
        ("all-equal-keys", vec![run(2 * ENGAGE, 1, 3), run(2 * ENGAGE, 1, 4)]),
        ("one-giant-run", vec![run(64, 9, 5), run(5 * ENGAGE, 9, 6), run(64, 9, 7)]),
        ("duplicate-heavy", (0..6).map(|s| run(ENGAGE, 13, 10 + s)).collect()),
        ("below-threshold", vec![run(100, 7, 20), run(100, 7, 21)]),
        ("single-run", vec![run(2 * ENGAGE, 40, 22)]),
        ("no-runs", Vec::new()),
    ]
}

#[test]
fn parallel_pair_merge_is_byte_identical() {
    for (name, runs) in pair_run_cases() {
        let mut want = Vec::new();
        kway_merge_pairs(&runs, |k, v| want.push((k, v)));
        for threads in THREADS {
            let mut got = Vec::new();
            kway_merge_pairs_threads(&runs, threads, |k, v| got.push((k, v)));
            assert_eq!(got, want, "{name}: threads={threads} diverged from sequential");
        }
    }
}

#[test]
fn parallel_fixed_segment_merge_is_byte_identical_and_tie_stable() {
    // segments sorted by key only; values tag (segment, position) so the
    // (key, segment-index) tie-break is byte-checkable
    let seg = |n: usize, key_space: u64, tag: u64, seed: u64| -> Vec<(u64, u64)> {
        let mut r = Rng::new(seed);
        let mut s: Vec<(u64, u64)> =
            (0..n).map(|i| (r.below(key_space.max(1)), tag * 1_000_000 + i as u64)).collect();
        s.sort_by_key(|p| p.0); // stable: positions survive within a key
        s
    };
    let cases: Vec<(&'static str, Vec<Vec<(u64, u64)>>)> = vec![
        ("all-equal-keys", (0..4).map(|t| seg(ENGAGE, 1, t, 40 + t)).collect()),
        ("duplicate-heavy", (0..5).map(|t| seg(ENGAGE, 11, t, 50 + t)).collect()),
        (
            "empty-segments-mixed",
            vec![Vec::new(), seg(3 * ENGAGE, 100, 1, 60), Vec::new(), seg(ENGAGE, 100, 2, 61)],
        ),
        ("below-threshold", vec![seg(50, 5, 1, 70), seg(50, 5, 2, 71)]),
    ];
    for (name, segments) in cases {
        let mut want = Vec::new();
        kway_merge_fixed(
            segments.iter().cloned().map(FixedRun::from_vec).collect(),
            |k, v| {
                want.push((k, v));
                Ok(())
            },
        )
        .unwrap();
        for threads in THREADS {
            let mut got = Vec::new();
            merge_fixed_segments_threads(segments.clone(), threads, |k, v| {
                got.push((k, v));
                Ok(())
            })
            .unwrap();
            assert_eq!(got, want, "{name}: threads={threads} diverged from sequential");
        }
    }
}

// ---------------- whole-job repeated-run determinism ----------------

/// One scheme run; returns the raw output-file bytes per reducer and the
/// full ledger snapshot. Knobs sized so the spill radix sort and the
/// sorting-group pair sort both cross the parallel engage threshold.
fn scheme_run_raw(threads: usize) -> (Vec<Vec<u8>>, Footprint) {
    let reads = synth_corpus(&CorpusSpec {
        n_reads: 400,
        read_len: 60,
        len_jitter: 5,
        genome_len: 4096, // repetitive enough to force tie-break groups
        seed: 4242,
        ..Default::default()
    });
    let store = SharedStore::new(3);
    let s = store.clone();
    let factory: StoreFactory = Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>);
    let cfg = SchemeConfig {
        conf: JobConf {
            n_reducers: 2,
            split_bytes: 64 << 10,
            io_sort_bytes: 1 << 20, // one big spill: > 2^14 records, radix engages
            io_sort_factor: 3,
            parallel_sort_threads: threads,
            ..JobConf::default()
        },
        group_threshold: 30_000, // one big flush: pair sort engages
        samples_per_reducer: 200,
        parallel_sort_threads: threads,
        ..Default::default()
    };
    let ledger = Ledger::new();
    let res = scheme::run(&reads, &cfg, factory, &ledger).expect("scheme run");
    let raw: Vec<Vec<u8>> = res
        .job
        .output
        .iter()
        .map(|f| std::fs::read(&f.path).expect("read output file"))
        .collect();
    (raw, ledger.snapshot())
}

#[test]
fn repeated_parallel_runs_are_bit_identical_and_match_sequential() {
    let (raw_seq, fp_seq) = scheme_run_raw(1);
    let mut runs = Vec::new();
    for _ in 0..3 {
        runs.push(scheme_run_raw(8));
    }
    for (i, (raw, fp)) in runs.iter().enumerate() {
        assert_eq!(
            raw, &raw_seq,
            "run {i}: threads=8 output files differ from the sequential baseline"
        );
        for ch in CHANNELS {
            assert_eq!(
                fp.get(ch),
                fp_seq.get(ch),
                "run {i}: {} differs from the sequential baseline",
                ch.name()
            );
        }
    }
    // and the three parallel runs agree with each other bit-for-bit
    assert_eq!(runs[0].0, runs[1].0);
    assert_eq!(runs[1].0, runs[2].0);
}

//! Elapsed-time cost model: projects a (paper-scale) data store footprint
//! onto the Table-II cluster and produces μ/σ minutes plus breakdown
//! behaviour — the engine behind Figures 5/8 and the Time rows of
//! Tables III–VII.
//!
//! The premise is the paper's own (§III): "the extent of space required
//! can reflect the extent of time consumed" — each storage/network
//! channel's bytes divide by the cluster's aggregate bandwidth for that
//! resource; the slowest resource bounds each phase; GC pauses and
//! disk-capacity exhaustion perturb and break the linearity.

use crate::cluster::ClusterSpec;
use crate::footprint::{Channel, Footprint};
use crate::scheme::gc_model::{simulate_reducer_heap, HeapConfig, HeapOutcome};
use crate::util::rng::Rng;
use crate::util::stats::MuSigma;

/// Calibration constants (documented estimates for 2016-era hardware).
#[derive(Clone, Debug)]
pub struct CostParams {
    /// Comparison-sort throughput per vcore on suffix strings (bytes/s).
    pub sort_bps_per_core: f64,
    /// Speedup of sorting fixed-width numeric pairs vs suffix strings.
    pub numeric_sort_factor: f64,
    /// Effective per-reducer KV suffix-fetch throughput (paper §IV-D
    /// measures ~20 MB/s, latency-bound on 1 GbE).
    pub kv_fetch_bps_per_reducer: f64,
    /// Fraction of shuffle hidden under the map phase (Hadoop overlaps).
    pub shuffle_overlap: f64,
    /// Multiplicative per-trial noise σ (log-normal).
    pub noise: f64,
    /// Reducer temp+output disk multiplier (paper: ×2.89 incl. output).
    pub reducer_tmp_factor: f64,
    /// Fraction of a node's disk actually available to reducer temp
    /// files (the rest holds input shares, map outputs, DFS overhead).
    pub usable_disk_fraction: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        Self {
            sort_bps_per_core: 30e6,
            numeric_sort_factor: 6.0,
            kv_fetch_bps_per_reducer: 20e6,
            shuffle_overlap: 0.7,
            noise: 0.03,
            reducer_tmp_factor: 2.89,
            usable_disk_fraction: 0.8,
        }
    }
}

/// Job shape at paper scale.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadShape {
    pub n_reducers: u64,
    /// Bytes shuffled into one reducer.
    pub per_reducer_shuffle: u64,
    /// Largest sorting group (bytes) a reducer must hold.
    pub max_group_bytes: u64,
    /// Numeric (scheme) vs string (TeraSort) reduce pipeline.
    pub numeric_pipeline: bool,
    /// Reducers that can run concurrently per node (paper: 2).
    pub reduce_slots_per_node: u64,
}

/// μ/σ elapsed minutes over seeded trials, with breakdown bookkeeping.
#[derive(Clone, Debug)]
pub struct TimeEstimate {
    pub minutes: MuSigma,
    pub trials: usize,
    pub completed_trials: usize,
    /// Why trials failed, if any.
    pub breakdown: Option<String>,
}

impl TimeEstimate {
    pub fn completed(&self) -> bool {
        self.completed_trials == self.trials
    }
}

/// Estimate elapsed time for a job whose paper-scale footprint is `fp`.
pub fn estimate(
    cluster: &ClusterSpec,
    params: &CostParams,
    fp: &Footprint,
    shape: &WorkloadShape,
    heap: &HeapConfig,
    trials: usize,
    seed: u64,
) -> TimeEstimate {
    let cores = cluster.total_vcores() as f64;
    let agg_read = cluster.agg_disk_read();
    let agg_write = cluster.agg_disk_write();
    let agg_net = cluster.agg_net_bytes_per_sec();

    // ---- deterministic base time (seconds) ----
    let map_io = fp.get(Channel::HdfsRead) as f64 / agg_read
        + fp.get(Channel::MapLocalRead) as f64 / agg_read
        + fp.get(Channel::MapLocalWrite) as f64 / agg_write;
    // map CPU: producing + sorting the map output (≈ shuffled bytes)
    let sort_rate = params.sort_bps_per_core
        * if shape.numeric_pipeline { params.numeric_sort_factor } else { 1.0 };
    let map_cpu = fp.get(Channel::Shuffle) as f64 / (cores * sort_rate)
        + fp.get(Channel::KvPut) as f64 / agg_net;

    let shuffle_net =
        fp.get(Channel::Shuffle) as f64 / agg_net * (1.0 - params.shuffle_overlap);

    let reduce_io = fp.get(Channel::ReduceLocalRead) as f64 / agg_read
        + fp.get(Channel::ReduceLocalWrite) as f64 / agg_write
        + fp.get(Channel::HdfsWrite) as f64 / agg_write;
    // suffix fetches are latency-bound per reducer (paper: ~20 MB/s each)
    let kv_fetch = fp.get(Channel::KvFetch) as f64
        / (params.kv_fetch_bps_per_reducer * shape.n_reducers as f64).min(agg_net);
    let reduce_cpu_base =
        fp.get(Channel::Shuffle) as f64 / (cores * sort_rate);

    // ---- heap behaviour ----
    let heap_outcome =
        simulate_reducer_heap(heap, shape.per_reducer_shuffle, shape.max_group_bytes);
    let (gc_pause, heap_failure) = match heap_outcome {
        HeapOutcome::Ok { pause_fraction } => (pause_fraction, None),
        HeapOutcome::HeapSpace => (0.9, Some("Java heap space")),
        HeapOutcome::GcOverheadLimit => (0.9, Some("GC overhead limit exceeded")),
    };
    let reduce_cpu = reduce_cpu_base * (1.0 + gc_pause * 4.0);

    // ---- disk capacity (the Case-5 killer, §III) ----
    let per_node_need = shape.per_reducer_shuffle as f64
        * params.reducer_tmp_factor
        * shape.reduce_slots_per_node as f64;
    let disk_failure = if per_node_need
        > cluster.min_node_disk() as f64 * params.usable_disk_fraction
    {
        Some("insufficient local disk for reducer temp files")
    } else {
        None
    };

    let base_secs = map_io + map_cpu + shuffle_net + reduce_io + kv_fetch + reduce_cpu;

    // ---- trials ----
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let mut times = Vec::with_capacity(trials);
    let mut completed = 0usize;
    for _ in 0..trials {
        let noise = (params.noise * rng.normal()).exp();
        let mut t = base_secs * noise;
        let mut ok = true;
        if disk_failure.is_some() {
            // reducers rescheduled onto surviving nodes, temp files
            // re-created; most attempts fail outright (paper: 4 of 5)
            t *= 1.8 + rng.f64() * 1.4;
            ok = rng.f64() < 0.2;
        }
        if heap_failure.is_some() {
            // OOM-ed reducers restart with nothing to show for it
            t *= 1.5 + rng.f64();
            ok = ok && rng.f64() < 0.4;
        }
        if ok {
            completed += 1;
        }
        times.push(t / 60.0);
    }
    TimeEstimate {
        minutes: MuSigma::of(&times),
        trials,
        completed_trials: completed,
        breakdown: heap_failure.or(disk_failure).map(String::from),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::footprint::Channel;
    use crate::util::bytes::{GB, TB};

    /// Paper-scale TeraSort footprint for a given suffix volume, using
    /// Table III's measured ratios.
    fn terasort_fp(input: u64, red_rw: f64) -> Footprint {
        let mut fp = Footprint::default();
        let u = input as f64;
        fp.set(Channel::HdfsRead, input);
        fp.set(Channel::MapLocalRead, (1.03 * u) as u64);
        fp.set(Channel::MapLocalWrite, (2.07 * u) as u64);
        fp.set(Channel::Shuffle, (1.03 * u) as u64);
        fp.set(Channel::ReduceLocalRead, (red_rw * u) as u64);
        fp.set(Channel::ReduceLocalWrite, (red_rw * u) as u64);
        fp.set(Channel::HdfsWrite, (1.01 * u) as u64);
        fp
    }

    fn terasort_shape(input: u64, n_red: u64) -> WorkloadShape {
        WorkloadShape {
            n_reducers: n_red,
            per_reducer_shuffle: input / n_red,
            max_group_bytes: terasort_max_group(input),
            numeric_pipeline: false,
            reduce_slots_per_node: 2,
        }
    }

    #[test]
    fn case1_lands_near_paper_hour() {
        let cluster = ClusterSpec::table2();
        let input = 637 * GB;
        let est = estimate(
            &cluster,
            &CostParams::default(),
            &terasort_fp(input, 1.03),
            &terasort_shape(input, 32),
            &HeapConfig::paper_terasort(7 * GB),
            5,
            1,
        );
        assert!(est.completed(), "case 1 must complete: {:?}", est.breakdown);
        // paper: μ=61.8 min — same order of magnitude is the bar
        assert!(
            (25.0..140.0).contains(&est.minutes.mu),
            "mu={} min",
            est.minutes.mu
        );
    }

    #[test]
    fn case5_breaks_down() {
        let cluster = ClusterSpec::table2();
        let input = (3.37 * TB as f64) as u64;
        let est = estimate(
            &cluster,
            &CostParams::default(),
            &terasort_fp(input, 1.88),
            &terasort_shape(input, 32),
            &HeapConfig::paper_terasort(7 * GB),
            5,
            1,
        );
        assert!(!est.completed(), "case 5 must break down");
        assert!(est.breakdown.is_some());
        // paper: μ=709.4 — far off the linear trend, huge σ
        let est1 = estimate(
            &cluster,
            &CostParams::default(),
            &terasort_fp(637 * GB, 1.03),
            &terasort_shape(637 * GB, 32),
            &HeapConfig::paper_terasort(7 * GB),
            5,
            1,
        );
        assert!(est.minutes.mu > 4.0 * est1.minutes.mu);
        assert!(est.minutes.sigma > est1.minutes.sigma);
    }

    #[test]
    fn time_scales_linearly_in_linear_region() {
        let cluster = ClusterSpec::table2();
        let t = |input: u64| {
            estimate(
                &cluster,
                &CostParams::default(),
                &terasort_fp(input, 1.2),
                &terasort_shape(input, 32),
                &HeapConfig::paper_terasort(7 * GB),
                3,
                7,
            )
            .minutes
            .mu
        };
        let t1 = t(600 * GB);
        let t2 = t(1200 * GB);
        let ratio = t2 / t1;
        // paper itself is mildly superlinear (61.8 -> 143.4 min for 1.94x)
        assert!((1.7..2.4).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn scheme_beats_terasort_at_same_volume() {
        let cluster = ClusterSpec::table2();
        let suffixes = (3.4 * TB as f64) as u64;
        // scheme footprint: Table V ratios (normalized to output ≈ suffix
        // volume), KV channels extra
        let mut fp = Footprint::default();
        let u = suffixes as f64;
        fp.set(Channel::HdfsRead, (0.01 * u) as u64);
        fp.set(Channel::MapLocalRead, (0.30 * u) as u64);
        fp.set(Channel::MapLocalWrite, (0.45 * u) as u64);
        fp.set(Channel::Shuffle, (0.16 * u) as u64);
        fp.set(Channel::ReduceLocalRead, (0.16 * u) as u64);
        fp.set(Channel::ReduceLocalWrite, (0.16 * u) as u64);
        fp.set(Channel::HdfsWrite, (1.01 * u) as u64);
        fp.set(Channel::KvPut, (0.015 * u) as u64);
        fp.set(Channel::KvFetch, (0.55 * u) as u64);
        let shape = WorkloadShape {
            n_reducers: 32,
            per_reducer_shuffle: (0.16 * u) as u64 / 32,
            max_group_bytes: 26 << 20, // 1.6e6 × 16 B
            numeric_pipeline: true,
            reduce_slots_per_node: 2,
        };
        let scheme = estimate(
            &cluster,
            &CostParams::default(),
            &fp,
            &shape,
            &HeapConfig::paper_scheme(),
            5,
            3,
        );
        assert!(scheme.completed(), "{:?}", scheme.breakdown);
        let tera = estimate(
            &cluster,
            &CostParams::default(),
            &terasort_fp(suffixes, 1.88),
            &terasort_shape(suffixes, 32),
            &HeapConfig::paper_terasort(7 * GB),
            5,
            3,
        );
        assert!(
            scheme.minutes.mu < tera.minutes.mu,
            "scheme {} vs tera {}",
            scheme.minutes.mu,
            tera.minutes.mu
        );
    }
}

/// Largest same-10-char-prefix sorting group TeraSort must hold, as a
/// function of total suffix volume. Genomic repeats give the group-size
/// distribution a heavy tail; the largest cluster grows sublinearly —
/// calibrated ~√N so that the paper's observed breakdowns reproduce
/// (Case 4 survives a 7 GB heap, Case 5 does not, mem_heap's 15 GB heap
/// survives Case 5, and Table IV's 9 GB heap is memory-safe at 3.95 TB).
pub fn terasort_max_group(total_suffix_bytes: u64) -> u64 {
    (1225.0 * (total_suffix_bytes as f64).sqrt()) as u64
}

//! Minimal CLI argument parsing (no external deps in the offline build).

use std::collections::HashMap;

/// Parsed command line: subcommand, positional args, `--key value` flags
/// and bare `--switch`es.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key value` unless next token is another flag/missing
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else if out.command.is_empty() {
                out.command = a;
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// A flag the subcommand cannot run without; the `Err` is a
    /// ready-to-print usage message naming the flag.
    pub fn require(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .ok_or_else(|| format!("{}: missing required flag --{key}", self.command))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn positional_parse<T: std::str::FromStr>(&self, i: usize) -> Option<T> {
        self.positional.get(i).and_then(|v| v.parse().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse("table 3 --thrift 8 --verbose --trials 5");
        assert_eq!(a.command, "table");
        assert_eq!(a.positional, vec!["3"]);
        assert_eq!(a.get_parse("thrift", 1.0), 8.0);
        assert_eq!(a.get_parse("trials", 0usize), 5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.positional_parse::<u32>(0), Some(3));
    }

    #[test]
    fn require_names_the_missing_flag() {
        let a = parse("serve --port 7000");
        assert_eq!(a.require("port"), Ok("7000"));
        let err = a.require("index").unwrap_err();
        assert!(err.contains("--index") && err.contains("serve"), "{err}");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("scheme");
        assert_eq!(a.get_parse("reads", 100usize), 100);
        assert!(a.get("missing").is_none());
    }
}

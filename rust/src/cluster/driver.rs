//! The cluster driver: true multi-process mode. KV shards and
//! map/reduce workers run as separate `samr` OS processes; this driver
//! spawns them, dispatches task attempts over RESP, reschedules an
//! attempt when its worker process dies, and respawns a killed shard
//! process from its append-only log.
//!
//! **Topology.** `n_shards` processes run `samr shard` (one KV instance
//! each, AOF-backed), `n_workers` run `samr worker` (the task executor
//! in [`crate::cluster::worker`]). Children print `ADDR <ip:port>` on
//! stdout once bound; the driver publishes shard addresses through an
//! atomically-rewritten shard-map file that worker-side store clients
//! re-read on every reconnect — a respawned shard on a fresh port is
//! found without any coordination beyond the rename.
//!
//! **Attempt lifecycle across processes.** Each task goes through the
//! same [`run_with_retries`] harness as the in-process engine: an
//! attempt gets a scratch subdirectory and a redirected ledger scope;
//! the driver picks a live worker, charges `HdfsRead` (map) exactly
//! where the engine would, sends the spec, and replays the worker's
//! nine-channel delta into the attempt scope on success. A dead socket
//! — worker SIGKILLed, aborted, or crashed — surfaces as a failed
//! attempt carrying the child's exit status and stderr tail; its
//! charges (recovered from the worker's journal when it finished before
//! aborting) fold into `wasted`, and the retry lands on a surviving
//! worker. Workers are not respawned; shards are, because their state
//! (the reads) is needed for the rest of the job and their AOF plus the
//! store clients' idempotent-window replay makes the restart exact.
//!
//! **Fault injection.** A [`FaultPlan`]'s `proc_faults` are consulted
//! only here: `Start` means the driver SIGKILLs the chosen worker
//! before dispatching (the attempt dies on the dead socket), `Finish`
//! means the spec carries `abort=1` and the worker journals its result
//! then aborts without replying. `shard_abort` rides to one shard child
//! as `--kill-at-request N`; the monitor thread observes the death and
//! respawns the shard from its AOF. The monitor is stopped *before*
//! orderly shutdown kills the fleet, so only fault-induced deaths are
//! tallied via [`FaultPlan::note_proc_kill`].

use std::io::{self, BufRead, BufReader};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::worker::{
    encode_cfg, encode_spill, parse_map_result, parse_reduce_result, read_shard_map,
    write_shard_map, Spec,
};
use crate::faults::{FaultPlan, FaultPoint, Phase};
use crate::footprint::{Channel, Ledger, CHANNELS};
use crate::kvstore::client::{Client, FailoverConfig};
use crate::kvstore::resp::Value;
use crate::kvstore::shard::{ShardedClient, SuffixStore};
use crate::mapreduce::engine::{reap_stale_scratch, run_with_retries, JobResult, ScratchDir};
use crate::mapreduce::io::OutputFile;
use crate::mapreduce::mapper::{MapTaskStats, SpillFile};
use crate::mapreduce::pool::WorkerPool;
use crate::mapreduce::reducer::ReduceTaskStats;
use crate::scheme::{self, sampler, SchemeConfig};
use crate::suffix::reads::Read;

/// How a cluster run is shaped: process counts, the `samr` binary to
/// spawn, and an optional process-level fault plan.
pub struct ClusterOpts {
    pub n_workers: usize,
    pub n_shards: usize,
    /// Path to the `samr` binary for child processes (tests use
    /// `env!("CARGO_BIN_EXE_samr")`; the CLI uses its own image).
    pub samr_bin: PathBuf,
    /// Process-kill schedule. Task retries come from
    /// `cfg.conf.max_task_attempts` as usual; a plan with kills needs
    /// `max_task_attempts >= 2` to leave room for the reschedule.
    pub plan: Option<Arc<FaultPlan>>,
}

/// What a cluster construction produces — the cluster-mode analogue of
/// [`scheme::SchemeResult`].
pub struct ClusterRun {
    pub job: JobResult,
    /// The suffix array (packed indexes in output order).
    pub order: Vec<i64>,
    /// Total memory used by the shard processes' stores.
    pub kv_memory: u64,
    /// Partition boundaries used.
    pub boundaries: Vec<i64>,
}

/// One spawned child process and what the driver knows about it.
struct Proc {
    child: Child,
    addr: SocketAddr,
    /// Scheduling eligibility: cleared on observed death or on the
    /// first dispatch failure against this child.
    alive: bool,
    /// OS exit status, once the monitor reaped it.
    exit: Option<String>,
    /// The monitor observed (and, under a plan, tallied) the death.
    reaped: bool,
    stderr: Arc<Mutex<Vec<u8>>>,
}

struct Fleet {
    workers: Vec<Proc>,
    shards: Vec<Proc>,
}

/// Spawn one child and wait for its `ADDR <ip:port>` line. stderr is
/// captured for post-mortems; stdout past the address line is drained
/// so the child can never block on a full pipe.
fn spawn_proc(bin: &Path, args: &[String]) -> io::Result<Proc> {
    let mut child = Command::new(bin)
        .args(args)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .map_err(|e| io::Error::new(e.kind(), format!("spawning {}: {e}", bin.display())))?;
    let stderr = Arc::new(Mutex::new(Vec::new()));
    if let Some(mut pipe) = child.stderr.take() {
        let buf = stderr.clone();
        std::thread::spawn(move || {
            let mut v = Vec::new();
            let _ = std::io::Read::read_to_end(&mut pipe, &mut v);
            buf.lock().unwrap().extend_from_slice(&v);
        });
    }
    let mut lines = BufReader::new(child.stdout.take().expect("piped stdout"));
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if lines.read_line(&mut line)? == 0 {
            let status =
                child.wait().map(|s| s.to_string()).unwrap_or_else(|e| e.to_string());
            let tail = String::from_utf8_lossy(&stderr.lock().unwrap()).into_owned();
            return Err(io::Error::other(format!(
                "child `{} {}` exited ({status}) before reporting its address: {}",
                bin.display(),
                args.join(" "),
                tail.trim()
            )));
        }
        if let Some(rest) = line.trim().strip_prefix("ADDR ") {
            break rest.parse::<SocketAddr>().map_err(|_| {
                io::Error::new(io::ErrorKind::InvalidData, format!("bad ADDR line {line:?}"))
            })?;
        }
    };
    std::thread::spawn(move || {
        let _ = std::io::copy(&mut lines, &mut std::io::sink());
    });
    Ok(Proc { child, addr, alive: true, exit: None, reaped: false, stderr })
}

fn spawn_shard(bin: &Path, idx: usize, aof: &Path, kill_at: Option<u64>) -> io::Result<Proc> {
    let mut args = vec![
        "shard".to_string(),
        "--shard".into(),
        idx.to_string(),
        "--port".into(),
        "0".into(),
        "--aof".into(),
        aof.display().to_string(),
    ];
    if let Some(n) = kill_at {
        args.push("--kill-at-request".into());
        args.push(n.to_string());
    }
    spawn_proc(bin, &args)
}

fn spawn_worker(bin: &Path) -> io::Result<Proc> {
    spawn_proc(bin, &["worker".into(), "--port".into(), "0".into()])
}

/// One monitor pass: reap dead children, tally plan-era kills, respawn
/// dead shards from their AOF and republish the shard map.
fn sweep(
    fleet: &Mutex<Fleet>,
    plan: Option<&Arc<FaultPlan>>,
    bin: &Path,
    shard_map: &Path,
    aofs: &[PathBuf],
) {
    let mut f = fleet.lock().unwrap();
    for w in &mut f.workers {
        if w.reaped {
            continue;
        }
        if let Ok(Some(status)) = w.child.try_wait() {
            w.reaped = true;
            w.alive = false;
            w.exit = Some(status.to_string());
            if let Some(p) = plan {
                p.note_proc_kill();
            }
        }
    }
    for i in 0..f.shards.len() {
        if f.shards[i].reaped {
            continue;
        }
        if let Ok(Some(status)) = f.shards[i].child.try_wait() {
            if let Some(p) = plan {
                p.note_proc_kill();
            }
            // respawn from the AOF on a fresh port (no fault flag — the
            // schedule fired), then republish the map so store clients'
            // rediscover-on-reconnect finds the new address
            match spawn_shard(bin, i, &aofs[i], None) {
                Ok(p2) => {
                    f.shards[i] = p2;
                    let addrs: Vec<SocketAddr> = f.shards.iter().map(|s| s.addr).collect();
                    let _ = write_shard_map(shard_map, &addrs);
                }
                Err(e) => {
                    f.shards[i].reaped = true;
                    f.shards[i].alive = false;
                    f.shards[i].exit = Some(format!("{status}; respawn failed: {e}"));
                }
            }
        }
    }
}

/// Control-plane client config: one connect, one shot, generous read
/// deadline (the reply lands only when the task finishes). No failover
/// — a dead worker must surface as a failed attempt, not a silent
/// replay somewhere else.
fn control_cfg() -> FailoverConfig {
    FailoverConfig {
        connect_timeout: Duration::from_secs(2),
        connect_attempts: 1,
        backoff_base: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(200),
        read_timeout: Some(Duration::from_secs(120)),
        write_timeout: Some(Duration::from_secs(30)),
        failover_attempts: 1,
    }
}

/// Send one task command and return the worker's bulk reply text.
fn dispatch(addr: SocketAddr, cmd: &[u8], spec: &str) -> io::Result<String> {
    let mut c = Client::connect_with(addr, control_cfg()).map_err(io::Error::from)?;
    match c.call(&[cmd, spec.as_bytes()]).map_err(io::Error::from)? {
        Value::Bulk(b) => String::from_utf8(b)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 task reply")),
        Value::Error(e) => Err(io::Error::other(e)),
        other => Err(io::Error::other(format!("unexpected task reply {other:?}"))),
    }
}

/// Pick the worker for `(task, attempt)` among the live ones —
/// rotating by attempt, so a retry lands on a *different* worker when
/// one exists. With `kill_first` the chosen child is SIGKILLed before
/// the address is returned: the process-level `Start` fault.
fn pick_worker(
    fleet: &Mutex<Fleet>,
    task: usize,
    attempt: usize,
    kill_first: bool,
) -> io::Result<(usize, SocketAddr)> {
    let mut f = fleet.lock().unwrap();
    let live: Vec<usize> =
        f.workers.iter().enumerate().filter(|(_, w)| w.alive).map(|(i, _)| i).collect();
    if live.is_empty() {
        return Err(io::Error::other("no live workers remain"));
    }
    let w = live[(task + attempt) % live.len()];
    if kill_first {
        let _ = f.workers[w].child.kill();
        f.workers[w].alive = false;
    }
    Ok((w, f.workers[w].addr))
}

/// Mark a worker dead after a failed dispatch and describe what the
/// driver knows: exit status (if reaped yet) and a stderr tail.
fn fail_worker(fleet: &Mutex<Fleet>, w: usize) -> String {
    let mut f = fleet.lock().unwrap();
    f.workers[w].alive = false;
    let exit = f.workers[w].exit.clone().unwrap_or_else(|| "not yet reaped".into());
    let buf = f.workers[w].stderr.lock().unwrap();
    let tail = String::from_utf8_lossy(&buf[buf.len().saturating_sub(300)..]).into_owned();
    format!("exit: {exit}; stderr: {:?}", tail.trim())
}

/// Replay a worker-reported nine-channel delta into the job ledger on
/// the calling thread. Inside an attempt scope this lands in the
/// attempt's private ledger, so a later failure folds the whole delta
/// into `wasted` exactly like an in-process attempt's own charges.
fn replay_delta(ledger: &Ledger, delta: &[u64; 9]) {
    for (ch, &b) in CHANNELS.iter().zip(delta) {
        if b > 0 {
            ledger.add(*ch, b);
        }
    }
}

/// Run the scheme construction across worker and shard *processes*.
/// Output bytes and all nine footprint channels are byte-identical to
/// [`scheme::run_files`] over the same corpus and config — with or
/// without process kills — because task bodies, split plans, and charge
/// sites are shared with the in-process engine, and failed attempts'
/// charges fold into [`JobResult::wasted`], never the footprint.
pub fn run_cluster_files(
    files: &[&[Read]],
    cfg: &SchemeConfig,
    opts: &ClusterOpts,
    ledger: &Arc<Ledger>,
) -> io::Result<ClusterRun> {
    assert!(opts.n_workers > 0, "cluster needs at least one worker process");
    assert!(opts.n_shards > 0, "cluster needs at least one shard process");
    let start = Instant::now();
    scheme::check_unique_seqs(files)?;
    let boundaries = sampler::make_boundaries_files(
        files,
        cfg.conf.n_reducers,
        cfg.samples_per_reducer,
        cfg.prefix_len,
        cfg.seed,
    );

    reap_stale_scratch(cfg.conf.spill_dir.as_deref());
    let base = cfg.conf.spill_dir.as_deref();
    // meta holds the shard map and the shards' AOFs: it must outlive
    // every shard (re)spawn, so it is its own dir, dropped last
    let meta = ScratchDir::new(base, "cluster-meta")?;
    let scratch = Arc::new(ScratchDir::new(base, "cluster")?);
    let out_dir = Arc::new(ScratchDir::new(base, "cluster-out")?);
    let lcp_dir =
        if cfg.emit_lcp { Some(ScratchDir::new(base, "cluster-lcp")?) } else { None };
    let shard_map = meta.path.join("shards");
    let aofs: Vec<PathBuf> =
        (0..opts.n_shards).map(|i| meta.path.join(format!("shard{i}.aof"))).collect();
    let plan = opts.plan.clone();

    let fleet = Arc::new(Mutex::new(Fleet { workers: Vec::new(), shards: Vec::new() }));
    let stop = Arc::new(AtomicBool::new(false));
    let mut mon: Option<std::thread::JoinHandle<()>> = None;

    // everything past this point runs under the shutdown guard below:
    // whatever the body returns, the monitor is stopped first and the
    // fleet is killed and reaped
    let body = (|| -> io::Result<ClusterRun> {
        {
            let mut f = fleet.lock().unwrap();
            for i in 0..opts.n_shards {
                let kill_at = plan
                    .as_ref()
                    .and_then(|p| p.shard_abort)
                    .filter(|s| s.shard == i)
                    .map(|s| s.at_request);
                f.shards.push(spawn_shard(&opts.samr_bin, i, &aofs[i], kill_at)?);
            }
            let addrs: Vec<SocketAddr> = f.shards.iter().map(|s| s.addr).collect();
            write_shard_map(&shard_map, &addrs)?;
            for _ in 0..opts.n_workers {
                f.workers.push(spawn_worker(&opts.samr_bin)?);
            }
        }
        mon = Some({
            let fleet = fleet.clone();
            let stop = stop.clone();
            let plan = plan.clone();
            let bin = opts.samr_bin.clone();
            let shard_map = shard_map.clone();
            let aofs = aofs.clone();
            std::thread::spawn(move || loop {
                let done = stop.load(Ordering::SeqCst);
                sweep(&fleet, plan.as_ref(), &bin, &shard_map, &aofs);
                if done {
                    return; // one final sweep after the stop signal
                }
                std::thread::sleep(Duration::from_millis(20));
            })
        });

        let (spool, splits) = scheme::spool_inputs(files, &cfg.conf)?;
        let n_maps = splits.len();
        let n_reds = cfg.conf.n_reducers;
        let threads = cfg.conf.task_parallelism.max(1);
        let pool = WorkerPool::global();
        let wasted = Ledger::new();
        // retries are the driver's; the retry harness itself must stay
        // fault-blind (process kills are injected here, not by it)
        let mut retry_conf = cfg.conf.clone();
        retry_conf.faults = None;
        let bounds_csv =
            boundaries.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",");

        // ---------------- map phase ----------------
        type MapSlot = Option<io::Result<(SpillFile, MapTaskStats)>>;
        let map_slots: Arc<Mutex<Vec<MapSlot>>> =
            Arc::new(Mutex::new((0..n_maps).map(|_| None).collect()));
        let splits = Arc::new(splits);
        let tasks: Vec<(u64, Box<dyn FnOnce() + Send>)> = (0..n_maps)
            .map(|i| {
                let slots = map_slots.clone();
                let splits = splits.clone();
                let fleet = fleet.clone();
                let plan = plan.clone();
                let ledger = ledger.clone();
                let wasted = wasted.clone();
                let scratch = scratch.clone();
                let retry_conf = retry_conf.clone();
                let cfg = cfg.clone();
                let shard_map = shard_map.clone();
                let bounds_csv = bounds_csv.clone();
                let weight = splits[i].bytes;
                let run: Box<dyn FnOnce() + Send> = Box::new(move || {
                    let split = &splits[i];
                    let r = run_with_retries(
                        Phase::Map,
                        i,
                        "cluster",
                        &retry_conf,
                        &ledger,
                        &wasted,
                        &scratch,
                        |dir, a| {
                            let kill =
                                plan.as_ref().and_then(|p| p.proc_fault_at(Phase::Map, i, a));
                            let (w, addr) = pick_worker(
                                &fleet,
                                i,
                                a,
                                kill == Some(FaultPoint::Start),
                            )?;
                            // the engine's charge, made by the driver —
                            // the worker never touches HdfsRead
                            ledger.add(Channel::HdfsRead, split.bytes);
                            let mut spec = Spec::new();
                            encode_cfg(&mut spec, &cfg);
                            spec.push("task", i.to_string());
                            spec.push("dir", dir.display().to_string());
                            spec.push("split_path", split.path.display().to_string());
                            spec.push("split_offset", split.offset.to_string());
                            spec.push("split_bytes_n", split.bytes.to_string());
                            spec.push("split_records", split.records.to_string());
                            spec.push("boundaries", bounds_csv.clone());
                            spec.push("shard_map", shard_map.display().to_string());
                            if kill == Some(FaultPoint::Finish) {
                                spec.push("abort", "1");
                            }
                            match dispatch(addr, b"MAP", &spec.encode()) {
                                Ok(text) => {
                                    let (spill, stats, delta) = parse_map_result(&text)?;
                                    replay_delta(&ledger, &delta);
                                    Ok((spill, stats))
                                }
                                Err(e) => {
                                    let detail = fail_worker(&fleet, w);
                                    // a journaled (finished-then-aborted)
                                    // attempt still spent its bytes
                                    if let Ok(j) =
                                        std::fs::read_to_string(dir.join("journal"))
                                    {
                                        if let Ok((_, _, delta)) = parse_map_result(&j) {
                                            replay_delta(&ledger, &delta);
                                        }
                                    }
                                    Err(io::Error::other(format!(
                                        "worker {addr} died mid-map ({detail}): {e}"
                                    )))
                                }
                            }
                        },
                        |_a| {},
                    );
                    slots.lock().unwrap()[i] = Some(r);
                });
                (weight, run)
            })
            .collect();
        pool.run_all_weighted(tasks, threads);
        let mut map_out = Vec::with_capacity(n_maps);
        let mut map_stats = Vec::with_capacity(n_maps);
        for s in map_slots.lock().unwrap().drain(..) {
            let (spill, st) = s.expect("map slot filled")?;
            map_out.push(spill);
            map_stats.push(st);
        }

        // ---------------- reduce phase ----------------
        let map_out = Arc::new(map_out);
        type RedSlot = Option<io::Result<(OutputFile, ReduceTaskStats)>>;
        let red_slots: Arc<Mutex<Vec<RedSlot>>> =
            Arc::new(Mutex::new((0..n_reds).map(|_| None).collect()));
        let tasks: Vec<(u64, Box<dyn FnOnce() + Send>)> = (0..n_reds)
            .map(|r| {
                let slots = red_slots.clone();
                let map_out = map_out.clone();
                let fleet = fleet.clone();
                let plan = plan.clone();
                let ledger = ledger.clone();
                let wasted = wasted.clone();
                let scratch = scratch.clone();
                let retry_conf = retry_conf.clone();
                let cfg = cfg.clone();
                let shard_map = shard_map.clone();
                let sink_path = out_dir.path.join(format!("part-{r:05}"));
                let lcp_path =
                    lcp_dir.as_ref().map(|d| d.path.join(scheme::lcp_sidecar_name(r)));
                let weight: u64 = map_out.iter().map(|o| o.segments[r].bytes).sum();
                let run: Box<dyn FnOnce() + Send> = Box::new(move || {
                    let sink_cleanup = sink_path.clone();
                    let res = run_with_retries(
                        Phase::Reduce,
                        r,
                        "cluster",
                        &retry_conf,
                        &ledger,
                        &wasted,
                        &scratch,
                        |dir, a| {
                            let kill = plan
                                .as_ref()
                                .and_then(|p| p.proc_fault_at(Phase::Reduce, r, a));
                            let (w, addr) = pick_worker(
                                &fleet,
                                r,
                                a,
                                kill == Some(FaultPoint::Start),
                            )?;
                            let mut spec = Spec::new();
                            encode_cfg(&mut spec, &cfg);
                            spec.push("task", r.to_string());
                            spec.push("dir", dir.display().to_string());
                            spec.push("sink", sink_path.display().to_string());
                            if let Some(p) = &lcp_path {
                                spec.push("lcp", p.display().to_string());
                            }
                            spec.push("shard_map", shard_map.display().to_string());
                            for o in map_out.iter() {
                                spec.push("spill_in", encode_spill(o));
                            }
                            if kill == Some(FaultPoint::Finish) {
                                spec.push("abort", "1");
                            }
                            match dispatch(addr, b"REDUCE", &spec.encode()) {
                                Ok(text) => {
                                    let (file, stats, delta) = parse_reduce_result(&text)?;
                                    replay_delta(&ledger, &delta);
                                    // the engine's post-sink charge,
                                    // made by the driver
                                    ledger.add(Channel::HdfsWrite, file.bytes);
                                    Ok((file, stats))
                                }
                                Err(e) => {
                                    let detail = fail_worker(&fleet, w);
                                    if let Ok(j) =
                                        std::fs::read_to_string(dir.join("journal"))
                                    {
                                        if let Ok((file, _, delta)) =
                                            parse_reduce_result(&j)
                                        {
                                            replay_delta(&ledger, &delta);
                                            // the sink was sealed before
                                            // the abort: its write was
                                            // real, and belongs to this
                                            // doomed attempt's tally
                                            ledger.add(Channel::HdfsWrite, file.bytes);
                                        }
                                    }
                                    Err(io::Error::other(format!(
                                        "worker {addr} died mid-reduce ({detail}): {e}"
                                    )))
                                }
                            }
                        },
                        |_a| {
                            let _ = std::fs::remove_file(&sink_cleanup);
                        },
                    );
                    slots.lock().unwrap()[r] = Some(res);
                });
                (weight, run)
            })
            .collect();
        pool.run_all_weighted(tasks, threads);
        let mut output = Vec::with_capacity(n_reds);
        let mut reduce_stats = Vec::with_capacity(n_reds);
        for s in red_slots.lock().unwrap().drain(..) {
            let (file, st) = s.expect("reduce slot filled")?;
            output.push(file);
            reduce_stats.push(st);
        }
        for o in map_out.iter() {
            o.remove();
        }
        drop(spool);

        let job = JobResult::from_parts(
            output,
            out_dir.clone(),
            ledger.snapshot(),
            wasted.snapshot(),
            map_stats,
            reduce_stats,
            start.elapsed(),
        );
        let order = job.collect_i64_values()?;
        // memory probe over a fresh, uncharged control connection
        let addrs = read_shard_map(&shard_map)?;
        let kv_memory =
            ShardedClient::connect(&addrs).map_err(io::Error::from)?.used_memory();
        Ok(ClusterRun { job, order, kv_memory, boundaries })
    })();

    // orderly teardown: stop the monitor FIRST (its final sweep tallies
    // fault-era deaths), only then kill the fleet — shutdown kills are
    // never counted as process faults
    stop.store(true, Ordering::SeqCst);
    if let Some(m) = mon {
        let _ = m.join();
    }
    let mut f = fleet.lock().unwrap();
    for p in f.workers.iter_mut().chain(f.shards.iter_mut()) {
        let _ = p.child.kill();
        let _ = p.child.wait();
    }
    drop(f);
    body
}

//! The `samr worker` process: executes one map or reduce task attempt
//! per request, in its own OS process, over the same task runners the
//! in-process engine uses.
//!
//! The driver (`cluster::driver`) speaks to workers over the existing
//! RESP plumbing — a worker is just another [`RespService`] — with two
//! task commands, `MAP <spec>` and `REDUCE <spec>`, plus `PING`. Specs
//! and results travel as line-oriented `key=value` text (floats as
//! exact `f64::to_bits` integers, so a decoded `JobConf` computes
//! byte-identical spill triggers).
//!
//! **Division of accounting.** A worker runs `run_map_task` /
//! `run_reduce_task` against a *fresh local ledger* and reports the
//! per-channel delta in its reply; the driver replays the delta into
//! the job ledger inside the task's attempt scope. `HdfsRead` /
//! `HdfsWrite` are charged by the driver (exactly where the in-process
//! engine charges them), and the control-plane RESP traffic itself is
//! charged to no channel — so a cluster run's nine-channel footprint is
//! byte-identical to a single-process run's by construction.
//!
//! **Journal-then-abort.** A spec with `abort=1` makes the worker
//! finish the task, persist its reply (the "journal") into the attempt
//! directory via tmp+rename, then `std::process::abort()` WITHOUT
//! replying — the process-level Finish fault. The driver sees the
//! connection die, reads the journal, and charges the dead attempt's
//! delta to the job's `wasted` tally, mirroring how an in-process
//! aborted attempt's redirected ledger folds into `wasted`.

use std::io;
use std::net::SocketAddr;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::footprint::{Ledger, CHANNELS};
use crate::kvstore::client::FailoverConfig;
use crate::kvstore::resp::{self, Value};
use crate::kvstore::service::{RespHandler, RespServer, RespService};
use crate::kvstore::shard::{ShardedClient, SuffixStore};
use crate::mapreduce::io::{FileSink, InputSplit, OutputFile};
use crate::mapreduce::job::JobConf;
use crate::mapreduce::mapper::{
    run_map_task, run_map_task_fixed, MapTaskStats, Segment, SpillFile,
};
use crate::mapreduce::record::decode_i64_key;
use crate::mapreduce::reducer::{run_reduce_task, run_reduce_task_fixed, ReduceTaskStats};
use crate::runtime::native;
use crate::scheme::{self, SchemeConfig, StoreSlot, TimeSplit};

// ---------------- spec wire format ----------------

/// Line-oriented `key=value` blob — the worker protocol's only payload
/// shape (task specs, task results, journals). Keys may repeat
/// (`spill=` lines); values run to end-of-line, so they must not
/// contain newlines (true of every path and number we carry).
#[derive(Debug, Default)]
pub(crate) struct Spec {
    fields: Vec<(String, String)>,
}

impl Spec {
    pub(crate) fn new() -> Spec {
        Spec::default()
    }

    pub(crate) fn push(&mut self, key: &str, value: impl Into<String>) {
        self.fields.push((key.to_string(), value.into()));
    }

    pub(crate) fn parse(text: &str) -> io::Result<Spec> {
        let mut fields = Vec::new();
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("spec line without '=': {line:?}"),
                )
            })?;
            fields.push((k.to_string(), v.to_string()));
        }
        Ok(Spec { fields })
    }

    pub(crate) fn encode(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.fields {
            out.push_str(k);
            out.push('=');
            out.push_str(v);
            out.push('\n');
        }
        out
    }

    pub(crate) fn opt(&self, key: &str) -> Option<&str> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    pub(crate) fn get(&self, key: &str) -> io::Result<&str> {
        self.opt(key).ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, format!("spec is missing {key:?}"))
        })
    }

    pub(crate) fn get_parse<T: std::str::FromStr>(&self, key: &str) -> io::Result<T> {
        self.get(key)?.parse().map_err(|_| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("spec field {key:?} failed to parse: {:?}", self.opt(key)),
            )
        })
    }

    pub(crate) fn all<'a>(&'a self, key: &'a str) -> impl Iterator<Item = &'a str> {
        self.fields.iter().filter(move |(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

fn csv<T: std::fmt::Display>(vals: impl IntoIterator<Item = T>) -> String {
    vals.into_iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
}

fn parse_csv<T: std::str::FromStr>(s: &str) -> io::Result<Vec<T>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|p| {
            p.parse().map_err(|_| {
                io::Error::new(io::ErrorKind::InvalidData, format!("bad CSV element {p:?}"))
            })
        })
        .collect()
}

// ---------------- config transport ----------------

/// Serialize the scheme + job knobs a task attempt's behavior depends
/// on. Floats go as `to_bits` so the worker's spill/merge triggers are
/// bit-identical to the driver's.
pub(crate) fn encode_cfg(spec: &mut Spec, cfg: &SchemeConfig) {
    let c = &cfg.conf;
    spec.push("prefix_len", cfg.prefix_len.to_string());
    spec.push("group_threshold", cfg.group_threshold.to_string());
    spec.push("write_suffixes", if cfg.write_suffixes { "1" } else { "0" });
    spec.push("samples_per_reducer", cfg.samples_per_reducer.to_string());
    spec.push("put_batch", cfg.put_batch.to_string());
    spec.push("prefetch", if cfg.prefetch { "1" } else { "0" });
    spec.push("fixed_shuffle", if cfg.fixed_shuffle { "1" } else { "0" });
    spec.push("sort_threads", cfg.parallel_sort_threads.to_string());
    spec.push("emit_lcp", if cfg.emit_lcp { "1" } else { "0" });
    spec.push("seed", cfg.seed.to_string());
    spec.push("io_sort_bytes", c.io_sort_bytes.to_string());
    spec.push("spill_percent_bits", c.spill_percent.to_bits().to_string());
    spec.push("io_sort_factor", c.io_sort_factor.to_string());
    spec.push("split_bytes", c.split_bytes.to_string());
    spec.push("n_reducers", c.n_reducers.to_string());
    spec.push("reducer_heap_bytes", c.reducer_heap_bytes.to_string());
    spec.push("shuffle_in_bits", c.shuffle_input_buffer_percent.to_bits().to_string());
    spec.push("shuffle_merge_bits", c.shuffle_merge_percent.to_bits().to_string());
    spec.push("shuffle_limit_bits", c.shuffle_memory_limit_percent.to_bits().to_string());
}

/// Rebuild the config in the worker. Driver-side knobs (task
/// parallelism, retries, fault plan, spill dir) deliberately reset to
/// inert values: the worker runs exactly one attempt in the directory
/// it was handed.
pub(crate) fn decode_cfg(spec: &Spec) -> io::Result<SchemeConfig> {
    let f64_bits = |key: &str| -> io::Result<f64> { Ok(f64::from_bits(spec.get_parse(key)?)) };
    let flag = |key: &str| -> io::Result<bool> { Ok(spec.get(key)? == "1") };
    let fixed_shuffle = flag("fixed_shuffle")?;
    Ok(SchemeConfig {
        conf: JobConf {
            io_sort_bytes: spec.get_parse("io_sort_bytes")?,
            spill_percent: f64_bits("spill_percent_bits")?,
            io_sort_factor: spec.get_parse("io_sort_factor")?,
            split_bytes: spec.get_parse("split_bytes")?,
            n_reducers: spec.get_parse("n_reducers")?,
            reducer_heap_bytes: spec.get_parse("reducer_heap_bytes")?,
            shuffle_input_buffer_percent: f64_bits("shuffle_in_bits")?,
            shuffle_merge_percent: f64_bits("shuffle_merge_bits")?,
            shuffle_memory_limit_percent: f64_bits("shuffle_limit_bits")?,
            task_parallelism: 1,
            parallel_sort_threads: spec.get_parse("sort_threads")?,
            spill_dir: None,
            fixed_width: fixed_shuffle,
            max_task_attempts: 1,
            faults: None,
        },
        prefix_len: spec.get_parse("prefix_len")?,
        group_threshold: spec.get_parse("group_threshold")?,
        write_suffixes: flag("write_suffixes")?,
        samples_per_reducer: spec.get_parse("samples_per_reducer")?,
        put_batch: spec.get_parse("put_batch")?,
        prefetch: flag("prefetch")?,
        fixed_shuffle,
        parallel_sort_threads: spec.get_parse("sort_threads")?,
        emit_lcp: flag("emit_lcp")?,
        seed: spec.get_parse("seed")?,
    })
}

// ---------------- spill / result transport ----------------

/// One spill descriptor as a single spec value:
/// `path<TAB>bytes<TAB>off:bytes:records,...` (one segment triple per
/// reducer partition).
pub(crate) fn encode_spill(s: &SpillFile) -> String {
    let segs = csv(s.segments.iter().map(|g| format!("{}:{}:{}", g.offset, g.bytes, g.records)));
    format!("{}\t{}\t{}", s.path.display(), s.bytes, segs)
}

fn decode_spill(v: &str) -> io::Result<SpillFile> {
    let bad = |msg: &str| io::Error::new(io::ErrorKind::InvalidData, format!("spill: {msg}: {v:?}"));
    let mut parts = v.split('\t');
    let path = PathBuf::from(parts.next().ok_or_else(|| bad("missing path"))?);
    let bytes = parts
        .next()
        .and_then(|p| p.parse().ok())
        .ok_or_else(|| bad("missing/bad byte count"))?;
    let segs = parts.next().ok_or_else(|| bad("missing segments"))?;
    let mut segments = Vec::new();
    for t in segs.split(',').filter(|t| !t.is_empty()) {
        let nums: Vec<u64> = parse_csv(&t.replace(':', ","))?;
        if nums.len() != 3 {
            return Err(bad("segment is not an off:bytes:records triple"));
        }
        segments.push(Segment { offset: nums[0], bytes: nums[1], records: nums[2] });
    }
    Ok(SpillFile { path, segments, bytes })
}

fn encode_delta(spec: &mut Spec, ledger: &Ledger) {
    spec.push("delta", csv(CHANNELS.iter().map(|&ch| ledger.get(ch))));
}

/// The nine-channel delta a worker reported, in `CHANNELS` order.
pub(crate) fn decode_delta(spec: &Spec) -> io::Result<[u64; 9]> {
    let vals: Vec<u64> = parse_csv(spec.get("delta")?)?;
    vals.try_into().map_err(|v: Vec<u64>| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            format!("delta has {} channels, expected 9", v.len()),
        )
    })
}

fn encode_map_result(spill: &SpillFile, stats: &MapTaskStats, ledger: &Ledger) -> String {
    let mut out = Spec::new();
    out.push("spill", encode_spill(spill));
    out.push(
        "stats",
        csv([
            stats.input_records,
            stats.input_bytes,
            stats.output_records,
            stats.output_bytes,
            stats.spills,
        ]),
    );
    encode_delta(&mut out, ledger);
    out.encode()
}

pub(crate) fn parse_map_result(text: &str) -> io::Result<(SpillFile, MapTaskStats, [u64; 9])> {
    let spec = Spec::parse(text)?;
    let spill = decode_spill(spec.get("spill")?)?;
    let s: Vec<u64> = parse_csv(spec.get("stats")?)?;
    if s.len() != 5 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "map stats need 5 fields"));
    }
    let stats = MapTaskStats {
        input_records: s[0],
        input_bytes: s[1],
        output_records: s[2],
        output_bytes: s[3],
        spills: s[4],
    };
    Ok((spill, stats, decode_delta(&spec)?))
}

fn encode_reduce_result(file: &OutputFile, stats: &ReduceTaskStats, ledger: &Ledger) -> String {
    let mut out = Spec::new();
    out.push("out_path", file.path.display().to_string());
    out.push("out_bytes", file.bytes.to_string());
    out.push("out_records", file.records.to_string());
    out.push(
        "stats",
        csv([
            stats.shuffled_bytes,
            stats.shuffled_records,
            stats.disk_segments,
            stats.mem_merges,
            stats.merge_rounds_bytes,
            stats.groups,
            stats.max_group,
            stats.output_records,
            stats.output_bytes,
        ]),
    );
    encode_delta(&mut out, ledger);
    out.encode()
}

pub(crate) fn parse_reduce_result(
    text: &str,
) -> io::Result<(OutputFile, ReduceTaskStats, [u64; 9])> {
    let spec = Spec::parse(text)?;
    let file = OutputFile {
        path: PathBuf::from(spec.get("out_path")?),
        bytes: spec.get_parse("out_bytes")?,
        records: spec.get_parse("out_records")?,
    };
    let s: Vec<u64> = parse_csv(spec.get("stats")?)?;
    if s.len() != 9 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "reduce stats need 9 fields"));
    }
    let stats = ReduceTaskStats {
        shuffled_bytes: s[0],
        shuffled_records: s[1],
        disk_segments: s[2],
        mem_merges: s[3],
        merge_rounds_bytes: s[4],
        groups: s[5],
        max_group: s[6],
        output_records: s[7],
        output_bytes: s[8],
    };
    Ok((file, stats, decode_delta(&spec)?))
}

// ---------------- shard map ----------------

/// Write the shard address map (lines of `<index> <addr>`) atomically:
/// readers racing a shard respawn see either the old complete map or
/// the new complete map, never a truncated one.
pub(crate) fn write_shard_map(path: &Path, addrs: &[SocketAddr]) -> io::Result<()> {
    let mut text = String::new();
    for (i, a) in addrs.iter().enumerate() {
        text.push_str(&format!("{i} {a}\n"));
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)
}

/// Read the shard address map, in shard order.
pub(crate) fn read_shard_map(path: &Path) -> io::Result<Vec<SocketAddr>> {
    let text = std::fs::read_to_string(path)?;
    let bad = |line: &str| {
        io::Error::new(io::ErrorKind::InvalidData, format!("bad shard-map line {line:?}"))
    };
    let mut entries: Vec<(usize, SocketAddr)> = Vec::new();
    for line in text.lines() {
        let (i, a) = line.split_once(' ').ok_or_else(|| bad(line))?;
        entries.push((
            i.parse().map_err(|_| bad(line))?,
            a.parse().map_err(|_| bad(line))?,
        ));
    }
    entries.sort_by_key(|(i, _)| *i);
    Ok(entries.into_iter().map(|(_, a)| a).collect())
}

/// Connect a sharded store client from the shard map, with a
/// rediscover hook that re-reads the map on every reconnect — so when
/// the driver respawns a killed shard process on a fresh port, this
/// client's failover replay lands on the respawned process.
fn open_store(shard_map: &Path) -> io::Result<Box<dyn SuffixStore>> {
    let addrs = read_shard_map(shard_map)?;
    let mut client =
        ShardedClient::connect_with(&addrs, FailoverConfig::default()).map_err(io::Error::from)?;
    let map_path = shard_map.to_path_buf();
    client.set_rediscover(Arc::new(move |i| {
        read_shard_map(&map_path).ok().and_then(|a| a.get(i).copied())
    }));
    Ok(Box::new(client))
}

/// A parked handle from a finished map task, or a fresh connection.
fn store_for_task(park: &StoreSlot, shard_map: &Path) -> io::Result<Box<dyn SuffixStore>> {
    if let Some(s) = park.lock().unwrap().take() {
        return Ok(s);
    }
    open_store(shard_map)
}

// ---------------- task execution ----------------

/// Persist `text` as `dir/journal` (tmp+rename so the driver never
/// reads a half-written journal), then kill this whole process without
/// replying — the counter-triggered Finish fault at process level.
fn journal_then_abort(dir: &Path, text: &str) -> ! {
    let tmp = dir.join("journal.tmp");
    // best-effort: if the journal cannot be written the driver simply
    // sees a dead attempt with no recoverable delta
    if std::fs::write(&tmp, text).is_ok() {
        let _ = std::fs::rename(&tmp, dir.join("journal"));
    }
    std::process::abort();
}

fn run_map(spec: &Spec, park: &StoreSlot) -> io::Result<String> {
    let cfg = decode_cfg(spec)?;
    let task_id: usize = spec.get_parse("task")?;
    let dir = PathBuf::from(spec.get("dir")?);
    let split = InputSplit {
        path: Arc::new(PathBuf::from(spec.get("split_path")?)),
        offset: spec.get_parse("split_offset")?,
        bytes: spec.get_parse("split_bytes_n")?,
        records: spec.get_parse("split_records")?,
    };
    let boundaries: Vec<i64> = parse_csv(spec.get("boundaries")?)?;
    let store = store_for_task(park, Path::new(spec.get("shard_map")?))?;
    // fresh per-task ledger: the reply's delta is exactly this task's
    // charges, which the driver replays into the job ledger — NOT
    // HdfsRead, which the driver charges itself (engine parity)
    let ledger = Ledger::new();
    let mut task =
        scheme::make_mapper(&cfg, boundaries.clone(), store, park.clone(), ledger.clone());
    let partitioner = move |key: &[u8]| native::bucket(decode_i64_key(key), &boundaries);
    let mut reader = split.open()?;
    let run = if cfg.conf.fixed_width { run_map_task_fixed } else { run_map_task };
    let (spill, stats) =
        run(task_id, &mut reader, task.as_mut(), &cfg.conf, &partitioner, &ledger, &dir)?;
    let text = encode_map_result(&spill, &stats, &ledger);
    if spec.opt("abort") == Some("1") {
        journal_then_abort(&dir, &text);
    }
    Ok(text)
}

fn run_reduce(spec: &Spec, park: &StoreSlot) -> io::Result<String> {
    let cfg = decode_cfg(spec)?;
    let task_id: usize = spec.get_parse("task")?;
    let dir = PathBuf::from(spec.get("dir")?);
    let sink_path = PathBuf::from(spec.get("sink")?);
    let lcp = spec.opt("lcp").map(PathBuf::from);
    let spills: Vec<SpillFile> =
        spec.all("spill_in").map(decode_spill).collect::<io::Result<_>>()?;
    let store = store_for_task(park, Path::new(spec.get("shard_map")?))?;
    let ledger = Ledger::new();
    let times = Arc::new(TimeSplit::default());
    let mut task = scheme::make_reducer(&cfg, store, ledger.clone(), times, lcp);
    let mut sink = FileSink::create(sink_path)?;
    let run = if cfg.conf.fixed_width { run_reduce_task_fixed } else { run_reduce_task };
    let stats =
        run(task_id, task_id, &spills, task.as_mut(), &mut sink, &cfg.conf, &ledger, &dir)?;
    let file = sink.finish()?;
    // HdfsWrite for `file.bytes` is the driver's charge, like HdfsRead
    let text = encode_reduce_result(&file, &stats, &ledger);
    if spec.opt("abort") == Some("1") {
        journal_then_abort(&dir, &text);
    }
    Ok(text)
}

// ---------------- the RESP service ----------------

struct WorkerService {
    /// Worker-global park slot: the first finished map task parks its
    /// store handle here; a later task (or none) reuses it. Mirrors the
    /// in-process pipeline's one-handle-per-task discipline.
    park: StoreSlot,
}

struct WorkerHandler {
    park: StoreSlot,
}

impl RespService for WorkerService {
    fn handler(&self) -> Box<dyn RespHandler> {
        Box::new(WorkerHandler { park: self.park.clone() })
    }
}

/// Run one task body, converting a panic (e.g. the mapper's "KV put
/// failed" after shard failover is exhausted) into a clean RESP error
/// the driver turns into a failed attempt.
fn run_caught(
    f: impl FnOnce() -> io::Result<String> + std::panic::UnwindSafe,
    what: &str,
) -> Value {
    match catch_unwind(f) {
        Ok(Ok(body)) => Value::Bulk(body.into_bytes()),
        Ok(Err(e)) => Value::Error(format!("ERR {what} failed: {e}")),
        Err(p) => {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Value::Error(format!("ERR {what} panicked: {msg}"))
        }
    }
}

impl RespHandler for WorkerHandler {
    fn handle(&mut self, args: &[Vec<u8>], reply: &mut Vec<u8>) -> io::Result<u64> {
        let cmd = args.first().map(|a| a.to_ascii_uppercase()).unwrap_or_default();
        let spec_of = |args: &[Vec<u8>]| -> io::Result<Spec> {
            let raw = args.get(1).ok_or_else(|| {
                io::Error::new(io::ErrorKind::InvalidData, "task command without a spec")
            })?;
            let text = std::str::from_utf8(raw).map_err(|_| {
                io::Error::new(io::ErrorKind::InvalidData, "task spec is not UTF-8")
            })?;
            Spec::parse(text)
        };
        let v = match cmd.as_slice() {
            b"PING" => Value::Simple("PONG".into()),
            b"MAP" => match spec_of(args) {
                Ok(spec) => {
                    let park = self.park.clone();
                    run_caught(AssertUnwindSafe(move || run_map(&spec, &park)), "map task")
                }
                Err(e) => Value::Error(format!("ERR bad spec: {e}")),
            },
            b"REDUCE" => match spec_of(args) {
                Ok(spec) => {
                    let park = self.park.clone();
                    run_caught(AssertUnwindSafe(move || run_reduce(&spec, &park)), "reduce task")
                }
                Err(e) => Value::Error(format!("ERR bad spec: {e}")),
            },
            other => Value::Error(format!(
                "ERR unknown worker command {:?}",
                String::from_utf8_lossy(other)
            )),
        };
        resp::write_value(reply, &v)?;
        Ok(v.wire_len())
    }
}

/// Bind a worker server on `127.0.0.1:port` (0 = ephemeral). The
/// `samr worker` subcommand prints the bound address and parks on this.
pub fn serve(port: u16) -> io::Result<RespServer> {
    let service = Arc::new(WorkerService { park: Arc::new(Mutex::new(None)) });
    RespServer::start(port, 0, None, service)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrips_including_repeated_keys() {
        let mut s = Spec::new();
        s.push("task", "7");
        s.push("spill_in", "a\t1\t0:1:2");
        s.push("spill_in", "b\t2\t3:4:5");
        s.push("dir", "/tmp/x y/z"); // spaces in values survive
        let back = Spec::parse(&s.encode()).unwrap();
        assert_eq!(back.get_parse::<usize>("task").unwrap(), 7);
        assert_eq!(back.all("spill_in").count(), 2);
        assert_eq!(back.get("dir").unwrap(), "/tmp/x y/z");
        assert!(back.opt("absent").is_none());
        assert!(back.get("absent").is_err());
    }

    #[test]
    fn cfg_roundtrip_is_exact_including_floats() {
        let cfg = SchemeConfig {
            conf: JobConf {
                n_reducers: 5,
                io_sort_bytes: 12345,
                spill_percent: 0.811111117,
                shuffle_merge_percent: 0.66000000001,
                ..JobConf::scaled_down()
            },
            prefix_len: 21,
            group_threshold: 4242,
            write_suffixes: false,
            prefetch: false,
            seed: 99,
            ..SchemeConfig::default()
        };
        let mut spec = Spec::new();
        encode_cfg(&mut spec, &cfg);
        let back = decode_cfg(&Spec::parse(&spec.encode()).unwrap()).unwrap();
        assert_eq!(back.prefix_len, 21);
        assert_eq!(back.group_threshold, 4242);
        assert!(!back.write_suffixes && !back.prefetch);
        assert_eq!(back.seed, 99);
        assert_eq!(back.conf.n_reducers, 5);
        assert_eq!(back.conf.io_sort_bytes, 12345);
        // exact bit equality: the spill trigger must compute identically
        assert_eq!(back.conf.spill_percent.to_bits(), cfg.conf.spill_percent.to_bits());
        assert_eq!(
            back.conf.shuffle_merge_percent.to_bits(),
            cfg.conf.shuffle_merge_percent.to_bits()
        );
        assert_eq!(back.conf.spill_trigger(), cfg.conf.spill_trigger());
        // worker-side conf is single-attempt and unplanned by design
        assert_eq!(back.conf.max_task_attempts, 1);
        assert!(back.conf.faults.is_none());
        assert_eq!(back.conf.fixed_width, cfg.fixed_shuffle);
    }

    #[test]
    fn map_and_reduce_results_roundtrip() {
        let ledger = Ledger::new();
        ledger.add(crate::footprint::Channel::MapLocalWrite, 111);
        ledger.add(crate::footprint::Channel::KvPut, 222);
        let spill = SpillFile {
            path: PathBuf::from("/tmp/samr-x/map-3"),
            segments: vec![
                Segment { offset: 0, bytes: 10, records: 2 },
                Segment { offset: 10, bytes: 0, records: 0 },
            ],
            bytes: 10,
        };
        let stats = MapTaskStats {
            input_records: 1,
            input_bytes: 2,
            output_records: 3,
            output_bytes: 4,
            spills: 5,
        };
        let text = encode_map_result(&spill, &stats, &ledger);
        let (s2, st2, delta) = parse_map_result(&text).unwrap();
        assert_eq!(s2.path, spill.path);
        assert_eq!(s2.bytes, 10);
        assert_eq!(s2.segments.len(), 2);
        assert_eq!(s2.segments[0].bytes, 10);
        assert_eq!(s2.segments[1].records, 0);
        assert_eq!(st2.spills, 5);
        // delta is in CHANNELS order: MapLocalWrite is slot 3, KvPut 7
        assert_eq!(delta[3], 111);
        assert_eq!(delta[7], 222);
        assert_eq!(delta.iter().sum::<u64>(), 333);

        let file = OutputFile { path: PathBuf::from("/tmp/out/part-00001"), bytes: 77, records: 9 };
        let rstats = ReduceTaskStats {
            shuffled_bytes: 1,
            shuffled_records: 2,
            disk_segments: 3,
            mem_merges: 4,
            merge_rounds_bytes: 5,
            groups: 6,
            max_group: 7,
            output_records: 8,
            output_bytes: 9,
        };
        let text = encode_reduce_result(&file, &rstats, &ledger);
        let (f2, rs2, delta2) = parse_reduce_result(&text).unwrap();
        assert_eq!(f2.path, file.path);
        assert_eq!(f2.bytes, 77);
        assert_eq!(f2.records, 9);
        assert_eq!(rs2.max_group, 7);
        assert_eq!(rs2.output_bytes, 9);
        assert_eq!(delta2, delta);
    }

    #[test]
    fn shard_map_roundtrips_and_is_atomic_under_rewrite() {
        let dir = std::env::temp_dir().join(format!("samr-shardmap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shards");
        let a: Vec<SocketAddr> =
            vec!["127.0.0.1:6001".parse().unwrap(), "127.0.0.1:6002".parse().unwrap()];
        write_shard_map(&path, &a).unwrap();
        assert_eq!(read_shard_map(&path).unwrap(), a);
        // rewrite with one replaced address — a reader sees old or new,
        // never a mix (rename is atomic); after the rewrite, new
        let b: Vec<SocketAddr> =
            vec!["127.0.0.1:6001".parse().unwrap(), "127.0.0.1:7777".parse().unwrap()];
        write_shard_map(&path, &b).unwrap();
        assert_eq!(read_shard_map(&path).unwrap(), b);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! The cluster layer: the *simulated* cluster below — Table II's 16
//! physical nodes, their disks and memory, the YARN slot arithmetic of
//! §II, and Gigabit Ethernet — plus the *real* multi-process mode:
//! [`driver`] spawns and supervises `samr worker` / `samr shard` OS
//! processes, [`worker`] is the task-executor those processes run.

pub mod driver;
pub mod worker;

use crate::util::bytes::GB;
#[cfg(test)]
use crate::util::bytes::TB;

/// One physical node.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub name: String,
    pub cpu: &'static str,
    pub ghz: f64,
    /// Hardware threads.
    pub threads: u32,
    /// YARN vcores donated (paper default: 8).
    pub vcores: u32,
    pub memory: u64,
    pub disk: u64,
}

/// The cluster: nodes + fabric.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
    /// Per-node NIC bandwidth (bits/s). Paper: Gigabit Ethernet.
    pub net_bps: f64,
    /// Per-disk sequential bandwidth (bytes/s).
    pub disk_read_bps: f64,
    pub disk_write_bps: f64,
    /// YARN memory per node (paper: 16 GB + 1 GB AM).
    pub yarn_memory_per_node: u64,
}

impl ClusterSpec {
    /// Table II: 10× E5620 2.40GHz + 6× E5-2620 2.00GHz; memory
    /// 48 GB×5 / 96 GB×3 / 128 GB×8; disks 825 GB×4 / 870 GB / 1.61 TB×7
    /// / 3.22 TB×4; 128 VCores and 256 GB managed by YARN; 1 GbE.
    pub fn table2() -> ClusterSpec {
        let mut nodes = Vec::new();
        let mem_plan: Vec<u64> = [vec![48 * GB; 5], vec![96 * GB; 3], vec![128 * GB; 8]].concat();
        let disk_plan: Vec<u64> = [
            vec![825 * GB; 4],
            vec![870 * GB; 1],
            vec![1_610 * GB; 7],
            vec![3_220 * GB; 4],
        ]
        .concat();
        for i in 0..16 {
            let (cpu, ghz, threads) = if i < 10 {
                ("E5620", 2.40, 8)
            } else {
                ("E5-2620", 2.00, 12)
            };
            nodes.push(NodeSpec {
                name: format!("node{i:02}"),
                cpu,
                ghz,
                threads,
                vcores: 8,
                memory: mem_plan[i],
                disk: disk_plan[i],
            });
        }
        ClusterSpec {
            nodes,
            net_bps: 1e9,
            // 7.2k SATA-era disks, matching the paper's vintage
            disk_read_bps: 150e6,
            disk_write_bps: 120e6,
            yarn_memory_per_node: 16 * GB,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn total_vcores(&self) -> u32 {
        self.nodes.iter().map(|n| n.vcores).sum()
    }

    pub fn total_yarn_memory(&self) -> u64 {
        self.yarn_memory_per_node * self.nodes.len() as u64
    }

    pub fn total_disk(&self) -> u64 {
        self.nodes.iter().map(|n| n.disk).sum()
    }

    pub fn min_node_disk(&self) -> u64 {
        self.nodes.iter().map(|n| n.disk).min().unwrap_or(0)
    }

    /// Aggregate network bandwidth in bytes/s.
    pub fn agg_net_bytes_per_sec(&self) -> f64 {
        self.net_bps / 8.0 * self.nodes.len() as f64
    }

    pub fn agg_disk_read(&self) -> f64 {
        self.disk_read_bps * self.nodes.len() as f64
    }

    pub fn agg_disk_write(&self) -> f64 {
        self.disk_write_bps * self.nodes.len() as f64
    }

    /// §II slot arithmetic: with `map_mem` and `reduce_mem` containers,
    /// how many of each can run concurrently per node?
    pub fn slots_per_node(&self, map_mem: u64, reduce_mem: u64, n_reducers_share: u64) -> (u64, u64) {
        // the paper reserves 1 GB for the AM and packs e.g. 8 mappers +
        // 2 reducers into 16 GB + 1 GB
        let budget = self.yarn_memory_per_node;
        let reducers = n_reducers_share.min(budget / reduce_mem.max(1));
        let mappers = (budget - reducers * reduce_mem) / map_mem.max(1);
        (mappers, reducers)
    }

    /// Extra per-node memory the scheme's KV instance needs for `bytes`
    /// of total stored data (§IV-D: ~1.5× input / n_nodes).
    pub fn kv_donation_per_node(&self, input_bytes: u64) -> u64 {
        (input_bytes as f64 * 1.5 / self.nodes.len() as f64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_totals() {
        let c = ClusterSpec::table2();
        assert_eq!(c.n_nodes(), 16);
        assert_eq!(c.total_vcores(), 128);
        assert_eq!(c.total_yarn_memory(), 256 * GB);
        // 28.24 TB of disk (paper's figure, decimal units)
        let disk_tb = c.total_disk() as f64 / TB as f64;
        assert!((disk_tb - 28.24).abs() < 0.15, "disk={disk_tb} TB");
        // CPU mix
        assert_eq!(c.nodes.iter().filter(|n| n.cpu == "E5620").count(), 10);
        assert_eq!(c.nodes.iter().filter(|n| n.cpu == "E5-2620").count(), 6);
    }

    #[test]
    fn paper_slot_arithmetic() {
        // §II: "at most, 8 mappers and 2 reducers can run concurrently"
        // with 2 GB mappers and 8 GB reducers less the AM gigabyte —
        // the 16 GB budget femains after the donated AM memory.
        let c = ClusterSpec::table2();
        let (mappers, reducers) = c.slots_per_node(2 * GB, 8 * GB, 2);
        assert_eq!(reducers, 2);
        assert_eq!(mappers, 0); // 16 = 2*8: nothing left -> paper donates +1 GB
        let (mappers, _) = c.slots_per_node(2 * GB, 8 * GB, 0);
        assert_eq!(mappers, 8);
    }

    #[test]
    fn kv_donation_matches_paper() {
        // §IV-D: 32 GB input -> 48 GB across 16 instances = 3 GB/node...
        // the paper says "donate the extra 4 GB" counting rounding slack.
        let c = ClusterSpec::table2();
        let per_node = c.kv_donation_per_node(32 * GB);
        assert_eq!(per_node, 3 * GB);
    }
}

//! The TeraSort baseline (§III): materialize *every suffix* and sort them
//! with MapReduce — "keeping every suffix in place".
//!
//! Faithful to the paper's setup: the suffix files are generated first
//! (outside the timed job); TeraSort's records carry the **full suffix
//! text** as value with the **first 10 characters** as the grouping key,
//! so the shuffle and the local disks bear the ~100× self-expanded data,
//! and reducers in-memory-sort every same-prefix group (the GC stress).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::footprint::Ledger;
use crate::mapreduce::engine::{run_job, Job, JobResult, ScratchDir};
use crate::mapreduce::io::SplitWriter;
use crate::mapreduce::job::JobConf;
use crate::mapreduce::partitioner::{RangePartitioner, SAMPLES_PER_REDUCER};
use crate::mapreduce::record::Record;
use crate::suffix::encode::pack_index;
use crate::suffix::reads::Read;
use crate::util::rng::Rng;

/// TeraSort groups suffixes by their first 10 characters (§III).
pub const KEY_BYTES: usize = 10;

#[derive(Clone, Debug)]
pub struct TeraSortConfig {
    pub conf: JobConf,
    pub samples_per_reducer: usize,
    pub seed: u64,
}

impl Default for TeraSortConfig {
    fn default() -> Self {
        Self { conf: JobConf::scaled_down(), samples_per_reducer: SAMPLES_PER_REDUCER, seed: 1 }
    }
}

pub struct TeraSortResult {
    pub job: JobResult,
    /// Materialized suffix bytes (the job's input, the paper's "1 unit").
    pub suffix_input_bytes: u64,
    /// Largest same-key sorting group (records) any reducer held — the
    /// §III GC-stress metric.
    pub max_group_records: u64,
    /// Largest in-memory group bytes.
    pub max_group_bytes: u64,
    /// Output suffix order (packed indexes) for validation.
    pub order: Vec<i64>,
}

/// 10-byte grouping key of a suffix (codes, 0-padded like the terminator).
pub fn group_key(read: &Read, offset: usize) -> Vec<u8> {
    let mut k = vec![0u8; KEY_BYTES];
    let tail = &read.codes[offset.min(read.len())..];
    for (dst, &c) in k.iter_mut().zip(tail) {
        *dst = c;
    }
    k
}

/// One suffix record: key = 10-char prefix, value = packed index (8 B)
/// + full suffix text.
fn suffix_record(read: &Read, off: usize) -> Record {
    let mut value = pack_index(read.seq, off).to_be_bytes().to_vec();
    value.extend_from_slice(&read.codes[off..]);
    Record::new(group_key(read, off), value)
}

/// Materialize the suffix records of a corpus in memory. [`run`] no
/// longer does this — it spools the records straight to disk-backed
/// split files — but tests and benches still use the resident form.
pub fn materialize_suffixes(reads: &[Read]) -> Vec<Record> {
    let mut out = Vec::new();
    for r in reads {
        for off in 0..=r.len() {
            out.push(suffix_record(r, off));
        }
    }
    out
}

/// Sample suffix keys for the range partitioner (10000 × n, §IV-A).
pub fn sample_keys(reads: &[Read], n_samples: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    let mut samples = Vec::with_capacity(n_samples);
    if reads.is_empty() {
        return samples;
    }
    for _ in 0..n_samples {
        let r = &reads[rng.below(reads.len() as u64) as usize];
        let off = rng.below(r.suffix_count() as u64) as usize;
        samples.push(group_key(r, off));
    }
    samples
}

/// Run the baseline on a corpus. The returned footprint covers the sort
/// job only (suffix generation is excluded, as in Table III).
pub fn run(reads: &[Read], cfg: &TeraSortConfig, ledger: &Arc<Ledger>) -> std::io::Result<TeraSortResult> {
    // generate the self-expanded suffix records straight into
    // disk-backed split files — the paper writes its suffix files to
    // HDFS before the timed job, and like there, the ~100x expanded
    // volume never lives in memory
    let spool = ScratchDir::new(cfg.conf.spill_dir.as_deref(), "terasort-in")?;
    let mut w = SplitWriter::create(spool.path.join("suffixes"), cfg.conf.split_bytes)?;
    for r in reads {
        for off in 0..=r.len() {
            w.push(&suffix_record(r, off))?;
        }
    }
    let suffix_input_bytes: u64 = w.bytes();
    let splits = w.finish()?;

    let samples = sample_keys(reads, cfg.samples_per_reducer * cfg.conf.n_reducers, cfg.seed);
    let partitioner = Arc::new(RangePartitioner::from_samples(samples, cfg.conf.n_reducers));

    let max_group_records = Arc::new(AtomicU64::new(0));
    let max_group_bytes = Arc::new(AtomicU64::new(0));
    let mg_r = max_group_records.clone();
    let mg_b = max_group_bytes.clone();

    let job = Job {
        name: "terasort".into(),
        conf: cfg.conf.clone(),
        // identity map: suffixes already materialized
        map_factory: Arc::new(|_| {
            Box::new(|rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone()))
        }),
        // reduce: in-memory sort of each same-prefix group by full suffix
        // text (then index), the paper's heap-stressing step
        reduce_factory: Arc::new(move |_| {
            let mg_r = mg_r.clone();
            let mg_b = mg_b.clone();
            Box::new(
                move |key: &[u8], mut vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                    let bytes: u64 = vals.iter().map(|v| v.len() as u64).sum();
                    mg_r.fetch_max(vals.len() as u64, Ordering::Relaxed);
                    mg_b.fetch_max(bytes, Ordering::Relaxed);
                    // values are index(8B) + suffix text; sort by (text, index)
                    vals.sort_unstable_by(|a, b| a[8..].cmp(&b[8..]).then(a[..8].cmp(&b[..8])));
                    for v in vals {
                        out(Record::new(key.to_vec(), v));
                    }
                },
            )
        }),
        partitioner: partitioner.as_fn(),
    };

    let result = run_job(&job, splits, ledger)?;
    drop(spool); // input consumed; release the spooled suffix files
    // stream the order out of the per-reducer output sinks
    let order = result.collect_i64_values()?;
    Ok(TeraSortResult {
        job: result,
        suffix_input_bytes,
        max_group_records: max_group_records.load(Ordering::Relaxed),
        max_group_bytes: max_group_bytes.load(Ordering::Relaxed),
        order,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::footprint::Channel;
    use crate::suffix::reads::{synth_corpus, CorpusSpec};
    use crate::suffix::validate::validate_order;

    fn small_corpus(n: usize, len: usize) -> Vec<Read> {
        synth_corpus(&CorpusSpec {
            n_reads: n,
            read_len: len,
            len_jitter: 2,
            genome_len: 4096, // small genome -> repeated suffixes (GC stress)
            ..Default::default()
        })
    }

    #[test]
    fn materialization_self_expands() {
        let reads = small_corpus(50, 60);
        let suffixes = materialize_suffixes(&reads);
        assert_eq!(
            suffixes.len(),
            reads.iter().map(|r| r.suffix_count()).sum::<usize>()
        );
        let input: u64 = reads.iter().map(|r| r.record_bytes()).sum();
        let expanded: u64 = suffixes.iter().map(|r| r.wire_bytes()).sum();
        // ~len/2 expansion (plus framing): must be much larger than input
        assert!(expanded > input * 10, "expanded={expanded} input={input}");
    }

    #[test]
    fn produces_valid_suffix_order() {
        let reads = small_corpus(40, 30);
        let ledger = Ledger::new();
        let cfg = TeraSortConfig {
            conf: JobConf {
                n_reducers: 4,
                split_bytes: 8 << 10,
                io_sort_bytes: 8 << 10,
                reducer_heap_bytes: 64 << 10,
                ..JobConf::default()
            },
            ..Default::default()
        };
        let res = run(&reads, &cfg, &ledger).unwrap();
        validate_order(&reads, &res.order).expect("terasort order invalid");
        assert!(res.max_group_records >= 1);
        // shuffle carried the full self-expanded suffix volume
        let shuffled = res.job.footprint.get(Channel::Shuffle);
        assert_eq!(shuffled, res.suffix_input_bytes);
    }

    #[test]
    fn repeated_genome_creates_big_groups() {
        // highly repetitive corpus -> same 10-char prefixes group together
        let mut reads = Vec::new();
        for i in 0..30u64 {
            reads.push(Read::from_ascii(i, b"ATATATATATATATATATAT"));
        }
        let ledger = Ledger::new();
        let cfg = TeraSortConfig {
            conf: JobConf { n_reducers: 2, ..JobConf::default() },
            ..Default::default()
        };
        let res = run(&reads, &cfg, &ledger).unwrap();
        validate_order(&reads, &res.order).expect("order invalid");
        // identical reads: every suffix text repeats 30x; groups pile up
        assert!(res.max_group_records >= 30, "max_group={}", res.max_group_records);
    }
}

//! Regeneration of every table and figure in the paper's evaluation.
//! Each `table_*`/`figure_*` function runs the scaled experiments and
//! returns the rendered artifact (the CLI and the benches print them).

pub mod experiments;
pub mod render;

use crate::cluster::ClusterSpec;
use crate::footprint::model::{efficiency, ScalabilityModel, ScalePoint};
use crate::mapreduce::merge::merge_round_plan;
use crate::simcost::CostParams;
use crate::suffix::encode;
use crate::util::bytes::{human, TB};
use experiments::{
    paper_times_table3, paper_times_table5, run_scheme_case, run_terasort_case, table3_inputs,
    table5_inputs, CaseRow, ScaledEnv, TeraVariant,
};
use render::{chart, footprint_table, kv_block, Series};

/// Everything needed to run the reproduction suite.
pub struct Reporter {
    pub env: ScaledEnv,
    pub cluster: ClusterSpec,
    pub params: CostParams,
}

impl Default for Reporter {
    fn default() -> Self {
        Self {
            env: ScaledEnv::default(),
            cluster: ClusterSpec::table2(),
            params: CostParams::default(),
        }
    }
}

impl Reporter {
    pub fn quick() -> Self {
        Self { env: ScaledEnv { thrift: 8.0, trials: 5, ..Default::default() }, ..Default::default() }
    }

    // ---------------- tables ----------------

    /// Table I: the didactic SINICA$ suffix array.
    pub fn table1(&self) -> String {
        let text = b"SINICA";
        let sa = crate::suffix::sa::sais(&text.map(|c| c)); // bytes as-is; '$' implicit
        let mut pairs = Vec::new();
        let n = text.len();
        // row 0 is the implicit '$' suffix
        pairs.push(("0".to_string(), format!("SA[0] = {n}  suffix = $")));
        for (i, &p) in sa.iter().enumerate() {
            let suffix: String =
                text[p as usize..].iter().map(|&c| c as char).chain(['$']).collect();
            pairs.push((format!("{}", i + 1), format!("SA[{}] = {p}  suffix = {suffix}", i + 1)));
        }
        kv_block("Table I — Suffix Array of SINICA$", &pairs)
    }

    /// Table II: the simulated cluster inventory.
    pub fn table2(&self) -> String {
        let c = &self.cluster;
        let mut pairs = vec![
            ("Nodes".to_string(), c.n_nodes().to_string()),
            ("VCores (YARN)".to_string(), c.total_vcores().to_string()),
            ("Memory (YARN)".to_string(), human(c.total_yarn_memory())),
            ("Disk".to_string(), human(c.total_disk())),
            ("Network".to_string(), format!("{:.0} Gb/s per node", c.net_bps / 1e9)),
        ];
        for cpu in ["E5620", "E5-2620"] {
            let n = c.nodes.iter().filter(|nd| nd.cpu == cpu).count();
            pairs.push((format!("CPU {cpu}"), format!("{n} nodes")));
        }
        kv_block("Table II — Cluster resources", &pairs)
    }

    /// Table III: TeraSort footprint across the five input sizes.
    pub fn table3_rows(&self) -> std::io::Result<Vec<CaseRow>> {
        table3_inputs()
            .iter()
            .map(|(label, input)| {
                run_terasort_case(
                    label,
                    *input,
                    &TeraVariant::baseline(),
                    &self.env,
                    &self.cluster,
                    &self.params,
                )
            })
            .collect()
    }

    pub fn table3(&self) -> std::io::Result<String> {
        let rows = self.table3_rows()?;
        Ok(footprint_table(
            "Table III — TeraSort data store footprint (32 reducers)",
            &rows,
            Some(&paper_times_table3()),
            false,
        ))
    }

    /// Table IV: TeraSort with 10 GB reducers at 3.95 TB.
    pub fn table4(&self) -> std::io::Result<String> {
        let row = run_terasort_case(
            "3.95 TB",
            (3.95 * TB as f64) as u64,
            &TeraVariant::table4(),
            &self.env,
            &self.cluster,
            &self.params,
        )?;
        Ok(footprint_table(
            "Table IV — TeraSort, 10 GB reducers (9 GB heap)",
            &[row],
            Some(&[(835.6, 67.95, false)]),
            false,
        ))
    }

    /// Table V: the scheme's footprint across six cases (6 = pair-end,
    /// executed as a genuine two-input-file workload).
    pub fn table5_rows(&self) -> std::io::Result<Vec<CaseRow>> {
        table5_inputs()
            .iter()
            .map(|(label, input, workload)| {
                run_scheme_case(label, *input, *workload, &self.env, &self.cluster, &self.params)
            })
            .collect()
    }

    pub fn table5(&self) -> std::io::Result<String> {
        let rows = self.table5_rows()?;
        Ok(footprint_table(
            "Table V — Scheme data store footprint (32 reducers, incl. suffix generation)",
            &rows,
            Some(&paper_times_table5()),
            true,
        ))
    }

    /// Table VI: mem_heap variant.
    pub fn table6_rows(&self) -> std::io::Result<Vec<CaseRow>> {
        table3_inputs()
            .iter()
            .map(|(label, input)| {
                run_terasort_case(
                    label,
                    *input,
                    &TeraVariant::mem_heap(),
                    &self.env,
                    &self.cluster,
                    &self.params,
                )
            })
            .collect()
    }

    pub fn table6(&self) -> std::io::Result<String> {
        let rows = self.table6_rows()?;
        Ok(footprint_table(
            "Table VI — mem_heap: 32 reducers × 15 GB heap",
            &rows,
            Some(&[
                (66.6, 7.30, true),
                (141.0, 11.22, true),
                (185.4, 11.48, true),
                (289.4, 15.04, true),
                (425.2, 13.55, true),
            ]),
            false,
        ))
    }

    /// Table VII: mem_reducer variant.
    pub fn table7_rows(&self) -> std::io::Result<Vec<CaseRow>> {
        table3_inputs()
            .iter()
            .map(|(label, input)| {
                run_terasort_case(
                    label,
                    *input,
                    &TeraVariant::mem_reducer(),
                    &self.env,
                    &self.cluster,
                    &self.params,
                )
            })
            .collect()
    }

    pub fn table7(&self) -> std::io::Result<String> {
        let rows = self.table7_rows()?;
        Ok(footprint_table(
            "Table VII — mem_reducer: 64 reducers × 7 GB heap",
            &rows,
            Some(&[
                (46.8, 3.56, true),
                (100.0, 0.70, true),
                (156.6, 2.41, true),
                (242.8, 7.53, true),
                (365.8, 13.83, false),
            ]),
            false,
        ))
    }

    /// Table VIII: efficiency = speedup / mem_ratio for Cases 1–4.
    pub fn table8(&self) -> std::io::Result<String> {
        let base = self.table3_rows()?;
        let heap = self.table6_rows()?;
        let red = self.table7_rows()?;
        let scheme = self.table5_rows()?;
        let mut s = String::from("== Table VIII — efficiency = speedup / mem_ratio ==\n");
        s.push_str(&format!(
            "{:<14}{:>10}{:>10}{:>10}{:>10}\n",
            "", "Case 1", "Case 2", "Case 3", "Case 4"
        ));
        let yarn = self.cluster.total_yarn_memory() as f64;
        let row = |name: &str, variant: &[CaseRow], ratios: &dyn Fn(usize) -> f64| {
            let mut l = format!("{name:<14}");
            for i in 0..4 {
                let e = efficiency(
                    base[i].time.minutes.mu,
                    variant[i].time.minutes.mu,
                    ratios(i),
                );
                l.push_str(&format!("{:>9.1}%", e * 100.0));
            }
            l.push('\n');
            l
        };
        s.push_str(&row("mem_heap", &heap, &|_| 2.0));
        s.push_str(&row("mem_reducer", &red, &|_| 2.0));
        s.push_str(&row("our scheme", &scheme, &|i| {
            let kv = experiments::paper_kv_memory(table5_inputs()[i].1) as f64;
            (yarn + kv) / yarn
        }));
        s.push_str("paper:        mem_heap 46.4/50.9/62.1/53.9  mem_reducer 66.0/63.5/74.0/64.3  scheme 95.5/140.0/141.1/134.5\n");
        Ok(s)
    }

    // ---------------- figures ----------------

    /// Figure 3: map-side spill mechanics (128 MB split, 80 MB trigger).
    pub fn figure3(&self) -> std::io::Result<String> {
        let rows = self.table3_rows()?;
        let r = &rows[0];
        Ok(kv_block(
            "Figure 3 — Map-side local I/O (per unit of input)",
            &[
                ("split / spill-trigger".into(), format!("{} / {}", human(self.env.split), human(self.env.conf().spill_trigger()))),
                ("spills per mapper".into(), "2 (split ≈ 1.6 × trigger)".into()),
                ("Local Read".into(), format!("{:.2} (paper 1.03)", r.map_lr)),
                ("Local Write".into(), format!("{:.2} (paper 2.07)", r.map_lw)),
            ],
        ))
    }

    /// Figure 4: reduce-side merge mechanics and the Case-5 estimate.
    pub fn figure4(&self) -> String {
        let mut pairs = Vec::new();
        // the paper's worked example: 35 spilled files, factor 10
        let plan = merge_round_plan(35, 10);
        pairs.push((
            "35 files, factor 10".into(),
            format!("merge {} files in {} groups -> 10 remain", plan.iter().sum::<usize>(), plan.len()),
        ));
        let merged: usize = plan.iter().sum();
        let units = (merged as f64 / 34.06 + 1.0) * 1.03;
        pairs.push((
            "estimated R/W units".into(),
            format!("({merged}/34.06 + 1) × 1.03 = {units:.2} (paper 1.88)"),
        ));
        for files in [6, 12, 20, 35, 60] {
            let p = merge_round_plan(files, 10);
            pairs.push((
                format!("{files} spilled files"),
                if p.is_empty() {
                    "no intermediate round (≤ factor)".into()
                } else {
                    format!("{} merged in round 1", p.iter().sum::<usize>())
                },
            ));
        }
        kv_block("Figure 4 — Reduce-side merge rounds", &pairs)
    }

    /// Figure 5: TeraSort scalability₁ (time vs input, breakdown at 3.37 TB).
    pub fn figure5(&self) -> std::io::Result<String> {
        let rows = self.table3_rows()?;
        let mut points: Vec<(f64, f64, bool)> = rows
            .iter()
            .map(|r| (r.paper_input as f64 / TB as f64, r.time.minutes.mu, r.time.completed()))
            .collect();
        let t4 = run_terasort_case(
            "3.95 TB",
            (3.95 * TB as f64) as u64,
            &TeraVariant::table4(),
            &self.env,
            &self.cluster,
            &self.params,
        )?;
        let series = vec![
            Series { name: "TeraSort (7 GB heap)".into(), points: points.clone() },
            Series {
                name: "10 GB reducers (Table IV)".into(),
                points: vec![(3.95, t4.time.minutes.mu, t4.time.completed())],
            },
        ];
        points.push((3.95, t4.time.minutes.mu, t4.time.completed()));
        Ok(chart("Figure 5 — Scalability_1 of TeraSort (minutes vs TB)", &series, 60, 16))
    }

    /// Figure 7: prefix length vs sorting-group size on a real corpus.
    pub fn figure7(&self) -> String {
        use std::collections::HashMap;
        let reads = experiments::example_corpus(400, 60, 7);
        let mut pairs = Vec::new();
        for p in [3usize, 5, 8, 13, 23] {
            let mut groups: HashMap<i64, u64> = HashMap::new();
            for r in &reads {
                for off in 0..=r.len() {
                    *groups
                        .entry(encode::suffix_key(&r.codes, off, p))
                        .or_default() += 1;
                }
            }
            let max = groups.values().max().copied().unwrap_or(0);
            let avg = groups.values().sum::<u64>() as f64 / groups.len() as f64;
            pairs.push((
                format!("prefix {p:>2}"),
                format!("{:>6} groups, avg {:>8.2}, max {:>6}", groups.len(), avg, max),
            ));
        }
        pairs.push((
            "rule of thumb".into(),
            "longer prefix -> smaller sorting groups -> less reducer memory".into(),
        ));
        kv_block("Figure 7 — Sorting-group size vs prefix length", &pairs)
    }

    /// Figure 8: scalability of all four variants + f(x)=ax+b fits.
    pub fn figure8(&self) -> std::io::Result<String> {
        let base = self.table3_rows()?;
        let heap = self.table6_rows()?;
        let red = self.table7_rows()?;
        let scheme = self.table5_rows()?;
        let to_points = |rows: &[CaseRow], scale_suffixes: bool| -> Vec<(f64, f64, bool)> {
            rows.iter()
                .map(|r| {
                    let x = if scale_suffixes {
                        // scheme x-axis: suffix volume of the same data
                        r.paper_input as f64 * 107.0 / TB as f64
                    } else {
                        r.paper_input as f64 / TB as f64
                    };
                    (x, r.time.minutes.mu, r.time.completed())
                })
                .collect()
        };
        let series = vec![
            Series { name: "TeraSort".into(), points: to_points(&base, false) },
            Series { name: "mem_heap".into(), points: to_points(&heap, false) },
            Series { name: "mem_reducer".into(), points: to_points(&red, false) },
            Series { name: "scheme".into(), points: to_points(&scheme, true) },
        ];
        let mut out = chart("Figure 8 — Scalability_{1,2} (minutes vs TB of suffixes)", &series, 60, 18);
        for sr in &series {
            let pts: Vec<ScalePoint> = sr
                .points
                .iter()
                .map(|&(x, m, ok)| ScalePoint { x, minutes: m, sigma: 0.0, completed: ok })
                .collect();
            let m = ScalabilityModel::fit(&pts);
            out.push_str(&format!(
                "fit {:<12} a={:>7.2} min/TB  b={:>7.2} min  r2={:.3}  breakdown={}\n",
                sr.name,
                m.a,
                m.b,
                m.r2,
                m.breakdown.map(|b| format!("{b:.2} TB")).unwrap_or_else(|| "none".into()),
            ));
        }
        Ok(out)
    }

    /// §IV-D analysis block: time split, KV overhead, headline ratios.
    pub fn scheme_stats(&self) -> std::io::Result<String> {
        let tera = self.table3_rows()?;
        let scheme = self.table5_rows()?;
        let t1 = &tera[0];
        let s1 = &scheme[0];
        Ok(kv_block(
            "Scheme vs TeraSort — headline ratios (Case 1)",
            &[
                (
                    "Map local write".into(),
                    format!("{:.2} -> {:.2} units (paper 2.07 -> 0.45)", t1.map_lw, s1.map_lw),
                ),
                (
                    "Reduce local R/W".into(),
                    format!("{:.2} -> {:.2} units (paper 1.03 -> 0.16)", t1.red_lr, s1.red_lr),
                ),
                (
                    "Shuffle".into(),
                    format!("{:.2} -> {:.2} units (paper 1.03 -> 0.16)", t1.shuffle, s1.shuffle),
                ),
                (
                    "KV memory overhead".into(),
                    format!("1.5x input (paper: 48 GB for 32 GB)"),
                ),
                (
                    "TeraSort breakdown".into(),
                    format!(
                        "{}",
                        tera.iter()
                            .find(|r| !r.time.completed())
                            .map(|r| format!("{} ({})", r.label, human(r.paper_input)))
                            .unwrap_or_else(|| "none observed".into())
                    ),
                ),
                (
                    "Scheme breakdown".into(),
                    scheme
                        .iter()
                        .find(|r| !r.time.completed())
                        .map(|r| r.label.clone())
                        .unwrap_or_else(|| "none (incl. pair-end Case 6)".into()),
                ),
            ],
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let r = Reporter::quick();
        let t = r.table1();
        assert!(t.contains("SA[1] = 5"), "{t}");
        assert!(t.contains("suffix = A$"));
        assert!(t.contains("SA[6] = 0"));
    }

    #[test]
    fn table2_renders() {
        let t = Reporter::quick().table2();
        assert!(t.contains("VCores"));
        assert!(t.contains("128"));
    }

    #[test]
    fn figure4_reproduces_case5_estimate() {
        let f = Reporter::quick().figure4();
        assert!(f.contains("28 files in 3 groups"), "{f}");
        assert!(f.contains("1.88"));
    }

    #[test]
    fn figure7_group_sizes_shrink() {
        let f = Reporter::quick().figure7();
        assert!(f.contains("prefix  3"));
        assert!(f.contains("prefix 23"));
    }
}

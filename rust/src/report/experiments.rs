//! Paper-case definitions and the scaled execution + projection recipe
//! behind every table/figure (see DESIGN.md §5 experiment index).
//!
//! Method: each paper case (e.g. Table III Case 3, 1.86 TB of suffixes)
//! is re-run at laptop scale with every byte-valued knob shrunk by the
//! same factor, so spill counts and merge rounds — and therefore the
//! normalized footprint ratios — reproduce *mechanically*, not by
//! curve-fitting. The measured ratios are then projected back to paper
//! scale and run through the `simcost` cluster model to recover the
//! Time rows (μ/σ, breakdown).

use std::sync::Arc;

use crate::cluster::ClusterSpec;
use crate::footprint::{Channel, Footprint, Ledger};
use crate::kvstore::shard::{SharedStore, SuffixStore};
use crate::mapreduce::job::JobConf;
use crate::scheme::gc_model::HeapConfig;
use crate::scheme::{self, SchemeConfig};
use crate::simcost::{self, terasort_max_group, CostParams, TimeEstimate, WorkloadShape};
use crate::suffix::reads::{synth_corpus, synth_paired_corpus, CorpusSpec, Read};
use crate::terasort::{self, TeraSortConfig};
use crate::util::bytes::{GB, TB};

/// Paper constants.
pub const PAPER_REDUCERS: u64 = 32;
pub const PAPER_SHUFFLE_BUFFER: f64 = 4.9 * (1u64 << 30) as f64; // 0.7 × 7 GB
pub const PAPER_READ_LEN: usize = 200;

/// Table III / V–VII input sizes (bytes of materialized suffixes for
/// TeraSort; bytes of raw reads for the scheme — same underlying data).
pub fn table3_inputs() -> Vec<(&'static str, u64)> {
    vec![
        ("Case 1", 637_180_000_000),
        ("Case 2", (1.24 * TB as f64) as u64),
        ("Case 3", (1.86 * TB as f64) as u64),
        ("Case 4", (2.49 * TB as f64) as u64),
        ("Case 5", (3.37 * TB as f64) as u64),
    ]
}

/// Input-file shape of a scheme workload (Table V): every case is one
/// single-end file except Case 6, the pair-end case, which is TWO input
/// files over the same fragments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeWorkload {
    /// One input file of single-end reads.
    SingleFile,
    /// Two input files — forward reads + reverse-complement mates of the
    /// same fragments (the paper's Case 6).
    PairEnd,
}

pub fn table5_inputs() -> Vec<(&'static str, u64, SchemeWorkload)> {
    vec![
        ("Case 1", (5.86 * GB as f64) as u64, SchemeWorkload::SingleFile),
        ("Case 2", (11.72 * GB as f64) as u64, SchemeWorkload::SingleFile),
        ("Case 3", (17.57 * GB as f64) as u64, SchemeWorkload::SingleFile),
        ("Case 4", (23.43 * GB as f64) as u64, SchemeWorkload::SingleFile),
        ("Case 5", (31.76 * GB as f64) as u64, SchemeWorkload::SingleFile),
        ("Case 6", (63.12 * GB as f64) as u64, SchemeWorkload::PairEnd),
    ]
}

/// Paper-reported times for reference columns (μ, σ, completed).
pub fn paper_times_table3() -> Vec<(f64, f64, bool)> {
    vec![
        (61.8, 1.30, true),
        (143.4, 4.83, true),
        (230.4, 12.30, true),
        (312.0, 12.65, true),
        (709.4, 95.55, false),
    ]
}

pub fn paper_times_table5() -> Vec<(f64, f64, bool)> {
    vec![
        (63.2, 0.45, true),
        (100.0, 0.71, true),
        (156.6, 2.41, true),
        (205.4, 4.16, true),
        (284.2, 8.38, true),
        (671.0, 12.19, true),
    ]
}

/// The scaled environment: every byte knob ÷ SCALE relative to the paper,
/// reducer count ÷ 4 (8 instead of 32 — execution cost), read length as
/// the paper's 200 bp.
#[derive(Clone, Debug)]
pub struct ScaledEnv {
    pub n_reducers: usize,
    pub reducer_heap: u64,
    pub io_sort: u64,
    pub split: u64,
    pub read_len: usize,
    pub trials: usize,
    pub seed: u64,
    /// Extra shrink on corpus volume (1.0 = ratio-exact; >1 = faster CI).
    pub thrift: f64,
}

impl Default for ScaledEnv {
    fn default() -> Self {
        Self {
            n_reducers: 8,
            reducer_heap: 500 << 10, // buffer 350 KB, merge trigger 231 KB
            io_sort: 24 << 10,
            split: 32 << 10,
            read_len: 200,
            trials: 5,
            seed: 20170101,
            thrift: 1.0,
        }
    }
}

impl ScaledEnv {
    pub fn conf(&self) -> JobConf {
        // thrift shrinks every byte knob by the same factor, so spill
        // counts and merge rounds (which depend only on ratios) survive.
        let t = self.thrift;
        JobConf {
            io_sort_bytes: ((self.io_sort as f64 / t) as u64).max(2 << 10),
            split_bytes: ((self.split as f64 / t) as u64).max(3 << 10),
            n_reducers: self.n_reducers,
            reducer_heap_bytes: ((self.reducer_heap as f64 / t) as u64).max(30 << 10),
            ..JobConf::default()
        }
    }

    fn shuffle_buffer(&self) -> f64 {
        self.conf().shuffle_buffer() as f64
    }

    /// Corpus sized so that per-reducer-shuffle / shuffle-buffer matches
    /// the paper case's ratio (the quantity that drives merge rounds).
    pub fn corpus_for_ratio(&self, paper_per_red_over_buffer: f64, bytes_per_read: f64) -> CorpusSpec {
        // buffer is already thrift-scaled via conf(), so the ratio holds
        let target_total =
            paper_per_red_over_buffer * self.shuffle_buffer() * self.n_reducers as f64;
        CorpusSpec {
            n_reads: (target_total / bytes_per_read).ceil() as usize,
            read_len: self.read_len,
            len_jitter: 4,
            genome_len: 1 << 20,
            seed: self.seed,
            ..Default::default()
        }
    }
}

/// One reproduced row of a footprint table.
#[derive(Clone, Debug)]
pub struct CaseRow {
    pub label: String,
    pub paper_input: u64,
    /// Normalized units, paper-style.
    pub map_lr: f64,
    pub map_lw: f64,
    pub red_lr: f64,
    pub red_lw: f64,
    pub hdfs_r: f64,
    pub hdfs_w: f64,
    pub shuffle: f64,
    pub kv_put: f64,
    pub kv_fetch: f64,
    /// Projected elapsed time on the Table-II cluster.
    pub time: TimeEstimate,
    /// Raw measured footprint + its normalization reference.
    pub measured: Footprint,
    pub reference_bytes: u64,
    /// Scaled corpus actually executed.
    pub mini_reads: usize,
}

fn normalize(fp: &Footprint, reference: u64) -> [f64; 9] {
    let n = |ch| fp.normalized(ch, reference);
    [
        n(Channel::MapLocalRead),
        n(Channel::MapLocalWrite),
        n(Channel::ReduceLocalRead),
        n(Channel::ReduceLocalWrite),
        n(Channel::HdfsRead),
        n(Channel::HdfsWrite),
        n(Channel::Shuffle),
        n(Channel::KvPut),
        n(Channel::KvFetch),
    ]
}

/// TeraSort variant knobs (baseline / mem_heap / mem_reducer / Table IV).
#[derive(Clone, Copy, Debug)]
pub struct TeraVariant {
    pub paper_heap: u64,
    pub paper_reducers: u64,
    pub reduce_slots_per_node: u64,
}

impl TeraVariant {
    pub fn baseline() -> Self {
        Self { paper_heap: 7 * GB, paper_reducers: 32, reduce_slots_per_node: 2 }
    }

    pub fn mem_heap() -> Self {
        Self { paper_heap: 15 * GB, paper_reducers: 32, reduce_slots_per_node: 2 }
    }

    pub fn mem_reducer() -> Self {
        Self { paper_heap: 7 * GB, paper_reducers: 64, reduce_slots_per_node: 4 }
    }

    pub fn table4() -> Self {
        Self { paper_heap: 9 * GB, paper_reducers: 32, reduce_slots_per_node: 2 }
    }
}

/// Average materialized bytes of one read's suffixes (incl. index+framing).
fn suffix_bytes_per_read(read_len: usize) -> f64 {
    let l = read_len as f64;
    // per suffix: 10-byte key + (8B index + avg (l+1)/2 text) value + 8B framing
    (l + 1.0) * (10.0 + 8.0 + 8.0 + (l + 1.0) / 2.0)
}

/// Run one TeraSort paper case at scale and project it.
pub fn run_terasort_case(
    label: &str,
    paper_input: u64,
    variant: &TeraVariant,
    env: &ScaledEnv,
    cluster: &ClusterSpec,
    params: &CostParams,
) -> std::io::Result<CaseRow> {
    // ratio that controls reduce-side merge mechanics
    let paper_per_red = paper_input as f64 * 1.03 / variant.paper_reducers as f64;
    let paper_buffer = PAPER_SHUFFLE_BUFFER * variant.paper_heap as f64 / (7 * GB) as f64;
    let ratio = paper_per_red / paper_buffer;

    // scaled reducers double when the paper variant doubles them
    let mut env = env.clone();
    env.n_reducers = env.n_reducers * variant.paper_reducers as usize / 32;
    env.reducer_heap = env.reducer_heap * variant.paper_heap / (7 * GB);

    let spec = env.corpus_for_ratio(
        ratio * 32.0 / variant.paper_reducers as f64, // per-red ratio at scaled reducer count
        suffix_bytes_per_read(env.read_len),
    );
    let reads = synth_corpus(&spec);

    let ledger = Ledger::new();
    let cfg = TeraSortConfig { conf: env.conf(), samples_per_reducer: 200, seed: env.seed };
    let res = terasort::run(&reads, &cfg, &ledger)?;
    let reference = res.suffix_input_bytes;
    let [map_lr, map_lw, red_lr, red_lw, hdfs_r, hdfs_w, shuffle, kv_put, kv_fetch] =
        normalize(&res.job.footprint, reference);

    // ---- project to paper scale ----
    let mut fp = Footprint::default();
    let scale = paper_input as f64;
    for (ch, v) in [
        (Channel::MapLocalRead, map_lr),
        (Channel::MapLocalWrite, map_lw),
        (Channel::ReduceLocalRead, red_lr),
        (Channel::ReduceLocalWrite, red_lw),
        (Channel::HdfsRead, hdfs_r),
        (Channel::HdfsWrite, hdfs_w),
        (Channel::Shuffle, shuffle),
    ] {
        fp.set(ch, (v * scale) as u64);
    }
    let shape = WorkloadShape {
        n_reducers: variant.paper_reducers,
        per_reducer_shuffle: (paper_input as f64 * 1.03 / variant.paper_reducers as f64) as u64,
        max_group_bytes: terasort_max_group(paper_input),
        numeric_pipeline: false,
        reduce_slots_per_node: variant.reduce_slots_per_node,
    };
    let heap = HeapConfig::paper_terasort(variant.paper_heap);
    let time = simcost::estimate(cluster, params, &fp, &shape, &heap, env.trials, env.seed);

    Ok(CaseRow {
        label: label.to_string(),
        paper_input,
        map_lr,
        map_lw,
        red_lr,
        red_lw,
        hdfs_r,
        hdfs_w,
        shuffle,
        kv_put,
        kv_fetch,
        time,
        measured: res.job.footprint,
        reference_bytes: reference,
        mini_reads: reads.len(),
    })
}

/// Run one scheme paper case (Table V) at scale and project it.
///
/// A [`SchemeWorkload::PairEnd`] case runs as a genuine TWO-input-file
/// workload (the paper's closing claim, Case 6): the corpus is generated
/// as forward + reverse-complement mate files over the same fragments
/// with the fragment-linked pair numbering, and the construction goes
/// through [`scheme::run_files`] — two files, one shared store, one
/// joint index stream. The per-unit normalization is identical either
/// way, which is exactly what lets
/// `case6_pair_end_ratios_match_single_file` check "no degradation"
/// mechanically.
pub fn run_scheme_case(
    label: &str,
    paper_read_input: u64,
    workload: SchemeWorkload,
    env: &ScaledEnv,
    cluster: &ClusterSpec,
    params: &CostParams,
) -> std::io::Result<CaseRow> {
    // paper scheme shuffles 16 B per suffix; suffixes = reads × (L+1)
    let paper_reads = paper_read_input as f64 / (PAPER_READ_LEN as f64 + 8.0);
    let paper_suffixes = paper_reads * (PAPER_READ_LEN as f64 + 1.0);
    let paper_shuffle = paper_suffixes * 16.0;
    let paper_per_red = paper_shuffle / PAPER_REDUCERS as f64;
    let ratio = paper_per_red / PAPER_SHUFFLE_BUFFER;

    // our shuffled pair is 24 B (8 key + 8 index + 8 framing)
    let l = env.read_len as f64;
    let shuffle_bytes_per_read = (l + 1.0) * 24.0;
    let mut spec = env.corpus_for_ratio(ratio, shuffle_bytes_per_read);

    // pair-end: two input files of n/2 fragments each (the paper's
    // 63.12 GB Case 6 is the TOTAL of both files)
    let files: Vec<Vec<Read>> = match workload {
        SchemeWorkload::PairEnd => {
            spec.n_reads = (spec.n_reads / 2).max(1);
            let (fwd, rev) = synth_paired_corpus(&spec);
            vec![fwd, rev]
        }
        SchemeWorkload::SingleFile => vec![synth_corpus(&spec)],
    };
    let file_refs: Vec<&[Read]> = files.iter().map(|f| f.as_slice()).collect();
    let n_reads_total: usize = files.iter().map(|f| f.len()).sum();

    let ledger = Ledger::new();
    let store = SharedStore::new(cluster.n_nodes());
    let s = store.clone();
    let factory: scheme::StoreFactory =
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>);
    let cfg = SchemeConfig {
        conf: env.conf(),
        group_threshold: 4000,
        samples_per_reducer: 1000,
        seed: env.seed,
        ..Default::default()
    };
    let res = scheme::run_files(&file_refs, &cfg, factory, &ledger)?;

    // Table V normalizes by the OUTPUT size ("1.01 unit" reference)
    let reference = (res.job.footprint.get(Channel::HdfsWrite) as f64 / 1.01) as u64;
    let [map_lr, map_lw, red_lr, red_lw, hdfs_r, hdfs_w, shuffle, kv_put, kv_fetch] =
        normalize(&res.job.footprint, reference);

    // ---- project ----
    // paper-scale output reference = suffix volume (texts + indexes)
    let paper_output_ref = paper_suffixes * ((PAPER_READ_LEN as f64 + 1.0) / 2.0 + 8.0);
    let mut fp = Footprint::default();
    for (ch, v) in [
        (Channel::MapLocalRead, map_lr),
        (Channel::MapLocalWrite, map_lw),
        (Channel::ReduceLocalRead, red_lr),
        (Channel::ReduceLocalWrite, red_lw),
        (Channel::HdfsRead, hdfs_r),
        (Channel::HdfsWrite, hdfs_w),
        (Channel::Shuffle, shuffle),
        (Channel::KvPut, kv_put),
        (Channel::KvFetch, kv_fetch),
    ] {
        fp.set(ch, (v * paper_output_ref) as u64);
    }
    let shape = WorkloadShape {
        n_reducers: PAPER_REDUCERS,
        per_reducer_shuffle: paper_per_red as u64,
        max_group_bytes: 1_600_000 * 16, // threshold × 16 B pairs (§IV-C)
        numeric_pipeline: true,
        reduce_slots_per_node: 2,
    };
    let heap = HeapConfig::paper_scheme();
    let time = simcost::estimate(cluster, params, &fp, &shape, &heap, env.trials, env.seed);

    Ok(CaseRow {
        label: label.to_string(),
        paper_input: paper_read_input,
        map_lr,
        map_lw,
        red_lr,
        red_lw,
        hdfs_r,
        hdfs_w,
        shuffle,
        kv_put,
        kv_fetch,
        time,
        measured: res.job.footprint,
        reference_bytes: reference,
        mini_reads: n_reads_total,
    })
}

/// KV memory at paper scale for an input of raw reads (the 1.5× rule) —
/// Table VIII's scheme mem_ratio numerator term.
pub fn paper_kv_memory(paper_read_input: u64) -> u64 {
    (paper_read_input as f64 * 1.5) as u64
}

/// Corpus helper shared by examples and benches.
pub fn example_corpus(n_reads: usize, read_len: usize, seed: u64) -> Vec<Read> {
    synth_corpus(&CorpusSpec {
        n_reads,
        read_len,
        len_jitter: 4,
        genome_len: 1 << 20,
        seed,
        ..Default::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_env() -> ScaledEnv {
        ScaledEnv { thrift: 8.0, trials: 3, ..Default::default() }
    }

    #[test]
    fn terasort_case1_ratios_match_paper_shape() {
        let env = quick_env();
        let cluster = ClusterSpec::table2();
        let row = run_terasort_case(
            "Case 1",
            637_180_000_000,
            &TeraVariant::baseline(),
            &env,
            &cluster,
            &CostParams::default(),
        )
        .unwrap();
        // paper: Map 1.03R/2.07W; shape: ~1R / ~2W
        assert!((0.8..1.3).contains(&row.map_lr), "map_lr={}", row.map_lr);
        assert!((1.7..2.4).contains(&row.map_lw), "map_lw={}", row.map_lw);
        // paper: Reduce 1.03/1.03 — no merge rounds at case-1 ratio
        assert!((0.7..1.3).contains(&row.red_lr), "red_lr={}", row.red_lr);
        assert!((row.red_lr - row.red_lw).abs() < 0.15);
        assert!((0.9..1.15).contains(&row.shuffle), "shuffle={}", row.shuffle);
        assert!(row.time.completed());
    }

    #[test]
    fn terasort_case5_grows_reduce_io_and_breaks() {
        let env = quick_env();
        let cluster = ClusterSpec::table2();
        let c1 = run_terasort_case(
            "Case 1",
            637_180_000_000,
            &TeraVariant::baseline(),
            &env,
            &cluster,
            &CostParams::default(),
        )
        .unwrap();
        let c5 = run_terasort_case(
            "Case 5",
            (3.37 * TB as f64) as u64,
            &TeraVariant::baseline(),
            &env,
            &cluster,
            &CostParams::default(),
        )
        .unwrap();
        // paper: 1.03 -> 1.88 growth in reduce-side R/W
        assert!(
            c5.red_lr > c1.red_lr + 0.3,
            "case5 reduce R {} should exceed case1 {}",
            c5.red_lr,
            c1.red_lr
        );
        // map side stays flat
        assert!((c5.map_lw - c1.map_lw).abs() < 0.25);
        // breakdown at case 5
        assert!(!c5.time.completed());
        assert!(c5.time.minutes.mu > 3.0 * c4_or(&c1));
    }

    fn c4_or(c1: &CaseRow) -> f64 {
        c1.time.minutes.mu
    }

    #[test]
    fn scheme_case_ratios_match_paper_shape() {
        let env = quick_env();
        let cluster = ClusterSpec::table2();
        let row = run_scheme_case(
            "Case 1",
            (5.86 * GB as f64) as u64,
            SchemeWorkload::SingleFile,
            &env,
            &cluster,
            &CostParams::default(),
        )
        .unwrap();
        // paper Table V: Map 0.30R/0.45W, Reduce 0.16/0.16, Shuffle 0.16,
        // HDFS read 0.01, write 1.01 — all per unit of output.
        assert!(row.map_lw < 0.9, "map_lw={}", row.map_lw);
        assert!(row.map_lr < row.map_lw);
        assert!(row.red_lr < 0.45, "red_lr={}", row.red_lr);
        assert!((row.red_lr - row.shuffle).abs() < 0.08, "red==shuffle (paper)");
        assert!(row.hdfs_r < 0.05, "hdfs_r={}", row.hdfs_r);
        assert!((0.95..1.1).contains(&row.hdfs_w), "hdfs_w={}", row.hdfs_w);
        assert!(row.time.completed());
    }

    #[test]
    fn scheme_survives_case6_where_terasort_died_at_case5() {
        let env = quick_env();
        let cluster = ClusterSpec::table2();
        let row = run_scheme_case(
            "Case 6",
            (63.12 * GB as f64) as u64,
            SchemeWorkload::PairEnd,
            &env,
            &cluster,
            &CostParams::default(),
        )
        .unwrap();
        assert!(row.time.completed(), "{:?}", row.time.breakdown);
        // Case 6 now executes as two real input files
        assert!(row.mini_reads > 100, "paired corpus actually ran");
    }

    #[test]
    fn case6_pair_end_ratios_match_single_file() {
        // The paper's closing claim, checked mechanically: pair-end
        // construction from TWO input files shows no degradation in the
        // normalized per-unit footprint (Table V columns) relative to
        // single-file construction.
        let env = quick_env();
        let cluster = ClusterSpec::table2();
        let params = CostParams::default();
        let input6 = (63.12 * GB as f64) as u64;

        // the genuine two-file pair-end run
        let row6 = run_scheme_case(
            "Case 6",
            input6,
            SchemeWorkload::PairEnd,
            &env,
            &cluster,
            &params,
        )
        .unwrap();
        // control: the SAME total volume as one single file — isolates
        // two-file-ness from scale
        let ctl = run_scheme_case(
            "Case 6 control",
            input6,
            SchemeWorkload::SingleFile,
            &env,
            &cluster,
            &params,
        )
        .unwrap();
        // the largest single-file paper case — scale invariance
        let row5 = run_scheme_case(
            "Case 5",
            (31.76 * GB as f64) as u64,
            SchemeWorkload::SingleFile,
            &env,
            &cluster,
            &params,
        )
        .unwrap();

        let close = |name: &str, a: f64, b: f64| {
            let tol = (0.20 * b.abs()).max(0.08);
            assert!(
                (a - b).abs() <= tol,
                "{name}: pair-end {a:.3} vs single-file {b:.3} (tol {tol:.3})"
            );
        };
        // equal volume, two files vs one: EVERY Table V column must match
        for (name, a, b) in [
            ("map_lr", row6.map_lr, ctl.map_lr),
            ("map_lw", row6.map_lw, ctl.map_lw),
            ("red_lr", row6.red_lr, ctl.red_lr),
            ("red_lw", row6.red_lw, ctl.red_lw),
            ("shuffle", row6.shuffle, ctl.shuffle),
            ("kv_put", row6.kv_put, ctl.kv_put),
            ("kv_fetch", row6.kv_fetch, ctl.kv_fetch),
            ("hdfs_w", row6.hdfs_w, ctl.hdfs_w),
        ] {
            close(name, a, b);
        }
        // across scale (2× Case 5's volume): the per-unit map/shuffle/KV
        // columns — the ones the paper's scalability argument rests on —
        // stay flat
        for (name, a, b) in [
            ("map_lr", row6.map_lr, row5.map_lr),
            ("map_lw", row6.map_lw, row5.map_lw),
            ("shuffle", row6.shuffle, row5.shuffle),
            ("kv_put", row6.kv_put, row5.kv_put),
            ("kv_fetch", row6.kv_fetch, row5.kv_fetch),
            ("hdfs_w", row6.hdfs_w, row5.hdfs_w),
        ] {
            close(name, a, b);
        }
        assert!(row6.time.completed());
    }
}

//! ASCII rendering of the paper's tables and figures.

use crate::report::experiments::CaseRow;
use crate::util::bytes::human;

/// Render a footprint table in the paper's row layout (Tables III–VII).
pub fn footprint_table(
    title: &str,
    rows: &[CaseRow],
    paper_times: Option<&[(f64, f64, bool)]>,
    show_kv: bool,
) -> String {
    let mut s = String::new();
    s.push_str(&format!("== {title} ==\n"));
    let mut header = format!("{:<10}", "");
    for r in rows {
        header.push_str(&format!("{:>22}", r.label));
    }
    s.push_str(&header);
    s.push('\n');
    let mut sizes = format!("{:<10}", "Input");
    for r in rows {
        sizes.push_str(&format!("{:>22}", human(r.paper_input)));
    }
    s.push_str(&sizes);
    s.push('\n');
    let line = |name: &str, f: &dyn Fn(&CaseRow) -> String| {
        let mut l = format!("{name:<10}");
        for r in rows {
            l.push_str(&format!("{:>22}", f(r)));
        }
        l.push('\n');
        l
    };
    s.push_str(&line("", &|_| "Map | Reduce".into()));
    s.push_str(&line("LocalRead", &|r| format!("{:.2} | {:.2}", r.map_lr, r.red_lr)));
    s.push_str(&line("LocalWrite", &|r| format!("{:.2} | {:.2}", r.map_lw, r.red_lw)));
    s.push_str(&line("HDFS Read", &|r| format!("{:.2}", r.hdfs_r)));
    s.push_str(&line("HDFS Write", &|r| format!("{:.2}", r.hdfs_w)));
    s.push_str(&line("Shuffle", &|r| format!("{:.2}", r.shuffle)));
    if show_kv {
        s.push_str(&line("KV Put", &|r| format!("{:.2}", r.kv_put)));
        s.push_str(&line("KV Fetch", &|r| format!("{:.2}", r.kv_fetch)));
    }
    s.push_str(&line("Time(min)", &|r| {
        let t = &r.time;
        let star = if t.completed() { "" } else { "*" };
        format!("μ={:.1}; σ={:.2}{}", t.minutes.mu, t.minutes.sigma, star)
    }));
    if let Some(pt) = paper_times {
        let mut l = format!("{:<10}", "Paper");
        for (i, _) in rows.iter().enumerate() {
            if let Some((mu, sigma, ok)) = pt.get(i) {
                let star = if *ok { "" } else { "*" };
                l.push_str(&format!("{:>22}", format!("μ={mu:.1}; σ={sigma:.2}{star}")));
            }
        }
        s.push_str(&l);
        s.push('\n');
    }
    if rows.iter().any(|r| !r.time.completed()) {
        s.push_str("(* = breakdown: not all trials completed)\n");
    }
    s
}

/// A labelled (x, y, completed) series for the figures.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64, bool)>, // (input TB, minutes, completed)
}

/// ASCII scatter/line chart (Figures 5 and 8).
pub fn chart(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let mut s = format!("== {title} ==\n");
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|sr| sr.points.iter().map(|&(x, y, _)| (x, y)))
        .collect();
    if all.is_empty() {
        return s;
    }
    let xmax = all.iter().map(|p| p.0).fold(0.0, f64::max) * 1.05;
    let ymax = all.iter().map(|p| p.1).fold(0.0, f64::max) * 1.05;
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['o', 'x', '+', '#', '@', '%'];
    for (si, sr) in series.iter().enumerate() {
        for &(x, y, ok) in &sr.points {
            let cx = ((x / xmax) * (width - 1) as f64).round() as usize;
            let cy = ((y / ymax) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            let mark = if ok { marks[si % marks.len()] } else { '!' };
            grid[row][cx.min(width - 1)] = mark;
        }
    }
    for (i, row) in grid.iter().enumerate() {
        let yval = ymax * (height - 1 - i) as f64 / (height - 1) as f64;
        s.push_str(&format!("{yval:>8.0} |"));
        s.push_str(&row.iter().collect::<String>());
        s.push('\n');
    }
    s.push_str(&format!("{:>8} +{}\n", "", "-".repeat(width)));
    s.push_str(&format!("{:>10}0{:>width$.2}\n", "", xmax, width = width - 1));
    for (si, sr) in series.iter().enumerate() {
        s.push_str(&format!("  {} = {}   ", marks[si % marks.len()], sr.name));
    }
    s.push_str("(! = breakdown)\n");
    s
}

/// Simple aligned key/value block.
pub fn kv_block(title: &str, pairs: &[(String, String)]) -> String {
    let mut s = format!("== {title} ==\n");
    let w = pairs.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
    for (k, v) in pairs {
        s.push_str(&format!("{k:<w$}  {v}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::footprint::Footprint;
    use crate::simcost::TimeEstimate;
    use crate::util::stats::MuSigma;

    fn dummy_row(label: &str, mu: f64, ok: bool) -> CaseRow {
        CaseRow {
            label: label.into(),
            paper_input: 637_000_000_000,
            map_lr: 1.03,
            map_lw: 2.07,
            red_lr: 1.03,
            red_lw: 1.03,
            hdfs_r: 1.0,
            hdfs_w: 1.01,
            shuffle: 1.03,
            kv_put: 0.0,
            kv_fetch: 0.0,
            time: TimeEstimate {
                minutes: MuSigma { mu, sigma: 1.3, n: 5 },
                trials: 5,
                completed_trials: if ok { 5 } else { 1 },
                breakdown: None,
            },
            measured: Footprint::default(),
            reference_bytes: 1,
            mini_reads: 100,
        }
    }

    #[test]
    fn table_renders_all_rows() {
        let rows = vec![dummy_row("Case 1", 61.8, true), dummy_row("Case 5", 700.0, false)];
        let t = footprint_table("Table III", &rows, Some(&[(61.8, 1.3, true)]), false);
        assert!(t.contains("Case 1"));
        assert!(t.contains("2.07"));
        assert!(t.contains("μ=61.8"));
        assert!(t.contains("breakdown"));
        assert!(t.contains("Paper"));
    }

    #[test]
    fn chart_renders_marks() {
        let s = vec![
            Series { name: "TeraSort".into(), points: vec![(0.6, 60.0, true), (3.4, 700.0, false)] },
            Series { name: "Scheme".into(), points: vec![(0.6, 63.0, true)] },
        ];
        let c = chart("Fig 5", &s, 40, 10);
        assert!(c.contains('o'));
        assert!(c.contains('!'));
        assert!(c.contains("TeraSort"));
    }
}

//! samr — the launcher.
//!
//! Subcommands:
//!   quickstart                         Table I demo + a tiny end-to-end run
//!   table <1..8>                       regenerate a paper table
//!   figure <3|4|5|7|8>                 regenerate a paper figure
//!   terasort [--reads N --len L ...]   run the baseline on a synthetic corpus
//!   scheme   [--reads N --tcp ...]     run the scheme (in-proc or TCP KV)
//!   kv-server [--port P]               run one KV instance (RESP + MGETSUFFIX)
//!   stats                              §IV-D headline comparison block
//!   all                                every table and figure
//!
//! Global flags: --thrift F (shrink experiments F×, default 4),
//! --trials N (simulated repetitions), --artifacts DIR (PJRT kernels;
//! "none" forces the native fallback), --reducers N, --seed S.

use std::sync::Arc;

use samr::cli::Args;
use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::kvstore::{server::Server, LocalKvCluster};
use samr::report::experiments::{example_corpus, ScaledEnv};
use samr::report::Reporter;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::validate::validate_order;
use samr::terasort::{self, TeraSortConfig};
use samr::util::bytes::human;

fn main() {
    let args = Args::from_env();
    // runtime init: --artifacts DIR | "none" | default ./artifacts
    match args.get("artifacts") {
        Some("none") => {
            runtime::init(None);
        }
        Some(dir) => {
            runtime::init(Some(std::path::Path::new(dir)));
        }
        None => {
            runtime::init(Some(&runtime::default_artifacts_dir()));
        }
    }
    let reporter = reporter_from(&args);
    let code = match args.command.as_str() {
        "quickstart" => quickstart(&reporter),
        "table" => table(&args, &reporter),
        "figure" => figure(&args, &reporter),
        "terasort" => run_terasort(&args),
        "scheme" => run_scheme(&args),
        "kv-server" => kv_server(&args),
        "stats" => {
            print!("{}", reporter.scheme_stats().expect("stats"));
            0
        }
        "all" => all(&reporter),
        "" | "help" | "--help" => {
            eprintln!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "samr — suffix array construction with MapReduce + in-memory data store
  samr quickstart | stats | all
  samr table <1..8>   samr figure <3|4|5|7|8>
  samr terasort|scheme [--reads N --len L --reducers R --tcp]
  samr kv-server [--port P]
  global: --thrift F --trials N --artifacts DIR|none --seed S";

fn reporter_from(args: &Args) -> Reporter {
    let mut r = Reporter::default();
    r.env = ScaledEnv {
        thrift: args.get_parse("thrift", 4.0),
        trials: args.get_parse("trials", 5),
        seed: args.get_parse("seed", 20170101),
        ..Default::default()
    };
    r
}

fn quickstart(reporter: &Reporter) -> i32 {
    print!("{}", reporter.table1());
    println!(
        "\nPJRT artifacts: {}",
        if runtime::pjrt_active() { "active" } else { "native fallback" }
    );
    // tiny end-to-end run of both pipelines with validation
    let reads = example_corpus(200, 60, 42);
    let ledger = Ledger::new();
    let tera = terasort::run(
        &reads,
        &TeraSortConfig {
            conf: samr::mapreduce::JobConf {
                n_reducers: 4,
                ..samr::mapreduce::JobConf::scaled_down()
            },
            ..Default::default()
        },
        &ledger,
    )
    .expect("terasort");
    validate_order(&reads, &tera.order).expect("terasort order");
    println!(
        "TeraSort: {} suffixes sorted & validated; shuffle {}",
        tera.order.len(),
        human(tera.job.footprint.get(Channel::Shuffle))
    );

    let ledger2 = Ledger::new();
    let store = SharedStore::new(4);
    let s = store.clone();
    let res = scheme::run(
        &reads,
        &SchemeConfig {
            conf: samr::mapreduce::JobConf {
                n_reducers: 4,
                ..samr::mapreduce::JobConf::scaled_down()
            },
            group_threshold: 5000,
            samples_per_reducer: 500,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger2,
    )
    .expect("scheme");
    validate_order(&reads, &res.order).expect("scheme order");
    println!(
        "Scheme:   {} suffixes sorted & validated; shuffle {} ({}x less), KV memory {}",
        res.order.len(),
        human(ledger2.get(Channel::Shuffle)),
        ledger.get(Channel::Shuffle) / ledger2.get(Channel::Shuffle).max(1),
        human(res.kv_memory),
    );
    0
}

fn table(args: &Args, reporter: &Reporter) -> i32 {
    let n: u32 = args.positional_parse(0).unwrap_or(0);
    let out = match n {
        1 => Ok(reporter.table1()),
        2 => Ok(reporter.table2()),
        3 => reporter.table3(),
        4 => reporter.table4(),
        5 => reporter.table5(),
        6 => reporter.table6(),
        7 => reporter.table7(),
        8 => reporter.table8(),
        _ => {
            eprintln!("table must be 1..8");
            return 2;
        }
    };
    print!("{}", out.expect("table"));
    0
}

fn figure(args: &Args, reporter: &Reporter) -> i32 {
    let n: u32 = args.positional_parse(0).unwrap_or(0);
    let out = match n {
        3 => reporter.figure3().expect("figure"),
        4 => reporter.figure4(),
        5 => reporter.figure5().expect("figure"),
        7 => reporter.figure7(),
        8 => reporter.figure8().expect("figure"),
        _ => {
            eprintln!("figure must be one of 3, 4, 5, 7, 8");
            return 2;
        }
    };
    print!("{out}");
    0
}

fn corpus_from(args: &Args) -> Vec<samr::suffix::reads::Read> {
    example_corpus(
        args.get_parse("reads", 2000),
        args.get_parse("len", 100),
        args.get_parse("seed", 42),
    )
}

fn conf_from(args: &Args) -> samr::mapreduce::JobConf {
    samr::mapreduce::JobConf {
        n_reducers: args.get_parse("reducers", 8),
        ..samr::mapreduce::JobConf::scaled_down()
    }
}

fn run_terasort(args: &Args) -> i32 {
    let reads = corpus_from(args);
    let ledger = Ledger::new();
    samr::mapreduce::resident::reset();
    let t0 = std::time::Instant::now();
    let res = terasort::run(
        &reads,
        &TeraSortConfig { conf: conf_from(args), ..Default::default() },
        &ledger,
    )
    .expect("terasort");
    validate_order(&reads, &res.order).expect("output order invalid");
    println!(
        "TeraSort over {} reads -> {} suffixes in {:?}",
        reads.len(),
        res.order.len(),
        t0.elapsed()
    );
    println!("suffix input {} (disk-backed: splits + output spooled)", human(res.suffix_input_bytes));
    print!("{}", res.job.footprint);
    println!(
        "max sorting group: {} records / {}",
        res.max_group_records,
        human(res.max_group_bytes)
    );
    println!(
        "peak resident shuffle records: {}",
        samr::mapreduce::resident::peak()
    );
    0
}

fn run_scheme(args: &Args) -> i32 {
    let reads = corpus_from(args);
    let ledger = Ledger::new();
    let cfg = SchemeConfig {
        conf: conf_from(args),
        group_threshold: args.get_parse("threshold", 100_000),
        write_suffixes: !args.has("index-only"),
        samples_per_reducer: 1000,
        ..Default::default()
    };
    samr::mapreduce::resident::reset();
    let t0 = std::time::Instant::now();
    let n_instances = args.get_parse("instances", 4usize);
    let res = if args.has("tcp") {
        let kv = LocalKvCluster::start(n_instances).expect("kv cluster");
        let addrs = kv.addrs();
        let factory: scheme::StoreFactory = Arc::new(move || {
            Box::new(samr::kvstore::shard::ShardedClient::connect(&addrs).expect("connect"))
                as Box<dyn SuffixStore>
        });
        let res = scheme::run(&reads, &cfg, factory, &ledger).expect("scheme");
        println!(
            "KV servers: {} instances, {} total memory",
            n_instances,
            human(kv.used_memory())
        );
        res
    } else {
        let store = SharedStore::new(n_instances);
        let s = store.clone();
        scheme::run(
            &reads,
            &cfg,
            Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
            &ledger,
        )
        .expect("scheme")
    };
    validate_order(&reads, &res.order).expect("output order invalid");
    println!(
        "Scheme over {} reads -> {} suffixes in {:?} (PJRT {})",
        reads.len(),
        res.order.len(),
        t0.elapsed(),
        if runtime::pjrt_active() { "on" } else { "off" }
    );
    print!("{}", res.job.footprint);
    let (f, s, o) = res.time_split.percentages();
    println!("reducer time split: fetch {f:.0}% / sort {s:.0}% / other {o:.0}% (paper: 60/13/27)");
    println!("KV memory: {}", human(res.kv_memory));
    println!(
        "peak resident shuffle records: {}",
        samr::mapreduce::resident::peak()
    );
    0
}

fn kv_server(args: &Args) -> i32 {
    let port = args.get_parse("port", 6379u16);
    let mut server = Server::start(port).expect("bind");
    println!("samr-kv listening on {} (RESP subset + MGETSUFFIX)", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
        let _ = &mut server;
    }
}

fn all(reporter: &Reporter) -> i32 {
    print!("{}", reporter.table1());
    print!("{}", reporter.table2());
    print!("{}", reporter.table3().expect("t3"));
    print!("{}", reporter.table4().expect("t4"));
    print!("{}", reporter.table5().expect("t5"));
    print!("{}", reporter.table6().expect("t6"));
    print!("{}", reporter.table7().expect("t7"));
    print!("{}", reporter.table8().expect("t8"));
    print!("{}", reporter.figure3().expect("f3"));
    print!("{}", reporter.figure4());
    print!("{}", reporter.figure5().expect("f5"));
    print!("{}", reporter.figure7());
    print!("{}", reporter.figure8().expect("f8"));
    print!("{}", reporter.scheme_stats().expect("stats"));
    0
}

//! samr — the launcher.
//!
//! Subcommands:
//!   quickstart                         Table I demo + a tiny end-to-end run
//!   table <1..8>                       regenerate a paper table
//!   figure <3|4|5|7|8>                 regenerate a paper figure
//!   terasort [--reads N --len L ...]   run the baseline on a synthetic corpus
//!   scheme   [--reads N --tcp ...]     run the scheme (in-proc or TCP KV)
//!   build    --out PATH [...]          construct AND seal a synthetic corpus
//!   seal     <fa> [mates.fa] --out P   construct + seal FASTA input file(s)
//!   serve    --index PATH [--port P]   serve a sealed index (SEARCH/PAIRS/STAT)
//!   query    <op> [...] --addr|--index query a server or a local artifact
//!   kv-server [--port P]               run one KV instance (RESP + MGETSUFFIX)
//!   cluster  [--reads N --workers W]   multi-process run (driver + workers + shards)
//!   worker   [--port P]                cluster task-executor process (internal)
//!   shard    --shard I --aof PATH      cluster KV-shard process (internal)
//!   stats                              §IV-D headline comparison block
//!   all                                every table and figure
//!
//! Global flags: --thrift F (shrink experiments F×, default 4),
//! --trials N (simulated repetitions), --artifacts DIR (PJRT kernels;
//! "none" forces the native fallback), --reducers N, --seed S.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use samr::cli::Args;
use samr::footprint::{Channel, Ledger};
use samr::kvstore::query::{QueryClient, QueryServer};
use samr::kvstore::shard::{ShardedClient, SharedStore, SuffixStore};
use samr::kvstore::{server::Server, LocalKvCluster};
use samr::report::experiments::{example_corpus, ScaledEnv};
use samr::report::Reporter;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::encode::strict_code_of;
use samr::suffix::reads::{
    parse_fasta, parse_paired_files, synth_paired_corpus, CorpusSpec, ParsePolicy, Read,
};
use samr::suffix::sealed::SealedIndex;
use samr::suffix::search::{IndexView, PairHit};
use samr::suffix::validate::validate_order;
use samr::terasort::{self, TeraSortConfig};
use samr::util::bytes::human;

fn main() {
    let args = Args::from_env();
    // runtime init: --artifacts DIR | "none" | default ./artifacts
    match args.get("artifacts") {
        Some("none") => {
            runtime::init(None);
        }
        Some(dir) => {
            runtime::init(Some(std::path::Path::new(dir)));
        }
        None => {
            runtime::init(Some(&runtime::default_artifacts_dir()));
        }
    }
    let reporter = reporter_from(&args);
    let code = match args.command.as_str() {
        "quickstart" => quickstart(&reporter),
        "table" => table(&args, &reporter),
        "figure" => figure(&args, &reporter),
        "terasort" => run_terasort(&args),
        "scheme" => run_scheme(&args),
        "build" => build(&args),
        "seal" => seal(&args),
        "serve" => serve(&args),
        "query" => query(&args),
        "kv-server" => kv_server(&args),
        "cluster" => cluster(&args),
        "worker" => worker(&args),
        "shard" => shard(&args),
        "stats" => {
            print!("{}", reporter.scheme_stats().expect("stats"));
            0
        }
        "all" => all(&reporter),
        "" | "help" | "--help" => {
            eprintln!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "samr — suffix array construction with MapReduce + in-memory data store
  samr quickstart | stats | all
  samr table <1..8>   samr figure <3|4|5|7|8>
  samr terasort|scheme [--reads N --len L --reducers R --tcp]
  samr build --out index.samr [--reads N --len L --paired --tcp --instances K --no-lcp]
  samr seal reads.fa [mates.fa] --out index.samr [--strict --instances K --no-lcp]
  samr serve --index index.samr [--port P]
  samr query search <PATTERN> --addr H:P | --index index.samr
  samr query pairs <FWD> <REV> [--max-insert N] --addr H:P | --index index.samr
  samr query stat --addr H:P | --index index.samr
  samr kv-server [--port P]
  samr cluster [--reads N --len L --reducers R --workers W --shards S]
  samr worker [--port P]                    (internal: cluster task executor)
  samr shard --shard I --aof PATH [--port P --kill-at-request N]
  global: --thrift F --trials N --artifacts DIR|none --seed S";

fn reporter_from(args: &Args) -> Reporter {
    let mut r = Reporter::default();
    r.env = ScaledEnv {
        thrift: args.get_parse("thrift", 4.0),
        trials: args.get_parse("trials", 5),
        seed: args.get_parse("seed", 20170101),
        ..Default::default()
    };
    r
}

fn quickstart(reporter: &Reporter) -> i32 {
    print!("{}", reporter.table1());
    println!(
        "\nPJRT artifacts: {}",
        if runtime::pjrt_active() { "active" } else { "native fallback" }
    );
    // tiny end-to-end run of both pipelines with validation
    let reads = example_corpus(200, 60, 42);
    let ledger = Ledger::new();
    let tera = terasort::run(
        &reads,
        &TeraSortConfig {
            conf: samr::mapreduce::JobConf {
                n_reducers: 4,
                ..samr::mapreduce::JobConf::scaled_down()
            },
            ..Default::default()
        },
        &ledger,
    )
    .expect("terasort");
    validate_order(&reads, &tera.order).expect("terasort order");
    println!(
        "TeraSort: {} suffixes sorted & validated; shuffle {}",
        tera.order.len(),
        human(tera.job.footprint.get(Channel::Shuffle))
    );

    let ledger2 = Ledger::new();
    let store = SharedStore::new(4);
    let s = store.clone();
    let res = scheme::run(
        &reads,
        &SchemeConfig {
            conf: samr::mapreduce::JobConf {
                n_reducers: 4,
                ..samr::mapreduce::JobConf::scaled_down()
            },
            group_threshold: 5000,
            samples_per_reducer: 500,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger2,
    )
    .expect("scheme");
    validate_order(&reads, &res.order).expect("scheme order");
    println!(
        "Scheme:   {} suffixes sorted & validated; shuffle {} ({}x less), KV memory {}",
        res.order.len(),
        human(ledger2.get(Channel::Shuffle)),
        ledger.get(Channel::Shuffle) / ledger2.get(Channel::Shuffle).max(1),
        human(res.kv_memory),
    );
    0
}

fn table(args: &Args, reporter: &Reporter) -> i32 {
    let n: u32 = args.positional_parse(0).unwrap_or(0);
    let out = match n {
        1 => Ok(reporter.table1()),
        2 => Ok(reporter.table2()),
        3 => reporter.table3(),
        4 => reporter.table4(),
        5 => reporter.table5(),
        6 => reporter.table6(),
        7 => reporter.table7(),
        8 => reporter.table8(),
        _ => {
            eprintln!("table must be 1..8");
            return 2;
        }
    };
    print!("{}", out.expect("table"));
    0
}

fn figure(args: &Args, reporter: &Reporter) -> i32 {
    let n: u32 = args.positional_parse(0).unwrap_or(0);
    let out = match n {
        3 => reporter.figure3().expect("figure"),
        4 => reporter.figure4(),
        5 => reporter.figure5().expect("figure"),
        7 => reporter.figure7(),
        8 => reporter.figure8().expect("figure"),
        _ => {
            eprintln!("figure must be one of 3, 4, 5, 7, 8");
            return 2;
        }
    };
    print!("{out}");
    0
}

fn corpus_from(args: &Args) -> Vec<samr::suffix::reads::Read> {
    example_corpus(
        args.get_parse("reads", 2000),
        args.get_parse("len", 100),
        args.get_parse("seed", 42),
    )
}

fn conf_from(args: &Args) -> samr::mapreduce::JobConf {
    samr::mapreduce::JobConf {
        n_reducers: args.get_parse("reducers", 8),
        ..samr::mapreduce::JobConf::scaled_down()
    }
}

fn run_terasort(args: &Args) -> i32 {
    let reads = corpus_from(args);
    let ledger = Ledger::new();
    samr::mapreduce::resident::reset();
    let t0 = std::time::Instant::now();
    let res = terasort::run(
        &reads,
        &TeraSortConfig { conf: conf_from(args), ..Default::default() },
        &ledger,
    )
    .expect("terasort");
    validate_order(&reads, &res.order).expect("output order invalid");
    println!(
        "TeraSort over {} reads -> {} suffixes in {:?}",
        reads.len(),
        res.order.len(),
        t0.elapsed()
    );
    println!("suffix input {} (disk-backed: splits + output spooled)", human(res.suffix_input_bytes));
    print!("{}", res.job.footprint);
    println!(
        "max sorting group: {} records / {}",
        res.max_group_records,
        human(res.max_group_bytes)
    );
    println!(
        "peak resident shuffle records: {}",
        samr::mapreduce::resident::peak()
    );
    0
}

fn run_scheme(args: &Args) -> i32 {
    let reads = corpus_from(args);
    let ledger = Ledger::new();
    let cfg = SchemeConfig {
        conf: conf_from(args),
        group_threshold: args.get_parse("threshold", 100_000),
        write_suffixes: !args.has("index-only"),
        samples_per_reducer: 1000,
        ..Default::default()
    };
    samr::mapreduce::resident::reset();
    let t0 = std::time::Instant::now();
    let n_instances = args.get_parse("instances", 4usize);
    let res = if args.has("tcp") {
        let kv = LocalKvCluster::start(n_instances).expect("kv cluster");
        let addrs = kv.addrs();
        let factory: scheme::StoreFactory = Arc::new(move || {
            Box::new(samr::kvstore::shard::ShardedClient::connect(&addrs).expect("connect"))
                as Box<dyn SuffixStore>
        });
        let res = scheme::run(&reads, &cfg, factory, &ledger).expect("scheme");
        println!(
            "KV servers: {} instances, {} total memory",
            n_instances,
            human(kv.used_memory())
        );
        res
    } else {
        let store = SharedStore::new(n_instances);
        let s = store.clone();
        scheme::run(
            &reads,
            &cfg,
            Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
            &ledger,
        )
        .expect("scheme")
    };
    validate_order(&reads, &res.order).expect("output order invalid");
    println!(
        "Scheme over {} reads -> {} suffixes in {:?} (PJRT {})",
        reads.len(),
        res.order.len(),
        t0.elapsed(),
        if runtime::pjrt_active() { "on" } else { "off" }
    );
    print!("{}", res.job.footprint);
    let (f, s, o) = res.time_split.percentages();
    println!("reducer time split: fetch {f:.0}% / sort {s:.0}% / other {o:.0}% (paper: 60/13/27)");
    println!("KV memory: {}", human(res.kv_memory));
    println!(
        "peak resident shuffle records: {}",
        samr::mapreduce::resident::peak()
    );
    0
}

/// Scheme config for the sealing subcommands (`build`/`seal`).
/// `--no-lcp` turns off inline LCP/BWT emission and seals a plain
/// (v1-equivalent search behavior) artifact.
fn sealed_cfg(args: &Args) -> SchemeConfig {
    SchemeConfig {
        conf: conf_from(args),
        group_threshold: args.get_parse("threshold", 100_000),
        samples_per_reducer: 1000,
        emit_lcp: !args.has("no-lcp"),
        ..Default::default()
    }
}

/// Run the sealing construction over `files` with the store backend the
/// flags select (in-proc shards by default, real TCP KV under `--tcp`),
/// then report the artifact.
fn seal_files(args: &Args, files: &[&[Read]], out: &Path) -> i32 {
    let cfg = sealed_cfg(args);
    let ledger = Ledger::new();
    let n_instances = args.get_parse("instances", 4usize);
    let t0 = std::time::Instant::now();
    let res = if args.has("tcp") {
        let kv = LocalKvCluster::start(n_instances).expect("kv cluster");
        let addrs = kv.addrs();
        let factory: scheme::StoreFactory = Arc::new(move || {
            Box::new(ShardedClient::connect(&addrs).expect("connect")) as Box<dyn SuffixStore>
        });
        scheme::run_files_sealed(files, &cfg, factory, &ledger, out)
    } else {
        let store = SharedStore::new(n_instances);
        let factory: scheme::StoreFactory =
            Arc::new(move || Box::new(store.clone()) as Box<dyn SuffixStore>);
        scheme::run_files_sealed(files, &cfg, factory, &ledger, out)
    };
    let res = match res {
        Ok(r) => r,
        Err(e) => {
            eprintln!("seal failed: {e}");
            return 1;
        }
    };
    let artifact_bytes = std::fs::metadata(out).map(|m| m.len()).unwrap_or(0);
    let n_reads: usize = files.iter().map(|f| f.len()).sum();
    println!(
        "sealed {} suffixes ({} reads, {} files) to {} in {:?}",
        res.n_sealed,
        n_reads,
        files.len(),
        out.display(),
        t0.elapsed()
    );
    println!(
        "artifact {} ({}); shuffle {}; KV memory {}",
        human(artifact_bytes),
        if cfg.emit_lcp { "lcp+tree+bwt sections" } else { "plain" },
        human(ledger.get(Channel::Shuffle)),
        human(res.kv_memory)
    );
    0
}

fn build(args: &Args) -> i32 {
    let out = match args.require("out") {
        Ok(p) => PathBuf::from(p),
        Err(e) => {
            eprintln!("{e}\n{HELP}");
            return 2;
        }
    };
    if args.has("paired") {
        let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
            n_reads: args.get_parse("reads", 2000),
            read_len: args.get_parse("len", 100),
            seed: args.get_parse("seed", 42),
            ..Default::default()
        });
        seal_files(args, &[&fwd, &rev], &out)
    } else {
        let reads = corpus_from(args);
        seal_files(args, &[&reads], &out)
    }
}

fn seal(args: &Args) -> i32 {
    let out = match args.require("out") {
        Ok(p) => PathBuf::from(p),
        Err(e) => {
            eprintln!("{e}\n{HELP}");
            return 2;
        }
    };
    let policy = if args.has("strict") { ParsePolicy::Strict } else { ParsePolicy::MaskN };
    let read_file = |p: &str| match std::fs::read(p) {
        Ok(d) => Ok(d),
        Err(e) => Err(format!("seal: {p}: {e}")),
    };
    match args.positional.as_slice() {
        [single] => {
            let data = match read_file(single) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            match parse_fasta(&data, 0, policy) {
                Ok(reads) => seal_files(args, &[&reads], &out),
                Err(e) => {
                    eprintln!("seal: {single}: {e}");
                    1
                }
            }
        }
        [fwd_path, rev_path] => {
            let (fwd_data, rev_data) = match (read_file(fwd_path), read_file(rev_path)) {
                (Ok(f), Ok(r)) => (f, r),
                (Err(e), _) | (_, Err(e)) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            match parse_paired_files(&fwd_data, &rev_data, policy) {
                Ok((fwd, rev)) => seal_files(args, &[&fwd, &rev], &out),
                Err(e) => {
                    eprintln!("seal: {e}");
                    1
                }
            }
        }
        _ => {
            eprintln!("seal takes one FASTA file (or two for pair-end)\n{HELP}");
            2
        }
    }
}

fn serve(args: &Args) -> i32 {
    let path = match args.require("index") {
        Ok(p) => PathBuf::from(p),
        Err(e) => {
            eprintln!("{e}\n{HELP}");
            return 2;
        }
    };
    let index = match SealedIndex::open(&path) {
        Ok(i) => Arc::new(i),
        Err(e) => {
            eprintln!("serve: {e}");
            return 1;
        }
    };
    let port = args.get_parse("port", 6380u16);
    let mut server = QueryServer::start(port, index).expect("bind");
    let st = server.index().stats();
    println!(
        "samr-query serving {} on {} ({} suffixes, {} reads, {} files, corpus {}, artifact {}, {} SEARCH)",
        path.display(),
        server.addr(),
        st.n_suffixes,
        st.n_reads,
        st.n_files,
        human(st.corpus_bytes),
        human(st.file_bytes),
        if st.has_tree { "accelerated" } else { "plain" }
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
        let _ = &mut server;
    }
}

/// Strict ASCII → codes for CLI query patterns; mirrors the server's
/// rejection so local and TCP queries fail identically.
fn query_codes(pattern: &str) -> Result<Vec<u8>, String> {
    pattern
        .bytes()
        .map(|c| {
            strict_code_of(c).ok_or_else(|| {
                format!("pattern byte {:?} is not a base (expected one of $ACGT)", c as char)
            })
        })
        .collect()
}

fn print_search_hits(hits: &[(u64, usize)]) {
    for (seq, off) in hits {
        println!("{seq}\t{off}");
    }
    println!("{} hits", hits.len());
}

fn print_pair_hits(hits: &[PairHit]) {
    for h in hits {
        println!(
            "{}\t{}\t{}\t{}\t{}",
            h.fragment, h.forward.0, h.forward.1, h.reverse.0, h.reverse.1
        );
    }
    println!("{} pairs", hits.len());
}

#[allow(clippy::too_many_arguments)]
fn print_stat(
    n_suffixes: u64,
    n_reads: u64,
    n_files: u64,
    corpus_bytes: u64,
    file_bytes: u64,
    has_lcp: bool,
    has_tree: bool,
    has_bwt: bool,
) {
    println!(
        "suffixes {n_suffixes} / reads {n_reads} / files {n_files} / corpus {} / artifact {}",
        human(corpus_bytes),
        human(file_bytes)
    );
    let yn = |b: bool| if b { "yes" } else { "no" };
    println!(
        "sections: lcp {} / tree {} / bwt {} ({} SEARCH)",
        yn(has_lcp),
        yn(has_tree),
        yn(has_bwt),
        if has_tree { "accelerated" } else { "plain" }
    );
}

fn query(args: &Args) -> i32 {
    let op = args.positional.first().map(String::as_str).unwrap_or("");
    let max_insert = args.get_parse("max-insert", 1000usize);
    // the two backends produce the same value shapes, so the printed
    // output is identical whichever path answered
    if let Some(addr) = args.get("addr") {
        let addr: std::net::SocketAddr = match addr.parse() {
            Ok(a) => a,
            Err(e) => {
                eprintln!("query: bad --addr {addr:?}: {e}");
                return 2;
            }
        };
        let mut c = match QueryClient::connect(addr) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("query: {e}");
                return 1;
            }
        };
        let r = match (op, args.positional.get(1), args.positional.get(2)) {
            ("search", Some(p), _) => c.search(p.as_bytes()).map(|h| print_search_hits(&h)),
            ("pairs", Some(f), Some(r)) => {
                c.pairs(f.as_bytes(), r.as_bytes(), max_insert).map(|h| print_pair_hits(&h))
            }
            ("stat", _, _) => c.stat().map(|s| {
                print_stat(
                    s.n_suffixes,
                    s.n_reads,
                    s.n_files,
                    s.corpus_bytes,
                    s.file_bytes,
                    s.has_lcp,
                    s.has_tree,
                    s.has_bwt,
                )
            }),
            _ => {
                eprintln!("query: expected search <P> | pairs <F> <R> | stat\n{HELP}");
                return 2;
            }
        };
        match r {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("query: {e}");
                1
            }
        }
    } else if let Some(path) = args.get("index") {
        let index = match SealedIndex::open(Path::new(path)) {
            Ok(i) => i,
            Err(e) => {
                eprintln!("query: {e}");
                return 1;
            }
        };
        match (op, args.positional.get(1), args.positional.get(2)) {
            ("search", Some(p), _) => match query_codes(p) {
                Ok(pat) => {
                    print_search_hits(&index.find(&pat));
                    0
                }
                Err(e) => {
                    eprintln!("query: {e}");
                    2
                }
            },
            ("pairs", Some(f), Some(r)) => match (query_codes(f), query_codes(r)) {
                (Ok(fc), Ok(rc)) => {
                    print_pair_hits(&index.find_pairs(&fc, &rc, max_insert));
                    0
                }
                (Err(e), _) | (_, Err(e)) => {
                    eprintln!("query: {e}");
                    2
                }
            },
            ("stat", _, _) => {
                let st = index.stats();
                print_stat(
                    st.n_suffixes,
                    st.n_reads,
                    st.n_files,
                    st.corpus_bytes,
                    st.file_bytes,
                    st.has_lcp,
                    st.has_tree,
                    st.has_bwt,
                );
                0
            }
            _ => {
                eprintln!("query: expected search <P> | pairs <F> <R> | stat\n{HELP}");
                2
            }
        }
    } else {
        eprintln!("query needs --addr HOST:PORT or --index PATH\n{HELP}");
        2
    }
}

fn kv_server(args: &Args) -> i32 {
    let port = args.get_parse("port", 6379u16);
    let mut server = Server::start(port).expect("bind");
    println!("samr-kv listening on {} (RESP subset + MGETSUFFIX)", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
        let _ = &mut server;
    }
}

/// Demo of the true multi-process mode: this binary re-execs itself as
/// `samr worker` / `samr shard` children and runs the scheme across
/// them. The footprint printed is byte-identical to an in-process
/// `samr scheme` run over the same corpus and config.
fn cluster(args: &Args) -> i32 {
    let reads = corpus_from(args);
    let cfg = SchemeConfig {
        conf: conf_from(args),
        group_threshold: args.get_parse("threshold", 100_000),
        samples_per_reducer: 1000,
        ..Default::default()
    };
    let bin = match std::env::current_exe() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cluster: cannot locate own binary: {e}");
            return 1;
        }
    };
    let opts = samr::cluster::driver::ClusterOpts {
        n_workers: args.get_parse("workers", 2usize),
        n_shards: args.get_parse("shards", 2usize),
        samr_bin: bin,
        plan: None,
    };
    let ledger = Ledger::new();
    let t0 = std::time::Instant::now();
    let res = match samr::cluster::driver::run_cluster_files(&[&reads], &cfg, &opts, &ledger) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cluster: {e}");
            return 1;
        }
    };
    validate_order(&reads, &res.order).expect("output order invalid");
    println!(
        "Cluster: {} workers + {} shards (separate processes) over {} reads -> {} suffixes in {:?}",
        opts.n_workers,
        opts.n_shards,
        reads.len(),
        res.order.len(),
        t0.elapsed()
    );
    print!("{}", res.job.footprint);
    println!("KV memory: {}", human(res.kv_memory));
    0
}

/// A cluster task-executor child. Prints `ADDR <ip:port>` (flushed — the
/// driver blocks on this line through the pipe) and parks forever; the
/// driver owns the process lifetime.
fn worker(args: &Args) -> i32 {
    let port = args.get_parse("port", 0u16);
    let mut server = samr::cluster::worker::serve(port).expect("bind");
    println!("ADDR {}", server.addr());
    let _ = std::io::Write::flush(&mut std::io::stdout());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
        let _ = &mut server;
    }
}

/// A cluster KV-shard child: one AOF-backed store instance. On respawn
/// after a kill the same `--aof` path replays the log, and the store
/// clients' idempotent-window failover re-drives whatever the dead
/// process never acknowledged. `--kill-at-request N` arms the
/// process-level fault: the Nth command aborts the process.
fn shard(args: &Args) -> i32 {
    let idx = args.get_parse("shard", 0usize);
    let port = args.get_parse("port", 0u16);
    let aof = match args.require("aof") {
        Ok(p) => PathBuf::from(p),
        Err(e) => {
            eprintln!("{e}\n{HELP}");
            return 2;
        }
    };
    let store = match samr::kvstore::store::Store::open_aof(&aof) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("shard {idx}: {e}");
            return 1;
        }
    };
    // the plan is local to this process, so it always names shard 0 —
    // and the RESP server registers as shard 0 to match
    let faults = args.get("kill-at-request").and_then(|v| v.parse().ok()).map(|n| {
        let mut p = samr::faults::FaultPlan::with_shard_fault(samr::faults::ShardFault {
            shard: 0,
            kill_at_request: n,
            refuse_connects: u64::MAX,
        });
        p.process_kill = true;
        Arc::new(p)
    });
    let mut server =
        Server::start_with_store(port, 0, faults, Arc::new(std::sync::Mutex::new(store)))
            .expect("bind");
    println!("ADDR {}", server.addr());
    let _ = std::io::Write::flush(&mut std::io::stdout());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
        let _ = &mut server;
    }
}

fn all(reporter: &Reporter) -> i32 {
    print!("{}", reporter.table1());
    print!("{}", reporter.table2());
    print!("{}", reporter.table3().expect("t3"));
    print!("{}", reporter.table4().expect("t4"));
    print!("{}", reporter.table5().expect("t5"));
    print!("{}", reporter.table6().expect("t6"));
    print!("{}", reporter.table7().expect("t7"));
    print!("{}", reporter.table8().expect("t8"));
    print!("{}", reporter.figure3().expect("f3"));
    print!("{}", reporter.figure4());
    print!("{}", reporter.figure5().expect("f5"));
    print!("{}", reporter.figure7());
    print!("{}", reporter.figure8().expect("f8"));
    print!("{}", reporter.scheme_stats().expect("stats"));
    0
}

//! Byte-size formatting/parsing ("1.24 TB", "128MB") used by configs,
//! reports and the footprint ledger.

pub const KB: u64 = 1000;
pub const MB: u64 = 1000 * KB;
pub const GB: u64 = 1000 * MB;
pub const TB: u64 = 1000 * GB;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

/// Decimal digit count of `v` — `v.to_string().len()` without the
/// allocation. The RESP wire-length arithmetic on the fetch hot path
/// (client, server, and the modeled in-process store) all use this, so
/// their totals match the materializing `Value::wire_len` byte for byte.
pub fn dec_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 10 {
        v /= 10;
        n += 1;
    }
    n
}

/// Format `v` in decimal into a stack buffer, returning the used prefix —
/// the per-request key/offset formatting of `MGETSUFFIX` commands without
/// a `to_string().into_bytes()` heap Vec each (20 bytes fits `u64::MAX`).
pub fn fmt_dec(v: u64, buf: &mut [u8; 20]) -> &[u8] {
    let n = dec_len(v);
    let mut v = v;
    for i in (0..n).rev() {
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
    }
    &buf[..n]
}

/// Render bytes the way the paper's tables do (decimal units, 2 decimals).
pub fn human(bytes: u64) -> String {
    human_f(bytes as f64)
}

pub fn human_f(bytes: f64) -> String {
    let b = bytes.abs();
    if b >= TB as f64 {
        format!("{:.2} TB", bytes / TB as f64)
    } else if b >= GB as f64 {
        format!("{:.2} GB", bytes / GB as f64)
    } else if b >= MB as f64 {
        format!("{:.2} MB", bytes / MB as f64)
    } else if b >= KB as f64 {
        format!("{:.2} KB", bytes / KB as f64)
    } else {
        format!("{bytes:.0} B")
    }
}

/// Parse "64GB", "1.5 TB", "200", "128 MiB".
pub fn parse(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(s.len());
    if split == 0 {
        return None;
    }
    let (num, unit) = s.split_at(split);
    let v: f64 = num.parse().ok()?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "b" | "" => 1,
        "kb" => KB,
        "mb" => MB,
        "gb" => GB,
        "tb" => TB,
        "kib" => 1 << 10,
        "mib" => MIB,
        "gib" => GIB,
        "tib" => 1u64 << 40,
        _ => return None,
    };
    Some((v * mult as f64).round() as u64)
}

/// Parse a plain decimal count possibly ending in k/m/b ("10k" = 10_000).
pub fn parse_count(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Ok(v) = s.parse::<u64>() {
        return Some(v);
    }
    let (num, suffix) = s.split_at(s.len().checked_sub(1)?);
    let v: f64 = num.parse().ok()?;
    let mult = match suffix {
        "k" | "K" => 1_000.0,
        "m" | "M" => 1_000_000.0,
        "b" | "B" => 1_000_000_000.0,
        _ => return None,
    };
    Some((v * mult) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_units() {
        assert_eq!(parse("64GB"), Some(64 * GB));
        assert_eq!(parse("1.5 TB"), Some(1500 * GB));
        assert_eq!(parse("200"), Some(200));
        assert_eq!(parse("128 MiB"), Some(128 * MIB));
        assert_eq!(parse("bogus"), None);
    }

    #[test]
    fn human_matches_paper_style() {
        assert_eq!(human(637_180_000_000), "637.18 GB");
        assert_eq!(human(1_240_000_000_000), "1.24 TB");
        assert_eq!(human(1234), "1.23 KB");
        assert_eq!(human(12), "12 B");
    }

    #[test]
    fn dec_len_matches_to_string() {
        for v in [0u64, 1, 9, 10, 99, 100, 999, 1000, 123_456, u64::MAX] {
            assert_eq!(dec_len(v), v.to_string().len(), "v={v}");
        }
    }

    #[test]
    fn fmt_dec_matches_to_string() {
        let mut buf = [0u8; 20];
        for v in [0u64, 7, 42, 999, 1_000, 98_765_432, u64::MAX] {
            assert_eq!(fmt_dec(v, &mut buf), v.to_string().as_bytes(), "v={v}");
        }
    }

    #[test]
    fn counts() {
        assert_eq!(parse_count("10k"), Some(10_000));
        assert_eq!(parse_count("1.5m"), Some(1_500_000));
        assert_eq!(parse_count("42"), Some(42));
    }
}

//! Deterministic PRNG (SplitMix64 + xoshiro256**), in-tree because the
//! offline vendor set has no `rand` crate. Used by workload generators,
//! the simulator's seeded repetitions and the property-test kit.

/// SplitMix64: seeds the main generator and is a fine generator itself
/// for non-crypto use.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (used for the simulator's noise).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct positions from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((8000..12000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}

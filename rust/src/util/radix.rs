//! LSD radix sorting for the shuffle's fixed-width records and the
//! reducer's numeric (key, index) group sort.
//!
//! The shuffle's hot regime — millions of items, fixed-width integer
//! keys, stability required — is exactly where radix methods dominate
//! comparison sorting (arXiv:1808.00963). Every sort here is a stable
//! byte-wise LSD pass with a 256-bucket counting scatter. A single
//! pre-scan builds the histogram of every digit at once, and passes
//! whose digit is constant across the input are skipped, so the common
//! case (partitions fit one byte, keys far below 2^64) performs only
//! the informative passes.

use crate::mapreduce::record::FixedRec;

/// A sort item with a fixed number of radix-256 digits, least
/// significant digit first.
pub trait RadixKey: Copy + Default {
    /// Number of byte digits in the sort key.
    const DIGITS: usize;
    /// Digit `d` (0 = least significant).
    fn digit(&self, d: usize) -> u8;
}

impl RadixKey for u128 {
    const DIGITS: usize = 16;
    #[inline]
    fn digit(&self, d: usize) -> u8 {
        (*self >> (8 * d)) as u8
    }
}

impl RadixKey for FixedRec {
    // Sort key is (partition, key): the key's 8 bytes are the low
    // digits, the partition's 4 bytes the high ones. The carried value
    // never participates — stability keeps equal (partition, key)
    // records in emission order, like the generic path's stable sort.
    const DIGITS: usize = 12;
    #[inline]
    fn digit(&self, d: usize) -> u8 {
        if d < 8 {
            (self.key >> (8 * d)) as u8
        } else {
            (self.partition >> (8 * (d - 8))) as u8
        }
    }
}

/// Stable LSD radix sort. `scratch` is resized to `data.len()` and
/// reused across calls, so steady-state sorting allocates nothing but
/// the per-call histogram (`DIGITS` × 1 KiB).
pub fn lsd_sort<T: RadixKey>(data: &mut [T], scratch: &mut Vec<T>) {
    let n = data.len();
    if n < 2 {
        return;
    }
    debug_assert!(n <= u32::MAX as usize, "radix counters are u32");
    scratch.clear();
    scratch.resize(n, T::default());

    // One pass over the data builds every digit's histogram.
    let mut hist = vec![[0u32; 256]; T::DIGITS];
    for item in data.iter() {
        for (d, h) in hist.iter_mut().enumerate() {
            h[item.digit(d) as usize] += 1;
        }
    }

    // Ping-pong between `data` and `scratch`, skipping constant digits.
    let mut in_data = true;
    for (d, h) in hist.iter().enumerate() {
        if h.iter().any(|&c| c as usize == n) {
            continue; // every item shares this digit: pass is a no-op
        }
        let mut offsets = [0u32; 256];
        let mut sum = 0u32;
        for (off, c) in offsets.iter_mut().zip(h.iter()) {
            *off = sum;
            sum += *c;
        }
        if in_data {
            scatter(data, scratch, d, &mut offsets);
        } else {
            scatter(scratch, data, d, &mut offsets);
        }
        in_data = !in_data;
    }
    if !in_data {
        data.copy_from_slice(scratch);
    }
}

#[inline]
fn scatter<T: RadixKey>(src: &[T], dst: &mut [T], d: usize, offsets: &mut [u32; 256]) {
    for item in src {
        let b = item.digit(d) as usize;
        dst[offsets[b] as usize] = *item;
        offsets[b] += 1;
    }
}

/// Sort a mapper spill buffer by (partition, key), stable in emission
/// order — the radix replacement for the generic path's
/// `sort_by(partition, key-bytes)` (byte-lexicographic order over an
/// 8-byte big-endian key equals unsigned numeric order).
pub fn sort_spill(recs: &mut [FixedRec], scratch: &mut Vec<FixedRec>) {
    lsd_sort(recs, scratch);
}

/// Lexicographic (key, index) pair sort over parallel `i64` arrays —
/// the radix backend of `runtime::native::group_sort`. Sign bits are
/// flipped into unsigned order, so the full `i64` range sorts exactly
/// like the comparison sort it replaces.
pub fn sort_pairs(keys: &mut [i64], indexes: &mut [i64]) {
    debug_assert_eq!(keys.len(), indexes.len());
    let mut packed: Vec<u128> = keys
        .iter()
        .zip(indexes.iter())
        .map(|(&k, &ix)| ((flip(k) as u128) << 64) | flip(ix) as u128)
        .collect();
    let mut scratch = Vec::new();
    lsd_sort(&mut packed, &mut scratch);
    for (i, p) in packed.iter().enumerate() {
        keys[i] = unflip((p >> 64) as u64);
        indexes[i] = unflip(*p as u64);
    }
}

#[inline]
fn flip(v: i64) -> u64 {
    (v as u64) ^ (1 << 63)
}

#[inline]
fn unflip(v: u64) -> i64 {
    (v ^ (1 << 63)) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sort_spill_matches_comparison_sort() {
        let mut rng = Rng::new(42);
        let mut recs: Vec<FixedRec> = (0..5000)
            .map(|i| FixedRec {
                partition: (rng.below(5)) as u32,
                key: rng.below(1 << 53),
                value: i as u64,
            })
            .collect();
        let mut want = recs.clone();
        want.sort_by(|a, b| {
            (a.partition, a.key.to_be_bytes()).cmp(&(b.partition, b.key.to_be_bytes()))
        });
        let mut scratch = Vec::new();
        sort_spill(&mut recs, &mut scratch);
        assert_eq!(recs, want);
    }

    #[test]
    fn sort_spill_is_stable() {
        // equal (partition, key): emission order (the value) survives
        let mut recs: Vec<FixedRec> = (0..100)
            .map(|i| FixedRec { partition: (i % 2) as u32, key: (i % 3) as u64, value: i as u64 })
            .collect();
        let mut scratch = Vec::new();
        sort_spill(&mut recs, &mut scratch);
        for w in recs.windows(2) {
            if (w[0].partition, w[0].key) == (w[1].partition, w[1].key) {
                assert!(w[0].value < w[1].value, "stability violated: {w:?}");
            }
        }
    }

    #[test]
    fn sort_spill_wide_partitions_and_keys() {
        // exercise the high digit passes the skip logic usually elides
        let mut rng = Rng::new(7);
        let mut recs: Vec<FixedRec> = (0..2000)
            .map(|v| FixedRec {
                partition: rng.next_u64() as u32,
                key: rng.next_u64(),
                value: v as u64,
            })
            .collect();
        let mut want = recs.clone();
        want.sort_by_key(|r| (r.partition, r.key));
        let mut scratch = Vec::new();
        sort_spill(&mut recs, &mut scratch);
        assert_eq!(recs, want);
    }

    #[test]
    fn sort_pairs_matches_comparison_sort_including_negatives() {
        let mut rng = Rng::new(9);
        let n = 3000;
        let mut keys: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
        let mut idxs: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
        let mut want: Vec<(i64, i64)> =
            keys.iter().copied().zip(idxs.iter().copied()).collect();
        want.sort_unstable();
        sort_pairs(&mut keys, &mut idxs);
        let got: Vec<(i64, i64)> = keys.into_iter().zip(idxs).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_and_singleton_are_noops() {
        let mut scratch = Vec::new();
        let mut empty: Vec<FixedRec> = Vec::new();
        sort_spill(&mut empty, &mut scratch);
        assert!(empty.is_empty());
        let mut one = vec![FixedRec { partition: 3, key: 9, value: 1 }];
        sort_spill(&mut one, &mut scratch);
        assert_eq!(one[0].value, 1);
        sort_pairs(&mut [], &mut []);
    }
}

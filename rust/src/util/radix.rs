//! LSD radix sorting for the shuffle's fixed-width records and the
//! reducer's numeric (key, index) group sort.
//!
//! The shuffle's hot regime — millions of items, fixed-width integer
//! keys, stability required — is exactly where radix methods dominate
//! comparison sorting (arXiv:1808.00963). Every sort here is a stable
//! byte-wise LSD pass with a 256-bucket counting scatter. A single
//! pre-scan builds the histogram of every digit at once, and passes
//! whose digit is constant across the input are skipped, so the common
//! case (partitions fit one byte, keys far below 2^64) performs only
//! the informative passes.

use crate::mapreduce::record::FixedRec;

/// A sort item with a fixed number of radix-256 digits, least
/// significant digit first.
pub trait RadixKey: Copy + Default {
    /// Number of byte digits in the sort key.
    const DIGITS: usize;
    /// Digit `d` (0 = least significant).
    fn digit(&self, d: usize) -> u8;
}

impl RadixKey for u128 {
    const DIGITS: usize = 16;
    #[inline]
    fn digit(&self, d: usize) -> u8 {
        (*self >> (8 * d)) as u8
    }
}

impl RadixKey for FixedRec {
    // Sort key is (partition, key): the key's 8 bytes are the low
    // digits, the partition's 4 bytes the high ones. The carried value
    // never participates — stability keeps equal (partition, key)
    // records in emission order, like the generic path's stable sort.
    const DIGITS: usize = 12;
    #[inline]
    fn digit(&self, d: usize) -> u8 {
        if d < 8 {
            (self.key >> (8 * d)) as u8
        } else {
            (self.partition >> (8 * (d - 8))) as u8
        }
    }
}

/// Stable LSD radix sort. `scratch` is resized to `data.len()` and
/// reused across calls, so steady-state sorting allocates nothing but
/// the per-call histogram (`DIGITS` × 1 KiB).
pub fn lsd_sort<T: RadixKey>(data: &mut [T], scratch: &mut Vec<T>) {
    let n = data.len();
    if n < 2 {
        return;
    }
    debug_assert!(n <= u32::MAX as usize, "radix counters are u32");
    scratch.clear();
    scratch.resize(n, T::default());

    // One pass over the data builds every digit's histogram.
    let mut hist = vec![[0u32; 256]; T::DIGITS];
    for item in data.iter() {
        for (d, h) in hist.iter_mut().enumerate() {
            h[item.digit(d) as usize] += 1;
        }
    }

    // Ping-pong between `data` and `scratch`, skipping constant digits.
    let mut in_data = true;
    for (d, h) in hist.iter().enumerate() {
        if h.iter().any(|&c| c as usize == n) {
            continue; // every item shares this digit: pass is a no-op
        }
        let mut offsets = [0u32; 256];
        let mut sum = 0u32;
        for (off, c) in offsets.iter_mut().zip(h.iter()) {
            *off = sum;
            sum += *c;
        }
        if in_data {
            scatter(data, scratch, d, &mut offsets);
        } else {
            scatter(scratch, data, d, &mut offsets);
        }
        in_data = !in_data;
    }
    if !in_data {
        data.copy_from_slice(scratch);
    }
}

#[inline]
fn scatter<T: RadixKey>(src: &[T], dst: &mut [T], d: usize, offsets: &mut [u32; 256]) {
    for item in src {
        let b = item.digit(d) as usize;
        dst[offsets[b] as usize] = *item;
        offsets[b] += 1;
    }
}

// ---------------- parallel path ----------------

/// Fewest items per worker chunk before [`lsd_sort_threads`] engages its
/// parallel scatter — below this, thread spawn and cache-line contention
/// cost more than they save, so the call degrades to [`lsd_sort`]
/// (byte-identical output either way; see `tests/sort_equivalence.rs`).
const PAR_MIN_PER_CHUNK: usize = 1 << 13;

/// A raw destination pointer that may cross thread boundaries. Each
/// scatter thread writes a provably disjoint index set (see
/// [`par_scatter`]), which is what makes sharing it sound.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}

/// Parallel stable LSD radix sort — the same digit plan, digit skipping,
/// and ping-pong as [`lsd_sort`], with each pass's histogram and scatter
/// split over `threads` contiguous chunks. The scatter is deterministic:
/// within every bucket the destination region is partitioned
/// chunk-major (chunk 0's items first, then chunk 1's, ...), and each
/// chunk scatters in input order — so equal digits land in global input
/// order, exactly as the sequential scatter places them. Output is
/// therefore byte-identical to [`lsd_sort`] for every input and thread
/// count, independent of scheduling.
///
/// `threads <= 1` dispatches the literal sequential [`lsd_sort`] — the
/// equivalence baseline, not a 1-thread instance of this code.
pub fn lsd_sort_threads<T: RadixKey + Send + Sync>(
    data: &mut [T],
    scratch: &mut Vec<T>,
    threads: usize,
) {
    let n = data.len();
    if threads <= 1 {
        return lsd_sort(data, scratch);
    }
    let chunks = threads.min(n / PAR_MIN_PER_CHUNK);
    if chunks < 2 {
        return lsd_sort(data, scratch);
    }
    debug_assert!(n <= u32::MAX as usize, "radix counters are u32");
    scratch.clear();
    scratch.resize(n, T::default());

    // chunk c covers [bounds[c], bounds[c+1]) of the current source
    let bounds: Vec<usize> = (0..=chunks).map(|c| c * n / chunks).collect();

    // Parallel pre-scan: per-chunk histograms of every digit at once,
    // reduced to the global histogram for the skip test. The per-chunk
    // counts stay valid for the first executed pass (items have not
    // moved yet), so that pass skips its counting sweep.
    let chunk_hists = par_all_digit_counts(&*data, &bounds);
    let mut hist = vec![[0u32; 256]; T::DIGITS];
    for ch in &chunk_hists {
        for (d, hd) in ch.iter().enumerate() {
            for (b, c) in hd.iter().enumerate() {
                hist[d][b] += *c;
            }
        }
    }

    let mut in_data = true;
    let mut first_pass = true;
    for (d, h) in hist.iter().enumerate() {
        if h.iter().any(|&c| c as usize == n) {
            continue; // every item shares this digit: pass is a no-op
        }
        // per-chunk counts of digit d over the CURRENT source layout
        let counts: Vec<[u32; 256]> = if first_pass {
            chunk_hists.iter().map(|ch| ch[d]).collect()
        } else if in_data {
            par_digit_counts(&*data, &bounds, d)
        } else {
            par_digit_counts(scratch, &bounds, d)
        };
        first_pass = false;
        // exclusive prefix sums in (bucket, chunk) order: bucket b's
        // destination region starts after all smaller buckets and is
        // itself laid out chunk-major — the stability invariant.
        let mut starts: Vec<[u32; 256]> = vec![[0u32; 256]; chunks];
        let mut sum = 0u32;
        for b in 0..256 {
            for (c, st) in starts.iter_mut().enumerate() {
                st[b] = sum;
                sum += counts[c][b];
            }
        }
        if in_data {
            par_scatter(&*data, scratch, &bounds, d, starts);
        } else {
            par_scatter(scratch, data, &bounds, d, starts);
        }
        in_data = !in_data;
    }
    if !in_data {
        data.copy_from_slice(scratch);
    }
}

/// Histogram every digit of every chunk of `src` at once, in parallel —
/// the parallel analogue of [`lsd_sort`]'s single pre-scan.
fn par_all_digit_counts<T: RadixKey + Send + Sync>(
    src: &[T],
    bounds: &[usize],
) -> Vec<Vec<[u32; 256]>> {
    std::thread::scope(|s| {
        let handles: Vec<_> = bounds
            .windows(2)
            .map(|w| {
                let chunk = &src[w[0]..w[1]];
                s.spawn(move || {
                    let mut h = vec![[0u32; 256]; T::DIGITS];
                    for item in chunk {
                        for (d, hd) in h.iter_mut().enumerate() {
                            hd[item.digit(d) as usize] += 1;
                        }
                    }
                    h
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("histogram thread")).collect()
    })
}

/// Count digit `d` per chunk of `src`, in parallel.
fn par_digit_counts<T: RadixKey + Send + Sync>(
    src: &[T],
    bounds: &[usize],
    d: usize,
) -> Vec<[u32; 256]> {
    std::thread::scope(|s| {
        let handles: Vec<_> = bounds
            .windows(2)
            .map(|w| {
                let chunk = &src[w[0]..w[1]];
                s.spawn(move || {
                    let mut h = [0u32; 256];
                    for item in chunk {
                        h[item.digit(d) as usize] += 1;
                    }
                    h
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("count thread")).collect()
    })
}

/// Scatter every chunk of `src` into `dst` concurrently, chunk c using
/// its own running cursors `starts[c]`.
///
/// Safety of the shared `dst` pointer: the cursor construction in
/// [`lsd_sort_threads`] gives chunk c exactly `counts[c][b]` slots in
/// bucket b starting at `starts[c][b]`, and those slot ranges tile
/// [0, n) without overlap across all (bucket, chunk) pairs — every
/// `dst` index is written by exactly one thread, exactly once.
fn par_scatter<T: RadixKey + Send + Sync>(
    src: &[T],
    dst: &mut [T],
    bounds: &[usize],
    d: usize,
    starts: Vec<[u32; 256]>,
) {
    let dst_base = dst.as_mut_ptr();
    std::thread::scope(|s| {
        for (c, mut offsets) in starts.into_iter().enumerate() {
            let chunk = &src[bounds[c]..bounds[c + 1]];
            let dst = SendPtr(dst_base);
            s.spawn(move || {
                // destructure the whole wrapper so the closure captures
                // `SendPtr` (Send), not the raw pointer field
                let SendPtr(dst) = dst;
                for item in chunk {
                    let b = item.digit(d) as usize;
                    // SAFETY: disjoint (bucket, chunk) slot ranges — see
                    // the function-level invariant above.
                    unsafe { *dst.add(offsets[b] as usize) = *item };
                    offsets[b] += 1;
                }
            });
        }
    });
}

/// Sort a mapper spill buffer by (partition, key), stable in emission
/// order — the radix replacement for the generic path's
/// `sort_by(partition, key-bytes)` (byte-lexicographic order over an
/// 8-byte big-endian key equals unsigned numeric order).
pub fn sort_spill(recs: &mut [FixedRec], scratch: &mut Vec<FixedRec>) {
    lsd_sort(recs, scratch);
}

/// [`sort_spill`] with the spill buffer split over `threads` scatter
/// chunks. `threads <= 1` calls the literal sequential [`sort_spill`];
/// any thread count produces byte-identical output (stability included)
/// — proven in `tests/sort_equivalence.rs`.
pub fn sort_spill_threads(recs: &mut [FixedRec], scratch: &mut Vec<FixedRec>, threads: usize) {
    if threads <= 1 {
        sort_spill(recs, scratch);
    } else {
        lsd_sort_threads(recs, scratch, threads);
    }
}

/// Lexicographic (key, index) pair sort over parallel `i64` arrays —
/// the radix backend of `runtime::native::group_sort`. Sign bits are
/// flipped into unsigned order, so the full `i64` range sorts exactly
/// like the comparison sort it replaces.
pub fn sort_pairs(keys: &mut [i64], indexes: &mut [i64]) {
    debug_assert_eq!(keys.len(), indexes.len());
    let mut packed: Vec<u128> = keys
        .iter()
        .zip(indexes.iter())
        .map(|(&k, &ix)| ((flip(k) as u128) << 64) | flip(ix) as u128)
        .collect();
    let mut scratch = Vec::new();
    lsd_sort(&mut packed, &mut scratch);
    for (i, p) in packed.iter().enumerate() {
        keys[i] = unflip((p >> 64) as u64);
        indexes[i] = unflip(*p as u64);
    }
}

/// [`sort_pairs`] with the radix passes split over `threads` chunks.
/// The pack/unpack sweeps stay sequential (they are order-preserving
/// maps); only the sort itself parallelizes. `threads <= 1` calls the
/// literal sequential [`sort_pairs`].
pub fn sort_pairs_threads(keys: &mut [i64], indexes: &mut [i64], threads: usize) {
    if threads <= 1 {
        return sort_pairs(keys, indexes);
    }
    debug_assert_eq!(keys.len(), indexes.len());
    let mut packed: Vec<u128> = keys
        .iter()
        .zip(indexes.iter())
        .map(|(&k, &ix)| ((flip(k) as u128) << 64) | flip(ix) as u128)
        .collect();
    let mut scratch = Vec::new();
    lsd_sort_threads(&mut packed, &mut scratch, threads);
    for (i, p) in packed.iter().enumerate() {
        keys[i] = unflip((p >> 64) as u64);
        indexes[i] = unflip(*p as u64);
    }
}

#[inline]
fn flip(v: i64) -> u64 {
    (v as u64) ^ (1 << 63)
}

#[inline]
fn unflip(v: u64) -> i64 {
    (v ^ (1 << 63)) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sort_spill_matches_comparison_sort() {
        let mut rng = Rng::new(42);
        let mut recs: Vec<FixedRec> = (0..5000)
            .map(|i| FixedRec {
                partition: (rng.below(5)) as u32,
                key: rng.below(1 << 53),
                value: i as u64,
            })
            .collect();
        let mut want = recs.clone();
        want.sort_by(|a, b| {
            (a.partition, a.key.to_be_bytes()).cmp(&(b.partition, b.key.to_be_bytes()))
        });
        let mut scratch = Vec::new();
        sort_spill(&mut recs, &mut scratch);
        assert_eq!(recs, want);
    }

    #[test]
    fn sort_spill_is_stable() {
        // equal (partition, key): emission order (the value) survives
        let mut recs: Vec<FixedRec> = (0..100)
            .map(|i| FixedRec { partition: (i % 2) as u32, key: (i % 3) as u64, value: i as u64 })
            .collect();
        let mut scratch = Vec::new();
        sort_spill(&mut recs, &mut scratch);
        for w in recs.windows(2) {
            if (w[0].partition, w[0].key) == (w[1].partition, w[1].key) {
                assert!(w[0].value < w[1].value, "stability violated: {w:?}");
            }
        }
    }

    #[test]
    fn sort_spill_wide_partitions_and_keys() {
        // exercise the high digit passes the skip logic usually elides
        let mut rng = Rng::new(7);
        let mut recs: Vec<FixedRec> = (0..2000)
            .map(|v| FixedRec {
                partition: rng.next_u64() as u32,
                key: rng.next_u64(),
                value: v as u64,
            })
            .collect();
        let mut want = recs.clone();
        want.sort_by_key(|r| (r.partition, r.key));
        let mut scratch = Vec::new();
        sort_spill(&mut recs, &mut scratch);
        assert_eq!(recs, want);
    }

    #[test]
    fn sort_pairs_matches_comparison_sort_including_negatives() {
        let mut rng = Rng::new(9);
        let n = 3000;
        let mut keys: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
        let mut idxs: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
        let mut want: Vec<(i64, i64)> =
            keys.iter().copied().zip(idxs.iter().copied()).collect();
        want.sort_unstable();
        sort_pairs(&mut keys, &mut idxs);
        let got: Vec<(i64, i64)> = keys.into_iter().zip(idxs).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_sort_matches_sequential_above_engage_threshold() {
        // big enough that lsd_sort_threads actually splits into chunks
        let n = 4 * PAR_MIN_PER_CHUNK + 37;
        let mut rng = Rng::new(11);
        let base: Vec<FixedRec> = (0..n)
            .map(|v| FixedRec {
                partition: rng.below(7) as u32,
                key: rng.below(1 << 20), // duplicate-heavy: stability matters
                value: v as u64,
            })
            .collect();
        let mut want = base.clone();
        let mut scratch = Vec::new();
        sort_spill(&mut want, &mut scratch);
        for threads in [2, 3, 8] {
            let mut got = base.clone();
            sort_spill_threads(&mut got, &mut scratch, threads);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn parallel_pairs_match_sequential() {
        let n = 2 * PAR_MIN_PER_CHUNK + 5;
        let mut rng = Rng::new(23);
        let keys0: Vec<i64> = (0..n).map(|_| rng.below(512) as i64 - 256).collect();
        let idxs0: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
        let (mut k_seq, mut i_seq) = (keys0.clone(), idxs0.clone());
        sort_pairs(&mut k_seq, &mut i_seq);
        for threads in [2, 8] {
            let (mut k, mut i) = (keys0.clone(), idxs0.clone());
            sort_pairs_threads(&mut k, &mut i, threads);
            assert_eq!(k, k_seq, "keys, threads={threads}");
            assert_eq!(i, i_seq, "indexes, threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_are_noops() {
        let mut scratch = Vec::new();
        let mut empty: Vec<FixedRec> = Vec::new();
        sort_spill(&mut empty, &mut scratch);
        assert!(empty.is_empty());
        let mut one = vec![FixedRec { partition: 3, key: 9, value: 1 }];
        sort_spill(&mut one, &mut scratch);
        assert_eq!(one[0].value, 1);
        sort_pairs(&mut [], &mut []);
    }
}

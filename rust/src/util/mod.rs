//! Shared utilities: PRNG, statistics, byte formatting.

pub mod bytes;
pub mod rng;
pub mod stats;

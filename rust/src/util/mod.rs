//! Shared utilities: PRNG, statistics, byte formatting, radix sorting.

pub mod bytes;
pub mod radix;
pub mod rng;
pub mod stats;

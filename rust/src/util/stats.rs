//! Small statistics helpers (μ, σ, percentiles) used by the simulator's
//! repeated-trial reporting and the bench harness.

/// Mean of a sample.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator), matching how the paper
/// reports σ over 5 repetitions.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Summary of repeated trials.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MuSigma {
    pub mu: f64,
    pub sigma: f64,
    pub n: usize,
}

impl MuSigma {
    pub fn of(xs: &[f64]) -> Self {
        Self { mu: mean(xs), sigma: stddev(xs), n: xs.len() }
    }
}

impl std::fmt::Display for MuSigma {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "μ={:.1}; σ={:.2}", self.mu, self.sigma)
    }
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Least-squares fit of y = a·x + b; returns (a, b, r²).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let a = sxy / sxx;
    let b = my - a * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a * x + b);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn musigma_display() {
        let s = MuSigma::of(&[61.0, 62.0, 63.0]);
        assert_eq!(format!("{s}"), "μ=62.0; σ=1.00");
    }
}

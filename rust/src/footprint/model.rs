//! The paper's scalability/efficiency models (§IV-D):
//!
//!   f(x) = a·x + b   if x < breakdown,   N/A otherwise
//!
//! `a` and `breakdown` characterize scalability₁ (workload growth without
//! added resources), `b` lumps parallelization/acceleration and relates to
//! scalability₂. Efficiency of spending extra memory is
//! `speedup / mem_ratio` (Table VIII).

use crate::util::stats::linear_fit;

/// One measured point of a scalability series.
#[derive(Clone, Copy, Debug)]
pub struct ScalePoint {
    /// Input size (bytes).
    pub x: f64,
    /// Elapsed time (minutes), μ over repetitions.
    pub minutes: f64,
    /// σ over repetitions.
    pub sigma: f64,
    /// Did the system complete reliably at this size?
    pub completed: bool,
}

/// Fitted f(x) = a·x + b with a breakdown threshold.
#[derive(Clone, Copy, Debug)]
pub struct ScalabilityModel {
    /// Slope (minutes per byte) over the linear region.
    pub a: f64,
    /// Intercept (minutes).
    pub b: f64,
    /// R² of the linear region fit.
    pub r2: f64,
    /// Smallest input size at which the system broke down (None = never
    /// observed within the series).
    pub breakdown: Option<f64>,
}

impl ScalabilityModel {
    /// Fit from a series: the linear region is every completed point below
    /// the first failure; breakdown is the first non-completed (or wildly
    /// off-trend) size.
    pub fn fit(points: &[ScalePoint]) -> ScalabilityModel {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut breakdown = None;
        for p in points {
            if !p.completed {
                breakdown = breakdown.or(Some(p.x));
                continue;
            }
            if breakdown.is_none() {
                xs.push(p.x);
                ys.push(p.minutes);
            }
        }
        // off-trend detection: a completed point whose time exceeds the
        // extrapolated fit by >50% also marks a breakdown (the paper's
        // Case 5 completed once out of five but off-trend).
        let (a, b, r2) = if xs.len() >= 2 {
            linear_fit(&xs, &ys)
        } else {
            (f64::NAN, f64::NAN, f64::NAN)
        };
        if breakdown.is_none() && xs.len() >= 3 {
            let (a2, b2, _) = linear_fit(&xs[..xs.len() - 1], &ys[..ys.len() - 1]);
            let last_x = xs[xs.len() - 1];
            let predicted = a2 * last_x + b2;
            if ys[ys.len() - 1] > predicted * 1.5 {
                breakdown = Some(last_x);
                let (a3, b3, r3) = linear_fit(&xs[..xs.len() - 1], &ys[..ys.len() - 1]);
                return ScalabilityModel { a: a3, b: b3, r2: r3, breakdown };
            }
        }
        ScalabilityModel { a, b, r2, breakdown }
    }

    /// Predicted minutes at size x (None above breakdown — "N/A").
    pub fn predict(&self, x: f64) -> Option<f64> {
        match self.breakdown {
            Some(bd) if x >= bd => None,
            _ => Some(self.a * x + self.b),
        }
    }
}

/// Table VIII's efficiency: `speedup / mem_ratio`, where speedup is
/// baseline-time / variant-time at the same input size and mem_ratio is
/// variant-memory / baseline-memory.
pub fn efficiency(baseline_minutes: f64, variant_minutes: f64, mem_ratio: f64) -> f64 {
    (baseline_minutes / variant_minutes) / mem_ratio
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(x: f64, m: f64, ok: bool) -> ScalePoint {
        ScalePoint { x, minutes: m, sigma: 1.0, completed: ok }
    }

    #[test]
    fn fits_linear_region() {
        // paper Table III shape: linear through case 4, breakdown case 5
        let pts = [
            pt(0.637, 61.8, true),
            pt(1.24, 143.4, true),
            pt(1.86, 230.4, true),
            pt(2.49, 312.0, true),
            pt(3.37, 709.4, false),
        ];
        let m = ScalabilityModel::fit(&pts);
        assert!(m.breakdown == Some(3.37));
        assert!(m.r2 > 0.99, "r2={}", m.r2);
        assert!((m.a - 135.0).abs() < 10.0, "a={}", m.a);
        assert!(m.predict(3.5).is_none());
        assert!(m.predict(1.0).unwrap() > 0.0);
    }

    #[test]
    fn off_trend_completed_point_is_breakdown() {
        // completes but wildly off-trend (paper's Case 5 with one success)
        let pts = [
            pt(1.0, 100.0, true),
            pt(2.0, 200.0, true),
            pt(3.0, 300.0, true),
            pt(4.0, 900.0, true),
        ];
        let m = ScalabilityModel::fit(&pts);
        assert_eq!(m.breakdown, Some(4.0));
        assert!((m.a - 100.0).abs() < 1e-6);
    }

    #[test]
    fn no_breakdown_when_linear() {
        let pts = [pt(1.0, 110.0, true), pt(2.0, 210.0, true), pt(3.0, 310.0, true)];
        let m = ScalabilityModel::fit(&pts);
        assert!(m.breakdown.is_none());
        assert!((m.b - 10.0).abs() < 1e-6);
    }

    #[test]
    fn efficiency_matches_table8_arithmetic() {
        // paper Table VIII, mem_heap Case 1: speedup 61.8/66.6, ratio 2
        let e = efficiency(61.8, 66.6, 2.0);
        assert!((e - 0.464).abs() < 0.001, "e={e}");
        // scheme can exceed 1.0 when mem_ratio ~ 1
        assert!(efficiency(100.0, 50.0, 1.1) > 1.0);
    }
}

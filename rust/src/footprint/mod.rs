//! Data store footprint — the paper's analytical instrument (§III).
//!
//! "Tracking how much the effective data is read from or written in the
//! storages": deterministic, invariant under stragglers/failures, and
//! commensurate with the time a system is *supposed* to take. The ledger
//! mirrors the models of Fig. 2 (TeraSort) and Fig. 6(a) (scheme): local
//! disk R/W on the map and reduce sides, HDFS R/W, shuffled bytes, plus
//! the scheme's KV-store channels.

pub mod model;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Storage channels of the footprint models (Fig. 2 / Fig. 6(a)).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Channel {
    HdfsRead,
    HdfsWrite,
    MapLocalRead,
    MapLocalWrite,
    Shuffle,
    ReduceLocalRead,
    ReduceLocalWrite,
    /// Scheme only: reads PUT into the in-memory store (network).
    KvPut,
    /// Scheme only: suffixes fetched from the store (network).
    KvFetch,
}

pub const CHANNELS: [Channel; 9] = [
    Channel::HdfsRead,
    Channel::HdfsWrite,
    Channel::MapLocalRead,
    Channel::MapLocalWrite,
    Channel::Shuffle,
    Channel::ReduceLocalRead,
    Channel::ReduceLocalWrite,
    Channel::KvPut,
    Channel::KvFetch,
];

impl Channel {
    pub fn name(&self) -> &'static str {
        match self {
            Channel::HdfsRead => "HDFS Read",
            Channel::HdfsWrite => "HDFS Write",
            Channel::MapLocalRead => "Local Read (Map)",
            Channel::MapLocalWrite => "Local Write (Map)",
            Channel::Shuffle => "Shuffle",
            Channel::ReduceLocalRead => "Local Read (Reduce)",
            Channel::ReduceLocalWrite => "Local Write (Reduce)",
            Channel::KvPut => "KV Put",
            Channel::KvFetch => "KV Fetch",
        }
    }

    fn slot(&self) -> usize {
        CHANNELS.iter().position(|c| c == self).unwrap()
    }
}

/// Thread-safe byte ledger, shared by every task of a job.
#[derive(Debug, Default)]
pub struct Ledger {
    bytes: [AtomicU64; 9],
}

thread_local! {
    /// Per-thread ledger redirection: charges aimed at the ledger whose
    /// address matches `.0` land on `.1` instead. Installed by
    /// [`Ledger::redirect_for_attempt`] for the duration of one task
    /// attempt, so the attempt's charges can be kept or discarded
    /// atomically without changing any task/factory signature.
    static REDIRECT: RefCell<Option<(usize, Arc<Ledger>)>> = const { RefCell::new(None) };
}

/// RAII guard for a task-attempt ledger redirection; restores the
/// previous redirection (normally none) on drop — including during an
/// unwind, so a panicking attempt cannot leak its redirection onto the
/// pool thread.
pub struct AttemptScope {
    prev: Option<(usize, Arc<Ledger>)>,
}

impl Drop for AttemptScope {
    fn drop(&mut self) {
        REDIRECT.with(|r| *r.borrow_mut() = self.prev.take());
    }
}

impl Ledger {
    pub fn new() -> Arc<Ledger> {
        Arc::new(Ledger::default())
    }

    /// Redirect this thread's charges on `job` to `attempt` until the
    /// returned guard drops. Only charges addressed at `job` *by
    /// pointer identity* are redirected — charges on any other ledger
    /// (including `attempt` itself) pass through untouched. Sound for
    /// task attempts because every charge of an attempt happens on the
    /// task's own thread (the prefetch thread never touches the ledger;
    /// fetch traffic is charged by `account_fetch` on the task thread).
    pub fn redirect_for_attempt(job: &Arc<Ledger>, attempt: &Arc<Ledger>) -> AttemptScope {
        let key = Arc::as_ptr(job) as usize;
        let prev = REDIRECT.with(|r| r.borrow_mut().replace((key, attempt.clone())));
        AttemptScope { prev }
    }

    fn redirect_target(&self) -> Option<Arc<Ledger>> {
        REDIRECT.with(|r| {
            r.borrow().as_ref().and_then(|(from, to)| {
                (*from == self as *const Ledger as usize).then(|| to.clone())
            })
        })
    }

    pub fn add(&self, ch: Channel, bytes: u64) {
        if let Some(target) = self.redirect_target() {
            target.bytes[ch.slot()].fetch_add(bytes, Ordering::Relaxed);
            return;
        }
        self.bytes[ch.slot()].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Fold a snapshot's totals into this ledger (bypassing any
    /// redirection — used to merge a *finished* attempt into the job).
    pub fn add_footprint(&self, fp: &Footprint) {
        for ch in CHANNELS {
            self.bytes[ch.slot()].fetch_add(fp.get(ch), Ordering::Relaxed);
        }
    }

    pub fn get(&self, ch: Channel) -> u64 {
        self.bytes[ch.slot()].load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> Footprint {
        let mut fp = Footprint::default();
        for ch in CHANNELS {
            fp.bytes[ch.slot()] = self.get(ch);
        }
        fp
    }

    pub fn reset(&self) {
        for b in &self.bytes {
            b.store(0, Ordering::Relaxed);
        }
    }
}

/// Immutable snapshot of a ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Footprint {
    bytes: [u64; 9],
}

impl Footprint {
    pub fn get(&self, ch: Channel) -> u64 {
        self.bytes[ch.slot()]
    }

    pub fn set(&mut self, ch: Channel, v: u64) {
        self.bytes[ch.slot()] = v;
    }

    pub fn add(&mut self, ch: Channel, v: u64) {
        self.bytes[ch.slot()] += v;
    }

    /// Units relative to a reference size — the paper normalizes TeraSort
    /// tables by input size and scheme tables by output size.
    pub fn normalized(&self, ch: Channel, reference: u64) -> f64 {
        self.get(ch) as f64 / reference as f64
    }

    /// Total local-disk traffic (the quantity whose growth breaks
    /// TeraSort's scalability).
    pub fn local_disk_total(&self) -> u64 {
        self.get(Channel::MapLocalRead)
            + self.get(Channel::MapLocalWrite)
            + self.get(Channel::ReduceLocalRead)
            + self.get(Channel::ReduceLocalWrite)
    }

    /// Total network traffic (shuffle + KV channels).
    pub fn network_total(&self) -> u64 {
        self.get(Channel::Shuffle) + self.get(Channel::KvPut) + self.get(Channel::KvFetch)
    }

    pub fn merged(mut self, other: &Footprint) -> Footprint {
        for ch in CHANNELS {
            self.bytes[ch.slot()] += other.get(ch);
        }
        self
    }
}

impl std::fmt::Display for Footprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for ch in CHANNELS {
            if self.get(ch) > 0 {
                writeln!(
                    f,
                    "{:<22} {}",
                    ch.name(),
                    crate::util::bytes::human(self.get(ch))
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_and_snapshots() {
        let l = Ledger::new();
        l.add(Channel::MapLocalWrite, 100);
        l.add(Channel::MapLocalWrite, 50);
        l.add(Channel::Shuffle, 7);
        let fp = l.snapshot();
        assert_eq!(fp.get(Channel::MapLocalWrite), 150);
        assert_eq!(fp.get(Channel::Shuffle), 7);
        assert_eq!(fp.get(Channel::HdfsRead), 0);
        l.reset();
        assert_eq!(l.snapshot().get(Channel::Shuffle), 0);
    }

    #[test]
    fn normalization() {
        let mut fp = Footprint::default();
        fp.set(Channel::MapLocalWrite, 207);
        assert!((fp.normalized(Channel::MapLocalWrite, 100) - 2.07).abs() < 1e-9);
    }

    #[test]
    fn totals() {
        let mut fp = Footprint::default();
        fp.set(Channel::MapLocalRead, 1);
        fp.set(Channel::MapLocalWrite, 2);
        fp.set(Channel::ReduceLocalRead, 4);
        fp.set(Channel::ReduceLocalWrite, 8);
        fp.set(Channel::Shuffle, 16);
        fp.set(Channel::KvPut, 32);
        fp.set(Channel::KvFetch, 64);
        assert_eq!(fp.local_disk_total(), 15);
        assert_eq!(fp.network_total(), 112);
    }

    #[test]
    fn merge() {
        let mut a = Footprint::default();
        a.set(Channel::HdfsRead, 5);
        let mut b = Footprint::default();
        b.set(Channel::HdfsRead, 6);
        b.set(Channel::HdfsWrite, 1);
        let m = a.merged(&b);
        assert_eq!(m.get(Channel::HdfsRead), 11);
        assert_eq!(m.get(Channel::HdfsWrite), 1);
    }

    #[test]
    fn redirect_scopes_charges_to_the_attempt_ledger() {
        let job = Ledger::new();
        let attempt = Ledger::new();
        let other = Ledger::new();
        {
            let _scope = Ledger::redirect_for_attempt(&job, &attempt);
            job.add(Channel::HdfsRead, 10); // redirected
            other.add(Channel::HdfsRead, 3); // different ledger: untouched
            attempt.add(Channel::Shuffle, 5); // direct on the attempt
        }
        assert_eq!(job.get(Channel::HdfsRead), 0);
        assert_eq!(attempt.get(Channel::HdfsRead), 10);
        assert_eq!(attempt.get(Channel::Shuffle), 5);
        assert_eq!(other.get(Channel::HdfsRead), 3);
        // Guard dropped: charges land on the job ledger again.
        job.add(Channel::HdfsRead, 7);
        assert_eq!(job.get(Channel::HdfsRead), 7);
    }

    #[test]
    fn redirect_is_per_thread_and_unwind_safe() {
        let job = Ledger::new();
        let attempt = Ledger::new();
        let _scope = Ledger::redirect_for_attempt(&job, &attempt);
        // Another thread's charges on the job ledger are not redirected.
        let j = job.clone();
        std::thread::spawn(move || j.add(Channel::Shuffle, 9))
            .join()
            .unwrap();
        assert_eq!(job.get(Channel::Shuffle), 9);
        // A panic inside a scope still restores the thread's state.
        let job2 = Ledger::new();
        let att2 = Ledger::new();
        let j2 = job2.clone();
        let a2 = att2.clone();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _s = Ledger::redirect_for_attempt(&j2, &a2);
            j2.add(Channel::KvPut, 1);
            panic!("boom");
        }));
        assert!(r.is_err());
        job2.add(Channel::KvPut, 2);
        assert_eq!(job2.get(Channel::KvPut), 2);
        assert_eq!(att2.get(Channel::KvPut), 1);
    }

    #[test]
    fn add_footprint_merges_totals() {
        let l = Ledger::new();
        let mut fp = Footprint::default();
        fp.set(Channel::HdfsRead, 4);
        fp.set(Channel::KvFetch, 6);
        l.add(Channel::HdfsRead, 1);
        l.add_footprint(&fp);
        assert_eq!(l.get(Channel::HdfsRead), 5);
        assert_eq!(l.get(Channel::KvFetch), 6);
    }

    #[test]
    fn threaded_ledger() {
        let l = Ledger::new();
        let mut hs = Vec::new();
        for _ in 0..8 {
            let l = l.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    l.add(Channel::Shuffle, 1);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(l.get(Channel::Shuffle), 8000);
    }
}

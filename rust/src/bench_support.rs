//! Criterion-substitute bench harness (the offline vendor set has no
//! criterion): warmup + timed iterations, mean ± σ, throughput report.

use std::time::{Duration, Instant};

use crate::util::stats::{mean, stddev};

/// One timed measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub sigma: Duration,
    /// Optional units-per-iteration for throughput reporting.
    pub units: Option<(f64, &'static str)>,
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12.3?} ± {:>9.3?}  ({} iters)",
            self.name, self.mean, self.sigma, self.iters
        )?;
        if let Some((units, label)) = self.units {
            let per_sec = units / self.mean.as_secs_f64();
            write!(f, "  {:>12.0} {label}/s", per_sec)?;
        }
        Ok(())
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(mean(&times)),
        sigma: Duration::from_secs_f64(stddev(&times)),
        units: None,
    }
}

/// Like [`bench`] but reports `units` of work per iteration (throughput).
pub fn bench_throughput(
    name: &str,
    warmup: usize,
    iters: usize,
    units: f64,
    label: &'static str,
    f: impl FnMut(),
) -> Measurement {
    let mut m = bench(name, warmup, iters, f);
    m.units = Some((units, label));
    m
}

/// Standard bench header so `cargo bench` output is navigable.
pub fn section(title: &str) {
    println!("\n––– {title} –––");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let m = bench("spin", 1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(m.iters, 3);
        assert!(m.mean.as_nanos() > 0);
    }

    #[test]
    fn throughput_renders() {
        let m = bench_throughput("t", 0, 2, 1000.0, "recs", || {});
        let s = format!("{m}");
        assert!(s.contains("recs/s"), "{s}");
    }
}

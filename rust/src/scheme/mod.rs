//! The paper's scheme (§IV): **keep only the raw data in place**.
//!
//! Mappers put the raw reads into the distributed in-memory store and
//! shuffle only fixed-width (base-5 prefix key, packed index) pairs;
//! reducers accumulate sorting groups, fetch the suffix texts in bulk via
//! `MGETSUFFIX`, tie-break equal-prefix groups, and emit the sorted
//! output. MapReduce never carries a suffix — only its index.
//!
//! [`run`] builds over one input file; [`run_files`] over several — the
//! paper's pair-end Case 6, where two mate files feed one shared store
//! and one joint shuffled index stream. [`run_files_sealed`] is the
//! serving ending: instead of materializing the order in memory, it
//! streams the reducer output into a sealed on-disk index artifact
//! (`crate::suffix::sealed`) that the query tier loads and serves.

pub mod gc_model;
pub mod sampler;
pub mod sorting_group;

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::footprint::{Channel, Ledger};
use crate::kvstore::batch::SuffixBatch;
use crate::kvstore::prefetch::SuffixPrefetcher;
use crate::kvstore::shard::{SuffixStore, Traffic};
use crate::mapreduce::engine::{run_job, Job, JobResult, ScratchDir};
use crate::mapreduce::io::SplitWriter;
use crate::mapreduce::job::JobConf;
use crate::mapreduce::merge::{kway_merge_pairs, kway_merge_pairs_threads};
use crate::mapreduce::partitioner::SAMPLES_PER_REDUCER;
use crate::mapreduce::record::{decode_i64_key, encode_i64_key, Record};
use crate::runtime::{self, native};
use crate::suffix::encode::{key_common_prefix, unpack_index, DEFAULT_PREFIX_LEN};
use crate::suffix::reads::{spool_read_records, Read};
use crate::suffix::sealed::{SealWriter, BWT_TERMINATOR};
use sorting_group::{
    complete_key_len, key_groups, key_is_complete, tie_break_positions, SortingGroupBuffer,
};

/// Scheme configuration (paper defaults, scaled knobs in `JobConf`).
#[derive(Clone, Debug)]
pub struct SchemeConfig {
    /// MapReduce job knobs (reducers, split/spill sizes, parallelism).
    pub conf: JobConf,
    /// Fixed prefix length (paper: 23 with `long` keys).
    pub prefix_len: usize,
    /// Sorting-group accumulation threshold in suffixes (paper: 1.6e6).
    pub group_threshold: usize,
    /// Write the suffix *texts* to HDFS (paper's fair-comparison mode);
    /// `false` emits only (key, index) — the paper's "could be faster"
    /// variant (§IV-D closing note).
    pub write_suffixes: bool,
    /// Boundary samples taken per reducer (§IV-A, paper: 10000).
    pub samples_per_reducer: usize,
    /// Reads per KV put batch from one mapper (aggregation, §IV-B):
    /// key/value pairs per batched `MSET`.
    pub put_batch: usize,
    /// Double-buffer the reducer: fetch sorting group *i+1*'s suffix
    /// texts on a background thread while group *i* is tie-break sorted
    /// and emitted, hiding fetch time behind sort time. `false` falls
    /// back to blocking fetches with byte-identical requests.
    pub prefetch: bool,
    /// Route the shuffle through the fixed-width fast path (packed
    /// 24 B records, radix-sorted spills, loser-tree merges). Output
    /// order and every footprint-ledger total are identical either way
    /// (`tests/shuffle_equivalence.rs`); `false` selects the generic
    /// `Record` path for comparison.
    pub fixed_shuffle: bool,
    /// Threads for the in-node sorting hot paths: the shuffle's spill
    /// radix sort, the reducer's in-memory segment merges, and the
    /// sorting-group (key, index) sort + run merge. 1 (the default)
    /// dispatches the literal sequential code path — the equivalence
    /// baseline; any value leaves output order and every footprint
    /// channel byte-identical (`tests/sort_equivalence.rs`).
    pub parallel_sort_threads: usize,
    /// Compute each emitted suffix's LCP with its predecessor inline at
    /// reduce-emit time (the texts are already in the reducer's arena,
    /// so the LCP is nearly free there) and spool it to an *uncharged*
    /// per-task sidecar file a sealed run stitches into the artifact's
    /// LCP/tree sections. Output records, output order, and every
    /// footprint-ledger channel are byte-identical either way
    /// (`tests/lcp_oracle.rs`); non-sealed runs simply discard the
    /// sidecars. `false` seals a plain-search (no-aux) artifact.
    pub emit_lcp: bool,
    /// RNG seed for boundary sampling (§IV-A).
    pub seed: u64,
}

impl Default for SchemeConfig {
    fn default() -> Self {
        Self {
            conf: JobConf::scaled_down(),
            prefix_len: DEFAULT_PREFIX_LEN,
            group_threshold: 1_600_000,
            write_suffixes: true,
            samples_per_reducer: SAMPLES_PER_REDUCER,
            put_batch: crate::kvstore::shard::BATCH_PAIRS,
            prefetch: true,
            fixed_shuffle: true,
            parallel_sort_threads: 1,
            emit_lcp: true,
            seed: 1,
        }
    }
}

/// Factory for per-task store handles (a TCP client per task, or clones
/// of one shared in-process store).
pub type StoreFactory = Arc<dyn Fn() -> Box<dyn SuffixStore> + Send + Sync>;

/// Reducer wall-time split (§IV-D: ~60% getting suffixes / 13% sorting /
/// 27% others), aggregated across reducers in nanoseconds.
#[derive(Debug, Default)]
pub struct TimeSplit {
    /// Time stalled on `MGETSUFFIX` (with prefetching: only the part the
    /// overlap failed to hide behind sorting).
    pub fetch_ns: AtomicU64,
    /// Numeric group sort + tie-break sort time.
    pub sort_ns: AtomicU64,
    /// Everything else (planning, scatter, emit).
    pub other_ns: AtomicU64,
}

impl TimeSplit {
    /// (fetch, sort, other) as percentages of the accounted total.
    pub fn percentages(&self) -> (f64, f64, f64) {
        let f = self.fetch_ns.load(Ordering::Relaxed) as f64;
        let s = self.sort_ns.load(Ordering::Relaxed) as f64;
        let o = self.other_ns.load(Ordering::Relaxed) as f64;
        let t = (f + s + o).max(1.0);
        (100.0 * f / t, 100.0 * s / t, 100.0 * o / t)
    }
}

/// Everything one scheme run produces.
pub struct SchemeResult {
    /// The underlying MapReduce job result (output, footprint, stats).
    pub job: JobResult,
    /// Output suffix order (packed indexes).
    pub order: Vec<i64>,
    /// Memory used by the KV instances after loading (paper's 1.5×).
    pub kv_memory: u64,
    /// Reducer time split.
    pub time_split: Arc<TimeSplit>,
    /// Partition boundaries used.
    pub boundaries: Vec<i64>,
}

/// Everything a [`run_files_sealed`] run produces. The suffix order
/// itself is NOT here — it lives in the sealed artifact on disk, which
/// is the point: the construction ends in a servable file, not a
/// process-resident `Vec`.
pub struct SealedSchemeResult {
    /// The underlying MapReduce job result (output, footprint, stats).
    pub job: JobResult,
    /// Memory used by the KV instances after loading (paper's 1.5×).
    pub kv_memory: u64,
    /// Reducer time split.
    pub time_split: Arc<TimeSplit>,
    /// Partition boundaries used.
    pub boundaries: Vec<i64>,
    /// Suffix-array entries streamed into the artifact.
    pub n_sealed: u64,
}

// ---------------- mapper ----------------

/// Shared slot where one finished mapper parks its store handle so the
/// pipeline can reuse it for the post-job `used_memory` probe instead
/// of opening a fresh (in cluster mode: TCP) connection.
pub(crate) type StoreSlot = Arc<Mutex<Option<Box<dyn SuffixStore>>>>;

struct SchemeMapper {
    cfg: SchemeConfig,
    boundaries: Vec<i64>,
    /// Store handle; moved into `park` after the final aggregated put.
    store: Option<Box<dyn SuffixStore>>,
    park: StoreSlot,
    ledger: Arc<Ledger>,
    /// Reads held for tile-encoding and the aggregated KV put.
    pending: Vec<Read>,
    all_reads: Vec<Read>,
}

impl SchemeMapper {
    /// Encode pending reads (PJRT tile when available, native otherwise)
    /// and emit one numeric (key, index) pair per valid suffix. Both
    /// `MapTask` paths funnel through here: the fixed-width path packs
    /// the pairs straight into the shuffle, the generic path wraps them
    /// in big-endian `Record`s with identical bytes.
    fn encode_pending(&mut self, emit: &mut dyn FnMut(i64, i64)) {
        if self.pending.is_empty() {
            return;
        }
        let reads = std::mem::take(&mut self.pending);
        let done = runtime::with_engine(|eng| {
            let Some(eng) = eng else { return false };
            let refs: Vec<&Read> = reads.iter().collect();
            let max_len = refs.iter().map(|r| r.len()).max().unwrap_or(0);
            // tile to the variant's row count (large tiles amortize
            // PJRT dispatch — §Perf iteration 1)
            let tile_r = eng
                .map_encode_meta(max_len, self.cfg.prefix_len, self.boundaries.len())
                .map(|m| m.r)
                .unwrap_or(128);
            let mut ok = true;
            for tile in refs.chunks(tile_r) {
                match eng.map_encode_tile(tile, &self.boundaries, self.cfg.prefix_len) {
                    Ok(out) => {
                        for (i, rd) in tile.iter().enumerate() {
                            for off in 0..=rd.len() {
                                let j = i * out.lp + off;
                                debug_assert_eq!(out.valid[j], 1);
                                emit(out.keys[j], out.indexes[j]);
                            }
                        }
                    }
                    Err(e) => {
                        eprintln!("samr: map_encode_tile failed, native fallback: {e}");
                        ok = false;
                        break;
                    }
                }
            }
            ok
        });
        if !done {
            for rd in &reads {
                let mut recs = Vec::with_capacity(rd.suffix_count());
                native::encode_read(rd, &self.boundaries, self.cfg.prefix_len, &mut recs);
                for r in recs {
                    emit(r.key, r.index);
                }
            }
        }
        self.all_reads.extend(reads);
    }

    /// Queue one input read; returns true when the encode batch is full.
    fn push_read(&mut self, rec: &Record) -> bool {
        let seq = u64::from_be_bytes(rec.key[..8].try_into().expect("8-byte seq key"));
        self.pending.push(Read::new(seq, rec.value.clone()));
        self.pending.len() >= 512
    }

    /// Aggregated put of this split's reads (paper: "when the mappers
    /// finish reading the input file").
    fn put_reads(&mut self) {
        let reads = std::mem::take(&mut self.all_reads);
        let store = self.store.as_mut().expect("mapper store handle");
        match store.put_reads(&reads) {
            Ok(t) => self.ledger.add(Channel::KvPut, t.total()),
            Err(e) => panic!("KV put failed: {e}"),
        }
        // the task is done with the handle: park it for the pipeline's
        // used_memory probe (first finisher wins; the rest just drop)
        let mut slot = self.park.lock().unwrap();
        if slot.is_none() {
            *slot = self.store.take();
        }
    }
}

impl crate::mapreduce::mapper::MapTask for SchemeMapper {
    fn map(&mut self, rec: &Record, emit: &mut dyn FnMut(Record)) {
        if self.push_read(rec) {
            // the [u8; 8] arrays convert straight into the Record's Vecs:
            // one allocation each (Record owns its bytes), no `.to_vec()`
            // staging copy
            self.encode_pending(&mut |k, ix| {
                emit(Record::new(encode_i64_key(k), ix.to_be_bytes()))
            });
        }
    }

    fn finish(&mut self, emit: &mut dyn FnMut(Record)) {
        self.encode_pending(&mut |k, ix| {
            emit(Record::new(encode_i64_key(k), ix.to_be_bytes()))
        });
        self.put_reads();
    }

    // Fixed-width overrides: identical pairs, no Record allocation.
    // Keys are non-negative i64, so `as u64` preserves both the value
    // and the big-endian byte order the generic path would have written.
    fn map_fixed(&mut self, rec: &Record, emit: &mut dyn FnMut(u64, u64)) {
        if self.push_read(rec) {
            self.encode_pending(&mut |k, ix| emit(k as u64, ix as u64));
        }
    }

    fn finish_fixed(&mut self, emit: &mut dyn FnMut(u64, u64)) {
        self.encode_pending(&mut |k, ix| emit(k as u64, ix as u64));
        self.put_reads();
    }
}

// ---------------- reducer ----------------

/// A key-sorted batch whose suffix texts are (possibly) still in flight
/// on the prefetch thread — the reducer's double buffer. Key groups are
/// not materialized: `key_groups(&keys)` re-derives them on demand.
struct PendingBatch {
    keys: Vec<i64>,
    indexes: Vec<i64>,
    /// Positions in `indexes` whose texts were requested: `None` = every
    /// position (write mode), `Some` = tie-break positions only.
    want: Option<Vec<usize>>,
    /// Whether a fetch was actually issued (false for empty plans).
    requested: bool,
}

/// Trailer length of an LCP sidecar file: entry count (u64), first key
/// (i64), last key (i64).
const LCP_SIDECAR_TRAILER: usize = 24;

/// Sidecar file name for reduce task `r` inside the LCP scratch dir.
pub(crate) fn lcp_sidecar_name(r: usize) -> String {
    format!("lcp-{r:05}")
}

/// Streaming writer for one reduce task's LCP sidecar: one u32 LE per
/// emitted suffix (the LCP with its predecessor *within this task*;
/// entry 0 is a placeholder the seal-time stitch replaces), then a
/// 24-byte trailer (count, first key, last key) for the cross-reducer
/// stitch. A *sidecar* — not part of the task's output records — so the
/// nine footprint-ledger channels are byte-identical with emission on
/// or off; like spill files, local scratch I/O is uncharged.
///
/// The file is created lazily on the first entry: an empty partition
/// writes nothing (seal treats a missing sidecar as zero records), and
/// a retried task attempt re-creates (truncates) the file and — the
/// input being deterministic — rewrites it identically.
struct LcpSidecar {
    path: PathBuf,
    w: Option<BufWriter<File>>,
    n: u64,
    first_key: i64,
    last_key: i64,
}

impl LcpSidecar {
    fn new(path: PathBuf) -> LcpSidecar {
        LcpSidecar { path, w: None, n: 0, first_key: 0, last_key: 0 }
    }

    /// Append one suffix's LCP (and remember its key for the trailer).
    fn push(&mut self, lcp: u32, key: i64) -> std::io::Result<()> {
        if self.w.is_none() {
            self.w = Some(BufWriter::new(File::create(&self.path)?));
            self.first_key = key;
        }
        self.last_key = key;
        self.n += 1;
        self.w.as_mut().expect("created above").write_all(&lcp.to_le_bytes())
    }

    /// Write the trailer and flush. No-op when no entry arrived (the
    /// file was never created).
    fn finish(&mut self) -> std::io::Result<()> {
        let Some(w) = self.w.as_mut() else { return Ok(()) };
        w.write_all(&self.n.to_le_bytes())?;
        w.write_all(&self.first_key.to_le_bytes())?;
        w.write_all(&self.last_key.to_le_bytes())?;
        w.flush()
    }
}

/// One reducer sidecar, parsed back at seal time.
struct SidecarData {
    lcp: Vec<u32>,
    first_key: i64,
    last_key: i64,
}

/// Read reduce task `r`'s sidecar; `None` when the task emitted nothing
/// (no file).
fn read_lcp_sidecar(dir: &std::path::Path, r: usize) -> std::io::Result<Option<SidecarData>> {
    let path = dir.join(lcp_sidecar_name(r));
    let bytes = match std::fs::read(&path) {
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        other => other?,
    };
    let bad = |msg: String| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("LCP sidecar {}: {msg}", path.display()),
        )
    };
    if bytes.len() < LCP_SIDECAR_TRAILER {
        return Err(bad(format!("{} bytes is shorter than the trailer", bytes.len())));
    }
    let t = bytes.len() - LCP_SIDECAR_TRAILER;
    let n = u64::from_le_bytes(bytes[t..t + 8].try_into().expect("8-byte count")) as usize;
    if t != n * 4 {
        return Err(bad(format!("{n} entries declared but {t} payload bytes present")));
    }
    let lcp = bytes[..t]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().expect("4-byte LCP")))
        .collect();
    Ok(Some(SidecarData {
        lcp,
        first_key: i64::from_le_bytes(bytes[t + 8..t + 16].try_into().expect("8-byte key")),
        last_key: i64::from_le_bytes(bytes[t + 16..t + 24].try_into().expect("8-byte key")),
    }))
}

struct SchemeReducer {
    cfg: SchemeConfig,
    /// Fetch handle for the blocking path (`cfg.prefetch == false`).
    store: Option<Box<dyn SuffixStore>>,
    /// Background fetch worker for the double-buffered path; owns the
    /// store handle the blocking path would have used.
    prefetcher: Option<SuffixPrefetcher>,
    ledger: Arc<Ledger>,
    times: Arc<TimeSplit>,
    buf: SortingGroupBuffer,
    /// The previous sorting group, emitted once its texts arrive.
    pending: Option<PendingBatch>,
    /// Recycled fetch arenas: the blocking path rotates one, the
    /// prefetching path two (one in flight, one being consumed) — steady
    /// state allocates no arena.
    spares: Vec<SuffixBatch>,
    /// LCP sidecar writer (`cfg.emit_lcp` runs only).
    lcp: Option<LcpSidecar>,
    /// Key of the last emitted suffix, for LCPs across batch boundaries
    /// (batches end on key-group boundaries, so the keys always differ
    /// and the key digits determine the LCP exactly).
    prev_key: Option<i64>,
}

impl SchemeReducer {
    /// A cleared arena from the recycle pool (or a fresh one, first use).
    fn spare_arena(&mut self) -> SuffixBatch {
        self.spares.pop().unwrap_or_default()
    }

    /// Return a consumed arena to the pool for the next fetch.
    fn recycle(&mut self, mut arena: SuffixBatch) {
        arena.clear();
        self.spares.push(arena);
    }

    fn flush(&mut self, out: &mut dyn FnMut(Record)) -> std::io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let t_start = Instant::now();
        let (mut keys, mut indexes) = self.buf.take();

        // 1. numeric (key, index) sort — PJRT bitonic blocks + merge, or
        //    native. Input arrives key-ordered, so blocks are nearly
        //    sorted; the kernel still performs the full network (§IV-C).
        let t_sort = Instant::now();
        let sort_threads = self.cfg.parallel_sort_threads;
        runtime::with_engine(|eng| match eng {
            Some(eng) if eng.max_group_block() > 0 => {
                let block = eng.preferred_group_block();
                let n = keys.len();
                let mut runs: Vec<(Vec<i64>, Vec<i64>)> = Vec::new();
                let mut i = 0;
                while i < n {
                    let j = (i + block).min(n);
                    let mut kb = keys[i..j].to_vec();
                    let mut ib = indexes[i..j].to_vec();
                    // adaptive: key-ordered arrival means many blocks are
                    // already (key, index)-sorted — skip the network then
                    // (§Perf iteration 3)
                    if !is_pair_sorted(&kb, &ib) && eng.group_sort(&mut kb, &mut ib).is_err() {
                        native::group_sort(&mut kb, &mut ib);
                    }
                    runs.push((kb, ib));
                    i = j;
                }
                let (k, ix) = merge_pair_runs(runs, sort_threads);
                keys = k;
                indexes = ix;
            }
            _ => native::group_sort_threads(&mut keys, &mut indexes, sort_threads),
        });
        let sort_ns = t_sort.elapsed().as_nanos() as u64;

        // 2. fetch plan: every text when writing suffixes out, else only
        //    incomplete multi-member groups (tie-breaking).
        let want: Option<Vec<usize>> = if self.cfg.write_suffixes {
            None
        } else {
            Some(tie_break_positions(key_groups(&keys), self.cfg.prefix_len))
        };
        let idxs: Vec<i64> = match &want {
            None => indexes.clone(),
            Some(w) => w.iter().map(|&i| indexes[i]).collect(),
        };
        let requested = !idxs.is_empty();
        let batch = PendingBatch { keys, indexes, want, requested };

        // accumulation + sort + planning accounted here; fetch stalls,
        // tie-break, and emit are accounted where they happen
        self.times.sort_ns.fetch_add(sort_ns, Ordering::Relaxed);
        let planned_ns = t_start.elapsed().as_nanos() as u64;
        self.times
            .other_ns
            .fetch_add(planned_ns.saturating_sub(sort_ns), Ordering::Relaxed);

        if self.prefetcher.is_some() {
            // double-buffered: queue this batch's fetch, then finish the
            // *previous* batch while the fetch streams in — its tie-break
            // sort and emit hide this batch's fetch latency (and the
            // fetch queued last flush hid behind this batch's sort).
            if requested {
                let arena = self.spare_arena();
                self.prefetcher.as_mut().expect("checked above").request(idxs, arena);
            }
            let prev = self.pending.replace(batch);
            self.complete(prev, out)
        } else {
            // blocking path: byte-identical requests, no overlap.
            let mut arena = self.spare_arena();
            if requested {
                let store = self.store.as_mut().expect("blocking reducer holds the store");
                account_fetch(&self.ledger, &self.times, || {
                    store.fetch_suffixes_into(&idxs, &mut arena).map(|t| ((), t))
                })?;
            }
            self.finish_batch(batch, &arena, out)?;
            self.recycle(arena);
            Ok(())
        }
    }

    /// Wait for `prev`'s in-flight texts and finish it (double-buffered
    /// path). Only the time spent *stalled* in the wait counts as fetch
    /// time — that is exactly the fetch cost the overlap failed to hide.
    fn complete(
        &mut self,
        prev: Option<PendingBatch>,
        out: &mut dyn FnMut(Record),
    ) -> std::io::Result<()> {
        let Some(prev) = prev else { return Ok(()) };
        let arena = if prev.requested {
            let pf = self.prefetcher.as_mut().expect("prefetching reducer holds the worker");
            account_fetch(&self.ledger, &self.times, || pf.wait())?
        } else {
            self.spare_arena() // empty: nothing was requested
        };
        self.finish_batch(prev, &arena, out)?;
        self.recycle(arena);
        Ok(())
    }

    /// Tie-break, emit, and account one batch whose texts have arrived in
    /// `texts`' flat arena. Tie-breaking compares borrowed arena slices
    /// and permutes only the (index, arena-entry) table — suffix bytes
    /// never move or copy until the one unavoidable copy into the emitted
    /// `Record` (which must own its key).
    ///
    /// With `emit_lcp` this is also where each suffix's LCP with its
    /// predecessor is computed — at emit time the answer is nearly free:
    /// * **different keys** — adjacent sorted suffixes whose prefix keys
    ///   differ have byte LCP = shared leading key digits
    ///   ([`key_common_prefix`]'s exactness argument), no texts needed;
    /// * **equal complete keys** — identical suffixes (a complete key
    ///   *is* the whole suffix), LCP = the suffix length from the key;
    /// * **equal incomplete keys** — both positions sit in the same
    ///   multi-member incomplete group, which is exactly what the
    ///   tie-break plan fetched, so both texts are in the arena and one
    ///   zip counts the LCP.
    /// Batches end on key-group boundaries (`push_group` admits whole
    /// groups), so a batch's first suffix never shares a key with
    /// `prev_key` and the cross-batch case is always the key-digit one.
    fn finish_batch(
        &mut self,
        batch: PendingBatch,
        texts: &SuffixBatch,
        out: &mut dyn FnMut(Record),
    ) -> std::io::Result<()> {
        let PendingBatch { keys, mut indexes, want, .. } = batch;
        // position -> arena entry (NO_TEXT where no text was fetched)
        const NO_TEXT: usize = usize::MAX;
        let mut entry_at: Vec<usize> = vec![NO_TEXT; keys.len()];
        match &want {
            None => {
                for (i, e) in entry_at.iter_mut().enumerate() {
                    *e = i;
                }
            }
            Some(w) => {
                for (j, &pos) in w.iter().enumerate() {
                    entry_at[pos] = j;
                }
            }
        }

        // 3. tie-break: re-sort incomplete multi-member groups by
        //    (suffix text, index) — a spans permutation, no byte moves.
        let t_tie = Instant::now();
        let mut span: Vec<(usize, i64)> = Vec::new(); // (entry, index), reused
        for (s, e, k) in key_groups(&keys) {
            if e - s > 1 && !key_is_complete(k, self.cfg.prefix_len) {
                span.clear();
                span.extend((s..e).map(|i| (entry_at[i], indexes[i])));
                span.sort_by(|a, b| {
                    texts.slice(a.0).cmp(texts.slice(b.0)).then(a.1.cmp(&b.1))
                });
                for (off, &(entry, ix)) in span.iter().enumerate() {
                    entry_at[s + off] = entry;
                    indexes[s + off] = ix;
                }
            }
        }
        let tie_ns = t_tie.elapsed().as_nanos() as u64;

        // 4. emit. `Record` owns its bytes, so each record costs exactly
        //    the two Vecs it is made of — nothing else is allocated.
        let t_emit = Instant::now();
        for i in 0..keys.len() {
            if self.lcp.is_some() {
                let lcp: u32 = if i == 0 {
                    match self.prev_key {
                        // task's first suffix: placeholder; the seal-time
                        // stitch supplies the cross-reducer LCP
                        None => 0,
                        Some(pk) => key_common_prefix(pk, keys[0], self.cfg.prefix_len) as u32,
                    }
                } else if keys[i] != keys[i - 1] {
                    key_common_prefix(keys[i - 1], keys[i], self.cfg.prefix_len) as u32
                } else if let Some(len) = complete_key_len(keys[i], self.cfg.prefix_len) {
                    len as u32
                } else {
                    let a = texts.slice(entry_at[i - 1]);
                    let b = texts.slice(entry_at[i]);
                    a.iter().zip(b).take_while(|(x, y)| x == y).count() as u32
                };
                self.lcp.as_mut().expect("checked above").push(lcp, keys[i])?;
                self.prev_key = Some(keys[i]);
            }
            let value = indexes[i].to_be_bytes();
            let rec = if self.cfg.write_suffixes {
                // entry_at[i] is always a fetched entry in write mode
                Record::new(texts.slice(entry_at[i]), value)
            } else {
                Record::new(encode_i64_key(keys[i]), value)
            };
            out(rec);
        }

        self.times.sort_ns.fetch_add(tie_ns, Ordering::Relaxed);
        self.times
            .other_ns
            .fetch_add(t_emit.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(())
    }
}

/// Run one fetch (blocking call or prefetch wait), charge the ledger,
/// and book the elapsed stall as fetch time. Both reducer paths go
/// through here so their accounting can never diverge. A fetch failure
/// is a clean `io::Error` out of the reducer (and so out of the job) —
/// not a panic.
fn account_fetch<T>(
    ledger: &Ledger,
    times: &TimeSplit,
    fetch: impl FnOnce() -> crate::kvstore::client::Result<(T, Traffic)>,
) -> std::io::Result<T> {
    let t = Instant::now();
    let res = fetch();
    times.fetch_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
    let (value, traffic) = res.map_err(|e| {
        // one conversion policy (client.rs: From<KvError> for io::Error,
        // kind-preserving), plus this call site's context
        let e = std::io::Error::from(e);
        std::io::Error::new(e.kind(), format!("suffix fetch failed: {e}"))
    })?;
    ledger.add(Channel::KvFetch, traffic.total());
    Ok(value)
}

/// Is the (key, index) sequence already lexicographically sorted?
fn is_pair_sorted(keys: &[i64], indexes: &[i64]) -> bool {
    (1..keys.len()).all(|i| (keys[i - 1], indexes[i - 1]) <= (keys[i], indexes[i]))
}

/// Merge sorted (key, index) runs in one k-way pass on the loser tree
/// (`mapreduce/merge.rs`): O(n log k) where the old pairwise pop-merge
/// was O(n·k), with identical output — indexes are unique, so ascending
/// (key, index) order is the unique sorted order either way. `threads`
/// > 1 range-partitions the merge across that many threads with the
/// same output (`kway_merge_pairs_threads`); 1 keeps the sequential
/// loser tree.
fn merge_pair_runs(mut runs: Vec<(Vec<i64>, Vec<i64>)>, threads: usize) -> (Vec<i64>, Vec<i64>) {
    if runs.len() <= 1 {
        return runs.pop().unwrap_or_default();
    }
    let total: usize = runs.iter().map(|(k, _)| k.len()).sum();
    let mut keys = Vec::with_capacity(total);
    let mut indexes = Vec::with_capacity(total);
    if threads <= 1 {
        kway_merge_pairs(&runs, |k, ix| {
            keys.push(k);
            indexes.push(ix);
        });
    } else {
        kway_merge_pairs_threads(&runs, threads, |k, ix| {
            keys.push(k);
            indexes.push(ix);
        });
    }
    (keys, indexes)
}

impl crate::mapreduce::reducer::ReduceTask for SchemeReducer {
    fn reduce(
        &mut self,
        key: &[u8],
        values: Vec<Vec<u8>>,
        out: &mut dyn FnMut(Record),
    ) -> std::io::Result<()> {
        let k = decode_i64_key(key);
        self.buf.push_group(
            k,
            values
                .iter()
                .map(|v| i64::from_be_bytes(v[..8].try_into().expect("8-byte index"))),
        );
        if self.buf.len() >= self.cfg.group_threshold {
            self.flush(out)?;
        }
        Ok(())
    }

    // Fixed-width override: the packed u64s decode straight back into
    // the numeric pairs the sorting-group buffer stores — no byte
    // buffers materialized per value.
    fn reduce_fixed(
        &mut self,
        key: u64,
        values: &[u64],
        out: &mut dyn FnMut(Record),
    ) -> std::io::Result<()> {
        self.buf.push_group(key as i64, values.iter().map(|&v| v as i64));
        if self.buf.len() >= self.cfg.group_threshold {
            self.flush(out)?;
        }
        Ok(())
    }

    fn finish(&mut self, out: &mut dyn FnMut(Record)) -> std::io::Result<()> {
        self.flush(out)?;
        // drain the double buffer: the last batch's fetch is still in
        // flight when the input runs out
        let prev = self.pending.take();
        self.complete(prev, out)?;
        if let Some(sc) = self.lcp.as_mut() {
            sc.finish()?;
        }
        Ok(())
    }
}

// ---------------- pipeline ----------------

/// Run the scheme over a corpus. `store_factory` yields one store handle
/// per task (TCP client or shared in-proc store).
pub fn run(
    reads: &[Read],
    cfg: &SchemeConfig,
    store_factory: StoreFactory,
    ledger: &Arc<Ledger>,
) -> std::io::Result<SchemeResult> {
    run_files(&[reads], cfg, store_factory, ledger)
}

/// Run the scheme over SEVERAL input files as one construction — the
/// paper's pair-end workload (Case 6): forward reads in one file, their
/// reverse-complement mates in another, both over the same fragments.
///
/// Each file keeps its own input splits (a mapper never straddles a file
/// boundary, exactly as HDFS would split two files), every mapper puts
/// its reads into the SAME sharded store with the unchanged `seq mod N`
/// routing, and all files' (prefix key, packed index) pairs feed one
/// joint shuffle — so the reducers see a single global index stream and
/// emit one suffix array spanning both files.
///
/// Sequence numbers must be unique across the files (the fragment-linked
/// [`crate::suffix::reads::pair_seq`] scheme guarantees it); a collision
/// would silently overwrite a read in the store, so it is rejected here
/// with a real error.
pub fn run_files(
    files: &[&[Read]],
    cfg: &SchemeConfig,
    store_factory: StoreFactory,
    ledger: &Arc<Ledger>,
) -> std::io::Result<SchemeResult> {
    let core = run_files_core(files, cfg, &store_factory, ledger)?;
    // stream the order straight out of the per-reducer output sinks —
    // one record resident at a time, not the whole output
    let order = core.job.collect_i64_values()?;
    let kv_memory = probe_kv_memory(&core.parked, &store_factory);
    Ok(SchemeResult {
        job: core.job,
        order,
        kv_memory,
        time_split: core.times,
        boundaries: core.boundaries,
    })
}

/// [`run_files`] with the serving ending: the reducer output streams
/// into a sealed index artifact at `out` (corpus + SA + read metadata,
/// checksummed — see `crate::suffix::sealed`) instead of materializing
/// the order as a `Vec<i64>`. One SA entry is resident at a time on the
/// sealing path, so the artifact scales with disk, not heap; the
/// `SealWriter`'s finish-time invariants (SA count vs corpus suffix
/// count) turn any wiring bug into a clean error rather than a
/// plausible-looking artifact.
///
/// With `cfg.emit_lcp` (the default) the artifact also gets the v2
/// LCP / midpoint-tree / BWT sections: each reducer's sidecar supplies
/// the within-task LCPs the emit loop already computed, and this stitch
/// fills in the one value a reducer cannot know — its first suffix's LCP
/// with the *previous reducer's* last suffix. Range partitioning puts
/// different keys on either side of every reducer boundary, so that LCP
/// is exactly the shared key digits ([`key_common_prefix`]). The BWT
/// character (the byte preceding each suffix; [`BWT_TERMINATOR`] at
/// offset 0) is read here from the in-memory input reads — the emitting
/// reducer may not hold the read, but the sealer does.
pub fn run_files_sealed(
    files: &[&[Read]],
    cfg: &SchemeConfig,
    store_factory: StoreFactory,
    ledger: &Arc<Ledger>,
    out: &std::path::Path,
) -> std::io::Result<SealedSchemeResult> {
    let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
    let parse_index = |rec: &Record| -> std::io::Result<i64> {
        if rec.value.len() < 8 {
            return Err(bad(format!(
                "output value is {} bytes; an 8-byte i64 prefix is required",
                rec.value.len()
            )));
        }
        Ok(i64::from_be_bytes(rec.value[..8].try_into().expect("checked length")))
    };
    let mut writer = if cfg.emit_lcp {
        SealWriter::create_with_aux(out)?
    } else {
        SealWriter::create(out)?
    };
    for file in files {
        writer.add_file(file)?;
    }
    let core = run_files_core(files, cfg, &store_factory, ledger)?;
    let mut n_sealed = 0u64;
    if cfg.emit_lcp {
        let dir = &core.lcp_dir.as_ref().expect("emit_lcp runs hold the sidecar dir").path;
        // the BWT needs each suffix's *preceding* character, which lives
        // with the read, not the emitting reducer — index the in-memory
        // inputs by sequence number
        let reads_by_seq: HashMap<u64, &[u8]> = files
            .iter()
            .flat_map(|f| f.iter().map(|rd| (rd.seq, rd.codes.as_slice())))
            .collect();
        let mut prev_last_key: Option<i64> = None;
        for r in 0..core.job.output.len() {
            let side = read_lcp_sidecar(dir, r)?;
            let mut reader = core.job.output_reader(r)?;
            let mut i = 0usize;
            while let Some(rec) = reader.next_record()? {
                let idx = parse_index(&rec)?;
                let side = side
                    .as_ref()
                    .ok_or_else(|| bad(format!("reduce task {r} emitted records but no LCP sidecar")))?;
                let lcp = if i == 0 {
                    match prev_last_key {
                        None => 0,
                        Some(pk) => key_common_prefix(pk, side.first_key, cfg.prefix_len) as u32,
                    }
                } else {
                    *side.lcp.get(i).ok_or_else(|| {
                        bad(format!(
                            "reduce task {r}: more output records than the {} sidecar entries",
                            side.lcp.len()
                        ))
                    })?
                };
                let (seq, off) = unpack_index(idx);
                let bwt = if off == 0 {
                    BWT_TERMINATOR
                } else {
                    let codes = reads_by_seq
                        .get(&seq)
                        .ok_or_else(|| bad(format!("output index {idx} names unknown seq {seq}")))?;
                    codes[off - 1]
                };
                writer.push_entry(idx, lcp, bwt)?;
                n_sealed += 1;
                i += 1;
            }
            if let Some(s) = side.as_ref() {
                if s.lcp.len() != i {
                    return Err(bad(format!(
                        "reduce task {r}: {} sidecar entries for {i} output records",
                        s.lcp.len()
                    )));
                }
                prev_last_key = Some(s.last_key);
            }
        }
    } else {
        core.job.for_each_output(|rec| {
            writer.push_index(parse_index(&rec)?)?;
            n_sealed += 1;
            Ok(())
        })?;
    }
    writer.finish()?;
    let kv_memory = probe_kv_memory(&core.parked, &store_factory);
    Ok(SealedSchemeResult {
        job: core.job,
        kv_memory,
        time_split: core.times,
        boundaries: core.boundaries,
        n_sealed,
    })
}

/// What [`run_files_core`] hands back to an ending: the finished job
/// plus the handles the endings need (memory probe, time split,
/// boundaries).
struct CoreRun {
    job: JobResult,
    parked: StoreSlot,
    times: Arc<TimeSplit>,
    boundaries: Vec<i64>,
    /// Scratch dir holding the reducers' LCP sidecars (`emit_lcp` runs);
    /// kept alive so a sealing ending can stitch them before the files
    /// are reclaimed. Non-sealing endings just drop it.
    lcp_dir: Option<ScratchDir>,
}

/// Memory probe on a handle a map task already opened (parked in
/// `put_reads`); only an empty job falls back to a fresh connection.
fn probe_kv_memory(parked: &StoreSlot, store_factory: &StoreFactory) -> u64 {
    match parked.lock().unwrap().take() {
        Some(mut store) => store.used_memory(),
        None => store_factory().used_memory(),
    }
}

/// Collision-free sequence numbering is a precondition of the shared
/// store: reads are keyed by seq, so a duplicate would silently
/// overwrite another file's read. Rejected with a real error here (and
/// by the cluster driver, which shares this check).
pub(crate) fn check_unique_seqs(files: &[&[Read]]) -> std::io::Result<()> {
    let total: usize = files.iter().map(|f| f.len()).sum();
    let mut seqs: Vec<u64> = files.iter().flat_map(|f| f.iter().map(|r| r.seq)).collect();
    seqs.sort_unstable();
    seqs.dedup();
    if seqs.len() != total {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!(
                "duplicate sequence numbers across {} input files ({} reads, {} distinct \
                 seqs): colliding reads would overwrite each other in the store",
                files.len(),
                total,
                seqs.len()
            ),
        ));
    }
    Ok(())
}

/// Spool each file's `<seq, read>` records to its own disk-backed record
/// file (the paper's HDFS input) and cut per-file splits — a mapper
/// never straddles an input-file boundary, exactly as HDFS would split
/// two files. Returns the spool dir (keep it alive until the job
/// consumed the splits) and the split plan. The in-proc pipeline and the
/// multi-process cluster driver share this, so their split plans — and
/// therefore their `HdfsRead` charges — are identical by construction.
pub(crate) fn spool_inputs(
    files: &[&[Read]],
    conf: &JobConf,
) -> std::io::Result<(ScratchDir, Vec<crate::mapreduce::io::InputSplit>)> {
    let spool = ScratchDir::new(conf.spill_dir.as_deref(), "scheme-in")?;
    let mut splits = Vec::new();
    for (fi, file) in files.iter().enumerate() {
        let mut w =
            SplitWriter::create(spool.path.join(format!("reads{fi}")), conf.split_bytes)?;
        spool_read_records(file, &mut w)?;
        splits.extend(w.finish()?);
    }
    Ok((spool, splits))
}

/// Build one scheme map task over an already-opened store handle. The
/// in-proc `map_factory` and the cluster worker both call this, so a
/// map task executes identical code — and charges identical `KvPut`
/// bytes — whichever process it runs in.
pub(crate) fn make_mapper(
    cfg: &SchemeConfig,
    boundaries: Vec<i64>,
    mut store: Box<dyn SuffixStore>,
    park: StoreSlot,
    ledger: Arc<Ledger>,
) -> Box<dyn crate::mapreduce::mapper::MapTask> {
    store.set_put_batch(cfg.put_batch);
    Box::new(SchemeMapper {
        cfg: cfg.clone(),
        boundaries,
        store: Some(store),
        park,
        ledger,
        pending: Vec::new(),
        all_reads: Vec::new(),
    })
}

/// Build one scheme reduce task over an already-opened store handle.
/// In prefetch mode the handle moves onto the background fetch worker;
/// the blocking path keeps it inline. Shared by the in-proc
/// `reduce_factory` and the cluster worker for the same byte-identity
/// reason as [`make_mapper`].
pub(crate) fn make_reducer(
    cfg: &SchemeConfig,
    handle: Box<dyn SuffixStore>,
    ledger: Arc<Ledger>,
    times: Arc<TimeSplit>,
    lcp_sidecar: Option<PathBuf>,
) -> Box<dyn crate::mapreduce::reducer::ReduceTask> {
    let (store, prefetcher) = if cfg.prefetch {
        (None, Some(SuffixPrefetcher::spawn(handle)))
    } else {
        (Some(handle), None)
    };
    Box::new(SchemeReducer {
        cfg: cfg.clone(),
        store,
        prefetcher,
        ledger,
        times,
        buf: SortingGroupBuffer::new(),
        pending: None,
        spares: Vec::new(),
        lcp: lcp_sidecar.map(LcpSidecar::new),
        prev_key: None,
    })
}

/// The shared body of every scheme run: validate the inputs, sample the
/// boundaries, build and run the MapReduce job. The *ending* — what
/// becomes of the reducer output stream — is the caller's: [`run_files`]
/// collects it in memory, [`run_files_sealed`] streams it into the
/// sealed artifact.
fn run_files_core(
    files: &[&[Read]],
    cfg: &SchemeConfig,
    store_factory: &StoreFactory,
    ledger: &Arc<Ledger>,
) -> std::io::Result<CoreRun> {
    // collision-free numbering is a precondition of the shared store
    check_unique_seqs(files)?;

    // §IV-A sampling: boundaries over ALL files' suffix keys
    let boundaries = sampler::make_boundaries_files(
        files,
        cfg.conf.n_reducers,
        cfg.samples_per_reducer,
        cfg.prefix_len,
        cfg.seed,
    );

    let times = Arc::new(TimeSplit::default());
    let parked: StoreSlot = Arc::new(Mutex::new(None));
    // sidecar scratch space for inline LCP emission; uncharged local
    // scratch, exactly like the shuffle's spill files
    let lcp_dir = if cfg.emit_lcp {
        Some(ScratchDir::new(cfg.conf.spill_dir.as_deref(), "scheme-lcp")?)
    } else {
        None
    };
    let lcp_path: Option<PathBuf> = lcp_dir.as_ref().map(|d| d.path.clone());
    let map_bounds = boundaries.clone();
    let map_cfg = cfg.clone();
    let map_store = store_factory.clone();
    let map_ledger = ledger.clone();
    let map_park = parked.clone();
    let red_bounds = boundaries.clone();
    let red_cfg = cfg.clone();
    let red_store = store_factory.clone();
    let red_ledger = ledger.clone();
    let red_times = times.clone();

    let part_bounds = boundaries.clone();
    // the scheme's shuffle records are always 8 B + 8 B index pairs, so
    // the fixed-width fast path applies whenever the config asks for it
    let mut jconf = cfg.conf.clone();
    jconf.fixed_width = cfg.fixed_shuffle;
    jconf.parallel_sort_threads = cfg.parallel_sort_threads;
    let job = Job {
        name: "scheme".into(),
        conf: jconf,
        map_factory: Arc::new(move |_| {
            make_mapper(
                &map_cfg,
                map_bounds.clone(),
                map_store(),
                map_park.clone(),
                map_ledger.clone(),
            )
        }),
        reduce_factory: Arc::new(move |r| {
            let _ = &red_bounds;
            make_reducer(
                &red_cfg,
                red_store(),
                red_ledger.clone(),
                red_times.clone(),
                lcp_path.as_ref().map(|d| d.join(lcp_sidecar_name(r))),
            )
        }),
        partitioner: Arc::new(move |key: &[u8]| {
            native::bucket(decode_i64_key(key), &part_bounds)
        }),
    };

    // disk-backed input (the paper's HDFS): the corpus is never
    // re-materialized as resident job records
    let (spool, splits) = spool_inputs(files, &cfg.conf)?;
    let result = run_job(&job, splits, ledger)?;
    drop(spool); // input consumed; release the spool files

    Ok(CoreRun { job: result, parked, times, boundaries, lcp_dir })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::shard::SharedStore;
    use crate::suffix::reads::{synth_corpus, synth_paired_corpus, CorpusSpec};
    use crate::suffix::validate::validate_order;

    fn inproc_factory(n_shards: usize) -> (StoreFactory, SharedStore) {
        let store = SharedStore::new(n_shards);
        let s = store.clone();
        (Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>), store)
    }

    fn small_cfg(n_reducers: usize, threshold: usize) -> SchemeConfig {
        SchemeConfig {
            conf: JobConf {
                n_reducers,
                split_bytes: 4 << 10,
                io_sort_bytes: 8 << 10,
                reducer_heap_bytes: 64 << 10,
                ..JobConf::default()
            },
            group_threshold: threshold,
            samples_per_reducer: 200,
            ..Default::default()
        }
    }

    #[test]
    fn produces_valid_suffix_order() {
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 60,
            read_len: 30,
            genome_len: 2048, // repetitive: forces incomplete-group ties
            ..Default::default()
        });
        let (factory, _store) = inproc_factory(4);
        let ledger = Ledger::new();
        let res = run(&reads, &small_cfg(3, 500), factory, &ledger).unwrap();
        validate_order(&reads, &res.order).expect("scheme order invalid");
        assert!(res.kv_memory > 0);
        assert!(res.job.footprint.get(Channel::KvPut) > 0);
        assert!(res.job.footprint.get(Channel::KvFetch) > 0);
    }

    #[test]
    fn index_only_mode_matches_write_mode_order() {
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 40,
            read_len: 24,
            genome_len: 1024,
            ..Default::default()
        });
        let (f1, _s1) = inproc_factory(2);
        let ledger1 = Ledger::new();
        let mut cfg = small_cfg(2, 300);
        let res_w = run(&reads, &cfg, f1, &ledger1).unwrap();

        cfg.write_suffixes = false;
        let (f2, _s2) = inproc_factory(2);
        let ledger2 = Ledger::new();
        let res_i = run(&reads, &cfg, f2, &ledger2).unwrap();

        assert_eq!(res_w.order, res_i.order, "modes must agree on the order");
        // index-only mode fetches far fewer suffix bytes
        assert!(
            ledger2.get(Channel::KvFetch) < ledger1.get(Channel::KvFetch),
            "index-only should fetch less: {} vs {}",
            ledger2.get(Channel::KvFetch),
            ledger1.get(Channel::KvFetch)
        );
        // and writes far less to HDFS
        assert!(ledger2.get(Channel::HdfsWrite) < ledger1.get(Channel::HdfsWrite));
    }

    #[test]
    fn shuffle_carries_only_indexes() {
        // the headline mechanism: shuffled bytes ≈ 24 B per suffix
        // regardless of read length (§IV-B "has nothing to do with the
        // length of reads")
        let reads = synth_corpus(&CorpusSpec { n_reads: 50, read_len: 150, ..Default::default() });
        let n_suffixes: u64 = reads.iter().map(|r| r.suffix_count() as u64).sum();
        let (factory, _store) = inproc_factory(2);
        let ledger = Ledger::new();
        let res = run(&reads, &small_cfg(2, 10_000), factory, &ledger).unwrap();
        let shuffle = res.job.footprint.get(Channel::Shuffle);
        assert_eq!(shuffle, n_suffixes * 24, "8B key + 8B index + 8B framing");
        // vs the materialized suffixes which would be ~30x bigger
        let materialized = crate::suffix::reads::materialized_suffix_bytes(&reads);
        assert!(shuffle * 2 < materialized);
    }

    #[test]
    fn paired_end_case6_two_files_one_array() {
        let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
            n_reads: 30,
            read_len: 20,
            len_jitter: 0,
            genome_len: 4096,
            ..Default::default()
        });
        let (factory, _store) = inproc_factory(3);
        let ledger = Ledger::new();
        let res = run_files(&[&fwd, &rev], &small_cfg(2, 400), factory, &ledger).unwrap();
        // one joint array over both files, validated against the oracle
        let mut reads = fwd.clone();
        reads.extend(rev.clone());
        validate_order(&reads, &res.order).expect("paired-end order invalid");

        // and it equals the single-file run over the concatenation — two
        // files change the split plan, never the output
        let (factory2, _store2) = inproc_factory(3);
        let ledger2 = Ledger::new();
        let single = run(&reads, &small_cfg(2, 400), factory2, &ledger2).unwrap();
        assert_eq!(res.order, single.order);
    }

    #[test]
    fn sealed_run_streams_the_same_order_to_disk() {
        use crate::suffix::sealed::SealedIndex;
        let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
            n_reads: 20,
            read_len: 16,
            len_jitter: 0,
            genome_len: 2048,
            ..Default::default()
        });
        let (f1, _s1) = inproc_factory(2);
        let ledger1 = Ledger::new();
        let mem = run_files(&[&fwd, &rev], &small_cfg(2, 300), f1, &ledger1).unwrap();

        let dir = std::env::temp_dir().join(format!("samr-scheme-seal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("case6.samr");
        let (f2, _s2) = inproc_factory(2);
        let ledger2 = Ledger::new();
        let sealed =
            run_files_sealed(&[&fwd, &rev], &small_cfg(2, 300), f2, &ledger2, &path).unwrap();
        assert_eq!(sealed.n_sealed as usize, mem.order.len());
        assert!(sealed.kv_memory > 0);

        let idx = SealedIndex::open(&path).unwrap();
        let on_disk: Vec<i64> = (0..mem.order.len()).map(|r| idx.sa_at(r)).collect();
        assert_eq!(on_disk, mem.order, "sealed SA must equal the in-memory order");
        let st = idx.stats();
        assert_eq!(st.n_files, 2);
        assert_eq!(st.n_reads as usize, fwd.len() + rev.len());

        // the default-emit_lcp pipeline seals the v2 aux sections, and
        // the stitched LCPs equal a naive recompute over the final order
        assert!(st.has_lcp && st.has_tree && st.has_bwt);
        assert_eq!(idx.lcp_at(0), 0);
        for r in 1..mem.order.len() {
            let (a, b) = (idx.suffix(on_disk[r - 1]), idx.suffix(on_disk[r]));
            let want = a.iter().zip(b).take_while(|(x, y)| x == y).count() as u32;
            assert_eq!(idx.lcp_at(r), want, "stitched LCP at rank {r}");
        }

        // emit_lcp = false seals a plain (no-aux) v2 artifact with the
        // identical SA
        let plain_path = dir.join("case6-plain.samr");
        let (f3, _s3) = inproc_factory(2);
        let ledger3 = Ledger::new();
        let cfg_plain = SchemeConfig { emit_lcp: false, ..small_cfg(2, 300) };
        run_files_sealed(&[&fwd, &rev], &cfg_plain, f3, &ledger3, &plain_path).unwrap();
        let plain = SealedIndex::open(&plain_path).unwrap();
        let pst = plain.stats();
        assert!(!pst.has_lcp && !pst.has_tree && !pst.has_bwt);
        let plain_sa: Vec<i64> = (0..mem.order.len()).map(|r| plain.sa_at(r)).collect();
        assert_eq!(plain_sa, mem.order);
    }

    #[test]
    fn run_files_rejects_seq_collisions() {
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 10,
            read_len: 12,
            genome_len: 1024,
            ..Default::default()
        });
        let (factory, _store) = inproc_factory(2);
        let ledger = Ledger::new();
        // the same file twice: every seq collides
        let err = run_files(&[&reads, &reads], &small_cfg(2, 400), factory, &ledger)
            .expect_err("colliding seqs must be rejected");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
    }

    /// A store whose puts work but whose fetches always fail — the
    /// "suffix source went away mid-job" scenario.
    struct FailingFetchStore(SharedStore);

    impl SuffixStore for FailingFetchStore {
        fn put_reads(
            &mut self,
            reads: &[crate::suffix::reads::Read],
        ) -> crate::kvstore::client::Result<Traffic> {
            self.0.put_reads(reads)
        }

        fn fetch_suffixes(
            &mut self,
            _indexes: &[i64],
        ) -> crate::kvstore::client::Result<(Vec<Vec<u8>>, Traffic)> {
            Err(crate::kvstore::client::KvError::Server("store on fire".into()))
        }

        fn fetch_suffixes_into(
            &mut self,
            _indexes: &[i64],
            _out: &mut SuffixBatch,
        ) -> crate::kvstore::client::Result<Traffic> {
            Err(crate::kvstore::client::KvError::Server("store on fire".into()))
        }

        fn traffic(&self) -> Traffic {
            self.0.traffic()
        }

        fn used_memory(&mut self) -> u64 {
            self.0.used_memory()
        }

        fn n_shards(&self) -> usize {
            self.0.n_shards()
        }
    }

    #[test]
    fn fetch_failure_is_a_clean_error_not_a_panic() {
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 30,
            read_len: 20,
            genome_len: 1024,
            ..Default::default()
        });
        for prefetch in [false, true] {
            let shared = SharedStore::new(2);
            let s = shared.clone();
            let factory: StoreFactory =
                Arc::new(move || Box::new(FailingFetchStore(s.clone())) as Box<dyn SuffixStore>);
            let cfg = SchemeConfig { prefetch, ..small_cfg(2, 400) };
            let ledger = Ledger::new();
            let err = run(&reads, &cfg, factory, &ledger)
                .expect_err("a failing fetch must error the job");
            let msg = err.to_string();
            assert!(
                msg.contains("suffix fetch failed") && msg.contains("store on fire"),
                "clean fetch error expected, got: {msg}"
            );
            assert!(
                !msg.contains("panicked"),
                "fetch failure must not travel as a panic: {msg}"
            );
        }
    }

    #[test]
    fn memory_probe_reuses_a_task_store_handle() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 30,
            read_len: 20,
            genome_len: 1024,
            ..Default::default()
        });
        let store = SharedStore::new(2);
        let s = store.clone();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = calls.clone();
        let factory: StoreFactory = Arc::new(move || {
            c.fetch_add(1, Ordering::Relaxed);
            Box::new(s.clone()) as Box<dyn SuffixStore>
        });
        let ledger = Ledger::new();
        let res = run(&reads, &small_cfg(2, 400), factory, &ledger).unwrap();
        assert!(res.kv_memory > 0);
        // exactly one handle per task — the post-job used_memory probe
        // reuses a parked mapper handle instead of opening another
        // (in cluster mode: a throwaway TCP connection)
        assert_eq!(
            calls.load(Ordering::Relaxed),
            res.job.map_stats.len() + res.job.reduce_stats.len(),
            "store_factory must not be called beyond one handle per task"
        );
    }

    #[test]
    fn kv_memory_shows_metadata_overhead() {
        let reads = synth_corpus(&CorpusSpec { n_reads: 100, read_len: 100, ..Default::default() });
        let (factory, _store) = inproc_factory(4);
        let ledger = Ledger::new();
        let res = run(&reads, &small_cfg(2, 1000), factory, &ledger).unwrap();
        let payload: u64 = reads.iter().map(|r| r.len() as u64 + 3).sum(); // + key digits
        let ratio = res.kv_memory as f64 / payload as f64;
        assert!((1.3..2.0).contains(&ratio), "ratio={ratio}");
    }
}

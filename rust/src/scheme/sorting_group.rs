//! Reducer-side sorting groups (§IV-B/C and Fig. 7).
//!
//! The reducer receives fixed-width (prefix-key, packed-index) pairs in
//! key order. Pairs are *accumulated without sorting* until the batch
//! exceeds a threshold (paper: 1.6e6 suffixes) — small enough for the
//! heap, large enough to amortize per-group switching and KV round trips.
//!
//! Within a batch:
//!  * a key whose decoded prefix contains the terminator (a 0 digit)
//!    identifies the *complete* suffix — every pair sharing it is an
//!    identical suffix, ordered by index alone, no text fetch needed
//!    ("the prefix is the suffix itself", §IV-B);
//!  * other keys with multiple members need the full suffix texts
//!    (fetched in bulk via MGETSUFFIX) to break the tie.

use crate::suffix::encode::{decode_key, BASE};

/// Does this key's prefix window contain the `$` terminator? If so the
/// key determines the whole suffix (no tie-break fetch needed).
pub fn key_is_complete(key: i64, prefix_len: usize) -> bool {
    // decoded digits are 0..4; any 0 digit is the terminator (reads
    // contain only codes 1..4).
    let mut v = key;
    let mut saw_zero = false;
    for _ in 0..prefix_len {
        if v % BASE == 0 {
            saw_zero = true;
        }
        v /= BASE;
    }
    debug_assert_eq!(v, 0, "key wider than prefix_len");
    saw_zero || key == 0
}

/// Suffix length implied by a complete key (position of the first 0
/// digit), or `None` if the key is incomplete.
pub fn complete_key_len(key: i64, prefix_len: usize) -> Option<usize> {
    let digits = decode_key(key, prefix_len);
    digits.iter().position(|&d| d == 0)
}

/// An accumulated batch of (key, index) pairs plus group bookkeeping.
#[derive(Default)]
pub struct SortingGroupBuffer {
    /// Prefix keys, parallel to `indexes`.
    pub keys: Vec<i64>,
    /// Packed suffix indexes, parallel to `keys`.
    pub indexes: Vec<i64>,
}

impl SortingGroupBuffer {
    /// An empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulated pair count.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when nothing is accumulated.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Append every index of one shuffle group under `key`. Reserves
    /// from the iterator's `size_hint` up front, so exact-size sources
    /// (the reducer's value batches) grow both vectors at most once
    /// instead of element-by-element.
    pub fn push_group(&mut self, key: i64, indexes: impl IntoIterator<Item = i64>) {
        let it = indexes.into_iter();
        let (lo, hi) = it.size_hint();
        let n = hi.unwrap_or(lo);
        self.keys.reserve(n);
        self.indexes.reserve(n);
        for ix in it {
            self.keys.push(key);
            self.indexes.push(ix);
        }
    }

    /// Drain the buffer, returning the parallel (keys, indexes) vectors.
    pub fn take(&mut self) -> (Vec<i64>, Vec<i64>) {
        (std::mem::take(&mut self.keys), std::mem::take(&mut self.indexes))
    }
}

/// Iterator over spans of equal keys in a key-sorted batch, yielding
/// `(start, end, key)`. Being an iterator (rather than a collected
/// `Vec`) lets the reducer walk a flush's groups — twice if needed,
/// it's `Clone` — without allocating a span list per flush.
#[derive(Clone)]
pub struct KeyGroups<'a> {
    keys: &'a [i64],
    start: usize,
}

impl Iterator for KeyGroups<'_> {
    type Item = (usize, usize, i64);

    fn next(&mut self) -> Option<Self::Item> {
        let keys = self.keys;
        if self.start >= keys.len() {
            return None;
        }
        let start = self.start;
        let k = keys[start];
        let mut end = start + 1;
        while end < keys.len() && keys[end] == k {
            end += 1;
        }
        self.start = end;
        Some((start, end, k))
    }
}

/// Spans of equal keys in a key-sorted batch: (start, end, key).
pub fn key_groups(keys: &[i64]) -> KeyGroups<'_> {
    KeyGroups { keys, start: 0 }
}

/// Positions (into a key-sorted batch) whose suffix texts are needed for
/// tie-breaking: members of multi-member groups whose key does not embed
/// the terminator. This is the reducer's fetch plan in index-only mode —
/// everything else is ordered by (key, index) alone.
pub fn tie_break_positions(
    groups: impl IntoIterator<Item = (usize, usize, i64)>,
    prefix_len: usize,
) -> Vec<usize> {
    let mut want = Vec::new();
    for (s, e, k) in groups {
        if e - s > 1 && !key_is_complete(k, prefix_len) {
            want.extend(s..e);
        }
    }
    want
}

/// Fig. 7's rule of thumb, analytically: expected sorting-group size for
/// a random (uniform ACGT) corpus under a given prefix length — the
/// number of suffixes sharing one prefix is ≈ total / 4^min(p, ~len).
pub fn expected_group_size(total_suffixes: f64, prefix_len: usize) -> f64 {
    total_suffixes / 4f64.powi(prefix_len as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::{codes_of, encode_prefix};

    #[test]
    fn complete_key_detection() {
        let p = 10;
        // "AGT" (len 3 < 10): complete
        let k = encode_prefix(&codes_of(b"AGT"), p);
        assert!(key_is_complete(k, p));
        assert_eq!(complete_key_len(k, p), Some(3));
        // 10+ chars of bases: incomplete
        let k = encode_prefix(&codes_of(b"ACGTACGTAC"), p);
        assert!(!key_is_complete(k, p));
        assert_eq!(complete_key_len(k, p), None);
        // empty suffix ("$"): complete, len 0
        assert!(key_is_complete(0, p));
        assert_eq!(complete_key_len(0, p), Some(0));
    }

    #[test]
    fn exactly_prefix_len_is_incomplete() {
        // a suffix of exactly prefix_len base chars does NOT embed its
        // terminator; a longer suffix can share the key.
        let p = 4;
        let short = encode_prefix(&codes_of(b"ACGT"), p); // len == p
        let long = encode_prefix(&codes_of(b"ACGTAAA"), p);
        assert_eq!(short, long);
        assert!(!key_is_complete(short, p));
    }

    #[test]
    fn groups_partition_sorted_keys() {
        let keys = vec![1i64, 1, 2, 5, 5, 5, 9];
        let gs: Vec<_> = key_groups(&keys).collect();
        assert_eq!(gs, vec![(0, 2, 1), (2, 3, 2), (3, 6, 5), (6, 7, 9)]);
        assert_eq!(key_groups(&[]).next(), None);
    }

    #[test]
    fn fig7_longer_prefix_smaller_groups() {
        // Fig. 7: Prefix_1 (len 3) groups 4 suffixes together; Prefix_2
        // (longer) splits them into singletons.
        let total = 1e9;
        assert!(expected_group_size(total, 3) > expected_group_size(total, 13));
        assert!(expected_group_size(total, 23) < 1.0);
    }

    #[test]
    fn tie_break_positions_pick_incomplete_multi_member_groups() {
        let p = 4;
        let complete = encode_prefix(&codes_of(b"AC"), p); // embeds terminator
        let incomplete = encode_prefix(&codes_of(b"ACGT"), p);
        let other = encode_prefix(&codes_of(b"GGGG"), p);
        let keys = vec![complete, complete, incomplete, incomplete, incomplete, other];
        // singleton `other` and complete-key group need no texts
        assert_eq!(tie_break_positions(key_groups(&keys), p), vec![2, 3, 4]);
        assert!(tie_break_positions(key_groups(&[]), p).is_empty());
    }

    #[test]
    fn buffer_accumulates() {
        let mut b = SortingGroupBuffer::new();
        b.push_group(5, [50, 51]);
        b.push_group(7, [70]);
        assert_eq!(b.len(), 3);
        let (k, ix) = b.take();
        assert_eq!(k, vec![5, 5, 7]);
        assert_eq!(ix, vec![50, 51, 70]);
        assert!(b.is_empty());
    }
}

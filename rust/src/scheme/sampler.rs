//! Boundary sampling for the scheme (§IV-A): sample 10000·n suffix keys,
//! sort them (via the PJRT bitonic `sample_sort` kernel when available),
//! and take every 10000-th as a partition boundary.

use crate::runtime;
use crate::suffix::encode::suffix_key;
use crate::suffix::reads::Read;
use crate::util::rng::Rng;

/// Sample `n_samples` suffix keys uniformly over (read, offset).
pub fn sample_suffix_keys(
    reads: &[Read],
    n_samples: usize,
    prefix_len: usize,
    seed: u64,
) -> Vec<i64> {
    sample_suffix_keys_files(&[reads], n_samples, prefix_len, seed)
}

/// Sample suffix keys uniformly over the reads of SEVERAL input files
/// (pair-end construction samples both mate files as one population, so
/// the boundaries balance the joint index stream). A global read index
/// below the total count is drawn and mapped into its file — for a
/// single file this draws exactly the same sequence as
/// [`sample_suffix_keys`] always did.
pub fn sample_suffix_keys_files(
    files: &[&[Read]],
    n_samples: usize,
    prefix_len: usize,
    seed: u64,
) -> Vec<i64> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n_samples);
    let total: usize = files.iter().map(|f| f.len()).sum();
    if total == 0 {
        return out;
    }
    for _ in 0..n_samples {
        let mut i = rng.below(total as u64) as usize;
        let mut r = None;
        for f in files {
            if i < f.len() {
                r = Some(&f[i]);
                break;
            }
            i -= f.len();
        }
        let r = r.expect("global index below total");
        let off = rng.below(r.suffix_count() as u64) as usize;
        out.push(suffix_key(&r.codes, off, prefix_len));
    }
    out
}

/// Sort sampled keys — PJRT bitonic kernel in blocks merged natively, or
/// the native sort when artifacts are absent.
pub fn sort_samples(mut samples: Vec<i64>) -> Vec<i64> {
    runtime::with_engine(|eng| match eng {
        Some(eng) => {
            // sort in kernel-sized blocks, then k-way merge natively
            let block = 4096.min(samples.len().next_power_of_two());
            let mut runs: Vec<Vec<i64>> = Vec::new();
            for chunk in samples.chunks(block) {
                let mut v = chunk.to_vec();
                if eng.sample_sort(&mut v).is_err() {
                    v.sort_unstable();
                }
                runs.push(v);
            }
            merge_runs(runs)
        }
        None => {
            samples.sort_unstable();
            samples
        }
    })
}

fn merge_runs(mut runs: Vec<Vec<i64>>) -> Vec<i64> {
    while runs.len() > 1 {
        let b = runs.pop().unwrap();
        let a = runs.pop().unwrap();
        let mut m = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            if a[i] <= b[j] {
                m.push(a[i]);
                i += 1;
            } else {
                m.push(b[j]);
                j += 1;
            }
        }
        m.extend_from_slice(&a[i..]);
        m.extend_from_slice(&b[j..]);
        runs.push(m);
    }
    runs.pop().unwrap_or_default()
}

/// Pick the n-1 boundaries from sorted samples (every stride-th, §IV-A).
pub fn boundaries_from_sorted(sorted: &[i64], n_reducers: usize) -> Vec<i64> {
    let mut out = Vec::with_capacity(n_reducers.saturating_sub(1));
    if sorted.is_empty() || n_reducers <= 1 {
        return out;
    }
    let stride = (sorted.len() / n_reducers).max(1);
    for r in 1..n_reducers {
        out.push(sorted[(r * stride).min(sorted.len() - 1)]);
    }
    out
}

/// Convenience: sample + sort + boundaries.
pub fn make_boundaries(
    reads: &[Read],
    n_reducers: usize,
    samples_per_reducer: usize,
    prefix_len: usize,
    seed: u64,
) -> Vec<i64> {
    make_boundaries_files(&[reads], n_reducers, samples_per_reducer, prefix_len, seed)
}

/// Multi-file convenience: sample all files as one population, sort,
/// pick boundaries.
pub fn make_boundaries_files(
    files: &[&[Read]],
    n_reducers: usize,
    samples_per_reducer: usize,
    prefix_len: usize,
    seed: u64,
) -> Vec<i64> {
    let samples =
        sample_suffix_keys_files(files, samples_per_reducer * n_reducers, prefix_len, seed);
    boundaries_from_sorted(&sort_samples(samples), n_reducers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;
    use crate::suffix::reads::{synth_corpus, CorpusSpec};

    #[test]
    fn boundaries_are_sorted_and_sized() {
        let reads = synth_corpus(&CorpusSpec { n_reads: 200, ..Default::default() });
        let b = make_boundaries(&reads, 8, 100, 13, 3);
        assert_eq!(b.len(), 7);
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn boundaries_balance_partitions() {
        let reads = synth_corpus(&CorpusSpec { n_reads: 500, read_len: 80, ..Default::default() });
        let n_red = 4;
        let b = make_boundaries(&reads, n_red, 1000, 13, 5);
        // route every actual suffix; partitions within 2x of even
        let mut counts = vec![0u64; n_red];
        let mut total = 0u64;
        for r in &reads {
            for off in 0..=r.len() {
                let k = suffix_key(&r.codes, off, 13);
                counts[native::bucket(k, &b) as usize] += 1;
                total += 1;
            }
        }
        let even = total / n_red as u64;
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > even / 2 && *c < even * 2, "partition {i}: {c} vs {even}");
        }
    }

    #[test]
    fn merge_runs_sorts() {
        let runs = vec![vec![1i64, 5, 9], vec![2, 3, 4], vec![0, 7]];
        assert_eq!(merge_runs(runs), vec![0, 1, 2, 3, 4, 5, 7, 9]);
    }

    #[test]
    fn empty_inputs() {
        assert!(sample_suffix_keys(&[], 10, 13, 1).is_empty());
        assert!(sample_suffix_keys_files(&[&[], &[]], 10, 13, 1).is_empty());
        assert!(boundaries_from_sorted(&[], 4).is_empty());
        assert!(merge_runs(vec![]).is_empty());
    }

    #[test]
    fn multi_file_sampling_matches_concatenation() {
        // splitting one corpus into two files must not change the sampled
        // keys (same seed, same global read indexing), so single- and
        // two-file runs of the same data get identical boundaries.
        let reads = synth_corpus(&CorpusSpec { n_reads: 120, ..Default::default() });
        let (a, b) = reads.split_at(47);
        let joint = sample_suffix_keys(&reads, 500, 13, 9);
        let split = sample_suffix_keys_files(&[a, b], 500, 13, 9);
        assert_eq!(joint, split);
    }
}

//! JVM heap/GC model (§III "GC overhead limit or Java heap space",
//! §IV-C young/old generations, AlwaysTenure + ConcMarkSweep).
//!
//! The simulator needs two things from this model:
//!  * *failure prediction*: does a reducer with heap H survive a shuffle
//!    of S bytes whose largest sorting group is g bytes? (TeraSort Case 5
//!    dies here; the scheme's fixed-width pairs never do.)
//!  * *throughput penalty*: what fraction of wall time goes to GC pauses
//!    (stop-the-world) vs concurrent sweeping (the scheme's CMS choice).

/// Outcome of running one reducer's sort workload in a modeled heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HeapOutcome {
    /// Completed; `pause_fraction` of wall time was lost to GC.
    Ok { pause_fraction: f64 },
    /// `java.lang.OutOfMemoryError: Java heap space`
    HeapSpace,
    /// `java.lang.OutOfMemoryError: GC overhead limit exceeded`
    GcOverheadLimit,
}

/// One reducer JVM's heap shape and collector choice.
#[derive(Clone, Copy, Debug)]
pub struct HeapConfig {
    /// Total heap (-Xmx).
    pub heap_bytes: u64,
    /// Young generation (paper: 1 GB, AlwaysTenure).
    pub young_bytes: u64,
    /// Concurrent old-gen collection (-XX:+UseConcMarkSweepGC).
    pub concurrent_sweep: bool,
}

impl HeapConfig {
    /// Paper's reducer JVM: 7 GB heap, 1 GB young, CMS.
    pub fn paper_scheme() -> Self {
        Self {
            heap_bytes: 7 << 30,
            young_bytes: 1 << 30,
            concurrent_sweep: true,
        }
    }

    /// TeraSort's default reducer JVM: same heap, default stop-the-world.
    pub fn paper_terasort(heap_bytes: u64) -> Self {
        Self { heap_bytes, young_bytes: heap_bytes / 8, concurrent_sweep: false }
    }
}

/// Sorting a group of `g` bytes needs ~2g live bytes (input + sort
/// scratch / object headers).
pub const SORT_WORKING_FACTOR: f64 = 2.0;
/// Java object overhead for many small objects adds ~1.4x on top
/// (measured folklore; the paper's groups are boxed suffix strings).
pub const OBJECT_OVERHEAD: f64 = 1.4;

/// Model one reducer: total bytes churned through the heap (`shuffled`)
/// and the largest single sorting group (`max_group`).
pub fn simulate_reducer_heap(cfg: &HeapConfig, shuffled: u64, max_group: u64) -> HeapOutcome {
    let old_gen = cfg.heap_bytes.saturating_sub(cfg.young_bytes) as f64;
    let live_peak = max_group as f64 * SORT_WORKING_FACTOR * OBJECT_OVERHEAD;
    if live_peak > old_gen {
        return HeapOutcome::HeapSpace;
    }
    let occupancy = live_peak / old_gen;
    // GC-overhead-limit: >98% of time collecting while recovering <2% —
    // approximated by near-full old gen (JVM thrashes before the OOM).
    if occupancy > 0.90 {
        return HeapOutcome::GcOverheadLimit;
    }
    // churn cycles: every (old_gen - live_peak) bytes of allocation forces
    // a major collection whose cost scales with the live set.
    let headroom = (old_gen - live_peak).max(1.0);
    let cycles = shuffled as f64 / headroom;
    // pause per cycle grows with occupancy (more to trace/compact)
    let pause_unit = occupancy / (1.0 - occupancy);
    let mut pause_fraction = (cycles * pause_unit * 0.02).min(0.95);
    if cfg.concurrent_sweep {
        // CMS sweeps concurrently; paper's §IV-C setup keeps acquisition
        // running — residual pauses are young-gen + remark only.
        pause_fraction *= 0.25;
    }
    HeapOutcome::Ok { pause_fraction }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: u64 = 1 << 30;

    #[test]
    fn small_groups_are_fine() {
        let cfg = HeapConfig::paper_scheme();
        // scheme: 1.6e6 pairs of 16 B = ~26 MB groups
        let out = simulate_reducer_heap(&cfg, 17 * GB, 26 << 20);
        match out {
            HeapOutcome::Ok { pause_fraction } => assert!(pause_fraction < 0.2),
            other => panic!("expected Ok, got {other:?}"),
        }
    }

    #[test]
    fn giant_group_blows_heap() {
        // TeraSort Case 5: a reducer holds ~multi-GB same-prefix groups
        let cfg = HeapConfig::paper_terasort(7 * GB);
        let out = simulate_reducer_heap(&cfg, 111 * GB, 3 * GB);
        assert!(matches!(out, HeapOutcome::HeapSpace | HeapOutcome::GcOverheadLimit));
    }

    #[test]
    fn bigger_heap_defers_breakdown() {
        // mem_heap (Table VI): same workload, 15 GB heap -> survives
        let small = HeapConfig::paper_terasort(7 * GB);
        let big = HeapConfig::paper_terasort(15 * GB);
        let g = 2 * GB;
        let dies = simulate_reducer_heap(&small, 50 * GB, g);
        let lives = simulate_reducer_heap(&big, 50 * GB, g);
        assert!(!matches!(dies, HeapOutcome::Ok { .. }));
        assert!(matches!(lives, HeapOutcome::Ok { .. }));
    }

    #[test]
    fn cms_reduces_pauses() {
        let stw = HeapConfig { concurrent_sweep: false, ..HeapConfig::paper_scheme() };
        let cms = HeapConfig::paper_scheme();
        let (s, c) = (
            simulate_reducer_heap(&stw, 40 * GB, 500 << 20),
            simulate_reducer_heap(&cms, 40 * GB, 500 << 20),
        );
        let (HeapOutcome::Ok { pause_fraction: ps }, HeapOutcome::Ok { pause_fraction: pc }) =
            (s, c)
        else {
            panic!("both should complete: {s:?} {c:?}");
        };
        assert!(pc < ps);
    }

    #[test]
    fn more_churn_more_pause() {
        let cfg = HeapConfig::paper_terasort(7 * GB);
        let HeapOutcome::Ok { pause_fraction: a } =
            simulate_reducer_heap(&cfg, 20 * GB, 100 << 20)
        else {
            panic!()
        };
        let HeapOutcome::Ok { pause_fraction: b } =
            simulate_reducer_heap(&cfg, 100 * GB, 100 << 20)
        else {
            panic!()
        };
        assert!(b > a);
    }
}

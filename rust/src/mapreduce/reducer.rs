//! Reduce task execution with Hadoop's shuffle/merge mechanics (Fig. 4):
//! fetched map segments land in a memory buffer (70% of heap); the
//! in-memory merger spills to disk at 66% occupancy; oversized segments
//! bypass memory; on-disk files above io.sort.factor trigger intermediate
//! merge rounds; the final k-way merge feeds `reduce()` grouped by key,
//! and output records stream straight into an [`OutputSink`] (the
//! engine's spooled "HDFS" file) instead of accumulating in memory.
//! This module is what makes TeraSort's reduce-side Local R/W grow from
//! 1.03 to 1.88 units as the input grows (Table III).

use std::fs::File;
use std::io::{self, BufWriter, Read as IoRead, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::footprint::{Channel, Ledger};
use crate::mapreduce::io::OutputSink;
use crate::mapreduce::job::JobConf;
use crate::mapreduce::mapper::{Segment, SpillFile};
use crate::mapreduce::merge::{
    kway_merge, kway_merge_fixed, merge_fixed_segments_threads, run_merge_rounds,
    run_merge_rounds_fixed, FixedRun, Run,
};
use crate::mapreduce::record::{fixed_frame, Record, FIXED_WIRE_BYTES};
use crate::mapreduce::resident;

/// User reduce logic: one call per key group, then `finish` (the scheme
/// flushes its accumulated sorting groups there). Both hooks are
/// fallible: a clean failure (a KV fetch error, say) returns an
/// `io::Error` that aborts the merge and surfaces from the job — it is
/// *not* a panic (panics are reserved for bugs; the engine's
/// catch_unwind path converts those separately).
pub trait ReduceTask: Send {
    fn reduce(
        &mut self,
        key: &[u8],
        values: Vec<Vec<u8>>,
        out: &mut dyn FnMut(Record),
    ) -> io::Result<()>;
    fn finish(&mut self, _out: &mut dyn FnMut(Record)) -> io::Result<()> {
        Ok(())
    }

    /// Fixed-width grouping: one call per key group of packed u64
    /// values, borrowed from a buffer the merge loop reuses. The
    /// default adapts to [`reduce`](ReduceTask::reduce) by re-encoding
    /// the group; hot reducers override it to skip the conversion.
    fn reduce_fixed(
        &mut self,
        key: u64,
        values: &[u64],
        out: &mut dyn FnMut(Record),
    ) -> io::Result<()> {
        self.reduce(
            &key.to_be_bytes(),
            values.iter().map(|v| v.to_be_bytes().to_vec()).collect(),
            out,
        )
    }
}

/// Infallible closures are reduce tasks (the common test/bench shape).
impl<F: FnMut(&[u8], Vec<Vec<u8>>, &mut dyn FnMut(Record)) + Send> ReduceTask for F {
    fn reduce(
        &mut self,
        key: &[u8],
        values: Vec<Vec<u8>>,
        out: &mut dyn FnMut(Record),
    ) -> io::Result<()> {
        self(key, values, out);
        Ok(())
    }
}

/// Per-reduce-task statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReduceTaskStats {
    pub shuffled_bytes: u64,
    pub shuffled_records: u64,
    pub disk_segments: u64,
    pub mem_merges: u64,
    pub merge_rounds_bytes: u64,
    pub groups: u64,
    pub max_group: u64,
    pub output_records: u64,
    pub output_bytes: u64,
}

/// Execute one reduce attempt: fetch segment `partition` of every map
/// output, run the merge pipeline, call `task` per key group. Output
/// records stream into `sink` as they are produced — the engine passes
/// a spooled "HDFS" file sink, so the output is never memory-resident.
#[allow(clippy::too_many_arguments)]
pub fn run_reduce_task(
    task_id: usize,
    partition: usize,
    map_outputs: &[SpillFile],
    task: &mut dyn ReduceTask,
    sink: &mut dyn OutputSink,
    conf: &JobConf,
    ledger: &Arc<Ledger>,
    dir: &Path,
) -> io::Result<ReduceTaskStats> {
    let mut stats = ReduceTaskStats::default();
    let mut disk_files: Vec<PathBuf> = Vec::new();
    let mut mem_segments: Vec<Vec<Record>> = Vec::new();
    let mut mem_bytes: u64 = 0;
    let mut scratch = 0usize;
    let seg_limit = conf.segment_memory_limit();
    let merge_trigger = conf.merge_trigger();

    // ---- shuffle: fetch this partition's segment from every mapper ----
    for mo in map_outputs {
        let seg: Segment = mo.segments[partition];
        if seg.records == 0 {
            continue;
        }
        ledger.add(Channel::Shuffle, seg.bytes);
        stats.shuffled_bytes += seg.bytes;
        stats.shuffled_records += seg.records;
        if seg.bytes > seg_limit {
            // oversized segment goes straight to local disk
            let path = dir.join(format!("red{task_id}_seg{scratch}"));
            scratch += 1;
            copy_segment(&mo.path, seg, &path)?;
            ledger.add(Channel::ReduceLocalWrite, seg.bytes);
            stats.disk_segments += 1;
            disk_files.push(path);
        } else {
            let mut recs = Vec::with_capacity(seg.records as usize);
            let run = Run::from_segment(&mo.path, seg.offset, seg.records)?;
            kway_merge(vec![run], |r| {
                recs.push(r);
                Ok(())
            })?;
            mem_bytes += seg.bytes;
            resident::add(seg.records);
            mem_segments.push(recs);
            if mem_bytes >= merge_trigger {
                // memory-to-disk merge
                let path = dir.join(format!("red{task_id}_memmerge{scratch}"));
                scratch += 1;
                let taken = std::mem::take(&mut mem_segments);
                let drained: u64 = taken.iter().map(|s| s.len() as u64).sum();
                let written = merge_mem_to_disk(taken, &path)?;
                resident::sub(drained);
                ledger.add(Channel::ReduceLocalWrite, written);
                stats.mem_merges += 1;
                mem_bytes = 0;
                disk_files.push(path);
            }
        }
    }

    // ---- intermediate on-disk merge rounds (io.sort.factor) ----
    let pre_r = ledger.get(Channel::ReduceLocalRead);
    let disk_files = run_merge_rounds(
        disk_files,
        conf.io_sort_factor,
        &mut |i| dir.join(format!("red{task_id}_round{i}")),
        &mut |b| ledger.add(Channel::ReduceLocalRead, b),
        &mut |b| ledger.add(Channel::ReduceLocalWrite, b),
    )?;
    stats.merge_rounds_bytes = ledger.get(Channel::ReduceLocalRead) - pre_r;

    // ---- final merge feeding reduce(), grouped by key ----
    let mut runs: Vec<Run> = Vec::new();
    for p in &disk_files {
        ledger.add(Channel::ReduceLocalRead, std::fs::metadata(p)?.len());
        runs.push(Run::from_path(p)?);
    }
    let mem_resident: u64 = mem_segments.iter().map(|s| s.len() as u64).sum();
    for seg in mem_segments {
        runs.push(Run::from_vec(seg));
    }

    // the user task's emit closure cannot return an error, so a sink
    // failure is stashed — and the merge loop, which CAN error, aborts
    // on the next record instead of burning the rest of the partition.
    // The task's own clean errors propagate through the merge closure
    // (mid-stream groups) or `tail_res` (last group + finish).
    let mut sink_err: Option<io::Error> = None;
    let sink_broken = std::cell::Cell::new(false);
    let merge_res;
    let mut tail_res: io::Result<()> = Ok(());
    {
        let mut out = |rec: Record| {
            stats.output_records += 1;
            stats.output_bytes += rec.wire_bytes();
            if !sink_broken.get() {
                if let Err(e) = sink.push(rec) {
                    sink_err = Some(e);
                    sink_broken.set(true);
                }
            }
        };
        let mut cur_key: Option<Vec<u8>> = None;
        let mut cur_vals: Vec<Vec<u8>> = Vec::new();
        merge_res = kway_merge(runs, |rec| {
            match &cur_key {
                Some(k) if *k == rec.key => cur_vals.push(rec.value),
                Some(k) => {
                    stats.groups += 1;
                    stats.max_group = stats.max_group.max(cur_vals.len() as u64);
                    task.reduce(k, std::mem::take(&mut cur_vals), &mut out)?;
                    cur_key = Some(rec.key);
                    cur_vals.push(rec.value);
                }
                None => {
                    cur_key = Some(rec.key);
                    cur_vals.push(rec.value);
                }
            }
            if sink_broken.get() {
                return Err(io::Error::other("output sink failed; aborting the merge"));
            }
            Ok(())
        });
        if merge_res.is_ok() && !sink_broken.get() {
            tail_res = (|| {
                if let Some(k) = cur_key {
                    stats.groups += 1;
                    stats.max_group = stats.max_group.max(cur_vals.len() as u64);
                    task.reduce(&k, cur_vals, &mut out)?;
                }
                task.finish(&mut out)
            })();
        }
    }
    resident::sub(mem_resident);
    // the sink's own error outranks the merge-abort placeholder
    if let Some(e) = sink_err {
        return Err(e);
    }
    merge_res?;
    tail_res?;
    for p in disk_files {
        let _ = std::fs::remove_file(p);
    }
    Ok(stats)
}

/// Copy one map-output segment to its own file (records pass through
/// unchanged — they're already sorted).
fn copy_segment(src: &Path, seg: Segment, dst: &Path) -> io::Result<()> {
    let run = Run::from_segment(src, seg.offset, seg.records)?;
    let mut w = BufWriter::new(File::create(dst)?);
    kway_merge(vec![run], |r| r.write_to(&mut w))?;
    w.flush()
}

fn merge_mem_to_disk(segments: Vec<Vec<Record>>, dst: &Path) -> io::Result<u64> {
    let runs: Vec<Run> = segments.into_iter().map(Run::from_vec).collect();
    let mut w = BufWriter::new(File::create(dst)?);
    let mut bytes = 0u64;
    kway_merge(runs, |r| {
        bytes += r.wire_bytes();
        r.write_to(&mut w)
    })?;
    w.flush()?;
    Ok(bytes)
}

// ---------------- fixed-width fast path ----------------

/// Execute one reduce attempt on the fixed-width fast path: the same
/// shuffle/merge pipeline as [`run_reduce_task`], but in-memory segments
/// hold packed `(u64, u64)` pairs, every merge runs on the loser tree
/// over strided 24 B readers, and key groups reach the task as borrowed
/// `&[u64]` slices from one reused buffer — zero per-record allocation.
/// Output records stream into `sink` exactly as in [`run_reduce_task`].
/// Bytes on every ledger channel (and all stats) are identical to the
/// generic path; see `tests/shuffle_equivalence`.
#[allow(clippy::too_many_arguments)]
pub fn run_reduce_task_fixed(
    task_id: usize,
    partition: usize,
    map_outputs: &[SpillFile],
    task: &mut dyn ReduceTask,
    sink: &mut dyn OutputSink,
    conf: &JobConf,
    ledger: &Arc<Ledger>,
    dir: &Path,
) -> io::Result<ReduceTaskStats> {
    let mut stats = ReduceTaskStats::default();
    let mut disk_files: Vec<PathBuf> = Vec::new();
    let mut mem_segments: Vec<Vec<(u64, u64)>> = Vec::new();
    let mut mem_bytes: u64 = 0;
    let mut scratch = 0usize;
    let seg_limit = conf.segment_memory_limit();
    let merge_trigger = conf.merge_trigger();

    // ---- shuffle: fetch this partition's segment from every mapper ----
    for mo in map_outputs {
        let seg: Segment = mo.segments[partition];
        if seg.records == 0 {
            continue;
        }
        ledger.add(Channel::Shuffle, seg.bytes);
        stats.shuffled_bytes += seg.bytes;
        stats.shuffled_records += seg.records;
        if seg.bytes > seg_limit {
            // oversized segment goes straight to local disk — the frames
            // are contiguous, so this is one raw byte copy
            let path = dir.join(format!("red{task_id}_seg{scratch}"));
            scratch += 1;
            copy_segment_raw(&mo.path, seg, &path)?;
            ledger.add(Channel::ReduceLocalWrite, seg.bytes);
            stats.disk_segments += 1;
            disk_files.push(path);
        } else {
            let mut recs: Vec<(u64, u64)> = Vec::with_capacity(seg.records as usize);
            let mut run = FixedRun::from_segment(&mo.path, seg.offset, seg.records)?;
            while let Some(kv) = run.next_pair()? {
                recs.push(kv);
            }
            mem_bytes += seg.bytes;
            resident::add(seg.records);
            mem_segments.push(recs);
            if mem_bytes >= merge_trigger {
                // memory-to-disk merge
                let path = dir.join(format!("red{task_id}_memmerge{scratch}"));
                scratch += 1;
                let taken = std::mem::take(&mut mem_segments);
                let drained: u64 = taken.iter().map(|s| s.len() as u64).sum();
                let written =
                    merge_mem_to_disk_fixed(taken, &path, conf.parallel_sort_threads)?;
                resident::sub(drained);
                ledger.add(Channel::ReduceLocalWrite, written);
                stats.mem_merges += 1;
                mem_bytes = 0;
                disk_files.push(path);
            }
        }
    }

    // ---- intermediate on-disk merge rounds (io.sort.factor) ----
    let pre_r = ledger.get(Channel::ReduceLocalRead);
    let disk_files = run_merge_rounds_fixed(
        disk_files,
        conf.io_sort_factor,
        &mut |i| dir.join(format!("red{task_id}_round{i}")),
        &mut |b| ledger.add(Channel::ReduceLocalRead, b),
        &mut |b| ledger.add(Channel::ReduceLocalWrite, b),
    )?;
    stats.merge_rounds_bytes = ledger.get(Channel::ReduceLocalRead) - pre_r;

    // ---- final merge feeding reduce(), grouped by key ----
    let mut runs: Vec<FixedRun> = Vec::new();
    for p in &disk_files {
        ledger.add(Channel::ReduceLocalRead, std::fs::metadata(p)?.len());
        runs.push(FixedRun::from_path(p)?);
    }
    let mem_resident: u64 = mem_segments.iter().map(|s| s.len() as u64).sum();
    for seg in mem_segments {
        runs.push(FixedRun::from_vec(seg));
    }

    // as in [`run_reduce_task`]: stash the sink error, abort the merge
    let mut sink_err: Option<io::Error> = None;
    let sink_broken = std::cell::Cell::new(false);
    let merge_res;
    let mut tail_res: io::Result<()> = Ok(());
    {
        let mut out = |rec: Record| {
            stats.output_records += 1;
            stats.output_bytes += rec.wire_bytes();
            if !sink_broken.get() {
                if let Err(e) = sink.push(rec) {
                    sink_err = Some(e);
                    sink_broken.set(true);
                }
            }
        };
        let mut cur_key: Option<u64> = None;
        let mut vals: Vec<u64> = Vec::new(); // reused across groups
        merge_res = kway_merge_fixed(runs, |key, val| {
            match cur_key {
                Some(k) if k == key => vals.push(val),
                Some(k) => {
                    stats.groups += 1;
                    stats.max_group = stats.max_group.max(vals.len() as u64);
                    task.reduce_fixed(k, &vals, &mut out)?;
                    vals.clear();
                    cur_key = Some(key);
                    vals.push(val);
                }
                None => {
                    cur_key = Some(key);
                    vals.push(val);
                }
            }
            if sink_broken.get() {
                return Err(io::Error::other("output sink failed; aborting the merge"));
            }
            Ok(())
        });
        if merge_res.is_ok() && !sink_broken.get() {
            tail_res = (|| {
                if let Some(k) = cur_key {
                    stats.groups += 1;
                    stats.max_group = stats.max_group.max(vals.len() as u64);
                    task.reduce_fixed(k, &vals, &mut out)?;
                }
                task.finish(&mut out)
            })();
        }
    }
    resident::sub(mem_resident);
    if let Some(e) = sink_err {
        return Err(e);
    }
    merge_res?;
    tail_res?;
    for p in disk_files {
        let _ = std::fs::remove_file(p);
    }
    Ok(stats)
}

/// Copy one fixed-width map-output segment to its own file. Records are
/// already sorted and frames are contiguous, so this is a raw byte copy
/// producing exactly the bytes [`copy_segment`] re-encodes.
fn copy_segment_raw(src: &Path, seg: Segment, dst: &Path) -> io::Result<()> {
    let mut f = File::open(src)?;
    f.seek(SeekFrom::Start(seg.offset))?;
    let mut r = f.take(seg.bytes);
    let mut w = BufWriter::new(File::create(dst)?);
    let copied = io::copy(&mut r, &mut w)?;
    if copied != seg.bytes {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            format!("segment truncated: copied {copied} of {} bytes", seg.bytes),
        ));
    }
    w.flush()
}

/// Spill buffered shuffle segments to one sorted on-disk run. `threads`
/// > 1 range-partitions the merge (`merge_fixed_segments_threads`);
/// 1 keeps the literal sequential `FixedRun` + `kway_merge_fixed` path
/// — identical bytes either way, so `ReduceLocalWrite` totals match.
fn merge_mem_to_disk_fixed(
    segments: Vec<Vec<(u64, u64)>>,
    dst: &Path,
    threads: usize,
) -> io::Result<u64> {
    let mut w = BufWriter::new(File::create(dst)?);
    let mut bytes = 0u64;
    merge_fixed_segments_threads(segments, threads, |key, val| {
        bytes += FIXED_WIRE_BYTES;
        w.write_all(&fixed_frame(key, val))
    })?;
    w.flush()?;
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::io::spool_records;
    use crate::mapreduce::mapper::{run_map_task, MapTask};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("samr-red-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Build map outputs by actually running map tasks over spooled splits.
    fn make_map_outputs(
        dir: &Path,
        conf: &JobConf,
        n_maps: usize,
        recs_per_map: usize,
    ) -> Vec<SpillFile> {
        let ledger = Ledger::new();
        (0..n_maps)
            .map(|m| {
                let split: Vec<Record> = (0..recs_per_map)
                    .map(|i| {
                        let k = format!("key{:05}", (i * 7919 + m * 13) % 1000);
                        Record::new(k.into_bytes(), vec![m as u8; 16])
                    })
                    .collect();
                let splits =
                    spool_records(dir.join(format!("in{m}")), &split, u64::MAX).unwrap();
                let mut input = splits[0].open().unwrap();
                let n_parts = conf.n_reducers as u32;
                let mut mapper =
                    |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
                let task: &mut dyn MapTask = &mut mapper;
                run_map_task(
                    m,
                    &mut input,
                    task,
                    conf,
                    &move |k| (k[5] as u32) % n_parts,
                    &ledger,
                    dir,
                )
                .unwrap()
                .0
            })
            .collect()
    }

    #[test]
    fn all_in_memory_reduce_has_no_local_io() {
        let dir = tmpdir("mem");
        let conf = JobConf { n_reducers: 2, ..JobConf::default() }; // huge buffers
        let maps = make_map_outputs(&dir, &conf, 3, 200);
        let ledger = Ledger::new();
        let mut seen = 0u64;
        let mut red = |_k: &[u8], vals: Vec<Vec<u8>>, _out: &mut dyn FnMut(Record)| {
            seen += vals.len() as u64;
        };
        let mut out: Vec<Record> = Vec::new();
        let stats =
            run_reduce_task(0, 0, &maps, &mut red, &mut out, &conf, &ledger, &dir).unwrap();
        assert!(out.is_empty());
        assert!(stats.shuffled_records > 0);
        assert_eq!(seen, stats.shuffled_records);
        assert_eq!(ledger.get(Channel::ReduceLocalRead), 0);
        assert_eq!(ledger.get(Channel::ReduceLocalWrite), 0);
        assert_eq!(ledger.get(Channel::Shuffle), stats.shuffled_bytes);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tight_memory_spills_and_reads_back_once() {
        let dir = tmpdir("spill");
        // tiny reducer heap: everything spills, no intermediate rounds
        let conf = JobConf {
            n_reducers: 2,
            reducer_heap_bytes: 8 << 10, // 8 KB heap -> 5.7 KB buffer
            ..JobConf::default()
        };
        let maps = make_map_outputs(&dir, &conf, 4, 300);
        let ledger = Ledger::new();
        let mut red = |_k: &[u8], _v: Vec<Vec<u8>>, _o: &mut dyn FnMut(Record)| {};
        let mut out: Vec<Record> = Vec::new();
        let stats =
            run_reduce_task(1, 1, &maps, &mut red, &mut out, &conf, &ledger, &dir).unwrap();
        let w = ledger.get(Channel::ReduceLocalWrite);
        let r = ledger.get(Channel::ReduceLocalRead);
        // paper Case 1 behaviour: ~1W (all spilled) and ~1R (final merge)
        assert!(w > 0 && r == w, "r={r} w={w}");
        assert!(w >= stats.shuffled_bytes, "everything shuffled must hit disk");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fixed_reduce_task_matches_generic() {
        // same map outputs (8 B keys + values) through both reduce
        // paths, with memory tight enough to force spills and rounds
        let dir = tmpdir("fixedeq");
        let conf = JobConf {
            n_reducers: 2,
            reducer_heap_bytes: 8 << 10,
            io_sort_factor: 3,
            ..JobConf::default()
        };
        let ledger = Ledger::new();
        let maps: Vec<SpillFile> = (0..4)
            .map(|m| {
                let split: Vec<Record> = (0..300)
                    .map(|i| {
                        let k = ((i * 7919 + m * 13) % 500) as u64;
                        Record::new(k.to_be_bytes().to_vec(), (i as u64).to_be_bytes().to_vec())
                    })
                    .collect();
                let splits =
                    spool_records(dir.join(format!("fin{m}")), &split, u64::MAX).unwrap();
                let mut input = splits[0].open().unwrap();
                let mut mapper =
                    |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
                let task: &mut dyn MapTask = &mut mapper;
                run_map_task(m, &mut input, task, &conf, &move |k| (k[7] as u32) % 2, &ledger, &dir)
                    .unwrap()
                    .0
            })
            .collect();
        let mut results = Vec::new();
        for fixed in [false, true] {
            let ledger = Ledger::new();
            let mut seen: Vec<(Vec<u8>, Vec<Vec<u8>>)> = Vec::new();
            let mut red = |k: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                seen.push((k.to_vec(), vals.clone()));
                out(Record::new(k.to_vec(), (vals.len() as u64).to_be_bytes().to_vec()));
            };
            let task: &mut dyn ReduceTask = &mut red;
            let mut out: Vec<Record> = Vec::new();
            let stats = if fixed {
                run_reduce_task_fixed(1, 1, &maps, task, &mut out, &conf, &ledger, &dir)
                    .unwrap()
            } else {
                run_reduce_task(1, 1, &maps, task, &mut out, &conf, &ledger, &dir).unwrap()
            };
            assert!(ledger.get(Channel::ReduceLocalWrite) > 0, "want reduce-side spills");
            results.push((
                out,
                seen,
                stats.shuffled_bytes,
                stats.groups,
                stats.max_group,
                ledger.snapshot(),
            ));
        }
        assert_eq!(results[0], results[1]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn groups_are_key_sorted_and_complete() {
        let dir = tmpdir("groups");
        let conf = JobConf { n_reducers: 1, ..JobConf::default() };
        let maps = make_map_outputs(&dir, &conf, 2, 100);
        let ledger = Ledger::new();
        let mut keys: Vec<Vec<u8>> = Vec::new();
        let mut total = 0usize;
        let mut red = |k: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
            keys.push(k.to_vec());
            total += vals.len();
            out(Record::new(k.to_vec(), (vals.len() as u32).to_be_bytes().to_vec()));
        };
        let mut out: Vec<Record> = Vec::new();
        let stats =
            run_reduce_task(0, 0, &maps, &mut red, &mut out, &conf, &ledger, &dir).unwrap();
        assert_eq!(total as u64, stats.shuffled_records);
        assert_eq!(out.len(), keys.len());
        for w in keys.windows(2) {
            assert!(w[0] < w[1], "group keys must be strictly increasing");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Sampled range partitioning (§IV-A) — the TotalOrderPartitioner analog
//! used by both TeraSort and the scheme: sample `10000 × n` keys, sort
//! them, take every 10000-th as a boundary, route key k to partition
//! |{b : b <= k}|.

use std::sync::Arc;

/// Samples per reducer (paper: N = 10000 × n).
pub const SAMPLES_PER_REDUCER: usize = 10_000;

/// Range partitioner over byte-comparable keys.
#[derive(Clone, Debug)]
pub struct RangePartitioner {
    boundaries: Vec<Vec<u8>>, // n_reducers - 1 sorted keys
}

impl RangePartitioner {
    pub fn new(boundaries: Vec<Vec<u8>>) -> Self {
        debug_assert!(boundaries.windows(2).all(|w| w[0] <= w[1]));
        Self { boundaries }
    }

    /// Paper's recipe: sort the samples, pick the `s`-th, `2s`-th, ...
    /// as the `n-1` boundaries (s = samples / n).
    pub fn from_samples(mut samples: Vec<Vec<u8>>, n_reducers: usize) -> Self {
        assert!(n_reducers >= 1);
        samples.sort();
        let n = samples.len();
        let mut boundaries = Vec::with_capacity(n_reducers.saturating_sub(1));
        if n > 0 {
            let stride = (n / n_reducers).max(1);
            for r in 1..n_reducers {
                let i = (r * stride).min(n - 1);
                boundaries.push(samples[i].clone());
            }
        } else {
            boundaries.resize(n_reducers.saturating_sub(1), Vec::new());
        }
        Self::new(boundaries)
    }

    pub fn n_partitions(&self) -> usize {
        self.boundaries.len() + 1
    }

    /// partition(k) = #{b : b <= k} — matches the L1 `bucket` kernel's
    /// searchsorted-right semantics exactly.
    pub fn partition(&self, key: &[u8]) -> u32 {
        self.boundaries.partition_point(|b| b.as_slice() <= key) as u32
    }

    pub fn boundaries(&self) -> &[Vec<u8>] {
        &self.boundaries
    }

    /// Closure form for the MR engine.
    pub fn as_fn(self: Arc<Self>) -> Arc<dyn Fn(&[u8]) -> u32 + Send + Sync> {
        Arc::new(move |k| self.partition(k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn partition_semantics() {
        let p = RangePartitioner::new(vec![b"10".to_vec(), b"50".to_vec()]);
        assert_eq!(p.n_partitions(), 3);
        assert_eq!(p.partition(b"05"), 0);
        assert_eq!(p.partition(b"10"), 1); // boundary key goes right
        assert_eq!(p.partition(b"49"), 1);
        assert_eq!(p.partition(b"50"), 2);
        assert_eq!(p.partition(b"99"), 2);
    }

    #[test]
    fn from_samples_balances_random_keys() {
        let mut rng = Rng::new(17);
        let n_red = 8;
        let samples: Vec<Vec<u8>> = (0..SAMPLES_PER_REDUCER * n_red)
            .map(|_| rng.next_u64().to_be_bytes().to_vec())
            .collect();
        let part = RangePartitioner::from_samples(samples, n_red);
        assert_eq!(part.n_partitions(), n_red);
        // route a fresh random population; buckets within ±25% of even
        let mut counts = vec![0u64; n_red];
        let total = 80_000u64;
        for _ in 0..total {
            counts[part.partition(&rng.next_u64().to_be_bytes()) as usize] += 1;
        }
        let even = total / n_red as u64;
        for (i, c) in counts.iter().enumerate() {
            assert!(
                (*c as f64) > 0.75 * even as f64 && (*c as f64) < 1.25 * even as f64,
                "partition {i} count {c} vs even {even}"
            );
        }
    }

    #[test]
    fn single_reducer_no_boundaries() {
        let p = RangePartitioner::from_samples(vec![b"a".to_vec()], 1);
        assert_eq!(p.n_partitions(), 1);
        assert_eq!(p.partition(b"zzz"), 0);
    }

    #[test]
    fn empty_samples() {
        let p = RangePartitioner::from_samples(Vec::new(), 4);
        // degenerate but total: everything >= empty boundary -> last bucket
        assert_eq!(p.partition(b"x"), 3);
    }
}

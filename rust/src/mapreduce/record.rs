//! Records and spill-file format.
//!
//! A record is a (key, value) byte pair. Keys compare as raw bytes, so
//! pipelines encode ordered keys order-preservingly: TeraSort uses the
//! suffix text itself; the scheme uses big-endian fixed-width integers
//! (non-negative i64 compares correctly as unsigned big-endian bytes).

use std::io::{self, Read as IoRead, Write};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    pub key: Vec<u8>,
    pub value: Vec<u8>,
}

impl Record {
    pub fn new(key: impl Into<Vec<u8>>, value: impl Into<Vec<u8>>) -> Self {
        Self { key: key.into(), value: value.into() }
    }

    /// Serialized size: 4+4 length prefixes + payload (Hadoop's IFile is
    /// comparable; constant framing keeps ratios honest).
    pub fn wire_bytes(&self) -> u64 {
        8 + self.key.len() as u64 + self.value.len() as u64
    }

    pub fn write_to(&self, w: &mut impl Write) -> io::Result<()> {
        w.write_all(&(self.key.len() as u32).to_be_bytes())?;
        w.write_all(&(self.value.len() as u32).to_be_bytes())?;
        w.write_all(&self.key)?;
        w.write_all(&self.value)
    }

    pub fn read_from(r: &mut impl IoRead) -> io::Result<Option<Record>> {
        let mut len4 = [0u8; 4];
        let klen = match r.read_exact(&mut len4) {
            Ok(()) => u32::from_be_bytes(len4),
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e),
        };
        r.read_exact(&mut len4)?;
        let vlen = u32::from_be_bytes(len4);
        if klen > MAX_FIELD_BYTES || vlen > MAX_FIELD_BYTES {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("record field length {} exceeds cap {MAX_FIELD_BYTES} (corrupt spill?)",
                    klen.max(vlen)),
            ));
        }
        let mut key = vec![0u8; klen as usize];
        r.read_exact(&mut key)?;
        let mut value = vec![0u8; vlen as usize];
        r.read_exact(&mut value)?;
        Ok(Some(Record { key, value }))
    }
}

/// Upper bound on a serialized key or value length. Real records are a
/// few hundred bytes at most (reads, suffix texts, fixed index pairs);
/// a larger prefix means a corrupt or truncated spill file, and must
/// not be trusted to drive a multi-GB allocation.
pub const MAX_FIELD_BYTES: u32 = 64 << 20;

// ---------------- fixed-width fast path ----------------

/// Fixed-width fast-path record: the scheme's 24 B (prefix-key,
/// packed-index) pair plus its shuffle partition, packed into 20 bytes
/// of plain integers instead of two heap-allocated byte vectors. The
/// on-disk frame ([`fixed_frame`]) is byte-identical to a generic
/// [`Record`] with an 8-byte key and 8-byte value, so spill files,
/// segment offsets, and every footprint-ledger total are unchanged —
/// only CPU time and allocations drop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FixedRec {
    /// Shuffle partition (computed once at buffer time).
    pub partition: u32,
    /// 8-byte big-endian key, held as the numerically equal `u64`
    /// (byte-lexicographic order over the frame == unsigned order here).
    pub key: u64,
    /// 8-byte big-endian value (the packed suffix index).
    pub value: u64,
}

/// On-disk frame size of a fixed record: 4+4 length prefixes + 8 B key
/// + 8 B value — identical to `Record::wire_bytes()` for such a record.
pub const FIXED_WIRE_BYTES: u64 = 24;

/// Serialize one fixed record into its 24-byte frame, byte-identical to
/// `Record::write_to` for an 8-byte key and value.
#[inline]
pub fn fixed_frame(key: u64, value: u64) -> [u8; FIXED_WIRE_BYTES as usize] {
    let mut f = [0u8; FIXED_WIRE_BYTES as usize];
    f[3] = 8; // klen = 8, big-endian
    f[7] = 8; // vlen = 8
    f[8..16].copy_from_slice(&key.to_be_bytes());
    f[16..24].copy_from_slice(&value.to_be_bytes());
    f
}

/// Decode a 24-byte frame written by [`fixed_frame`]; any other framing
/// means the bytes are not a fixed-width record stream.
#[inline]
pub fn decode_fixed_frame(f: &[u8]) -> io::Result<(u64, u64)> {
    debug_assert_eq!(f.len(), FIXED_WIRE_BYTES as usize);
    if f[..8] != [0, 0, 0, 8, 0, 0, 0, 8] {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "corrupt fixed-width record frame (framing is not 8+8)",
        ));
    }
    Ok((
        u64::from_be_bytes(f[8..16].try_into().expect("8-byte key")),
        u64::from_be_bytes(f[16..24].try_into().expect("8-byte value")),
    ))
}

/// Split a generic record into its fixed-width (key, value) parts.
/// Panics unless the record is exactly 8 B + 8 B: jobs that opt into
/// the fixed-width shuffle must emit only such records.
#[inline]
pub fn to_fixed_parts(rec: &Record) -> (u64, u64) {
    let key: [u8; 8] = rec
        .key
        .as_slice()
        .try_into()
        .expect("fixed-width shuffle requires 8-byte keys");
    let value: [u8; 8] = rec
        .value
        .as_slice()
        .try_into()
        .expect("fixed-width shuffle requires 8-byte values");
    (u64::from_be_bytes(key), u64::from_be_bytes(value))
}

/// Order-preserving key encoding for non-negative i64 (scheme keys).
pub fn encode_i64_key(v: i64) -> [u8; 8] {
    debug_assert!(v >= 0);
    v.to_be_bytes()
}

pub fn decode_i64_key(b: &[u8]) -> i64 {
    i64::from_be_bytes(b[..8].try_into().expect("8-byte i64 key"))
}

/// Total serialized size of a record batch.
pub fn batch_bytes(records: &[Record]) -> u64 {
    records.iter().map(Record::wire_bytes).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_roundtrip() {
        let recs = vec![
            Record::new(b"a".to_vec(), b"1".to_vec()),
            Record::new(b"".to_vec(), b"".to_vec()),
            Record::new(vec![0u8, 255, 0], vec![9u8; 100]),
        ];
        let mut buf = Vec::new();
        for r in &recs {
            r.write_to(&mut buf).unwrap();
        }
        assert_eq!(buf.len() as u64, batch_bytes(&recs));
        let mut cur = std::io::Cursor::new(buf);
        let mut got = Vec::new();
        while let Some(r) = Record::read_from(&mut cur).unwrap() {
            got.push(r);
        }
        assert_eq!(got, recs);
    }

    #[test]
    fn corrupt_length_prefix_is_invalid_data_not_alloc() {
        // a huge klen must be rejected before any allocation happens
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_be_bytes()); // klen ~4 GB
        buf.extend_from_slice(&8u32.to_be_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        let err = Record::read_from(&mut std::io::Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // truncated-but-sane frames still surface as UnexpectedEof
        let mut buf = Vec::new();
        buf.extend_from_slice(&8u32.to_be_bytes());
        buf.extend_from_slice(&8u32.to_be_bytes());
        buf.extend_from_slice(&[0u8; 3]); // missing 13 payload bytes
        let err = Record::read_from(&mut std::io::Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn fixed_frame_matches_generic_wire_format() {
        let rec = Record::new(
            0x0102030405060708u64.to_be_bytes().to_vec(),
            0x1112131415161718u64.to_be_bytes().to_vec(),
        );
        let mut generic = Vec::new();
        rec.write_to(&mut generic).unwrap();
        let fixed = fixed_frame(0x0102030405060708, 0x1112131415161718);
        assert_eq!(generic, fixed.to_vec());
        assert_eq!(rec.wire_bytes(), FIXED_WIRE_BYTES);
        let (k, v) = decode_fixed_frame(&fixed).unwrap();
        assert_eq!((k, v), to_fixed_parts(&rec));
    }

    #[test]
    fn decode_fixed_frame_rejects_foreign_framing() {
        let mut f = fixed_frame(1, 2);
        f[3] = 9; // klen = 9: not a fixed record
        let err = decode_fixed_frame(&f).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn i64_key_order_preserving() {
        let vals = [0i64, 1, 5, 1000, 5i64.pow(23) - 1, i64::MAX];
        for w in vals.windows(2) {
            assert!(encode_i64_key(w[0]) < encode_i64_key(w[1]));
        }
        for v in vals {
            assert_eq!(decode_i64_key(&encode_i64_key(v)), v);
        }
    }
}

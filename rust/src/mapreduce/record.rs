//! Records and spill-file format.
//!
//! A record is a (key, value) byte pair. Keys compare as raw bytes, so
//! pipelines encode ordered keys order-preservingly: TeraSort uses the
//! suffix text itself; the scheme uses big-endian fixed-width integers
//! (non-negative i64 compares correctly as unsigned big-endian bytes).

use std::io::{self, Read as IoRead, Write};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    pub key: Vec<u8>,
    pub value: Vec<u8>,
}

impl Record {
    pub fn new(key: impl Into<Vec<u8>>, value: impl Into<Vec<u8>>) -> Self {
        Self { key: key.into(), value: value.into() }
    }

    /// Serialized size: 4+4 length prefixes + payload (Hadoop's IFile is
    /// comparable; constant framing keeps ratios honest).
    pub fn wire_bytes(&self) -> u64 {
        8 + self.key.len() as u64 + self.value.len() as u64
    }

    pub fn write_to(&self, w: &mut impl Write) -> io::Result<()> {
        w.write_all(&(self.key.len() as u32).to_be_bytes())?;
        w.write_all(&(self.value.len() as u32).to_be_bytes())?;
        w.write_all(&self.key)?;
        w.write_all(&self.value)
    }

    pub fn read_from(r: &mut impl IoRead) -> io::Result<Option<Record>> {
        let mut len4 = [0u8; 4];
        let klen = match r.read_exact(&mut len4) {
            Ok(()) => u32::from_be_bytes(len4),
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e),
        };
        r.read_exact(&mut len4)?;
        let vlen = u32::from_be_bytes(len4);
        let mut key = vec![0u8; klen as usize];
        r.read_exact(&mut key)?;
        let mut value = vec![0u8; vlen as usize];
        r.read_exact(&mut value)?;
        Ok(Some(Record { key, value }))
    }
}

/// Order-preserving key encoding for non-negative i64 (scheme keys).
pub fn encode_i64_key(v: i64) -> [u8; 8] {
    debug_assert!(v >= 0);
    v.to_be_bytes()
}

pub fn decode_i64_key(b: &[u8]) -> i64 {
    i64::from_be_bytes(b[..8].try_into().expect("8-byte i64 key"))
}

/// Total serialized size of a record batch.
pub fn batch_bytes(records: &[Record]) -> u64 {
    records.iter().map(Record::wire_bytes).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_roundtrip() {
        let recs = vec![
            Record::new(b"a".to_vec(), b"1".to_vec()),
            Record::new(b"".to_vec(), b"".to_vec()),
            Record::new(vec![0u8, 255, 0], vec![9u8; 100]),
        ];
        let mut buf = Vec::new();
        for r in &recs {
            r.write_to(&mut buf).unwrap();
        }
        assert_eq!(buf.len() as u64, batch_bytes(&recs));
        let mut cur = std::io::Cursor::new(buf);
        let mut got = Vec::new();
        while let Some(r) = Record::read_from(&mut cur).unwrap() {
            got.push(r);
        }
        assert_eq!(got, recs);
    }

    #[test]
    fn i64_key_order_preserving() {
        let vals = [0i64, 1, 5, 1000, 5i64.pow(23) - 1, i64::MAX];
        for w in vals.windows(2) {
            assert!(encode_i64_key(w[0]) < encode_i64_key(w[1]));
        }
        for v in vals {
            assert_eq!(decode_i64_key(&encode_i64_key(v)), v);
        }
    }
}

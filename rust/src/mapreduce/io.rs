//! Disk-backed dataflow: record files in, record files out.
//!
//! The paper's jobs read their input from HDFS files and write their
//! output back to HDFS files — nothing requires either end to fit in
//! memory. This module is that boundary for the in-process engine:
//!
//! * **Input**: a [`SplitWriter`] spools records into one disk-backed
//!   record file and cuts [`InputSplit`] descriptors at the job's split
//!   byte budget. A split names a byte range of that file; the mapper
//!   pulls records through a [`RecordReader`] instead of iterating a
//!   resident `Vec`.
//! * **Output**: each reduce task streams its records into an
//!   [`OutputSink`] — per-reducer spooled "HDFS" [`OutputFile`]s — so
//!   the engine never materializes `Vec<Record>` output either.
//!
//! Wire format is exactly [`Record::write_to`], byte-identical to what
//! the resident-vector dataflow serialized, so every footprint-ledger
//! charge (HdfsRead/HdfsWrite in particular) is unchanged. What *is*
//! resident at any moment is only the engine's bounded buffers — see
//! [`crate::mapreduce::resident`] for the gauge that proves it.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::mapreduce::record::Record;

/// One Hadoop-style input split: a byte range of a disk-backed record
/// file. Splits of one spool share the file via `Arc`, so descriptors
/// are cheap to clone into task closures.
#[derive(Clone, Debug)]
pub struct InputSplit {
    /// The record file this split is a range of.
    pub path: Arc<PathBuf>,
    /// Byte offset of the split's first record in the file.
    pub offset: u64,
    /// Serialized bytes in the range (sum of record wire bytes) — the
    /// HdfsRead charge for the map task that consumes it.
    pub bytes: u64,
    /// Records in the range.
    pub records: u64,
}

impl InputSplit {
    /// Open a streaming reader over this split's records.
    pub fn open(&self) -> io::Result<RecordReader> {
        RecordReader::open(self.path.as_ref(), self.offset, self.records)
    }
}

/// Streams [`Record`]s out of a byte range of a record file — what a
/// map task iterates instead of a resident slice.
pub struct RecordReader {
    r: BufReader<File>,
    remaining: u64,
}

impl RecordReader {
    /// Open `records` records starting `offset` bytes into `path`.
    pub fn open(path: &Path, offset: u64, records: u64) -> io::Result<Self> {
        let mut f = File::open(path)?;
        if offset > 0 {
            f.seek(SeekFrom::Start(offset))?;
        }
        Ok(Self { r: BufReader::new(f), remaining: records })
    }

    /// Next record, or `None` once the range is exhausted. A file that
    /// ends before the declared record count is a real error, not a
    /// silent short read.
    pub fn next_record(&mut self) -> io::Result<Option<Record>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        match Record::read_from(&mut self.r)? {
            Some(rec) => {
                self.remaining -= 1;
                Ok(Some(rec))
            }
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("record file truncated with {} records unread", self.remaining),
            )),
        }
    }
}

/// Spools records into one disk-backed record file, cutting
/// [`InputSplit`] descriptors every `split_bytes` — the out-of-core
/// replacement for materializing `Vec<Vec<Record>>` splits. Boundaries
/// match the old in-memory splitter exactly: a split closes at the
/// first record that reaches the byte budget.
pub struct SplitWriter {
    w: BufWriter<File>,
    path: Arc<PathBuf>,
    split_bytes: u64,
    splits: Vec<InputSplit>,
    /// Absolute write position (== total bytes spooled).
    offset: u64,
    /// Offset where the current (open) split began.
    start: u64,
    cur_bytes: u64,
    cur_records: u64,
}

impl SplitWriter {
    /// Create the spool file at `path` with the given split byte budget.
    pub fn create(path: PathBuf, split_bytes: u64) -> io::Result<Self> {
        let w = BufWriter::new(File::create(&path)?);
        Ok(Self {
            w,
            path: Arc::new(path),
            split_bytes,
            splits: Vec::new(),
            offset: 0,
            start: 0,
            cur_bytes: 0,
            cur_records: 0,
        })
    }

    /// Append one record to the spool.
    pub fn push(&mut self, rec: &Record) -> io::Result<()> {
        rec.write_to(&mut self.w)?;
        let b = rec.wire_bytes();
        self.offset += b;
        self.cur_bytes += b;
        self.cur_records += 1;
        if self.cur_bytes >= self.split_bytes {
            self.cut();
        }
        Ok(())
    }

    fn cut(&mut self) {
        if self.cur_records == 0 {
            return;
        }
        self.splits.push(InputSplit {
            path: self.path.clone(),
            offset: self.start,
            bytes: self.cur_bytes,
            records: self.cur_records,
        });
        self.start = self.offset;
        self.cur_bytes = 0;
        self.cur_records = 0;
    }

    /// Total serialized bytes spooled so far.
    pub fn bytes(&self) -> u64 {
        self.offset
    }

    /// Flush the file and return the split descriptors. The spool file
    /// must outlive the job that reads the splits.
    pub fn finish(mut self) -> io::Result<Vec<InputSplit>> {
        self.cut();
        self.w.flush()?;
        Ok(self.splits)
    }
}

/// Spool a record batch to `path` in one call — convenience for tests,
/// benches, and callers that already hold the records.
pub fn spool_records(
    path: PathBuf,
    records: &[Record],
    split_bytes: u64,
) -> io::Result<Vec<InputSplit>> {
    let mut w = SplitWriter::create(path, split_bytes)?;
    for r in records {
        w.push(r)?;
    }
    w.finish()
}

// ---------------------------------------------------------------------
// output
// ---------------------------------------------------------------------

/// Streaming destination for a reduce task's output records — the
/// engine hands each task a spooled [`FileSink`]; unit tests pass a
/// plain `Vec<Record>`.
pub trait OutputSink {
    /// Accept one output record.
    fn push(&mut self, rec: Record) -> io::Result<()>;
}

/// Collecting sink for unit tests and small in-memory jobs.
impl OutputSink for Vec<Record> {
    fn push(&mut self, rec: Record) -> io::Result<()> {
        Vec::push(self, rec);
        Ok(())
    }
}

/// One reducer's sealed, spooled "HDFS" output file. The file lives as
/// long as the owning `JobResult`'s output directory; cloning the
/// descriptor does not extend that lifetime.
#[derive(Clone, Debug)]
pub struct OutputFile {
    /// Location of the spooled records.
    pub path: PathBuf,
    /// Serialized bytes (== the HdfsWrite charge for this reducer).
    pub bytes: u64,
    /// Record count.
    pub records: u64,
}

impl OutputFile {
    /// Open a streaming reader over the output records.
    pub fn open(&self) -> io::Result<RecordReader> {
        RecordReader::open(&self.path, 0, self.records)
    }

    /// Opt-in collect — the full output is resident again; small
    /// tests only.
    pub fn read_all(&self) -> io::Result<Vec<Record>> {
        let mut r = self.open()?;
        let mut out = Vec::with_capacity(self.records as usize);
        while let Some(rec) = r.next_record()? {
            out.push(rec);
        }
        Ok(out)
    }
}

/// File-backed [`OutputSink`]: streams records to disk with the exact
/// wire bytes the resident-vector path would have serialized.
pub struct FileSink {
    w: BufWriter<File>,
    path: PathBuf,
    bytes: u64,
    records: u64,
}

impl FileSink {
    /// Create the sink's backing file.
    pub fn create(path: PathBuf) -> io::Result<Self> {
        let w = BufWriter::new(File::create(&path)?);
        Ok(Self { w, path, bytes: 0, records: 0 })
    }

    /// Flush and seal the file, returning its descriptor.
    pub fn finish(mut self) -> io::Result<OutputFile> {
        self.w.flush()?;
        Ok(OutputFile { path: self.path, bytes: self.bytes, records: self.records })
    }
}

impl OutputSink for FileSink {
    fn push(&mut self, rec: Record) -> io::Result<()> {
        rec.write_to(&mut self.w)?;
        self.bytes += rec.wire_bytes();
        self.records += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("samr-io-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn split_writer_respects_budget_and_roundtrips() {
        let dir = tmp("splits");
        let recs: Vec<Record> =
            (0..100).map(|i| Record::new(vec![i as u8], vec![0u8; 92])).collect();
        // 1000-byte budget over ~101 B records: >= 10 splits, like the
        // old in-memory make_splits
        let splits = spool_records(dir.join("input"), &recs, 1000).unwrap();
        assert!(splits.len() >= 10);
        assert_eq!(splits.iter().map(|s| s.records).sum::<u64>(), 100);
        let total: u64 = recs.iter().map(Record::wire_bytes).sum();
        assert_eq!(splits.iter().map(|s| s.bytes).sum::<u64>(), total);
        // offsets tile the file exactly
        let mut expect_offset = 0;
        for s in &splits {
            assert_eq!(s.offset, expect_offset);
            expect_offset += s.bytes;
        }
        // every record reads back, in order, through the split readers
        let mut got = Vec::new();
        for s in &splits {
            let mut r = s.open().unwrap();
            while let Some(rec) = r.next_record().unwrap() {
                got.push(rec);
            }
        }
        assert_eq!(got, recs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_record_file_is_an_error() {
        let dir = tmp("trunc");
        let recs: Vec<Record> =
            (0..10).map(|i| Record::new(vec![i as u8; 8], vec![7u8; 8])).collect();
        let splits = spool_records(dir.join("input"), &recs, u64::MAX).unwrap();
        let len = std::fs::metadata(splits[0].path.as_ref()).unwrap().len();
        // chop the last record in half
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(splits[0].path.as_ref())
            .unwrap();
        f.set_len(len - 10).unwrap();
        drop(f);
        let mut r = splits[0].open().unwrap();
        let err = loop {
            match r.next_record() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("truncation must not read as clean EOF"),
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_sink_writes_exactly_the_record_wire_bytes() {
        let dir = tmp("sink");
        let recs: Vec<Record> = (0..50)
            .map(|i| Record::new(format!("k{i:03}").into_bytes(), vec![i as u8; 11]))
            .collect();
        let mut sink = FileSink::create(dir.join("part-0")).unwrap();
        for r in &recs {
            OutputSink::push(&mut sink, r.clone()).unwrap();
        }
        let out = sink.finish().unwrap();
        assert_eq!(out.records, 50);
        assert_eq!(out.bytes, recs.iter().map(Record::wire_bytes).sum::<u64>());
        // raw file bytes == the records' serialized form
        let raw = std::fs::read(&out.path).unwrap();
        let mut want = Vec::new();
        for r in &recs {
            r.write_to(&mut want).unwrap();
        }
        assert_eq!(raw, want);
        assert_eq!(out.read_all().unwrap(), recs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn vec_sink_collects() {
        let mut v: Vec<Record> = Vec::new();
        OutputSink::push(&mut v, Record::new(b"a".to_vec(), b"b".to_vec())).unwrap();
        assert_eq!(v.len(), 1);
    }
}

//! Resident-record gauge: process-wide instrumentation of how many
//! shuffle records the engine is holding in memory buffers right now
//! (map-side spill buffers plus reduce-side in-memory merge segments),
//! and the high-water mark.
//!
//! With the disk-backed dataflow (`mapreduce::io`), these buffers are
//! the ONLY place input-volume-proportional record data can sit in
//! memory — splits stream from disk and reduce output streams back to
//! disk — so the peak here is bounded by the `JobConf` buffer budgets
//! (`io_sort_bytes`, `reducer_heap_bytes`), not by input volume. The
//! out-of-core smoke test (`tests/dataflow.rs`) asserts exactly that.
//!
//! The gauge is advisory instrumentation: counters are process-global
//! and not synchronized with job boundaries, so tests that assert on
//! [`peak`] must [`reset`] first and serialize against other jobs in
//! the same process. A task that aborts mid-flight may leave the
//! current count non-zero; totals are never used for accounting (the
//! footprint [`crate::footprint::Ledger`] is the accounting instrument).

use std::sync::atomic::{AtomicU64, Ordering};

/// How many records a task may buffer locally before publishing them to
/// the global gauge. Hot loops (the map-side spill buffers) count into
/// a task-local `u64` and publish in batches of this size, so the
/// shared cachelines see two RMWs per batch instead of two per record.
/// The gauge therefore under-reads by at most this many records per
/// in-flight task — noise against the byte-sized buffer budgets it
/// exists to bound.
pub const GAUGE_BATCH: u64 = 256;

static CURRENT: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

/// `n` records entered an in-memory engine buffer.
pub fn add(n: u64) {
    let cur = CURRENT.fetch_add(n, Ordering::Relaxed) + n;
    PEAK.fetch_max(cur, Ordering::Relaxed);
}

/// `n` records left an in-memory engine buffer (spilled, merged to
/// disk, or streamed out).
pub fn sub(n: u64) {
    CURRENT.fetch_sub(n, Ordering::Relaxed);
}

/// Records currently buffered.
pub fn current() -> u64 {
    CURRENT.load(Ordering::Relaxed)
}

/// High-water mark since the last [`reset`].
pub fn peak() -> u64 {
    PEAK.load(Ordering::Relaxed)
}

/// Zero both gauges. Callers must ensure no job is mid-flight.
pub fn reset() {
    CURRENT.store(0, Ordering::Relaxed);
    PEAK.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        // NOTE: the gauge is process-global; this test only checks the
        // arithmetic relative to its own movements.
        let base = current();
        add(10);
        add(5);
        assert!(current() >= base + 15);
        assert!(peak() >= base + 15);
        sub(15);
        assert!(peak() >= base + 15, "peak must not move on sub");
    }
}

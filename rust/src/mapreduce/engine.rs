//! The job engine: splits input, runs map attempts on a worker pool,
//! shuffles, runs reduce attempts, and accounts every byte in the
//! footprint ledger. This is an *in-process* Hadoop: real records, real
//! spill files, real merges — only the cluster (nodes/disks/network) is
//! simulated elsewhere (`simcost`).

use std::io;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mapreduce::pool::WorkerPool;

use crate::footprint::{Channel, Footprint, Ledger};
use crate::mapreduce::job::JobConf;
use crate::mapreduce::mapper::{run_map_task, run_map_task_fixed, MapTask, MapTaskStats, SpillFile};
use crate::mapreduce::record::{batch_bytes, Record};
use crate::mapreduce::reducer::{
    run_reduce_task, run_reduce_task_fixed, ReduceTask, ReduceTaskStats,
};

pub type PartitionFn = Arc<dyn Fn(&[u8]) -> u32 + Send + Sync>;
pub type MapFactory = Arc<dyn Fn(usize) -> Box<dyn MapTask> + Send + Sync>;
pub type ReduceFactory = Arc<dyn Fn(usize) -> Box<dyn ReduceTask> + Send + Sync>;

/// A configured MapReduce job.
pub struct Job {
    pub name: String,
    pub conf: JobConf,
    pub map_factory: MapFactory,
    pub reduce_factory: ReduceFactory,
    pub partitioner: PartitionFn,
}

/// Everything a run produces.
pub struct JobResult {
    /// Per-reducer output records (the "HDFS" output files).
    pub output: Vec<Vec<Record>>,
    pub footprint: Footprint,
    pub map_stats: Vec<MapTaskStats>,
    pub reduce_stats: Vec<ReduceTaskStats>,
    pub wall: Duration,
}

impl JobResult {
    pub fn output_bytes(&self) -> u64 {
        self.footprint.get(Channel::HdfsWrite)
    }

    pub fn all_output(&self) -> impl Iterator<Item = &Record> {
        self.output.iter().flatten()
    }
}

/// Scratch directory for spill files, removed on drop.
pub struct ScratchDir {
    pub path: PathBuf,
}

static SCRATCH_SEQ: AtomicUsize = AtomicUsize::new(0);

impl ScratchDir {
    pub fn new(base: Option<&std::path::Path>, tag: &str) -> io::Result<Self> {
        let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = base
            .map(|p| p.to_path_buf())
            .unwrap_or_else(std::env::temp_dir)
            .join(format!("samr-{tag}-{}-{seq}", std::process::id()));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Split input records into Hadoop-style input splits by byte budget.
pub fn make_splits(records: Vec<Record>, split_bytes: u64) -> Vec<Vec<Record>> {
    let mut splits = Vec::new();
    let mut cur = Vec::new();
    let mut cur_bytes = 0u64;
    for rec in records {
        cur_bytes += rec.wire_bytes();
        cur.push(rec);
        if cur_bytes >= split_bytes {
            splits.push(std::mem::take(&mut cur));
            cur_bytes = 0;
        }
    }
    if !cur.is_empty() {
        splits.push(cur);
    }
    splits
}

/// Run a job over pre-split input. The ledger accumulates the footprint
/// (callers pass a fresh one per experiment, or share across stages).
///
/// Task attempts run on the process-wide [`WorkerPool`] so worker threads
/// (and their thread-local PJRT engines) persist across phases and jobs.
pub fn run_job(
    job: &Job,
    splits: Vec<Vec<Record>>,
    ledger: &Arc<Ledger>,
) -> io::Result<JobResult> {
    let start = Instant::now();
    let scratch = Arc::new(ScratchDir::new(job.conf.spill_dir.as_deref(), &job.name)?);
    let splits = Arc::new(splits);
    let n_maps = splits.len();
    let n_reds = job.conf.n_reducers;
    let threads = job.conf.task_parallelism.max(1);
    let pool = WorkerPool::global();

    // ---------------- map phase ----------------
    type MapSlot = Option<io::Result<(SpillFile, MapTaskStats)>>;
    let map_outputs: Arc<Mutex<Vec<MapSlot>>> =
        Arc::new(Mutex::new((0..n_maps).map(|_| None).collect()));
    let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n_maps)
        .map(|i| {
            let splits = splits.clone();
            let ledger = ledger.clone();
            let scratch = scratch.clone();
            let conf = job.conf.clone();
            let partitioner = job.partitioner.clone();
            let factory = job.map_factory.clone();
            let out = map_outputs.clone();
            Box::new(move || {
                ledger.add(Channel::HdfsRead, batch_bytes(&splits[i]));
                let mut task = factory(i);
                // both paths produce byte-identical spill files and
                // ledger charges; fixed_width only changes CPU cost
                let run = if conf.fixed_width { run_map_task_fixed } else { run_map_task };
                let res = run(
                    i,
                    &splits[i],
                    task.as_mut(),
                    &conf,
                    &*partitioner,
                    &ledger,
                    &scratch.path,
                );
                out.lock().unwrap()[i] = Some(res);
            }) as Box<dyn FnOnce() + Send>
        })
        .collect();
    pool.run_all(tasks, threads);
    let mut outputs = Vec::with_capacity(n_maps);
    let mut map_stats = Vec::with_capacity(n_maps);
    for slot in map_outputs.lock().unwrap().drain(..) {
        let (o, s) = slot.expect("map slot")?;
        outputs.push(o);
        map_stats.push(s);
    }
    let outputs = Arc::new(outputs);

    // ---------------- reduce phase ----------------
    type RedSlot = Option<io::Result<(Vec<Record>, ReduceTaskStats)>>;
    let red_results: Arc<Mutex<Vec<RedSlot>>> =
        Arc::new(Mutex::new((0..n_reds).map(|_| None).collect()));
    let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n_reds)
        .map(|r| {
            let ledger = ledger.clone();
            let scratch = scratch.clone();
            let conf = job.conf.clone();
            let factory = job.reduce_factory.clone();
            let outputs = outputs.clone();
            let out = red_results.clone();
            Box::new(move || {
                let mut task = factory(r);
                let run = if conf.fixed_width { run_reduce_task_fixed } else { run_reduce_task };
                let res = run(
                    r,
                    r,
                    &outputs,
                    task.as_mut(),
                    &conf,
                    &ledger,
                    &scratch.path,
                );
                out.lock().unwrap()[r] = Some(res);
            }) as Box<dyn FnOnce() + Send>
        })
        .collect();
    pool.run_all(tasks, threads);
    for o in outputs.iter() {
        o.remove();
    }
    let mut output = Vec::with_capacity(n_reds);
    let mut reduce_stats = Vec::with_capacity(n_reds);
    for slot in red_results.lock().unwrap().drain(..) {
        let (o, s) = slot.expect("reduce slot")?;
        output.push(o);
        reduce_stats.push(s);
    }

    // write output to "HDFS"
    for recs in &output {
        ledger.add(Channel::HdfsWrite, batch_bytes(recs));
    }

    Ok(JobResult {
        output,
        footprint: ledger.snapshot(),
        map_stats,
        reduce_stats,
        wall: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::partitioner::RangePartitioner;
    use crate::util::rng::Rng;

    /// Identity sort job = TeraSort in miniature: random keys in, globally
    /// sorted out.
    fn sort_job(n_reducers: usize, conf: JobConf) -> (Job, Vec<Record>) {
        let mut rng = Rng::new(23);
        let input: Vec<Record> = (0..5000)
            .map(|_| Record::new(rng.next_u64().to_be_bytes().to_vec(), vec![0u8; 8]))
            .collect();
        let samples: Vec<Vec<u8>> = input.iter().take(2000).map(|r| r.key.clone()).collect();
        let part = Arc::new(RangePartitioner::from_samples(samples, n_reducers));
        let job = Job {
            name: "minisort".into(),
            conf: JobConf { n_reducers, ..conf },
            map_factory: Arc::new(|_| {
                Box::new(|rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone()))
            }),
            reduce_factory: Arc::new(|_| {
                Box::new(
                    |key: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                        for v in vals {
                            out(Record::new(key.to_vec(), v));
                        }
                    },
                )
            }),
            partitioner: part.as_fn(),
        };
        (job, input)
    }

    #[test]
    fn end_to_end_sort_is_correct() {
        let (job, input) = sort_job(4, JobConf { split_bytes: 16 << 10, ..JobConf::default() });
        let ledger = Ledger::new();
        let splits = make_splits(input.clone(), job.conf.split_bytes);
        assert!(splits.len() > 1);
        let res = run_job(&job, splits, &ledger).unwrap();
        // concatenated reducer outputs = globally sorted input
        let got: Vec<Vec<u8>> = res.all_output().map(|r| r.key.clone()).collect();
        let mut want: Vec<Vec<u8>> = input.iter().map(|r| r.key.clone()).collect();
        want.sort();
        assert_eq!(got, want);
        // footprint sanity: read input once, wrote output once, shuffled all
        let in_bytes = batch_bytes(&input);
        assert_eq!(res.footprint.get(Channel::HdfsRead), in_bytes);
        assert_eq!(res.footprint.get(Channel::HdfsWrite), in_bytes);
        assert_eq!(res.footprint.get(Channel::Shuffle), in_bytes);
    }

    #[test]
    fn fixed_width_job_matches_generic_end_to_end() {
        // the whole engine, both shuffle paths, tight buffers: output
        // records and every footprint channel must be identical
        let conf = JobConf {
            split_bytes: 8 << 10,
            io_sort_bytes: 2 << 10,
            reducer_heap_bytes: 4 << 10,
            io_sort_factor: 3,
            ..JobConf::default()
        };
        let mut results = Vec::new();
        for fixed in [false, true] {
            let (job, input) =
                sort_job(3, JobConf { fixed_width: fixed, ..conf.clone() });
            let ledger = Ledger::new();
            let res =
                run_job(&job, make_splits(input, job.conf.split_bytes), &ledger).unwrap();
            assert!(res.map_stats.iter().any(|s| s.spills > 1));
            results.push((res.output, res.footprint));
        }
        assert_eq!(results[0], results[1]);
    }

    #[test]
    fn reducer_outputs_are_range_disjoint() {
        let (job, input) = sort_job(3, JobConf { split_bytes: 32 << 10, ..JobConf::default() });
        let ledger = Ledger::new();
        let res = run_job(&job, make_splits(input, job.conf.split_bytes), &ledger).unwrap();
        for pair in res.output.windows(2) {
            if let (Some(last), Some(first)) = (pair[0].last(), pair[1].first()) {
                assert!(last.key <= first.key);
            }
        }
    }

    #[test]
    fn tight_buffers_still_correct() {
        let (job, input) = sort_job(
            2,
            JobConf {
                split_bytes: 8 << 10,
                io_sort_bytes: 2 << 10,
                reducer_heap_bytes: 4 << 10,
                io_sort_factor: 3,
                ..JobConf::default()
            },
        );
        let ledger = Ledger::new();
        let res = run_job(&job, make_splits(input.clone(), 8 << 10), &ledger).unwrap();
        let got: Vec<Vec<u8>> = res.all_output().map(|r| r.key.clone()).collect();
        let mut want: Vec<Vec<u8>> = input.iter().map(|r| r.key.clone()).collect();
        want.sort();
        assert_eq!(got, want);
        // constrained memory must have caused reduce-side disk traffic
        assert!(res.footprint.get(Channel::ReduceLocalWrite) > 0);
        assert!(res.footprint.get(Channel::ReduceLocalRead) > 0);
        // and multiple map spills
        assert!(res.map_stats.iter().any(|s| s.spills > 1));
    }

    #[test]
    fn make_splits_respects_budget() {
        let recs: Vec<Record> = (0..100)
            .map(|i| Record::new(vec![i as u8], vec![0u8; 92]))
            .collect();
        let splits = make_splits(recs, 1000);
        assert!(splits.len() >= 10);
        assert_eq!(splits.iter().map(Vec::len).sum::<usize>(), 100);
    }
}

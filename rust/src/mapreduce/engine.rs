//! The job engine: streams disk-backed input splits through map attempts
//! on a worker pool, shuffles, and streams reduce output back to spooled
//! per-reducer "HDFS" files, accounting every byte in the footprint
//! ledger. This is an *in-process* Hadoop: real records, real spill
//! files, real merges — only the cluster (nodes/disks/network) is
//! simulated elsewhere (`simcost`).
//!
//! Neither end of the dataflow is memory-resident: input is a list of
//! [`InputSplit`] byte ranges pulled through [`RecordReader`]s, output
//! is written through per-reducer `FileSink`s as it is produced, so the
//! runnable input volume is bounded by disk, not RAM (see
//! `docs/ARCHITECTURE.md` "Dataflow").

use std::io;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mapreduce::pool::WorkerPool;

use crate::faults::{FaultPoint, Phase};
use crate::footprint::{Channel, Footprint, Ledger};
use crate::mapreduce::io::{FileSink, InputSplit, OutputFile, RecordReader};
use crate::mapreduce::job::JobConf;
use crate::mapreduce::mapper::{run_map_task, run_map_task_fixed, MapTask, MapTaskStats, SpillFile};
use crate::mapreduce::record::Record;
use crate::mapreduce::reducer::{
    run_reduce_task, run_reduce_task_fixed, ReduceTask, ReduceTaskStats,
};

pub type PartitionFn = Arc<dyn Fn(&[u8]) -> u32 + Send + Sync>;
pub type MapFactory = Arc<dyn Fn(usize) -> Box<dyn MapTask> + Send + Sync>;
pub type ReduceFactory = Arc<dyn Fn(usize) -> Box<dyn ReduceTask> + Send + Sync>;

/// A configured MapReduce job.
pub struct Job {
    pub name: String,
    pub conf: JobConf,
    pub map_factory: MapFactory,
    pub reduce_factory: ReduceFactory,
    pub partitioner: PartitionFn,
}

/// Everything a run produces. Output records live in per-reducer
/// spooled files (the "HDFS" output), not in memory; they are deleted
/// when this result is dropped.
pub struct JobResult {
    /// Per-reducer sealed output files, in partition order.
    pub output: Vec<OutputFile>,
    /// Keeps the output files on disk for exactly this result's lifetime.
    _out_dir: Arc<ScratchDir>,
    pub footprint: Footprint,
    /// Bytes charged by *abandoned* task attempts (failed or panicked,
    /// then retried). Kept out of `footprint` — the footprint is the
    /// paper's invariant-under-failures instrument, so a retried run's
    /// nine channels stay byte-identical to a clean run's — but tallied
    /// here for observability. All-zero on a fault-free run.
    pub wasted: Footprint,
    pub map_stats: Vec<MapTaskStats>,
    pub reduce_stats: Vec<ReduceTaskStats>,
    pub wall: Duration,
}

impl JobResult {
    pub fn output_bytes(&self) -> u64 {
        self.footprint.get(Channel::HdfsWrite)
    }

    /// Stream reducer `r`'s output file.
    pub fn output_reader(&self, r: usize) -> io::Result<RecordReader> {
        self.output[r].open()
    }

    /// Stream every output record in reducer order — the reducer files
    /// concatenate to the job's globally ordered output. This is the
    /// out-of-core consumption path: one record resident at a time.
    pub fn for_each_output(
        &self,
        mut f: impl FnMut(Record) -> io::Result<()>,
    ) -> io::Result<()> {
        for file in &self.output {
            let mut r = file.open()?;
            while let Some(rec) = r.next_record()? {
                f(rec)?;
            }
        }
        Ok(())
    }

    /// Opt-in collect of all reducer outputs — the whole output is
    /// resident again; use only for small tests.
    pub fn collect_output(&self) -> io::Result<Vec<Vec<Record>>> {
        self.output.iter().map(OutputFile::read_all).collect()
    }

    /// Stream every output record in reducer order and decode the first
    /// 8 bytes of its value as a big-endian i64 — how both suffix
    /// pipelines recover their packed-index order from the sinks
    /// without materializing the records.
    pub fn collect_i64_values(&self) -> io::Result<Vec<i64>> {
        let n: u64 = self.output.iter().map(|o| o.records).sum();
        let mut out = Vec::with_capacity(n as usize);
        self.for_each_output(|r| {
            let prefix: [u8; 8] = r
                .value
                .get(..8)
                .and_then(|s| s.try_into().ok())
                .ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "output value is {} bytes; an 8-byte i64 prefix is required",
                            r.value.len()
                        ),
                    )
                })?;
            out.push(i64::from_be_bytes(prefix));
            Ok(())
        })?;
        Ok(out)
    }

    /// Assemble a result from parts — how the cluster driver, which runs
    /// task bodies in worker *processes* rather than through [`run_job`],
    /// returns the same artifact as the in-process engine.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        output: Vec<OutputFile>,
        out_dir: Arc<ScratchDir>,
        footprint: Footprint,
        wasted: Footprint,
        map_stats: Vec<MapTaskStats>,
        reduce_stats: Vec<ReduceTaskStats>,
        wall: Duration,
    ) -> JobResult {
        JobResult { output, _out_dir: out_dir, footprint, wasted, map_stats, reduce_stats, wall }
    }
}

/// Scratch directory for spill files, removed on drop.
pub struct ScratchDir {
    pub path: PathBuf,
}

static SCRATCH_SEQ: AtomicUsize = AtomicUsize::new(0);

impl ScratchDir {
    pub fn new(base: Option<&std::path::Path>, tag: &str) -> io::Result<Self> {
        let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = base
            .map(|p| p.to_path_buf())
            .unwrap_or_else(std::env::temp_dir)
            .join(format!("samr-{tag}-{}-{seq}", std::process::id()));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Remove scratch directories (`samr-{tag}-{pid}-{seq}`) left behind by
/// a previous *crashed* run — a SIGKILLed driver or worker never runs
/// `ScratchDir::drop`, so its spill dirs, `{phase}-{id}-a{attempt}`
/// attempt subdirectories, and `lcp-*` sidecars would otherwise
/// accumulate. Only directories whose embedded pid is provably dead are
/// removed — a live process's scratch (including our own) is never
/// touched — so any number of processes may call this concurrently.
/// Returns how many directories were removed.
pub fn reap_stale_scratch(base: Option<&std::path::Path>) -> usize {
    let root = base.map(|p| p.to_path_buf()).unwrap_or_else(std::env::temp_dir);
    let Ok(entries) = std::fs::read_dir(&root) else { return 0 };
    let mut reaped = 0;
    for e in entries.flatten() {
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(pid) = scratch_dir_pid(name) else { continue };
        if pid == std::process::id() || pid_alive(pid) {
            continue;
        }
        if e.path().is_dir() && std::fs::remove_dir_all(e.path()).is_ok() {
            reaped += 1;
        }
    }
    reaped
}

/// Parse the `{pid}` out of a `samr-{tag}-{pid}-{seq}` scratch name.
/// Tags may themselves contain `-` (e.g. `scheme-lcp`), so parse from
/// the right.
fn scratch_dir_pid(name: &str) -> Option<u32> {
    let rest = name.strip_prefix("samr-")?;
    let mut it = rest.rsplitn(3, '-');
    let _seq: usize = it.next()?.parse().ok()?;
    let pid: u32 = it.next()?.parse().ok()?;
    // a non-empty tag must remain, or this isn't a scratch dir name
    it.next().filter(|t| !t.is_empty())?;
    Some(pid)
}

/// Best-effort liveness check. On Linux `/proc/<pid>` is authoritative;
/// elsewhere report every pid alive so nothing is ever reaped wrongly.
fn pid_alive(pid: u32) -> bool {
    if cfg!(target_os = "linux") {
        std::path::Path::new("/proc").join(pid.to_string()).exists()
    } else {
        true
    }
}

/// A caught task panic, surfaced as a real error naming the task
/// instead of unwinding through the engine.
fn task_panic_error(
    phase: &str,
    id: usize,
    job: &str,
    payload: Box<dyn std::any::Any + Send>,
) -> io::Error {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string());
    io::Error::other(format!("{phase} task {id} of job {job:?} panicked: {msg}"))
}

/// Run one task attempt-by-attempt up to `JobConf::max_task_attempts`.
///
/// The default configuration (`max_task_attempts == 1`, no fault plan)
/// dispatches the literal pre-existing single-attempt path: the attempt
/// charges the job ledger directly and spills into the shared scratch
/// directory, exactly as before this function existed.
///
/// With retries enabled, each attempt gets a fresh scratch subdirectory
/// (`{phase}-{id}-a{attempt}`) and a fresh private ledger that the task
/// thread's charges are redirected into ([`Ledger::redirect_for_attempt`]
/// — sound because every charge of an attempt happens on the task's own
/// thread). A successful attempt's totals merge into the job ledger — so
/// the job footprint equals a clean run's; a failed attempt's totals fold
/// into `wasted`, its scratch subdirectory is removed, and `cleanup` runs
/// to delete any phase-specific output (the reduce sink). Only after
/// every attempt fails does the task surface an error naming the phase,
/// task, job, and attempt count.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_with_retries<T>(
    phase: Phase,
    id: usize,
    name: &str,
    conf: &JobConf,
    ledger: &Arc<Ledger>,
    wasted: &Arc<Ledger>,
    scratch: &ScratchDir,
    attempt: impl Fn(&std::path::Path, usize) -> io::Result<T>,
    cleanup: impl Fn(usize),
) -> io::Result<T> {
    let max = conf.max_task_attempts.max(1);
    if max == 1 && conf.faults.is_none() {
        return catch_unwind(AssertUnwindSafe(|| attempt(&scratch.path, 0)))
            .unwrap_or_else(|p| Err(task_panic_error(phase.name(), id, name, p)));
    }
    let mut last_err = None;
    for a in 0..max {
        let attempt_dir = scratch.path.join(format!("{}-{id}-a{a}", phase.name()));
        if let Err(e) = std::fs::create_dir_all(&attempt_dir) {
            last_err = Some(e);
            continue;
        }
        let attempt_ledger = Ledger::new();
        let result = {
            let _scope = Ledger::redirect_for_attempt(ledger, &attempt_ledger);
            catch_unwind(AssertUnwindSafe(|| -> io::Result<T> {
                if let Some(plan) = conf.faults.as_deref() {
                    plan.maybe_fail(phase, id, a, FaultPoint::Start)?;
                }
                let v = attempt(&attempt_dir, a)?;
                if let Some(plan) = conf.faults.as_deref() {
                    plan.maybe_fail(phase, id, a, FaultPoint::Finish)?;
                }
                Ok(v)
            }))
            .unwrap_or_else(|p| Err(task_panic_error(phase.name(), id, name, p)))
        };
        match result {
            Ok(v) => {
                ledger.add_footprint(&attempt_ledger.snapshot());
                return Ok(v);
            }
            Err(e) => {
                wasted.add_footprint(&attempt_ledger.snapshot());
                let _ = std::fs::remove_dir_all(&attempt_dir);
                cleanup(a);
                last_err = Some(e);
            }
        }
    }
    let last = last_err.expect("at least one attempt ran");
    Err(io::Error::other(format!(
        "{} task {id} of job {name:?} failed after {max} attempts: {last}",
        phase.name()
    )))
}

/// Run a job over disk-backed input splits. The ledger accumulates the
/// footprint (callers pass a fresh one per experiment, or share across
/// stages). The split spool files must outlive this call.
///
/// Task attempts run on the process-wide [`WorkerPool`] so worker threads
/// (and their thread-local PJRT engines) persist across phases and jobs.
/// Both phases dispatch heaviest-first (map: split bytes; reduce:
/// partition shuffle bytes) so an oversized task overlaps the lighter
/// ones — scheduling order cannot change results, which are stored by
/// task index with commutative ledger adds.
/// A panicking task attempt is caught on its worker and returned as an
/// `io::Error` naming the task — it cannot take down the pool or
/// surface as an opaque unwind.
pub fn run_job(
    job: &Job,
    splits: Vec<InputSplit>,
    ledger: &Arc<Ledger>,
) -> io::Result<JobResult> {
    let start = Instant::now();
    // a previous crashed run (SIGKILLed driver or worker) never dropped
    // its ScratchDirs; reap provably-dead runs' dirs before adding ours
    reap_stale_scratch(job.conf.spill_dir.as_deref());
    let scratch = Arc::new(ScratchDir::new(job.conf.spill_dir.as_deref(), &job.name)?);
    // output files live in their own dir: spills die with `scratch` when
    // this function returns, output dies with the JobResult
    let out_dir = Arc::new(ScratchDir::new(
        job.conf.spill_dir.as_deref(),
        &format!("{}-out", job.name),
    )?);
    let splits = Arc::new(splits);
    let n_maps = splits.len();
    let n_reds = job.conf.n_reducers;
    let threads = job.conf.task_parallelism.max(1);
    let pool = WorkerPool::global();
    // abandoned-attempt charges land here, never in the job ledger
    let wasted = Ledger::new();

    // ---------------- map phase ----------------
    type MapSlot = Option<io::Result<(SpillFile, MapTaskStats)>>;
    let map_outputs: Arc<Mutex<Vec<MapSlot>>> =
        Arc::new(Mutex::new((0..n_maps).map(|_| None).collect()));
    let tasks: Vec<(u64, Box<dyn FnOnce() + Send>)> = (0..n_maps)
        .map(|i| {
            // weight = split bytes: the biggest split is dispatched first
            // so it overlaps the lighter ones instead of straggling
            let weight = splits[i].bytes;
            let splits = splits.clone();
            let ledger = ledger.clone();
            let scratch = scratch.clone();
            let conf = job.conf.clone();
            let partitioner = job.partitioner.clone();
            let factory = job.map_factory.clone();
            let name = job.name.clone();
            let out = map_outputs.clone();
            let wasted = wasted.clone();
            let task = Box::new(move || {
                let attempt = |dir: &std::path::Path, _a: usize| -> io::Result<(SpillFile, MapTaskStats)> {
                    let split = &splits[i];
                    let mut reader = split.open()?;
                    // reading the split IS the HDFS read of this task
                    ledger.add(Channel::HdfsRead, split.bytes);
                    let mut task = factory(i);
                    // both paths produce byte-identical spill files and
                    // ledger charges; fixed_width only changes CPU cost
                    let run = if conf.fixed_width { run_map_task_fixed } else { run_map_task };
                    run(i, &mut reader, task.as_mut(), &conf, &*partitioner, &ledger, dir)
                };
                let res = run_with_retries(
                    Phase::Map,
                    i,
                    &name,
                    &conf,
                    &ledger,
                    &wasted,
                    &scratch,
                    attempt,
                    |_a| {}, // a map attempt leaves nothing outside its scratch dir
                );
                out.lock().unwrap()[i] = Some(res);
            }) as Box<dyn FnOnce() + Send>;
            (weight, task)
        })
        .collect();
    pool.run_all_weighted(tasks, threads);
    let mut outputs = Vec::with_capacity(n_maps);
    let mut map_stats = Vec::with_capacity(n_maps);
    for (i, slot) in map_outputs.lock().unwrap().drain(..).enumerate() {
        let (o, s) = slot
            .unwrap_or_else(|| Err(io::Error::other(format!("map task {i} reported no result"))))?;
        outputs.push(o);
        map_stats.push(s);
    }
    let outputs = Arc::new(outputs);

    // ---------------- reduce phase ----------------
    type RedSlot = Option<io::Result<(OutputFile, ReduceTaskStats)>>;
    let red_results: Arc<Mutex<Vec<RedSlot>>> =
        Arc::new(Mutex::new((0..n_reds).map(|_| None).collect()));
    let tasks: Vec<(u64, Box<dyn FnOnce() + Send>)> = (0..n_reds)
        .map(|r| {
            // weight = this partition's shuffle bytes across all map
            // outputs: the oversized sorting partition starts first, so
            // it cannot straggle the job from the dispatch tail
            let weight: u64 = outputs.iter().map(|o| o.segments[r].bytes).sum();
            let ledger = ledger.clone();
            let scratch = scratch.clone();
            let out_dir = out_dir.clone();
            let conf = job.conf.clone();
            let factory = job.reduce_factory.clone();
            let name = job.name.clone();
            let outputs = outputs.clone();
            let out = red_results.clone();
            let wasted = wasted.clone();
            let task = Box::new(move || {
                let sink_path = out_dir.path.join(format!("part-{r:05}"));
                let attempt = |dir: &std::path::Path, _a: usize| -> io::Result<(OutputFile, ReduceTaskStats)> {
                    let mut task = factory(r);
                    let mut sink = FileSink::create(sink_path.clone())?;
                    let run =
                        if conf.fixed_width { run_reduce_task_fixed } else { run_reduce_task };
                    let stats = run(
                        r,
                        r,
                        &outputs,
                        task.as_mut(),
                        &mut sink,
                        &conf,
                        &ledger,
                        dir,
                    )?;
                    let file = sink.finish()?;
                    // write output to "HDFS": charged as the file seals,
                    // totalling exactly the old end-of-job charge
                    ledger.add(Channel::HdfsWrite, file.bytes);
                    Ok((file, stats))
                };
                // an abandoned attempt's partial sink must not leak —
                // attempts are sequential, so the retry recreates it
                let sink_cleanup = |_a: usize| {
                    let _ = std::fs::remove_file(out_dir.path.join(format!("part-{r:05}")));
                };
                let res = run_with_retries(
                    Phase::Reduce,
                    r,
                    &name,
                    &conf,
                    &ledger,
                    &wasted,
                    &scratch,
                    attempt,
                    sink_cleanup,
                );
                out.lock().unwrap()[r] = Some(res);
            }) as Box<dyn FnOnce() + Send>;
            (weight, task)
        })
        .collect();
    pool.run_all_weighted(tasks, threads);
    for o in outputs.iter() {
        o.remove();
    }
    let mut output = Vec::with_capacity(n_reds);
    let mut reduce_stats = Vec::with_capacity(n_reds);
    for (r, slot) in red_results.lock().unwrap().drain(..).enumerate() {
        let (o, s) = slot.unwrap_or_else(|| {
            Err(io::Error::other(format!("reduce task {r} reported no result")))
        })?;
        output.push(o);
        reduce_stats.push(s);
    }

    Ok(JobResult {
        output,
        _out_dir: out_dir,
        footprint: ledger.snapshot(),
        wasted: wasted.snapshot(),
        map_stats,
        reduce_stats,
        wall: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::io::spool_records;
    use crate::mapreduce::partitioner::RangePartitioner;
    use crate::mapreduce::record::batch_bytes;
    use crate::util::rng::Rng;

    /// Identity sort job = TeraSort in miniature: random keys in, globally
    /// sorted out.
    fn sort_job(n_reducers: usize, conf: JobConf) -> (Job, Vec<Record>) {
        let mut rng = Rng::new(23);
        let input: Vec<Record> = (0..5000)
            .map(|_| Record::new(rng.next_u64().to_be_bytes().to_vec(), vec![0u8; 8]))
            .collect();
        let samples: Vec<Vec<u8>> = input.iter().take(2000).map(|r| r.key.clone()).collect();
        let part = Arc::new(RangePartitioner::from_samples(samples, n_reducers));
        let job = Job {
            name: "minisort".into(),
            conf: JobConf { n_reducers, ..conf },
            map_factory: Arc::new(|_| {
                Box::new(|rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone()))
            }),
            reduce_factory: Arc::new(|_| {
                Box::new(
                    |key: &[u8], vals: Vec<Vec<u8>>, out: &mut dyn FnMut(Record)| {
                        for v in vals {
                            out(Record::new(key.to_vec(), v));
                        }
                    },
                )
            }),
            partitioner: part.as_fn(),
        };
        (job, input)
    }

    /// Spool `input` to a fresh scratch dir at the given split budget.
    fn spool(input: &[Record], split_bytes: u64) -> (ScratchDir, Vec<InputSplit>) {
        let dir = ScratchDir::new(None, "engine-test-in").unwrap();
        let splits = spool_records(dir.path.join("input"), input, split_bytes).unwrap();
        (dir, splits)
    }

    #[test]
    fn end_to_end_sort_is_correct() {
        let (job, input) = sort_job(4, JobConf { split_bytes: 16 << 10, ..JobConf::default() });
        let ledger = Ledger::new();
        let (_spool, splits) = spool(&input, job.conf.split_bytes);
        assert!(splits.len() > 1);
        let res = run_job(&job, splits, &ledger).unwrap();
        // concatenated reducer outputs = globally sorted input
        let mut got: Vec<Vec<u8>> = Vec::new();
        res.for_each_output(|r| {
            got.push(r.key);
            Ok(())
        })
        .unwrap();
        let mut want: Vec<Vec<u8>> = input.iter().map(|r| r.key.clone()).collect();
        want.sort();
        assert_eq!(got, want);
        // footprint sanity: read input once, wrote output once, shuffled all
        let in_bytes = batch_bytes(&input);
        assert_eq!(res.footprint.get(Channel::HdfsRead), in_bytes);
        assert_eq!(res.footprint.get(Channel::HdfsWrite), in_bytes);
        assert_eq!(res.footprint.get(Channel::Shuffle), in_bytes);
    }

    #[test]
    fn fixed_width_job_matches_generic_end_to_end() {
        // the whole engine, both shuffle paths, tight buffers: output
        // records and every footprint channel must be identical
        let conf = JobConf {
            split_bytes: 8 << 10,
            io_sort_bytes: 2 << 10,
            reducer_heap_bytes: 4 << 10,
            io_sort_factor: 3,
            ..JobConf::default()
        };
        let mut results = Vec::new();
        for fixed in [false, true] {
            let (job, input) =
                sort_job(3, JobConf { fixed_width: fixed, ..conf.clone() });
            let ledger = Ledger::new();
            let (_spool, splits) = spool(&input, job.conf.split_bytes);
            let res = run_job(&job, splits, &ledger).unwrap();
            assert!(res.map_stats.iter().any(|s| s.spills > 1));
            results.push((res.collect_output().unwrap(), res.footprint));
        }
        assert_eq!(results[0], results[1]);
    }

    #[test]
    fn reducer_outputs_are_range_disjoint() {
        let (job, input) = sort_job(3, JobConf { split_bytes: 32 << 10, ..JobConf::default() });
        let ledger = Ledger::new();
        let (_spool, splits) = spool(&input, job.conf.split_bytes);
        let res = run_job(&job, splits, &ledger).unwrap();
        let collected = res.collect_output().unwrap();
        for pair in collected.windows(2) {
            if let (Some(last), Some(first)) = (pair[0].last(), pair[1].first()) {
                assert!(last.key <= first.key);
            }
        }
    }

    #[test]
    fn tight_buffers_still_correct() {
        let (job, input) = sort_job(
            2,
            JobConf {
                split_bytes: 8 << 10,
                io_sort_bytes: 2 << 10,
                reducer_heap_bytes: 4 << 10,
                io_sort_factor: 3,
                ..JobConf::default()
            },
        );
        let ledger = Ledger::new();
        let (_spool, splits) = spool(&input, 8 << 10);
        let res = run_job(&job, splits, &ledger).unwrap();
        let mut got: Vec<Vec<u8>> = Vec::new();
        res.for_each_output(|r| {
            got.push(r.key);
            Ok(())
        })
        .unwrap();
        let mut want: Vec<Vec<u8>> = input.iter().map(|r| r.key.clone()).collect();
        want.sort();
        assert_eq!(got, want);
        // constrained memory must have caused reduce-side disk traffic
        assert!(res.footprint.get(Channel::ReduceLocalWrite) > 0);
        assert!(res.footprint.get(Channel::ReduceLocalRead) > 0);
        // and multiple map spills
        assert!(res.map_stats.iter().any(|s| s.spills > 1));
    }

    #[test]
    fn output_files_die_with_the_result() {
        let (job, input) = sort_job(2, JobConf::default());
        let ledger = Ledger::new();
        let (_spool, splits) = spool(&input, 1 << 20);
        let res = run_job(&job, splits, &ledger).unwrap();
        let paths: Vec<PathBuf> = res.output.iter().map(|o| o.path.clone()).collect();
        assert!(paths.iter().all(|p| p.exists()));
        drop(res);
        assert!(paths.iter().all(|p| !p.exists()), "output must be cleaned up on drop");
    }

    #[test]
    fn panicking_map_task_is_a_named_error() {
        let (job, input) = sort_job(2, JobConf::default());
        let job = Job {
            map_factory: Arc::new(|_| {
                Box::new(|_: &Record, _: &mut dyn FnMut(Record)| {
                    panic!("injected map failure")
                })
            }),
            ..job
        };
        let (_spool, splits) = spool(&input, 16 << 10);
        let err = run_job(&job, splits, &Ledger::new()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("map task"), "{msg}");
        assert!(msg.contains("minisort"), "{msg}");
        assert!(msg.contains("injected map failure"), "{msg}");
        // the pool survives: the same job minus the panic still runs
        let (job2, input2) = sort_job(2, JobConf::default());
        let (_spool2, splits2) = spool(&input2, 16 << 10);
        run_job(&job2, splits2, &Ledger::new()).unwrap();
    }

    #[test]
    fn retried_run_matches_clean_run_byte_for_byte() {
        use crate::faults::{FaultPlan, FaultPoint, Phase, TaskFaultKind, TaskFaultSpec};
        let conf = JobConf { split_bytes: 16 << 10, ..JobConf::default() };
        // fault-free baseline
        let (job, input) = sort_job(2, conf.clone());
        let (_spool, splits) = spool(&input, job.conf.split_bytes);
        let base = run_job(&job, splits, &Ledger::new()).unwrap();
        assert_eq!(base.wasted, Footprint::default());

        // same job, one map panic at Start + one reduce error at Finish,
        // both absorbed by the retry budget
        let plan = Arc::new(FaultPlan::with_task_faults(vec![
            TaskFaultSpec {
                phase: Phase::Map,
                task: 1,
                attempt: 0,
                kind: TaskFaultKind::Panic,
                point: FaultPoint::Start,
            },
            TaskFaultSpec {
                phase: Phase::Reduce,
                task: 0,
                attempt: 0,
                kind: TaskFaultKind::Error,
                point: FaultPoint::Finish,
            },
        ]));
        let (job2, input2) = sort_job(
            2,
            JobConf { max_task_attempts: 3, faults: Some(plan.clone()), ..conf },
        );
        assert_eq!(input, input2);
        let (_spool2, splits2) = spool(&input2, job2.conf.split_bytes);
        let res = run_job(&job2, splits2, &Ledger::new()).unwrap();
        assert_eq!(plan.task_faults_fired(), 2);
        // output records and every logical ledger channel are identical
        assert_eq!(res.collect_output().unwrap(), base.collect_output().unwrap());
        assert_eq!(res.footprint, base.footprint);
        // the reduce Finish fault threw away a full attempt: its shuffle
        // reads are visible in the wasted tally, not the footprint
        assert_ne!(res.wasted, Footprint::default());
        assert!(res.wasted.get(Channel::Shuffle) > 0);
    }

    #[test]
    fn retry_exhaustion_names_task_and_attempts_and_leaks_nothing() {
        use crate::faults::{FaultPlan, FaultPoint, Phase, TaskFaultKind, TaskFaultSpec};
        let spill_root = ScratchDir::new(None, "exhaust-test").unwrap();
        // map task 0 fails on every attempt of a 2-attempt budget
        let plan = Arc::new(FaultPlan::with_task_faults(
            (0..2)
                .map(|a| TaskFaultSpec {
                    phase: Phase::Map,
                    task: 0,
                    attempt: a,
                    kind: if a == 0 { TaskFaultKind::Panic } else { TaskFaultKind::Error },
                    point: FaultPoint::Start,
                })
                .collect(),
        ));
        let (job, input) = sort_job(
            2,
            JobConf {
                split_bytes: 16 << 10,
                max_task_attempts: 2,
                faults: Some(plan),
                spill_dir: Some(spill_root.path.clone()),
                ..JobConf::default()
            },
        );
        let (_spool, splits) = spool(&input, job.conf.split_bytes);
        let err = run_job(&job, splits, &Ledger::new()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("map task 0"), "{msg}");
        assert!(msg.contains("minisort"), "{msg}");
        assert!(msg.contains("after 2 attempts"), "{msg}");
        // no partial output or scratch leaks past the failed run: both
        // job dirs under our private spill root are gone
        let leftovers: Vec<_> = std::fs::read_dir(&spill_root.path)
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .collect();
        assert!(leftovers.is_empty(), "leaked: {leftovers:?}");
    }

    #[test]
    fn abandoned_attempt_scratch_is_cleaned_while_winner_survives() {
        use crate::faults::{FaultPlan, FaultPoint, Phase, TaskFaultKind, TaskFaultSpec};
        let spill_root = ScratchDir::new(None, "attempt-dirs-test").unwrap();
        // map task 0 attempt 0 dies *after* doing its work (Finish), so
        // a populated attempt-0 scratch dir must be torn down while
        // attempt 1's spill survives for the reduce phase to read
        let plan = Arc::new(FaultPlan::with_task_faults(vec![TaskFaultSpec {
            phase: Phase::Map,
            task: 0,
            attempt: 0,
            kind: TaskFaultKind::Error,
            point: FaultPoint::Finish,
        }]));
        let (job, input) = sort_job(
            2,
            JobConf {
                split_bytes: 16 << 10,
                max_task_attempts: 2,
                faults: Some(plan),
                spill_dir: Some(spill_root.path.clone()),
                ..JobConf::default()
            },
        );
        // observe attempt dirs from inside the reduce phase — after the
        // map phase settled, before the job's scratch dir is dropped
        let seen: Arc<Mutex<Option<(bool, bool)>>> = Arc::new(Mutex::new(None));
        let seen2 = seen.clone();
        let root = spill_root.path.clone();
        let inner_reduce = job.reduce_factory.clone();
        let job = Job {
            reduce_factory: Arc::new(move |r| {
                let scratch_dir = std::fs::read_dir(&root)
                    .unwrap()
                    .map(|e| e.unwrap().path())
                    .find(|p| {
                        let n = p.file_name().unwrap().to_string_lossy().into_owned();
                        n.starts_with("samr-minisort-") && !n.contains("-out")
                    })
                    .expect("job scratch dir exists during reduce");
                let a0 = scratch_dir.join("map-0-a0").exists();
                let a1_spill = scratch_dir.join("map-0-a1").join("map0_out").exists()
                    || std::fs::read_dir(scratch_dir.join("map-0-a1"))
                        .map(|mut d| d.next().is_some())
                        .unwrap_or(false);
                *seen2.lock().unwrap() = Some((a0, a1_spill));
                inner_reduce(r)
            }),
            ..job
        };
        let (_spool, splits) = spool(&input, job.conf.split_bytes);
        let res = run_job(&job, splits, &Ledger::new()).unwrap();
        let (a0, a1_spill) = seen.lock().unwrap().expect("reducer ran");
        assert!(!a0, "abandoned attempt 0 dir must be cleaned before job end");
        assert!(a1_spill, "winning attempt 1 spill must survive until job end");
        assert!(res.wasted.get(Channel::MapLocalWrite) > 0 || res.wasted.get(Channel::HdfsRead) > 0);
    }

    #[test]
    fn scratch_dir_pid_parses_from_the_right() {
        assert_eq!(scratch_dir_pid("samr-scheme-lcp-1234-7"), Some(1234));
        assert_eq!(scratch_dir_pid("samr-minisort-99-0"), Some(99));
        assert_eq!(scratch_dir_pid("samr-a-b-c-d-42-3"), Some(42));
        assert_eq!(scratch_dir_pid("samr--42-3"), None); // empty tag
        assert_eq!(scratch_dir_pid("samr-notanumber-x"), None);
        assert_eq!(scratch_dir_pid("other-scheme-12-3"), None);
        assert_eq!(scratch_dir_pid("samr-12-3"), None); // no tag at all
    }

    #[test]
    fn reap_removes_dead_runs_scratch_but_never_live_ones() {
        let base = ScratchDir::new(None, "reap-base").unwrap();
        // a provably dead pid: spawn-and-wait a trivial child
        let dead_pid = {
            let mut c = std::process::Command::new("true")
                .spawn()
                .expect("spawn `true`");
            let pid = c.id();
            c.wait().unwrap();
            pid
        };
        let dead = base.path.join(format!("samr-scheme-lcp-{dead_pid}-0"));
        std::fs::create_dir_all(dead.join("map-0-a1")).unwrap();
        std::fs::write(dead.join("lcp-00000"), b"stale").unwrap();
        let live = base
            .path
            .join(format!("samr-minisort-{}-1", std::process::id()));
        std::fs::create_dir_all(&live).unwrap();
        let not_ours = base.path.join("somethingelse");
        std::fs::create_dir_all(&not_ours).unwrap();
        let reaped = reap_stale_scratch(Some(&base.path));
        assert_eq!(reaped, 1);
        assert!(!dead.exists(), "dead run's scratch must be reaped");
        assert!(live.exists(), "live run's scratch must survive");
        assert!(not_ours.exists(), "non-scratch dirs must survive");
        // idempotent
        assert_eq!(reap_stale_scratch(Some(&base.path)), 0);
    }

    #[test]
    fn panicking_reduce_task_is_a_named_error() {
        let (job, input) = sort_job(2, JobConf::default());
        let job = Job {
            reduce_factory: Arc::new(|_| {
                Box::new(
                    |_: &[u8], _: Vec<Vec<u8>>, _: &mut dyn FnMut(Record)| {
                        panic!("injected reduce failure")
                    },
                )
            }),
            ..job
        };
        let (_spool, splits) = spool(&input, 16 << 10);
        let err = run_job(&job, splits, &Ledger::new()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("reduce task"), "{msg}");
        assert!(msg.contains("injected reduce failure"), "{msg}");
    }
}

//! Persistent worker pool for task attempts.
//!
//! Task threads must be long-lived: the PJRT engine is thread-local
//! (`runtime::with_engine`), and compiling the bitonic sort artifact
//! costs ~2 s per thread — scoped per-phase threads would pay that on
//! every job (§Perf iteration 6). The pool spawns once per process;
//! worker N compiles each kernel at most once, ever.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Task = Box<dyn FnOnce() + Send + 'static>;

pub struct WorkerPool {
    tx: Sender<Task>,
    size: usize,
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

impl WorkerPool {
    /// The process-wide pool (size = available parallelism, overridable
    /// with SAMR_WORKERS).
    pub fn global() -> &'static WorkerPool {
        POOL.get_or_init(|| {
            let size = std::env::var("SAMR_WORKERS")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
                })
                .max(1);
            WorkerPool::new(size)
        })
    }

    /// A dedicated pool with `size` workers. Production code shares
    /// [`WorkerPool::global`]; a private pool exists for tests that must
    /// own their workers (e.g. proving liveness after a leaked panic
    /// without deadlocking against concurrently running tests). Workers
    /// exit when the pool (its `Sender`) is dropped.
    pub fn new(size: usize) -> WorkerPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..size {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("samr-worker-{i}"))
                .spawn(move || loop {
                    let task = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match task {
                        // catch_unwind here is the pool's last line of
                        // defense: run_all catches task panics itself,
                        // but a panic that escapes any other submitted
                        // closure must not silently kill this worker and
                        // shrink the process-wide pool forever
                        Ok(t) => {
                            if catch_unwind(AssertUnwindSafe(t)).is_err() {
                                eprintln!(
                                    "samr: panic escaped a pool task on {}; worker continues",
                                    std::thread::current().name().unwrap_or("?")
                                );
                            }
                        }
                        Err(_) => break, // pool dropped (process exit)
                    }
                })
                .expect("spawn pool worker");
        }
        WorkerPool { tx, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run every task to completion (at most `max_parallel` in flight),
    /// re-raising the first panic on the caller thread.
    pub fn run_all(&self, tasks: Vec<Task>, max_parallel: usize) {
        self.run_all_weighted(tasks.into_iter().map(|t| (0u64, t)).collect(), max_parallel);
    }

    /// [`run_all`] with straggler mitigation: tasks are dispatched
    /// heaviest-first (longest-processing-time-first list scheduling),
    /// so one oversized reduce partition starts immediately and overlaps
    /// every lighter task instead of running alone at the tail. The sort
    /// is stable and ties keep submission order, so the dispatch order —
    /// and with uniform weights, the whole schedule — is deterministic.
    /// Scheduling never touches task results: the engine stores them by
    /// task index and ledger adds are commutative, so outputs are
    /// byte-identical regardless of dispatch order.
    pub fn run_all_weighted(&self, mut tasks: Vec<(u64, Task)>, max_parallel: usize) {
        if tasks.is_empty() {
            return;
        }
        tasks.sort_by_key(|(w, _)| std::cmp::Reverse(*w));
        let tasks: Vec<Task> = tasks.into_iter().map(|(_, t)| t).collect();
        let max_parallel = max_parallel.max(1);
        #[allow(clippy::type_complexity)]
        let state: Arc<(
            Mutex<(usize, usize, Option<Box<dyn std::any::Any + Send>>)>,
            Condvar,
        )> = Arc::new((Mutex::new((0, tasks.len(), None)), Condvar::new()));
        // (in_flight, remaining, first_panic)
        for task in tasks {
            // throttle: wait until a slot frees up
            {
                let (lock, cvar) = &*state;
                let mut s = lock.lock().unwrap();
                while s.0 >= max_parallel {
                    s = cvar.wait(s).unwrap();
                }
                s.0 += 1;
            }
            let state = state.clone();
            self.tx
                .send(Box::new(move || {
                    let result = catch_unwind(AssertUnwindSafe(task));
                    let (lock, cvar) = &*state;
                    let mut s = lock.lock().unwrap();
                    s.0 -= 1;
                    s.1 -= 1;
                    if let Err(e) = result {
                        s.2.get_or_insert(e);
                    }
                    cvar.notify_all();
                }))
                .expect("pool send");
        }
        let (lock, cvar) = &*state;
        let mut s = lock.lock().unwrap();
        while s.1 > 0 {
            s = cvar.wait(s).unwrap();
        }
        if let Some(e) = s.2.take() {
            drop(s);
            resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_tasks() {
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..100)
            .map(|_| {
                let c = counter.clone();
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        WorkerPool::global().run_all(tasks, 4);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn propagates_panics() {
        let result = std::panic::catch_unwind(|| {
            let tasks: Vec<Task> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("task exploded")),
                Box::new(|| {}),
            ];
            WorkerPool::global().run_all(tasks, 2);
        });
        assert!(result.is_err());
    }

    #[test]
    fn weighted_dispatch_is_heaviest_first_and_deterministic() {
        // max_parallel = 1 serializes execution into dispatch order, so
        // the observed order IS the schedule: weight-descending, ties in
        // submission order, identical on every run.
        let order = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..2 {
            let tasks: Vec<(u64, Task)> = [3u64, 9, 1, 7, 5]
                .iter()
                .map(|&w| {
                    let o = order.clone();
                    (w, Box::new(move || o.lock().unwrap().push(w)) as Task)
                })
                .collect();
            WorkerPool::global().run_all_weighted(tasks, 1);
        }
        assert_eq!(*order.lock().unwrap(), vec![9, 7, 5, 3, 1, 9, 7, 5, 3, 1]);
    }

    #[test]
    fn pool_survives_leaked_panics() {
        // a dedicated pool: the liveness proof below needs to own all of
        // its workers, which the shared global pool cannot guarantee
        let pool = WorkerPool::new(3);
        assert_eq!(pool.size(), 3);
        // leak panics straight into the worker loop, bypassing
        // run_all's own per-task catch_unwind
        for _ in 0..3 {
            pool.tx
                .send(Box::new(|| panic!("leaked panic")))
                .unwrap();
        }
        // all 3 workers must still be alive: 3 tasks rendezvous, which
        // completes only if 3 distinct workers serve them concurrently
        let state = Arc::new((Mutex::new(0usize), Condvar::new()));
        let ok = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..3)
            .map(|_| {
                let state = state.clone();
                let ok = ok.clone();
                Box::new(move || {
                    let (lock, cvar) = &*state;
                    let mut n = lock.lock().unwrap();
                    *n += 1;
                    cvar.notify_all();
                    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
                    while *n < 3 {
                        let left = deadline.saturating_duration_since(std::time::Instant::now());
                        if left.is_zero() {
                            return; // a worker died; bail out instead of hanging
                        }
                        let (g, _) = cvar.wait_timeout(n, left).unwrap();
                        n = g;
                    }
                    ok.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run_all(tasks, 3);
        assert_eq!(
            ok.load(Ordering::Relaxed),
            3,
            "a leaked panic killed a pool worker"
        );
    }

    #[test]
    fn threads_are_reused() {
        // worker thread identity must be stable across run_all calls
        let names = Arc::new(Mutex::new(std::collections::HashSet::new()));
        for _ in 0..3 {
            let n = names.clone();
            let tasks: Vec<Task> = (0..2)
                .map(|_| {
                    let n = n.clone();
                    Box::new(move || {
                        n.lock().unwrap().insert(
                            std::thread::current().name().unwrap_or("?").to_string(),
                        );
                    }) as Task
                })
                .collect();
            WorkerPool::global().run_all(tasks, 2);
        }
        // all executions landed on pool threads
        assert!(names.lock().unwrap().iter().all(|n| n.starts_with("samr-worker-")));
    }
}

//! In-process MapReduce runtime with Hadoop's exact spill/merge mechanics
//! (the substrate the paper's analysis is about): job conf, records,
//! map-side buffer/spill/merge, shuffle, reduce-side memory merger and
//! on-disk merge rounds, sampled range partitioner, and the job engine.

pub mod engine;
pub mod job;
pub mod mapper;
pub mod merge;
pub mod partitioner;
pub mod pool;
pub mod record;
pub mod reducer;

pub use engine::{make_splits, run_job, Job, JobResult};
pub use job::JobConf;
pub use record::Record;

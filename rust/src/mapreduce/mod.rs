//! In-process MapReduce runtime with Hadoop's exact spill/merge mechanics
//! (the substrate the paper's analysis is about): job conf, records,
//! disk-backed input splits and spooled output files (`io`), map-side
//! buffer/spill/merge, shuffle, reduce-side memory merger and on-disk
//! merge rounds, sampled range partitioner, and the job engine. Both
//! ends of the dataflow live on disk, so input volume is bounded by
//! storage, not memory (`resident` gauges what stays in RAM).

pub mod engine;
pub mod io;
pub mod job;
pub mod mapper;
pub mod merge;
pub mod partitioner;
pub mod pool;
pub mod record;
pub mod reducer;
pub mod resident;

pub use engine::{run_job, Job, JobResult, ScratchDir};
pub use io::{InputSplit, OutputFile, OutputSink, RecordReader, SplitWriter};
pub use job::JobConf;
pub use record::Record;

//! Map task execution with Hadoop's buffer/spill/merge mechanics (Fig. 3):
//! records stream in from a disk-backed [`RecordReader`] split and buffer
//! in a sort buffer; at the spill threshold (80% of io.sort.mb) they are
//! sorted by (partition, key) and spilled; at task end the spills are
//! merged into one partitioned map-output file — exactly the "1R / 2W per
//! input unit" behaviour of the paper's Table III when a 128 MB split
//! spills twice. The sort buffer (gauged by [`resident`]) is the only
//! place map-side records sit in memory.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::PathBuf;
use std::sync::Arc;

use crate::footprint::{Channel, Ledger};
use crate::mapreduce::io::RecordReader;
use crate::mapreduce::job::JobConf;
use crate::mapreduce::merge::{kway_merge, kway_merge_fixed, merge_round_plan, FixedRun, Run};
use crate::mapreduce::record::{
    fixed_frame, to_fixed_parts, FixedRec, Record, FIXED_WIRE_BYTES,
};
use crate::mapreduce::resident;
use crate::util::radix;

/// User map logic. `finish` runs once after the split is exhausted (the
/// scheme uses it to flush aggregated KV puts).
pub trait MapTask: Send {
    fn map(&mut self, rec: &Record, emit: &mut dyn FnMut(Record));
    fn finish(&mut self, _emit: &mut dyn FnMut(Record)) {}

    /// Fixed-width emission: like [`map`](MapTask::map) but feeding
    /// packed `(key, value)` u64 pairs straight into the fixed-width
    /// shuffle, with no `Record` allocation. The default adapts through
    /// `map`, so any task whose records are 8 B + 8 B runs on the fast
    /// path unchanged; hot mappers override it.
    fn map_fixed(&mut self, rec: &Record, emit: &mut dyn FnMut(u64, u64)) {
        self.map(rec, &mut |r| {
            let (k, v) = to_fixed_parts(&r);
            emit(k, v)
        });
    }

    /// Fixed-width counterpart of [`finish`](MapTask::finish).
    fn finish_fixed(&mut self, emit: &mut dyn FnMut(u64, u64)) {
        self.finish(&mut |r| {
            let (k, v) = to_fixed_parts(&r);
            emit(k, v)
        });
    }
}

/// Blanket impl so simple mappers can be plain closures.
impl<F: FnMut(&Record, &mut dyn FnMut(Record)) + Send> MapTask for F {
    fn map(&mut self, rec: &Record, emit: &mut dyn FnMut(Record)) {
        self(rec, emit)
    }
}

/// One per-partition byte range of a spill/map-output file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Segment {
    pub offset: u64,
    pub bytes: u64,
    pub records: u64,
}

/// A partitioned, sorted, on-disk run: spill file or final map output.
#[derive(Debug)]
pub struct SpillFile {
    pub path: PathBuf,
    pub segments: Vec<Segment>,
    pub bytes: u64,
}

impl SpillFile {
    pub fn remove(&self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Write `(partition, record)`s (already sorted) as a spill file.
fn write_spill(
    path: PathBuf,
    n_partitions: usize,
    recs: &[(u32, Record)],
) -> io::Result<SpillFile> {
    let mut segments = vec![Segment::default(); n_partitions];
    let mut w = BufWriter::new(File::create(&path)?);
    let mut offset = 0u64;
    for (p, rec) in recs {
        let seg = &mut segments[*p as usize];
        if seg.records == 0 {
            seg.offset = offset;
        }
        let b = rec.wire_bytes();
        rec.write_to(&mut w)?;
        seg.bytes += b;
        seg.records += 1;
        offset += b;
    }
    w.flush()?;
    Ok(SpillFile { path, segments, bytes: offset })
}

/// Write already-sorted fixed-width records as a spill file. Emits the
/// same 24 B frames (and therefore the same segment offsets and ledger
/// bytes) as [`write_spill`] over the equivalent generic records.
fn write_spill_fixed(
    path: PathBuf,
    n_partitions: usize,
    recs: &[FixedRec],
) -> io::Result<SpillFile> {
    let mut segments = vec![Segment::default(); n_partitions];
    let mut w = BufWriter::new(File::create(&path)?);
    let mut offset = 0u64;
    for rec in recs {
        let seg = &mut segments[rec.partition as usize];
        if seg.records == 0 {
            seg.offset = offset;
        }
        w.write_all(&fixed_frame(rec.key, rec.value))?;
        seg.bytes += FIXED_WIRE_BYTES;
        seg.records += 1;
        offset += FIXED_WIRE_BYTES;
    }
    w.flush()?;
    Ok(SpillFile { path, segments, bytes: offset })
}

/// Merge several spill files into one (per-partition k-way merges written
/// sequentially). Byte counts go to the given channels on `ledger`.
pub fn merge_spills(
    spills: &[SpillFile],
    out_path: PathBuf,
    ledger: &Ledger,
    read_ch: Channel,
    write_ch: Channel,
) -> io::Result<SpillFile> {
    let n_partitions = spills[0].segments.len();
    let mut segments = vec![Segment::default(); n_partitions];
    let mut offset = 0u64;
    let mut w = BufWriter::new(File::create(&out_path)?);
    for p in 0..n_partitions {
        let mut runs = Vec::new();
        for s in spills {
            let seg = s.segments[p];
            if seg.records > 0 {
                runs.push(Run::from_segment(&s.path, seg.offset, seg.records)?);
                ledger.add(read_ch, seg.bytes);
            }
        }
        let seg = &mut segments[p];
        seg.offset = offset;
        kway_merge(runs, |rec| {
            let b = rec.wire_bytes();
            rec.write_to(&mut w)?;
            seg.bytes += b;
            seg.records += 1;
            offset += b;
            Ok(())
        })?;
    }
    w.flush()?;
    ledger.add(write_ch, offset);
    Ok(SpillFile { path: out_path, segments, bytes: offset })
}

/// [`merge_spills`] over fixed-width runs: identical bytes and ledger
/// charges, with loser-tree merges and strided segment readers.
pub fn merge_spills_fixed(
    spills: &[SpillFile],
    out_path: PathBuf,
    ledger: &Ledger,
    read_ch: Channel,
    write_ch: Channel,
) -> io::Result<SpillFile> {
    let n_partitions = spills[0].segments.len();
    let mut segments = vec![Segment::default(); n_partitions];
    let mut offset = 0u64;
    let mut w = BufWriter::new(File::create(&out_path)?);
    for p in 0..n_partitions {
        let mut runs = Vec::new();
        for s in spills {
            let seg = s.segments[p];
            if seg.records > 0 {
                runs.push(FixedRun::from_segment(&s.path, seg.offset, seg.records)?);
                ledger.add(read_ch, seg.bytes);
            }
        }
        let seg = &mut segments[p];
        seg.offset = offset;
        kway_merge_fixed(runs, |key, val| {
            w.write_all(&fixed_frame(key, val))?;
            seg.bytes += FIXED_WIRE_BYTES;
            seg.records += 1;
            offset += FIXED_WIRE_BYTES;
            Ok(())
        })?;
    }
    w.flush()?;
    ledger.add(write_ch, offset);
    Ok(SpillFile { path: out_path, segments, bytes: offset })
}

/// Per-map-task statistics for the simulator and reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct MapTaskStats {
    pub input_records: u64,
    pub input_bytes: u64,
    pub output_records: u64,
    pub output_bytes: u64,
    pub spills: u64,
}

/// Execute one map attempt, pulling records through the split reader.
#[allow(clippy::too_many_arguments)]
pub fn run_map_task(
    task_id: usize,
    input: &mut RecordReader,
    task: &mut dyn MapTask,
    conf: &JobConf,
    partitioner: &(dyn Fn(&[u8]) -> u32 + Sync),
    ledger: &Arc<Ledger>,
    dir: &std::path::Path,
) -> io::Result<(SpillFile, MapTaskStats)> {
    let n_partitions = conf.n_reducers;
    let mut stats = MapTaskStats::default();
    let mut spills: Vec<SpillFile> = Vec::new();
    let mut buffer: Vec<(u32, Record)> = Vec::new();
    let mut buffered: u64 = 0;
    let trigger = conf.spill_trigger();
    // buffered records not yet published to the resident gauge: hot
    // loops count task-locally and publish per GAUGE_BATCH, keeping
    // atomic RMWs off the per-record path (invariant: published +
    // ungauged == buffer.len())
    let mut ungauged: u64 = 0;

    let spill_now = |buffer: &mut Vec<(u32, Record)>,
                         buffered: &mut u64,
                         spills: &mut Vec<SpillFile>,
                         ungauged: &mut u64|
     -> io::Result<()> {
        if buffer.is_empty() {
            return Ok(());
        }
        // stable sort by (partition, key); stability keeps equal keys in
        // emission order like Hadoop's index-chained buffer.
        buffer.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.key.cmp(&b.1.key)));
        let path = dir.join(format!("map{task_id}_spill{}", spills.len()));
        let sf = write_spill(path, n_partitions, buffer)?;
        ledger.add(Channel::MapLocalWrite, sf.bytes);
        spills.push(sf);
        resident::sub(buffer.len() as u64 - *ungauged);
        *ungauged = 0;
        buffer.clear();
        *buffered = 0;
        Ok(())
    };

    {
        let mut pending: Vec<Record> = Vec::new();
        let absorb = |pending: &mut Vec<Record>,
                          buffer: &mut Vec<(u32, Record)>,
                          buffered: &mut u64,
                          spills: &mut Vec<SpillFile>,
                          ungauged: &mut u64,
                          stats: &mut MapTaskStats|
         -> io::Result<()> {
            for rec in pending.drain(..) {
                let p = partitioner(&rec.key);
                debug_assert!((p as usize) < n_partitions);
                stats.output_records += 1;
                stats.output_bytes += rec.wire_bytes();
                *buffered += rec.wire_bytes();
                buffer.push((p, rec));
                *ungauged += 1;
                if *ungauged >= resident::GAUGE_BATCH {
                    resident::add(*ungauged);
                    *ungauged = 0;
                }
                if *buffered >= trigger {
                    spill_now(buffer, buffered, spills, ungauged)?;
                }
            }
            Ok(())
        };
        while let Some(rec) = input.next_record()? {
            stats.input_records += 1;
            stats.input_bytes += rec.wire_bytes();
            task.map(&rec, &mut |r| pending.push(r));
            absorb(&mut pending, &mut buffer, &mut buffered, &mut spills, &mut ungauged, &mut stats)?;
        }
        task.finish(&mut |r| pending.push(r));
        absorb(&mut pending, &mut buffer, &mut buffered, &mut spills, &mut ungauged, &mut stats)?;
    }
    spill_now(&mut buffer, &mut buffered, &mut spills, &mut ungauged)?;
    stats.spills = spills.len() as u64;

    // ---- merge spills into the final map output (Fig. 3) ----
    let output =
        finalize_map_output(task_id, spills, n_partitions, conf, ledger, dir, &merge_spills)?;
    Ok((output, stats))
}

/// Signature shared by [`merge_spills`] and [`merge_spills_fixed`].
type SpillMergeFn =
    dyn Fn(&[SpillFile], PathBuf, &Ledger, Channel, Channel) -> io::Result<SpillFile>;

/// Merge a task's spill files into the final map output (Fig. 3):
/// 0 spills = empty output, 1 spill IS the output (no merge I/O),
/// otherwise intermediate rounds past the merge factor then one final
/// merge. `merge` is [`merge_spills`] or [`merge_spills_fixed`]; both
/// charge the ledger identically, so the paper's R/W units hold on
/// either path.
fn finalize_map_output(
    task_id: usize,
    mut spills: Vec<SpillFile>,
    n_partitions: usize,
    conf: &JobConf,
    ledger: &Arc<Ledger>,
    dir: &std::path::Path,
    merge: &SpillMergeFn,
) -> io::Result<SpillFile> {
    match spills.len() {
        0 => {
            // empty output: zero-length file with empty segments
            let path = dir.join(format!("map{task_id}_out"));
            File::create(&path)?;
            Ok(SpillFile { path, segments: vec![Segment::default(); n_partitions], bytes: 0 })
        }
        1 => Ok(spills.pop().unwrap()),
        _ => {
            // intermediate rounds if spill count exceeds the merge factor
            let mut files = spills;
            let mut scratch = 0usize;
            loop {
                let plan = merge_round_plan(files.len(), conf.io_sort_factor);
                if plan.is_empty() {
                    break;
                }
                let mut rest = files.split_off(plan.iter().sum());
                let mut it = files.into_iter();
                let mut merged = Vec::with_capacity(plan.len());
                for &g in &plan {
                    let group: Vec<SpillFile> = it.by_ref().take(g).collect();
                    let path = dir.join(format!("map{task_id}_imerge{scratch}"));
                    scratch += 1;
                    let m = merge(
                        &group,
                        path,
                        ledger,
                        Channel::MapLocalRead,
                        Channel::MapLocalWrite,
                    )?;
                    for s in group {
                        s.remove();
                    }
                    merged.push(m);
                }
                merged.append(&mut rest);
                files = merged;
            }
            let path = dir.join(format!("map{task_id}_out"));
            let out = merge(
                &files,
                path,
                ledger,
                Channel::MapLocalRead,
                Channel::MapLocalWrite,
            )?;
            for s in files {
                s.remove();
            }
            Ok(out)
        }
    }
}

/// Execute one map attempt on the fixed-width fast path: the spill
/// buffer holds packed [`FixedRec`]s (no per-record heap allocation),
/// spills are LSD-radix sorted on (partition, key), and spill merging
/// runs on the loser tree. Wire bytes, segment layout, ledger charges,
/// and stats are identical to [`run_map_task`] over the equivalent
/// 8 B + 8 B records — proven in `tests/shuffle_equivalence`.
#[allow(clippy::too_many_arguments)]
pub fn run_map_task_fixed(
    task_id: usize,
    input: &mut RecordReader,
    task: &mut dyn MapTask,
    conf: &JobConf,
    partitioner: &(dyn Fn(&[u8]) -> u32 + Sync),
    ledger: &Arc<Ledger>,
    dir: &std::path::Path,
) -> io::Result<(SpillFile, MapTaskStats)> {
    let n_partitions = conf.n_reducers;
    let mut stats = MapTaskStats::default();
    let mut spills: Vec<SpillFile> = Vec::new();
    let mut buffer: Vec<FixedRec> = Vec::new();
    let mut buffered: u64 = 0;
    let trigger = conf.spill_trigger();
    let sort_threads = conf.parallel_sort_threads;
    // radix scratch survives across spills: steady state allocates
    // nothing per record or per spill
    let mut scratch: Vec<FixedRec> = Vec::new();
    // task-local gauge batch, as in the generic path: keep atomic RMWs
    // out of the allocation-free per-record loop
    let mut ungauged: u64 = 0;

    let spill_now = |buffer: &mut Vec<FixedRec>,
                         scratch: &mut Vec<FixedRec>,
                         buffered: &mut u64,
                         spills: &mut Vec<SpillFile>,
                         ungauged: &mut u64|
     -> io::Result<()> {
        if buffer.is_empty() {
            return Ok(());
        }
        // stable LSD radix on (partition, key): same order (and same
        // equal-key emission-order ties) as the generic stable sort.
        // threads = 1 is the literal sequential sort_spill.
        radix::sort_spill_threads(buffer, scratch, sort_threads);
        let path = dir.join(format!("map{task_id}_spill{}", spills.len()));
        let sf = write_spill_fixed(path, n_partitions, buffer)?;
        ledger.add(Channel::MapLocalWrite, sf.bytes);
        spills.push(sf);
        resident::sub(buffer.len() as u64 - *ungauged);
        *ungauged = 0;
        buffer.clear();
        *buffered = 0;
        Ok(())
    };

    {
        let mut pending: Vec<(u64, u64)> = Vec::new();
        let absorb = |pending: &mut Vec<(u64, u64)>,
                          buffer: &mut Vec<FixedRec>,
                          scratch: &mut Vec<FixedRec>,
                          buffered: &mut u64,
                          spills: &mut Vec<SpillFile>,
                          ungauged: &mut u64,
                          stats: &mut MapTaskStats|
         -> io::Result<()> {
            for (key, value) in pending.drain(..) {
                let p = partitioner(&key.to_be_bytes());
                debug_assert!((p as usize) < n_partitions);
                stats.output_records += 1;
                stats.output_bytes += FIXED_WIRE_BYTES;
                *buffered += FIXED_WIRE_BYTES;
                buffer.push(FixedRec { partition: p, key, value });
                *ungauged += 1;
                if *ungauged >= resident::GAUGE_BATCH {
                    resident::add(*ungauged);
                    *ungauged = 0;
                }
                if *buffered >= trigger {
                    spill_now(buffer, scratch, buffered, spills, ungauged)?;
                }
            }
            Ok(())
        };
        while let Some(rec) = input.next_record()? {
            stats.input_records += 1;
            stats.input_bytes += rec.wire_bytes();
            task.map_fixed(&rec, &mut |k, v| pending.push((k, v)));
            absorb(&mut pending, &mut buffer, &mut scratch, &mut buffered, &mut spills, &mut ungauged, &mut stats)?;
        }
        task.finish_fixed(&mut |k, v| pending.push((k, v)));
        absorb(&mut pending, &mut buffer, &mut scratch, &mut buffered, &mut spills, &mut ungauged, &mut stats)?;
    }
    spill_now(&mut buffer, &mut scratch, &mut buffered, &mut spills, &mut ungauged)?;
    stats.spills = spills.len() as u64;

    let output = finalize_map_output(
        task_id,
        spills,
        n_partitions,
        conf,
        ledger,
        dir,
        &merge_spills_fixed,
    )?;
    Ok((output, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::footprint::Ledger;
    use crate::mapreduce::io::spool_records;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("samr-map-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Spool a record batch into `dir` as one split and open its reader.
    fn reader_over(dir: &std::path::Path, recs: &[Record]) -> RecordReader {
        let splits = spool_records(dir.join("input"), recs, u64::MAX).unwrap();
        splits[0].open().unwrap()
    }

    fn identity_split(n: usize, vlen: usize) -> Vec<Record> {
        (0..n)
            .map(|i| Record::new(format!("k{:04}", (n - i) % n).into_bytes(), vec![7u8; vlen]))
            .collect()
    }

    #[test]
    fn single_spill_no_merge_io() {
        let dir = tmpdir("single");
        let ledger = Ledger::new();
        let conf = JobConf { io_sort_bytes: 1 << 20, n_reducers: 2, ..Default::default() };
        let split = identity_split(100, 10);
        let mut input = reader_over(&dir, &split);
        let mut mapper = |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
        let (out, stats) = run_map_task(
            0, &mut input, &mut mapper, &conf,
            &|k| u32::from(k >= b"k0050".as_slice()),
            &ledger, &dir,
        )
        .unwrap();
        assert_eq!(stats.spills, 1);
        assert_eq!(stats.output_records, 100);
        // single spill: write once, zero local reads
        assert_eq!(ledger.get(Channel::MapLocalWrite), out.bytes);
        assert_eq!(ledger.get(Channel::MapLocalRead), 0);
        assert_eq!(out.segments.len(), 2);
        assert_eq!(out.segments.iter().map(|s| s.records).sum::<u64>(), 100);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn two_spills_give_paper_1r_2w() {
        let dir = tmpdir("two");
        let ledger = Ledger::new();
        // split ~2x the spill trigger => 2 spills, like the paper's
        // 128 MB split vs 80 MB trigger (Fig. 3).
        let split = identity_split(200, 100); // ~22 KB of records
        let conf = JobConf {
            io_sort_bytes: 14 << 10, // trigger ~11 KB
            n_reducers: 4,
            ..Default::default()
        };
        let mut input = reader_over(&dir, &split);
        let mut mapper = |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
        let (out, stats) =
            run_map_task(1, &mut input, &mut mapper, &conf, &|k| (k[3] as u32) % 4, &ledger, &dir)
                .unwrap();
        assert_eq!(stats.spills, 2);
        let w = ledger.get(Channel::MapLocalWrite) as f64;
        let r = ledger.get(Channel::MapLocalRead) as f64;
        let out_b = out.bytes as f64;
        // W = spills + merged = 2 units; R = spills = 1 unit
        assert!((w / out_b - 2.0).abs() < 1e-9, "w/out={}", w / out_b);
        assert!((r / out_b - 1.0).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fixed_map_task_is_byte_identical_to_generic() {
        // same multi-spill workload down both paths: identical output
        // file bytes, segments, stats, and ledger totals
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let split: Vec<Record> = (0..600)
            .map(|_| {
                Record::new(
                    rng.below(1 << 40).to_be_bytes().to_vec(),
                    rng.next_u64().to_be_bytes().to_vec(),
                )
            })
            .collect();
        let conf = JobConf {
            io_sort_bytes: 3 << 10, // several spills -> real merge rounds
            io_sort_factor: 3,
            n_reducers: 3,
            ..Default::default()
        };
        let part = |k: &[u8]| (k[7] as u32) % 3;
        let mut results = Vec::new();
        for fixed in [false, true] {
            let dir = tmpdir(if fixed { "eqf" } else { "eqg" });
            let ledger = Ledger::new();
            let mut input = reader_over(&dir, &split);
            let mut mapper = |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
            let task: &mut dyn MapTask = &mut mapper;
            let (out, stats) = if fixed {
                run_map_task_fixed(9, &mut input, task, &conf, &part, &ledger, &dir).unwrap()
            } else {
                run_map_task(9, &mut input, task, &conf, &part, &ledger, &dir).unwrap()
            };
            assert!(stats.spills > 3, "want merge rounds, got {} spills", stats.spills);
            let bytes = std::fs::read(&out.path).unwrap();
            results.push((
                bytes,
                out.segments.clone(),
                stats.output_bytes,
                ledger.get(Channel::MapLocalRead),
                ledger.get(Channel::MapLocalWrite),
            ));
            std::fs::remove_dir_all(&dir).ok();
        }
        assert_eq!(results[0], results[1]);
    }

    #[test]
    fn output_is_sorted_within_partitions() {
        let dir = tmpdir("sorted");
        let ledger = Ledger::new();
        let split = identity_split(500, 20);
        let conf = JobConf { io_sort_bytes: 4 << 10, n_reducers: 3, ..Default::default() };
        let mut input = reader_over(&dir, &split);
        let mut mapper = |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
        let (out, stats) =
            run_map_task(2, &mut input, &mut mapper, &conf, &|k| (k[4] as u32) % 3, &ledger, &dir)
                .unwrap();
        assert!(stats.spills > 2);
        let mut total = 0u64;
        for (p, seg) in out.segments.iter().enumerate() {
            let mut rs = Vec::new();
            let run = Run::from_segment(&out.path, seg.offset, seg.records).unwrap();
            kway_merge(vec![run], |r| {
                rs.push(r);
                Ok(())
            })
            .unwrap();
            assert_eq!(rs.len() as u64, seg.records);
            for w in rs.windows(2) {
                assert!(w[0].key <= w[1].key, "partition {p} unsorted");
            }
            total += seg.records;
        }
        assert_eq!(total, 500);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Map task execution with Hadoop's buffer/spill/merge mechanics (Fig. 3):
//! records buffer in a sort buffer; at the spill threshold (80% of
//! io.sort.mb) they are sorted by (partition, key) and spilled; at task
//! end the spills are merged into one partitioned map-output file —
//! exactly the "1R / 2W per input unit" behaviour of the paper's Table III
//! when a 128 MB split spills twice.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::PathBuf;
use std::sync::Arc;

use crate::footprint::{Channel, Ledger};
use crate::mapreduce::job::JobConf;
use crate::mapreduce::merge::{kway_merge, merge_round_plan, Run};
use crate::mapreduce::record::Record;

/// User map logic. `finish` runs once after the split is exhausted (the
/// scheme uses it to flush aggregated KV puts).
pub trait MapTask: Send {
    fn map(&mut self, rec: &Record, emit: &mut dyn FnMut(Record));
    fn finish(&mut self, _emit: &mut dyn FnMut(Record)) {}
}

/// Blanket impl so simple mappers can be plain closures.
impl<F: FnMut(&Record, &mut dyn FnMut(Record)) + Send> MapTask for F {
    fn map(&mut self, rec: &Record, emit: &mut dyn FnMut(Record)) {
        self(rec, emit)
    }
}

/// One per-partition byte range of a spill/map-output file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Segment {
    pub offset: u64,
    pub bytes: u64,
    pub records: u64,
}

/// A partitioned, sorted, on-disk run: spill file or final map output.
#[derive(Debug)]
pub struct SpillFile {
    pub path: PathBuf,
    pub segments: Vec<Segment>,
    pub bytes: u64,
}

impl SpillFile {
    pub fn remove(&self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Write `(partition, record)`s (already sorted) as a spill file.
fn write_spill(
    path: PathBuf,
    n_partitions: usize,
    recs: &[(u32, Record)],
) -> io::Result<SpillFile> {
    let mut segments = vec![Segment::default(); n_partitions];
    let mut w = BufWriter::new(File::create(&path)?);
    let mut offset = 0u64;
    for (p, rec) in recs {
        let seg = &mut segments[*p as usize];
        if seg.records == 0 {
            seg.offset = offset;
        }
        let b = rec.wire_bytes();
        rec.write_to(&mut w)?;
        seg.bytes += b;
        seg.records += 1;
        offset += b;
    }
    w.flush()?;
    Ok(SpillFile { path, segments, bytes: offset })
}

/// Merge several spill files into one (per-partition k-way merges written
/// sequentially). Byte counts go to the given channels on `ledger`.
pub fn merge_spills(
    spills: &[SpillFile],
    out_path: PathBuf,
    ledger: &Ledger,
    read_ch: Channel,
    write_ch: Channel,
) -> io::Result<SpillFile> {
    let n_partitions = spills[0].segments.len();
    let mut segments = vec![Segment::default(); n_partitions];
    let mut offset = 0u64;
    let mut w = BufWriter::new(File::create(&out_path)?);
    for p in 0..n_partitions {
        let mut runs = Vec::new();
        for s in spills {
            let seg = s.segments[p];
            if seg.records > 0 {
                runs.push(Run::from_segment(&s.path, seg.offset, seg.records)?);
                ledger.add(read_ch, seg.bytes);
            }
        }
        let seg = &mut segments[p];
        seg.offset = offset;
        kway_merge(runs, |rec| {
            let b = rec.wire_bytes();
            rec.write_to(&mut w)?;
            seg.bytes += b;
            seg.records += 1;
            offset += b;
            Ok(())
        })?;
    }
    w.flush()?;
    ledger.add(write_ch, offset);
    Ok(SpillFile { path: out_path, segments, bytes: offset })
}

/// Per-map-task statistics for the simulator and reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct MapTaskStats {
    pub input_records: u64,
    pub input_bytes: u64,
    pub output_records: u64,
    pub output_bytes: u64,
    pub spills: u64,
}

/// Execute one map attempt over `split`.
#[allow(clippy::too_many_arguments)]
pub fn run_map_task(
    task_id: usize,
    split: &[Record],
    task: &mut dyn MapTask,
    conf: &JobConf,
    partitioner: &(dyn Fn(&[u8]) -> u32 + Sync),
    ledger: &Arc<Ledger>,
    dir: &std::path::Path,
) -> io::Result<(SpillFile, MapTaskStats)> {
    let n_partitions = conf.n_reducers;
    let mut stats = MapTaskStats::default();
    let mut spills: Vec<SpillFile> = Vec::new();
    let mut buffer: Vec<(u32, Record)> = Vec::new();
    let mut buffered: u64 = 0;
    let trigger = conf.spill_trigger();

    let spill_now = |buffer: &mut Vec<(u32, Record)>,
                         buffered: &mut u64,
                         spills: &mut Vec<SpillFile>|
     -> io::Result<()> {
        if buffer.is_empty() {
            return Ok(());
        }
        // stable sort by (partition, key); stability keeps equal keys in
        // emission order like Hadoop's index-chained buffer.
        buffer.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.key.cmp(&b.1.key)));
        let path = dir.join(format!("map{task_id}_spill{}", spills.len()));
        let sf = write_spill(path, n_partitions, buffer)?;
        ledger.add(Channel::MapLocalWrite, sf.bytes);
        spills.push(sf);
        buffer.clear();
        *buffered = 0;
        Ok(())
    };

    {
        let mut pending: Vec<Record> = Vec::new();
        let absorb = |pending: &mut Vec<Record>,
                          buffer: &mut Vec<(u32, Record)>,
                          buffered: &mut u64,
                          spills: &mut Vec<SpillFile>,
                          stats: &mut MapTaskStats|
         -> io::Result<()> {
            for rec in pending.drain(..) {
                let p = partitioner(&rec.key);
                debug_assert!((p as usize) < n_partitions);
                stats.output_records += 1;
                stats.output_bytes += rec.wire_bytes();
                *buffered += rec.wire_bytes();
                buffer.push((p, rec));
                if *buffered >= trigger {
                    spill_now(buffer, buffered, spills)?;
                }
            }
            Ok(())
        };
        for rec in split {
            stats.input_records += 1;
            stats.input_bytes += rec.wire_bytes();
            task.map(rec, &mut |r| pending.push(r));
            absorb(&mut pending, &mut buffer, &mut buffered, &mut spills, &mut stats)?;
        }
        task.finish(&mut |r| pending.push(r));
        absorb(&mut pending, &mut buffer, &mut buffered, &mut spills, &mut stats)?;
    }
    spill_now(&mut buffer, &mut buffered, &mut spills)?;
    stats.spills = spills.len() as u64;

    // ---- merge spills into the final map output (Fig. 3) ----
    let output = match spills.len() {
        0 => {
            // empty output: zero-length file with empty segments
            let path = dir.join(format!("map{task_id}_out"));
            File::create(&path)?;
            SpillFile { path, segments: vec![Segment::default(); n_partitions], bytes: 0 }
        }
        1 => spills.pop().unwrap(), // single spill IS the output: no merge I/O
        _ => {
            // intermediate rounds if spill count exceeds the merge factor
            let mut files = spills;
            let mut scratch = 0usize;
            loop {
                let plan = merge_round_plan(files.len(), conf.io_sort_factor);
                if plan.is_empty() {
                    break;
                }
                let mut rest = files.split_off(plan.iter().sum());
                let mut it = files.into_iter();
                let mut merged = Vec::with_capacity(plan.len());
                for &g in &plan {
                    let group: Vec<SpillFile> = it.by_ref().take(g).collect();
                    let path = dir.join(format!("map{task_id}_imerge{scratch}"));
                    scratch += 1;
                    let m = merge_spills(
                        &group,
                        path,
                        ledger,
                        Channel::MapLocalRead,
                        Channel::MapLocalWrite,
                    )?;
                    for s in group {
                        s.remove();
                    }
                    merged.push(m);
                }
                merged.append(&mut rest);
                files = merged;
            }
            let path = dir.join(format!("map{task_id}_out"));
            let out = merge_spills(
                &files,
                path,
                ledger,
                Channel::MapLocalRead,
                Channel::MapLocalWrite,
            )?;
            for s in files {
                s.remove();
            }
            out
        }
    };
    Ok((output, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::footprint::Ledger;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("samr-map-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn identity_split(n: usize, vlen: usize) -> Vec<Record> {
        (0..n)
            .map(|i| Record::new(format!("k{:04}", (n - i) % n).into_bytes(), vec![7u8; vlen]))
            .collect()
    }

    #[test]
    fn single_spill_no_merge_io() {
        let dir = tmpdir("single");
        let ledger = Ledger::new();
        let conf = JobConf { io_sort_bytes: 1 << 20, n_reducers: 2, ..Default::default() };
        let split = identity_split(100, 10);
        let mut mapper = |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
        let (out, stats) = run_map_task(
            0, &split, &mut mapper, &conf,
            &|k| u32::from(k >= b"k0050".as_slice()),
            &ledger, &dir,
        )
        .unwrap();
        assert_eq!(stats.spills, 1);
        assert_eq!(stats.output_records, 100);
        // single spill: write once, zero local reads
        assert_eq!(ledger.get(Channel::MapLocalWrite), out.bytes);
        assert_eq!(ledger.get(Channel::MapLocalRead), 0);
        assert_eq!(out.segments.len(), 2);
        assert_eq!(out.segments.iter().map(|s| s.records).sum::<u64>(), 100);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn two_spills_give_paper_1r_2w() {
        let dir = tmpdir("two");
        let ledger = Ledger::new();
        // split ~2x the spill trigger => 2 spills, like the paper's
        // 128 MB split vs 80 MB trigger (Fig. 3).
        let split = identity_split(200, 100); // ~22 KB of records
        let conf = JobConf {
            io_sort_bytes: 14 << 10, // trigger ~11 KB
            n_reducers: 4,
            ..Default::default()
        };
        let mut mapper = |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
        let (out, stats) =
            run_map_task(1, &split, &mut mapper, &conf, &|k| (k[3] as u32) % 4, &ledger, &dir)
                .unwrap();
        assert_eq!(stats.spills, 2);
        let w = ledger.get(Channel::MapLocalWrite) as f64;
        let r = ledger.get(Channel::MapLocalRead) as f64;
        let out_b = out.bytes as f64;
        // W = spills + merged = 2 units; R = spills = 1 unit
        assert!((w / out_b - 2.0).abs() < 1e-9, "w/out={}", w / out_b);
        assert!((r / out_b - 1.0).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn output_is_sorted_within_partitions() {
        let dir = tmpdir("sorted");
        let ledger = Ledger::new();
        let split = identity_split(500, 20);
        let conf = JobConf { io_sort_bytes: 4 << 10, n_reducers: 3, ..Default::default() };
        let mut mapper = |rec: &Record, emit: &mut dyn FnMut(Record)| emit(rec.clone());
        let (out, stats) =
            run_map_task(2, &split, &mut mapper, &conf, &|k| (k[4] as u32) % 3, &ledger, &dir)
                .unwrap();
        assert!(stats.spills > 2);
        let mut total = 0u64;
        for (p, seg) in out.segments.iter().enumerate() {
            let mut rs = Vec::new();
            let run = Run::from_segment(&out.path, seg.offset, seg.records).unwrap();
            kway_merge(vec![run], |r| {
                rs.push(r);
                Ok(())
            })
            .unwrap();
            assert_eq!(rs.len() as u64, seg.records);
            for w in rs.windows(2) {
                assert!(w[0].key <= w[1].key, "partition {p} unsorted");
            }
            total += seg.records;
        }
        assert_eq!(total, 500);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Job configuration — the Hadoop knobs the paper's analysis turns on
//! (§II–III): map output buffer + spill threshold, merge factor, reducer
//! heap and shuffle-buffer percentages, split size, reducer count.

/// Hadoop-equivalent job configuration. Byte-valued knobs are real bytes;
/// at laptop scale the presets shrink proportionally so spill counts (and
/// therefore footprint ratios) match the paper's.
#[derive(Clone, Debug)]
pub struct JobConf {
    /// mapreduce.task.io.sort.mb — map-side sort buffer (bytes).
    pub io_sort_bytes: u64,
    /// mapreduce.map.sort.spill.percent (default 0.80).
    pub spill_percent: f64,
    /// mapreduce.task.io.sort.factor (default 10) — k-way merge width.
    pub io_sort_factor: usize,
    /// Input split size (Hadoop default 128 MB).
    pub split_bytes: u64,
    /// Number of reduce tasks.
    pub n_reducers: usize,
    /// Reducer JVM heap (bytes) — paper: 7 GB heap in an 8 GB container.
    pub reducer_heap_bytes: u64,
    /// mapreduce.reduce.shuffle.input.buffer.percent (default 0.70):
    /// fraction of the heap used as the shuffle buffer (paper: 4.9 GB).
    pub shuffle_input_buffer_percent: f64,
    /// mapreduce.reduce.shuffle.merge.percent (default 0.66): in-memory
    /// merger trigger level within the shuffle buffer.
    pub shuffle_merge_percent: f64,
    /// Per-segment cap: a fetched map segment larger than this fraction
    /// of the shuffle buffer goes straight to disk (Hadoop: 0.25).
    pub shuffle_memory_limit_percent: f64,
    /// Worker threads for map/reduce task execution.
    pub task_parallelism: usize,
    /// Threads for the in-node sorting hot paths inside one task: the
    /// fixed-width spill radix sort and the reducer's in-memory segment
    /// merges. 1 (the default) dispatches the literal sequential code —
    /// the equivalence baseline; any value produces byte-identical
    /// output and ledger totals (see `tests/sort_equivalence.rs`).
    pub parallel_sort_threads: usize,
    /// Directory for spill files; None = std::env::temp_dir().
    pub spill_dir: Option<std::path::PathBuf>,
    /// Route the shuffle through the fixed-width fast path: packed
    /// 24 B records, LSD-radix-sorted spills, loser-tree merges, and
    /// strided spill readers. Requires every mapper-emitted record to
    /// carry an 8-byte key and 8-byte value (the scheme's index pairs);
    /// wire bytes and every ledger total are identical to the generic
    /// path — only CPU time and allocations change.
    pub fixed_width: bool,
    /// Maximum attempts per map/reduce task before the job fails
    /// (Hadoop: `mapreduce.map|reduce.maxattempts`, default 4). The
    /// default here is 1, which — with `faults` unset — dispatches the
    /// literal pre-existing single-attempt path: same ledger, same
    /// scratch layout, same sink names. Retried attempts get fresh
    /// scratch subdirectories and their abandoned ledger charges are
    /// folded into a `wasted` tally instead of the job footprint, so a
    /// retried run's nine-channel footprint is byte-identical to a
    /// clean run's.
    pub max_task_attempts: usize,
    /// Deterministic fault-injection plan (tests only; `None` = no
    /// hooks active). See [`crate::faults::FaultPlan`].
    pub faults: Option<std::sync::Arc<crate::faults::FaultPlan>>,
}

impl Default for JobConf {
    fn default() -> Self {
        Self {
            io_sort_bytes: 100 << 20,
            spill_percent: 0.80,
            io_sort_factor: 10,
            split_bytes: 128 << 20,
            n_reducers: 1,
            reducer_heap_bytes: 7 << 30,
            shuffle_input_buffer_percent: 0.70,
            shuffle_merge_percent: 0.66,
            shuffle_memory_limit_percent: 0.25,
            task_parallelism: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            parallel_sort_threads: 1,
            spill_dir: None,
            fixed_width: false,
            max_task_attempts: 1,
            faults: None,
        }
    }
}

impl JobConf {
    /// Laptop-scale conf whose buffer-to-input ratios mirror the paper's
    /// terabyte runs: every knob shrunk by the same factor (~1000×).
    pub fn scaled_down() -> Self {
        Self {
            io_sort_bytes: 100 << 10,       // 100 KB "io.sort.mb"
            split_bytes: 128 << 10,         // 128 KB splits
            reducer_heap_bytes: 7 << 20,    // 7 MB heap
            ..Default::default()
        }
    }

    /// Map-side spill trigger level (bytes buffered).
    pub fn spill_trigger(&self) -> u64 {
        (self.io_sort_bytes as f64 * self.spill_percent) as u64
    }

    /// Reduce-side shuffle buffer size (bytes) — 0.70 × heap by default.
    pub fn shuffle_buffer(&self) -> u64 {
        (self.reducer_heap_bytes as f64 * self.shuffle_input_buffer_percent) as u64
    }

    /// In-memory merge trigger (bytes) — 0.66 × shuffle buffer.
    pub fn merge_trigger(&self) -> u64 {
        (self.shuffle_buffer() as f64 * self.shuffle_merge_percent) as u64
    }

    /// Segments above this size bypass the shuffle buffer.
    pub fn segment_memory_limit(&self) -> u64 {
        (self.shuffle_buffer() as f64 * self.shuffle_memory_limit_percent) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        // §III: buffer 100 MB spilled at 80% = 80 MB; reducer heap 7 GB,
        // shuffle buffer 0.7×7 = 4.9 GB, merge trigger at 66%.
        let c = JobConf::default();
        assert_eq!(c.spill_trigger(), 80 << 20);
        let gb = 1u64 << 30;
        assert_eq!(c.shuffle_buffer(), (4.9 * gb as f64) as u64);
        assert_eq!(
            c.merge_trigger(),
            ((4.9 * gb as f64) as u64 as f64 * 0.66) as u64
        );
    }

    #[test]
    fn scaled_preserves_ratios() {
        let full = JobConf::default();
        let small = JobConf::scaled_down();
        let ratio_full = full.split_bytes as f64 / full.io_sort_bytes as f64;
        let ratio_small = small.split_bytes as f64 / small.io_sort_bytes as f64;
        assert!((ratio_full - ratio_small).abs() < 1e-9);
    }
}

//! Sorted-run k-way merging and the Hadoop merge-round policy — the
//! mechanics behind Figs. 3–4 and the Case-5 "1.88 R/W" estimate.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read as IoRead, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::mapreduce::record::{decode_fixed_frame, fixed_frame, Record, FIXED_WIRE_BYTES};

/// A sorted run of records: either an open spill-file segment or an
/// in-memory vector.
pub enum Run {
    File(BufReader<File>),
    /// A byte-range of a spill file holding `remaining` records.
    Segment(BufReader<File>, u64),
    Mem(std::vec::IntoIter<Record>),
}

impl Run {
    pub fn from_path(p: &Path) -> io::Result<Run> {
        Ok(Run::File(BufReader::new(File::open(p)?)))
    }

    /// Open a per-partition segment: `offset` bytes in, `records` records.
    pub fn from_segment(p: &Path, offset: u64, records: u64) -> io::Result<Run> {
        let mut f = File::open(p)?;
        f.seek(SeekFrom::Start(offset))?;
        Ok(Run::Segment(BufReader::new(f), records))
    }

    pub fn from_vec(v: Vec<Record>) -> Run {
        Run::Mem(v.into_iter())
    }

    fn next_record(&mut self) -> io::Result<Option<Record>> {
        match self {
            Run::File(r) => Record::read_from(r),
            Run::Segment(r, remaining) => {
                if *remaining == 0 {
                    return Ok(None);
                }
                *remaining -= 1;
                Record::read_from(r)
            }
            Run::Mem(it) => Ok(it.next()),
        }
    }
}

struct HeapEntry {
    rec: Record,
    run: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.rec.key == other.rec.key && self.run == other.run
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for ascending (key, run).
        other
            .rec
            .key
            .cmp(&self.rec.key)
            .then(other.run.cmp(&self.run))
    }
}

/// Merge sorted runs, feeding each record (ascending by key, ties by run
/// index — deterministic and stable across spill order) to `sink`.
pub fn kway_merge(
    mut runs: Vec<Run>,
    mut sink: impl FnMut(Record) -> io::Result<()>,
) -> io::Result<()> {
    let mut heap = BinaryHeap::with_capacity(runs.len());
    for (i, run) in runs.iter_mut().enumerate() {
        if let Some(rec) = run.next_record()? {
            heap.push(HeapEntry { rec, run: i });
        }
    }
    while let Some(HeapEntry { rec, run }) = heap.pop() {
        sink(rec)?;
        if let Some(next) = runs[run].next_record()? {
            heap.push(HeapEntry { rec: next, run });
        }
    }
    Ok(())
}

// ---------------- fixed-width fast path ----------------

/// Frames per block read of a fixed-width run (24 KiB blocks).
const FIXED_READ_FRAMES: usize = 1024;

/// Block reader over a stream of 24 B fixed-width frames. The known
/// stride lets it refill one reusable buffer with whole frames — no
/// per-record allocation, no framing scan, no BufReader indirection.
pub struct FixedReader {
    file: File,
    /// Frames not yet read from the file.
    remaining: u64,
    buf: Vec<u8>,
    pos: usize,
}

impl FixedReader {
    fn open(path: &Path, offset: u64, records: u64) -> io::Result<Self> {
        let mut file = File::open(path)?;
        if offset > 0 {
            file.seek(SeekFrom::Start(offset))?;
        }
        Ok(Self { file, remaining: records, buf: Vec::new(), pos: 0 })
    }

    fn next(&mut self) -> io::Result<Option<(u64, u64)>> {
        const FRAME: usize = FIXED_WIRE_BYTES as usize;
        if self.pos == self.buf.len() {
            if self.remaining == 0 {
                return Ok(None);
            }
            let frames = (self.remaining as usize).min(FIXED_READ_FRAMES);
            self.buf.resize(frames * FRAME, 0);
            self.file.read_exact(&mut self.buf)?;
            self.remaining -= frames as u64;
            self.pos = 0;
        }
        let kv = decode_fixed_frame(&self.buf[self.pos..self.pos + FRAME])?;
        self.pos += FRAME;
        Ok(Some(kv))
    }
}

/// A sorted run of fixed-width (key, value) records — the fast-path
/// counterpart of [`Run`], reading the same on-disk bytes.
pub enum FixedRun {
    /// On-disk frames: a spill segment or a whole file.
    File(FixedReader),
    /// An in-memory vector with a cursor.
    Mem(Vec<(u64, u64)>, usize),
}

impl FixedRun {
    /// Open a whole spill file of fixed frames.
    pub fn from_path(p: &Path) -> io::Result<FixedRun> {
        let len = std::fs::metadata(p)?.len();
        if len % FIXED_WIRE_BYTES != 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("file length {len} is not a multiple of the 24 B record stride"),
            ));
        }
        Self::from_segment(p, 0, len / FIXED_WIRE_BYTES)
    }

    /// Open a per-partition segment: `offset` bytes in, `records` frames.
    pub fn from_segment(p: &Path, offset: u64, records: u64) -> io::Result<FixedRun> {
        Ok(FixedRun::File(FixedReader::open(p, offset, records)?))
    }

    /// Wrap an in-memory sorted vector.
    pub fn from_vec(v: Vec<(u64, u64)>) -> FixedRun {
        FixedRun::Mem(v, 0)
    }

    /// Next (key, value) pair, or `None` at end of run.
    pub fn next_pair(&mut self) -> io::Result<Option<(u64, u64)>> {
        match self {
            FixedRun::File(r) => r.next(),
            FixedRun::Mem(v, cur) => {
                if *cur < v.len() {
                    *cur += 1;
                    Ok(Some(v[*cur - 1]))
                } else {
                    Ok(None)
                }
            }
        }
    }
}

/// Loser-tree tournament merge over `k` run cursors: `refill(i)` yields
/// run `i`'s next head, `wins(a, i, b, j)` orders two live heads
/// (exhausted runs lose to everything). The tree replays one
/// leaf-to-root path (⌈log₂ k⌉ comparisons) per record. Factored out so
/// the shuffle merge (order by key, ties by run index) and the scheme's
/// pair-run merge (order by the full (key, value) pair) share one
/// tournament.
fn loser_tree_merge<T: Copy>(
    k: usize,
    mut refill: impl FnMut(usize) -> io::Result<Option<T>>,
    wins: impl Fn(&T, usize, &T, usize) -> bool,
    mut sink: impl FnMut(T) -> io::Result<()>,
) -> io::Result<()> {
    if k == 0 {
        return Ok(());
    }
    let mut heads: Vec<Option<T>> = Vec::with_capacity(k);
    for i in 0..k {
        heads.push(refill(i)?);
    }
    // Does leaf `a` win (sort before) leaf `b`? Exhausted runs lose to
    // everything; None/None ties break toward the lower run index.
    let beats = |heads: &[Option<T>], a: usize, b: usize| -> bool {
        match (&heads[a], &heads[b]) {
            (Some(x), Some(y)) => wins(x, a, y, b),
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => a < b,
        }
    };
    // Build the tournament bottom-up: leaf j sits at node k + j, node i
    // has children 2i and 2i+1. Internal node i keeps the loser of its
    // subtree in `losers[i]`; `losers[0]` holds the overall winner.
    let mut losers = vec![0usize; k];
    {
        let mut winners = vec![0usize; 2 * k];
        for (j, w) in winners[k..].iter_mut().enumerate() {
            *w = j;
        }
        for node in (1..k).rev() {
            let (l, r) = (winners[2 * node], winners[2 * node + 1]);
            let (win, lose) = if beats(&heads, l, r) { (l, r) } else { (r, l) };
            winners[node] = win;
            losers[node] = lose;
        }
        losers[0] = winners[1];
    }
    loop {
        let w = losers[0];
        let Some(head) = heads[w] else { break };
        sink(head)?;
        heads[w] = refill(w)?;
        // replay leaf w's path to the root
        let mut cur = w;
        let mut node = (k + w) / 2;
        while node >= 1 {
            if beats(&heads, losers[node], cur) {
                std::mem::swap(&mut losers[node], &mut cur);
            }
            node /= 2;
        }
        losers[0] = cur;
    }
    Ok(())
}

/// K-way merge of fixed-width runs on the loser tree, ascending by
/// (key, run index) — exactly [`kway_merge`]'s order and tie rule over
/// the equivalent generic records, moving only `(u64, u64)` pairs with
/// zero per-record allocation.
pub fn kway_merge_fixed(
    mut runs: Vec<FixedRun>,
    mut sink: impl FnMut(u64, u64) -> io::Result<()>,
) -> io::Result<()> {
    let k = runs.len();
    loser_tree_merge(
        k,
        |i| runs[i].next_pair(),
        |a, i, b, j| (a.0, i) < (b.0, j),
        |(key, val)| sink(key, val),
    )
}

/// K-way merge of in-memory sorted `(keys, values)` i64 pair runs,
/// ascending by the FULL (key, value) pair — the ordering the scheme's
/// reducer group-sort merge needs, as opposed to the shuffle merges'
/// (key, run-index) rule; run index only breaks exact pair ties (which
/// the scheme's unique packed indexes make impossible). O(n log k) on
/// the shared loser tree, replacing the old O(n·k) pairwise pop-merge.
pub fn kway_merge_pairs(runs: &[(Vec<i64>, Vec<i64>)], mut sink: impl FnMut(i64, i64)) {
    let mut cursors = vec![0usize; runs.len()];
    loser_tree_merge(
        runs.len(),
        |i| {
            let c = cursors[i];
            Ok(if c < runs[i].0.len() {
                cursors[i] = c + 1;
                Some((runs[i].0[c], runs[i].1[c]))
            } else {
                None
            })
        },
        |a, i, b, j| (a.0, a.1, i) < (b.0, b.1, j),
        |(key, val)| {
            sink(key, val);
            Ok(())
        },
    )
    .expect("in-memory pair merge cannot fail");
}

// ---------------- parallel range-partitioned merges ----------------

/// Fewest items per merge range before the parallel merges engage —
/// below this the splitter bookkeeping costs more than the merge, so
/// the call degrades to the sequential loser tree (byte-identical
/// output either way; see `tests/sort_equivalence.rs`).
const PAR_MERGE_MIN_PER_PART: usize = 1 << 13;

/// Splitter-sample positions taken per run — fixed fractional offsets,
/// so splitter selection is a pure function of the run contents and
/// never of thread timing.
const SPLITTER_SAMPLES_PER_RUN: usize = 64;

/// First position in the sorted-by-(key, index) pair run whose pair
/// exceeds `s` — the range cut. `<=` keeps pairs equal to the splitter
/// wholly on the low side, so a cut can never separate equal pairs.
fn partition_upper_pair(keys: &[i64], ixs: &[i64], s: (i64, i64)) -> usize {
    let (mut lo, mut hi) = (0, keys.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        if (keys[mid], ixs[mid]) <= s {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// [`kway_merge_pairs`] with the key space cut into disjoint ranges by
/// deterministic splitters and each range merged concurrently, then the
/// range outputs concatenated in splitter order.
///
/// Why this is byte-identical to the sequential merge: the runs are
/// sorted by the full (key, value) pair, and every cut uses the
/// predicate `pair <= splitter` — monotone along a sorted run — so a
/// range holds exactly the global-output pairs between two splitters,
/// equal pairs never straddle a cut, and within a range every run keeps
/// its original index (empty slices included), preserving the
/// (key, value, run) tie-break of the global loser tree. Concatenating
/// ranges in splitter order therefore reproduces the sequential output
/// exactly, independent of thread scheduling.
///
/// `threads <= 1` dispatches the literal sequential [`kway_merge_pairs`].
pub fn kway_merge_pairs_threads(
    runs: &[(Vec<i64>, Vec<i64>)],
    threads: usize,
    mut sink: impl FnMut(i64, i64),
) {
    if threads <= 1 || runs.len() < 2 {
        return kway_merge_pairs(runs, sink);
    }
    let total: usize = runs.iter().map(|r| r.0.len()).sum();
    let parts = threads.min(total / PAR_MERGE_MIN_PER_PART);
    if parts < 2 {
        return kway_merge_pairs(runs, sink);
    }
    // deterministic splitters: fixed fractional sample positions per
    // run, pooled, sorted, then quantiles
    let mut samples: Vec<(i64, i64)> = Vec::new();
    for (keys, ixs) in runs {
        let s = SPLITTER_SAMPLES_PER_RUN.min(keys.len());
        for i in 0..s {
            let p = i * keys.len() / s;
            samples.push((keys[p], ixs[p]));
        }
    }
    samples.sort_unstable();
    let splitters: Vec<(i64, i64)> =
        (1..parts).map(|t| samples[t * samples.len() / parts]).collect();
    // cuts[r] = run r's range boundaries 0 ..= len, monotone because the
    // splitters are sorted
    let cuts: Vec<Vec<usize>> = runs
        .iter()
        .map(|(keys, ixs)| {
            let mut c = Vec::with_capacity(parts + 1);
            c.push(0);
            for s in &splitters {
                c.push(partition_upper_pair(keys, ixs, *s));
            }
            c.push(keys.len());
            c
        })
        .collect();
    let buffers: Vec<Vec<(i64, i64)>> = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..parts)
            .map(|t| {
                let slices: Vec<(&[i64], &[i64])> = runs
                    .iter()
                    .enumerate()
                    .map(|(r, (keys, ixs))| {
                        let (lo, hi) = (cuts[r][t], cuts[r][t + 1]);
                        (&keys[lo..hi], &ixs[lo..hi])
                    })
                    .collect();
                sc.spawn(move || {
                    let mut out: Vec<(i64, i64)> =
                        Vec::with_capacity(slices.iter().map(|sl| sl.0.len()).sum());
                    let mut cursors = vec![0usize; slices.len()];
                    loser_tree_merge(
                        slices.len(),
                        |i| {
                            let c = cursors[i];
                            Ok(if c < slices[i].0.len() {
                                cursors[i] = c + 1;
                                Some((slices[i].0[c], slices[i].1[c]))
                            } else {
                                None
                            })
                        },
                        |a, i, b, j| (a.0, a.1, i) < (b.0, b.1, j),
                        |p| {
                            out.push(p);
                            Ok(())
                        },
                    )
                    .expect("in-memory pair merge cannot fail");
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("merge thread")).collect()
    });
    for buf in buffers {
        for (k, v) in buf {
            sink(k, v);
        }
    }
}

/// Parallel merge of in-memory fixed-width segments, ascending by
/// (key, segment index) — the reducer's memory-to-disk merge with the
/// key space range-partitioned like [`kway_merge_pairs_threads`].
///
/// Splitters here are KEYS alone (the merge order's primary component):
/// the cut `key <= splitter` keeps every instance of an equal key in
/// one range, so the segment-index tie-break inside a range is
/// identical to the global merge's. `threads <= 1` dispatches the
/// literal sequential path — [`FixedRun::from_vec`] cursors through
/// [`kway_merge_fixed`] — byte-for-byte the pre-existing code.
pub fn merge_fixed_segments_threads(
    segments: Vec<Vec<(u64, u64)>>,
    threads: usize,
    mut sink: impl FnMut(u64, u64) -> io::Result<()>,
) -> io::Result<()> {
    let total: usize = segments.iter().map(|s| s.len()).sum();
    let parts = if threads <= 1 || segments.len() < 2 {
        1
    } else {
        threads.min(total / PAR_MERGE_MIN_PER_PART)
    };
    if parts < 2 {
        let runs: Vec<FixedRun> = segments.into_iter().map(FixedRun::from_vec).collect();
        return kway_merge_fixed(runs, sink);
    }
    let mut samples: Vec<u64> = Vec::new();
    for seg in &segments {
        let s = SPLITTER_SAMPLES_PER_RUN.min(seg.len());
        for i in 0..s {
            samples.push(seg[i * seg.len() / s].0);
        }
    }
    samples.sort_unstable();
    let splitters: Vec<u64> = (1..parts).map(|t| samples[t * samples.len() / parts]).collect();
    let cuts: Vec<Vec<usize>> = segments
        .iter()
        .map(|seg| {
            let mut c = Vec::with_capacity(parts + 1);
            c.push(0);
            for s in &splitters {
                c.push(seg.partition_point(|&(k, _)| k <= *s));
            }
            c.push(seg.len());
            c
        })
        .collect();
    let buffers: Vec<Vec<(u64, u64)>> = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..parts)
            .map(|t| {
                let slices: Vec<&[(u64, u64)]> = segments
                    .iter()
                    .enumerate()
                    .map(|(r, seg)| &seg[cuts[r][t]..cuts[r][t + 1]])
                    .collect();
                sc.spawn(move || {
                    let mut out: Vec<(u64, u64)> =
                        Vec::with_capacity(slices.iter().map(|s| s.len()).sum());
                    let mut cursors = vec![0usize; slices.len()];
                    loser_tree_merge(
                        slices.len(),
                        |i| {
                            let c = cursors[i];
                            Ok(if c < slices[i].len() {
                                cursors[i] = c + 1;
                                Some(slices[i][c])
                            } else {
                                None
                            })
                        },
                        |a, i, b, j| (a.0, i) < (b.0, j),
                        |p| {
                            out.push(p);
                            Ok(())
                        },
                    )
                    .expect("in-memory merge cannot fail");
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("merge thread")).collect()
    });
    for buf in buffers {
        for (k, v) in buf {
            sink(k, v)?;
        }
    }
    Ok(())
}

/// The paper's intermediate merge-round plan (§III, Fig. 4 discussion):
/// with `n` on-disk files and merge width `factor`, merge the minimum
/// number of files so that at most `factor` remain for the final merge.
/// Returns the group sizes to merge now (empty when `n <= factor`).
///
/// k = ceil((n - factor) / (factor - 1)) groups covering m = n - factor + k
/// files — for the paper's Case 5 (n=35, factor=10): k=3 groups of
/// 10+10+8 = 28 files, leaving 3 merged + 7 originals = 10.
pub fn merge_round_plan(n: usize, factor: usize) -> Vec<usize> {
    assert!(factor >= 2);
    if n <= factor {
        return Vec::new();
    }
    let mut k = (n - factor).div_ceil(factor - 1);
    let mut m = n - factor + k; // files merged now
    if m > n {
        // one round cannot reach <= factor files even merging everything
        // (n > factor^2-ish); merge all files in width-<=factor groups and
        // let the caller run another round.
        k = n.div_ceil(factor);
        m = n;
    }
    // distribute m over k groups, each <= factor
    let base = m / k;
    let extra = m % k;
    (0..k)
        .map(|i| base + usize::from(i < extra))
        .collect()
}

/// Run intermediate merge rounds on disk files until at most `factor`
/// remain. `scratch` names new files; `on_read`/`on_write` receive byte
/// counts for the footprint ledger. Returns the surviving file list.
pub fn run_merge_rounds(
    files: Vec<PathBuf>,
    factor: usize,
    scratch: &mut impl FnMut(usize) -> PathBuf,
    on_read: &mut impl FnMut(u64),
    on_write: &mut impl FnMut(u64),
) -> io::Result<Vec<PathBuf>> {
    run_merge_rounds_impl(files, factor, scratch, on_read, on_write, &mut |group, out_path| {
        let mut in_bytes = 0u64;
        let runs = group
            .iter()
            .map(|p| {
                in_bytes += std::fs::metadata(p)?.len();
                Run::from_path(p)
            })
            .collect::<io::Result<Vec<_>>>()?;
        let mut out_bytes = 0u64;
        let mut w = BufWriter::new(File::create(out_path)?);
        kway_merge(runs, |rec| {
            out_bytes += rec.wire_bytes();
            rec.write_to(&mut w)
        })?;
        w.flush()?;
        Ok((in_bytes, out_bytes))
    })
}

/// [`run_merge_rounds`] over fixed-width runs: the same round plan and
/// byte accounting, with loser-tree merges and strided readers.
pub fn run_merge_rounds_fixed(
    files: Vec<PathBuf>,
    factor: usize,
    scratch: &mut impl FnMut(usize) -> PathBuf,
    on_read: &mut impl FnMut(u64),
    on_write: &mut impl FnMut(u64),
) -> io::Result<Vec<PathBuf>> {
    run_merge_rounds_impl(files, factor, scratch, on_read, on_write, &mut |group, out_path| {
        let mut in_bytes = 0u64;
        let runs = group
            .iter()
            .map(|p| {
                in_bytes += std::fs::metadata(p)?.len();
                FixedRun::from_path(p)
            })
            .collect::<io::Result<Vec<_>>>()?;
        let mut out_bytes = 0u64;
        let mut w = BufWriter::new(File::create(out_path)?);
        kway_merge_fixed(runs, |key, val| {
            out_bytes += FIXED_WIRE_BYTES;
            w.write_all(&fixed_frame(key, val))
        })?;
        w.flush()?;
        Ok((in_bytes, out_bytes))
    })
}

/// Merges one file group to the given output, returning (read, written)
/// bytes — the pluggable heart of a merge round.
type GroupMergeFn<'a> = &'a mut dyn FnMut(&[PathBuf], &Path) -> io::Result<(u64, u64)>;

/// Shared merge-round driver: plan, group, merge (via `merge_group`,
/// which returns the group's (read, written) bytes), delete, repeat.
fn run_merge_rounds_impl(
    mut files: Vec<PathBuf>,
    factor: usize,
    scratch: &mut impl FnMut(usize) -> PathBuf,
    on_read: &mut impl FnMut(u64),
    on_write: &mut impl FnMut(u64),
    merge_group: GroupMergeFn<'_>,
) -> io::Result<Vec<PathBuf>> {
    let mut round = 0usize;
    loop {
        let plan = merge_round_plan(files.len(), factor);
        if plan.is_empty() {
            return Ok(files);
        }
        // merge the largest-count prefix; order is irrelevant to byte
        // totals, so take files from the front (oldest spills first).
        let mut rest = files.split_off(plan.iter().sum());
        let mut merged: Vec<PathBuf> = Vec::with_capacity(plan.len());
        let mut it = files.into_iter();
        for (gi, &gsize) in plan.iter().enumerate() {
            let group: Vec<PathBuf> = it.by_ref().take(gsize).collect();
            let out_path = scratch(round * 1000 + gi);
            let (in_bytes, out_bytes) = merge_group(&group, &out_path)?;
            on_read(in_bytes);
            on_write(out_bytes);
            for p in group {
                let _ = std::fs::remove_file(p);
            }
            merged.push(out_path);
        }
        merged.append(&mut rest);
        files = merged;
        round += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_case5_plan() {
        // 35 spilled files, factor 10 -> merge 28 files in 3 groups.
        let plan = merge_round_plan(35, 10);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.iter().sum::<usize>(), 28);
        assert!(plan.iter().all(|&g| g <= 10));
    }

    #[test]
    fn no_round_needed_at_or_below_factor() {
        assert!(merge_round_plan(10, 10).is_empty());
        assert!(merge_round_plan(6, 10).is_empty());
        // paper Case 1: ~6 spilled files, no intermediate merging.
    }

    #[test]
    fn plan_always_reaches_factor() {
        for factor in [2usize, 3, 10, 16] {
            for n in 2..200 {
                let mut n_now = n;
                let mut rounds = 0;
                loop {
                    let plan = merge_round_plan(n_now, factor);
                    if plan.is_empty() {
                        break;
                    }
                    assert!(plan.iter().all(|&g| g >= 1 && g <= factor));
                    n_now = n_now - plan.iter().sum::<usize>() + plan.len();
                    rounds += 1;
                    assert!(rounds < 64, "n={n} factor={factor} diverges");
                }
                assert!(n_now <= factor);
            }
        }
    }

    #[test]
    fn kway_merge_sorts() {
        let a = vec![
            Record::new(b"a".to_vec(), b"1".to_vec()),
            Record::new(b"c".to_vec(), b"2".to_vec()),
        ];
        let b = vec![
            Record::new(b"b".to_vec(), b"3".to_vec()),
            Record::new(b"c".to_vec(), b"4".to_vec()),
            Record::new(b"d".to_vec(), b"5".to_vec()),
        ];
        let mut got = Vec::new();
        kway_merge(vec![Run::from_vec(a), Run::from_vec(b)], |r| {
            got.push(r);
            Ok(())
        })
        .unwrap();
        let keys: Vec<&[u8]> = got.iter().map(|r| r.key.as_slice()).collect();
        assert_eq!(keys, vec![b"a" as &[u8], b"b", b"c", b"c", b"d"]);
        // tie on "c": run 0 first
        assert_eq!(got[2].value, b"2");
        assert_eq!(got[3].value, b"4");
    }

    #[test]
    fn loser_tree_matches_heap_merge() {
        // same runs through both merges: order and ties must agree
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let n_runs = 7;
        let mut fixed_runs = Vec::new();
        let mut generic_runs = Vec::new();
        for r in 0..n_runs {
            let mut v: Vec<(u64, u64)> = (0..200)
                .map(|i| (rng.below(50), (r * 1000 + i) as u64))
                .collect();
            v.sort_unstable();
            generic_runs.push(Run::from_vec(
                v.iter()
                    .map(|&(k, val)| {
                        Record::new(k.to_be_bytes().to_vec(), val.to_be_bytes().to_vec())
                    })
                    .collect(),
            ));
            fixed_runs.push(FixedRun::from_vec(v));
        }
        let mut got_fixed = Vec::new();
        kway_merge_fixed(fixed_runs, |k, v| {
            got_fixed.push((k, v));
            Ok(())
        })
        .unwrap();
        let mut got_generic = Vec::new();
        kway_merge(generic_runs, |r| {
            got_generic.push((
                u64::from_be_bytes(r.key[..8].try_into().unwrap()),
                u64::from_be_bytes(r.value[..8].try_into().unwrap()),
            ));
            Ok(())
        })
        .unwrap();
        assert_eq!(got_fixed.len(), n_runs * 200);
        assert_eq!(got_fixed, got_generic);
    }

    #[test]
    fn pair_merge_orders_by_full_pair_not_run_index() {
        // equal keys whose VALUES are out of order across runs: a
        // (key, run)-ordered merge would emit (5, 9) before (5, 3);
        // the pair merge must not.
        let runs = vec![
            (vec![1i64, 5, 7], vec![10i64, 9, 1]),
            (vec![5i64, 5, 8], vec![3i64, 11, 0]),
        ];
        let mut got = Vec::new();
        kway_merge_pairs(&runs, |k, v| got.push((k, v)));
        assert_eq!(got, vec![(1, 10), (5, 3), (5, 9), (5, 11), (7, 1), (8, 0)]);
    }

    #[test]
    fn pair_merge_matches_pairwise_reference() {
        // the old O(n·k) pairwise pop-merge, kept as the test oracle
        fn reference(mut runs: Vec<(Vec<i64>, Vec<i64>)>) -> (Vec<i64>, Vec<i64>) {
            while runs.len() > 1 {
                let (kb, ib) = runs.pop().unwrap();
                let (ka, ia) = runs.pop().unwrap();
                let mut k = Vec::with_capacity(ka.len() + kb.len());
                let mut ix = Vec::with_capacity(k.capacity());
                let (mut i, mut j) = (0, 0);
                while i < ka.len() && j < kb.len() {
                    if (ka[i], ia[i]) <= (kb[j], ib[j]) {
                        k.push(ka[i]);
                        ix.push(ia[i]);
                        i += 1;
                    } else {
                        k.push(kb[j]);
                        ix.push(ib[j]);
                        j += 1;
                    }
                }
                k.extend_from_slice(&ka[i..]);
                ix.extend_from_slice(&ia[i..]);
                k.extend_from_slice(&kb[j..]);
                ix.extend_from_slice(&ib[j..]);
                runs.push((k, ix));
            }
            runs.pop().unwrap_or_default()
        }

        use crate::util::rng::Rng;
        let mut rng = Rng::new(41);
        for n_runs in [0usize, 1, 2, 5, 9] {
            let mut runs = Vec::new();
            let mut next_index = 0i64;
            for _ in 0..n_runs {
                // duplicate-heavy keys, globally unique indexes (the
                // scheme's regime), sorted by (key, index)
                let mut pairs: Vec<(i64, i64)> = (0..1 + rng.below(300))
                    .map(|_| {
                        next_index += 1;
                        (rng.below(40) as i64, next_index)
                    })
                    .collect();
                pairs.sort_unstable();
                runs.push((
                    pairs.iter().map(|p| p.0).collect::<Vec<i64>>(),
                    pairs.iter().map(|p| p.1).collect::<Vec<i64>>(),
                ));
            }
            let want = reference(runs.clone());
            let mut keys = Vec::new();
            let mut ixs = Vec::new();
            kway_merge_pairs(&runs, |k, v| {
                keys.push(k);
                ixs.push(v);
            });
            assert_eq!((keys, ixs), want, "n_runs={n_runs}");
        }
    }

    #[test]
    fn loser_tree_edge_cases() {
        // zero runs, one run, empty runs mixed with non-empty
        kway_merge_fixed(Vec::new(), |_, _| panic!("no records")).unwrap();
        let mut got = Vec::new();
        kway_merge_fixed(vec![FixedRun::from_vec(vec![(3, 30), (5, 50)])], |k, v| {
            got.push((k, v));
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![(3, 30), (5, 50)]);
        let runs = vec![
            FixedRun::from_vec(Vec::new()),
            FixedRun::from_vec(vec![(2, 1)]),
            FixedRun::from_vec(Vec::new()),
            FixedRun::from_vec(vec![(1, 2)]),
        ];
        let mut got = Vec::new();
        kway_merge_fixed(runs, |k, v| {
            got.push((k, v));
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![(1, 2), (2, 1)]);
    }

    #[test]
    fn fixed_reader_roundtrips_segments() {
        // frames written through the generic writer read back through
        // the strided reader, including at a non-zero offset
        let dir = std::env::temp_dir().join(format!("samr-fixedrun-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("frames");
        let n = 2500u64; // > FIXED_READ_FRAMES: several refills
        {
            let mut w = BufWriter::new(File::create(&p).unwrap());
            for i in 0..n {
                Record::new(i.to_be_bytes().to_vec(), (i * 2).to_be_bytes().to_vec())
                    .write_to(&mut w)
                    .unwrap();
            }
            w.flush().unwrap();
        }
        let mut run = FixedRun::from_path(&p).unwrap();
        let mut i = 0u64;
        while let Some((k, v)) = run.next_pair().unwrap() {
            assert_eq!((k, v), (i, i * 2));
            i += 1;
        }
        assert_eq!(i, n);
        // segment starting 100 records in, 50 records long
        let mut run = FixedRun::from_segment(&p, 100 * FIXED_WIRE_BYTES, 50).unwrap();
        let mut got = Vec::new();
        while let Some(kv) = run.next_pair().unwrap() {
            got.push(kv);
        }
        assert_eq!(got.len(), 50);
        assert_eq!(got[0], (100, 200));
        assert_eq!(got[49], (149, 298));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fixed_merge_rounds_match_generic_bytes() {
        let dir = std::env::temp_dir().join(format!("samr-fmerge-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let make_files = |tag: &str| -> Vec<PathBuf> {
            (0..25)
                .map(|i| {
                    let p = dir.join(format!("{tag}{i}"));
                    let mut w = BufWriter::new(File::create(&p).unwrap());
                    w.write_all(&fixed_frame(i as u64, 7)).unwrap();
                    w.flush().unwrap();
                    p
                })
                .collect()
        };
        let mut totals = Vec::new();
        for fixed in [false, true] {
            let files = make_files(if fixed { "f" } else { "g" });
            let mut scratch_n = 0;
            let (mut read, mut write) = (0u64, 0u64);
            let tag = if fixed { "fs" } else { "gs" };
            let mut scratch = |_: usize| {
                scratch_n += 1;
                dir.join(format!("{tag}{scratch_n}"))
            };
            let out = if fixed {
                run_merge_rounds_fixed(files, 4, &mut scratch, &mut |b| read += b, &mut |b| {
                    write += b
                })
                .unwrap()
            } else {
                run_merge_rounds(files, 4, &mut scratch, &mut |b| read += b, &mut |b| write += b)
                    .unwrap()
            };
            assert!(out.len() <= 4);
            // surviving files hold identical bytes in both modes
            let mut contents: Vec<Vec<u8>> =
                out.iter().map(|p| std::fs::read(p).unwrap()).collect();
            contents.sort();
            totals.push((read, write, contents));
        }
        assert_eq!(totals[0], totals[1], "fixed and generic rounds must agree");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disk_merge_rounds_account_bytes() {
        let dir = std::env::temp_dir().join(format!("samr-merge-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // 25 single-record files, factor 4
        let mut files = Vec::new();
        for i in 0..25 {
            let p = dir.join(format!("run{i}"));
            let mut w = BufWriter::new(File::create(&p).unwrap());
            Record::new(format!("k{i:02}").into_bytes(), vec![0u8; 10])
                .write_to(&mut w)
                .unwrap();
            w.flush().unwrap();
            files.push(p);
        }
        let mut scratch_n = 0;
        let mut read = 0u64;
        let mut write = 0u64;
        let out = run_merge_rounds(
            files,
            4,
            &mut |_| {
                scratch_n += 1;
                dir.join(format!("scratch{scratch_n}"))
            },
            &mut |b| read += b,
            &mut |b| write += b,
        )
        .unwrap();
        assert!(out.len() <= 4);
        assert_eq!(read, write); // merging re-writes exactly what it reads
        // every surviving file still k-way merges to 25 sorted records
        let runs = out.iter().map(|p| Run::from_path(p).unwrap()).collect();
        let mut n = 0;
        let mut last: Option<Vec<u8>> = None;
        kway_merge(runs, |r| {
            if let Some(l) = &last {
                assert!(*l <= r.key);
            }
            last = Some(r.key.clone());
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 25);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Sorted-run k-way merging and the Hadoop merge-round policy — the
//! mechanics behind Figs. 3–4 and the Case-5 "1.88 R/W" estimate.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{self, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::mapreduce::record::Record;

/// A sorted run of records: either an open spill-file segment or an
/// in-memory vector.
pub enum Run {
    File(BufReader<File>),
    /// A byte-range of a spill file holding `remaining` records.
    Segment(BufReader<File>, u64),
    Mem(std::vec::IntoIter<Record>),
}

impl Run {
    pub fn from_path(p: &Path) -> io::Result<Run> {
        Ok(Run::File(BufReader::new(File::open(p)?)))
    }

    /// Open a per-partition segment: `offset` bytes in, `records` records.
    pub fn from_segment(p: &Path, offset: u64, records: u64) -> io::Result<Run> {
        use std::io::Seek;
        let mut f = File::open(p)?;
        f.seek(std::io::SeekFrom::Start(offset))?;
        Ok(Run::Segment(BufReader::new(f), records))
    }

    pub fn from_vec(v: Vec<Record>) -> Run {
        Run::Mem(v.into_iter())
    }

    fn next_record(&mut self) -> io::Result<Option<Record>> {
        match self {
            Run::File(r) => Record::read_from(r),
            Run::Segment(r, remaining) => {
                if *remaining == 0 {
                    return Ok(None);
                }
                *remaining -= 1;
                Record::read_from(r)
            }
            Run::Mem(it) => Ok(it.next()),
        }
    }
}

struct HeapEntry {
    rec: Record,
    run: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.rec.key == other.rec.key && self.run == other.run
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for ascending (key, run).
        other
            .rec
            .key
            .cmp(&self.rec.key)
            .then(other.run.cmp(&self.run))
    }
}

/// Merge sorted runs, feeding each record (ascending by key, ties by run
/// index — deterministic and stable across spill order) to `sink`.
pub fn kway_merge(
    mut runs: Vec<Run>,
    mut sink: impl FnMut(Record) -> io::Result<()>,
) -> io::Result<()> {
    let mut heap = BinaryHeap::with_capacity(runs.len());
    for (i, run) in runs.iter_mut().enumerate() {
        if let Some(rec) = run.next_record()? {
            heap.push(HeapEntry { rec, run: i });
        }
    }
    while let Some(HeapEntry { rec, run }) = heap.pop() {
        sink(rec)?;
        if let Some(next) = runs[run].next_record()? {
            heap.push(HeapEntry { rec: next, run });
        }
    }
    Ok(())
}

/// The paper's intermediate merge-round plan (§III, Fig. 4 discussion):
/// with `n` on-disk files and merge width `factor`, merge the minimum
/// number of files so that at most `factor` remain for the final merge.
/// Returns the group sizes to merge now (empty when `n <= factor`).
///
/// k = ceil((n - factor) / (factor - 1)) groups covering m = n - factor + k
/// files — for the paper's Case 5 (n=35, factor=10): k=3 groups of
/// 10+10+8 = 28 files, leaving 3 merged + 7 originals = 10.
pub fn merge_round_plan(n: usize, factor: usize) -> Vec<usize> {
    assert!(factor >= 2);
    if n <= factor {
        return Vec::new();
    }
    let mut k = (n - factor).div_ceil(factor - 1);
    let mut m = n - factor + k; // files merged now
    if m > n {
        // one round cannot reach <= factor files even merging everything
        // (n > factor^2-ish); merge all files in width-<=factor groups and
        // let the caller run another round.
        k = n.div_ceil(factor);
        m = n;
    }
    // distribute m over k groups, each <= factor
    let base = m / k;
    let extra = m % k;
    (0..k)
        .map(|i| base + usize::from(i < extra))
        .collect()
}

/// Run intermediate merge rounds on disk files until at most `factor`
/// remain. `scratch` names new files; `on_read`/`on_write` receive byte
/// counts for the footprint ledger. Returns the surviving file list.
pub fn run_merge_rounds(
    mut files: Vec<PathBuf>,
    factor: usize,
    scratch: &mut impl FnMut(usize) -> PathBuf,
    on_read: &mut impl FnMut(u64),
    on_write: &mut impl FnMut(u64),
) -> io::Result<Vec<PathBuf>> {
    let mut round = 0usize;
    loop {
        let plan = merge_round_plan(files.len(), factor);
        if plan.is_empty() {
            return Ok(files);
        }
        // merge the largest-count prefix; order is irrelevant to byte
        // totals, so take files from the front (oldest spills first).
        let mut rest = files.split_off(plan.iter().sum());
        let mut merged: Vec<PathBuf> = Vec::with_capacity(plan.len());
        let mut it = files.into_iter();
        for (gi, &gsize) in plan.iter().enumerate() {
            let group: Vec<PathBuf> = it.by_ref().take(gsize).collect();
            let mut in_bytes = 0u64;
            let runs = group
                .iter()
                .map(|p| {
                    in_bytes += std::fs::metadata(p)?.len();
                    Run::from_path(p)
                })
                .collect::<io::Result<Vec<_>>>()?;
            let out_path = scratch(round * 1000 + gi);
            let mut out_bytes = 0u64;
            {
                let mut w = BufWriter::new(File::create(&out_path)?);
                kway_merge(runs, |rec| {
                    out_bytes += rec.wire_bytes();
                    rec.write_to(&mut w)
                })?;
                w.flush()?;
            }
            on_read(in_bytes);
            on_write(out_bytes);
            for p in group {
                let _ = std::fs::remove_file(p);
            }
            merged.push(out_path);
        }
        merged.append(&mut rest);
        files = merged;
        round += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_case5_plan() {
        // 35 spilled files, factor 10 -> merge 28 files in 3 groups.
        let plan = merge_round_plan(35, 10);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.iter().sum::<usize>(), 28);
        assert!(plan.iter().all(|&g| g <= 10));
    }

    #[test]
    fn no_round_needed_at_or_below_factor() {
        assert!(merge_round_plan(10, 10).is_empty());
        assert!(merge_round_plan(6, 10).is_empty());
        // paper Case 1: ~6 spilled files, no intermediate merging.
    }

    #[test]
    fn plan_always_reaches_factor() {
        for factor in [2usize, 3, 10, 16] {
            for n in 2..200 {
                let mut n_now = n;
                let mut rounds = 0;
                loop {
                    let plan = merge_round_plan(n_now, factor);
                    if plan.is_empty() {
                        break;
                    }
                    assert!(plan.iter().all(|&g| g >= 1 && g <= factor));
                    n_now = n_now - plan.iter().sum::<usize>() + plan.len();
                    rounds += 1;
                    assert!(rounds < 64, "n={n} factor={factor} diverges");
                }
                assert!(n_now <= factor);
            }
        }
    }

    #[test]
    fn kway_merge_sorts() {
        let a = vec![
            Record::new(b"a".to_vec(), b"1".to_vec()),
            Record::new(b"c".to_vec(), b"2".to_vec()),
        ];
        let b = vec![
            Record::new(b"b".to_vec(), b"3".to_vec()),
            Record::new(b"c".to_vec(), b"4".to_vec()),
            Record::new(b"d".to_vec(), b"5".to_vec()),
        ];
        let mut got = Vec::new();
        kway_merge(vec![Run::from_vec(a), Run::from_vec(b)], |r| {
            got.push(r);
            Ok(())
        })
        .unwrap();
        let keys: Vec<&[u8]> = got.iter().map(|r| r.key.as_slice()).collect();
        assert_eq!(keys, vec![b"a" as &[u8], b"b", b"c", b"c", b"d"]);
        // tie on "c": run 0 first
        assert_eq!(got[2].value, b"2");
        assert_eq!(got[3].value, b"4");
    }

    #[test]
    fn disk_merge_rounds_account_bytes() {
        let dir = std::env::temp_dir().join(format!("samr-merge-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // 25 single-record files, factor 4
        let mut files = Vec::new();
        for i in 0..25 {
            let p = dir.join(format!("run{i}"));
            let mut w = BufWriter::new(File::create(&p).unwrap());
            Record::new(format!("k{i:02}").into_bytes(), vec![0u8; 10])
                .write_to(&mut w)
                .unwrap();
            w.flush().unwrap();
            files.push(p);
        }
        let mut scratch_n = 0;
        let mut read = 0u64;
        let mut write = 0u64;
        let out = run_merge_rounds(
            files,
            4,
            &mut |_| {
                scratch_n += 1;
                dir.join(format!("scratch{scratch_n}"))
            },
            &mut |b| read += b,
            &mut |b| write += b,
        )
        .unwrap();
        assert!(out.len() <= 4);
        assert_eq!(read, write); // merging re-writes exactly what it reads
        // every surviving file still k-way merges to 25 sorted records
        let runs = out.iter().map(|p| Run::from_path(p).unwrap()).collect();
        let mut n = 0;
        let mut last: Option<Vec<u8>> = None;
        kway_merge(runs, |r| {
            if let Some(l) = &last {
                assert!(*l <= r.key);
            }
            last = Some(r.key.clone());
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 25);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Burrows–Wheeler transform derived from the suffix array (the paper's
//! §I: sequence alignment relies on SA and BWT, the latter derived from
//! the former).

use crate::suffix::sa;

/// BWT of `text + sentinel` where the sentinel is the implicit smallest
/// character, returned with `None` marking the sentinel's slot.
pub fn bwt(text: &[u8]) -> Vec<Option<u8>> {
    let sa = sa::sais(text);
    bwt_from_sa(text, &sa)
}

/// BWT from a precomputed suffix array of `text` (no sentinel in `sa`).
///
/// Row 0 of the sorted rotations is the sentinel suffix, whose preceding
/// character is `text[n-1]`; the suffix starting at 0 contributes the
/// sentinel itself (`None`).
pub fn bwt_from_sa(text: &[u8], sa: &[u32]) -> Vec<Option<u8>> {
    let n = text.len();
    assert_eq!(sa.len(), n);
    let mut out = Vec::with_capacity(n + 1);
    if n == 0 {
        out.push(None);
        return out;
    }
    out.push(Some(text[n - 1])); // sentinel row
    for &p in sa {
        if p == 0 {
            out.push(None);
        } else {
            out.push(Some(text[p as usize - 1]));
        }
    }
    out
}

/// Invert a BWT produced by [`bwt`] (LF mapping), recovering the text.
pub fn inverse_bwt(b: &[Option<u8>]) -> Vec<u8> {
    let n = b.len();
    if n <= 1 {
        return Vec::new();
    }
    // stable counting sort of the BWT column gives the first column.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| (b[i], i)); // None (sentinel) sorts first; ties by row = stability
    // LF: the k-th occurrence of c in the last column is the k-th
    // occurrence of c in the first column.
    let mut lf = vec![0usize; n];
    for (first_row, &last_row) in order.iter().enumerate() {
        lf[last_row] = first_row;
    }
    // walk from the sentinel row backwards: last[row] is the character
    // preceding the row's first character in the text, so emitting before
    // stepping yields text[n-1], text[n-2], ..., text[0].
    let mut out = Vec::with_capacity(n - 1);
    let mut row = 0usize; // row 0 of first column is the sentinel suffix
    for _ in 0..n - 1 {
        out.push(b[row].expect("sentinel revisited"));
        row = lf[row];
    }
    out.reverse();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn render(b: &[Option<u8>]) -> String {
        b.iter()
            .map(|c| c.map(|x| x as char).unwrap_or('$'))
            .collect()
    }

    #[test]
    fn banana() {
        // classic: BWT(banana$) = annb$aa
        assert_eq!(render(&bwt(b"banana")), "annb$aa");
    }

    #[test]
    fn roundtrip_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        for len in [1usize, 2, 3, 10, 100, 1000] {
            let text: Vec<u8> = (0..len).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
            let b = bwt(&text);
            assert_eq!(b.len(), len + 1);
            assert_eq!(inverse_bwt(&b), text, "len={len}");
        }
    }

    #[test]
    fn empty() {
        let b = bwt(b"");
        assert_eq!(b, vec![None]);
        assert_eq!(inverse_bwt(&b), Vec::<u8>::new());
    }
}

//! Suffix-array domain: encoding, read corpora, construction algorithms,
//! BWT, and output validation.

pub mod bwt;
pub mod encode;
pub mod lcp;
pub mod reads;
pub mod sa;
pub mod search;
pub mod validate;

//! Suffix-array domain: encoding, read corpora, construction algorithms,
//! BWT, the sealed on-disk index artifact, query views, and output
//! validation.

pub mod bwt;
pub mod encode;
pub mod lcp;
pub mod reads;
pub mod sa;
pub mod sealed;
pub mod search;
pub mod validate;

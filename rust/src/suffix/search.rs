//! Pattern matching over a suffix array — the application the paper's
//! introduction motivates (sequence alignment seeds, plagiarism
//! detection, compression all reduce to "find every occurrence of P").
//!
//! Classic Manber–Myers binary search: O(|P| log n) per query over the
//! SA of a single text, plus a corpus-level variant over the pipeline's
//! packed-index output.

use std::collections::HashMap;

use crate::suffix::encode::unpack_index;
use crate::suffix::sa;

/// All occurrences (start positions) of `pattern` in `text`, via binary
/// search on the suffix array. Positions are returned sorted.
pub fn find_all(text: &[u8], sa: &[u32], pattern: &[u8]) -> Vec<u32> {
    if pattern.is_empty() || pattern.len() > text.len() {
        return Vec::new();
    }
    let cmp = |p: u32| -> std::cmp::Ordering {
        let suffix = &text[p as usize..];
        let k = suffix.len().min(pattern.len());
        suffix[..k].cmp(&pattern[..k]).then(
            // suffix shorter than pattern sorts before it
            if suffix.len() < pattern.len() {
                std::cmp::Ordering::Less
            } else {
                std::cmp::Ordering::Equal
            },
        )
    };
    let lo = sa.partition_point(|&p| cmp(p) == std::cmp::Ordering::Less);
    let hi = lo + sa[lo..].partition_point(|&p| cmp(p) == std::cmp::Ordering::Equal);
    let mut out: Vec<u32> = sa[lo..hi].to_vec();
    out.sort_unstable();
    out
}

/// Convenience: build the SA and search in one call.
pub fn occurrences(text: &[u8], pattern: &[u8]) -> Vec<u32> {
    let sa = sa::sais(text);
    find_all(text, &sa, pattern)
}

/// Search the *pipeline's* output: the globally sorted packed suffix
/// indexes plus the read map. Returns `(seq, offset)` pairs where the
/// pattern occurs (pattern must not span reads — reads are independent
/// strings, exactly like alignment seeds).
pub fn find_in_corpus(
    order: &[i64],
    reads: &HashMap<u64, Vec<u8>>,
    pattern: &[u8],
) -> Vec<(u64, usize)> {
    if pattern.is_empty() {
        return Vec::new();
    }
    let suffix_of = |idx: i64| -> &[u8] {
        let (seq, off) = unpack_index(idx);
        let r = &reads[&seq];
        &r[off.min(r.len())..]
    };
    let cmp = |idx: i64| -> std::cmp::Ordering {
        let suffix = suffix_of(idx);
        let k = suffix.len().min(pattern.len());
        suffix[..k].cmp(&pattern[..k]).then(if suffix.len() < pattern.len() {
            std::cmp::Ordering::Less
        } else {
            std::cmp::Ordering::Equal
        })
    };
    let lo = order.partition_point(|&i| cmp(i) == std::cmp::Ordering::Less);
    let hi = lo + order[lo..].partition_point(|&i| cmp(i) == std::cmp::Ordering::Equal);
    let mut out: Vec<(u64, usize)> = order[lo..hi].iter().map(|&i| unpack_index(i)).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::codes_of;
    use crate::suffix::reads::Read;
    use crate::suffix::validate::{read_map, reference_order};

    #[test]
    fn finds_all_occurrences() {
        let text = b"GATTACAGATTACA";
        assert_eq!(occurrences(text, b"GATTACA"), vec![0, 7]);
        assert_eq!(occurrences(text, b"TA"), vec![3, 10]);
        assert_eq!(occurrences(text, b"X"), Vec::<u32>::new());
        assert_eq!(occurrences(text, b""), Vec::<u32>::new());
        assert_eq!(occurrences(text, b"GATTACAGATTACA"), vec![0]);
        assert_eq!(occurrences(text, b"GATTACAGATTACAX"), Vec::<u32>::new());
    }

    #[test]
    fn matches_naive_scan_on_random_text() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(12);
        let text: Vec<u8> = (0..2000).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
        let sa = sa::sais(&text);
        for plen in [1usize, 2, 4, 8] {
            for _ in 0..10 {
                let start = rng.below((text.len() - plen) as u64) as usize;
                let pattern = &text[start..start + plen];
                let got = find_all(&text, &sa, pattern);
                let want: Vec<u32> = (0..=text.len() - plen)
                    .filter(|&i| &text[i..i + plen] == pattern)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, want, "plen={plen}");
            }
        }
    }

    #[test]
    fn corpus_search_over_pipeline_output() {
        let reads = vec![
            Read::from_ascii(0, b"ACGTACGT"),
            Read::from_ascii(1, b"TTACGTT"),
            Read::from_ascii(5, b"GGGG"),
        ];
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let pat = codes_of(b"ACGT");
        let hits = find_in_corpus(&order, &map, &pat);
        assert_eq!(hits, vec![(0, 0), (0, 4), (1, 2)]);
        assert!(find_in_corpus(&order, &map, &codes_of(b"AAAA")).is_empty());
    }
}

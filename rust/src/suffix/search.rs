//! Pattern matching over a suffix array — the application the paper's
//! introduction motivates (sequence alignment seeds, plagiarism
//! detection, compression all reduce to "find every occurrence of P").
//!
//! Classic Manber–Myers binary search: O(|P| log n) per query. All
//! queries run through one abstraction, [`IndexView`] — a sorted suffix
//! array addressed by rank — implemented by the single-text view
//! ([`TextIndex`]), the in-memory construction result ([`CorpusIndex`]),
//! and the on-disk artifact (`crate::suffix::sealed::SealedIndex`).
//! Because every backend shares the same default [`IndexView::sa_range`]
//! / [`IndexView::find`] / [`IndexView::find_pairs`] implementations,
//! sealed-vs-in-memory equivalence holds by construction: the only code
//! that differs per backend is "fetch the suffix at rank r".

use std::collections::HashMap;
use std::ops::Range;

use crate::suffix::encode::unpack_index;
use crate::suffix::reads::{fragment_of, pair_seq, Mate};
use crate::suffix::sa;

/// Compare a suffix against a query pattern, looking at no more than
/// `|pattern|` bytes: `Equal` means "the pattern is a prefix of this
/// suffix". A suffix shorter than the pattern sorts before it, matching
/// SA order.
#[inline]
fn suffix_cmp(suffix: &[u8], pattern: &[u8]) -> std::cmp::Ordering {
    let k = suffix.len().min(pattern.len());
    suffix[..k].cmp(&pattern[..k]).then(
        // suffix shorter than pattern sorts before it
        if suffix.len() < pattern.len() {
            std::cmp::Ordering::Less
        } else {
            std::cmp::Ordering::Equal
        },
    )
}

/// First rank in `[lo, hi)` where `pred` turns false (`pred` must be
/// monotone true-then-false over the range) — the one binary-search
/// primitive both query bounds are built from.
fn partition(mut lo: usize, mut hi: usize, mut pred: impl FnMut(usize) -> bool) -> usize {
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if pred(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// A queryable suffix-array index: suffixes in sorted order, addressed
/// by rank. Implementors provide the three rank accessors; every search
/// (`sa_range`, `find`, `find_pairs`) is a provided method on top, so
/// all backends answer queries through exactly one code path.
pub trait IndexView {
    /// Number of suffixes (SA entries) in the index.
    fn n_suffixes(&self) -> usize;

    /// The suffix at sorted rank `rank`.
    fn suffix_at(&self, rank: usize) -> &[u8];

    /// The packed index (`crate::suffix::encode::pack_index`) at sorted
    /// rank `rank`.
    fn index_at(&self, rank: usize) -> i64;

    /// The contiguous SA rank range whose suffixes start with `pattern`
    /// — the deduplicated bounds primitive every query calls. Empty
    /// patterns match nothing.
    fn sa_range(&self, pattern: &[u8]) -> Range<usize> {
        if pattern.is_empty() {
            return 0..0;
        }
        let n = self.n_suffixes();
        let lo = partition(0, n, |r| {
            suffix_cmp(self.suffix_at(r), pattern) == std::cmp::Ordering::Less
        });
        let hi = partition(lo, n, |r| {
            suffix_cmp(self.suffix_at(r), pattern) != std::cmp::Ordering::Greater
        });
        lo..hi
    }

    /// All occurrences of `pattern`, as sorted `(seq, offset)` pairs.
    /// The pattern must not span reads — reads are independent strings,
    /// exactly like alignment seeds.
    fn find(&self, pattern: &[u8]) -> Vec<(u64, usize)> {
        let mut out: Vec<(u64, usize)> = self
            .sa_range(pattern)
            .map(|r| unpack_index(self.index_at(r)))
            .collect();
        out.sort_unstable();
        out
    }

    /// Pair-end seed alignment over the joint suffix array of a two-file
    /// pair-end construction — the query half of the paper's Case 6
    /// claim ("complete the pair-end sequencing and alignment with two
    /// input files").
    ///
    /// `seed_fwd` is searched among forward mates and `seed_rev` (already
    /// in the reverse read's coordinates, i.e. the reverse complement of
    /// the fragment-strand seed) among reverse mates; hits are joined by
    /// the fragment id recovered from the pair-numbered sequence
    /// (`crate::suffix::reads::fragment_of`), and a joined pair survives
    /// only if it is compatible with a sequencing insert of at most
    /// `max_insert` bases. Geometry: a forward seed at offset `of`
    /// occupies fragment positions `[of, of + |seed_fwd|)` from the
    /// fragment's start; a reverse seed at offset `or` occupies the
    /// `|seed_rev|` bases ending `or` before the fragment's END. The
    /// smallest fragment consistent with both is therefore
    /// `max(of + |seed_fwd|, or + |seed_rev|)` — mates of short
    /// fragments may overlap (see
    /// `crate::suffix::reads::paired_reads_from_fragment`), so the two
    /// seed intervals are allowed to cover the same bases.
    ///
    /// Both seed lookups are `O(|seed| log n)` binary searches on the
    /// joint SA; the join is hash-by-fragment. Results are sorted by
    /// (fragment, forward offset, reverse offset).
    fn find_pairs(&self, seed_fwd: &[u8], seed_rev: &[u8], max_insert: usize) -> Vec<PairHit> {
        if seed_fwd.is_empty() || seed_rev.is_empty() {
            return Vec::new();
        }
        // hits on the correct mate only: a forward seed found in a
        // reverse read (or vice versa) is not a mate pairing
        let mate_hits = |seed: &[u8], want: Mate| -> HashMap<u64, Vec<usize>> {
            let mut by_fragment: HashMap<u64, Vec<usize>> = HashMap::new();
            for (seq, off) in self.find(seed) {
                let (fragment, mate) = fragment_of(seq);
                if mate == want {
                    by_fragment.entry(fragment).or_default().push(off);
                }
            }
            by_fragment
        };
        let fwd_hits = mate_hits(seed_fwd, Mate::Forward);
        let rev_hits = mate_hits(seed_rev, Mate::Reverse);

        let mut out = Vec::new();
        for (&fragment, f_offs) in &fwd_hits {
            let Some(r_offs) = rev_hits.get(&fragment) else { continue };
            for &of in f_offs {
                for &or in r_offs {
                    let min_fragment = (of + seed_fwd.len()).max(or + seed_rev.len());
                    if min_fragment <= max_insert {
                        out.push(PairHit {
                            fragment,
                            forward: (pair_seq(fragment, Mate::Forward), of),
                            reverse: (pair_seq(fragment, Mate::Reverse), or),
                        });
                    }
                }
            }
        }
        out.sort_by_key(|h| (h.fragment, h.forward.1, h.reverse.1));
        out
    }
}

/// [`IndexView`] over a single text and its suffix array — the classic
/// Manber–Myers setting. Packed indexes are plain text positions (seq 0
/// is implied, so `index_at` returns the raw position).
pub struct TextIndex<'a> {
    text: &'a [u8],
    sa: &'a [u32],
}

impl<'a> TextIndex<'a> {
    /// View `text` through its suffix array `sa`.
    pub fn new(text: &'a [u8], sa: &'a [u32]) -> Self {
        TextIndex { text, sa }
    }
}

impl IndexView for TextIndex<'_> {
    fn n_suffixes(&self) -> usize {
        self.sa.len()
    }

    fn suffix_at(&self, rank: usize) -> &[u8] {
        &self.text[self.sa[rank] as usize..]
    }

    fn index_at(&self, rank: usize) -> i64 {
        self.sa[rank] as i64
    }
}

/// [`IndexView`] over the *pipeline's* in-memory output: the globally
/// sorted packed suffix indexes plus the read map. The construction-side
/// twin of `crate::suffix::sealed::SealedIndex` — both answer every
/// query through the same provided methods.
pub struct CorpusIndex<'a> {
    order: &'a [i64],
    reads: &'a HashMap<u64, Vec<u8>>,
}

impl<'a> CorpusIndex<'a> {
    /// View a construction result: `order` is the globally sorted packed
    /// indexes, `reads` maps each sequence number to its codes.
    pub fn new(order: &'a [i64], reads: &'a HashMap<u64, Vec<u8>>) -> Self {
        CorpusIndex { order, reads }
    }
}

impl IndexView for CorpusIndex<'_> {
    fn n_suffixes(&self) -> usize {
        self.order.len()
    }

    fn suffix_at(&self, rank: usize) -> &[u8] {
        let (seq, off) = unpack_index(self.order[rank]);
        let r = &self.reads[&seq];
        &r[off.min(r.len())..]
    }

    fn index_at(&self, rank: usize) -> i64 {
        self.order[rank]
    }
}

/// All occurrences (start positions) of `pattern` in `text`, via binary
/// search on the suffix array. Positions are returned sorted.
pub fn find_all(text: &[u8], sa: &[u32], pattern: &[u8]) -> Vec<u32> {
    let view = TextIndex::new(text, sa);
    let mut out: Vec<u32> = view.sa_range(pattern).map(|r| sa[r]).collect();
    out.sort_unstable();
    out
}

/// Convenience: build the SA and search in one call.
pub fn occurrences(text: &[u8], pattern: &[u8]) -> Vec<u32> {
    let sa = sa::sais(text);
    find_all(text, &sa, pattern)
}

/// Search the pipeline's in-memory output. Thin wrapper over
/// [`CorpusIndex`] + [`IndexView::find`].
pub fn find_in_corpus(
    order: &[i64],
    reads: &HashMap<u64, Vec<u8>>,
    pattern: &[u8],
) -> Vec<(u64, usize)> {
    CorpusIndex::new(order, reads).find(pattern)
}

/// One joined pair-end seed hit: both mates of a fragment carry their
/// seed, at compatible positions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PairHit {
    /// Fragment id both mates belong to.
    pub fragment: u64,
    /// `(seq, offset)` of the forward seed in the forward-mate read.
    pub forward: (u64, usize),
    /// `(seq, offset)` of the reverse seed in the reverse-mate read.
    pub reverse: (u64, usize),
}

/// Pair-end seed alignment over the pipeline's in-memory output. Thin
/// wrapper over [`CorpusIndex`] + [`IndexView::find_pairs`]; see the
/// trait method for the geometry.
pub fn find_pairs(
    order: &[i64],
    reads: &HashMap<u64, Vec<u8>>,
    seed_fwd: &[u8],
    seed_rev: &[u8],
    max_insert: usize,
) -> Vec<PairHit> {
    CorpusIndex::new(order, reads).find_pairs(seed_fwd, seed_rev, max_insert)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::codes_of;
    use crate::suffix::reads::Read;
    use crate::suffix::validate::{read_map, reference_order};

    #[test]
    fn finds_all_occurrences() {
        let text = b"GATTACAGATTACA";
        assert_eq!(occurrences(text, b"GATTACA"), vec![0, 7]);
        assert_eq!(occurrences(text, b"TA"), vec![3, 10]);
        assert_eq!(occurrences(text, b"X"), Vec::<u32>::new());
        assert_eq!(occurrences(text, b""), Vec::<u32>::new());
        assert_eq!(occurrences(text, b"GATTACAGATTACA"), vec![0]);
        assert_eq!(occurrences(text, b"GATTACAGATTACAX"), Vec::<u32>::new());
    }

    #[test]
    fn matches_naive_scan_on_random_text() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(12);
        let text: Vec<u8> = (0..2000).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
        let sa = sa::sais(&text);
        for plen in [1usize, 2, 4, 8] {
            for _ in 0..10 {
                let start = rng.below((text.len() - plen) as u64) as usize;
                let pattern = &text[start..start + plen];
                let got = find_all(&text, &sa, pattern);
                let want: Vec<u32> = (0..=text.len() - plen)
                    .filter(|&i| &text[i..i + plen] == pattern)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, want, "plen={plen}");
            }
        }
    }

    #[test]
    fn sa_range_brackets_exactly_the_matching_suffixes() {
        let reads = vec![
            Read::from_ascii(0, b"ACGTACGT"),
            Read::from_ascii(1, b"TTACGTT"),
        ];
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let view = CorpusIndex::new(&order, &map);
        let pat = codes_of(b"ACGT");
        let range = view.sa_range(&pat);
        assert_eq!(range.len(), 3);
        for r in range.clone() {
            assert!(view.suffix_at(r).starts_with(&pat), "rank {r} inside range");
        }
        for r in (0..view.n_suffixes()).filter(|r| !range.contains(r)) {
            assert!(!view.suffix_at(r).starts_with(&pat), "rank {r} outside range");
        }
        assert_eq!(view.sa_range(&[]), 0..0);
    }

    #[test]
    fn find_pairs_joins_planted_fragments() {
        use crate::suffix::reads::paired_reads_from_fragment;
        // 20 bp fragments, 8 bp reads from each end, pair-numbered seqs.
        // fragment 0 carries BOTH seeds: "ACGT" in its forward read
        // (offsets 0 and 4) and "AAAC" in its reverse read (offset 0).
        // fragments 1-3 are decoys missing one seed or carrying it on
        // the wrong mate.
        let frags: [&[u8]; 4] = [
            b"ACGTACGTAAACCCGGGTTT", // fwd ACGTACGT, rev revcomp(CCGGGTTT)=AAACCCGG
            b"ACGTGGGGGGGGTTTTGGGG", // fwd has ACGT, rev CCCCAAAA lacks AAAC
            b"GGGGGGGGGGGGCCGGGTTT", // rev has AAAC, fwd GGGGGGGG lacks ACGT
            b"AAACGGGGGGGGACGTACGT", // seeds present but each on the WRONG mate
        ];
        let mut reads = Vec::new();
        for (f, frag) in frags.iter().enumerate() {
            let (fwd, rev) = paired_reads_from_fragment(f as u64, &codes_of(frag), 8);
            reads.push(fwd);
            reads.push(rev);
        }
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let seed_fwd = codes_of(b"ACGT");
        let seed_rev = codes_of(b"AAAC");

        let hits = find_pairs(&order, &map, &seed_fwd, &seed_rev, 30);
        assert_eq!(
            hits,
            vec![
                PairHit { fragment: 0, forward: (0, 0), reverse: (1, 0) },
                PairHit { fragment: 0, forward: (0, 4), reverse: (1, 0) },
            ]
        );

        // insert window: min fragment = max(of+|sf|, or+|sr|) — 4 for
        // (of=0, or=0), 8 for (of=4, or=0) — prunes mechanically
        let tight = find_pairs(&order, &map, &seed_fwd, &seed_rev, 7);
        assert_eq!(tight.len(), 1);
        assert_eq!(tight[0].forward.1, 0);
        assert!(find_pairs(&order, &map, &seed_fwd, &seed_rev, 3).is_empty());
        // empty seeds never match
        assert!(find_pairs(&order, &map, &[], &seed_rev, 30).is_empty());
    }

    #[test]
    fn find_pairs_keeps_overlapping_mates_of_short_fragments() {
        use crate::suffix::reads::paired_reads_from_fragment;
        // fragment length == read length: the mates fully overlap, so
        // both seeds cover the SAME fragment bases. A formula that
        // forces the reverse seed downstream of the forward one would
        // wrongly prune this genuine pairing.
        let frag = codes_of(b"ACGTTGCA");
        let (fwd, rev) = paired_reads_from_fragment(0, &frag, frag.len());
        let reads = vec![fwd, rev];
        let order = reference_order(&reads);
        let map = read_map(&reads);
        // fwd seed = fragment tail (of=4); rev seed = the revcomp view
        // of that same tail, i.e. the rev read's head (or=0)
        let seed_fwd = codes_of(b"TGCA");
        let seed_rev = codes_of(b"TGCA"); // revcomp(TGCA) == TGCA
        let hits = find_pairs(&order, &map, &seed_fwd, &seed_rev, frag.len());
        assert!(
            hits.iter().any(|h| h.fragment == 0 && h.forward.1 == 4 && h.reverse.1 == 0),
            "overlapping-mate pairing wrongly pruned: {hits:?}"
        );
    }

    #[test]
    fn corpus_search_over_pipeline_output() {
        let reads = vec![
            Read::from_ascii(0, b"ACGTACGT"),
            Read::from_ascii(1, b"TTACGTT"),
            Read::from_ascii(5, b"GGGG"),
        ];
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let pat = codes_of(b"ACGT");
        let hits = find_in_corpus(&order, &map, &pat);
        assert_eq!(hits, vec![(0, 0), (0, 4), (1, 2)]);
        assert!(find_in_corpus(&order, &map, &codes_of(b"AAAA")).is_empty());
    }
}

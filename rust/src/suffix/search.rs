//! Pattern matching over a suffix array — the application the paper's
//! introduction motivates (sequence alignment seeds, plagiarism
//! detection, compression all reduce to "find every occurrence of P").
//!
//! Two bound algorithms behind one entry point: the classic Manber–Myers
//! binary search (O(|P| log n) byte comparisons) and its LCP-accelerated
//! variant (O(|P| + log n)) that resumes each midpoint comparison at the
//! common-prefix depth the (llcp, rlcp) midpoint tree
//! ([`crate::suffix::lcp::MidpointTree`]) already proves. All queries
//! run through one abstraction, [`IndexView`] — a sorted suffix array
//! addressed by rank — implemented by the single-text view
//! ([`TextIndex`]), the in-memory construction result ([`CorpusIndex`]),
//! and the on-disk artifact (`crate::suffix::sealed::SealedIndex`).
//! Because every backend shares the same default [`IndexView::sa_range`]
//! / [`IndexView::find`] / [`IndexView::find_pairs`] implementations,
//! sealed-vs-in-memory equivalence holds by construction: the only code
//! that differs per backend is "fetch the suffix at rank r" and whether
//! [`IndexView::midpoint_tree`] offers the acceleration structure.

use std::cmp::Ordering;
use std::collections::HashMap;
use std::ops::Range;

use crate::suffix::encode::unpack_index;
use crate::suffix::lcp::{build_midpoint_tree, MidpointTree};
use crate::suffix::reads::{fragment_of, pair_seq, Mate};
use crate::suffix::sa;

/// Observer of the byte comparisons a search bound performs — how the
/// complexity tests *prove* the O(|P| + log n) claim instead of assuming
/// it. Monomorphized away for production queries ([`NoProbe`]).
pub trait CompareProbe {
    /// Record `n` byte comparisons.
    fn add(&mut self, n: u64);
}

/// The free probe: every `add` compiles to nothing.
pub struct NoProbe;

impl CompareProbe for NoProbe {
    #[inline]
    fn add(&mut self, _: u64) {}
}

/// Counting probe for the complexity tests and benches.
#[derive(Default)]
pub struct CountProbe(pub u64);

impl CompareProbe for CountProbe {
    #[inline]
    fn add(&mut self, n: u64) {
        self.0 += n;
    }
}

/// Compare a suffix against a query pattern, resuming at byte `from`
/// (both sides already proven equal before it). Looks at no more than
/// `|pattern|` bytes: `Equal` means "the pattern is a prefix of this
/// suffix"; a suffix shorter than the pattern sorts before it, matching
/// SA order. Returns the ordering plus the new pattern LCP (bytes of the
/// pattern matched, capped at `|pattern|`).
#[inline]
fn cmp_from(
    suffix: &[u8],
    pattern: &[u8],
    from: usize,
    probe: &mut impl CompareProbe,
) -> (Ordering, usize) {
    let k = suffix.len().min(pattern.len());
    let mut i = from;
    while i < k {
        probe.add(1);
        if suffix[i] != pattern[i] {
            return (suffix[i].cmp(&pattern[i]), i);
        }
        i += 1;
    }
    let ord = if suffix.len() < pattern.len() { Ordering::Less } else { Ordering::Equal };
    (ord, i)
}

/// First rank in `[lo, hi)` where `pred` turns false (`pred` must be
/// monotone true-then-false over the range) — the binary-search
/// primitive the plain query bounds are built from.
fn partition(mut lo: usize, mut hi: usize, mut pred: impl FnMut(usize) -> bool) -> usize {
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if pred(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Classic Manber–Myers bounds: every midpoint comparison restarts at
/// byte 0, so a query costs O(|P| log n) byte comparisons.
fn plain_range<V: IndexView + ?Sized>(
    view: &V,
    pattern: &[u8],
    probe: &mut impl CompareProbe,
) -> Range<usize> {
    if pattern.is_empty() {
        return 0..0;
    }
    let n = view.n_suffixes();
    let lo = partition(0, n, |r| {
        cmp_from(view.suffix_at(r), pattern, 0, probe).0 == Ordering::Less
    });
    let hi = partition(lo, n, |r| {
        cmp_from(view.suffix_at(r), pattern, 0, probe).0 != Ordering::Greater
    });
    lo..hi
}

/// One LCP-accelerated Manber–Myers bound over the interval `(-1, n)`
/// (virtual sentinel ranks compare Less/Greater than everything and
/// share 0 bytes with the pattern). Returns the first rank whose suffix
/// is `>= pattern` (`upper = false`) or `> pattern` (`upper = true`).
///
/// Invariant: `lo` always satisfies the bound predicate ("before"), `hi`
/// never does; `l_lcp`/`r_lcp` are the pattern LCPs at the bounds,
/// capped at `|pattern|`. Each step resolves the midpoint `m` from the
/// stored `llcp[m]`/`rlcp[m]` (the tree was built over the *same*
/// `m = lo + (hi - lo) / 2` descent, so the stored entry is exactly this
/// interval's) — only the `== max(l_lcp, r_lcp)` case touches text, and
/// then resumes at that shared depth. Every byte compared raises
/// `max(l_lcp, r_lcp)`, which never decreases, so total byte comparisons
/// telescope to O(|P| + log n).
fn mm_bound<V: IndexView + ?Sized>(
    view: &V,
    tree: &MidpointTree<'_>,
    pattern: &[u8],
    upper: bool,
    probe: &mut impl CompareProbe,
) -> usize {
    let n = view.n_suffixes() as i64;
    debug_assert_eq!(tree.len() as i64, n, "midpoint tree must cover every rank");
    let before =
        |c: Ordering| if upper { c != Ordering::Greater } else { c == Ordering::Less };
    let (mut lo, mut hi) = (-1i64, n);
    let (mut l_lcp, mut r_lcp) = (0usize, 0usize);
    while hi - lo > 1 {
        let m = lo + (hi - lo) / 2;
        let mu = m as usize;
        // Resolve cmp(suffix[m], pattern) from the bound LCPs when the
        // stored tree entry differs from the larger of them; fall back
        // to a text comparison resuming at the proven shared depth.
        // (Case analysis in docs/ARCHITECTURE.md, "LCP-accelerated
        // serving".)
        let decided = if l_lcp >= r_lcp {
            let t = tree.llcp(mu) as usize;
            if t > l_lcp {
                // suffix[m] diverges from the pattern exactly where
                // suffix[lo] does, in the same direction
                Some((true, l_lcp))
            } else if t < l_lcp {
                // suffix[m][t] > suffix[lo][t] = pattern[t]
                Some((false, t))
            } else {
                None
            }
        } else {
            let t = tree.rlcp(mu) as usize;
            if t > r_lcp {
                // suffix[m] diverges from the pattern exactly where
                // suffix[hi] does, in the same direction
                Some((false, r_lcp))
            } else if t < r_lcp {
                // suffix[m][t] < suffix[hi][t] = pattern[t]
                Some((true, t))
            } else {
                None
            }
        };
        let (is_before, m_lcp) = match decided {
            Some(d) => d,
            None => {
                let depth = l_lcp.max(r_lcp);
                let (ord, lcp) = cmp_from(view.suffix_at(mu), pattern, depth, probe);
                (before(ord), lcp)
            }
        };
        if is_before {
            lo = m;
            l_lcp = m_lcp;
        } else {
            hi = m;
            r_lcp = m_lcp;
        }
    }
    hi as usize
}

/// LCP-accelerated bounds: O(|P| + log n) byte comparisons per query.
fn mm_range<V: IndexView + ?Sized>(
    view: &V,
    tree: &MidpointTree<'_>,
    pattern: &[u8],
    probe: &mut impl CompareProbe,
) -> Range<usize> {
    if pattern.is_empty() {
        return 0..0;
    }
    let lo = mm_bound(view, tree, pattern, false, probe);
    let hi = mm_bound(view, tree, pattern, true, probe);
    lo..hi
}

/// A queryable suffix-array index: suffixes in sorted order, addressed
/// by rank. Implementors provide the three rank accessors; every search
/// (`sa_range`, `find`, `find_pairs`) is a provided method on top, so
/// all backends answer queries through exactly one code path.
pub trait IndexView {
    /// Number of suffixes (SA entries) in the index.
    fn n_suffixes(&self) -> usize;

    /// The suffix at sorted rank `rank`.
    fn suffix_at(&self, rank: usize) -> &[u8];

    /// The packed index (`crate::suffix::encode::pack_index`) at sorted
    /// rank `rank`.
    fn index_at(&self, rank: usize) -> i64;

    /// The Manber–Myers acceleration structure, when this backend
    /// carries one (a sealed-v2 TREE section, or [`EnhancedIndex`]).
    /// `None` — the default — routes queries to the plain bounds.
    fn midpoint_tree(&self) -> Option<MidpointTree<'_>> {
        None
    }

    /// The contiguous SA rank range whose suffixes start with `pattern`
    /// — the deduplicated bounds primitive every query calls. Empty
    /// patterns match nothing. Uses the LCP-accelerated O(|P| + log n)
    /// bounds when [`IndexView::midpoint_tree`] offers the structure,
    /// the classic O(|P| log n) bounds otherwise; both return identical
    /// ranges (`tests/lcp_oracle.rs` proves it on fuzzed patterns).
    fn sa_range(&self, pattern: &[u8]) -> Range<usize> {
        match self.midpoint_tree() {
            Some(tree) => mm_range(self, &tree, pattern, &mut NoProbe),
            None => plain_range(self, pattern, &mut NoProbe),
        }
    }

    /// [`IndexView::sa_range`] forced onto the classic bounds, ignoring
    /// any acceleration structure — the comparison baseline for the
    /// equivalence oracle and the serve bench.
    fn sa_range_plain(&self, pattern: &[u8]) -> Range<usize> {
        plain_range(self, pattern, &mut NoProbe)
    }

    /// [`IndexView::sa_range`] plus the number of byte comparisons it
    /// performed — the instrumented path the complexity test asserts on.
    fn sa_range_counted(&self, pattern: &[u8]) -> (Range<usize>, u64) {
        let mut probe = CountProbe::default();
        let range = match self.midpoint_tree() {
            Some(tree) => mm_range(self, &tree, pattern, &mut probe),
            None => plain_range(self, pattern, &mut probe),
        };
        (range, probe.0)
    }

    /// [`IndexView::sa_range_plain`] plus its byte-comparison count.
    fn sa_range_plain_counted(&self, pattern: &[u8]) -> (Range<usize>, u64) {
        let mut probe = CountProbe::default();
        let range = plain_range(self, pattern, &mut probe);
        (range, probe.0)
    }

    /// All occurrences of `pattern`, as sorted `(seq, offset)` pairs.
    /// The pattern must not span reads — reads are independent strings,
    /// exactly like alignment seeds.
    fn find(&self, pattern: &[u8]) -> Vec<(u64, usize)> {
        let mut out: Vec<(u64, usize)> = self
            .sa_range(pattern)
            .map(|r| unpack_index(self.index_at(r)))
            .collect();
        out.sort_unstable();
        out
    }

    /// Pair-end seed alignment over the joint suffix array of a two-file
    /// pair-end construction — the query half of the paper's Case 6
    /// claim ("complete the pair-end sequencing and alignment with two
    /// input files").
    ///
    /// `seed_fwd` is searched among forward mates and `seed_rev` (already
    /// in the reverse read's coordinates, i.e. the reverse complement of
    /// the fragment-strand seed) among reverse mates; hits are joined by
    /// the fragment id recovered from the pair-numbered sequence
    /// (`crate::suffix::reads::fragment_of`), and a joined pair survives
    /// only if it is compatible with a sequencing insert of at most
    /// `max_insert` bases. Geometry: a forward seed at offset `of`
    /// occupies fragment positions `[of, of + |seed_fwd|)` from the
    /// fragment's start; a reverse seed at offset `or` occupies the
    /// `|seed_rev|` bases ending `or` before the fragment's END. The
    /// smallest fragment consistent with both is therefore
    /// `max(of + |seed_fwd|, or + |seed_rev|)` — mates of short
    /// fragments may overlap (see
    /// `crate::suffix::reads::paired_reads_from_fragment`), so the two
    /// seed intervals are allowed to cover the same bases.
    ///
    /// Both seed lookups are `O(|seed| log n)` binary searches on the
    /// joint SA; the join is hash-by-fragment. Results are sorted by
    /// (fragment, forward offset, reverse offset).
    fn find_pairs(&self, seed_fwd: &[u8], seed_rev: &[u8], max_insert: usize) -> Vec<PairHit> {
        if seed_fwd.is_empty() || seed_rev.is_empty() {
            return Vec::new();
        }
        // hits on the correct mate only: a forward seed found in a
        // reverse read (or vice versa) is not a mate pairing
        let mate_hits = |seed: &[u8], want: Mate| -> HashMap<u64, Vec<usize>> {
            let mut by_fragment: HashMap<u64, Vec<usize>> = HashMap::new();
            for (seq, off) in self.find(seed) {
                let (fragment, mate) = fragment_of(seq);
                if mate == want {
                    by_fragment.entry(fragment).or_default().push(off);
                }
            }
            by_fragment
        };
        let fwd_hits = mate_hits(seed_fwd, Mate::Forward);
        let rev_hits = mate_hits(seed_rev, Mate::Reverse);

        let mut out = Vec::new();
        for (&fragment, f_offs) in &fwd_hits {
            let Some(r_offs) = rev_hits.get(&fragment) else { continue };
            for &of in f_offs {
                for &or in r_offs {
                    let min_fragment = (of + seed_fwd.len()).max(or + seed_rev.len());
                    if min_fragment <= max_insert {
                        out.push(PairHit {
                            fragment,
                            forward: (pair_seq(fragment, Mate::Forward), of),
                            reverse: (pair_seq(fragment, Mate::Reverse), or),
                        });
                    }
                }
            }
        }
        out.sort_by_key(|h| (h.fragment, h.forward.1, h.reverse.1));
        out
    }
}

/// [`IndexView`] over a single text and its suffix array — the classic
/// Manber–Myers setting. Packed indexes are plain text positions (seq 0
/// is implied, so `index_at` returns the raw position).
pub struct TextIndex<'a> {
    text: &'a [u8],
    sa: &'a [u32],
}

impl<'a> TextIndex<'a> {
    /// View `text` through its suffix array `sa`.
    pub fn new(text: &'a [u8], sa: &'a [u32]) -> Self {
        TextIndex { text, sa }
    }
}

impl IndexView for TextIndex<'_> {
    fn n_suffixes(&self) -> usize {
        self.sa.len()
    }

    fn suffix_at(&self, rank: usize) -> &[u8] {
        &self.text[self.sa[rank] as usize..]
    }

    fn index_at(&self, rank: usize) -> i64 {
        self.sa[rank] as i64
    }
}

/// [`IndexView`] over the *pipeline's* in-memory output: the globally
/// sorted packed suffix indexes plus the read map. The construction-side
/// twin of `crate::suffix::sealed::SealedIndex` — both answer every
/// query through the same provided methods.
pub struct CorpusIndex<'a> {
    order: &'a [i64],
    reads: &'a HashMap<u64, Vec<u8>>,
}

impl<'a> CorpusIndex<'a> {
    /// View a construction result: `order` is the globally sorted packed
    /// indexes, `reads` maps each sequence number to its codes.
    pub fn new(order: &'a [i64], reads: &'a HashMap<u64, Vec<u8>>) -> Self {
        CorpusIndex { order, reads }
    }
}

impl IndexView for CorpusIndex<'_> {
    fn n_suffixes(&self) -> usize {
        self.order.len()
    }

    fn suffix_at(&self, rank: usize) -> &[u8] {
        let (seq, off) = unpack_index(self.order[rank]);
        let r = &self.reads[&seq];
        &r[off.min(r.len())..]
    }

    fn index_at(&self, rank: usize) -> i64 {
        self.order[rank]
    }
}

/// Any [`IndexView`] upgraded with a freshly built midpoint tree, so
/// in-memory backends get the same O(|P| + log n) bounds a sealed-v2
/// artifact serves from disk. Construction is O(n · avg-lcp) — it reads
/// each adjacent suffix pair once — so build it when a view will answer
/// many queries, not one.
pub struct EnhancedIndex<V> {
    inner: V,
    tree: Vec<u8>,
}

impl<V: IndexView> EnhancedIndex<V> {
    /// Wrap `inner`, computing its adjacent-pair LCPs and midpoint tree.
    pub fn new(inner: V) -> Self {
        let n = inner.n_suffixes();
        let mut lcp = vec![0u32; n];
        for i in 1..n {
            let (a, b) = (inner.suffix_at(i - 1), inner.suffix_at(i));
            lcp[i] = a.iter().zip(b).take_while(|(x, y)| x == y).count() as u32;
        }
        let tree = build_midpoint_tree(&lcp);
        EnhancedIndex { inner, tree }
    }

    /// The wrapped view.
    pub fn inner(&self) -> &V {
        &self.inner
    }
}

impl<V: IndexView> IndexView for EnhancedIndex<V> {
    fn n_suffixes(&self) -> usize {
        self.inner.n_suffixes()
    }

    fn suffix_at(&self, rank: usize) -> &[u8] {
        self.inner.suffix_at(rank)
    }

    fn index_at(&self, rank: usize) -> i64 {
        self.inner.index_at(rank)
    }

    fn midpoint_tree(&self) -> Option<MidpointTree<'_>> {
        Some(MidpointTree::new(&self.tree))
    }
}

/// All occurrences (start positions) of `pattern` in `text`, via binary
/// search on the suffix array. Positions are returned sorted.
pub fn find_all(text: &[u8], sa: &[u32], pattern: &[u8]) -> Vec<u32> {
    let view = TextIndex::new(text, sa);
    let mut out: Vec<u32> = view.sa_range(pattern).map(|r| sa[r]).collect();
    out.sort_unstable();
    out
}

/// Convenience: build the SA and search in one call.
pub fn occurrences(text: &[u8], pattern: &[u8]) -> Vec<u32> {
    let sa = sa::sais(text);
    find_all(text, &sa, pattern)
}

/// Search the pipeline's in-memory output. Thin wrapper over
/// [`CorpusIndex`] + [`IndexView::find`].
pub fn find_in_corpus(
    order: &[i64],
    reads: &HashMap<u64, Vec<u8>>,
    pattern: &[u8],
) -> Vec<(u64, usize)> {
    CorpusIndex::new(order, reads).find(pattern)
}

/// One joined pair-end seed hit: both mates of a fragment carry their
/// seed, at compatible positions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PairHit {
    /// Fragment id both mates belong to.
    pub fragment: u64,
    /// `(seq, offset)` of the forward seed in the forward-mate read.
    pub forward: (u64, usize),
    /// `(seq, offset)` of the reverse seed in the reverse-mate read.
    pub reverse: (u64, usize),
}

/// Pair-end seed alignment over the pipeline's in-memory output. Thin
/// wrapper over [`CorpusIndex`] + [`IndexView::find_pairs`]; see the
/// trait method for the geometry.
pub fn find_pairs(
    order: &[i64],
    reads: &HashMap<u64, Vec<u8>>,
    seed_fwd: &[u8],
    seed_rev: &[u8],
    max_insert: usize,
) -> Vec<PairHit> {
    CorpusIndex::new(order, reads).find_pairs(seed_fwd, seed_rev, max_insert)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::codes_of;
    use crate::suffix::reads::Read;
    use crate::suffix::validate::{read_map, reference_order};

    #[test]
    fn finds_all_occurrences() {
        let text = b"GATTACAGATTACA";
        assert_eq!(occurrences(text, b"GATTACA"), vec![0, 7]);
        assert_eq!(occurrences(text, b"TA"), vec![3, 10]);
        assert_eq!(occurrences(text, b"X"), Vec::<u32>::new());
        assert_eq!(occurrences(text, b""), Vec::<u32>::new());
        assert_eq!(occurrences(text, b"GATTACAGATTACA"), vec![0]);
        assert_eq!(occurrences(text, b"GATTACAGATTACAX"), Vec::<u32>::new());
    }

    #[test]
    fn matches_naive_scan_on_random_text() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(12);
        let text: Vec<u8> = (0..2000).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
        let sa = sa::sais(&text);
        for plen in [1usize, 2, 4, 8] {
            for _ in 0..10 {
                let start = rng.below((text.len() - plen) as u64) as usize;
                let pattern = &text[start..start + plen];
                let got = find_all(&text, &sa, pattern);
                let want: Vec<u32> = (0..=text.len() - plen)
                    .filter(|&i| &text[i..i + plen] == pattern)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, want, "plen={plen}");
            }
        }
    }

    #[test]
    fn sa_range_brackets_exactly_the_matching_suffixes() {
        let reads = vec![
            Read::from_ascii(0, b"ACGTACGT"),
            Read::from_ascii(1, b"TTACGTT"),
        ];
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let view = CorpusIndex::new(&order, &map);
        let pat = codes_of(b"ACGT");
        let range = view.sa_range(&pat);
        assert_eq!(range.len(), 3);
        for r in range.clone() {
            assert!(view.suffix_at(r).starts_with(&pat), "rank {r} inside range");
        }
        for r in (0..view.n_suffixes()).filter(|r| !range.contains(r)) {
            assert!(!view.suffix_at(r).starts_with(&pat), "rank {r} outside range");
        }
        assert_eq!(view.sa_range(&[]), 0..0);
    }

    #[test]
    fn find_pairs_joins_planted_fragments() {
        use crate::suffix::reads::paired_reads_from_fragment;
        // 20 bp fragments, 8 bp reads from each end, pair-numbered seqs.
        // fragment 0 carries BOTH seeds: "ACGT" in its forward read
        // (offsets 0 and 4) and "AAAC" in its reverse read (offset 0).
        // fragments 1-3 are decoys missing one seed or carrying it on
        // the wrong mate.
        let frags: [&[u8]; 4] = [
            b"ACGTACGTAAACCCGGGTTT", // fwd ACGTACGT, rev revcomp(CCGGGTTT)=AAACCCGG
            b"ACGTGGGGGGGGTTTTGGGG", // fwd has ACGT, rev CCCCAAAA lacks AAAC
            b"GGGGGGGGGGGGCCGGGTTT", // rev has AAAC, fwd GGGGGGGG lacks ACGT
            b"AAACGGGGGGGGACGTACGT", // seeds present but each on the WRONG mate
        ];
        let mut reads = Vec::new();
        for (f, frag) in frags.iter().enumerate() {
            let (fwd, rev) = paired_reads_from_fragment(f as u64, &codes_of(frag), 8);
            reads.push(fwd);
            reads.push(rev);
        }
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let seed_fwd = codes_of(b"ACGT");
        let seed_rev = codes_of(b"AAAC");

        let hits = find_pairs(&order, &map, &seed_fwd, &seed_rev, 30);
        assert_eq!(
            hits,
            vec![
                PairHit { fragment: 0, forward: (0, 0), reverse: (1, 0) },
                PairHit { fragment: 0, forward: (0, 4), reverse: (1, 0) },
            ]
        );

        // insert window: min fragment = max(of+|sf|, or+|sr|) — 4 for
        // (of=0, or=0), 8 for (of=4, or=0) — prunes mechanically
        let tight = find_pairs(&order, &map, &seed_fwd, &seed_rev, 7);
        assert_eq!(tight.len(), 1);
        assert_eq!(tight[0].forward.1, 0);
        assert!(find_pairs(&order, &map, &seed_fwd, &seed_rev, 3).is_empty());
        // empty seeds never match
        assert!(find_pairs(&order, &map, &[], &seed_rev, 30).is_empty());
    }

    #[test]
    fn find_pairs_keeps_overlapping_mates_of_short_fragments() {
        use crate::suffix::reads::paired_reads_from_fragment;
        // fragment length == read length: the mates fully overlap, so
        // both seeds cover the SAME fragment bases. A formula that
        // forces the reverse seed downstream of the forward one would
        // wrongly prune this genuine pairing.
        let frag = codes_of(b"ACGTTGCA");
        let (fwd, rev) = paired_reads_from_fragment(0, &frag, frag.len());
        let reads = vec![fwd, rev];
        let order = reference_order(&reads);
        let map = read_map(&reads);
        // fwd seed = fragment tail (of=4); rev seed = the revcomp view
        // of that same tail, i.e. the rev read's head (or=0)
        let seed_fwd = codes_of(b"TGCA");
        let seed_rev = codes_of(b"TGCA"); // revcomp(TGCA) == TGCA
        let hits = find_pairs(&order, &map, &seed_fwd, &seed_rev, frag.len());
        assert!(
            hits.iter().any(|h| h.fragment == 0 && h.forward.1 == 4 && h.reverse.1 == 0),
            "overlapping-mate pairing wrongly pruned: {hits:?}"
        );
    }

    #[test]
    fn corpus_search_over_pipeline_output() {
        let reads = vec![
            Read::from_ascii(0, b"ACGTACGT"),
            Read::from_ascii(1, b"TTACGTT"),
            Read::from_ascii(5, b"GGGG"),
        ];
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let pat = codes_of(b"ACGT");
        let hits = find_in_corpus(&order, &map, &pat);
        assert_eq!(hits, vec![(0, 0), (0, 4), (1, 2)]);
        assert!(find_in_corpus(&order, &map, &codes_of(b"AAAA")).is_empty());
    }

    #[test]
    fn enhanced_index_matches_plain_bounds_on_fuzzed_patterns() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xACCE1);
        let mut reads = Vec::new();
        for seq in 0..40u64 {
            let len = 20 + rng.below(60) as usize;
            let codes: Vec<u8> = (0..len).map(|_| 1 + rng.below(4) as u8).collect();
            reads.push(Read::new(seq, codes));
        }
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let view = EnhancedIndex::new(CorpusIndex::new(&order, &map));
        assert!(view.midpoint_tree().is_some());
        for trial in 0..200 {
            let plen = rng.below(16) as usize; // 0 = empty pattern
            let pattern: Vec<u8> = if trial % 3 == 0 {
                // planted: slice of a real read, so non-trivial ranges occur
                let r = &reads[rng.below(reads.len() as u64) as usize].codes;
                let plen = plen.min(r.len() - 1);
                let at = rng.below((r.len() - plen) as u64) as usize;
                r[at..at + plen].to_vec()
            } else {
                (0..plen).map(|_| 1 + rng.below(4) as u8).collect()
            };
            let accel = view.sa_range(&pattern);
            let plain = view.sa_range_plain(&pattern);
            assert_eq!(accel, plain, "trial {trial} pattern {pattern:?}");
            for r in accel {
                assert!(view.suffix_at(r).starts_with(&pattern));
            }
        }
    }

    #[test]
    fn enhanced_index_on_degenerate_corpora() {
        // all-identical reads and single-suffix corpora stress the
        // sentinel bounds and the equal-key tie-break ordering
        for texts in [vec![b"AAAA".to_vec(); 5], vec![b"A".to_vec()], vec![b"".to_vec()]] {
            let reads: Vec<Read> = texts
                .iter()
                .enumerate()
                .map(|(i, t)| Read::from_ascii(i as u64, t))
                .collect();
            let order = reference_order(&reads);
            let map = read_map(&reads);
            let view = EnhancedIndex::new(CorpusIndex::new(&order, &map));
            for pat in [&b"A"[..], b"AA", b"AAAAA", b"T", b""] {
                let pat = codes_of(pat);
                assert_eq!(view.sa_range(&pat), view.sa_range_plain(&pat), "{texts:?} {pat:?}");
            }
        }
    }

    #[test]
    fn accelerated_bounds_compare_fewer_bytes_on_repetitive_text() {
        // A corpus of reads sharing a long common prefix forces the
        // plain bounds to re-walk that prefix at every midpoint: cost
        // ~|P| log n. The accelerated bounds resume at the proven depth:
        // cost ≤ |P| + iterations. This is the unit-level smoke check;
        // the calibrated bound lives in tests/lcp_oracle.rs.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let stem: Vec<u8> = (0..120).map(|_| 1 + rng.below(4) as u8).collect();
        let reads: Vec<Read> = (0..64u64)
            .map(|seq| {
                let mut codes = stem.clone();
                codes.extend((0..40).map(|_| 1 + rng.below(4) as u8));
                Read::new(seq, codes)
            })
            .collect();
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let view = EnhancedIndex::new(CorpusIndex::new(&order, &map));
        let pattern = &stem[..100];
        let (accel_range, accel_n) = view.sa_range_counted(pattern);
        let (plain_range, plain_n) = view.sa_range_plain_counted(pattern);
        assert_eq!(accel_range, plain_range);
        assert!(!accel_range.is_empty());
        let n = view.n_suffixes();
        let lg = (usize::BITS - n.leading_zeros()) as u64;
        // two bounds, each ≤ |P| + one compare byte per iteration
        assert!(
            accel_n <= 2 * (pattern.len() as u64 + lg + 2),
            "accelerated bound not O(|P| + log n): {accel_n} compares"
        );
        assert!(
            plain_n > 2 * accel_n,
            "plain path should re-compare the shared prefix: plain={plain_n} accel={accel_n}"
        );
    }
}

//! The sealed index artifact: construction output as a servable file.
//!
//! The paper stops at *constructing* the suffix array; serving it means
//! the construction output must outlive the job as a first-class
//! artifact. This module defines that artifact — a versioned,
//! checksummed, section-offset binary container (byte-level spec in
//! `docs/INDEX_FORMAT.md`) holding everything a query needs: the packed
//! read corpus, the suffix array of packed indexes, and per-input-file
//! read metadata for pair-end joins.
//!
//! Two halves:
//!
//! * [`SealWriter`] streams the artifact out during construction —
//!   `scheme::run_files_sealed` feeds it each input file's reads and
//!   then the reducer output stream, one index at a time, so sealing
//!   never materializes the order in memory.
//! * [`SealedIndex`] loads the artifact with zero parse work: one
//!   sequential read, one checksum pass, and a fixed-size footer that
//!   resolves every section by offset. No per-record decoding, no
//!   allocation per read or suffix — suffix bytes are served as slices
//!   into the single file buffer.
//!
//! Corruption is rejected at [`SealedIndex::open`] with descriptive
//! `io::Error`s — truncation, bad magic, unsupported version, checksum
//! mismatch, and section-table inconsistencies all fail the open, never
//! a later query.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::suffix::encode::unpack_index;
use crate::suffix::reads::Read;
use crate::suffix::search::IndexView;

/// File magic: the first eight bytes of every sealed index.
pub const MAGIC: [u8; 8] = *b"SAMRIDX1";
/// Container version this build writes and reads.
pub const VERSION: u32 = 1;
/// Fixed preamble length: magic + version + reserved word.
pub const PREAMBLE_LEN: usize = 16;
/// Fixed footer length: counts + section table + reserved word.
pub const FOOTER_LEN: usize = 96;
/// Trailing checksum length (FNV-1a 64 over everything before it).
pub const CHECKSUM_LEN: usize = 8;
/// Bytes per read-table entry: seq (8) + corpus offset (8) + length (4).
pub const READ_ENTRY_LEN: usize = 20;
/// Bytes per file-metadata entry: read count + min seq + max seq.
pub const FILE_ENTRY_LEN: usize = 24;
/// The smallest well-formed artifact (empty sections).
pub const MIN_FILE_LEN: usize = PREAMBLE_LEN + FOOTER_LEN + CHECKSUM_LEN;

/// FNV-1a 64 over `bytes` — the artifact's integrity checksum. Exposed
/// so tests and tools can re-stamp a patched file.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = fnv_step(h, b);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

#[inline]
fn fnv_step(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
}

/// Headline counts of a sealed artifact (the `STAT` reply's source).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SealedStats {
    /// Reads stored in the corpus section.
    pub n_reads: u64,
    /// Suffix-array entries (packed indexes).
    pub n_suffixes: u64,
    /// Input files the construction consumed.
    pub n_files: u64,
    /// Total corpus payload bytes (base codes).
    pub corpus_bytes: u64,
}

/// Per-input-file read metadata, kept so a served artifact still knows
/// its pair-end shape (two mate files → pair-numbered seq ranges).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FileMeta {
    /// Reads this input file contributed.
    pub n_reads: u64,
    /// Smallest sequence number in the file (0 when empty).
    pub min_seq: u64,
    /// Largest sequence number in the file (0 when empty).
    pub max_seq: u64,
}

// ---------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------

/// Streaming writer for one sealed index artifact.
///
/// Usage order is fixed and enforced: [`SealWriter::add_file`] once per
/// input file (streams the corpus section), then
/// [`SealWriter::push_index`] once per suffix in final order (streams
/// the SA section), then [`SealWriter::finish`] (writes the read table,
/// file metadata, footer, and checksum). The checksum is folded over
/// every byte as it is written, so sealing costs one pass and no
/// re-read.
pub struct SealWriter {
    w: BufWriter<File>,
    path: PathBuf,
    hash: u64,
    pos: u64,
    /// (seq, corpus-relative offset, length) per read; sorted at finish.
    entries: Vec<(u64, u64, u32)>,
    files: Vec<FileMeta>,
    /// End of the corpus section; `None` until the first index arrives.
    corpus_end: Option<u64>,
    n_suffixes: u64,
}

impl SealWriter {
    /// Create the artifact at `path` and write the preamble.
    pub fn create(path: &Path) -> io::Result<SealWriter> {
        let file = File::create(path).map_err(|e| {
            io::Error::new(e.kind(), format!("seal {}: {e}", path.display()))
        })?;
        let mut w = SealWriter {
            w: BufWriter::new(file),
            path: path.to_path_buf(),
            hash: FNV_OFFSET,
            pos: 0,
            entries: Vec::new(),
            files: Vec::new(),
            corpus_end: None,
            n_suffixes: 0,
        };
        w.put(&MAGIC)?;
        w.put(&VERSION.to_le_bytes())?;
        w.put(&0u32.to_le_bytes())?;
        Ok(w)
    }

    /// Write `bytes`, folding them into the running checksum.
    fn put(&mut self, bytes: &[u8]) -> io::Result<()> {
        for &b in bytes {
            self.hash = fnv_step(self.hash, b);
        }
        self.w.write_all(bytes)?;
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Stream one input file's reads into the corpus section and record
    /// its metadata. Must precede the first [`SealWriter::push_index`].
    pub fn add_file(&mut self, reads: &[Read]) -> io::Result<()> {
        if self.corpus_end.is_some() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: add_file after the SA stream began — input files \
                     must all be added before the first index",
                    self.path.display()
                ),
            ));
        }
        let mut meta = FileMeta { n_reads: reads.len() as u64, min_seq: 0, max_seq: 0 };
        for (i, r) in reads.iter().enumerate() {
            if i == 0 {
                meta.min_seq = r.seq;
                meta.max_seq = r.seq;
            } else {
                meta.min_seq = meta.min_seq.min(r.seq);
                meta.max_seq = meta.max_seq.max(r.seq);
            }
            let off = self.pos - PREAMBLE_LEN as u64;
            self.entries.push((r.seq, off, r.codes.len() as u32));
            self.put_read(r)?;
        }
        self.files.push(meta);
        Ok(())
    }

    fn put_read(&mut self, r: &Read) -> io::Result<()> {
        // borrow dance: fold + write without cloning the codes
        for &b in &r.codes {
            self.hash = fnv_step(self.hash, b);
        }
        self.w.write_all(&r.codes)?;
        self.pos += r.codes.len() as u64;
        Ok(())
    }

    /// Append one packed suffix index to the SA section, in final order.
    pub fn push_index(&mut self, index: i64) -> io::Result<()> {
        if self.corpus_end.is_none() {
            self.corpus_end = Some(self.pos);
        }
        self.n_suffixes += 1;
        self.put(&index.to_le_bytes())
    }

    /// Write the read table, file metadata, footer, and checksum, then
    /// flush. Fails if the SA stream disagrees with the corpus (a wiring
    /// bug upstream must not produce a plausible-looking artifact).
    pub fn finish(mut self) -> io::Result<()> {
        let corpus_end = self.corpus_end.unwrap_or(self.pos);
        let expect_suffixes: u64 =
            self.entries.iter().map(|&(_, _, len)| len as u64 + 1).sum();
        if self.n_suffixes != expect_suffixes {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "seal {}: {} indexes streamed but the corpus holds {} suffixes \
                     ({} reads)",
                    self.path.display(),
                    self.n_suffixes,
                    expect_suffixes,
                    self.entries.len()
                ),
            ));
        }
        let mut entries = std::mem::take(&mut self.entries);
        entries.sort_unstable_by_key(|&(seq, _, _)| seq);
        if entries.windows(2).any(|w| w[0].0 == w[1].0) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: duplicate sequence numbers across input files",
                    self.path.display()
                ),
            ));
        }

        let table_off = self.pos;
        for &(seq, off, len) in &entries {
            self.put(&seq.to_le_bytes())?;
            self.put(&off.to_le_bytes())?;
            self.put(&len.to_le_bytes())?;
        }
        let meta_off = self.pos;
        let files = std::mem::take(&mut self.files);
        for m in &files {
            self.put(&m.n_reads.to_le_bytes())?;
            self.put(&m.min_seq.to_le_bytes())?;
            self.put(&m.max_seq.to_le_bytes())?;
        }

        // footer: counts, then (offset, length) per section, then a
        // reserved word — fixed FOOTER_LEN bytes, parsed from the tail
        let sections: [(u64, u64); 4] = [
            (PREAMBLE_LEN as u64, corpus_end - PREAMBLE_LEN as u64),
            (corpus_end, table_off - corpus_end),
            (table_off, meta_off - table_off),
            (meta_off, self.pos - meta_off),
        ];
        let footer_start = self.pos;
        self.put(&(entries.len() as u64).to_le_bytes())?;
        self.put(&self.n_suffixes.to_le_bytes())?;
        self.put(&(files.len() as u64).to_le_bytes())?;
        for &(off, len) in &sections {
            self.put(&off.to_le_bytes())?;
            self.put(&len.to_le_bytes())?;
        }
        self.put(&0u64.to_le_bytes())?;
        debug_assert_eq!(self.pos - footer_start, FOOTER_LEN as u64);

        // trailing checksum covers every byte before it
        let h = self.hash;
        self.w.write_all(&h.to_le_bytes())?;
        self.w.flush()
    }
}

/// Seal an already-materialized construction result in one call: the
/// input files plus their final suffix order. The streaming path for
/// pipelines is `scheme::run_files_sealed`; this convenience exists for
/// tests, tools, and small corpora.
pub fn seal(path: &Path, files: &[&[Read]], order: &[i64]) -> io::Result<()> {
    let mut w = SealWriter::create(path)?;
    for f in files {
        w.add_file(f)?;
    }
    for &idx in order {
        w.push_index(idx)?;
    }
    w.finish()
}

// ---------------------------------------------------------------------
// loader
// ---------------------------------------------------------------------

/// A loaded, integrity-checked sealed index. Read-only and `Sync`: one
/// instance is shared across every server connection with no lock — the
/// serving tier's whole concurrency model is "immutable artifact, any
/// number of readers".
///
/// Loading is one sequential file read plus one checksum pass; sections
/// are resolved by offset from the fixed-size footer with zero parse
/// work (no per-record decode, no allocation per read or suffix).
pub struct SealedIndex {
    data: Vec<u8>,
    corpus: (usize, usize),
    sa: (usize, usize),
    table: (usize, usize),
    meta: (usize, usize),
    n_reads: usize,
    n_sa: usize,
    n_files: usize,
}

fn bad(path: &Path, msg: String) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("sealed index {}: {msg}", path.display()),
    )
}

#[inline]
fn le_u64(data: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(data[off..off + 8].try_into().expect("8-byte field"))
}

impl SealedIndex {
    /// Load and verify the artifact at `path`. Every corruption mode —
    /// truncation, wrong magic, unsupported version, checksum mismatch,
    /// inconsistent section table — is a descriptive `io::Error`, never
    /// a panic and never a silently wrong answer later.
    pub fn open(path: &Path) -> io::Result<SealedIndex> {
        let data = std::fs::read(path).map_err(|e| {
            io::Error::new(e.kind(), format!("sealed index {}: {e}", path.display()))
        })?;
        if data.len() < MIN_FILE_LEN {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!(
                    "sealed index {}: {} bytes is shorter than the minimal \
                     container ({MIN_FILE_LEN} bytes) — truncated or not a \
                     sealed index",
                    path.display(),
                    data.len()
                ),
            ));
        }
        if data[..8] != MAGIC {
            return Err(bad(
                path,
                format!("bad magic {:?} (expected {:?})", &data[..8], &MAGIC[..]),
            ));
        }
        let version = u32::from_le_bytes(data[8..12].try_into().expect("4-byte version"));
        if version != VERSION {
            return Err(bad(
                path,
                format!("unsupported version {version} (this build reads version {VERSION})"),
            ));
        }
        let body_len = data.len() - CHECKSUM_LEN;
        let stored = le_u64(&data, body_len);
        let computed = checksum(&data[..body_len]);
        if stored != computed {
            return Err(bad(
                path,
                format!(
                    "checksum mismatch (stored {stored:#018x}, computed \
                     {computed:#018x}) — the artifact is corrupted or truncated"
                ),
            ));
        }

        // footer: counts + section table, all offsets absolute
        let f = body_len - FOOTER_LEN;
        let n_reads = le_u64(&data, f) as usize;
        let n_sa = le_u64(&data, f + 8) as usize;
        let n_files = le_u64(&data, f + 16) as usize;
        let section = |i: usize| -> (u64, u64) {
            (le_u64(&data, f + 24 + i * 16), le_u64(&data, f + 32 + i * 16))
        };
        let names = ["corpus", "SA", "read-table", "file-metadata"];
        let mut resolved = [(0usize, 0usize); 4];
        for i in 0..4 {
            let (off, len) = section(i);
            let end = off.checked_add(len).ok_or_else(|| {
                bad(path, format!("{} section offset overflows", names[i]))
            })?;
            if off < PREAMBLE_LEN as u64 || end > f as u64 {
                return Err(bad(
                    path,
                    format!(
                        "{} section [{off}, {end}) falls outside the file body \
                         [{PREAMBLE_LEN}, {f})",
                        names[i]
                    ),
                ));
            }
            resolved[i] = (off as usize, len as usize);
        }
        let [corpus, sa, table, meta] = resolved;
        let declared = |what: &str, len: usize, count: usize, each: usize| -> io::Result<()> {
            if len != count * each {
                return Err(bad(
                    path,
                    format!(
                        "{what} section is {len} bytes but the footer declares \
                         {count} entries ({} bytes expected)",
                        count * each
                    ),
                ));
            }
            Ok(())
        };
        declared("SA", sa.1, n_sa, 8)?;
        declared("read-table", table.1, n_reads, READ_ENTRY_LEN)?;
        declared("file-metadata", meta.1, n_files, FILE_ENTRY_LEN)?;

        let idx = SealedIndex {
            data,
            corpus,
            sa,
            table,
            meta,
            n_reads,
            n_sa,
            n_files,
        };
        // read-table scan: strictly increasing seqs, in-bounds corpus
        // ranges, and totals consistent with the corpus and SA sections.
        // O(n_reads) over fixed-width entries — metadata validation, not
        // record parsing: nothing is decoded, copied, or allocated.
        let mut corpus_used = 0u64;
        let mut suffix_total = 0u64;
        let mut prev: Option<u64> = None;
        for i in 0..idx.n_reads {
            let (seq, off, len) = idx.table_entry(i);
            if prev.is_some_and(|p| p >= seq) {
                return Err(bad(
                    path,
                    format!("read table not strictly seq-sorted at entry {i} (seq {seq})"),
                ));
            }
            prev = Some(seq);
            if off as usize + len as usize > idx.corpus.1 {
                return Err(bad(
                    path,
                    format!(
                        "read {seq} spans corpus bytes [{off}, {}) but the corpus \
                         section holds {}",
                        off + len as u64,
                        idx.corpus.1
                    ),
                ));
            }
            corpus_used += len as u64;
            suffix_total += len as u64 + 1;
        }
        if corpus_used != idx.corpus.1 as u64 {
            return Err(bad(
                path,
                format!(
                    "read table covers {corpus_used} corpus bytes but the corpus \
                     section holds {}",
                    idx.corpus.1
                ),
            ));
        }
        if suffix_total != idx.n_sa as u64 {
            return Err(bad(
                path,
                format!(
                    "corpus holds {suffix_total} suffixes but the SA section \
                     declares {}",
                    idx.n_sa
                ),
            ));
        }
        Ok(idx)
    }

    /// Headline counts.
    pub fn stats(&self) -> SealedStats {
        SealedStats {
            n_reads: self.n_reads as u64,
            n_suffixes: self.n_sa as u64,
            n_files: self.n_files as u64,
            corpus_bytes: self.corpus.1 as u64,
        }
    }

    /// Metadata of input file `i` (in construction order).
    pub fn file_meta(&self, i: usize) -> FileMeta {
        assert!(i < self.n_files, "file {i} of {}", self.n_files);
        let off = self.meta.0 + i * FILE_ENTRY_LEN;
        FileMeta {
            n_reads: le_u64(&self.data, off),
            min_seq: le_u64(&self.data, off + 8),
            max_seq: le_u64(&self.data, off + 16),
        }
    }

    #[inline]
    fn table_entry(&self, i: usize) -> (u64, u64, u32) {
        let off = self.table.0 + i * READ_ENTRY_LEN;
        (
            le_u64(&self.data, off),
            le_u64(&self.data, off + 8),
            u32::from_le_bytes(
                self.data[off + 16..off + 20].try_into().expect("4-byte len"),
            ),
        )
    }

    /// The SA entry at `rank` (packed suffix index).
    #[inline]
    pub fn sa_at(&self, rank: usize) -> i64 {
        assert!(rank < self.n_sa, "SA rank {rank} of {}", self.n_sa);
        i64::from_le_bytes(
            self.data[self.sa.0 + rank * 8..self.sa.0 + rank * 8 + 8]
                .try_into()
                .expect("8-byte SA entry"),
        )
    }

    /// The stored read with sequence number `seq`, as a slice into the
    /// file buffer (no copy).
    pub fn read_of(&self, seq: u64) -> Option<&[u8]> {
        let mut lo = 0usize;
        let mut hi = self.n_reads;
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let (s, off, len) = self.table_entry(mid);
            match s.cmp(&seq) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => {
                    let start = self.corpus.0 + off as usize;
                    return Some(&self.data[start..start + len as usize]);
                }
            }
        }
        None
    }

    /// The suffix a packed index denotes, as a slice into the file
    /// buffer — same offset clamping as the in-memory corpus search.
    pub fn suffix(&self, index: i64) -> Option<&[u8]> {
        if index < 0 {
            return None;
        }
        let (seq, off) = unpack_index(index);
        let r = self.read_of(seq)?;
        Some(&r[off.min(r.len())..])
    }
}

impl IndexView for SealedIndex {
    fn n_suffixes(&self) -> usize {
        self.n_sa
    }

    fn suffix_at(&self, rank: usize) -> &[u8] {
        self.suffix(self.sa_at(rank))
            .expect("sealed SA entry resolves to a stored read (checksum-verified artifact)")
    }

    fn index_at(&self, rank: usize) -> i64 {
        self.sa_at(rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::codes_of;
    use crate::suffix::validate::{read_map, reference_order};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("samr-sealed-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn corpus() -> Vec<Read> {
        vec![
            Read::from_ascii(0, b"ACGTACGT"),
            Read::from_ascii(1, b"TTACGTT"),
            Read::from_ascii(5, b"GGGG"),
        ]
    }

    #[test]
    fn roundtrips_reads_order_and_metadata() {
        let reads = corpus();
        let order = reference_order(&reads);
        let path = tmp("roundtrip.samr");
        seal(&path, &[&reads], &order).unwrap();

        let idx = SealedIndex::open(&path).unwrap();
        let st = idx.stats();
        assert_eq!(st.n_reads, 3);
        assert_eq!(st.n_suffixes, order.len() as u64);
        assert_eq!(st.n_files, 1);
        assert_eq!(st.corpus_bytes, 8 + 7 + 4);
        assert_eq!(
            idx.file_meta(0),
            FileMeta { n_reads: 3, min_seq: 0, max_seq: 5 }
        );
        for (rank, &want) in order.iter().enumerate() {
            assert_eq!(idx.sa_at(rank), want);
        }
        for r in &reads {
            assert_eq!(idx.read_of(r.seq), Some(&r.codes[..]));
        }
        assert_eq!(idx.read_of(2), None);
        assert_eq!(idx.suffix(5), Some(&codes_of(b"CGT")[..])); // seq 0, offset 5
        assert_eq!(idx.suffix(-3), None);
    }

    #[test]
    fn sealed_view_answers_match_in_memory_view() {
        let reads = corpus();
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let path = tmp("equiv.samr");
        seal(&path, &[&reads], &order).unwrap();
        let idx = SealedIndex::open(&path).unwrap();
        let mem = crate::suffix::search::CorpusIndex::new(&order, &map);
        for pat in [&b"ACGT"[..], b"T", b"GGGG", b"AAAA", b""] {
            let codes = codes_of(pat);
            assert_eq!(idx.find(&codes), mem.find(&codes), "pattern {pat:?}");
            assert_eq!(idx.sa_range(&codes), mem.sa_range(&codes));
        }
    }

    #[test]
    fn writer_rejects_misuse() {
        let reads = corpus();
        let path = tmp("misuse.samr");
        // add_file after the SA stream began
        let mut w = SealWriter::create(&path).unwrap();
        w.add_file(&reads).unwrap();
        w.push_index(0).unwrap();
        let err = w.add_file(&reads).unwrap_err();
        assert!(err.to_string().contains("add_file"), "{err}");
        // suffix-count mismatch at finish
        let mut w = SealWriter::create(&path).unwrap();
        w.add_file(&reads).unwrap();
        w.push_index(0).unwrap();
        let err = w.finish().unwrap_err();
        assert!(err.to_string().contains("suffixes"), "{err}");
        // duplicate seqs across files
        let mut w = SealWriter::create(&path).unwrap();
        w.add_file(&reads).unwrap();
        w.add_file(&reads).unwrap();
        for _ in 0..2 * reads.iter().map(Read::suffix_count).sum::<usize>() {
            w.push_index(0).unwrap();
        }
        let err = w.finish().unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn empty_artifact_is_well_formed() {
        let path = tmp("empty.samr");
        seal(&path, &[], &[]).unwrap();
        let idx = SealedIndex::open(&path).unwrap();
        assert_eq!(idx.stats().n_suffixes, 0);
        assert!(idx.find(&codes_of(b"ACGT")).is_empty());
    }
}

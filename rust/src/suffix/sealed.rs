//! The sealed index artifact: construction output as a servable file.
//!
//! The paper stops at *constructing* the suffix array; serving it means
//! the construction output must outlive the job as a first-class
//! artifact. This module defines that artifact — a versioned,
//! checksummed, section-offset binary container (byte-level spec in
//! `docs/INDEX_FORMAT.md`) holding everything a query needs: the packed
//! read corpus, the suffix array of packed indexes, and per-input-file
//! read metadata for pair-end joins.
//!
//! Two halves:
//!
//! * [`SealWriter`] streams the artifact out during construction —
//!   `scheme::run_files_sealed` feeds it each input file's reads and
//!   then the reducer output stream, one index at a time, so sealing
//!   never materializes the order in memory. (The v2 auxiliary
//!   sections — LCP, midpoint tree, BWT — are buffered until `finish`
//!   because they live *after* the SA on disk and the checksum is
//!   folded in one pass; that costs ~13 bytes per suffix, a fraction of
//!   the 8-byte SA entries the writer deliberately does *not* buffer.)
//! * [`SealedIndex`] loads the artifact with zero parse work: a
//!   footer-first preflight (preamble + tail only, so corrupt or
//!   wrong-format multi-GB files fail before any bulk I/O), then the
//!   body through a pluggable backend — default heap read, optional
//!   zero-copy `mmap` (feature-gated) — one checksum pass, and a
//!   fixed-size footer that resolves every section by offset. No
//!   per-record decoding, no allocation per read or suffix — suffix
//!   bytes are served as slices into the single file buffer.
//!
//! Version 2 appends three optional sections (adjacent-pair LCP,
//! (llcp, rlcp) midpoint tree, BWT) addressed by an extension footer;
//! version 1 artifacts still open and serve through the plain search
//! path. Corruption is rejected at [`SealedIndex::open`] with
//! descriptive `io::Error`s — truncation, bad magic, unsupported
//! version, checksum mismatch, and section-table inconsistencies all
//! fail the open, never a later query.

use std::fs::File;
use std::io::{self, BufWriter, Read as _, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::suffix::encode::unpack_index;
use crate::suffix::lcp::{build_midpoint_tree, MidpointTree, TREE_ENTRY_LEN};
use crate::suffix::reads::Read;
use crate::suffix::search::IndexView;

/// File magic: the first eight bytes of every sealed index (all
/// versions — the version word, not the magic, distinguishes them).
pub const MAGIC: [u8; 8] = *b"SAMRIDX1";
/// Container version this build writes. Reads this and [`VERSION_V1`].
pub const VERSION: u32 = 2;
/// The original container version (no auxiliary sections).
pub const VERSION_V1: u32 = 1;
/// Fixed preamble length: magic + version + reserved word.
pub const PREAMBLE_LEN: usize = 16;
/// Fixed footer length: counts + section table + reserved word.
pub const FOOTER_LEN: usize = 96;
/// v2 extension footer length: (offset, length) for LCP, TREE, BWT.
pub const EXT_LEN: usize = 48;
/// Trailing checksum length (FNV-1a 64 over everything before it).
pub const CHECKSUM_LEN: usize = 8;
/// Bytes per read-table entry: seq (8) + corpus offset (8) + length (4).
pub const READ_ENTRY_LEN: usize = 20;
/// Bytes per file-metadata entry: read count + min seq + max seq.
pub const FILE_ENTRY_LEN: usize = 24;
/// Bytes per LCP-section entry (u32 LE).
pub const LCP_ENTRY_LEN: usize = 4;
/// BWT code for "suffix starts at offset 0": the preceding character is
/// the *previous* read's terminator, which belongs to no read — one
/// past the largest real code (`$ACGT` = 0..=4).
pub const BWT_TERMINATOR: u8 = 5;
/// The smallest well-formed v1 artifact (empty sections); v2 adds
/// [`EXT_LEN`]. Anything shorter cannot hold a preamble + footer and is
/// rejected before any body I/O.
pub const MIN_FILE_LEN: usize = PREAMBLE_LEN + FOOTER_LEN + CHECKSUM_LEN;

/// FNV-1a 64 over `bytes` — the artifact's integrity checksum. Exposed
/// so tests and tools can re-stamp a patched file.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = fnv_step(h, b);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

#[inline]
fn fnv_step(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
}

/// Headline counts of a sealed artifact (the `STAT` reply's source).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SealedStats {
    /// Reads stored in the corpus section.
    pub n_reads: u64,
    /// Suffix-array entries (packed indexes).
    pub n_suffixes: u64,
    /// Input files the construction consumed.
    pub n_files: u64,
    /// Total corpus payload bytes (base codes).
    pub corpus_bytes: u64,
    /// Whole artifact size on disk, checksum included.
    pub file_bytes: u64,
    /// True when the artifact carries a (non-empty) LCP section.
    pub has_lcp: bool,
    /// True when the artifact carries the midpoint tree (and therefore
    /// serves O(|P| + log n) accelerated queries).
    pub has_tree: bool,
    /// True when the artifact carries a BWT section.
    pub has_bwt: bool,
}

/// Per-input-file read metadata, kept so a served artifact still knows
/// its pair-end shape (two mate files → pair-numbered seq ranges).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FileMeta {
    /// Reads this input file contributed.
    pub n_reads: u64,
    /// Smallest sequence number in the file (0 when empty).
    pub min_seq: u64,
    /// Largest sequence number in the file (0 when empty).
    pub max_seq: u64,
}

// ---------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------

/// The v2 auxiliary payload, accumulated per suffix and written as the
/// LCP / TREE / BWT sections at finish.
struct AuxBuf {
    lcp: Vec<u32>,
    bwt: Vec<u8>,
}

/// Streaming writer for one sealed index artifact.
///
/// Usage order is fixed and enforced: [`SealWriter::add_file`] once per
/// input file (streams the corpus section), then
/// [`SealWriter::push_index`] (plain) *or* [`SealWriter::push_entry`]
/// (with per-suffix LCP + BWT, [`SealWriter::create_with_aux`] only)
/// once per suffix in final order (streams the SA section), then
/// [`SealWriter::finish`] (writes the auxiliary sections, read table,
/// file metadata, footer, and checksum). The checksum is folded over
/// every byte as it is written, so sealing costs one pass and no
/// re-read. The SA section is never buffered; the auxiliary payload is
/// (~13 B/suffix) because it lands after the SA in the one-pass layout.
pub struct SealWriter {
    w: BufWriter<File>,
    path: PathBuf,
    version: u32,
    hash: u64,
    pos: u64,
    /// (seq, corpus-relative offset, length) per read; sorted at finish.
    entries: Vec<(u64, u64, u32)>,
    files: Vec<FileMeta>,
    /// End of the corpus section; `None` until the first index arrives.
    corpus_end: Option<u64>,
    n_suffixes: u64,
    aux: Option<AuxBuf>,
}

impl SealWriter {
    /// Create a v2 artifact at `path` *without* auxiliary sections
    /// (zero-length LCP/TREE/BWT — queries take the plain path). Feed
    /// the SA with [`SealWriter::push_index`].
    pub fn create(path: &Path) -> io::Result<SealWriter> {
        SealWriter::create_impl(path, VERSION, None)
    }

    /// Create a v2 artifact at `path` with LCP, midpoint-tree, and BWT
    /// sections. Feed the SA with [`SealWriter::push_entry`].
    pub fn create_with_aux(path: &Path) -> io::Result<SealWriter> {
        SealWriter::create_impl(path, VERSION, Some(AuxBuf { lcp: Vec::new(), bwt: Vec::new() }))
    }

    /// Create a version-1 artifact (no extension footer, no auxiliary
    /// sections). Kept as a *writer* so back-compat coverage needs no
    /// committed binary fixture; production sealing is v2.
    pub fn create_v1(path: &Path) -> io::Result<SealWriter> {
        SealWriter::create_impl(path, VERSION_V1, None)
    }

    fn create_impl(path: &Path, version: u32, aux: Option<AuxBuf>) -> io::Result<SealWriter> {
        let file = File::create(path).map_err(|e| {
            io::Error::new(e.kind(), format!("seal {}: {e}", path.display()))
        })?;
        let mut w = SealWriter {
            w: BufWriter::new(file),
            path: path.to_path_buf(),
            version,
            hash: FNV_OFFSET,
            pos: 0,
            entries: Vec::new(),
            files: Vec::new(),
            corpus_end: None,
            n_suffixes: 0,
            aux,
        };
        w.put(&MAGIC)?;
        w.put(&version.to_le_bytes())?;
        w.put(&0u32.to_le_bytes())?;
        Ok(w)
    }

    /// Write `bytes`, folding them into the running checksum.
    fn put(&mut self, bytes: &[u8]) -> io::Result<()> {
        for &b in bytes {
            self.hash = fnv_step(self.hash, b);
        }
        self.w.write_all(bytes)?;
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Stream one input file's reads into the corpus section and record
    /// its metadata. Must precede the first [`SealWriter::push_index`].
    pub fn add_file(&mut self, reads: &[Read]) -> io::Result<()> {
        if self.corpus_end.is_some() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: add_file after the SA stream began — input files \
                     must all be added before the first index",
                    self.path.display()
                ),
            ));
        }
        let mut meta = FileMeta { n_reads: reads.len() as u64, min_seq: 0, max_seq: 0 };
        for (i, r) in reads.iter().enumerate() {
            if i == 0 {
                meta.min_seq = r.seq;
                meta.max_seq = r.seq;
            } else {
                meta.min_seq = meta.min_seq.min(r.seq);
                meta.max_seq = meta.max_seq.max(r.seq);
            }
            let off = self.pos - PREAMBLE_LEN as u64;
            self.entries.push((r.seq, off, r.codes.len() as u32));
            self.put_read(r)?;
        }
        self.files.push(meta);
        Ok(())
    }

    fn put_read(&mut self, r: &Read) -> io::Result<()> {
        // borrow dance: fold + write without cloning the codes
        for &b in &r.codes {
            self.hash = fnv_step(self.hash, b);
        }
        self.w.write_all(&r.codes)?;
        self.pos += r.codes.len() as u64;
        Ok(())
    }

    /// Append one packed suffix index to the SA section, in final order.
    /// Plain writers only — an aux writer must not silently drop its
    /// per-suffix payload.
    pub fn push_index(&mut self, index: i64) -> io::Result<()> {
        if self.aux.is_some() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: push_index on a writer created with aux sections — \
                     use push_entry(index, lcp, bwt)",
                    self.path.display()
                ),
            ));
        }
        self.push_index_raw(index)
    }

    /// Append one suffix with its auxiliary payload: `lcp` = common
    /// prefix bytes with the *previous* suffix in order (0 for the
    /// first), `bwt` = code of the character preceding the suffix in
    /// its read ([`BWT_TERMINATOR`] for offset-0 suffixes). Aux writers
    /// ([`SealWriter::create_with_aux`]) only.
    pub fn push_entry(&mut self, index: i64, lcp: u32, bwt: u8) -> io::Result<()> {
        if self.aux.is_none() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: push_entry on a writer without aux sections — \
                     use create_with_aux, or push_index",
                    self.path.display()
                ),
            ));
        }
        if self.n_suffixes == 0 && lcp != 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: first suffix carries lcp {lcp}, must be 0 — \
                     upstream boundary stitching is wired wrong",
                    self.path.display()
                ),
            ));
        }
        if bwt > BWT_TERMINATOR {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: BWT code {bwt} out of range (max {BWT_TERMINATOR})",
                    self.path.display()
                ),
            ));
        }
        self.push_index_raw(index)?;
        let aux = self.aux.as_mut().expect("checked above");
        aux.lcp.push(lcp);
        aux.bwt.push(bwt);
        Ok(())
    }

    fn push_index_raw(&mut self, index: i64) -> io::Result<()> {
        if self.corpus_end.is_none() {
            self.corpus_end = Some(self.pos);
        }
        self.n_suffixes += 1;
        self.put(&index.to_le_bytes())
    }

    /// Write the read table, file metadata, footer, and checksum, then
    /// flush. Fails if the SA stream disagrees with the corpus (a wiring
    /// bug upstream must not produce a plausible-looking artifact).
    pub fn finish(mut self) -> io::Result<()> {
        let corpus_end = self.corpus_end.unwrap_or(self.pos);
        let expect_suffixes: u64 =
            self.entries.iter().map(|&(_, _, len)| len as u64 + 1).sum();
        if self.n_suffixes != expect_suffixes {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "seal {}: {} indexes streamed but the corpus holds {} suffixes \
                     ({} reads)",
                    self.path.display(),
                    self.n_suffixes,
                    expect_suffixes,
                    self.entries.len()
                ),
            ));
        }
        let mut entries = std::mem::take(&mut self.entries);
        entries.sort_unstable_by_key(|&(seq, _, _)| seq);
        if entries.windows(2).any(|w| w[0].0 == w[1].0) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "seal {}: duplicate sequence numbers across input files",
                    self.path.display()
                ),
            ));
        }

        let table_off = self.pos;
        for &(seq, off, len) in &entries {
            self.put(&seq.to_le_bytes())?;
            self.put(&off.to_le_bytes())?;
            self.put(&len.to_le_bytes())?;
        }
        let meta_off = self.pos;
        let files = std::mem::take(&mut self.files);
        for m in &files {
            self.put(&m.n_reads.to_le_bytes())?;
            self.put(&m.min_seq.to_le_bytes())?;
            self.put(&m.max_seq.to_le_bytes())?;
        }
        let meta_end = self.pos;

        // v2: the auxiliary sections, then the extension footer that
        // addresses them (zero lengths when the writer carried no aux)
        if self.version >= VERSION {
            let aux = self.aux.take();
            let lcp_off = self.pos;
            if let Some(a) = &aux {
                debug_assert_eq!(a.lcp.len() as u64, self.n_suffixes);
                for &v in &a.lcp {
                    self.put(&v.to_le_bytes())?;
                }
            }
            let tree_off = self.pos;
            if let Some(a) = &aux {
                self.put(&build_midpoint_tree(&a.lcp))?;
            }
            let bwt_off = self.pos;
            if let Some(a) = &aux {
                debug_assert_eq!(a.bwt.len() as u64, self.n_suffixes);
                self.put(&a.bwt)?;
            }
            let ext_start = self.pos;
            let ext: [(u64, u64); 3] = [
                (lcp_off, tree_off - lcp_off),
                (tree_off, bwt_off - tree_off),
                (bwt_off, ext_start - bwt_off),
            ];
            for &(off, len) in &ext {
                self.put(&off.to_le_bytes())?;
                self.put(&len.to_le_bytes())?;
            }
            debug_assert_eq!(self.pos - ext_start, EXT_LEN as u64);
        }

        // main footer: counts, then (offset, length) per core section,
        // then the reserved word — fixed FOOTER_LEN bytes, parsed from
        // the tail. The reserved word is the extension-footer length
        // (0 for v1, EXT_LEN for v2), which is how the loader finds the
        // extension without guessing.
        let sections: [(u64, u64); 4] = [
            (PREAMBLE_LEN as u64, corpus_end - PREAMBLE_LEN as u64),
            (corpus_end, table_off - corpus_end),
            (table_off, meta_off - table_off),
            (meta_off, meta_end - meta_off),
        ];
        let footer_start = self.pos;
        self.put(&(entries.len() as u64).to_le_bytes())?;
        self.put(&self.n_suffixes.to_le_bytes())?;
        self.put(&(files.len() as u64).to_le_bytes())?;
        for &(off, len) in &sections {
            self.put(&off.to_le_bytes())?;
            self.put(&len.to_le_bytes())?;
        }
        let reserved = if self.version >= VERSION { EXT_LEN as u64 } else { 0 };
        self.put(&reserved.to_le_bytes())?;
        debug_assert_eq!(self.pos - footer_start, FOOTER_LEN as u64);

        // trailing checksum covers every byte before it
        let h = self.hash;
        self.w.write_all(&h.to_le_bytes())?;
        self.w.flush()
    }
}

/// Resolve a packed index to its suffix slice over `files`' reads.
fn suffix_in<'a>(
    reads: &std::collections::HashMap<u64, &'a [u8]>,
    index: i64,
) -> &'a [u8] {
    let (seq, off) = unpack_index(index);
    let r = reads.get(&seq).expect("order references a stored read");
    &r[off.min(r.len())..]
}

/// Seal an already-materialized construction result in one call: the
/// input files plus their final suffix order, with the v2 auxiliary
/// sections computed naively (adjacent-pair LCP scan + preceding-char
/// BWT). The streaming path for pipelines is
/// `scheme::run_files_sealed`, which gets the LCPs from the reducers;
/// this convenience exists for tests, tools, and small corpora.
pub fn seal(path: &Path, files: &[&[Read]], order: &[i64]) -> io::Result<()> {
    let mut reads = std::collections::HashMap::new();
    for f in files {
        for r in *f {
            reads.insert(r.seq, &r.codes[..]);
        }
    }
    let mut w = SealWriter::create_with_aux(path)?;
    for f in files {
        w.add_file(f)?;
    }
    for (i, &idx) in order.iter().enumerate() {
        let lcp = if i == 0 {
            0
        } else {
            let (a, b) = (suffix_in(&reads, order[i - 1]), suffix_in(&reads, idx));
            a.iter().zip(b).take_while(|(x, y)| x == y).count() as u32
        };
        let (seq, off) = unpack_index(idx);
        let bwt = if off == 0 { BWT_TERMINATOR } else { reads[&seq][off - 1] };
        w.push_entry(idx, lcp, bwt)?;
    }
    w.finish()
}

/// [`seal`] without the auxiliary sections: a v2 artifact whose
/// LCP/TREE/BWT lengths are zero, serving through the plain search
/// path. Exercises the degrade case the format promises.
pub fn seal_plain(path: &Path, files: &[&[Read]], order: &[i64]) -> io::Result<()> {
    let mut w = SealWriter::create(path)?;
    for f in files {
        w.add_file(f)?;
    }
    for &idx in order {
        w.push_index(idx)?;
    }
    w.finish()
}

/// [`seal`] as a version-1 artifact — the back-compat writer that keeps
/// old-format coverage alive without a committed binary fixture.
pub fn seal_v1(path: &Path, files: &[&[Read]], order: &[i64]) -> io::Result<()> {
    let mut w = SealWriter::create_v1(path)?;
    for f in files {
        w.add_file(f)?;
    }
    for &idx in order {
        w.push_index(idx)?;
    }
    w.finish()
}

// ---------------------------------------------------------------------
// loader
// ---------------------------------------------------------------------

/// How [`SealedIndex::open_with`] gets the artifact body into memory.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// One sequential read into a heap buffer (the default — works
    /// everywhere, pays O(file) copy at open).
    #[default]
    Heap,
    /// Zero-copy `mmap(2)` of the artifact: open cost stops being
    /// O(file) heap traffic; pages fault in as queries touch them.
    /// Requires the `mmap` cargo feature.
    #[cfg(feature = "mmap")]
    Mmap,
}

/// Knobs for [`SealedIndex::open_with`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpenOptions {
    /// Body backend; [`Backend::Heap`] by default.
    pub backend: Backend,
    /// Verify the trailing FNV-1a 64 checksum (default `true`). Opting
    /// out trades integrity for a truly O(1)-touch mmap open; the
    /// structural preflight and section validation still run.
    pub verify_checksum: bool,
}

impl Default for OpenOptions {
    fn default() -> Self {
        OpenOptions { backend: Backend::Heap, verify_checksum: true }
    }
}

/// The loaded artifact body behind either backend.
enum IndexData {
    Heap(Vec<u8>),
    #[cfg(feature = "mmap")]
    Mapped(mmap_backend::Mapping),
}

impl IndexData {
    #[inline]
    fn bytes(&self) -> &[u8] {
        match self {
            IndexData::Heap(v) => v,
            #[cfg(feature = "mmap")]
            IndexData::Mapped(m) => m.bytes(),
        }
    }
}

/// Minimal read-only `mmap(2)` binding. Hand-rolled because this crate
/// is dependency-free by policy (no `memmap2` in the build image);
/// gated behind the `mmap` feature so default builds stay pure safe
/// Rust.
#[cfg(feature = "mmap")]
mod mmap_backend {
    use std::ffi::c_void;
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    /// A private read-only mapping of a whole file, unmapped on drop.
    pub struct Mapping {
        ptr: *mut c_void,
        len: usize,
    }

    // The mapping is immutable (PROT_READ, private) and owned: sharing
    // &Mapping across threads is sharing &[u8].
    unsafe impl Send for Mapping {}
    unsafe impl Sync for Mapping {}

    impl Mapping {
        /// Map `len` bytes of `file` from offset 0. `len` must be the
        /// file's length and non-zero (the artifact minimum guarantees
        /// it).
        pub fn map(file: &File, len: usize) -> io::Result<Mapping> {
            assert!(len > 0, "cannot map an empty file");
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            if ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            Ok(Mapping { ptr, len })
        }

        /// The mapped bytes.
        #[inline]
        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mapping {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

/// A loaded, integrity-checked sealed index. Read-only and `Sync`: one
/// instance is shared across every server connection with no lock — the
/// serving tier's whole concurrency model is "immutable artifact, any
/// number of readers".
///
/// Opening is footer-first: a fixed-size preflight (preamble + tail)
/// validates magic, version, and all section arithmetic *before* any
/// bulk I/O, so a corrupt or wrong-format multi-GB file is rejected in
/// O(1) reads. The body then loads through the chosen [`Backend`];
/// sections are resolved by offset with zero parse work (no per-record
/// decode, no allocation per read or suffix).
pub struct SealedIndex {
    data: IndexData,
    version: u32,
    file_len: u64,
    corpus: (usize, usize),
    sa: (usize, usize),
    table: (usize, usize),
    meta: (usize, usize),
    /// v2 auxiliary sections; zero-length when absent (or v1).
    lcp: (usize, usize),
    tree: (usize, usize),
    bwt: (usize, usize),
    n_reads: usize,
    n_sa: usize,
    n_files: usize,
}

fn bad(path: &Path, msg: String) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("sealed index {}: {msg}", path.display()),
    )
}

#[inline]
fn le_u64(data: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(data[off..off + 8].try_into().expect("8-byte field"))
}

/// Everything the footer-first preflight resolves without touching the
/// artifact body.
struct Preflight {
    version: u32,
    file_len: u64,
    n_reads: usize,
    n_sa: usize,
    n_files: usize,
    /// corpus, SA, read-table, file-metadata.
    core: [(usize, usize); 4],
    /// LCP, TREE, BWT — all zero for v1.
    aux: [(usize, usize); 3],
}

/// Validate preamble + footer (+ v2 extension footer) from fixed-size
/// reads at the file's ends: magic, version, reserved word, counts, and
/// every section's offset arithmetic. O(1) I/O regardless of artifact
/// size — a corrupt or wrong-format multi-GB file fails here, before
/// the body is read or mapped.
fn preflight(path: &Path, file: &mut File) -> io::Result<Preflight> {
    let file_len = file.metadata().map_err(|e| {
        io::Error::new(e.kind(), format!("sealed index {}: {e}", path.display()))
    })?.len();
    if (file_len as usize) < MIN_FILE_LEN {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            format!(
                "sealed index {}: {file_len} bytes is shorter than the minimal \
                 container ({MIN_FILE_LEN} bytes) — truncated or not a \
                 sealed index",
                path.display(),
            ),
        ));
    }
    let mut preamble = [0u8; PREAMBLE_LEN];
    file.read_exact(&mut preamble)?;
    if preamble[..8] != MAGIC {
        return Err(bad(
            path,
            format!("bad magic {:?} (expected {:?})", &preamble[..8], &MAGIC[..]),
        ));
    }
    let version = u32::from_le_bytes(preamble[8..12].try_into().expect("4-byte version"));
    if version != VERSION && version != VERSION_V1 {
        return Err(bad(
            path,
            format!(
                "unsupported version {version} (this build reads versions \
                 {VERSION_V1} and {VERSION})"
            ),
        ));
    }

    // one tail read covers checksum + footer + (v2) extension footer
    let tail_len = (file_len as usize).min(FOOTER_LEN + CHECKSUM_LEN + EXT_LEN);
    let mut tail = vec![0u8; tail_len];
    file.seek(SeekFrom::End(-(tail_len as i64)))?;
    file.read_exact(&mut tail)?;
    let f = file_len as usize - FOOTER_LEN - CHECKSUM_LEN; // footer offset in file
    let ft = tail_len - FOOTER_LEN - CHECKSUM_LEN; // footer offset in tail

    let n_reads = le_u64(&tail, ft) as usize;
    let n_sa = le_u64(&tail, ft + 8) as usize;
    let n_files = le_u64(&tail, ft + 16) as usize;
    let reserved = le_u64(&tail, ft + 88);
    let want_ext = if version >= VERSION { EXT_LEN as u64 } else { 0 };
    if reserved != want_ext {
        return Err(bad(
            path,
            format!(
                "version {version} artifact declares a {reserved}-byte extension \
                 footer (expected {want_ext})"
            ),
        ));
    }
    if version >= VERSION && (file_len as usize) < MIN_FILE_LEN + EXT_LEN {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            format!(
                "sealed index {}: {file_len} bytes cannot hold a version \
                 {version} container ({} bytes minimum)",
                path.display(),
                MIN_FILE_LEN + EXT_LEN
            ),
        ));
    }
    // sections must land before the extension footer (v2) / footer (v1)
    let limit = f - want_ext as usize;

    let resolve = |name: &str, off: u64, len: u64| -> io::Result<(usize, usize)> {
        let end = off
            .checked_add(len)
            .ok_or_else(|| bad(path, format!("{name} section offset overflows")))?;
        if off < PREAMBLE_LEN as u64 || end > limit as u64 {
            return Err(bad(
                path,
                format!(
                    "{name} section [{off}, {end}) falls outside the file body \
                     [{PREAMBLE_LEN}, {limit})"
                ),
            ));
        }
        Ok((off as usize, len as usize))
    };
    let declared = |what: &str, len: usize, count: usize, each: usize| -> io::Result<()> {
        if len != count * each {
            return Err(bad(
                path,
                format!(
                    "{what} section is {len} bytes but the footer declares \
                     {count} entries ({} bytes expected)",
                    count * each
                ),
            ));
        }
        Ok(())
    };

    let names = ["corpus", "SA", "read-table", "file-metadata"];
    let mut core = [(0usize, 0usize); 4];
    for (i, name) in names.iter().enumerate() {
        let (off, len) = (le_u64(&tail, ft + 24 + i * 16), le_u64(&tail, ft + 32 + i * 16));
        core[i] = resolve(name, off, len)?;
    }
    declared("SA", core[1].1, n_sa, 8)?;
    declared("read-table", core[2].1, n_reads, READ_ENTRY_LEN)?;
    declared("file-metadata", core[3].1, n_files, FILE_ENTRY_LEN)?;

    // v2 extension footer: LCP / TREE / BWT, each present in full
    // (n_sa entries) or absent (zero length) — nothing in between
    let mut aux = [(0usize, 0usize); 3];
    if version >= VERSION {
        let et = ft - EXT_LEN; // extension-footer offset in tail
        let aux_names = ["LCP", "midpoint-tree", "BWT"];
        let each = [LCP_ENTRY_LEN, TREE_ENTRY_LEN, 1];
        for (i, name) in aux_names.iter().enumerate() {
            let (off, len) = (le_u64(&tail, et + i * 16), le_u64(&tail, et + 8 + i * 16));
            if len == 0 {
                continue; // absent: plain-search degrade
            }
            aux[i] = resolve(name, off, len)?;
            declared(name, aux[i].1, n_sa, each[i])?;
        }
    }
    Ok(Preflight { version, file_len, n_reads, n_sa, n_files, core, aux })
}

impl SealedIndex {
    /// Load and verify the artifact at `path` with default options
    /// (heap backend, checksum verified). Every corruption mode —
    /// truncation, wrong magic, unsupported version, checksum mismatch,
    /// inconsistent section table — is a descriptive `io::Error`, never
    /// a panic and never a silently wrong answer later.
    pub fn open(path: &Path) -> io::Result<SealedIndex> {
        SealedIndex::open_with(path, OpenOptions::default())
    }

    /// [`SealedIndex::open`] with an explicit body [`Backend`] and
    /// checksum policy. The footer-first preflight always runs.
    pub fn open_with(path: &Path, opts: OpenOptions) -> io::Result<SealedIndex> {
        let mut file = File::open(path).map_err(|e| {
            io::Error::new(e.kind(), format!("sealed index {}: {e}", path.display()))
        })?;
        let pre = preflight(path, &mut file)?;

        let data = match opts.backend {
            Backend::Heap => {
                file.seek(SeekFrom::Start(0))?;
                let mut buf = Vec::with_capacity(pre.file_len as usize);
                file.read_to_end(&mut buf)?;
                if buf.len() as u64 != pre.file_len {
                    return Err(bad(
                        path,
                        format!(
                            "file changed while opening ({} bytes read, {} expected)",
                            buf.len(),
                            pre.file_len
                        ),
                    ));
                }
                IndexData::Heap(buf)
            }
            #[cfg(feature = "mmap")]
            Backend::Mmap => IndexData::Mapped(mmap_backend::Mapping::map(
                &file,
                pre.file_len as usize,
            )?),
        };

        if opts.verify_checksum {
            let bytes = data.bytes();
            let body_len = bytes.len() - CHECKSUM_LEN;
            let stored = le_u64(bytes, body_len);
            let computed = checksum(&bytes[..body_len]);
            if stored != computed {
                return Err(bad(
                    path,
                    format!(
                        "checksum mismatch (stored {stored:#018x}, computed \
                         {computed:#018x}) — the artifact is corrupted or truncated"
                    ),
                ));
            }
        }

        let [corpus, sa, table, meta] = pre.core;
        let [lcp, tree, bwt] = pre.aux;
        let idx = SealedIndex {
            data,
            version: pre.version,
            file_len: pre.file_len,
            corpus,
            sa,
            table,
            meta,
            lcp,
            tree,
            bwt,
            n_reads: pre.n_reads,
            n_sa: pre.n_sa,
            n_files: pre.n_files,
        };
        // read-table scan: strictly increasing seqs, in-bounds corpus
        // ranges, and totals consistent with the corpus and SA sections.
        // O(n_reads) over fixed-width entries — metadata validation, not
        // record parsing: nothing is decoded, copied, or allocated.
        let mut corpus_used = 0u64;
        let mut suffix_total = 0u64;
        let mut prev: Option<u64> = None;
        for i in 0..idx.n_reads {
            let (seq, off, len) = idx.table_entry(i);
            if prev.is_some_and(|p| p >= seq) {
                return Err(bad(
                    path,
                    format!("read table not strictly seq-sorted at entry {i} (seq {seq})"),
                ));
            }
            prev = Some(seq);
            if off as usize + len as usize > idx.corpus.1 {
                return Err(bad(
                    path,
                    format!(
                        "read {seq} spans corpus bytes [{off}, {}) but the corpus \
                         section holds {}",
                        off + len as u64,
                        idx.corpus.1
                    ),
                ));
            }
            corpus_used += len as u64;
            suffix_total += len as u64 + 1;
        }
        if corpus_used != idx.corpus.1 as u64 {
            return Err(bad(
                path,
                format!(
                    "read table covers {corpus_used} corpus bytes but the corpus \
                     section holds {}",
                    idx.corpus.1
                ),
            ));
        }
        if suffix_total != idx.n_sa as u64 {
            return Err(bad(
                path,
                format!(
                    "corpus holds {suffix_total} suffixes but the SA section \
                     declares {}",
                    idx.n_sa
                ),
            ));
        }
        Ok(idx)
    }

    /// The whole artifact, whichever backend holds it.
    #[inline]
    fn bytes(&self) -> &[u8] {
        self.data.bytes()
    }

    /// Container version of the opened artifact (1 or 2).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Artifact size on disk, checksum included.
    pub fn file_len(&self) -> u64 {
        self.file_len
    }

    /// True when the artifact carries a non-empty LCP section.
    pub fn has_lcp(&self) -> bool {
        self.lcp.1 > 0
    }

    /// True when the artifact carries a non-empty midpoint-tree section
    /// (queries take the accelerated path).
    pub fn has_tree(&self) -> bool {
        self.tree.1 > 0
    }

    /// True when the artifact carries a non-empty BWT section.
    pub fn has_bwt(&self) -> bool {
        self.bwt.1 > 0
    }

    /// Headline counts.
    pub fn stats(&self) -> SealedStats {
        SealedStats {
            n_reads: self.n_reads as u64,
            n_suffixes: self.n_sa as u64,
            n_files: self.n_files as u64,
            corpus_bytes: self.corpus.1 as u64,
            file_bytes: self.file_len,
            has_lcp: self.has_lcp(),
            has_tree: self.has_tree(),
            has_bwt: self.has_bwt(),
        }
    }

    /// Metadata of input file `i` (in construction order).
    pub fn file_meta(&self, i: usize) -> FileMeta {
        assert!(i < self.n_files, "file {i} of {}", self.n_files);
        let off = self.meta.0 + i * FILE_ENTRY_LEN;
        FileMeta {
            n_reads: le_u64(self.bytes(), off),
            min_seq: le_u64(self.bytes(), off + 8),
            max_seq: le_u64(self.bytes(), off + 16),
        }
    }

    #[inline]
    fn table_entry(&self, i: usize) -> (u64, u64, u32) {
        let off = self.table.0 + i * READ_ENTRY_LEN;
        let data = self.bytes();
        (
            le_u64(data, off),
            le_u64(data, off + 8),
            u32::from_le_bytes(data[off + 16..off + 20].try_into().expect("4-byte len")),
        )
    }

    /// The SA entry at `rank` (packed suffix index).
    #[inline]
    pub fn sa_at(&self, rank: usize) -> i64 {
        assert!(rank < self.n_sa, "SA rank {rank} of {}", self.n_sa);
        i64::from_le_bytes(
            self.bytes()[self.sa.0 + rank * 8..self.sa.0 + rank * 8 + 8]
                .try_into()
                .expect("8-byte SA entry"),
        )
    }

    /// The stored LCP of ranks `rank-1` and `rank` (`lcp[0] = 0`).
    /// Requires [`SealedIndex::has_lcp`].
    #[inline]
    pub fn lcp_at(&self, rank: usize) -> u32 {
        assert!(self.has_lcp(), "artifact has no LCP section");
        assert!(rank < self.n_sa, "LCP rank {rank} of {}", self.n_sa);
        let off = self.lcp.0 + rank * LCP_ENTRY_LEN;
        u32::from_le_bytes(self.bytes()[off..off + 4].try_into().expect("4-byte LCP"))
    }

    /// The BWT character code at `rank` ([`BWT_TERMINATOR`] for
    /// offset-0 suffixes). Requires [`SealedIndex::has_bwt`].
    #[inline]
    pub fn bwt_at(&self, rank: usize) -> u8 {
        assert!(self.has_bwt(), "artifact has no BWT section");
        assert!(rank < self.n_sa, "BWT rank {rank} of {}", self.n_sa);
        self.bytes()[self.bwt.0 + rank]
    }

    /// The stored read with sequence number `seq`, as a slice into the
    /// file buffer (no copy).
    pub fn read_of(&self, seq: u64) -> Option<&[u8]> {
        let mut lo = 0usize;
        let mut hi = self.n_reads;
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let (s, off, len) = self.table_entry(mid);
            match s.cmp(&seq) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => {
                    let start = self.corpus.0 + off as usize;
                    return Some(&self.bytes()[start..start + len as usize]);
                }
            }
        }
        None
    }

    /// The suffix a packed index denotes, as a slice into the file
    /// buffer — same offset clamping as the in-memory corpus search.
    pub fn suffix(&self, index: i64) -> Option<&[u8]> {
        if index < 0 {
            return None;
        }
        let (seq, off) = unpack_index(index);
        let r = self.read_of(seq)?;
        Some(&r[off.min(r.len())..])
    }
}

impl IndexView for SealedIndex {
    fn n_suffixes(&self) -> usize {
        self.n_sa
    }

    fn suffix_at(&self, rank: usize) -> &[u8] {
        self.suffix(self.sa_at(rank))
            .expect("sealed SA entry resolves to a stored read (checksum-verified artifact)")
    }

    fn index_at(&self, rank: usize) -> i64 {
        self.sa_at(rank)
    }

    fn midpoint_tree(&self) -> Option<MidpointTree<'_>> {
        if self.has_tree() {
            Some(MidpointTree::new(&self.bytes()[self.tree.0..self.tree.0 + self.tree.1]))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::codes_of;
    use crate::suffix::validate::{read_map, reference_order};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("samr-sealed-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn corpus() -> Vec<Read> {
        vec![
            Read::from_ascii(0, b"ACGTACGT"),
            Read::from_ascii(1, b"TTACGTT"),
            Read::from_ascii(5, b"GGGG"),
        ]
    }

    #[test]
    fn roundtrips_reads_order_and_metadata() {
        let reads = corpus();
        let order = reference_order(&reads);
        let path = tmp("roundtrip.samr");
        seal(&path, &[&reads], &order).unwrap();

        let idx = SealedIndex::open(&path).unwrap();
        let st = idx.stats();
        assert_eq!(st.n_reads, 3);
        assert_eq!(st.n_suffixes, order.len() as u64);
        assert_eq!(st.n_files, 1);
        assert_eq!(st.corpus_bytes, 8 + 7 + 4);
        assert_eq!(st.file_bytes, std::fs::metadata(&path).unwrap().len());
        assert!(st.has_lcp && st.has_tree && st.has_bwt);
        assert_eq!(idx.version(), VERSION);
        assert_eq!(
            idx.file_meta(0),
            FileMeta { n_reads: 3, min_seq: 0, max_seq: 5 }
        );
        for (rank, &want) in order.iter().enumerate() {
            assert_eq!(idx.sa_at(rank), want);
        }
        for r in &reads {
            assert_eq!(idx.read_of(r.seq), Some(&r.codes[..]));
        }
        assert_eq!(idx.read_of(2), None);
        assert_eq!(idx.suffix(5), Some(&codes_of(b"CGT")[..])); // seq 0, offset 5
        assert_eq!(idx.suffix(-3), None);
    }

    #[test]
    fn sealed_aux_sections_match_naive_recompute() {
        let reads = corpus();
        let order = reference_order(&reads);
        let path = tmp("aux.samr");
        seal(&path, &[&reads], &order).unwrap();
        let idx = SealedIndex::open(&path).unwrap();
        assert!(idx.midpoint_tree().is_some());
        assert_eq!(idx.lcp_at(0), 0);
        for rank in 0..order.len() {
            let (seq, off) = unpack_index(idx.sa_at(rank));
            let r = idx.read_of(seq).unwrap();
            let want_bwt = if off == 0 { BWT_TERMINATOR } else { r[off - 1] };
            assert_eq!(idx.bwt_at(rank), want_bwt, "rank {rank}");
            if rank > 0 {
                let (a, b) = (idx.suffix_at(rank - 1), idx.suffix_at(rank));
                let want = a.iter().zip(b).take_while(|(x, y)| x == y).count() as u32;
                assert_eq!(idx.lcp_at(rank), want, "rank {rank}");
            }
        }
    }

    #[test]
    fn v1_and_plain_v2_artifacts_serve_without_aux() {
        let reads = corpus();
        let order = reference_order(&reads);
        for (name, sealer) in [
            ("old.samr", seal_v1 as fn(&Path, &[&[Read]], &[i64]) -> io::Result<()>),
            ("plain.samr", seal_plain),
        ] {
            let path = tmp(name);
            sealer(&path, &[&reads], &order).unwrap();
            let idx = SealedIndex::open(&path).unwrap();
            let st = idx.stats();
            assert!(!st.has_lcp && !st.has_tree && !st.has_bwt, "{name}");
            assert!(idx.midpoint_tree().is_none(), "{name}");
            for (rank, &want) in order.iter().enumerate() {
                assert_eq!(idx.sa_at(rank), want, "{name}");
            }
            let pat = codes_of(b"ACGT");
            assert_eq!(idx.find(&pat), vec![(0, 0), (0, 4), (1, 2)], "{name}");
        }
        let v1 = tmp("old.samr");
        assert_eq!(SealedIndex::open(&v1).unwrap().version(), VERSION_V1);
    }

    #[test]
    fn sealed_view_answers_match_in_memory_view() {
        let reads = corpus();
        let order = reference_order(&reads);
        let map = read_map(&reads);
        let path = tmp("equiv.samr");
        seal(&path, &[&reads], &order).unwrap();
        let idx = SealedIndex::open(&path).unwrap();
        let mem = crate::suffix::search::CorpusIndex::new(&order, &map);
        for pat in [&b"ACGT"[..], b"T", b"GGGG", b"AAAA", b""] {
            let codes = codes_of(pat);
            assert_eq!(idx.find(&codes), mem.find(&codes), "pattern {pat:?}");
            assert_eq!(idx.sa_range(&codes), mem.sa_range(&codes));
        }
    }

    #[test]
    fn writer_rejects_misuse() {
        let reads = corpus();
        let path = tmp("misuse.samr");
        // add_file after the SA stream began
        let mut w = SealWriter::create(&path).unwrap();
        w.add_file(&reads).unwrap();
        w.push_index(0).unwrap();
        let err = w.add_file(&reads).unwrap_err();
        assert!(err.to_string().contains("add_file"), "{err}");
        // suffix-count mismatch at finish
        let mut w = SealWriter::create(&path).unwrap();
        w.add_file(&reads).unwrap();
        w.push_index(0).unwrap();
        let err = w.finish().unwrap_err();
        assert!(err.to_string().contains("suffixes"), "{err}");
        // duplicate seqs across files
        let mut w = SealWriter::create(&path).unwrap();
        w.add_file(&reads).unwrap();
        w.add_file(&reads).unwrap();
        for _ in 0..2 * reads.iter().map(Read::suffix_count).sum::<usize>() {
            w.push_index(0).unwrap();
        }
        let err = w.finish().unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        // push_index on an aux writer, and vice versa
        let mut w = SealWriter::create_with_aux(&path).unwrap();
        w.add_file(&reads).unwrap();
        let err = w.push_index(0).unwrap_err();
        assert!(err.to_string().contains("push_entry"), "{err}");
        let mut w = SealWriter::create(&path).unwrap();
        w.add_file(&reads).unwrap();
        let err = w.push_entry(0, 0, 1).unwrap_err();
        assert!(err.to_string().contains("create_with_aux"), "{err}");
        // first-lcp and bwt-range wiring guards
        let mut w = SealWriter::create_with_aux(&path).unwrap();
        w.add_file(&reads).unwrap();
        let err = w.push_entry(0, 3, 1).unwrap_err();
        assert!(err.to_string().contains("first suffix"), "{err}");
        let mut w = SealWriter::create_with_aux(&path).unwrap();
        w.add_file(&reads).unwrap();
        let err = w.push_entry(0, 0, 6).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    #[cfg(feature = "mmap")]
    fn mmap_backend_serves_identically_to_heap() {
        let reads = corpus();
        let order = reference_order(&reads);
        let path = tmp("mapped.samr");
        seal(&path, &[&reads], &order).unwrap();
        let heap = SealedIndex::open(&path).unwrap();
        let mapped = SealedIndex::open_with(
            &path,
            OpenOptions { backend: Backend::Mmap, verify_checksum: true },
        )
        .unwrap();
        assert_eq!(heap.stats(), mapped.stats());
        for pat in [&b"ACGT"[..], b"T", b"GGGG", b"AAAA", b""] {
            let codes = codes_of(pat);
            assert_eq!(heap.find(&codes), mapped.find(&codes), "pattern {pat:?}");
        }
        for rank in 0..order.len() {
            assert_eq!(heap.sa_at(rank), mapped.sa_at(rank));
            assert_eq!(heap.lcp_at(rank), mapped.lcp_at(rank));
            assert_eq!(heap.bwt_at(rank), mapped.bwt_at(rank));
        }
    }

    #[test]
    fn empty_artifact_is_well_formed() {
        let path = tmp("empty.samr");
        seal(&path, &[], &[]).unwrap();
        let idx = SealedIndex::open(&path).unwrap();
        assert_eq!(idx.stats().n_suffixes, 0);
        assert!(idx.find(&codes_of(b"ACGT")).is_empty());
    }
}

//! Ground truth and validation for the pipeline's output: the globally
//! sorted order of *all suffixes of all reads* (each read terminated by
//! its own `$`), ties between equal suffix texts broken by packed index —
//! exactly what the paper's 11-hour grouper run emits.

use std::collections::HashMap;
use std::cmp::Ordering;

use crate::suffix::encode::{pack_index, unpack_index};
use crate::suffix::reads::Read;

/// Read lookup by sequence number (the role Redis plays in the paper).
pub type ReadMap = HashMap<u64, Vec<u8>>;

pub fn read_map(reads: &[Read]) -> ReadMap {
    reads.iter().map(|r| (r.seq, r.codes.clone())).collect()
}

/// Compare two suffixes by text; suffix = read[offset..] + '$', and `$`
/// (code 0) is smaller than every base code, so comparing the code slices
/// with an implicit trailing 0 is plain prefix-aware slice ordering.
pub fn cmp_suffix(reads: &ReadMap, a: i64, b: i64) -> Ordering {
    let (sa, oa) = unpack_index(a);
    let (sb, ob) = unpack_index(b);
    let ra = &reads[&sa];
    let rb = &reads[&sb];
    let xa = &ra[oa.min(ra.len())..];
    let xb = &rb[ob.min(rb.len())..];
    // codes compare like the text; a proper prefix (earlier '$') is smaller
    xa.cmp(xb)
}

/// Suffix text (codes, including the terminator 0) for reports/tests.
pub fn suffix_codes(reads: &ReadMap, index: i64) -> Vec<u8> {
    let (s, o) = unpack_index(index);
    let r = &reads[&s];
    let mut v = r[o.min(r.len())..].to_vec();
    v.push(0);
    v
}

/// All packed suffix indexes of a corpus.
pub fn all_indexes(reads: &[Read]) -> Vec<i64> {
    let mut out = Vec::new();
    for r in reads {
        for o in 0..=r.len() {
            out.push(pack_index(r.seq, o));
        }
    }
    out
}

/// Reference order: sort all suffixes by (text, index) — the oracle.
pub fn reference_order(reads: &[Read]) -> Vec<i64> {
    let map = read_map(reads);
    let mut idx = all_indexes(reads);
    idx.sort_by(|&a, &b| cmp_suffix(&map, a, b).then(a.cmp(&b)));
    idx
}

/// Single-process SA-IS reference over the *concatenated* corpus — the
/// independent oracle the pair-end equivalence tests compare the
/// distributed two-file order against.
///
/// The reads (ascending seq) are joined into one text, each followed by
/// its `$` terminator (code 0), and SA-IS sorts every suffix of the
/// concatenation in linear time. Each concatenation position maps back
/// to exactly one `(read, offset)` with `offset ∈ 0..=len` (the
/// terminator position is the read's lone-`$` suffix), so the filtered
/// array is a permutation of all packed indexes. One correction remains:
/// where two read-suffixes are EQUAL as `$`-terminated strings, the
/// concatenation ordered them by whatever text follows the terminator,
/// while the pipeline's contract is ascending packed index — so equal-
/// text runs are re-sorted by index. Everything else is untouched: `$`
/// sorts below every base, so a proper prefix already precedes its
/// extensions in the concatenation order.
pub fn sais_reference_order(reads: &[Read]) -> Vec<i64> {
    let mut by_seq: Vec<&Read> = reads.iter().collect();
    by_seq.sort_by_key(|r| r.seq);

    let total: usize = by_seq.iter().map(|r| r.suffix_count()).sum();
    let mut text = Vec::with_capacity(total);
    // packed index of every concatenation position
    let mut index_at = Vec::with_capacity(total);
    for r in &by_seq {
        for (off, &c) in r.codes.iter().enumerate() {
            text.push(c);
            index_at.push(pack_index(r.seq, off));
        }
        text.push(0); // terminator position = the lone-'$' suffix
        index_at.push(pack_index(r.seq, r.len()));
    }

    let sa = crate::suffix::sa::sais(&text);
    let mut order: Vec<i64> = sa.iter().map(|&p| index_at[p as usize]).collect();

    // stabilize equal-text runs by packed index
    let map = read_map(reads);
    let mut start = 0;
    for i in 1..=order.len() {
        if i == order.len() || cmp_suffix(&map, order[i - 1], order[i]) != Ordering::Equal {
            if i - start > 1 {
                order[start..i].sort_unstable();
            }
            start = i;
        }
    }
    order
}

/// Validate a pipeline output against the corpus: must be a permutation of
/// all suffix indexes in (text, index) order.
pub fn validate_order(reads: &[Read], order: &[i64]) -> Result<(), String> {
    let expected = reads.iter().map(|r| r.suffix_count()).sum::<usize>();
    if order.len() != expected {
        return Err(format!(
            "output has {} suffixes, corpus has {expected}",
            order.len()
        ));
    }
    let map = read_map(reads);
    // permutation check
    let mut seen: Vec<i64> = order.to_vec();
    seen.sort_unstable();
    seen.dedup();
    if seen.len() != order.len() {
        return Err("duplicate suffix indexes in output".into());
    }
    let mut all = all_indexes(reads);
    all.sort_unstable();
    if seen != all {
        return Err("output is not a permutation of the corpus suffixes".into());
    }
    // ordering check
    for (i, w) in order.windows(2).enumerate() {
        match cmp_suffix(&map, w[0], w[1]) {
            Ordering::Less => {}
            Ordering::Equal if w[0] < w[1] => {}
            Ordering::Equal => {
                return Err(format!("tie at {i} not broken by index: {} !< {}", w[0], w[1]))
            }
            Ordering::Greater => {
                return Err(format!("out of order at {i}: index {} > {}", w[0], w[1]))
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::reads::CorpusSpec;
    use crate::suffix::{reads, sa};

    #[test]
    fn reference_matches_single_text_sa() {
        // For a corpus of ONE read, the reference order must equal the
        // classic suffix array of read+'$'.
        let r = Read::from_ascii(0, b"GATTACA");
        let order = reference_order(std::slice::from_ref(&r));
        let mut text = r.codes.clone();
        text.push(0);
        let sa = sa::sais(&text);
        let from_sa: Vec<i64> = sa.iter().map(|&p| p as i64).collect();
        assert_eq!(order, from_sa);
    }

    #[test]
    fn validate_accepts_reference_and_rejects_swaps() {
        let spec = CorpusSpec { n_reads: 30, read_len: 12, ..Default::default() };
        let corpus = reads::synth_corpus(&spec);
        let mut order = reference_order(&corpus);
        assert!(validate_order(&corpus, &order).is_ok());

        order.swap(5, 6);
        assert!(validate_order(&corpus, &order).is_err());
        order.swap(5, 6);

        let dropped = &order[1..];
        assert!(validate_order(&corpus, dropped).is_err());

        let mut dup = order.clone();
        dup[0] = dup[1];
        assert!(validate_order(&corpus, &dup).is_err());
    }

    #[test]
    fn equal_suffixes_tie_break_by_index() {
        // two identical reads -> every suffix text appears twice
        let rs = vec![Read::from_ascii(0, b"ACG"), Read::from_ascii(1, b"ACG")];
        let order = reference_order(&rs);
        assert!(validate_order(&rs, &order).is_ok());
        // pairs of equal texts must be adjacent with ascending index
        let map = read_map(&rs);
        for w in order.windows(2) {
            if cmp_suffix(&map, w[0], w[1]) == Ordering::Equal {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn sais_reference_matches_naive_reference() {
        // the concatenated-corpus SA-IS reference must agree with the
        // naive (text, index) sort on corpora with heavy duplication —
        // where the equal-run re-stabilization actually has work to do
        let mut corpus = reads::synth_corpus(&CorpusSpec {
            n_reads: 40,
            read_len: 16,
            genome_len: 256, // repetitive: many equal suffix texts
            ..Default::default()
        });
        // exact duplicate reads: maximal equal-text runs
        let dup = corpus[3].codes.clone();
        corpus.push(Read::new(40, dup.clone()));
        corpus.push(Read::new(41, dup));
        let want = reference_order(&corpus);
        let got = sais_reference_order(&corpus);
        assert_eq!(got, want);
        validate_order(&corpus, &got).expect("sais reference invalid");
        // degenerate corpora
        assert!(sais_reference_order(&[]).is_empty());
        let one = vec![Read::from_ascii(9, b"A")];
        assert_eq!(sais_reference_order(&one), reference_order(&one));
    }

    #[test]
    fn dollar_suffixes_sort_first() {
        let rs = vec![Read::from_ascii(0, b"AC"), Read::from_ascii(1, b"GT")];
        let order = reference_order(&rs);
        // first two entries are the two '$'-only suffixes (offset == len)
        let map = read_map(&rs);
        assert_eq!(suffix_codes(&map, order[0]), vec![0]);
        assert_eq!(suffix_codes(&map, order[1]), vec![0]);
    }
}

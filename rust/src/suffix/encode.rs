//! Base-5 prefix encoding and suffix-index packing (paper §IV-B).
//!
//! Characters: `$`=0, `A`=1, `C`=2, `G`=3, `T`=4. A suffix's sort key is
//! its first `prefix_len` characters packed base-5 into an `i64`, zero
//! ($) padded — so a suffix shorter than the prefix encodes as itself and
//! needs no further comparison ("the prefix is the suffix itself").
//!
//! A suffix's identity is `pack_index(seq, offset) = seq * 1000 + offset`
//! (offsets of ~200 bp reads fit well below 1000); `seq` and `offset`
//! are recovered by division and modulo.

/// Character codes in sort order; `$` is the smallest.
pub const ALPHABET: &[u8; 5] = b"$ACGT";
pub const BASE: i64 = 5;
/// Offset radix of the packed suffix index (`seq * 1000 + offset`).
pub const OFFSET_RADIX: i64 = 1000;
/// Paper's default prefix length for `long` keys (§IV-D).
pub const DEFAULT_PREFIX_LEN: usize = 23;
/// Longest prefix whose base-5 value fits an `i32` (paper: threshold 13).
pub const I32_PREFIX_LEN: usize = 13;
/// Longest prefix whose base-5 value fits an `i64` (paper: threshold 26).
pub const I64_PREFIX_LEN: usize = 26;

/// Strict code of an ASCII nucleotide (or `$`): `None` for anything
/// outside `$ACGT` (either case), *including* `N` — whether an ambiguous
/// base is masked or rejected is the parser's policy
/// ([`crate::suffix::reads::ParsePolicy`]), not the encoder's.
#[inline]
pub fn strict_code_of(c: u8) -> Option<u8> {
    match c {
        b'$' => Some(0),
        b'A' | b'a' => Some(1),
        b'C' | b'c' => Some(2),
        b'G' | b'g' => Some(3),
        b'T' | b't' => Some(4),
        _ => None,
    }
}

/// Map an ASCII nucleotide (or `$`) to its code, panicking on anything
/// else and masking `N` to `A`. For trusted input (literals in tests,
/// synthetic corpora); untrusted bytes go through the fallible parsers
/// in `suffix/reads.rs`, which surface `io::Error` instead.
#[inline]
pub fn code_of(c: u8) -> u8 {
    match c {
        b'N' | b'n' => 1,
        _ => strict_code_of(c)
            .unwrap_or_else(|| panic!("invalid read character {:?}", c as char)),
    }
}

#[inline]
pub fn char_of(code: u8) -> u8 {
    ALPHABET[code as usize]
}

/// Encode ASCII into codes.
pub fn codes_of(s: &[u8]) -> Vec<u8> {
    s.iter().map(|&c| code_of(c)).collect()
}

/// Render codes as ASCII (for reports/tests).
pub fn string_of(codes: &[u8]) -> String {
    codes.iter().map(|&c| char_of(c) as char).collect()
}

/// Base-5 key of `suffix` (codes, *without* implicit terminator),
/// zero-padded/truncated to `prefix_len` characters. The caller appends
/// the `$` terminator code (0) explicitly if the suffix has one — but
/// since `$`=0 equals the padding, omitting it is equivalent.
#[inline]
pub fn encode_prefix(suffix: &[u8], prefix_len: usize) -> i64 {
    debug_assert!(prefix_len <= I64_PREFIX_LEN);
    let mut v: i64 = 0;
    for j in 0..prefix_len {
        let c = if j < suffix.len() { suffix[j] as i64 } else { 0 };
        debug_assert!(c < BASE);
        v = v * BASE + c;
    }
    v
}

/// Key of the suffix of `read` (codes, no terminator) starting at `offset`.
/// `offset == read.len()` is the lone-`$` suffix and encodes to 0.
#[inline]
pub fn suffix_key(read: &[u8], offset: usize, prefix_len: usize) -> i64 {
    debug_assert!(offset <= read.len());
    encode_prefix(&read[offset.min(read.len())..], prefix_len)
}

/// Pack a suffix identity. Guarded *unconditionally*: an offset at or
/// beyond `OFFSET_RADIX` would alias the suffix into the next sequence
/// number — the same packed value as a different, valid suffix — and the
/// construction would emit a wrong suffix array with no error anywhere.
/// A `debug_assert` here once let exactly that happen in release builds;
/// ingestion also rejects oversized reads ([`crate::suffix::reads::Read`]),
/// so this assert is the last line of defense, not the first.
#[inline]
pub fn pack_index(seq: u64, offset: usize) -> i64 {
    assert!(
        (offset as i64) < OFFSET_RADIX,
        "suffix offset {offset} would alias past the packed-index radix {OFFSET_RADIX} \
         (seq {seq}); reads must be shorter than {OFFSET_RADIX} bp"
    );
    seq as i64 * OFFSET_RADIX + offset as i64
}

/// Recover `(seq, offset)`.
#[inline]
pub fn unpack_index(index: i64) -> (u64, usize) {
    ((index / OFFSET_RADIX) as u64, (index % OFFSET_RADIX) as usize)
}

/// Number of leading base-5 digits two prefix keys share.
///
/// For *adjacent* suffixes in sorted order whose keys differ, this is
/// exactly their byte LCP: before the first differing digit position no
/// digit pair can be (0, 0) — both suffixes ending at or before that
/// position would zero-pad every later digit identically, contradicting
/// the keys differing — and no pair can be (0, x≠0), which would itself
/// be the first difference. So every shared leading digit is a shared
/// real base, and the first differing digit is either a real-base
/// mismatch or one suffix's terminator, both of which end the byte LCP
/// there. (Keys equal means the suffixes agree across the whole window;
/// that case is handled from the texts, not from the keys.)
#[inline]
pub fn key_common_prefix(a: i64, b: i64, prefix_len: usize) -> usize {
    debug_assert!(prefix_len <= I64_PREFIX_LEN);
    if prefix_len == 0 {
        return 0;
    }
    let mut place = BASE.pow(prefix_len as u32 - 1);
    let mut common = 0;
    while place > 0 {
        if (a / place) % BASE != (b / place) % BASE {
            break;
        }
        common += 1;
        place /= BASE;
    }
    common
}

/// Decode a base-5 key back into `prefix_len` codes (reports, debugging).
pub fn decode_key(key: i64, prefix_len: usize) -> Vec<u8> {
    let mut out = vec![0u8; prefix_len];
    let mut v = key;
    for j in (0..prefix_len).rev() {
        out[j] = (v % BASE) as u8;
        v /= BASE;
    }
    out
}

/// The largest key of a given prefix length (all-`T`), the paper's
/// "1220703124 for TTTTTTTTTT" check.
pub fn max_key(prefix_len: usize) -> i64 {
    BASE.pow(prefix_len as u32) - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ttttt_threshold() {
        // §IV-B: the all-T prefix of length 13 encodes to 1220703124 =
        // 5^13 - 1, the largest value below i32::MAX = 2147483647 —
        // threshold 13 for int, 26 for long.
        assert_eq!(encode_prefix(&[4; 13], 13), 1_220_703_124);
        assert!(max_key(I32_PREFIX_LEN) <= i32::MAX as i64);
        assert!(max_key(I32_PREFIX_LEN + 1) > i32::MAX as i64);
        assert!(max_key(I64_PREFIX_LEN) <= i64::MAX);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for (seq, off) in [(0u64, 0usize), (5, 200), (1_000_000_007, 999)] {
            assert_eq!(unpack_index(pack_index(seq, off)), (seq, off));
        }
    }

    #[test]
    fn short_suffix_is_itself() {
        // AGT$ with prefix 10 == AGT zero-padded (paper §IV-B).
        let agt = codes_of(b"AGT");
        assert_eq!(encode_prefix(&agt, 10), encode_prefix(&codes_of(b"AGT$"), 10));
    }

    #[test]
    fn key_order_matches_string_order() {
        // keys compare like $-padded prefix strings
        let reads: &[&[u8]] = &[b"ACGT", b"A", b"TTTT", b"ACG", b"CAT", b""];
        let p = 6;
        let mut by_key: Vec<_> = reads.iter().map(|r| codes_of(r)).collect();
        by_key.sort_by_key(|r| encode_prefix(r, p));
        let mut by_str: Vec<_> = reads.iter().map(|r| codes_of(r)).collect();
        by_str.sort();
        assert_eq!(by_key, by_str);
    }

    #[test]
    fn key_common_prefix_counts_shared_digits() {
        let p = 8;
        let k = |s: &[u8]| encode_prefix(&codes_of(s), p);
        assert_eq!(key_common_prefix(k(b"ACGTACGT"), k(b"ACGTTTTT"), p), 4);
        assert_eq!(key_common_prefix(k(b"ACGT"), k(b"ACGTA"), p), 4); // terminator vs A
        assert_eq!(key_common_prefix(k(b"GATTACA"), k(b"TATTACA"), p), 0);
        assert_eq!(key_common_prefix(k(b"AAAA"), k(b"AAAA"), p), p);
        assert_eq!(key_common_prefix(0, 0, p), p); // two lone-$ suffixes
        // matches the byte LCP of the $-padded decoded prefixes
        for (a, b) in [(b"ACGTACGT" as &[u8], b"ACGGACGT" as &[u8]), (b"T", b"TT")] {
            let (ka, kb) = (k(a), k(b));
            let want = decode_key(ka, p)
                .iter()
                .zip(decode_key(kb, p))
                .take_while(|(&x, y)| x == *y)
                .count();
            assert_eq!(key_common_prefix(ka, kb, p), want, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn decode_inverts_encode() {
        let s = codes_of(b"GATTACA");
        let k = encode_prefix(&s, 7);
        assert_eq!(decode_key(k, 7), s);
    }

    #[test]
    fn suffix_key_at_end_is_zero() {
        let r = codes_of(b"ACGT");
        assert_eq!(suffix_key(&r, 4, 23), 0);
    }

    #[test]
    #[should_panic]
    fn invalid_char_panics() {
        code_of(b'X');
    }

    #[test]
    fn strict_code_rejects_n_and_garbage() {
        assert_eq!(strict_code_of(b'A'), Some(1));
        assert_eq!(strict_code_of(b't'), Some(4));
        assert_eq!(strict_code_of(b'$'), Some(0));
        assert_eq!(strict_code_of(b'N'), None); // N policy belongs to the parser
        assert_eq!(strict_code_of(b'n'), None);
        assert_eq!(strict_code_of(b'X'), None);
        assert_eq!(strict_code_of(b'\n'), None);
    }

    #[test]
    #[should_panic(expected = "alias")]
    fn pack_index_rejects_aliasing_offset() {
        // offset == OFFSET_RADIX would collide with (seq+1, 0). This must
        // panic in BOTH profiles — it was a debug_assert, so release
        // builds silently produced pack_index(5, 1000) == pack_index(6, 0).
        pack_index(5, 1000);
    }

    #[test]
    fn pack_index_boundary_offset_is_distinct() {
        // largest legal offset stays distinct from the next seq's first
        assert_ne!(pack_index(5, 999), pack_index(6, 0));
        assert_eq!(unpack_index(pack_index(5, 999)), (5, 999));
    }
}

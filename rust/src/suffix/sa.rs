//! Suffix-array construction algorithms.
//!
//! Three constructions, cross-validated against each other in tests:
//!  * [`naive`] — comparison sort of suffix slices, O(n² log n) worst case;
//!    the oracle for everything else.
//!  * [`doubling`] — Manber–Myers prefix doubling, O(n log² n); the
//!    paper's historical reference ([2] in the paper).
//!  * [`sais`] — linear-time SA-IS (the libdivsufsort-class algorithm the
//!    paper cites as the single-machine state of the art).
//!
//! All operate on a byte text *without* an explicit sentinel; the implicit
//! terminator sorts smallest (Rust slice ordering already gives that: a
//! proper prefix sorts before its extensions).

/// Naive comparison-sort construction (oracle).
pub fn naive(text: &[u8]) -> Vec<u32> {
    let mut sa: Vec<u32> = (0..text.len() as u32).collect();
    sa.sort_by(|&a, &b| text[a as usize..].cmp(&text[b as usize..]));
    sa
}

/// Manber–Myers prefix doubling with radix-free sorting.
pub fn doubling(text: &[u8]) -> Vec<u32> {
    let n = text.len();
    if n == 0 {
        return Vec::new();
    }
    let mut sa: Vec<u32> = (0..n as u32).collect();
    let mut rank: Vec<i64> = text.iter().map(|&c| c as i64).collect();
    let mut tmp: Vec<i64> = vec![0; n];
    let mut k = 1usize;
    loop {
        let key = |i: u32| -> (i64, i64) {
            let i = i as usize;
            let second = if i + k < n { rank[i + k] } else { -1 };
            (rank[i], second)
        };
        sa.sort_by(|&a, &b| key(a).cmp(&key(b)));
        tmp[sa[0] as usize] = 0;
        for w in 1..n {
            let prev = sa[w - 1];
            let cur = sa[w];
            tmp[cur as usize] =
                tmp[prev as usize] + if key(prev) < key(cur) { 1 } else { 0 };
        }
        rank.copy_from_slice(&tmp);
        if rank[sa[n - 1] as usize] as usize == n - 1 {
            break;
        }
        k *= 2;
    }
    sa
}

/// Linear-time SA-IS.
pub fn sais(text: &[u8]) -> Vec<u32> {
    if text.is_empty() {
        return Vec::new();
    }
    // Shift to u32 alphabet with 0 reserved for the appended sentinel.
    let mut s: Vec<u32> = text.iter().map(|&c| c as u32 + 1).collect();
    s.push(0);
    let sa = sais_u32(&s, 257);
    // Drop the sentinel (always first).
    sa.into_iter().skip(1).collect()
}

/// Core SA-IS over a u32 string whose last element is the unique smallest
/// sentinel (value 0, occurring exactly once).
fn sais_u32(s: &[u32], sigma: usize) -> Vec<u32> {
    let n = s.len();
    if n == 1 {
        return vec![0];
    }
    // --- classify S/L types (stype[i] = true iff suffix i is S-type) ---
    let mut stype = vec![false; n];
    stype[n - 1] = true;
    for i in (0..n - 1).rev() {
        stype[i] = s[i] < s[i + 1] || (s[i] == s[i + 1] && stype[i + 1]);
    }
    let is_lms = |i: usize| i > 0 && stype[i] && !stype[i - 1];

    // --- bucket boundaries ---
    let mut bucket = vec![0u32; sigma];
    for &c in s {
        bucket[c as usize] += 1;
    }
    let heads = |bucket: &[u32]| -> Vec<u32> {
        let mut h = vec![0u32; bucket.len()];
        let mut sum = 0;
        for (i, &b) in bucket.iter().enumerate() {
            h[i] = sum;
            sum += b;
        }
        h
    };
    let tails = |bucket: &[u32]| -> Vec<u32> {
        let mut t = vec![0u32; bucket.len()];
        let mut sum = 0;
        for (i, &b) in bucket.iter().enumerate() {
            sum += b;
            t[i] = sum;
        }
        t
    };

    const EMPTY: u32 = u32::MAX;
    let induce = |sa: &mut Vec<u32>, lms_sorted: &[u32]| {
        sa.clear();
        sa.resize(n, EMPTY);
        // place LMS suffixes at bucket tails, in given order (reversed fill)
        let mut t = tails(&bucket);
        for &p in lms_sorted.iter().rev() {
            let c = s[p as usize] as usize;
            t[c] -= 1;
            sa[t[c] as usize] = p;
        }
        // induce L-type from left to right
        let mut h = heads(&bucket);
        for i in 0..n {
            let p = sa[i];
            if p != EMPTY && p > 0 {
                let j = (p - 1) as usize;
                if !stype[j] {
                    let c = s[j] as usize;
                    sa[h[c] as usize] = j as u32;
                    h[c] += 1;
                }
            }
        }
        // induce S-type from right to left
        let mut t = tails(&bucket);
        for i in (0..n).rev() {
            let p = sa[i];
            if p != EMPTY && p > 0 {
                let j = (p - 1) as usize;
                if stype[j] {
                    let c = s[j] as usize;
                    t[c] -= 1;
                    sa[t[c] as usize] = j as u32;
                }
            }
        }
    };

    // --- pass 1: approximate LMS order (text order), induce, read LMS ---
    let lms_positions: Vec<u32> = (0..n).filter(|&i| is_lms(i)).map(|i| i as u32).collect();
    let mut sa: Vec<u32> = Vec::new();
    induce(&mut sa, &lms_positions);

    // LMS substrings in induced order
    let lms_in_sa: Vec<u32> = sa.iter().copied().filter(|&p| is_lms(p as usize)).collect();

    // --- name LMS substrings ---
    let n_lms = lms_positions.len();
    let mut name_of = vec![EMPTY; n];
    let mut name: u32 = 0;
    let mut prev: Option<u32> = None;
    for &p in &lms_in_sa {
        if let Some(q) = prev {
            if !lms_substring_eq(s, &stype, q as usize, p as usize) {
                name += 1;
            }
        }
        name_of[p as usize] = name;
        prev = Some(p);
    }
    let distinct = name + 1;

    // --- order LMS suffixes exactly ---
    let lms_sorted: Vec<u32> = if (distinct as usize) == n_lms {
        lms_in_sa
    } else {
        // recurse on the reduced string (names in text order)
        let reduced: Vec<u32> = lms_positions.iter().map(|&p| name_of[p as usize]).collect();
        let rsa = sais_u32(&reduced, distinct as usize);
        rsa.into_iter().map(|ri| lms_positions[ri as usize]).collect()
    };

    // --- pass 2: final induced sort from exactly ordered LMS ---
    induce(&mut sa, &lms_sorted);
    sa
}

/// Compare two LMS substrings (from their start up to and including the
/// next LMS position) for equality.
fn lms_substring_eq(s: &[u32], stype: &[bool], a: usize, b: usize) -> bool {
    let n = s.len();
    if a == b {
        return true;
    }
    let is_lms = |i: usize| i > 0 && stype[i] && !stype[i - 1];
    let mut i = 0;
    loop {
        let pa = a + i;
        let pb = b + i;
        if pa >= n || pb >= n {
            return false;
        }
        if s[pa] != s[pb] || stype[pa] != stype[pb] {
            return false;
        }
        if i > 0 && (is_lms(pa) || is_lms(pb)) {
            return is_lms(pa) && is_lms(pb);
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_all(text: &[u8]) {
        let want = naive(text);
        assert_eq!(doubling(text), want, "doubling mismatch on {text:?}");
        assert_eq!(sais(text), want, "sais mismatch on {text:?}");
    }

    #[test]
    fn paper_table1_sinica() {
        // Table I: SA of SINICA$ (with the $ as part of the text).
        // Expected SA = [6, 5, 4, 3, 1, 2, 0].
        let text = b"SINICA\x00"; // use 0 byte as the smallest '$'
        let want = vec![6, 5, 4, 3, 1, 2, 0];
        assert_eq!(naive(text), want);
        assert_eq!(sais(text), want);
        assert_eq!(doubling(text), want);
    }

    #[test]
    fn trivial_cases() {
        check_all(b"");
        check_all(b"A");
        check_all(b"AA");
        check_all(b"AB");
        check_all(b"BA");
        check_all(b"AAAAAAA");
        check_all(b"banana");
        check_all(b"mississippi");
        check_all(b"ACGTACGTACGT");
    }

    #[test]
    fn random_dna_cross_validation() {
        let mut rng = Rng::new(99);
        for len in [2usize, 3, 5, 17, 64, 257, 1000] {
            for _ in 0..5 {
                let text: Vec<u8> =
                    (0..len).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
                check_all(&text);
            }
        }
    }

    #[test]
    fn random_binary_stress() {
        // small alphabets stress SA-IS recursion depth
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let len = 1 + rng.below(300) as usize;
            let text: Vec<u8> = (0..len).map(|_| b"ab"[rng.below(2) as usize]).collect();
            check_all(&text);
        }
    }

    #[test]
    fn sais_large_is_permutation_and_sorted() {
        let mut rng = Rng::new(5);
        let text: Vec<u8> = (0..50_000).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
        let sa = sais(&text);
        let mut seen = vec![false; text.len()];
        for &p in &sa {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
        for w in sa.windows(2) {
            assert!(text[w[0] as usize..] < text[w[1] as usize..]);
        }
    }
}

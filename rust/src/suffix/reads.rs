//! Read corpora: synthetic genome generation (the grouper substitute),
//! pair-end fragment sampling, and fallible FASTA/line-format parsers.
//!
//! The paper's input files are `<sequence number, read>` records of ~200 bp
//! reads from a grouper genome. We generate synthetic reads by sampling
//! substrings of a synthetic reference genome — footprint and scaling
//! behaviour depend only on read count/length statistics, which we match
//! (DESIGN.md §2).
//!
//! **Pair-end (paper §III, Case 6).** A sequencing fragment is read from
//! both ends: the forward read is the fragment's head, the mate is the
//! reverse complement of its tail, and the two land in two separate input
//! files. The sequence-number scheme is fragment-linked and collision-free
//! by construction: fragment `f`'s forward read is `2f`, its mate `2f+1`
//! ([`pair_seq`]/[`fragment_of`]), so two independently parsed files can
//! never collide in the shared KV store and any read's fragment and mate
//! role are recoverable from its sequence number alone.
//!
//! **Length invariant.** The packed suffix index is `seq * OFFSET_RADIX +
//! offset`; a read with `len() + 1 > OFFSET_RADIX` suffixes would alias
//! its tail offsets into the next sequence number and silently corrupt
//! the suffix array. Every ingestion point here ([`Read::new`],
//! [`Read::try_new`], [`Read::from_ascii`], the parsers) enforces
//! `len() < OFFSET_RADIX` — the parsers with a real `io::Error`, the
//! constructors with an unconditional assert.

use std::io;

use crate::mapreduce::io::SplitWriter;
use crate::mapreduce::record::Record;
use crate::suffix::encode::{code_of, string_of, strict_code_of, OFFSET_RADIX};
use crate::util::rng::Rng;

/// Longest ingestible read: one below [`OFFSET_RADIX`], so offsets
/// `0..=len` (including the `$` suffix) all pack without aliasing.
pub const MAX_READ_LEN: usize = (OFFSET_RADIX - 1) as usize;

/// One sequencing read: a global sequence number plus base codes (0..4,
/// no terminator — the terminator is implicit, `$` = code 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Read {
    pub seq: u64,
    pub codes: Vec<u8>,
}

impl Read {
    /// Construct from trusted codes. Panics (in every profile) if the
    /// read is too long to pack — see [`Read::try_new`] for the fallible
    /// ingestion variant.
    pub fn new(seq: u64, codes: Vec<u8>) -> Self {
        assert!(
            codes.len() <= MAX_READ_LEN,
            "read {seq} has {} bp; the packed index holds offsets below {OFFSET_RADIX}",
            codes.len()
        );
        Self { seq, codes }
    }

    /// Fallible construction for untrusted input: rejects reads whose
    /// `len() + 1` suffixes would overflow the packed-index offset radix.
    pub fn try_new(seq: u64, codes: Vec<u8>) -> io::Result<Self> {
        if codes.len() > MAX_READ_LEN {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "read {seq} has {} bp but the packed suffix index only holds \
                     offsets below {OFFSET_RADIX}; split or truncate the read",
                    codes.len()
                ),
            ));
        }
        Ok(Self { seq, codes })
    }

    pub fn from_ascii(seq: u64, s: &[u8]) -> Self {
        Self::new(seq, s.iter().map(|&c| code_of(c)).collect())
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Number of suffixes this read contributes (offsets 0..=len, the last
    /// being the lone `$`).
    pub fn suffix_count(&self) -> usize {
        self.len() + 1
    }

    pub fn to_ascii(&self) -> String {
        string_of(&self.codes)
    }

    /// On-wire/disk size of the `<seq, read>` record (paper's accounting:
    /// 8-byte sequence number + one byte per character).
    pub fn record_bytes(&self) -> u64 {
        8 + self.len() as u64
    }
}

// ---------------------------------------------------------------------
// pair-end numbering
// ---------------------------------------------------------------------

/// Which end of the fragment a read comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mate {
    /// The fragment's head, read forward (file 1).
    Forward,
    /// The fragment's tail, read as its reverse complement (file 2).
    Reverse,
}

/// Fragment-linked sequence number: fragment `f`'s forward read is `2f`,
/// its reverse mate `2f + 1`. Collision-free across the two input files
/// by construction, with no need to know either file's size up front.
#[inline]
pub fn pair_seq(fragment: u64, mate: Mate) -> u64 {
    fragment * 2
        + match mate {
            Mate::Forward => 0,
            Mate::Reverse => 1,
        }
}

/// Recover `(fragment, mate)` from a pair-numbered sequence number.
#[inline]
pub fn fragment_of(seq: u64) -> (u64, Mate) {
    (seq / 2, if seq % 2 == 0 { Mate::Forward } else { Mate::Reverse })
}

/// A↔T, C↔G on codes.
#[inline]
pub fn complement(code: u8) -> u8 {
    match code {
        1 => 4,
        2 => 3,
        3 => 2,
        4 => 1,
        other => other,
    }
}

/// Reverse complement of a code slice (the mate's view of a fragment
/// tail).
pub fn reverse_complement(codes: &[u8]) -> Vec<u8> {
    codes.iter().rev().map(|&c| complement(c)).collect()
}

/// Both reads of one fragment: the forward read is the fragment's first
/// `read_len` bases, the mate is the reverse complement of its last
/// `read_len` bases (they overlap when the fragment is shorter than two
/// read lengths). Sequence numbers follow [`pair_seq`].
pub fn paired_reads_from_fragment(fragment_id: u64, frag: &[u8], read_len: usize) -> (Read, Read) {
    let take = read_len.min(frag.len());
    let fwd = Read::new(pair_seq(fragment_id, Mate::Forward), frag[..take].to_vec());
    let rev = Read::new(
        pair_seq(fragment_id, Mate::Reverse),
        reverse_complement(&frag[frag.len() - take..]),
    );
    (fwd, rev)
}

// ---------------------------------------------------------------------
// synthetic corpora
// ---------------------------------------------------------------------

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    /// Reads per file (pair-end: fragments, i.e. reads per *each* file).
    pub n_reads: usize,
    pub read_len: usize,
    /// +- jitter on read length (paper: "about 200 bp").
    pub len_jitter: usize,
    /// GC content of the synthetic reference (grouper ≈ 0.42).
    pub gc_content: f64,
    /// Reference genome length to sample reads from.
    pub genome_len: usize,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        Self {
            n_reads: 10_000,
            read_len: 100,
            len_jitter: 4,
            gc_content: 0.42,
            genome_len: 1 << 20,
            seed: 0x5EED,
        }
    }
}

/// Synthetic reference genome as base codes 1..4.
pub fn synth_genome(len: usize, gc: f64, rng: &mut Rng) -> Vec<u8> {
    (0..len)
        .map(|_| {
            let r = rng.f64();
            if r < gc / 2.0 {
                2 // C
            } else if r < gc {
                3 // G
            } else if r < gc + (1.0 - gc) / 2.0 {
                1 // A
            } else {
                4 // T
            }
        })
        .collect()
}

fn jittered_len(spec: &CorpusSpec, rng: &mut Rng) -> usize {
    let jitter = if spec.len_jitter > 0 {
        rng.below(2 * spec.len_jitter as u64 + 1) as i64 - spec.len_jitter as i64
    } else {
        0
    };
    ((spec.read_len as i64 + jitter).max(1) as usize).min(MAX_READ_LEN)
}

/// Sample a read corpus from a synthetic genome (single-direction file).
pub fn synth_corpus(spec: &CorpusSpec) -> Vec<Read> {
    let mut rng = Rng::new(spec.seed);
    let genome = synth_genome(spec.genome_len, spec.gc_content, &mut rng);
    let mut reads = Vec::with_capacity(spec.n_reads);
    for i in 0..spec.n_reads {
        let len = jittered_len(spec, &mut rng).min(genome.len());
        let start = rng.below((genome.len() - len + 1) as u64) as usize;
        reads.push(Read::new(i as u64, genome[start..start + len].to_vec()));
    }
    reads
}

/// Pair-end corpora (paper §III, Case 6): two input files over the SAME
/// sampled fragments. Each fragment is `~2.5×` read length; file 1 holds
/// its head read forward, file 2 the reverse complement of its tail, and
/// sequence numbers are fragment-linked via [`pair_seq`] — so the two
/// files are genuinely two views of one library, not two independent
/// corpora.
pub fn synth_paired_corpus(spec: &CorpusSpec) -> (Vec<Read>, Vec<Read>) {
    let mut rng = Rng::new(spec.seed);
    let genome = synth_genome(spec.genome_len, spec.gc_content, &mut rng);
    let mut fwd = Vec::with_capacity(spec.n_reads);
    let mut rev = Vec::with_capacity(spec.n_reads);
    for i in 0..spec.n_reads {
        let read_len = jittered_len(spec, &mut rng);
        // fragment = head read + inner gap + tail read (insert ≈ 2.5 L)
        let frag_len = (read_len * 2 + spec.read_len / 2).min(genome.len());
        let start = rng.below((genome.len() - frag_len + 1) as u64) as usize;
        let frag = &genome[start..start + frag_len];
        let (f, r) = paired_reads_from_fragment(i as u64, frag, read_len);
        fwd.push(f);
        rev.push(r);
    }
    (fwd, rev)
}

/// Total bytes of the `<seq, read>` records — the paper's "input size".
pub fn corpus_bytes(reads: &[Read]) -> u64 {
    reads.iter().map(|r| r.record_bytes()).sum()
}

/// The job-input record of one read: key = sequence number (8 B
/// big-endian), value = base codes.
pub fn read_record(read: &Read) -> Record {
    Record::new(read.seq.to_be_bytes().to_vec(), read.codes.clone())
}

/// Spool a corpus to a disk-backed record file through `w` — the
/// paper's HDFS input file of `<seq, read>` records. The scheme's jobs
/// stream their splits out of this file instead of holding a second,
/// record-shaped copy of the corpus in memory.
pub fn spool_read_records(reads: &[Read], w: &mut SplitWriter) -> io::Result<()> {
    for r in reads {
        w.push(&read_record(r))?;
    }
    Ok(())
}

/// Total suffix bytes if materialized (TeraSort's self-expansion): for a
/// read of length l, suffixes at offsets 0..=l have lengths l+1, l, ..., 1
/// (including the terminator) plus an 8-byte index each.
pub fn materialized_suffix_bytes(reads: &[Read]) -> u64 {
    reads
        .iter()
        .map(|r| {
            let l = r.len() as u64;
            (l + 1) * (l + 2) / 2 + 8 * (l + 1)
        })
        .sum()
}

// ---------------------------------------------------------------------
// parsing (untrusted input)
// ---------------------------------------------------------------------

/// What the parser does with an ambiguous `N`/`n` base. An explicit
/// policy instead of the encoder silently remapping: real pipelines
/// either mask (the paper's grouper corpus is N-free after masking) or
/// reject, and which one is a per-ingest decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParsePolicy {
    /// Mask `N`/`n` to `A` (code 1).
    MaskN,
    /// Reject any character outside `ACGT` (either case), `N` included.
    Strict,
}

fn parse_line(line: &[u8], policy: ParsePolicy, out: &mut Vec<u8>) -> io::Result<()> {
    for &c in line {
        match strict_code_of(c) {
            // code 0 is '$', the INTERNAL terminator sentinel — an input
            // file may never smuggle it into a read body
            Some(code) if code != 0 => out.push(code),
            None if policy == ParsePolicy::MaskN && (c == b'N' || c == b'n') => out.push(1),
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("invalid read character {:?} (0x{c:02x})", c as char),
                ))
            }
        }
    }
    Ok(())
}

/// Parse a FASTA or plain-lines byte buffer into code vectors (one per
/// `>`-delimited record; headerless input is one concatenated record).
/// Errors on invalid characters (per `policy`), on records longer than
/// [`MAX_READ_LEN`], and on headers with no sequence at all — an empty
/// record silently dropped would shift every later record's index,
/// which the pair-end ingest turns into wrong mate pairings.
fn parse_records(data: &[u8], policy: ParsePolicy) -> io::Result<Vec<Vec<u8>>> {
    let mut records = Vec::new();
    let mut current: Vec<u8> = Vec::new();
    let mut open_header = false;
    let empty_record = |n: usize| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            format!("record {n} has a header but no sequence"),
        )
    };
    for line in data.split(|&b| b == b'\n') {
        let line = line.strip_suffix(b"\r").unwrap_or(line);
        if line.is_empty() {
            continue;
        }
        if line[0] == b'>' {
            if open_header && current.is_empty() {
                return Err(empty_record(records.len()));
            }
            if !current.is_empty() {
                records.push(std::mem::take(&mut current));
            }
            open_header = true;
        } else {
            parse_line(line, policy, &mut current)?;
            if current.len() > MAX_READ_LEN {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "record {} is {} bp; the packed suffix index only holds \
                         offsets below {OFFSET_RADIX}",
                        records.len(),
                        current.len()
                    ),
                ));
            }
        }
    }
    if open_header && current.is_empty() {
        return Err(empty_record(records.len()));
    }
    if !current.is_empty() {
        records.push(current);
    }
    Ok(records)
}

/// Parse one single-end FASTA/line file into reads numbered consecutively
/// from `seq_base`.
pub fn parse_fasta(data: &[u8], seq_base: u64, policy: ParsePolicy) -> io::Result<Vec<Read>> {
    let records = parse_records(data, policy)?;
    records
        .into_iter()
        .enumerate()
        .map(|(i, codes)| Read::try_new(seq_base + i as u64, codes))
        .collect()
}

/// Two-file pair-end ingest: record `i` of `fwd_data` and record `i` of
/// `rev_data` are the two mates of fragment `i`, numbered with the
/// collision-free [`pair_seq`] scheme. Errors if the files hold different
/// record counts — a truncated mate file would otherwise silently break
/// every downstream pairing.
pub fn parse_paired_files(
    fwd_data: &[u8],
    rev_data: &[u8],
    policy: ParsePolicy,
) -> io::Result<(Vec<Read>, Vec<Read>)> {
    let fwd_recs = parse_records(fwd_data, policy)?;
    let rev_recs = parse_records(rev_data, policy)?;
    if fwd_recs.len() != rev_recs.len() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "pair-end files disagree: {} forward reads vs {} mates",
                fwd_recs.len(),
                rev_recs.len()
            ),
        ));
    }
    let number = |recs: Vec<Vec<u8>>, mate: Mate| -> io::Result<Vec<Read>> {
        recs.into_iter()
            .enumerate()
            .map(|(i, codes)| Read::try_new(pair_seq(i as u64, mate), codes))
            .collect()
    };
    Ok((number(fwd_recs, Mate::Forward)?, number(rev_recs, Mate::Reverse)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_sized() {
        let spec = CorpusSpec { n_reads: 100, read_len: 50, ..Default::default() };
        let a = synth_corpus(&spec);
        let b = synth_corpus(&spec);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        for r in &a {
            assert!((50 - 4..=50 + 4).contains(&r.len()));
            assert!(r.codes.iter().all(|&c| (1..=4).contains(&c)));
        }
        // sequence numbers are consecutive from 0
        assert!(a.iter().enumerate().all(|(i, r)| r.seq == i as u64));
    }

    #[test]
    fn gc_content_close() {
        let mut rng = Rng::new(1);
        let g = synth_genome(200_000, 0.42, &mut rng);
        let gc = g.iter().filter(|&&c| c == 2 || c == 3).count() as f64 / g.len() as f64;
        assert!((gc - 0.42).abs() < 0.01, "gc={gc}");
    }

    #[test]
    fn pair_numbering_roundtrips_and_never_collides() {
        for f in [0u64, 1, 2, 50, 1 << 40] {
            assert_eq!(fragment_of(pair_seq(f, Mate::Forward)), (f, Mate::Forward));
            assert_eq!(fragment_of(pair_seq(f, Mate::Reverse)), (f, Mate::Reverse));
            assert_ne!(pair_seq(f, Mate::Forward), pair_seq(f, Mate::Reverse));
        }
        // adjacent fragments stay disjoint
        assert_ne!(pair_seq(3, Mate::Reverse), pair_seq(4, Mate::Forward));
    }

    #[test]
    fn fragment_mates_are_exact_reverse_complements() {
        // fragment == read length: the mates fully overlap, so the
        // reverse read must be the exact reverse complement of the
        // forward one — the strongest possible linkage check.
        let frag = vec![1u8, 2, 3, 4, 4, 1, 2];
        let (fwd, rev) = paired_reads_from_fragment(9, &frag, frag.len());
        assert_eq!(fwd.seq, 18);
        assert_eq!(rev.seq, 19);
        assert_eq!(fwd.codes, frag);
        assert_eq!(rev.codes, reverse_complement(&frag));
        assert_eq!(reverse_complement(&rev.codes), frag); // involution
    }

    #[test]
    fn paired_corpus_is_fragment_linked() {
        let spec = CorpusSpec {
            n_reads: 50,
            read_len: 30,
            len_jitter: 0,
            genome_len: 10_000,
            ..Default::default()
        };
        let (fwd, rev) = synth_paired_corpus(&spec);
        assert_eq!(fwd.len(), 50);
        assert_eq!(rev.len(), 50);
        for (i, (f, r)) in fwd.iter().zip(&rev).enumerate() {
            // interleaved, collision-free numbering
            assert_eq!(f.seq, pair_seq(i as u64, Mate::Forward));
            assert_eq!(r.seq, f.seq + 1);
            assert_eq!(fragment_of(f.seq), (i as u64, Mate::Forward));
            assert_eq!(fragment_of(r.seq), (i as u64, Mate::Reverse));
            assert_eq!(f.len(), 30);
            assert_eq!(r.len(), 30);
        }
        // deterministic
        let (fwd2, rev2) = synth_paired_corpus(&spec);
        assert_eq!(fwd, fwd2);
        assert_eq!(rev, rev2);
    }

    #[test]
    fn paired_reads_share_their_fragment() {
        // read length == fragment length is forced by a genome exactly
        // one fragment long: mates must be exact reverse complements.
        let spec = CorpusSpec {
            n_reads: 10,
            read_len: 64,
            len_jitter: 0,
            genome_len: 64, // fragment clamps to the whole genome
            ..Default::default()
        };
        let (fwd, rev) = synth_paired_corpus(&spec);
        for (f, r) in fwd.iter().zip(&rev) {
            assert_eq!(reverse_complement(&f.codes), r.codes);
        }
    }

    #[test]
    fn expansion_factor_about_half_len() {
        // paper: self-expansion (1+200)/2 ≈ 100× for 200 bp reads.
        let spec = CorpusSpec {
            n_reads: 200,
            read_len: 200,
            len_jitter: 0,
            ..Default::default()
        };
        let reads = synth_corpus(&spec);
        let input = corpus_bytes(&reads);
        let suffixes = materialized_suffix_bytes(&reads);
        let factor = suffixes as f64 / input as f64;
        assert!((90.0..110.0).contains(&factor), "factor={factor}");
    }

    #[test]
    fn spooled_read_records_roundtrip() {
        let spec = CorpusSpec { n_reads: 40, read_len: 30, ..Default::default() };
        let reads = synth_corpus(&spec);
        let dir = std::env::temp_dir().join(format!("samr-readspool-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = SplitWriter::create(dir.join("reads"), 256).unwrap();
        spool_read_records(&reads, &mut w).unwrap();
        assert_eq!(w.bytes(), reads.iter().map(|r| read_record(r).wire_bytes()).sum::<u64>());
        let splits = w.finish().unwrap();
        assert!(splits.len() > 1, "256 B budget must cut multiple splits");
        let mut got = Vec::new();
        for s in &splits {
            let mut rd = s.open().unwrap();
            while let Some(rec) = rd.next_record().unwrap() {
                let seq = u64::from_be_bytes(rec.key[..8].try_into().unwrap());
                got.push(Read::new(seq, rec.value));
            }
        }
        assert_eq!(got, reads, "spooled records must reconstruct the corpus in order");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fasta_parse() {
        let data = b">r1\nACGT\nACG\n>r2\nTTT\n";
        let reads = parse_fasta(data, 10, ParsePolicy::Strict).unwrap();
        assert_eq!(reads.len(), 2);
        assert_eq!(reads[0].to_ascii(), "ACGTACG");
        assert_eq!(reads[1].to_ascii(), "TTT");
        assert_eq!(reads[1].seq, 11);
    }

    #[test]
    fn plain_lines_parse() {
        let reads = parse_fasta(b"ACG\nTGA\n", 0, ParsePolicy::Strict).unwrap();
        assert_eq!(reads.len(), 1); // no '>' headers: one concatenated read
    }

    #[test]
    fn empty_records_are_errors_not_skipped() {
        // a header with no sequence, silently dropped, would shift every
        // later record's index — and the pair-end ingest pairs by index,
        // so it would mispair every subsequent mate with no error
        for data in [&b">a\n>b\nACGT\n"[..], b">a\nACGT\n>b\n", b">only\n"] {
            let err = parse_fasta(data, 0, ParsePolicy::Strict).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{data:?}");
            assert!(err.to_string().contains("no sequence"), "{err}");
        }
        // an empty FILE is fine — zero records, not an empty record
        assert!(parse_fasta(b"", 0, ParsePolicy::Strict).unwrap().is_empty());
        // and a mid-file empty record in one mate file can no longer
        // shift the pairing silently
        let err = parse_paired_files(b">f0\nAC\n>f1\n>f2\nGT\n", b">r0\nTT\n>r1\nGG\n>r2\nCC\n", ParsePolicy::Strict)
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn parser_n_policy_is_explicit() {
        // masked: N -> A
        let masked = parse_fasta(b">r\nANT\n", 0, ParsePolicy::MaskN).unwrap();
        assert_eq!(masked[0].to_ascii(), "AAT");
        // strict: a real io::Error, not a process abort
        let err = parse_fasta(b">r\nANT\n", 0, ParsePolicy::Strict).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // garbage fails under BOTH policies
        for policy in [ParsePolicy::MaskN, ParsePolicy::Strict] {
            let err = parse_fasta(b">r\nACXGT\n", 0, policy).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{policy:?}");
            assert!(err.to_string().contains('X'), "{err}");
            // and so does '$' — the internal terminator sentinel must
            // never enter a read body from an input file
            let err = parse_fasta(b">r\nAC$GT\n", 0, policy).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{policy:?}");
        }
    }

    #[test]
    fn parser_rejects_oversized_reads() {
        // 1000+ bp read: construction must fail loudly at ingestion —
        // in release mode too — instead of aliasing packed indexes into
        // the next sequence number and emitting a wrong suffix array.
        let mut data = b">huge\n".to_vec();
        data.extend(vec![b'A'; OFFSET_RADIX as usize]); // len == 1000 > MAX_READ_LEN
        data.push(b'\n');
        let err = parse_fasta(&data, 0, ParsePolicy::Strict).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("1000"), "{err}");
        // the boundary length is still fine
        let mut ok = b">edge\n".to_vec();
        ok.extend(vec![b'A'; MAX_READ_LEN]);
        let reads = parse_fasta(&ok, 0, ParsePolicy::Strict).unwrap();
        assert_eq!(reads[0].len(), MAX_READ_LEN);
        assert_eq!(reads[0].suffix_count(), OFFSET_RADIX as usize);
    }

    #[test]
    fn try_new_rejects_what_new_panics_on() {
        assert!(Read::try_new(0, vec![1; MAX_READ_LEN]).is_ok());
        let err = Read::try_new(7, vec![1; MAX_READ_LEN + 1]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    #[should_panic(expected = "packed index")]
    fn new_rejects_oversized_read_in_every_profile() {
        // plain assert!, not debug_assert! — release builds must refuse too
        let _ = Read::new(0, vec![1; MAX_READ_LEN + 1]);
    }

    #[test]
    fn paired_files_parse_and_pair() {
        let fwd = b">f0\nACGT\n>f1\nGGCC\n";
        let rev = b">r0\nTTTT\n>r1\nCACA\n";
        let (f, r) = parse_paired_files(fwd, rev, ParsePolicy::Strict).unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(r.len(), 2);
        assert_eq!(f[0].seq, pair_seq(0, Mate::Forward));
        assert_eq!(r[0].seq, pair_seq(0, Mate::Reverse));
        assert_eq!(f[1].seq, 2);
        assert_eq!(r[1].seq, 3);
        // truncated mate file is an error, not a silent mispairing
        let err = parse_paired_files(fwd, b">r0\nTTTT\n", ParsePolicy::Strict).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }
}

//! Read corpora: synthetic genome generation (the grouper substitute) and
//! a minimal FASTA/line-format parser.
//!
//! The paper's input files are `<sequence number, read>` records of ~200 bp
//! reads from a grouper genome. We generate synthetic paired-end reads by
//! sampling substrings of a synthetic reference genome — footprint and
//! scaling behaviour depend only on read count/length statistics, which we
//! match (DESIGN.md §2).

use crate::suffix::encode::{code_of, string_of};
use crate::util::rng::Rng;

/// One sequencing read: a global sequence number plus base codes (0..4,
/// no terminator — the terminator is implicit, `$` = code 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Read {
    pub seq: u64,
    pub codes: Vec<u8>,
}

impl Read {
    pub fn new(seq: u64, codes: Vec<u8>) -> Self {
        Self { seq, codes }
    }

    pub fn from_ascii(seq: u64, s: &[u8]) -> Self {
        Self { seq, codes: s.iter().map(|&c| code_of(c)).collect() }
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Number of suffixes this read contributes (offsets 0..=len, the last
    /// being the lone `$`).
    pub fn suffix_count(&self) -> usize {
        self.len() + 1
    }

    pub fn to_ascii(&self) -> String {
        string_of(&self.codes)
    }

    /// On-wire/disk size of the `<seq, read>` record (paper's accounting:
    /// 8-byte sequence number + one byte per character).
    pub fn record_bytes(&self) -> u64 {
        8 + self.len() as u64
    }
}

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub n_reads: usize,
    pub read_len: usize,
    /// +- jitter on read length (paper: "about 200 bp").
    pub len_jitter: usize,
    /// GC content of the synthetic reference (grouper ≈ 0.42).
    pub gc_content: f64,
    /// Reference genome length to sample reads from.
    pub genome_len: usize,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        Self {
            n_reads: 10_000,
            read_len: 100,
            len_jitter: 4,
            gc_content: 0.42,
            genome_len: 1 << 20,
            seed: 0x5EED,
        }
    }
}

/// Synthetic reference genome as base codes 1..4.
pub fn synth_genome(len: usize, gc: f64, rng: &mut Rng) -> Vec<u8> {
    (0..len)
        .map(|_| {
            let r = rng.f64();
            if r < gc / 2.0 {
                2 // C
            } else if r < gc {
                3 // G
            } else if r < gc + (1.0 - gc) / 2.0 {
                1 // A
            } else {
                4 // T
            }
        })
        .collect()
}

/// Sample a read corpus from a synthetic genome (single-direction file).
pub fn synth_corpus(spec: &CorpusSpec) -> Vec<Read> {
    let mut rng = Rng::new(spec.seed);
    let genome = synth_genome(spec.genome_len, spec.gc_content, &mut rng);
    sample_reads(&genome, spec, 0, &mut rng, false)
}

/// Paired-end corpora (paper §III): one file of forward reads, one file of
/// the same fragments read from the opposite direction (reverse
/// complement). Sequence numbers of the pair files are disjoint.
pub fn synth_paired_corpus(spec: &CorpusSpec) -> (Vec<Read>, Vec<Read>) {
    let mut rng = Rng::new(spec.seed);
    let genome = synth_genome(spec.genome_len, spec.gc_content, &mut rng);
    let fwd = sample_reads(&genome, spec, 0, &mut rng, false);
    let rev = sample_reads(&genome, spec, spec.n_reads as u64, &mut rng, true);
    (fwd, rev)
}

fn sample_reads(
    genome: &[u8],
    spec: &CorpusSpec,
    seq_base: u64,
    rng: &mut Rng,
    reverse_complement: bool,
) -> Vec<Read> {
    let mut reads = Vec::with_capacity(spec.n_reads);
    for i in 0..spec.n_reads {
        let jitter = if spec.len_jitter > 0 {
            rng.below(2 * spec.len_jitter as u64 + 1) as i64 - spec.len_jitter as i64
        } else {
            0
        };
        let len = ((spec.read_len as i64 + jitter).max(1) as usize).min(genome.len());
        let start = rng.below((genome.len() - len + 1) as u64) as usize;
        let mut codes = genome[start..start + len].to_vec();
        if reverse_complement {
            codes.reverse();
            for c in codes.iter_mut() {
                *c = complement(*c);
            }
        }
        reads.push(Read::new(seq_base + i as u64, codes));
    }
    reads
}

/// A↔T, C↔G on codes.
#[inline]
pub fn complement(code: u8) -> u8 {
    match code {
        1 => 4,
        2 => 3,
        3 => 2,
        4 => 1,
        other => other,
    }
}

/// Total bytes of the `<seq, read>` records — the paper's "input size".
pub fn corpus_bytes(reads: &[Read]) -> u64 {
    reads.iter().map(|r| r.record_bytes()).sum()
}

/// Total suffix bytes if materialized (TeraSort's self-expansion): for a
/// read of length l, suffixes at offsets 0..=l have lengths l+1, l, ..., 1
/// (including the terminator) plus an 8-byte index each.
pub fn materialized_suffix_bytes(reads: &[Read]) -> u64 {
    reads
        .iter()
        .map(|r| {
            let l = r.len() as u64;
            (l + 1) * (l + 2) / 2 + 8 * (l + 1)
        })
        .sum()
}

/// Parse a FASTA or plain-lines byte buffer into reads.
pub fn parse_fasta(data: &[u8], seq_base: u64) -> Vec<Read> {
    let mut reads = Vec::new();
    let mut current: Vec<u8> = Vec::new();
    let mut seq = seq_base;
    let flush = |current: &mut Vec<u8>, seq: &mut u64, reads: &mut Vec<Read>| {
        if !current.is_empty() {
            reads.push(Read::new(*seq, std::mem::take(current)));
            *seq += 1;
        }
    };
    for line in data.split(|&b| b == b'\n') {
        let line = line.strip_suffix(b"\r").unwrap_or(line);
        if line.is_empty() {
            continue;
        }
        if line[0] == b'>' {
            flush(&mut current, &mut seq, &mut reads);
        } else {
            current.extend(line.iter().map(|&c| code_of(c)));
        }
    }
    flush(&mut current, &mut seq, &mut reads);
    reads
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_sized() {
        let spec = CorpusSpec { n_reads: 100, read_len: 50, ..Default::default() };
        let a = synth_corpus(&spec);
        let b = synth_corpus(&spec);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        for r in &a {
            assert!((50 - 4..=50 + 4).contains(&r.len()));
            assert!(r.codes.iter().all(|&c| (1..=4).contains(&c)));
        }
        // sequence numbers are consecutive from 0
        assert!(a.iter().enumerate().all(|(i, r)| r.seq == i as u64));
    }

    #[test]
    fn gc_content_close() {
        let mut rng = Rng::new(1);
        let g = synth_genome(200_000, 0.42, &mut rng);
        let gc = g.iter().filter(|&&c| c == 2 || c == 3).count() as f64 / g.len() as f64;
        assert!((gc - 0.42).abs() < 0.01, "gc={gc}");
    }

    #[test]
    fn paired_reads_are_reverse_complements_statistically() {
        let spec = CorpusSpec {
            n_reads: 50,
            read_len: 30,
            len_jitter: 0,
            genome_len: 10_000,
            ..Default::default()
        };
        let (fwd, rev) = synth_paired_corpus(&spec);
        assert_eq!(fwd.len(), 50);
        assert_eq!(rev.len(), 50);
        // disjoint sequence numbers
        assert_eq!(rev[0].seq, 50);
        // reverse strand has complementary GC/AT composition overall
        let at = |rs: &[Read]| {
            rs.iter()
                .flat_map(|r| &r.codes)
                .filter(|&&c| c == 1)
                .count()
        };
        let fwd_a = at(&fwd);
        let rev_t: usize = rev
            .iter()
            .flat_map(|r| &r.codes)
            .filter(|&&c| c == 4)
            .count();
        // complements map every A on the forward strand to a T when the
        // same position is read in reverse; counts need not be identical
        // (different fragments) but should be within noise of each other.
        let diff = (fwd_a as f64 - rev_t as f64).abs() / fwd_a as f64;
        assert!(diff < 0.25, "fwd_a={fwd_a} rev_t={rev_t}");
    }

    #[test]
    fn expansion_factor_about_half_len() {
        // paper: self-expansion (1+200)/2 ≈ 100× for 200 bp reads.
        let spec = CorpusSpec {
            n_reads: 200,
            read_len: 200,
            len_jitter: 0,
            ..Default::default()
        };
        let reads = synth_corpus(&spec);
        let input = corpus_bytes(&reads);
        let suffixes = materialized_suffix_bytes(&reads);
        let factor = suffixes as f64 / input as f64;
        assert!((90.0..110.0).contains(&factor), "factor={factor}");
    }

    #[test]
    fn fasta_parse() {
        let data = b">r1\nACGT\nACG\n>r2\nTTT\n";
        let reads = parse_fasta(data, 10);
        assert_eq!(reads.len(), 2);
        assert_eq!(reads[0].to_ascii(), "ACGTACG");
        assert_eq!(reads[1].to_ascii(), "TTT");
        assert_eq!(reads[1].seq, 11);
    }

    #[test]
    fn plain_lines_parse() {
        let reads = parse_fasta(b"ACG\nTGA\n", 0);
        assert_eq!(reads.len(), 1); // no '>' headers: one concatenated read
    }
}

//! LCP array construction (Kasai's algorithm) — the companion structure
//! of the *enhanced* suffix arrays the paper builds on ([3], Abouelhoda
//! et al.): `lcp[i]` = longest common prefix of the suffixes at SA[i-1]
//! and SA[i].

use crate::suffix::sa;

/// Kasai's O(n) LCP construction from a text and its suffix array.
/// `lcp[0] = 0`; `lcp[i]` refers to the pair (SA[i-1], SA[i]).
pub fn kasai(text: &[u8], sa: &[u32]) -> Vec<u32> {
    let n = text.len();
    assert_eq!(sa.len(), n);
    let mut rank = vec![0u32; n];
    for (i, &p) in sa.iter().enumerate() {
        rank[p as usize] = i as u32;
    }
    let mut lcp = vec![0u32; n];
    let mut h = 0usize;
    for i in 0..n {
        let r = rank[i] as usize;
        if r > 0 {
            let j = sa[r - 1] as usize;
            while i + h < n && j + h < n && text[i + h] == text[j + h] {
                h += 1;
            }
            lcp[r] = h as u32;
            h = h.saturating_sub(1);
        } else {
            h = 0;
        }
    }
    lcp
}

/// Convenience: SA + LCP of a text in one call.
pub fn sa_with_lcp(text: &[u8]) -> (Vec<u32>, Vec<u32>) {
    let sa = sa::sais(text);
    let lcp = kasai(text, &sa);
    (sa, lcp)
}

/// Longest repeated substring length via the LCP maximum (a classic
/// enhanced-SA application).
pub fn longest_repeat(text: &[u8]) -> usize {
    if text.len() < 2 {
        return 0;
    }
    let (_, lcp) = sa_with_lcp(text);
    lcp.iter().copied().max().unwrap_or(0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_lcp(a: &[u8], b: &[u8]) -> u32 {
        a.iter().zip(b).take_while(|(x, y)| x == y).count() as u32
    }

    #[test]
    fn banana() {
        // SA(banana) = [5,3,1,0,4,2]; LCP = [0,1,3,0,0,2]
        let (sa, lcp) = sa_with_lcp(b"banana");
        assert_eq!(sa, vec![5, 3, 1, 0, 4, 2]);
        assert_eq!(lcp, vec![0, 1, 3, 0, 0, 2]);
    }

    #[test]
    fn matches_naive_on_random_dna() {
        let mut rng = Rng::new(31);
        for len in [1usize, 2, 10, 100, 500] {
            let text: Vec<u8> = (0..len).map(|_| b"ACGT"[rng.below(4) as usize]).collect();
            let (sa, lcp) = sa_with_lcp(&text);
            assert_eq!(lcp[0], 0);
            for i in 1..sa.len() {
                let want = naive_lcp(&text[sa[i - 1] as usize..], &text[sa[i] as usize..]);
                assert_eq!(lcp[i], want, "i={i} len={len}");
            }
        }
    }

    #[test]
    fn longest_repeat_examples() {
        assert_eq!(longest_repeat(b"banana"), 3); // "ana"
        assert_eq!(longest_repeat(b"ACGT"), 0);
        assert_eq!(longest_repeat(b"AAAA"), 3);
        assert_eq!(longest_repeat(b""), 0);
    }
}

//! # samr — Scalable and Efficient Suffix-Array Construction
//!
//! Reproduction of "Scalable and Efficient Construction of Suffix Array
//! with MapReduce and In-Memory Data Store System" (Wu et al., 2017):
//! an in-process MapReduce runtime with Hadoop's spill/merge mechanics, a
//! Redis-like in-memory data store with the paper's `MGETSUFFIX` command,
//! the TeraSort baseline, the paper's index-only scheme, and the
//! data-store-footprint instrumentation its evaluation is built on.
//! The map/reduce compute hot spots execute AOT-compiled JAX/Pallas
//! kernels through PJRT (see `runtime`).
pub mod bench_support;
pub mod cli;
pub mod cluster;
#[warn(missing_docs)]
pub mod faults;
pub mod footprint;
#[warn(missing_docs)]
pub mod kvstore;
pub mod mapreduce;
pub mod report;
pub mod runtime;
#[warn(missing_docs)]
pub mod scheme;
pub mod simcost;
pub mod suffix;
pub mod terasort;
pub mod testkit;
pub mod util;

//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` produced
//! by `python/compile/aot.py`) and executes them on the XLA CPU client.
//! Python never runs here; HLO *text* is the interchange format.
//!
//! The XLA/PJRT bindings live behind the `pjrt` cargo feature so the
//! default build needs nothing beyond the standard library (the offline
//! vendor set may not carry the `xla` crate). Without the feature every
//! entry point transparently selects the bit-identical pure-Rust
//! fallback (`native`), which is cross-checked against the kernels in
//! `tests/runtime_pjrt.rs` whenever both are available.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each worker thread lazily
//! builds its own engine from the globally configured artifacts directory.

pub mod native;

#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

#[cfg(not(feature = "pjrt"))]
use crate::suffix::reads::Read;

/// Key sentinel used to pad sort blocks; sinks to the tail.
pub const PAD_KEY: i64 = i64::MAX;

/// Runtime error (manifest parsing, kernel compilation, execution).
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    /// Wrap a message.
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Runtime result.
pub type Result<T> = std::result::Result<T, RuntimeError>;

pub(crate) fn rt_err(msg: String) -> RuntimeError {
    RuntimeError::new(msg)
}

/// One `map_encode` variant from the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapEncodeMeta {
    /// Reads per tile.
    pub r: usize,
    /// Padded width (max read length + 1 <= lp).
    pub lp: usize,
    /// Prefix length.
    pub p: usize,
    /// Boundary slots.
    pub nb: usize,
}

/// Parsed manifest: entry name -> variants (meta + file).
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// `map_encode` kernel variants.
    pub map_encode: Vec<(MapEncodeMeta, PathBuf)>,
    /// `group_sort` kernel variants (block size -> file).
    pub group_sort: Vec<(usize, PathBuf)>,
    /// `sample_sort` kernel variants (block size -> file).
    pub sample_sort: Vec<(usize, PathBuf)>,
}

impl Manifest {
    /// Parse `manifest.txt` in `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .map_err(|e| rt_err(format!("reading {}/manifest.txt: {e}", dir.display())))?;
        let mut m = Manifest::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            let mut entry = "";
            for (i, tok) in line.split_whitespace().enumerate() {
                if i == 0 {
                    entry = tok;
                } else if let Some((k, v)) = tok.split_once('=') {
                    kv.insert(k, v);
                }
            }
            let file = dir.join(
                kv.get("file").ok_or_else(|| rt_err(format!("no file= in {line}")))?,
            );
            let geti = |k: &str| -> Result<usize> {
                kv.get(k)
                    .ok_or_else(|| rt_err(format!("missing {k}= in {line}")))?
                    .parse()
                    .map_err(|e| rt_err(format!("bad {k}= in {line}: {e}")))
            };
            match entry {
                "map_encode" => m.map_encode.push((
                    MapEncodeMeta { r: geti("r")?, lp: geti("lp")?, p: geti("p")?, nb: geti("nb")? },
                    file,
                )),
                "group_sort" => m.group_sort.push((geti("n")?, file)),
                "sample_sort" => m.sample_sort.push((geti("n")?, file)),
                other => return Err(rt_err(format!("unknown manifest entry {other}"))),
            }
        }
        Ok(m)
    }
}

/// Global artifacts directory; set once by [`init`].
static ARTIFACTS_DIR: OnceLock<Option<PathBuf>> = OnceLock::new();

/// Configure the runtime. `None` (or a missing manifest) selects the
/// native fallback everywhere. Returns whether PJRT artifacts are active
/// (always `false` without the `pjrt` cargo feature).
pub fn init(dir: Option<&Path>) -> bool {
    let resolved = dir.and_then(|d| {
        if d.join("manifest.txt").exists() {
            Some(d.to_path_buf())
        } else {
            None
        }
    });
    if !cfg!(feature = "pjrt") {
        if resolved.is_some() && ARTIFACTS_DIR.get().is_none() {
            eprintln!(
                "samr: artifacts present but the `pjrt` feature is off; using native fallback"
            );
        }
        let _ = ARTIFACTS_DIR.set(None);
        return false;
    }
    let active = resolved.is_some();
    let _ = ARTIFACTS_DIR.set(resolved);
    active
}

/// Default artifacts location: `$SAMR_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SAMR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Is the PJRT path configured (vs native fallback)?
pub fn pjrt_active() -> bool {
    matches!(ARTIFACTS_DIR.get(), Some(Some(_)))
}

#[cfg(feature = "pjrt")]
pub(crate) fn artifacts_dir() -> Option<PathBuf> {
    match ARTIFACTS_DIR.get() {
        Some(Some(d)) => Some(d.clone()),
        _ => None,
    }
}

/// Output of one map_encode tile (row-major [r][lp]).
pub struct EncodeTile {
    /// Reads per tile (rows).
    pub r: usize,
    /// Padded row width.
    pub lp: usize,
    /// Per-(read, offset) prefix keys.
    pub keys: Vec<i64>,
    /// Per-(read, offset) packed indexes.
    pub indexes: Vec<i64>,
    /// Per-(read, offset) partition numbers.
    pub partitions: Vec<i32>,
    /// 1 where the (read, offset) cell is a real suffix, 0 for padding.
    pub valid: Vec<i32>,
}

/// Stub engine used when the `pjrt` feature is disabled. Never
/// constructed — [`with_engine`] always passes `None` — but keeps every
/// call site compiling against the same API as the real engine.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    never: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always fails: the `pjrt` feature is disabled.
    pub fn load(_dir: &Path) -> Result<Engine> {
        Err(rt_err("built without the `pjrt` feature".into()))
    }

    /// See [`Manifest`].
    pub fn manifest(&self) -> &Manifest {
        match self.never {}
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        match self.never {}
    }

    /// Tile geometry for these inputs.
    pub fn map_encode_meta(
        &self,
        _max_read_len: usize,
        _prefix_len: usize,
        _n_boundaries: usize,
    ) -> Option<MapEncodeMeta> {
        match self.never {}
    }

    /// Run the `map_encode` entry point over one tile of reads.
    pub fn map_encode_tile(
        &self,
        _reads: &[&Read],
        _boundaries: &[i64],
        _prefix_len: usize,
    ) -> Result<EncodeTile> {
        match self.never {}
    }

    /// Sort (key, index) pairs lexicographically.
    pub fn group_sort(&self, _keys: &mut Vec<i64>, _indexes: &mut Vec<i64>) -> Result<()> {
        match self.never {}
    }

    /// Ascending key sort.
    pub fn sample_sort(&self, _keys: &mut Vec<i64>) -> Result<()> {
        match self.never {}
    }

    /// Largest group_sort block available.
    pub fn max_group_block(&self) -> usize {
        match self.never {}
    }

    /// Block size the reduce path should chunk to.
    pub fn preferred_group_block(&self) -> usize {
        match self.never {}
    }
}

/// Run `f` with this thread's engine (compiling artifacts on first use),
/// or `None` if PJRT is not configured.
#[cfg(feature = "pjrt")]
pub fn with_engine<T>(f: impl FnOnce(Option<&Engine>) -> T) -> T {
    pjrt::with_thread_engine(f)
}

/// Run `f` with this thread's engine — always the native fallback
/// (`None`) in builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub fn with_engine<T>(f: impl FnOnce(Option<&Engine>) -> T) -> T {
    f(None)
}

//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` produced
//! by `python/compile/aot.py`) and executes them on the XLA CPU client.
//! Python never runs here; HLO *text* is the interchange format (see
//! DESIGN.md and /opt/xla-example/README.md for why not serialized protos).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each worker thread lazily
//! builds its own engine from the globally configured artifacts directory.
//! Every entry point has a bit-identical pure-Rust fallback (`native`),
//! used when artifacts are absent and cross-checked in tests.

pub mod native;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use once_cell::sync::OnceCell;

use crate::suffix::reads::Read;

/// Key sentinel used to pad sort blocks; sinks to the tail.
pub const PAD_KEY: i64 = i64::MAX;

/// One `map_encode` variant from the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapEncodeMeta {
    /// Reads per tile.
    pub r: usize,
    /// Padded width (max read length + 1 <= lp).
    pub lp: usize,
    /// Prefix length.
    pub p: usize,
    /// Boundary slots.
    pub nb: usize,
}

/// Parsed manifest: entry name -> variants (meta + file).
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub map_encode: Vec<(MapEncodeMeta, PathBuf)>,
    pub group_sort: Vec<(usize, PathBuf)>,
    pub sample_sort: Vec<(usize, PathBuf)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt", dir.display()))?;
        let mut m = Manifest::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            let mut entry = "";
            for (i, tok) in line.split_whitespace().enumerate() {
                if i == 0 {
                    entry = tok;
                } else if let Some((k, v)) = tok.split_once('=') {
                    kv.insert(k, v);
                }
            }
            let file = dir.join(kv.get("file").ok_or_else(|| anyhow!("no file= in {line}"))?);
            let geti = |k: &str| -> Result<usize> {
                kv.get(k)
                    .ok_or_else(|| anyhow!("missing {k}= in {line}"))?
                    .parse()
                    .map_err(|e| anyhow!("bad {k}= in {line}: {e}"))
            };
            match entry {
                "map_encode" => m.map_encode.push((
                    MapEncodeMeta { r: geti("r")?, lp: geti("lp")?, p: geti("p")?, nb: geti("nb")? },
                    file,
                )),
                "group_sort" => m.group_sort.push((geti("n")?, file)),
                "sample_sort" => m.sample_sort.push((geti("n")?, file)),
                other => bail!("unknown manifest entry {other}"),
            }
        }
        Ok(m)
    }
}

/// Global artifacts directory; set once by [`init`].
static ARTIFACTS_DIR: OnceCell<Option<PathBuf>> = OnceCell::new();

/// Configure the runtime. `None` (or a missing manifest) selects the
/// native fallback everywhere. Returns whether PJRT artifacts are active.
pub fn init(dir: Option<&Path>) -> bool {
    let resolved = dir.and_then(|d| {
        if d.join("manifest.txt").exists() {
            Some(d.to_path_buf())
        } else {
            None
        }
    });
    let active = resolved.is_some();
    let _ = ARTIFACTS_DIR.set(resolved);
    active
}

/// Default artifacts location: `$SAMR_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SAMR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Is the PJRT path configured (vs native fallback)?
pub fn pjrt_active() -> bool {
    matches!(ARTIFACTS_DIR.get(), Some(Some(_)))
}

thread_local! {
    static ENGINE: RefCell<Option<Engine>> = const { RefCell::new(None) };
}

/// A lazily compiled executable: artifacts parse+compile happens on first
/// use, so worker threads only pay for the entry points they run.
struct LazyExe {
    path: PathBuf,
    cell: once_cell::unsync::OnceCell<xla::PjRtLoadedExecutable>,
}

impl LazyExe {
    fn new(path: PathBuf) -> Self {
        Self { path, cell: once_cell::unsync::OnceCell::new() }
    }

    fn get(&self, client: &xla::PjRtClient) -> Result<&xla::PjRtLoadedExecutable> {
        self.cell.get_or_try_init(|| {
            let proto = xla::HloModuleProto::from_text_file(&self.path)
                .map_err(|e| anyhow!("parse {}: {e:?}", self.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", self.path.display()))
        })
    }
}

/// Per-thread PJRT engine: client + lazily compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    map_encode: Vec<(MapEncodeMeta, LazyExe)>,
    group_sort: Vec<(usize, LazyExe)>,
    sample_sort: Vec<(usize, LazyExe)>,
}

impl Engine {
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let map_encode = manifest
            .map_encode
            .iter()
            .map(|(m, p)| (*m, LazyExe::new(p.clone())))
            .collect();
        let group_sort = manifest
            .group_sort
            .iter()
            .map(|(n, p)| (*n, LazyExe::new(p.clone())))
            .collect();
        let sample_sort = manifest
            .sample_sort
            .iter()
            .map(|(n, p)| (*n, LazyExe::new(p.clone())))
            .collect();
        Ok(Engine { client, manifest, map_encode, group_sort, sample_sort })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Pick the cheapest map_encode variant that fits reads of length
    /// `< lp`, the requested prefix length and the boundary count: the
    /// bucket kernel's work is r·lp·nb, so minimize (nb, lp) and prefer
    /// the LARGEST r to amortize PJRT dispatch (§Perf iteration 1).
    fn pick_map_encode(
        &self,
        max_read_len: usize,
        prefix_len: usize,
        n_boundaries: usize,
    ) -> Option<&(MapEncodeMeta, LazyExe)> {
        self.map_encode
            .iter()
            .filter(|(m, _)| {
                m.p == prefix_len && m.lp > max_read_len && m.nb >= n_boundaries
            })
            .min_by_key(|(m, _)| (m.nb, m.lp, std::cmp::Reverse(m.r)))
    }

    /// The tile geometry [`map_encode_tile`] will use for these inputs —
    /// callers chunk reads into `meta.r`-sized tiles.
    pub fn map_encode_meta(
        &self,
        max_read_len: usize,
        prefix_len: usize,
        n_boundaries: usize,
    ) -> Option<MapEncodeMeta> {
        self.pick_map_encode(max_read_len, prefix_len, n_boundaries)
            .map(|(m, _)| *m)
    }

    fn pick_block(blocks: &[(usize, LazyExe)], n: usize) -> Option<&(usize, LazyExe)> {
        blocks.iter().filter(|(b, _)| *b >= n).min_by_key(|(b, _)| *b)
    }

    /// Run the `map_encode` entry point over one tile of reads.
    /// Returns per-(read, offset) keys/indexes/partitions/validity in
    /// row-major [r][lp] order; rows beyond `reads.len()` are padding.
    pub fn map_encode_tile(
        &self,
        reads: &[&Read],
        boundaries: &[i64],
        prefix_len: usize,
    ) -> Result<EncodeTile> {
        let max_len = reads.iter().map(|r| r.len()).max().unwrap_or(0);
        let (meta, exe) = self
            .pick_map_encode(max_len, prefix_len, boundaries.len())
            .ok_or_else(|| anyhow!("no map_encode variant for len {max_len} p {prefix_len}"))?;
        if reads.len() > meta.r {
            bail!("tile of {} reads exceeds variant r={}", reads.len(), meta.r);
        }
        if boundaries.len() > meta.nb {
            bail!("{} boundaries exceed variant nb={}", boundaries.len(), meta.nb);
        }
        let total = meta.lp + meta.p;
        // pack reads into [r, lp+p] i32, zero ($) padded
        let mut flat = vec![0i32; meta.r * total];
        let mut seqs = vec![0i64; meta.r];
        let mut lens = vec![0i32; meta.r];
        for (i, rd) in reads.iter().enumerate() {
            let row = &mut flat[i * total..i * total + rd.len()];
            for (dst, &c) in row.iter_mut().zip(&rd.codes) {
                *dst = c as i32;
            }
            seqs[i] = rd.seq as i64;
            lens[i] = rd.len() as i32;
        }
        let mut bounds = vec![PAD_KEY; meta.nb];
        bounds[..boundaries.len()].copy_from_slice(boundaries);

        let lit_reads = xla::Literal::vec1(&flat)
            .reshape(&[meta.r as i64, total as i64])
            .map_err(|e| anyhow!("reshape reads: {e:?}"))?;
        let lit_seqs = xla::Literal::vec1(&seqs);
        let lit_lens = xla::Literal::vec1(&lens);
        let lit_bounds = xla::Literal::vec1(&bounds);
        let result = exe
            .get(&self.client)?
            .execute::<xla::Literal>(&[lit_reads, lit_seqs, lit_lens, lit_bounds])
            .map_err(|e| anyhow!("execute map_encode: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        let [keys, indexes, partitions, valid]: [xla::Literal; 4] = parts
            .try_into()
            .map_err(|v: Vec<_>| anyhow!("expected 4 outputs, got {}", v.len()))?;
        Ok(EncodeTile {
            r: meta.r,
            lp: meta.lp,
            keys: keys.to_vec::<i64>().map_err(|e| anyhow!("{e:?}"))?,
            indexes: indexes.to_vec::<i64>().map_err(|e| anyhow!("{e:?}"))?,
            partitions: partitions.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            valid: valid.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    /// Sort (key, index) pairs lexicographically via the bitonic kernel.
    pub fn group_sort(&self, keys: &mut Vec<i64>, indexes: &mut Vec<i64>) -> Result<()> {
        let n = keys.len();
        assert_eq!(n, indexes.len());
        if n <= 1 {
            return Ok(());
        }
        let Some((block, exe)) = Self::pick_block(&self.group_sort, n) else {
            bail!("no group_sort variant >= {n}");
        };
        // pad with unique (MAX, MAX - i) sentinels, which sink to the tail
        let mut k = keys.clone();
        let mut ix = indexes.clone();
        for i in 0..(block - n) {
            k.push(PAD_KEY);
            ix.push(i64::MAX - i as i64);
        }
        let result = exe
            .get(&self.client)?
            .execute::<xla::Literal>(&[xla::Literal::vec1(&k), xla::Literal::vec1(&ix)])
            .map_err(|e| anyhow!("execute group_sort: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (ks, ixs) = result.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        let mut ks = ks.to_vec::<i64>().map_err(|e| anyhow!("{e:?}"))?;
        let mut ixs = ixs.to_vec::<i64>().map_err(|e| anyhow!("{e:?}"))?;
        ks.truncate(n);
        ixs.truncate(n);
        *keys = ks;
        *indexes = ixs;
        Ok(())
    }

    /// Ascending key sort via the bitonic kernel.
    pub fn sample_sort(&self, keys: &mut Vec<i64>) -> Result<()> {
        let n = keys.len();
        if n <= 1 {
            return Ok(());
        }
        let Some((block, exe)) = Self::pick_block(&self.sample_sort, n) else {
            bail!("no sample_sort variant >= {n}");
        };
        let mut k = keys.clone();
        k.resize(*block, PAD_KEY);
        let result = exe
            .get(&self.client)?
            .execute::<xla::Literal>(&[xla::Literal::vec1(&k)])
            .map_err(|e| anyhow!("execute sample_sort: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let ks = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        let mut ks = ks.to_vec::<i64>().map_err(|e| anyhow!("{e:?}"))?;
        ks.truncate(n);
        *keys = ks;
        Ok(())
    }

    /// Largest group_sort block available (callers chunk to this).
    pub fn max_group_block(&self) -> usize {
        self.group_sort.iter().map(|(n, _)| *n).max().unwrap_or(0)
    }

    /// Block size the reduce path should chunk to: the bitonic network is
    /// O(n log^2 n), so smaller blocks win per-pair until dispatch
    /// overhead dominates — 1024 measured best on this host (7.6 M vs
    /// 5.2 M pairs/s at 8192; §Perf iteration 2). Override with
    /// SAMR_SORT_BLOCK.
    pub fn preferred_group_block(&self) -> usize {
        if let Some(n) = std::env::var("SAMR_SORT_BLOCK")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            if self.group_sort.iter().any(|(b, _)| *b == n) {
                return n;
            }
        }
        let preferred = 1024;
        self.group_sort
            .iter()
            .map(|(n, _)| *n)
            .filter(|&n| n >= preferred)
            .min()
            .or_else(|| self.group_sort.iter().map(|(n, _)| *n).max())
            .unwrap_or(0)
    }
}

/// Output of one map_encode tile (row-major [r][lp]).
pub struct EncodeTile {
    pub r: usize,
    pub lp: usize,
    pub keys: Vec<i64>,
    pub indexes: Vec<i64>,
    pub partitions: Vec<i32>,
    pub valid: Vec<i32>,
}

/// Run `f` with this thread's engine (compiling artifacts on first use),
/// or `None` if PJRT is not configured.
pub fn with_engine<T>(f: impl FnOnce(Option<&Engine>) -> T) -> T {
    let dir = match ARTIFACTS_DIR.get() {
        Some(Some(d)) => d.clone(),
        _ => return f(None),
    };
    ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            match Engine::load(&dir) {
                Ok(e) => *slot = Some(e),
                Err(err) => {
                    log::warn!("PJRT engine load failed, using native fallback: {err:#}");
                    return f(None);
                }
            }
        }
        f(slot.as_ref())
    })
}

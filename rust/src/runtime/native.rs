//! Pure-Rust fallback with bit-identical semantics to the PJRT entry
//! points — used when artifacts are absent, and cross-checked against the
//! compiled kernels in the integration tests.

use crate::suffix::encode::{pack_index, suffix_key, OFFSET_RADIX};
use crate::suffix::reads::Read;

/// One encoded suffix from the map phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SuffixRec {
    /// Base-5 prefix key.
    pub key: i64,
    /// Packed `seq * 1000 + offset` identity.
    pub index: i64,
    /// Shuffle partition (searchsorted-right over boundaries).
    pub partition: u32,
}

/// partition(k) = #{b : b <= k}; identical to the L1 `bucket` kernel and
/// `RangePartitioner::partition`.
#[inline]
pub fn bucket(key: i64, boundaries: &[i64]) -> u32 {
    boundaries.partition_point(|&b| b <= key) as u32
}

/// Encode every suffix (offsets 0..=len) of `read` — the native
/// equivalent of one `map_encode` row.
pub fn encode_read(
    read: &Read,
    boundaries: &[i64],
    prefix_len: usize,
    out: &mut Vec<SuffixRec>,
) {
    debug_assert!((read.len() as i64) < OFFSET_RADIX);
    for off in 0..=read.len() {
        let key = suffix_key(&read.codes, off, prefix_len);
        out.push(SuffixRec {
            key,
            index: pack_index(read.seq, off),
            partition: bucket(key, boundaries),
        });
    }
}

/// Encode a batch of reads.
pub fn encode_reads(reads: &[Read], boundaries: &[i64], prefix_len: usize) -> Vec<SuffixRec> {
    let mut out = Vec::with_capacity(reads.iter().map(|r| r.suffix_count()).sum());
    for r in reads {
        encode_read(r, boundaries, prefix_len, &mut out);
    }
    out
}

/// Lexicographic (key, index) pair sort — native `group_sort`. Backed
/// by the LSD radix sorter (`util::radix::sort_pairs`): same result as
/// the old permutation comparison sort for every i64 input, but linear
/// in the pair count — the fixed-width-integer regime where radix
/// dominates comparison sorting.
pub fn group_sort(keys: &mut [i64], indexes: &mut [i64]) {
    debug_assert_eq!(keys.len(), indexes.len());
    crate::util::radix::sort_pairs(keys, indexes);
}

/// [`group_sort`] with the radix passes split over `threads` chunks
/// (`util::radix::sort_pairs_threads`). `threads <= 1` dispatches the
/// literal sequential [`group_sort`]; any thread count yields identical
/// arrays.
pub fn group_sort_threads(keys: &mut [i64], indexes: &mut [i64], threads: usize) {
    if threads <= 1 {
        return group_sort(keys, indexes);
    }
    debug_assert_eq!(keys.len(), indexes.len());
    crate::util::radix::sort_pairs_threads(keys, indexes, threads);
}

/// Ascending key sort — native `sample_sort`.
pub fn sample_sort(keys: &mut [i64]) {
    keys.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::encode_prefix;

    #[test]
    fn encode_read_covers_all_offsets() {
        let r = Read::from_ascii(3, b"ACGT");
        let mut out = Vec::new();
        encode_read(&r, &[], 5, &mut out);
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].index, 3000);
        assert_eq!(out[4].index, 3004);
        assert_eq!(out[4].key, 0); // "$"
        assert_eq!(out[0].key, encode_prefix(&r.codes, 5));
        assert!(out.iter().all(|s| s.partition == 0));
    }

    #[test]
    fn bucket_matches_partition_point() {
        let bounds = [10i64, 20, 30];
        assert_eq!(bucket(5, &bounds), 0);
        assert_eq!(bucket(10, &bounds), 1);
        assert_eq!(bucket(29, &bounds), 2);
        assert_eq!(bucket(30, &bounds), 3);
        assert_eq!(bucket(i64::MAX, &bounds), 3);
    }

    #[test]
    fn group_sort_lexicographic() {
        let mut k = vec![3i64, 1, 3, 2];
        let mut ix = vec![30i64, 10, 29, 20];
        group_sort(&mut k, &mut ix);
        assert_eq!(k, vec![1, 2, 3, 3]);
        assert_eq!(ix, vec![10, 20, 29, 30]);
    }
}

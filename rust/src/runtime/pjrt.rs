//! The real PJRT engine (behind the `pjrt` cargo feature): parses HLO
//! text artifacts and executes them on the XLA CPU client via the `xla`
//! bindings. Everything here mirrors the stub in `runtime::mod` exactly.

use std::cell::RefCell;
use std::path::PathBuf;

use crate::runtime::{
    rt_err, EncodeTile, Manifest, MapEncodeMeta, Result, PAD_KEY,
};
use crate::suffix::reads::Read;

thread_local! {
    static ENGINE: RefCell<Option<Engine>> = const { RefCell::new(None) };
}

/// A lazily compiled executable: artifacts parse+compile happens on first
/// use, so worker threads only pay for the entry points they run.
struct LazyExe {
    path: PathBuf,
    cell: std::cell::OnceCell<xla::PjRtLoadedExecutable>,
}

impl LazyExe {
    fn new(path: PathBuf) -> Self {
        Self { path, cell: std::cell::OnceCell::new() }
    }

    fn get(&self, client: &xla::PjRtClient) -> Result<&xla::PjRtLoadedExecutable> {
        if self.cell.get().is_none() {
            let proto = xla::HloModuleProto::from_text_file(&self.path)
                .map_err(|e| rt_err(format!("parse {}: {e:?}", self.path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| rt_err(format!("compile {}: {e:?}", self.path.display())))?;
            let _ = self.cell.set(exe);
        }
        Ok(self.cell.get().expect("just initialized"))
    }
}

/// Per-thread PJRT engine: client + lazily compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    map_encode: Vec<(MapEncodeMeta, LazyExe)>,
    group_sort: Vec<(usize, LazyExe)>,
    sample_sort: Vec<(usize, LazyExe)>,
}

impl Engine {
    /// Load the manifest in `dir` and build the CPU client.
    pub fn load(dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| rt_err(format!("pjrt cpu client: {e:?}")))?;
        let map_encode = manifest
            .map_encode
            .iter()
            .map(|(m, p)| (*m, LazyExe::new(p.clone())))
            .collect();
        let group_sort = manifest
            .group_sort
            .iter()
            .map(|(n, p)| (*n, LazyExe::new(p.clone())))
            .collect();
        let sample_sort = manifest
            .sample_sort
            .iter()
            .map(|(n, p)| (*n, LazyExe::new(p.clone())))
            .collect();
        Ok(Engine { client, manifest, map_encode, group_sort, sample_sort })
    }

    /// The parsed artifacts manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Pick the cheapest map_encode variant that fits reads of length
    /// `< lp`, the requested prefix length and the boundary count: the
    /// bucket kernel's work is r·lp·nb, so minimize (nb, lp) and prefer
    /// the LARGEST r to amortize PJRT dispatch (§Perf iteration 1).
    fn pick_map_encode(
        &self,
        max_read_len: usize,
        prefix_len: usize,
        n_boundaries: usize,
    ) -> Option<&(MapEncodeMeta, LazyExe)> {
        self.map_encode
            .iter()
            .filter(|(m, _)| {
                m.p == prefix_len && m.lp > max_read_len && m.nb >= n_boundaries
            })
            .min_by_key(|(m, _)| (m.nb, m.lp, std::cmp::Reverse(m.r)))
    }

    /// The tile geometry [`Engine::map_encode_tile`] will use for these
    /// inputs — callers chunk reads into `meta.r`-sized tiles.
    pub fn map_encode_meta(
        &self,
        max_read_len: usize,
        prefix_len: usize,
        n_boundaries: usize,
    ) -> Option<MapEncodeMeta> {
        self.pick_map_encode(max_read_len, prefix_len, n_boundaries)
            .map(|(m, _)| *m)
    }

    fn pick_block(blocks: &[(usize, LazyExe)], n: usize) -> Option<&(usize, LazyExe)> {
        blocks.iter().filter(|(b, _)| *b >= n).min_by_key(|(b, _)| *b)
    }

    /// Run the `map_encode` entry point over one tile of reads.
    /// Returns per-(read, offset) keys/indexes/partitions/validity in
    /// row-major [r][lp] order; rows beyond `reads.len()` are padding.
    pub fn map_encode_tile(
        &self,
        reads: &[&Read],
        boundaries: &[i64],
        prefix_len: usize,
    ) -> Result<EncodeTile> {
        let max_len = reads.iter().map(|r| r.len()).max().unwrap_or(0);
        let (meta, exe) = self
            .pick_map_encode(max_len, prefix_len, boundaries.len())
            .ok_or_else(|| {
                rt_err(format!("no map_encode variant for len {max_len} p {prefix_len}"))
            })?;
        if reads.len() > meta.r {
            return Err(rt_err(format!(
                "tile of {} reads exceeds variant r={}",
                reads.len(),
                meta.r
            )));
        }
        if boundaries.len() > meta.nb {
            return Err(rt_err(format!(
                "{} boundaries exceed variant nb={}",
                boundaries.len(),
                meta.nb
            )));
        }
        let total = meta.lp + meta.p;
        // pack reads into [r, lp+p] i32, zero ($) padded
        let mut flat = vec![0i32; meta.r * total];
        let mut seqs = vec![0i64; meta.r];
        let mut lens = vec![0i32; meta.r];
        for (i, rd) in reads.iter().enumerate() {
            let row = &mut flat[i * total..i * total + rd.len()];
            for (dst, &c) in row.iter_mut().zip(&rd.codes) {
                *dst = c as i32;
            }
            seqs[i] = rd.seq as i64;
            lens[i] = rd.len() as i32;
        }
        let mut bounds = vec![PAD_KEY; meta.nb];
        bounds[..boundaries.len()].copy_from_slice(boundaries);

        let lit_reads = xla::Literal::vec1(&flat)
            .reshape(&[meta.r as i64, total as i64])
            .map_err(|e| rt_err(format!("reshape reads: {e:?}")))?;
        let lit_seqs = xla::Literal::vec1(&seqs);
        let lit_lens = xla::Literal::vec1(&lens);
        let lit_bounds = xla::Literal::vec1(&bounds);
        let result = exe
            .get(&self.client)?
            .execute::<xla::Literal>(&[lit_reads, lit_seqs, lit_lens, lit_bounds])
            .map_err(|e| rt_err(format!("execute map_encode: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("to_literal: {e:?}")))?;
        let parts = result.to_tuple().map_err(|e| rt_err(format!("to_tuple: {e:?}")))?;
        let [keys, indexes, partitions, valid]: [xla::Literal; 4] = parts
            .try_into()
            .map_err(|v: Vec<_>| rt_err(format!("expected 4 outputs, got {}", v.len())))?;
        Ok(EncodeTile {
            r: meta.r,
            lp: meta.lp,
            keys: keys.to_vec::<i64>().map_err(|e| rt_err(format!("{e:?}")))?,
            indexes: indexes.to_vec::<i64>().map_err(|e| rt_err(format!("{e:?}")))?,
            partitions: partitions.to_vec::<i32>().map_err(|e| rt_err(format!("{e:?}")))?,
            valid: valid.to_vec::<i32>().map_err(|e| rt_err(format!("{e:?}")))?,
        })
    }

    /// Sort (key, index) pairs lexicographically via the bitonic kernel.
    pub fn group_sort(&self, keys: &mut Vec<i64>, indexes: &mut Vec<i64>) -> Result<()> {
        let n = keys.len();
        assert_eq!(n, indexes.len());
        if n <= 1 {
            return Ok(());
        }
        let Some((block, exe)) = Self::pick_block(&self.group_sort, n) else {
            return Err(rt_err(format!("no group_sort variant >= {n}")));
        };
        // pad with unique (MAX, MAX - i) sentinels, which sink to the tail
        let mut k = keys.clone();
        let mut ix = indexes.clone();
        for i in 0..(block - n) {
            k.push(PAD_KEY);
            ix.push(i64::MAX - i as i64);
        }
        let result = exe
            .get(&self.client)?
            .execute::<xla::Literal>(&[xla::Literal::vec1(&k), xla::Literal::vec1(&ix)])
            .map_err(|e| rt_err(format!("execute group_sort: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("{e:?}")))?;
        let (ks, ixs) = result.to_tuple2().map_err(|e| rt_err(format!("{e:?}")))?;
        let mut ks = ks.to_vec::<i64>().map_err(|e| rt_err(format!("{e:?}")))?;
        let mut ixs = ixs.to_vec::<i64>().map_err(|e| rt_err(format!("{e:?}")))?;
        ks.truncate(n);
        ixs.truncate(n);
        *keys = ks;
        *indexes = ixs;
        Ok(())
    }

    /// Ascending key sort via the bitonic kernel.
    pub fn sample_sort(&self, keys: &mut Vec<i64>) -> Result<()> {
        let n = keys.len();
        if n <= 1 {
            return Ok(());
        }
        let Some((block, exe)) = Self::pick_block(&self.sample_sort, n) else {
            return Err(rt_err(format!("no sample_sort variant >= {n}")));
        };
        let mut k = keys.clone();
        k.resize(*block, PAD_KEY);
        let result = exe
            .get(&self.client)?
            .execute::<xla::Literal>(&[xla::Literal::vec1(&k)])
            .map_err(|e| rt_err(format!("execute sample_sort: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("{e:?}")))?;
        let ks = result.to_tuple1().map_err(|e| rt_err(format!("{e:?}")))?;
        let mut ks = ks.to_vec::<i64>().map_err(|e| rt_err(format!("{e:?}")))?;
        ks.truncate(n);
        *keys = ks;
        Ok(())
    }

    /// Largest group_sort block available (callers chunk to this).
    pub fn max_group_block(&self) -> usize {
        self.group_sort.iter().map(|(n, _)| *n).max().unwrap_or(0)
    }

    /// Block size the reduce path should chunk to: the bitonic network is
    /// O(n log^2 n), so smaller blocks win per-pair until dispatch
    /// overhead dominates — 1024 measured best on this host (7.6 M vs
    /// 5.2 M pairs/s at 8192; §Perf iteration 2). Override with
    /// SAMR_SORT_BLOCK.
    pub fn preferred_group_block(&self) -> usize {
        if let Some(n) = std::env::var("SAMR_SORT_BLOCK")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            if self.group_sort.iter().any(|(b, _)| *b == n) {
                return n;
            }
        }
        let preferred = 1024;
        self.group_sort
            .iter()
            .map(|(n, _)| *n)
            .filter(|&n| n >= preferred)
            .min()
            .or_else(|| self.group_sort.iter().map(|(n, _)| *n).max())
            .unwrap_or(0)
    }
}

/// Run `f` with this thread's engine (compiling artifacts on first use),
/// or `None` if PJRT is not configured or the engine failed to load.
pub(crate) fn with_thread_engine<T>(f: impl FnOnce(Option<&Engine>) -> T) -> T {
    let Some(dir) = crate::runtime::artifacts_dir() else {
        return f(None);
    };
    ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            match Engine::load(&dir) {
                Ok(e) => *slot = Some(e),
                Err(err) => {
                    eprintln!("samr: PJRT engine load failed, using native fallback: {err}");
                    return f(None);
                }
            }
        }
        f(slot.as_ref())
    })
}

//! Reusable RESP service layer: the connection/pipeline/staging
//! machinery of a threaded TCP server, independent of what the commands
//! *mean*.
//!
//! [`RespServer`] owns everything protocol- and transport-shaped —
//! accept loop with worker reaping, per-connection read/dispatch/write
//! loop, pipelining-aware flush policy, arithmetic wire accounting, and
//! the fault-injection hooks — while a [`RespService`] plugs in the
//! command semantics. The KV store (`crate::kvstore::server::Server`)
//! and the sealed-index query tier (`crate::kvstore::query::QueryServer`)
//! are both thin services over this one server; a fault plan or a
//! pipelined client exercised against one is exercising the identical
//! machinery of the other.
//!
//! Replies are staged into a reused in-memory buffer before the socket
//! write. That is not an extra copy for safety's sake — it is the lock
//! discipline: a handler may hold a shared resource (the KV store's
//! mutex) while serializing, and staging guarantees the resource is
//! released before the potentially blocking socket write, so one stalled
//! peer can never wedge the rest of the server.

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::faults::FaultPlan;
use crate::kvstore::resp;
use crate::util::bytes::dec_len;

/// Per-connection command processor. One handler is created per accepted
/// connection (so it can own reusable scratch buffers) and called once
/// per command, in order.
pub trait RespHandler: Send {
    /// Serialize the RESP reply to `args` into `reply` (appending;
    /// `reply` is a staging buffer the server writes to the socket after
    /// this returns) and return the reply's wire length in bytes.
    ///
    /// Infallible in steady state — an `Err` drops the connection, which
    /// is the RESP-appropriate response to a reply that cannot be
    /// serialized at all (malformed *commands* should instead stage a
    /// RESP `Error` reply).
    fn handle(&mut self, args: &[Vec<u8>], reply: &mut Vec<u8>) -> io::Result<u64>;
}

/// A command dialect served over RESP: a factory of per-connection
/// [`RespHandler`]s sharing whatever state the dialect needs (a store
/// mutex, an immutable index, ...).
pub trait RespService: Send + Sync + 'static {
    /// Create the handler for one newly accepted connection.
    fn handler(&self) -> Box<dyn RespHandler>;
}

/// Threaded TCP server speaking RESP for one [`RespService`]. One
/// worker thread per live connection; the accept loop reaps finished
/// workers so long-lived servers stay bounded.
///
/// Pipelined clients send several commands before reading any reply, so
/// the connection loop interleaves: it keeps dispatching as long as more
/// request bytes are already buffered and only flushes the reply stream
/// when the input runs dry. A burst of N pipelined commands then costs
/// one reply flush instead of N, and command processing overlaps the
/// client's request serialization.
pub struct RespServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Total request wire bytes received (network-footprint accounting).
    pub bytes_in: Arc<AtomicU64>,
    /// Total reply wire bytes sent (network-footprint accounting).
    pub bytes_out: Arc<AtomicU64>,
    /// Connection handles still tracked by the accept loop (live
    /// connections plus at most the finished ones not yet reaped).
    tracked: Arc<AtomicUsize>,
    /// Fault-injection plan consulted per connection/request (tests
    /// only; `None` = zero hooks on the serving path).
    faults: Option<Arc<FaultPlan>>,
    /// This server's shard index within the fault plan.
    shard: usize,
    service: Arc<dyn RespService>,
}

impl RespServer {
    /// Bind `127.0.0.1:port` (port 0 = ephemeral) and serve `service`,
    /// optionally under a fault plan as the plan's shard `shard`.
    pub fn start(
        port: u16,
        shard: usize,
        faults: Option<Arc<FaultPlan>>,
        service: Arc<dyn RespService>,
    ) -> io::Result<RespServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let mut server = RespServer {
            addr,
            stop: Arc::new(AtomicBool::new(false)),
            accept_thread: None,
            bytes_in: Arc::new(AtomicU64::new(0)),
            bytes_out: Arc::new(AtomicU64::new(0)),
            tracked: Arc::new(AtomicUsize::new(0)),
            faults,
            shard,
            service,
        };
        server.accept_thread = Some(server.spawn_accept(listener));
        Ok(server)
    }

    /// Spawn the accept loop over an already-bound listener.
    fn spawn_accept(&self, listener: TcpListener) -> JoinHandle<()> {
        let t_stop = self.stop.clone();
        let t_in = self.bytes_in.clone();
        let t_out = self.bytes_out.clone();
        let t_tracked = self.tracked.clone();
        let t_faults = self.faults.clone();
        let t_service = self.service.clone();
        let shard = self.shard;
        std::thread::spawn(move || {
            // each live connection: its worker thread plus a clone of its
            // socket, kept so shutdown can actively close the socket — a
            // worker blocked in a socket read would otherwise pin the
            // join below for as long as an idle client keeps its
            // connection open
            let mut workers: Vec<(JoinHandle<()>, TcpStream)> = Vec::new();
            for conn in listener.incoming() {
                // reap handles of connections that have since closed —
                // a long-lived server would otherwise accumulate one
                // JoinHandle (thread stack bookkeeping included) per
                // completed connection, forever
                let mut i = 0;
                while i < workers.len() {
                    if workers[i].0.is_finished() {
                        // finished: join() returns without blocking
                        let _ = workers.swap_remove(i).0.join();
                    } else {
                        i += 1;
                    }
                }
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(conn) = conn else { break };
                if let Some(plan) = &t_faults {
                    if plan.on_connect(shard) {
                        // shard is down: accept then drop — the client
                        // sees EOF on first use and runs another
                        // reconnect/backoff cycle; each refusal counts
                        // toward the plan's revive trigger
                        drop(conn);
                        continue;
                    }
                }
                let Ok(sock) = conn.try_clone() else {
                    // can't keep a shutdown handle: refuse rather than
                    // accept a connection shutdown couldn't interrupt
                    drop(conn);
                    continue;
                };
                let stop = t_stop.clone();
                let bin = t_in.clone();
                let bout = t_out.clone();
                let faults = t_faults.clone();
                let handler = t_service.handler();
                workers.push((
                    std::thread::spawn(move || {
                        let _ = serve_conn(conn, handler, stop, bin, bout, faults, shard);
                    }),
                    sock,
                ));
                t_tracked.store(workers.len(), Ordering::SeqCst);
            }
            for (w, sock) in workers {
                // unblock the worker's blocking read first: a client that
                // keeps its connection open must never stall shutdown. The
                // client side sees the close as an Io error and runs its
                // idempotent reconnect/replay failover.
                let _ = sock.shutdown(std::net::Shutdown::Both);
                let _ = w.join();
            }
            t_tracked.store(0, Ordering::SeqCst);
        })
    }

    /// Revive a shut-down server: bind the same address again over the
    /// *same* service state (whatever the service shares across
    /// handlers is the availability layer — a revived shard serves
    /// byte-identical data). A no-op on a server that is still running.
    pub fn restart(&mut self) -> io::Result<()> {
        if self.accept_thread.is_some() {
            return Ok(());
        }
        self.stop.store(false, Ordering::SeqCst);
        let listener = TcpListener::bind(self.addr)?;
        self.accept_thread = Some(self.spawn_accept(listener));
        Ok(())
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Connection handles the accept loop currently tracks (as of the
    /// last accepted connection). Stays bounded by the number of
    /// concurrently live connections — completed ones are reaped, not
    /// accumulated.
    pub fn tracked_connections(&self) -> usize {
        self.tracked.load(Ordering::SeqCst)
    }

    /// Stop accepting connections, actively close the live ones, and
    /// join the accept thread. Bounded: never blocks waiting for a
    /// client that keeps its connection open — in-flight clients see
    /// the close as a transport error and fail over.
    pub fn shutdown(&mut self) {
        if self.accept_thread.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for RespServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    conn: TcpStream,
    mut handler: Box<dyn RespHandler>,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<AtomicU64>,
    bytes_out: Arc<AtomicU64>,
    faults: Option<Arc<FaultPlan>>,
    shard: usize,
) -> io::Result<()> {
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut writer = BufWriter::new(conn);
    // reused reply staging buffer — no per-command allocation in steady
    // state, and the handler's locks are released before the socket write
    let mut reply_buf: Vec<u8> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let Some(args) = resp::read_command(&mut reader)? else {
            break; // client closed
        };
        if let Some(plan) = &faults {
            // delay before dispatch — never while the handler holds its
            // locks, so a slow shard stalls only its own replies
            if let Some(d) = plan.reply_delay {
                std::thread::sleep(d);
            }
            if plan.on_request(shard) {
                if plan.process_kill {
                    // a `samr shard` child under a process-kill plan
                    // dies for real: the whole process aborts before
                    // the command executes, and only a driver respawn
                    // (with log replay) brings the shard back
                    std::process::abort();
                }
                // shard dies mid-pipeline: drop the connection without
                // answering — the client sees EOF on a request it
                // already charged, and must replay it after failover
                break;
            }
        }
        // arithmetic wire length — no clones on the request path
        let mut in_len: u64 = 1 + dec_len(args.len() as u64) as u64 + 2;
        for a in &args {
            in_len += resp::bulk_wire_len(a.len());
        }
        bytes_in.fetch_add(in_len, Ordering::Relaxed);
        reply_buf.clear();
        let out_len = handler.handle(&args, &mut reply_buf)?;
        writer.write_all(&reply_buf)?;
        bytes_out.fetch_add(out_len, Ordering::Relaxed);
        // Flush only when no further pipelined request bytes are already
        // buffered: anything still in `reader`'s buffer was fully sent by
        // the client before it started waiting, so delaying the flush
        // cannot deadlock and batches replies for the whole burst.
        if reader.buffer().is_empty() {
            writer.flush()?;
        }
    }
    Ok(())
}

//! Threaded TCP server for one KV instance (the Redis role). One instance
//! per simulated node; the store is a mutex-guarded [`Store`] — Redis
//! itself is single-threaded, so serializing commands is faithful.
//!
//! Pipelined clients send several commands before reading any reply, so
//! the connection loop interleaves: it keeps dispatching as long as more
//! request bytes are already buffered and only flushes the reply stream
//! when the input runs dry. A burst of N pipelined commands then costs
//! one reply flush instead of N, and command processing overlaps the
//! client's request serialization.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::kvstore::resp::{self, Value};
use crate::kvstore::store::{Reply, Store};

/// Shared handle to a running server.
pub struct Server {
    addr: std::net::SocketAddr,
    store: Arc<Mutex<Store>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Total request wire bytes received (network-footprint accounting).
    pub bytes_in: Arc<AtomicU64>,
    /// Total reply wire bytes sent (network-footprint accounting).
    pub bytes_out: Arc<AtomicU64>,
    /// Connection handles still tracked by the accept loop (live
    /// connections plus at most the finished ones not yet reaped).
    tracked: Arc<AtomicUsize>,
}

impl Server {
    /// Bind and serve on `127.0.0.1:port` (port 0 = ephemeral).
    pub fn start(port: u16) -> std::io::Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let store = Arc::new(Mutex::new(Store::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let bytes_in = Arc::new(AtomicU64::new(0));
        let bytes_out = Arc::new(AtomicU64::new(0));

        let t_store = store.clone();
        let t_stop = stop.clone();
        let t_in = bytes_in.clone();
        let t_out = bytes_out.clone();
        let tracked = Arc::new(AtomicUsize::new(0));
        let t_tracked = tracked.clone();
        let accept_thread = std::thread::spawn(move || {
            let mut workers: Vec<JoinHandle<()>> = Vec::new();
            for conn in listener.incoming() {
                // reap handles of connections that have since closed —
                // a long-lived server would otherwise accumulate one
                // JoinHandle (thread stack bookkeeping included) per
                // completed connection, forever
                let mut i = 0;
                while i < workers.len() {
                    if workers[i].is_finished() {
                        // finished: join() returns without blocking
                        let _ = workers.swap_remove(i).join();
                    } else {
                        i += 1;
                    }
                }
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(conn) = conn else { break };
                let store = t_store.clone();
                let stop = t_stop.clone();
                let bin = t_in.clone();
                let bout = t_out.clone();
                workers.push(std::thread::spawn(move || {
                    let _ = serve_conn(conn, store, stop, bin, bout);
                }));
                t_tracked.store(workers.len(), Ordering::SeqCst);
            }
            for w in workers {
                let _ = w.join();
            }
            t_tracked.store(0, Ordering::SeqCst);
        });

        Ok(Server {
            addr,
            store,
            stop,
            accept_thread: Some(accept_thread),
            bytes_in,
            bytes_out,
            tracked,
        })
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Direct (in-process) access to the store — used by the simulator and
    /// by memory-usage probes, bypassing the socket.
    pub fn store(&self) -> &Arc<Mutex<Store>> {
        &self.store
    }

    /// Memory used by the instance (payload + metadata model).
    pub fn used_memory(&self) -> u64 {
        self.store.lock().unwrap().used_memory()
    }

    /// Connection handles the accept loop currently tracks (as of the
    /// last accepted connection). Stays bounded by the number of
    /// concurrently live connections — completed ones are reaped, not
    /// accumulated.
    pub fn tracked_connections(&self) -> usize {
        self.tracked.load(Ordering::SeqCst)
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(&mut self) {
        if self.accept_thread.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn reply_to_value(r: Reply) -> Value {
    match r {
        Reply::Ok => Value::ok(),
        Reply::Int(i) => Value::Int(i),
        Reply::Bulk(b) => Value::Bulk(b),
        Reply::Null => Value::Null,
        Reply::Multi(vs) => Value::Array(
            vs.into_iter()
                .map(|v| v.map(Value::Bulk).unwrap_or(Value::Null))
                .collect(),
        ),
        Reply::Err(e) => Value::Error(e),
    }
}

fn serve_conn(
    conn: TcpStream,
    store: Arc<Mutex<Store>>,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<AtomicU64>,
    bytes_out: Arc<AtomicU64>,
) -> std::io::Result<()> {
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut writer = BufWriter::new(conn);
    while !stop.load(Ordering::SeqCst) {
        let Some(args) = resp::read_command(&mut reader)? else {
            break; // client closed
        };
        // arithmetic wire length — no clones on the request path
        let mut in_len: u64 = 1 + args.len().to_string().len() as u64 + 2;
        for a in &args {
            in_len += 1 + a.len().to_string().len() as u64 + 2 + a.len() as u64 + 2;
        }
        bytes_in.fetch_add(in_len, Ordering::Relaxed);
        let reply = {
            let mut s = store.lock().unwrap();
            s.dispatch(&args)
        };
        let v = reply_to_value(reply);
        bytes_out.fetch_add(v.wire_len(), Ordering::Relaxed);
        resp::write_value(&mut writer, &v)?;
        // Flush only when no further pipelined request bytes are already
        // buffered: anything still in `reader`'s buffer was fully sent by
        // the client before it started waiting, so delaying the flush
        // cannot deadlock and batches replies for the whole burst.
        if reader.buffer().is_empty() {
            writer.flush()?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::client::Client;

    #[test]
    fn accept_loop_reaps_closed_connections() {
        let mut server = Server::start(0).expect("bind");
        let addr = server.addr();
        // many sequential connections, each closed before the next opens:
        // without reaping, the accept loop would track one handle per
        // completed connection (~40 here)
        for i in 0..40u64 {
            let mut c = Client::connect(addr).expect("connect");
            c.set(&i.to_string().into_bytes(), b"v").expect("set");
            // drop closes the socket; give serve_conn a beat to return
        }
        // each probe connection forces a reap pass; poll with a deadline
        // instead of fixed sleeps — on a loaded machine the 40 serve
        // threads can take a while to wind down
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut tracked = usize::MAX;
        while std::time::Instant::now() < deadline {
            // connect (accept loop reaps, then tracks this probe) and
            // disconnect again so shutdown never waits on a live peer
            drop(Client::connect(addr).expect("connect"));
            tracked = server.tracked_connections();
            if tracked <= 4 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(
            tracked <= 4,
            "accept loop leaks finished connection handles: {tracked} still tracked after 40 \
             sequential connections"
        );
        server.shutdown();
        assert_eq!(server.tracked_connections(), 0);
    }
}

//! The KV instance's TCP server (the Redis role): the store's command
//! dialect plugged into the reusable RESP service layer
//! ([`crate::kvstore::service::RespServer`]), which owns the accept
//! loop, pipelining-aware flush policy, wire accounting, and fault
//! hooks. One instance per simulated node; the store is a mutex-guarded
//! [`Store`] — Redis itself is single-threaded, so serializing commands
//! is faithful.

use std::io::Write;
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex};

use crate::faults::FaultPlan;
use crate::kvstore::resp::{self, Value};
use crate::kvstore::service::{RespHandler, RespServer, RespService};
use crate::kvstore::store::{parse_offset, Reply, Store};

/// Shared handle to a running KV server.
pub struct Server {
    inner: RespServer,
    store: Arc<Mutex<Store>>,
    /// Total request wire bytes received (network-footprint accounting).
    pub bytes_in: Arc<AtomicU64>,
    /// Total reply wire bytes sent (network-footprint accounting).
    pub bytes_out: Arc<AtomicU64>,
}

/// The KV command dialect: each connection's handler dispatches into the
/// shared mutex-guarded store.
struct KvService {
    store: Arc<Mutex<Store>>,
}

impl RespService for KvService {
    fn handler(&self) -> Box<dyn RespHandler> {
        Box::new(KvHandler {
            store: self.store.clone(),
            offsets: Vec::new(),
        })
    }
}

/// Per-connection KV dispatcher with reused `MGETSUFFIX` offset scratch.
struct KvHandler {
    store: Arc<Mutex<Store>>,
    offsets: Vec<usize>,
}

impl RespHandler for KvHandler {
    fn handle(&mut self, args: &[Vec<u8>], reply: &mut Vec<u8>) -> std::io::Result<u64> {
        if is_mgetsuffix(args) {
            // hot path: serialize the reply straight from the store's
            // value slices — no Reply::Multi, no Vec per suffix. Staged
            // into the reusable reply buffer (infallible writes) so the
            // store lock is released BEFORE the blocking socket write:
            // a slow peer must never stall other connections at
            // store.lock().
            write_mgetsuffix_reply(args, &self.store, reply, &mut self.offsets)
        } else {
            let r = {
                let mut s = self.store.lock().unwrap();
                s.dispatch(args)
            };
            let v = reply_to_value(r);
            resp::write_value(reply, &v)?;
            Ok(v.wire_len())
        }
    }
}

impl Server {
    /// Bind and serve on `127.0.0.1:port` (port 0 = ephemeral).
    pub fn start(port: u16) -> std::io::Result<Server> {
        Self::start_with_faults(port, 0, None)
    }

    /// [`Server::start`] with a fault-injection plan: this instance is
    /// shard `shard` of the plan, and consults its kill/revive schedule
    /// and reply delay while serving.
    pub fn start_with_faults(
        port: u16,
        shard: usize,
        faults: Option<Arc<FaultPlan>>,
    ) -> std::io::Result<Server> {
        Self::start_with_store(port, shard, faults, Arc::new(Mutex::new(Store::new())))
    }

    /// [`Server::start_with_faults`] over a caller-built store — how a
    /// respawned `samr shard` process serves a store already rebuilt
    /// from its append-only log ([`Store::open_aof`]) instead of an
    /// empty one.
    pub fn start_with_store(
        port: u16,
        shard: usize,
        faults: Option<Arc<FaultPlan>>,
        store: Arc<Mutex<Store>>,
    ) -> std::io::Result<Server> {
        let inner = RespServer::start(
            port,
            shard,
            faults,
            Arc::new(KvService { store: store.clone() }),
        )?;
        Ok(Server {
            bytes_in: inner.bytes_in.clone(),
            bytes_out: inner.bytes_out.clone(),
            store,
            inner,
        })
    }

    /// Revive a shut-down shard: bind the same address again over the
    /// *same* store — the in-memory store is the availability layer
    /// (§"Implementing Suffix Array ... Big Table" leans on exactly
    /// this), so a revived shard serves byte-identical data. A no-op on
    /// a server that is still running.
    pub fn restart(&mut self) -> std::io::Result<()> {
        self.inner.restart()
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    /// Direct (in-process) access to the store — used by the simulator and
    /// by memory-usage probes, bypassing the socket.
    pub fn store(&self) -> &Arc<Mutex<Store>> {
        &self.store
    }

    /// Memory used by the instance (payload + metadata model).
    pub fn used_memory(&self) -> u64 {
        self.store.lock().unwrap().used_memory()
    }

    /// Connection handles the accept loop currently tracks (as of the
    /// last accepted connection). Stays bounded by the number of
    /// concurrently live connections — completed ones are reaped, not
    /// accumulated.
    pub fn tracked_connections(&self) -> usize {
        self.inner.tracked_connections()
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(&mut self) {
        self.inner.shutdown()
    }
}

fn reply_to_value(r: Reply) -> Value {
    match r {
        Reply::Ok => Value::ok(),
        Reply::Int(i) => Value::Int(i),
        Reply::Bulk(b) => Value::Bulk(b),
        Reply::Null => Value::Null,
        Reply::Multi(vs) => Value::Array(
            vs.into_iter()
                .map(|v| v.map(Value::Bulk).unwrap_or(Value::Null))
                .collect(),
        ),
        Reply::Err(e) => Value::Error(e),
    }
}

/// Is this a well-formed `MGETSUFFIX key off [key off ...]` command (the
/// arity [`Store::dispatch`] would accept)? Malformed variants fall
/// through to `dispatch` so its error replies stay byte-identical.
fn is_mgetsuffix(args: &[Vec<u8>]) -> bool {
    args.len() >= 3 && args.len() % 2 == 1 && args[0].eq_ignore_ascii_case(b"MGETSUFFIX")
}

/// Serialize the `MGETSUFFIX` reply straight from [`Store::get_suffix`]
/// slices: `*n` then one bulk (or null) per pair, byte-identical to what
/// `reply_to_value(dispatch(..))` serializes, without materializing a
/// single suffix `Vec`. Returns the reply's wire length — measured as
/// the buffer's growth, so the accounting can never drift from the
/// bytes actually written.
///
/// `w` is an in-memory staging buffer by type, not the socket: the store
/// mutex is held across every write here (that is what lets the slices
/// be borrowed), so a blocking destination would let one stalled peer
/// wedge the whole shard.
///
/// Offsets are validated up front (into the reused `offsets` scratch)
/// because `dispatch` answers a bad offset with one error reply and no
/// partial results — the error must preempt the first array byte.
fn write_mgetsuffix_reply(
    args: &[Vec<u8>],
    store: &Arc<Mutex<Store>>,
    w: &mut Vec<u8>,
    offsets: &mut Vec<usize>,
) -> std::io::Result<u64> {
    let start = w.len();
    offsets.clear();
    for kv in args[1..].chunks(2) {
        match parse_offset(&kv[1]) {
            Some(o) => offsets.push(o),
            None => {
                resp::write_value(w, &Value::Error("ERR bad offset".into()))?;
                return Ok((w.len() - start) as u64);
            }
        }
    }
    // lock held only while serializing into the staging buffer: Redis
    // is single-threaded, so serializing command processing is faithful
    let s = store.lock().unwrap();
    let n = (args.len() - 1) / 2;
    write!(w, "*{n}\r\n")?;
    for (kv, &off) in args[1..].chunks(2).zip(offsets.iter()) {
        match s.get_suffix(&kv[0], off) {
            Some(suffix) => {
                write!(w, "${}\r\n", suffix.len())?;
                w.extend_from_slice(suffix);
                w.extend_from_slice(b"\r\n");
            }
            None => w.extend_from_slice(b"$-1\r\n"),
        }
    }
    Ok((w.len() - start) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::client::Client;
    use std::net::TcpStream;
    use std::sync::atomic::Ordering;

    #[test]
    fn streamed_mgetsuffix_reply_matches_dispatch_bytes() {
        // the streaming fast path must serialize exactly what
        // reply_to_value(dispatch(..)) would, and account it exactly
        let mut direct = Store::new();
        direct.set_exact(b"5".to_vec(), b"ACGTACGT".to_vec());
        let args: Vec<Vec<u8>> = [
            "MGETSUFFIX", "5", "3", "5", "8", "missing", "0", "5", "bogus",
        ]
        .iter()
        .map(|s| s.as_bytes().to_vec())
        .collect();
        for args in [&args[..7], &args[..]] {
            // reference bytes via dispatch + write_value
            let mut expected = Vec::new();
            let v = reply_to_value(direct.dispatch(args));
            resp::write_value(&mut expected, &v).unwrap();
            // streamed bytes
            let shared = Arc::new(Mutex::new(Store::new()));
            shared
                .lock()
                .unwrap()
                .set_exact(b"5".to_vec(), b"ACGTACGT".to_vec());
            let mut streamed = Vec::new();
            let mut offsets = Vec::new();
            let wire =
                write_mgetsuffix_reply(args, &shared, &mut streamed, &mut offsets).unwrap();
            assert_eq!(streamed, expected);
            assert_eq!(wire, expected.len() as u64, "accounted wire length");
        }
    }

    #[test]
    fn server_accounts_streamed_replies_exactly() {
        let server = Server::start(0).expect("bind");
        let mut c = Client::connect(server.addr()).expect("connect");
        c.set(b"1", b"GATTACA").expect("set");
        let out = c
            .mgetsuffix(&[(b"1".to_vec(), 2), (b"1".to_vec(), 7), (b"nope".to_vec(), 0)])
            .expect("mgetsuffix");
        assert_eq!(out, vec![Some(b"TTACA".to_vec()), Some(b"".to_vec()), None]);
        // server-side accounting is arithmetic on the streamed path; the
        // client measures the same reply through materialized Values
        assert_eq!(
            server.bytes_out.load(Ordering::Relaxed),
            c.bytes_received,
            "server bytes_out must equal client bytes_received"
        );
        assert_eq!(server.bytes_in.load(Ordering::Relaxed), c.bytes_sent);
    }

    #[test]
    fn restart_revives_the_shard_with_its_data() {
        let mut server = Server::start(0).expect("bind");
        let addr = server.addr();
        {
            let mut c = Client::connect(addr).expect("connect");
            c.set(b"9", b"MISSISSIPPI").expect("set");
        }
        server.shutdown();
        assert!(
            TcpStream::connect(addr).is_err(),
            "a shut-down shard must refuse connections"
        );
        server.restart().expect("restart");
        server.restart().expect("restart while running is a no-op");
        let mut c = Client::connect(addr).expect("reconnect after restart");
        let out = c.mgetsuffix(&[(b"9".to_vec(), 7)]).expect("fetch");
        // the revived shard serves the same store: data written before
        // the outage is still there
        assert_eq!(out, vec![Some(b"IPPI".to_vec())]);
    }

    #[test]
    fn killed_shard_drops_connections_until_the_plan_revives_it() {
        use crate::faults::{FaultPlan, ShardFault};
        let mut plan = FaultPlan::with_shard_fault(ShardFault {
            shard: 0,
            kill_at_request: 1,
            refuse_connects: 2,
        });
        // cover the delay hook too: every command sleeps briefly first
        plan.reply_delay = Some(std::time::Duration::from_millis(2));
        let plan = Arc::new(plan);
        let server = Server::start_with_faults(0, 0, Some(plan.clone())).expect("bind");
        let mut c = Client::connect(server.addr()).expect("connect");
        c.set(b"1", b"GATTACA").expect("set"); // request 0: passes
        // request 1 trips the kill: the connection drops mid-pipeline,
        // the next two reconnects are accepted-then-dropped, the third
        // revives the shard, and the client's replay completes — all
        // invisible to the caller
        let out = c
            .mgetsuffix(&[(b"1".to_vec(), 2)])
            .expect("client failover must ride out the kill");
        assert_eq!(out, vec![Some(b"TTACA".to_vec())]);
        assert_eq!(plan.shard_kills(), 1);
        assert!(
            c.wasted_sent > 0,
            "replayed request bytes must be charged as waste, not logical traffic"
        );
    }

    /// `shutdown()` racing a pipelined `MGETSUFFIX` window in flight:
    /// the client must either complete the window — failing over to the
    /// restarted shard and replaying its unanswered commands — or fail
    /// cleanly. It must never hang, and a request's logical bytes must
    /// never be charged twice (replays land in `wasted_sent`).
    #[test]
    fn shutdown_races_inflight_pipeline_without_hanging_or_double_charging() {
        use crate::faults::FaultPlan;
        use std::sync::mpsc;
        use std::time::Duration;

        // slow every reply slightly so the shutdown lands mid-window:
        // 150 commands x 2ms of server-side delay dwarf the 30ms fuse
        let plan = FaultPlan::with_reply_delay(Duration::from_millis(2));
        let mut server = Server::start_with_faults(0, 0, Some(Arc::new(plan))).expect("bind");
        let addr = server.addr();
        {
            let mut c = Client::connect(addr).expect("connect");
            c.set(b"1", b"GATTACA").expect("set");
        }
        let reqs: Vec<(Vec<u8>, usize)> = (0..300).map(|i| (b"1".to_vec(), i % 8)).collect();
        let expected: Vec<Option<Vec<u8>>> =
            reqs.iter().map(|(_, o)| Some(b"GATTACA"[*o..].to_vec())).collect();

        let w_reqs = reqs.clone();
        let (tx, rx) = mpsc::channel();
        let worker = std::thread::spawn(move || {
            let mut c = Client::connect(addr).expect("worker connect");
            let r = c.mgetsuffix_pipelined(&w_reqs, 2);
            let _ = tx.send(());
            (r, c.bytes_sent, c.wasted_sent)
        });
        std::thread::sleep(Duration::from_millis(30));
        server.shutdown();
        server.restart().expect("restart");
        rx.recv_timeout(Duration::from_secs(60))
            .expect("the pipelined client must never hang across a shutdown");
        let (r, sent, wasted) = worker.join().expect("worker thread");

        // an uninterrupted client running the identical window is the
        // accounting reference
        let mut control = Client::connect(addr).expect("control connect");
        let out = control.mgetsuffix_pipelined(&reqs, 2).expect("control window");
        assert_eq!(out, expected);

        match r {
            Ok(got) => {
                assert_eq!(got, expected, "completed window must answer correctly");
                assert_eq!(
                    sent, control.bytes_sent,
                    "bytes_sent must be byte-identical to a fault-free window"
                );
                assert!(
                    wasted > 0,
                    "the replayed in-flight commands must be charged as waste"
                );
            }
            Err(e) => {
                // bounded, clean failure is acceptable; double-charged
                // logical traffic is not
                assert!(
                    sent <= control.bytes_sent,
                    "a failed window must not over-charge bytes_sent ({e})"
                );
            }
        }
    }

    #[test]
    fn accept_loop_reaps_closed_connections() {
        let mut server = Server::start(0).expect("bind");
        let addr = server.addr();
        // many sequential connections, each closed before the next opens:
        // without reaping, the accept loop would track one handle per
        // completed connection (~40 here)
        for i in 0..40u64 {
            let mut c = Client::connect(addr).expect("connect");
            c.set(&i.to_string().into_bytes(), b"v").expect("set");
            // drop closes the socket; give serve_conn a beat to return
        }
        // each probe connection forces a reap pass; poll with a deadline
        // instead of fixed sleeps — on a loaded machine the 40 serve
        // threads can take a while to wind down
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut tracked = usize::MAX;
        while std::time::Instant::now() < deadline {
            // connect (accept loop reaps, then tracks this probe) and
            // disconnect again so shutdown never waits on a live peer
            drop(Client::connect(addr).expect("connect"));
            tracked = server.tracked_connections();
            if tracked <= 4 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(
            tracked <= 4,
            "accept loop leaks finished connection handles: {tracked} still tracked after 40 \
             sequential connections"
        );
        server.shutdown();
        assert_eq!(server.tracked_connections(), 0);
    }
}

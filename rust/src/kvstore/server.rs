//! Threaded TCP server for one KV instance (the Redis role). One instance
//! per simulated node; the store is a mutex-guarded [`Store`] — Redis
//! itself is single-threaded, so serializing commands is faithful.
//!
//! Pipelined clients send several commands before reading any reply, so
//! the connection loop interleaves: it keeps dispatching as long as more
//! request bytes are already buffered and only flushes the reply stream
//! when the input runs dry. A burst of N pipelined commands then costs
//! one reply flush instead of N, and command processing overlaps the
//! client's request serialization.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::faults::FaultPlan;
use crate::kvstore::resp::{self, Value};
use crate::kvstore::store::{parse_offset, Reply, Store};
use crate::util::bytes::dec_len;

/// Shared handle to a running server.
pub struct Server {
    addr: std::net::SocketAddr,
    store: Arc<Mutex<Store>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Total request wire bytes received (network-footprint accounting).
    pub bytes_in: Arc<AtomicU64>,
    /// Total reply wire bytes sent (network-footprint accounting).
    pub bytes_out: Arc<AtomicU64>,
    /// Connection handles still tracked by the accept loop (live
    /// connections plus at most the finished ones not yet reaped).
    tracked: Arc<AtomicUsize>,
    /// Fault-injection plan consulted per connection/request (tests
    /// only; `None` = zero hooks on the serving path).
    faults: Option<Arc<FaultPlan>>,
    /// This server's shard index within the fault plan.
    shard: usize,
}

impl Server {
    /// Bind and serve on `127.0.0.1:port` (port 0 = ephemeral).
    pub fn start(port: u16) -> std::io::Result<Server> {
        Self::start_with_faults(port, 0, None)
    }

    /// [`Server::start`] with a fault-injection plan: this instance is
    /// shard `shard` of the plan, and consults its kill/revive schedule
    /// and reply delay while serving.
    pub fn start_with_faults(
        port: u16,
        shard: usize,
        faults: Option<Arc<FaultPlan>>,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let mut server = Server {
            addr,
            store: Arc::new(Mutex::new(Store::new())),
            stop: Arc::new(AtomicBool::new(false)),
            accept_thread: None,
            bytes_in: Arc::new(AtomicU64::new(0)),
            bytes_out: Arc::new(AtomicU64::new(0)),
            tracked: Arc::new(AtomicUsize::new(0)),
            faults,
            shard,
        };
        server.accept_thread = Some(server.spawn_accept(listener));
        Ok(server)
    }

    /// Spawn the accept loop over an already-bound listener.
    fn spawn_accept(&self, listener: TcpListener) -> JoinHandle<()> {
        let t_store = self.store.clone();
        let t_stop = self.stop.clone();
        let t_in = self.bytes_in.clone();
        let t_out = self.bytes_out.clone();
        let t_tracked = self.tracked.clone();
        let t_faults = self.faults.clone();
        let shard = self.shard;
        std::thread::spawn(move || {
            let mut workers: Vec<JoinHandle<()>> = Vec::new();
            for conn in listener.incoming() {
                // reap handles of connections that have since closed —
                // a long-lived server would otherwise accumulate one
                // JoinHandle (thread stack bookkeeping included) per
                // completed connection, forever
                let mut i = 0;
                while i < workers.len() {
                    if workers[i].is_finished() {
                        // finished: join() returns without blocking
                        let _ = workers.swap_remove(i).join();
                    } else {
                        i += 1;
                    }
                }
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(conn) = conn else { break };
                if let Some(plan) = &t_faults {
                    if plan.on_connect(shard) {
                        // shard is down: accept then drop — the client
                        // sees EOF on first use and runs another
                        // reconnect/backoff cycle; each refusal counts
                        // toward the plan's revive trigger
                        drop(conn);
                        continue;
                    }
                }
                let store = t_store.clone();
                let stop = t_stop.clone();
                let bin = t_in.clone();
                let bout = t_out.clone();
                let faults = t_faults.clone();
                workers.push(std::thread::spawn(move || {
                    let _ = serve_conn(conn, store, stop, bin, bout, faults, shard);
                }));
                t_tracked.store(workers.len(), Ordering::SeqCst);
            }
            for w in workers {
                let _ = w.join();
            }
            t_tracked.store(0, Ordering::SeqCst);
        })
    }

    /// Revive a shut-down shard: bind the same address again over the
    /// *same* store — the in-memory store is the availability layer
    /// (§"Implementing Suffix Array ... Big Table" leans on exactly
    /// this), so a revived shard serves byte-identical data. A no-op on
    /// a server that is still running.
    pub fn restart(&mut self) -> std::io::Result<()> {
        if self.accept_thread.is_some() {
            return Ok(());
        }
        self.stop.store(false, Ordering::SeqCst);
        let listener = TcpListener::bind(self.addr)?;
        self.accept_thread = Some(self.spawn_accept(listener));
        Ok(())
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Direct (in-process) access to the store — used by the simulator and
    /// by memory-usage probes, bypassing the socket.
    pub fn store(&self) -> &Arc<Mutex<Store>> {
        &self.store
    }

    /// Memory used by the instance (payload + metadata model).
    pub fn used_memory(&self) -> u64 {
        self.store.lock().unwrap().used_memory()
    }

    /// Connection handles the accept loop currently tracks (as of the
    /// last accepted connection). Stays bounded by the number of
    /// concurrently live connections — completed ones are reaped, not
    /// accumulated.
    pub fn tracked_connections(&self) -> usize {
        self.tracked.load(Ordering::SeqCst)
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(&mut self) {
        if self.accept_thread.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn reply_to_value(r: Reply) -> Value {
    match r {
        Reply::Ok => Value::ok(),
        Reply::Int(i) => Value::Int(i),
        Reply::Bulk(b) => Value::Bulk(b),
        Reply::Null => Value::Null,
        Reply::Multi(vs) => Value::Array(
            vs.into_iter()
                .map(|v| v.map(Value::Bulk).unwrap_or(Value::Null))
                .collect(),
        ),
        Reply::Err(e) => Value::Error(e),
    }
}

fn serve_conn(
    conn: TcpStream,
    store: Arc<Mutex<Store>>,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<AtomicU64>,
    bytes_out: Arc<AtomicU64>,
    faults: Option<Arc<FaultPlan>>,
    shard: usize,
) -> std::io::Result<()> {
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut writer = BufWriter::new(conn);
    // reused MGETSUFFIX scratch (offsets + staged reply bytes) — no
    // per-command allocation in steady state
    let mut offsets: Vec<usize> = Vec::new();
    let mut reply_buf: Vec<u8> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let Some(args) = resp::read_command(&mut reader)? else {
            break; // client closed
        };
        if let Some(plan) = &faults {
            // delay before touching the store — never while holding its
            // lock, so a slow shard stalls only its own replies
            if let Some(d) = plan.reply_delay {
                std::thread::sleep(d);
            }
            if plan.on_request(shard) {
                // shard dies mid-pipeline: drop the connection without
                // answering — the client sees EOF on a request it
                // already charged, and must replay it after failover
                break;
            }
        }
        // arithmetic wire length — no clones on the request path
        let mut in_len: u64 = 1 + dec_len(args.len() as u64) as u64 + 2;
        for a in &args {
            in_len += resp::bulk_wire_len(a.len());
        }
        bytes_in.fetch_add(in_len, Ordering::Relaxed);
        let out_len = if is_mgetsuffix(&args) {
            // hot path: serialize the reply straight from the store's
            // value slices — no Reply::Multi, no Vec per suffix. It is
            // staged in the reused `reply_buf` (infallible writes) so
            // the store lock is released BEFORE the blocking socket
            // write: a slow peer must never stall other connections
            // at store.lock().
            reply_buf.clear();
            let n = write_mgetsuffix_reply(&args, &store, &mut reply_buf, &mut offsets)?;
            writer.write_all(&reply_buf)?;
            n
        } else {
            let reply = {
                let mut s = store.lock().unwrap();
                s.dispatch(&args)
            };
            let v = reply_to_value(reply);
            resp::write_value(&mut writer, &v)?;
            v.wire_len()
        };
        bytes_out.fetch_add(out_len, Ordering::Relaxed);
        // Flush only when no further pipelined request bytes are already
        // buffered: anything still in `reader`'s buffer was fully sent by
        // the client before it started waiting, so delaying the flush
        // cannot deadlock and batches replies for the whole burst.
        if reader.buffer().is_empty() {
            writer.flush()?;
        }
    }
    Ok(())
}

/// Is this a well-formed `MGETSUFFIX key off [key off ...]` command (the
/// arity [`Store::dispatch`] would accept)? Malformed variants fall
/// through to `dispatch` so its error replies stay byte-identical.
fn is_mgetsuffix(args: &[Vec<u8>]) -> bool {
    args.len() >= 3 && args.len() % 2 == 1 && args[0].eq_ignore_ascii_case(b"MGETSUFFIX")
}

/// Serialize the `MGETSUFFIX` reply straight from [`Store::get_suffix`]
/// slices: `*n` then one bulk (or null) per pair, byte-identical to what
/// `reply_to_value(dispatch(..))` serializes, without materializing a
/// single suffix `Vec`. Returns the reply's wire length — measured as
/// the buffer's growth, so the accounting can never drift from the
/// bytes actually written.
///
/// `w` is an in-memory staging buffer by type, not the socket: the store
/// mutex is held across every write here (that is what lets the slices
/// be borrowed), so a blocking destination would let one stalled peer
/// wedge the whole shard.
///
/// Offsets are validated up front (into the reused `offsets` scratch)
/// because `dispatch` answers a bad offset with one error reply and no
/// partial results — the error must preempt the first array byte.
fn write_mgetsuffix_reply(
    args: &[Vec<u8>],
    store: &Arc<Mutex<Store>>,
    w: &mut Vec<u8>,
    offsets: &mut Vec<usize>,
) -> std::io::Result<u64> {
    let start = w.len();
    offsets.clear();
    for kv in args[1..].chunks(2) {
        match parse_offset(&kv[1]) {
            Some(o) => offsets.push(o),
            None => {
                resp::write_value(w, &Value::Error("ERR bad offset".into()))?;
                return Ok((w.len() - start) as u64);
            }
        }
    }
    // lock held only while serializing into the staging buffer: Redis
    // is single-threaded, so serializing command processing is faithful
    let s = store.lock().unwrap();
    let n = (args.len() - 1) / 2;
    write!(w, "*{n}\r\n")?;
    for (kv, &off) in args[1..].chunks(2).zip(offsets.iter()) {
        match s.get_suffix(&kv[0], off) {
            Some(suffix) => {
                write!(w, "${}\r\n", suffix.len())?;
                w.extend_from_slice(suffix);
                w.extend_from_slice(b"\r\n");
            }
            None => w.extend_from_slice(b"$-1\r\n"),
        }
    }
    Ok((w.len() - start) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::client::Client;

    #[test]
    fn streamed_mgetsuffix_reply_matches_dispatch_bytes() {
        // the streaming fast path must serialize exactly what
        // reply_to_value(dispatch(..)) would, and account it exactly
        let mut direct = Store::new();
        direct.set_exact(b"5".to_vec(), b"ACGTACGT".to_vec());
        let args: Vec<Vec<u8>> = [
            "MGETSUFFIX", "5", "3", "5", "8", "missing", "0", "5", "bogus",
        ]
        .iter()
        .map(|s| s.as_bytes().to_vec())
        .collect();
        for args in [&args[..7], &args[..]] {
            // reference bytes via dispatch + write_value
            let mut expected = Vec::new();
            let v = reply_to_value(direct.dispatch(args));
            resp::write_value(&mut expected, &v).unwrap();
            // streamed bytes
            let shared = Arc::new(Mutex::new(Store::new()));
            shared
                .lock()
                .unwrap()
                .set_exact(b"5".to_vec(), b"ACGTACGT".to_vec());
            let mut streamed = Vec::new();
            let mut offsets = Vec::new();
            let wire =
                write_mgetsuffix_reply(args, &shared, &mut streamed, &mut offsets).unwrap();
            assert_eq!(streamed, expected);
            assert_eq!(wire, expected.len() as u64, "accounted wire length");
        }
    }

    #[test]
    fn server_accounts_streamed_replies_exactly() {
        let server = Server::start(0).expect("bind");
        let mut c = Client::connect(server.addr()).expect("connect");
        c.set(b"1", b"GATTACA").expect("set");
        let out = c
            .mgetsuffix(&[(b"1".to_vec(), 2), (b"1".to_vec(), 7), (b"nope".to_vec(), 0)])
            .expect("mgetsuffix");
        assert_eq!(out, vec![Some(b"TTACA".to_vec()), Some(b"".to_vec()), None]);
        // server-side accounting is arithmetic on the streamed path; the
        // client measures the same reply through materialized Values
        assert_eq!(
            server.bytes_out.load(Ordering::Relaxed),
            c.bytes_received,
            "server bytes_out must equal client bytes_received"
        );
        assert_eq!(server.bytes_in.load(Ordering::Relaxed), c.bytes_sent);
    }

    #[test]
    fn restart_revives_the_shard_with_its_data() {
        let mut server = Server::start(0).expect("bind");
        let addr = server.addr();
        {
            let mut c = Client::connect(addr).expect("connect");
            c.set(b"9", b"MISSISSIPPI").expect("set");
        }
        server.shutdown();
        assert!(
            TcpStream::connect(addr).is_err(),
            "a shut-down shard must refuse connections"
        );
        server.restart().expect("restart");
        server.restart().expect("restart while running is a no-op");
        let mut c = Client::connect(addr).expect("reconnect after restart");
        let out = c.mgetsuffix(&[(b"9".to_vec(), 7)]).expect("fetch");
        // the revived shard serves the same store: data written before
        // the outage is still there
        assert_eq!(out, vec![Some(b"IPPI".to_vec())]);
    }

    #[test]
    fn killed_shard_drops_connections_until_the_plan_revives_it() {
        use crate::faults::{FaultPlan, ShardFault};
        let mut plan = FaultPlan::with_shard_fault(ShardFault {
            shard: 0,
            kill_at_request: 1,
            refuse_connects: 2,
        });
        // cover the delay hook too: every command sleeps briefly first
        plan.reply_delay = Some(std::time::Duration::from_millis(2));
        let plan = Arc::new(plan);
        let server = Server::start_with_faults(0, 0, Some(plan.clone())).expect("bind");
        let mut c = Client::connect(server.addr()).expect("connect");
        c.set(b"1", b"GATTACA").expect("set"); // request 0: passes
        // request 1 trips the kill: the connection drops mid-pipeline,
        // the next two reconnects are accepted-then-dropped, the third
        // revives the shard, and the client's replay completes — all
        // invisible to the caller
        let out = c
            .mgetsuffix(&[(b"1".to_vec(), 2)])
            .expect("client failover must ride out the kill");
        assert_eq!(out, vec![Some(b"TTACA".to_vec())]);
        assert_eq!(plan.shard_kills(), 1);
        assert!(
            c.wasted_sent > 0,
            "replayed request bytes must be charged as waste, not logical traffic"
        );
    }

    #[test]
    fn accept_loop_reaps_closed_connections() {
        let mut server = Server::start(0).expect("bind");
        let addr = server.addr();
        // many sequential connections, each closed before the next opens:
        // without reaping, the accept loop would track one handle per
        // completed connection (~40 here)
        for i in 0..40u64 {
            let mut c = Client::connect(addr).expect("connect");
            c.set(&i.to_string().into_bytes(), b"v").expect("set");
            // drop closes the socket; give serve_conn a beat to return
        }
        // each probe connection forces a reap pass; poll with a deadline
        // instead of fixed sleeps — on a loaded machine the 40 serve
        // threads can take a while to wind down
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut tracked = usize::MAX;
        while std::time::Instant::now() < deadline {
            // connect (accept loop reaps, then tracks this probe) and
            // disconnect again so shutdown never waits on a live peer
            drop(Client::connect(addr).expect("connect"));
            tracked = server.tracked_connections();
            if tracked <= 4 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(
            tracked <= 4,
            "accept loop leaks finished connection handles: {tracked} still tracked after 40 \
             sequential connections"
        );
        server.shutdown();
        assert_eq!(server.tracked_connections(), 0);
    }
}
